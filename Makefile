PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test bench-quick bench lint

test:                      ## tier-1 test suite
	$(PYTHON) -m pytest -x -q

bench-quick:               ## reduced-size benchmarks + JSON (CI, CPU interpret)
	$(PYTHON) -m benchmarks.run --quick --json

bench:                     ## full benchmark suite + JSON
	$(PYTHON) -m benchmarks.run --json

lint:                      ## ruff (config in pyproject.toml)
	@command -v ruff >/dev/null 2>&1 \
		&& ruff check src tests benchmarks examples \
		|| echo "ruff not installed; skipping (pip install ruff)"
