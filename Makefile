PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

# LINT_STRICT=1 makes a missing ruff an ERROR instead of a soft skip (CI
# always sets it; local runs without ruff keep working).
LINT_STRICT ?=

.PHONY: test bench-quick bench bench-check lint docs-check

test:                      ## tier-1 test suite
	$(PYTHON) -m pytest -x -q

bench-quick:               ## reduced-size benchmarks + JSON (CI, CPU interpret)
	$(PYTHON) -m benchmarks.run --quick --json

bench:                     ## full benchmark suite + JSON
	$(PYTHON) -m benchmarks.run --json

bench-check:               ## e7+e8+e9 quick run + regression gate vs committed BENCH_engine.json
	$(PYTHON) -m benchmarks.run --quick --json --only e7 e8 e9
	$(PYTHON) benchmarks/check_regression.py

docs-check:                ## verify README/DESIGN/docs cross-references resolve
	$(PYTHON) tools/check_docs.py

lint:                      ## ruff (config in pyproject.toml); LINT_STRICT=1 to require ruff
	@if command -v ruff >/dev/null 2>&1; then \
		ruff check src tests benchmarks examples tools; \
	elif [ -n "$(LINT_STRICT)" ]; then \
		echo "ERROR: ruff not installed but LINT_STRICT=1 (pip install ruff)" >&2; \
		exit 1; \
	else \
		echo "ruff not installed; skipping (pip install ruff; LINT_STRICT=1 to fail instead)"; \
	fi
