PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

# LINT_STRICT=1 makes a missing ruff an ERROR instead of a soft skip (CI
# always sets it; local runs without ruff keep working).
LINT_STRICT ?=
# COV_STRICT=1 makes a missing pytest-cov an ERROR (CI sets it); COV_FLOOR is
# the committed line-coverage floor for the public-API packages repro.core +
# repro.fedsim — a conservative ratchet, raise it as measured coverage allows.
COV_STRICT ?=
COV_FLOOR ?= 75
# PYTEST_FLAGS passes extra flags through every pytest target, e.g.
#     make test PYTEST_FLAGS="-n auto"     # pytest-xdist (1-device legs ONLY:
# each xdist worker re-initializes jax under the leg's XLA_FLAGS, so on the
# forced-8-host-device leg N workers x 8 devices oversubscribes the runner
# and distorts the wall-clock/fault-timing assertions — keep that leg serial).
PYTEST_FLAGS ?=

.PHONY: test test-fast coverage bench-quick bench bench-check lint docs-check

test:                      ## tier-1 test suite (full matrix, slow sweeps included)
	$(PYTHON) -m pytest -x -q $(PYTEST_FLAGS)

test-fast:                 ## tier-1 minus the `slow` cross-engine sweeps (local iteration)
	$(PYTHON) -m pytest -x -q -m "not slow" $(PYTEST_FLAGS)

coverage:                  ## tier-1 suite under pytest-cov with the committed floor
	@if $(PYTHON) -c "import pytest_cov" 2>/dev/null; then \
		$(PYTHON) -m pytest -x -q $(PYTEST_FLAGS) \
			--cov=repro.core --cov=repro.fedsim \
			--cov-report=term --cov-report=xml:coverage.xml \
			--cov-fail-under=$(COV_FLOOR); \
	elif [ -n "$(COV_STRICT)" ]; then \
		echo "ERROR: pytest-cov not installed but COV_STRICT=1 (pip install pytest-cov)" >&2; \
		exit 1; \
	else \
		echo "pytest-cov not installed; running plain tests (pip install pytest-cov; COV_STRICT=1 to fail instead)"; \
		$(PYTHON) -m pytest -x -q $(PYTEST_FLAGS); \
	fi

bench-quick:               ## reduced-size benchmarks + JSON (CI, CPU interpret)
	$(PYTHON) -m benchmarks.run --quick --json

bench:                     ## full benchmark suite + JSON
	$(PYTHON) -m benchmarks.run --json

bench-check:               ## e7+e8+e9 quick run + regression gate vs committed BENCH_engine.json
	$(PYTHON) -m benchmarks.run --quick --json --only e7 e8 e9
	$(PYTHON) benchmarks/check_regression.py

docs-check:                ## verify README/DESIGN/docs cross-references resolve
	$(PYTHON) tools/check_docs.py

lint:                      ## ruff (config in pyproject.toml); LINT_STRICT=1 to require ruff
	@if command -v ruff >/dev/null 2>&1; then \
		ruff check src tests benchmarks examples tools; \
	elif [ -n "$(LINT_STRICT)" ]; then \
		echo "ERROR: ruff not installed but LINT_STRICT=1 (pip install ruff)" >&2; \
		exit 1; \
	else \
		echo "ruff not installed; skipping (pip install ruff; LINT_STRICT=1 to fail instead)"; \
	fi
