"""End-to-end driver: DP-FedEXP federated training of a transformer LM.

This is the datacenter path (repro.launch) on real hardware-free CPU: the same
``train_step`` that the 512-chip dry-run lowers, executed eagerly on a small
cohort, with checkpointing and a token pipeline.

    PYTHONPATH=src python examples/train_federated_lm.py                 # ~12M params, quick
    PYTHONPATH=src python examples/train_federated_lm.py --d-model 768 \
        --layers 12 --rounds 200                                         # ~100M-class run

Synthetic token stream (offline container): each client draws from its own
Markov chain over the vocab so client data is genuinely heterogeneous — the
regime DP-FedEXP targets.
"""
import argparse
import sys

sys.path.insert(0, "src")

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt
from repro.configs import ARCHS, FederatedConfig, reduced
from repro.launch.rules import count_params
from repro.launch.train import FederatedTrainer
from repro.models.transformer import DecoderLM
from repro.telemetry import CompositeTracker, JsonlTracker, StdoutTracker


def make_client_stream(key, num_clients: int, vocab: int, *, order_states: int = 64):
    """Per-client Markov chains: shared backbone + client-specific transitions."""
    k1, k2 = jax.random.split(key)
    base = jax.random.dirichlet(k1, 0.5 * jnp.ones(vocab), (order_states,))
    biases = jax.random.dirichlet(k2, 0.3 * jnp.ones(vocab), (num_clients, order_states))
    trans = 0.5 * base[None] + 0.5 * biases          # (M, S, V)
    cum = jnp.cumsum(trans, axis=-1)

    def sample(key, client, tau, b, s):
        def tok_step(carry, k):
            state = carry
            u = jax.random.uniform(k, state.shape)
            row = cum[client, state % order_states]          # (..., V)
            nxt = jnp.argmax(u[..., None] <= row, axis=-1)
            return nxt.astype(jnp.int32), nxt.astype(jnp.int32)

        keys = jax.random.split(key, s)
        init = jnp.zeros((tau, b), jnp.int32)
        _, toks = jax.lax.scan(tok_step, init, keys)
        return jnp.moveaxis(toks, 0, -1)                      # (tau, b, s)

    return sample


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b", help="family to reduce from")
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--vocab", type=int, default=2048)
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--cohort", type=int, default=4)
    ap.add_argument("--tau", type=int, default=2)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--algorithm", default="cdp-fedexp")
    ap.add_argument("--ckpt-dir", default="results/ckpt_lm")
    ap.add_argument("--telemetry", default=None, metavar="PATH",
                    help="also stream per-round JSONL telemetry to PATH")
    args = ap.parse_args()

    cfg = dataclasses.replace(
        reduced(ARCHS[args.arch], layers=args.layers, d_model=args.d_model),
        vocab_size=args.vocab)
    model = DecoderLM(cfg, attn_impl="xla_flash", remat=False)
    fed = FederatedConfig(algorithm=args.algorithm, local_steps=args.tau,
                          local_lr=0.05, clip_norm=1.0, noise_sigma=0.05)
    n = count_params(model)
    print(f"model: {cfg.name} d={args.d_model} L={args.layers} vocab={args.vocab} "
          f"-> {n/1e6:.1f}M params; algorithm={args.algorithm}")

    trainer = FederatedTrainer(model, fed, n)
    step = jax.jit(trainer.make_train_step(cohort_k=args.cohort))
    params = model.init(jax.random.PRNGKey(0))
    sampler = make_client_stream(jax.random.PRNGKey(1), args.cohort, args.vocab)

    # host-driven round loop: the tracker is fed directly (repro.telemetry,
    # DESIGN.md §15) — StdoutTracker prints on the historical cadence and
    # --telemetry adds a machine-readable JSONL stream of EVERY round
    tracker = StdoutTracker(every=5, prefix="lm ")
    if args.telemetry is not None:
        tracker = CompositeTracker(tracker, JsonlTracker(args.telemetry))
    tracker.start_phase("train", 0)
    for t in range(args.rounds):
        kd = jax.random.fold_in(jax.random.PRNGKey(2), t)
        toks = jnp.stack([
            sampler(jax.random.fold_in(kd, i), i, args.tau, args.batch, args.seq + 1)
            for i in range(args.cohort)])
        batch = {"tokens": toks[..., :-1], "labels": toks[..., 1:]}
        t0 = time.time()
        params, metrics = step(params, batch, jax.random.fold_in(jax.random.PRNGKey(3), t))
        tracker.log(t, {"loss": float(metrics["loss"]),
                        "eta": float(metrics["eta_g"]),
                        "update_norm": float(metrics["mean_update_norm"]),
                        "round_time_s": time.time() - t0})
    tracker.finish()
    path = ckpt.save_checkpoint(args.ckpt_dir, args.rounds, params,
                                extra={"algorithm": args.algorithm})
    print(f"checkpoint -> {path}")


if __name__ == "__main__":
    main()
