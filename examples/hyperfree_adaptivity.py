"""Remark 3.1 demo: one step-size rule, every noise scale — no retuning.

Runs CDP-FedEXP with the SAME configuration across a sweep of DP noise levels
and cohort sizes. The adaptive eta_g shrinks automatically as the effective
noise d*sigma^2/M grows — the behaviour that would otherwise require a
privacy-leaking global-learning-rate grid search (the paper's core argument
against FedOpt-style servers in DP-FL).

    PYTHONPATH=src python examples/hyperfree_adaptivity.py
"""
import math
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.core.fedexp import make_algorithm
from repro.data.synthetic import distance_to_opt, linreg_loss, make_synthetic_linreg
from repro.fedsim import FederatedSession, TrainSpec

D, TAU, ROUNDS, CLIP, ETA_L = 200, 20, 30, 0.3, 0.1

print(f"{'M':>6} {'sigma_mult':>10} {'mean eta_g':>10} {'final dist':>11}")
for m in (200, 1000):
    data = make_synthetic_linreg(jax.random.PRNGKey(0), m, D)
    for noise_mult in (1.0, 3.0, 10.0):
        sigma = noise_mult * 5 * CLIP / math.sqrt(m)
        alg = make_algorithm("cdp-fedexp", clip_norm=CLIP, sigma=sigma, num_clients=m)
        session = FederatedSession(
            alg, linreg_loss, jnp.zeros(D), data.client_batches(),
            train=TrainSpec(rounds=ROUNDS, tau=TAU, eta_l=ETA_L),
            eval_fn=distance_to_opt(data.w_star))
        r = session.run(jax.random.PRNGKey(7))
        print(f"{m:>6} {noise_mult:>10.1f} {float(jnp.mean(r.eta_history)):>10.2f} "
              f"{float(r.metric_history[-1]):>11.4f}")

print("\neta_g falls as noise grows and rises with cohort size M —")
print("the rule is adaptive to the EFFECTIVE noise d*sigma^2/M (Remark 3.1).")
