"""Serve a small model with batched requests: prefill + greedy decode.

The same ``ServeEngine`` steps that the decode_32k / long_500k dry-runs lower
to the 512-chip mesh, executed eagerly on CPU for a reduced model.

    PYTHONPATH=src python examples/serve_batched.py --arch mamba2-2.7b
    PYTHONPATH=src python examples/serve_batched.py --arch gemma-2b --new 24
"""
import argparse
import sys

sys.path.insert(0, "src")

import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, reduced
from repro.launch.serve import ServeEngine
from repro.models.transformer import DecoderLM


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new", type=int, default=16)
    args = ap.parse_args()

    cfg = reduced(ARCHS[args.arch])
    if cfg.arch_type == "audio":
        raise SystemExit("use a decoder arch for this example")
    model = DecoderLM(cfg, attn_impl="dense", remat=False)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model)

    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0, cfg.vocab_size)
    cache_len = args.prompt_len + args.new

    t0 = time.time()
    out = engine.generate(params, prompts, max_new=args.new,
                          cache_len=cache_len, dtype=jnp.float32)
    dt = time.time() - t0
    toks = args.batch * args.new
    print(f"arch={args.arch} ({cfg.arch_type}, reduced)  batch={args.batch}  "
          f"prompt={args.prompt_len}  new={args.new}")
    print(f"generated {toks} tokens in {dt:.2f}s  ({toks/dt:.1f} tok/s on CPU)")
    for i in range(min(2, args.batch)):
        print(f"  req{i}: ...{list(map(int, prompts[i, -4:]))} -> "
              f"{list(map(int, out[i]))}")


if __name__ == "__main__":
    main()
