"""Quickstart: DP-FedEXP vs DP-FedAvg via the session API (DESIGN.md §10).

    PYTHONPATH=src python examples/quickstart.py            # paper-scale CDP run
    PYTHONPATH=src python examples/quickstart.py --quick    # CI smoke (seconds)

Runs the paper's CDP setting (M=1000 clients, tau=20 local steps, 50 rounds)
and prints the distance to the shared optimum plus the adaptive step size.

A run is a ``FederatedSession`` bound to four frozen specs:

    TrainSpec(rounds, tau, eta_l)     what to train
    EngineSpec(chunk_rounds, ...)     how to compile it (default: ONE scan
                                      program for all rounds, cached across
                                      runs of the same session)
    ShardSpec(mesh=make_client_mesh())  partition clients across devices
                                      (DESIGN.md §9; on CPU force host devices
                                      first: XLA_FLAGS=--xla_force_host_
                                      platform_device_count=8)
    CohortSpec(q=0.25)                per-round client sampling with
                                      amplification-aware accounting
                                      (session.privacy_report)

``session.run(key, checkpoint_dir=...)`` makes the run resumable;
``session.resume(dir)`` continues it bit-exactly.  Pass a parameter PYTREE
(e.g. ``repro.models.cnn`` params) instead of a flat vector and the session
ravels/unravels at the boundary — see README.md for the pytree quickstart.

``--telemetry out.jsonl`` streams per-round events (eta, metric, cumulative
privacy ledger, round wall-clock) to a JSONL file WHILE the compiled run
executes — results stay bit-identical (DESIGN.md §15).

``--schedule`` adds a third leg, ``cdp-fedexp-schedule``: the same CDP
FedEXP run under a decaying noise schedule sigma(t) = sigma0 * 0.9**t
(DESIGN.md §17).  Its telemetry stream carries the per-round ``sigma`` the
device actually used, which ``tools/check_telemetry.py --sigma0 S
--sigma-decay 0.9`` pins against the declared schedule in CI.
"""
import argparse
import math
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.core.fedexp import make_algorithm
from repro.data.synthetic import distance_to_opt, linreg_loss, make_synthetic_linreg
from repro.fedsim import CohortSpec, FederatedSession, TrainSpec
from repro.telemetry import JsonlTracker

# grid-searched on this generation (EXPERIMENTS.md): (eta_l, C) per algorithm
HPS = {"dp-fedavg-cdp": (0.3, 3.0), "cdp-fedexp": (0.1, 0.3),
       "cdp-fedexp-schedule": (0.1, 0.3)}

# §17 demo schedule: sigma(t) = sigma0 * SCHEDULE_DECAY**t; CI pins the
# telemetry stream against exactly this decay (check_telemetry --sigma-decay)
SCHEDULE_DECAY = 0.9


def main(quick: bool = False, sampled_q: float | None = None,
         telemetry: str | None = None, schedule: bool = False):
    m, d, rounds, tau = (120, 64, 8, 5) if quick else (1000, 500, 50, 20)
    data = make_synthetic_linreg(jax.random.PRNGKey(0), m, d)
    w0 = jnp.zeros(d)
    eval_fn = distance_to_opt(data.w_star)
    cohort = CohortSpec() if sampled_q is None else CohortSpec(q=sampled_q)
    eval_every = 2 if quick else 10

    names = ["dp-fedavg-cdp", "cdp-fedexp"]
    if schedule:
        names.append("cdp-fedexp-schedule")
    for name in names:
        eta_l, clip = HPS[name]
        kw = dict(clip_norm=clip, sigma=5 * clip / math.sqrt(m),
                  num_clients=m)
        if name == "cdp-fedexp-schedule":
            kw["decay"] = SCHEDULE_DECAY
        alg = make_algorithm(name, **kw)
        session = FederatedSession(
            alg, linreg_loss, w0, data.client_batches(),
            train=TrainSpec(rounds=rounds, tau=tau, eta_l=eta_l,
                            eval_every=eval_every),
            cohort=cohort, eval_fn=eval_fn)
        # one tracker file per algorithm: quickstart.jsonl -> quickstart-<alg>.jsonl
        tracker = None
        if telemetry is not None:
            stem, dot, ext = telemetry.rpartition(".")
            path = f"{stem}-{name}.{ext}" if dot else f"{telemetry}-{name}"
            tracker = JsonlTracker(path)
        result = session.run(jax.random.PRNGKey(42), tracker=tracker)
        dist = float(eval_fn(result.final_w))
        etas = result.eta_history
        report = session.privacy_report(delta=1e-5)
        print(f"{name:16s}  final ||w - w*|| = {dist:8.4f}   "
              f"eta_g: first={float(etas[0]):.2f} last={float(etas[-1]):.2f}   "
              f"eps={report.eps_numerical:.2f}")
        # eval runs on the eval_every cadence; eval_rounds() drops the
        # NaN placeholder rows so only measured rounds print
        trail = "  ".join(f"t={t}: {v:.3f}"
                          for t, v in result.eval_rounds()[-3:])
        print(f"{'':16s}  ||w - w*|| trail: {trail}")

    print("\nDP-FedEXP reaches a closer iterate at the SAME privacy budget —")
    print("the global step size is derived from already-privatized statistics.")
    if sampled_q is not None:
        print(f"(sampled cohorts q={sampled_q}: epsilon accounts for the "
              "subsampled release — conditional-sensitivity inflation plus "
              "GDP amplification, see accounting.cdp_budget)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small geometry for CI smoke runs")
    ap.add_argument("--sampled-q", type=float, default=None,
                    help="per-round Bernoulli client sampling rate")
    ap.add_argument("--telemetry", default=None, metavar="PATH",
                    help="stream per-round JSONL telemetry to PATH "
                         "(one file per algorithm; DESIGN.md §15)")
    ap.add_argument("--schedule", action="store_true",
                    help="also run cdp-fedexp under a decaying noise "
                         f"schedule sigma(t) = sigma0 * {SCHEDULE_DECAY}**t "
                         "(DESIGN.md §17)")
    args = ap.parse_args()
    main(quick=args.quick, sampled_q=args.sampled_q, telemetry=args.telemetry,
         schedule=args.schedule)
