"""Quickstart: DP-FedEXP vs DP-FedAvg on the paper's synthetic problem.

    PYTHONPATH=src python examples/quickstart.py

Runs the paper's CDP setting (M=1000 clients, tau=20 local steps, 50 rounds)
and prints the distance to the shared optimum plus the adaptive step size.

The chunked-scan engine compiles all 50 rounds as ONE XLA program (histories
come back as stacked scan outputs); pass ``chunk_rounds=k`` to
``run_federated`` to trade compile time for ceil(50/k) dispatches instead,
or ``engine="eager"`` for the legacy one-program-per-round loop (see
DESIGN.md §8 and benchmarks/e7_engine_throughput.py).

Client sharding (DESIGN.md §9): to partition the M=1000 clients across
devices, pass a client mesh —

    from repro.launch.mesh import make_client_mesh
    run_federated(..., mesh=make_client_mesh())

On a CPU-only box, force several host devices BEFORE jax is imported to try
it locally (results match the single-device engine to ~1e-5):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/quickstart.py
"""
import math
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.core.fedexp import make_algorithm
from repro.data.synthetic import distance_to_opt, linreg_loss, make_synthetic_linreg
from repro.fedsim.server import run_federated

M, D, ROUNDS, TAU = 1000, 500, 50, 20
# grid-searched on this generation (EXPERIMENTS.md): (eta_l, C) per algorithm
HPS = {"dp-fedavg-cdp": (0.3, 3.0), "cdp-fedexp": (0.1, 0.3)}

data = make_synthetic_linreg(jax.random.PRNGKey(0), M, D)
w0 = jnp.zeros(D)
eval_fn = distance_to_opt(data.w_star)

for name in ("dp-fedavg-cdp", "cdp-fedexp"):
    eta_l, clip = HPS[name]
    alg = make_algorithm(name, clip_norm=clip,
                         sigma=5 * clip / math.sqrt(M), num_clients=M)
    result = run_federated(alg, linreg_loss, w0, data.client_batches(),
                           rounds=ROUNDS, tau=TAU, eta_l=eta_l,
                           key=jax.random.PRNGKey(42), eval_fn=eval_fn)
    dist = float(eval_fn(result.final_w))
    etas = result.eta_history
    print(f"{name:16s}  final ||w - w*|| = {dist:8.4f}   "
          f"eta_g: first={float(etas[0]):.2f} last={float(etas[-1]):.2f}")

print("\nDP-FedEXP reaches a closer iterate at the SAME privacy budget —")
print("the global step size is derived from already-privatized statistics.")
