"""Telemetry JSONL validator: schema + invariants of a §15 tracker stream.

    python tools/check_telemetry.py out.jsonl [more.jsonl ...]
    python tools/check_telemetry.py --rounds 50 out.jsonl   # pin round count

Validates the stream a ``JsonlTracker`` writes (one JSON object per line):

* every line parses as a JSON object;
* ROUND lines carry an integer ``"round"`` plus the per-round schema
  (``eta`` / ``eta_naive`` / ``eta_target`` floats-or-null, optional
  ``metric`` / ``clip`` / ``sigma`` / ``participants`` / fault totals /
  ledger fields) — unknown keys fail, so schema drift is caught in CI, not
  by a consumer;
* ``sigma`` (the §17 per-round noise std) when present must be finite and
  nonnegative; ``--sigma0 S [--sigma-decay D]`` additionally pins it to the
  declared schedule ``S * D**t`` (f32 tolerance) on EVERY executed round;
* CONTROL lines carry ``"event"`` (rollback / profile_start / profile_stop
  and their documented fields) and are exempt from the round schema;
* round indices are contiguous from the first round seen, except across a
  ``rollback`` event, which rewinds the expectation to its ``to_round``;
* the cumulative ledger is monotone: ``ledger_rounds`` strictly increases
  and ``eps`` / ``mu`` never decrease over executed rounds;
* with ``--rounds T``: exactly T distinct non-frozen round lines (retried
  rounds may deliver a round index more than once — the LAST delivery
  counts, matching the resumable-run semantics);
* ``bytes_per_round`` (the §16 communication footprint, 4 * comm_floats(d))
  when present must be a finite positive number and CONSTANT across the
  stream — it is static for a fixed spec; ``--require-bytes`` makes it
  mandatory on every executed round.

Pure stdlib so it runs in every CI leg with zero extra dependencies.
Exit 0 = valid, exit 1 = violations (each printed with its line number).
"""
from __future__ import annotations

import argparse
import json
import math
import numbers
import sys

# per-round payload keys the engine tap can emit (fedsim/server.py ->
# repro.telemetry.tap); "seed" joins via run_batched sub-trackers
ROUND_KEYS = {
    "round", "seed", "round_time_s", "frozen",
    "eta", "eta_naive", "eta_target", "metric", "clip", "sigma",
    "participants",
    "realized_clients", "dropped", "stragglers", "corrupt",
    "watchdog_fault_round", "bytes_per_round",
    "ledger_rounds", "mu", "eps", "eps_rdp", "ledger_error",
}
EVENT_KEYS = {
    "rollback": {"event", "round", "to_round", "attempt", "seed"},
    "profile_start": {"event", "round", "trace_dir", "seed"},
    "profile_stop": {"event", "round", "trace_dir", "seed"},
}


def _num_or_null(v) -> bool:
    return v is None or (isinstance(v, numbers.Real)
                         and not isinstance(v, bool))


def check_stream(lines, *, rounds: int | None = None,
                 require_bytes: bool = False,
                 sigma0: float | None = None, sigma_decay: float = 1.0,
                 label: str = "<stream>") -> list[str]:
    """Return a list of violations (empty = valid).

    ``sigma0`` (with ``sigma_decay``) pins the §17 per-round noise-std field
    against the declared schedule: every executed round must carry a
    ``sigma`` within f32 tolerance of ``sigma0 * sigma_decay ** t``.  Without
    it, any ``sigma`` present is only required to be finite and nonnegative
    (the tap omits the field for mechanisms with no shared noise std).
    """
    errors: list[str] = []
    expected: int | None = None
    last_ledger_rounds = 0
    last_eps = last_mu = float("-inf")
    delivered: dict[int, dict] = {}
    # §16: bytes_per_round is 4 * comm_floats(d), STATIC for a fixed spec —
    # any variation within one stream means the tap recomputed it wrong
    bytes_seen: float | None = None

    for n, raw in enumerate(lines, start=1):
        raw = raw.strip()
        if not raw:
            continue
        try:
            obj = json.loads(raw)
        except json.JSONDecodeError as e:
            errors.append(f"{label}:{n}: not valid JSON ({e})")
            continue
        if not isinstance(obj, dict):
            errors.append(f"{label}:{n}: not a JSON object")
            continue

        if "event" in obj:
            kind = obj["event"]
            allowed = EVENT_KEYS.get(kind)
            if allowed is None:
                errors.append(f"{label}:{n}: unknown event {kind!r}")
                continue
            extra = set(obj) - allowed
            if extra:
                errors.append(f"{label}:{n}: event {kind!r} has unexpected "
                              f"keys {sorted(extra)}")
            if kind == "rollback":
                to = obj.get("to_round")
                if not isinstance(to, int):
                    errors.append(f"{label}:{n}: rollback without integer "
                                  "to_round")
                else:
                    expected = to
            continue

        t = obj.get("round")
        if not isinstance(t, int) or isinstance(t, bool):
            errors.append(f"{label}:{n}: round line without integer 'round'")
            continue
        extra = set(obj) - ROUND_KEYS
        if extra:
            errors.append(f"{label}:{n}: unexpected round keys "
                          f"{sorted(extra)}")
        if expected is not None and t != expected:
            errors.append(f"{label}:{n}: round {t} breaks contiguity "
                          f"(expected {expected})")
        expected = t + 1

        if obj.get("frozen"):
            continue  # watchdog-frozen placeholder: no eta, no ledger
        for k in ("eta", "eta_naive", "eta_target", "metric", "clip",
                  "sigma", "round_time_s", "mu", "eps", "eps_rdp", "loss"):
            if k in obj and not _num_or_null(obj[k]):
                errors.append(f"{label}:{n}: {k} is not a number or null")
        if "eta" not in obj:
            errors.append(f"{label}:{n}: executed round without 'eta'")
        # §17: the tap omits sigma for mechanisms with no shared noise std,
        # so a delivered sigma must be a finite nonnegative number — and must
        # track the declared schedule when one is pinned on the CLI
        if "sigma" in obj:
            s = obj["sigma"]
            if (not isinstance(s, numbers.Real) or isinstance(s, bool)
                    or not math.isfinite(s) or s < 0):
                errors.append(f"{label}:{n}: sigma {s!r} is not a finite "
                              "nonnegative number")
            elif sigma0 is not None:
                want = sigma0 * sigma_decay ** t
                # the device computes sigma(t) in f32; compare at f32 rtol
                if abs(float(s) - want) > 1e-5 * max(abs(want), 1e-12):
                    errors.append(f"{label}:{n}: sigma {s} does not match "
                                  f"the declared schedule "
                                  f"{sigma0}*{sigma_decay}^{t} = {want}")
        elif sigma0 is not None:
            errors.append(f"{label}:{n}: executed round without 'sigma' "
                          "(--sigma0 pins the schedule on every round)")
        if "bytes_per_round" in obj:
            b = obj["bytes_per_round"]
            if (not isinstance(b, numbers.Real) or isinstance(b, bool)
                    or not math.isfinite(b) or b <= 0):
                errors.append(f"{label}:{n}: bytes_per_round {b!r} is not a "
                              "finite positive number")
            elif bytes_seen is None:
                bytes_seen = float(b)
            elif float(b) != bytes_seen:
                errors.append(f"{label}:{n}: bytes_per_round changed "
                              f"({b} != {bytes_seen}) — it is static for a "
                              "fixed spec")
        elif require_bytes:
            errors.append(f"{label}:{n}: executed round without "
                          "'bytes_per_round' (--require-bytes)")
        delivered[t] = obj
        if "ledger_rounds" in obj:
            lr = obj["ledger_rounds"]
            if not isinstance(lr, int) or lr <= last_ledger_rounds:
                errors.append(f"{label}:{n}: ledger_rounds {lr!r} not "
                              f"strictly increasing (last "
                              f"{last_ledger_rounds})")
            else:
                last_ledger_rounds = lr
            for k, last in (("eps", last_eps), ("mu", last_mu)):
                v = obj.get(k)
                if isinstance(v, numbers.Real) and v < last:
                    errors.append(f"{label}:{n}: ledger {k} decreased "
                                  f"({v} < {last})")
            last_eps = max(last_eps, obj.get("eps", last_eps) or last_eps)
            last_mu = max(last_mu, obj.get("mu", last_mu) or last_mu)

    if rounds is not None and len(delivered) != rounds:
        errors.append(f"{label}: expected {rounds} distinct executed rounds, "
                      f"saw {len(delivered)}")
    return errors


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("paths", nargs="+", help="JSONL telemetry files")
    ap.add_argument("--rounds", type=int, default=None,
                    help="require exactly this many distinct executed rounds")
    ap.add_argument("--require-bytes", action="store_true",
                    help="require bytes_per_round on every executed round "
                         "(§16 communication footprint)")
    ap.add_argument("--sigma0", type=float, default=None,
                    help="require a per-round 'sigma' matching the declared "
                         "schedule sigma0 * sigma-decay^t (§17)")
    ap.add_argument("--sigma-decay", type=float, default=1.0,
                    help="exponential decay of the declared sigma schedule "
                         "(default 1.0 = constant)")
    args = ap.parse_args()

    failures: list[str] = []
    for path in args.paths:
        with open(path) as f:
            failures += check_stream(f, rounds=args.rounds,
                                     require_bytes=args.require_bytes,
                                     sigma0=args.sigma0,
                                     sigma_decay=args.sigma_decay,
                                     label=path)
    if failures:
        print(f"{len(failures)} telemetry violations:")
        for f in failures:
            print(" ", f)
        sys.exit(1)
    print(f"telemetry OK: {len(args.paths)} file(s) validated")


if __name__ == "__main__":
    main()
