"""Docs cross-reference checker: every link and §-reference must resolve.

    python tools/check_docs.py          # from the repo root
    make docs-check

Scans the repo-root markdown files plus everything under ``docs/`` and
fails (exit 1) when:

* a relative markdown link ``[text](path)`` points at a file that does not
  exist (external ``http(s)://`` / ``mailto:`` targets are skipped, and a
  ``#fragment`` suffix is ignored for existence purposes);
* a ``§N`` section reference (e.g. ``DESIGN.md §12``, ``§9 sharding``, the
  range ``§1–§12``) names a section with no matching ``## §N`` heading in
  DESIGN.md — the one file that owns § numbering.  Dotted paper-section
  references like ``§3.2`` resolve through their integer part, which is how
  the docs use them.

Pure stdlib so it runs in every CI leg with zero extra dependencies.
"""
from __future__ import annotations

import pathlib
import re
import sys

# [text](target) — excluding images is unnecessary (we have none), but the
# negative lookbehind keeps badge-style ![...](...) out just in case
LINK_RE = re.compile(r"(?<!\!)\[[^\]]+\]\(([^)\s]+)\)")
SECTION_RE = re.compile(r"§(\d+)")
HEADING_RE = re.compile(r"^##\s+§(\d+)\b", re.MULTILINE)


def _doc_files(root: pathlib.Path) -> list[pathlib.Path]:
    files = sorted(root.glob("*.md"))
    files += sorted((root / "docs").glob("**/*.md"))
    return [f for f in files if f.is_file()]


def check(root: pathlib.Path) -> list[str]:
    """Return a list of human-readable failures (empty = all good)."""
    failures: list[str] = []
    design = root / "DESIGN.md"
    headings: set[int] = set()
    if design.is_file():
        headings = {int(m) for m in HEADING_RE.findall(design.read_text())}
    else:
        failures.append("DESIGN.md missing — § references cannot resolve")

    for f in _doc_files(root):
        text = f.read_text()
        rel = f.relative_to(root)
        for target in LINK_RE.findall(text):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            path = (f.parent / target.split("#", 1)[0]).resolve()
            if not path.exists():
                failures.append(f"{rel}: broken link -> {target}")
        for sec in SECTION_RE.findall(text):
            if int(sec) not in headings:
                failures.append(
                    f"{rel}: §{sec} has no '## §{sec}' heading in DESIGN.md")
    return failures


def main() -> int:
    """CLI entry point: check the repo this script lives in."""
    root = pathlib.Path(__file__).resolve().parent.parent
    failures = check(root)
    for msg in failures:
        print(f"FAIL {msg}")
    if failures:
        print(f"FAIL docs link check: {len(failures)} unresolved reference(s)")
        return 1
    n = len(_doc_files(root))
    print(f"OK  docs link check: {n} markdown files, all links and "
          "§ references resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
