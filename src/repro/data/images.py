"""Generated MNIST-like image classification dataset.

The container is offline, so the realistic experiment uses a *generated*
28x28 10-class dataset with MNIST-like statistics instead of the MNIST files
(deviation recorded in DESIGN.md §7 and EXPERIMENTS.md).  Each class has a
fixed smooth random template (low-frequency random field); a sample is the
template under a small random affine-ish distortion (shift + per-pixel jitter)
plus Gaussian pixel noise, clipped to [0, 1].  Classes are well separated but
not linearly trivial — a 2-conv CNN reaches high accuracy, a linear model does
not saturate, and the Dirichlet label split induces genuine client
heterogeneity, which is what the experiment is actually probing.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["ImageDataset", "make_image_dataset"]


@dataclasses.dataclass
class ImageDataset:
    train_x: jax.Array   # (N, 28, 28, 1) in [0, 1]
    train_y: jax.Array   # (N,) int32
    test_x: jax.Array
    test_y: jax.Array
    num_classes: int = 10


def _smooth_random_field(key: jax.Array, n: int, size: int = 28, cutoff: int = 6) -> jax.Array:
    """n low-frequency random images via truncated 2-D Fourier synthesis."""
    k_re, k_im = jax.random.split(key)
    coef = (jax.random.normal(k_re, (n, cutoff, cutoff))
            + 1j * jax.random.normal(k_im, (n, cutoff, cutoff)))
    spec = jnp.zeros((n, size, size), jnp.complex64).at[:, :cutoff, :cutoff].set(coef)
    img = jnp.real(jnp.fft.ifft2(spec)) * size
    img = (img - img.min(axis=(1, 2), keepdims=True))
    img = img / jnp.maximum(img.max(axis=(1, 2), keepdims=True), 1e-6)
    return img


def _make_split(key, templates, n, noise, shift_px):
    k_lab, k_shift, k_noise, k_gain = jax.random.split(key, 4)
    labels = jax.random.randint(k_lab, (n,), 0, templates.shape[0])
    imgs = templates[labels]
    # random small translation via jnp.roll (vectorized with vmap)
    shifts = jax.random.randint(k_shift, (n, 2), -shift_px, shift_px + 1)
    imgs = jax.vmap(lambda im, s: jnp.roll(im, (s[0], s[1]), axis=(0, 1)))(imgs, shifts)
    gain = 0.8 + 0.4 * jax.random.uniform(k_gain, (n, 1, 1))
    imgs = jnp.clip(imgs * gain + noise * jax.random.normal(k_noise, imgs.shape), 0.0, 1.0)
    return imgs[..., None], labels


def make_image_dataset(
    key: jax.Array,
    num_train: int = 12000,
    num_test: int = 2000,
    noise: float = 0.15,
    shift_px: int = 2,
) -> ImageDataset:
    k_tpl, k_tr, k_te = jax.random.split(key, 3)
    templates = _smooth_random_field(k_tpl, 10)
    train_x, train_y = _make_split(k_tr, templates, num_train, noise, shift_px)
    test_x, test_y = _make_split(k_te, templates, num_test, noise, shift_px)
    return ImageDataset(train_x=train_x, train_y=train_y.astype(jnp.int32),
                        test_x=test_x, test_y=test_y.astype(jnp.int32))
