"""Data substrate: synthetic linreg, generated images, partitioners, LM tokens."""

from repro.data.dirichlet import client_image_batches, dirichlet_partition
from repro.data.images import ImageDataset, make_image_dataset
from repro.data.synthetic import (
    SyntheticLinReg,
    distance_to_opt,
    linreg_loss,
    make_synthetic_linreg,
)

__all__ = [
    "SyntheticLinReg", "make_synthetic_linreg", "linreg_loss", "distance_to_opt",
    "ImageDataset", "make_image_dataset",
    "dirichlet_partition", "client_image_batches",
]
