"""Synthetic heterogeneous linear-regression dataset (paper §5 / Appendix E.1).

Generation (verbatim from E.1):
    w* ~ N(0, I_d)                       shared optimum across clients
    u_i ~ N(0, 0.1)                      per-client heterogeneity level
    m_i ~ N(u_i, 1)                      per-client feature mean (scalar)
    x_i ~ N(m_i * 1, I_d)                client i's feature vector
    y_i = x_i^T w*
    f_i(w) = (x_i^T w - y_i)^2

All clients share the minimizer w*, so the overparameterized-POCS picture
behind FedEXP (approximate projection condition, Eq. 4) holds.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["SyntheticLinReg", "make_synthetic_linreg", "linreg_loss", "distance_to_opt"]


@dataclasses.dataclass
class SyntheticLinReg:
    x: jax.Array        # (M, d)
    y: jax.Array        # (M,)
    w_star: jax.Array   # (d,)

    @property
    def num_clients(self) -> int:
        return self.x.shape[0]

    @property
    def dim(self) -> int:
        return self.x.shape[1]

    def client_batches(self):
        return {"x": self.x, "y": self.y}


def make_synthetic_linreg(key: jax.Array, num_clients: int, dim: int,
                          *, unit_features: bool = True) -> SyntheticLinReg:
    """Paper E.1 generation. ``unit_features`` normalizes each x_i to unit L2.

    The paper leaves the feature scale implicit; at the literal N(m_i, I_d)
    scale the local curvature 2||x_i||^2 ~ 2d(1+m_i^2) makes every learning
    rate in the paper's own grid locally unstable (2 eta_l ||x||^2 >> 1), so
    their effective scale must have been normalized. Unit features give unit
    curvature, the POCS projection picture of FedEXP, and stable local GD for
    the paper's grid — recorded as a deviation in DESIGN.md §7.
    """
    k_w, k_u, k_m, k_x = jax.random.split(key, 4)
    w_star = jax.random.normal(k_w, (dim,))
    u = jnp.sqrt(0.1) * jax.random.normal(k_u, (num_clients,))
    m = u + jax.random.normal(k_m, (num_clients,))
    x = m[:, None] + jax.random.normal(k_x, (num_clients, dim))
    if unit_features:
        x = x / jnp.linalg.norm(x, axis=1, keepdims=True)
    y = x @ w_star
    return SyntheticLinReg(x=x, y=y, w_star=w_star)


def linreg_loss(w: jax.Array, batch) -> jax.Array:
    """f_i(w) = (x_i^T w - y_i)^2 for one client."""
    resid = jnp.dot(batch["x"], w) - batch["y"]
    return jnp.square(resid)


def distance_to_opt(w_star: jax.Array):
    """Eval closure: ||w - w*|| (Fig. 1 left metric)."""

    def fn(w):
        return jnp.linalg.norm(w - w_star)

    return fn
