"""Label-Dirichlet federated partitioner (Hsu, Qi, Brown 2019).

For each client, class proportions p_i ~ Dir(alpha * 1_K); samples are drawn
to match. alpha=0.3 (the paper's setting) gives strongly non-IID clients.
Returns fixed-size padded per-client batches (mask-weighted loss) so the whole
cohort is vmappable.
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

__all__ = ["dirichlet_partition"]


def dirichlet_partition(
    seed: int,
    labels: np.ndarray,
    num_clients: int,
    alpha: float = 0.3,
    samples_per_client: int | None = None,
):
    """Partition sample indices across clients with Dir(alpha) label skew.

    Returns dict with 'idx' (M, n) int32 sample indices and 'mask' (M, n)
    float32 validity mask (padding repeats a valid index with mask 0).
    """
    rng = np.random.default_rng(seed)
    labels = np.asarray(labels)
    num_classes = int(labels.max()) + 1
    n_total = len(labels)
    per_client = samples_per_client or max(1, n_total // num_clients)

    by_class = [np.flatnonzero(labels == c) for c in range(num_classes)]
    idx = np.zeros((num_clients, per_client), np.int32)
    mask = np.ones((num_clients, per_client), np.float32)

    props = rng.dirichlet(alpha * np.ones(num_classes), size=num_clients)
    for i in range(num_clients):
        counts = rng.multinomial(per_client, props[i])
        chosen: list[np.ndarray] = []
        for c, k in enumerate(counts):
            if k == 0:
                continue
            pool = by_class[c]
            chosen.append(rng.choice(pool, size=k, replace=k > len(pool)))
        flat = np.concatenate(chosen) if chosen else np.array([0], np.int64)
        if len(flat) < per_client:  # defensive; multinomial sums to per_client
            flat = np.pad(flat, (0, per_client - len(flat)), mode="edge")
            mask[i, len(flat):] = 0.0
        idx[i] = flat[:per_client]
    return {"idx": jnp.asarray(idx), "mask": jnp.asarray(mask)}


def client_image_batches(dataset, part):
    """Materialize per-client padded batches from a partition."""
    x = dataset.train_x[part["idx"]]           # (M, n, 28, 28, 1)
    y = dataset.train_y[part["idx"]]           # (M, n)
    return {"x": x, "y": y, "mask": part["mask"]}
