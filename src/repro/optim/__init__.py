"""Server-side optimizers for federated pseudo-gradients.

DP-FedEXP's contribution is the *adaptive scalar* global step size; this
package provides the orthogonal axis — what the server does with the
(scaled) pseudo-gradient. ``sgd`` recovers the paper exactly; ``adam`` /
``momentum`` implement the FedOpt family (Reddi et al., 2021) that the paper
argues against (extra hyperparameters), kept as baselines and for the
beyond-paper ablations. All are pure (state, update) -> (state, step)
transforms over flat vectors or pytrees.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["Optimizer", "sgd", "momentum", "adam", "apply_update"]


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any], tuple[Any, Any]]  # (grad-like, state) -> (step, state)


def _tmap(f, *trees):
    return jax.tree_util.tree_map(f, *trees)


def sgd(lr: float = 1.0) -> Optimizer:
    """Plain scaling — lr=1 is exactly the paper's server update."""

    def init(params):
        return ()

    def update(g, state):
        return _tmap(lambda x: lr * x, g), state

    return Optimizer(init, update)


def momentum(lr: float = 1.0, beta: float = 0.9) -> Optimizer:
    def init(params):
        return _tmap(lambda p: jnp.zeros_like(p, jnp.float32), params)

    def update(g, m):
        m = _tmap(lambda mm, gg: beta * mm + gg.astype(jnp.float32), m, g)
        return _tmap(lambda mm: lr * mm, m), m

    return Optimizer(init, update)


def adam(lr: float = 1e-3, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8) -> Optimizer:
    """FedAdam (server Adam over pseudo-gradients)."""

    def init(params):
        z = _tmap(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return (z, _tmap(jnp.copy, z), jnp.zeros((), jnp.int32))

    def update(g, state):
        m, v, t = state
        t = t + 1
        m = _tmap(lambda mm, gg: b1 * mm + (1 - b1) * gg.astype(jnp.float32), m, g)
        v = _tmap(lambda vv, gg: b2 * vv + (1 - b2) * jnp.square(gg.astype(jnp.float32)), v, g)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)
        step = _tmap(lambda mm, vv: lr * (mm / bc1) / (jnp.sqrt(vv / bc2) + eps), m, v)
        return step, (m, v, t)

    return Optimizer(init, update)


def apply_update(params, step):
    """w <- w + step (pseudo-gradient ascent on the aggregated update)."""
    return _tmap(lambda p, s: (p.astype(jnp.float32) + s.astype(jnp.float32)).astype(p.dtype),
                 params, step)
