"""Decoder-only LM assembling the model zoo: dense / MoE / SSM / hybrid / VLM.

Layers are *stacked* (leading L axis) and executed with ``lax.scan`` so that
trace/compile time stays flat in depth (64-layer 104B configs lower in
seconds). The hybrid (zamba2) stack scans over super-blocks of
``hybrid_attn_every`` Mamba2 layers followed by ONE weight-shared attention
block (closed over, so its gradients sum over application sites — tied
weights).

The LM head is evaluated in sequence chunks under ``jax.checkpoint`` so the
(tokens, vocab) logits tensor never materializes for the full sequence
(vocab 256k x 1M tokens would be ~1 TB); this is the standard
memory-efficient CE and is exact.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import mlp as mlp_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.common import Param, init_params, logical_specs, rms_norm, sinusoidal_positions
from repro.models.sharding import shard

__all__ = ["DecoderLM"]

_MOE_AUX_COEF = 0.01


def _block_defs(cfg: ModelConfig) -> dict[str, Param]:
    """Parameter defs for ONE block of the scanned stack."""
    if cfg.arch_type in ("dense", "vlm"):
        return {
            "ln1": Param((cfg.d_model,), (None,)),
            "ln2": Param((cfg.d_model,), (None,)),
            **attn_mod.attention_defs(cfg),
            **mlp_mod.mlp_defs(cfg),
        }
    if cfg.arch_type == "moe":
        return {
            "ln1": Param((cfg.d_model,), (None,)),
            "ln2": Param((cfg.d_model,), (None,)),
            **attn_mod.attention_defs(cfg),
            **moe_mod.moe_defs(cfg),
        }
    if cfg.arch_type in ("ssm", "hybrid"):
        return {
            "ln1": Param((cfg.d_model,), (None,)),
            **ssm_mod.ssm_defs(cfg),
        }
    raise ValueError(cfg.arch_type)


def _shared_attn_defs(cfg: ModelConfig) -> dict[str, Param]:
    """zamba2's weight-shared attention(+MLP) block."""
    return {
        "ln1": Param((cfg.d_model,), (None,)),
        "ln2": Param((cfg.d_model,), (None,)),
        **attn_mod.attention_defs(cfg),
        **mlp_mod.mlp_defs(cfg),
    }


@dataclasses.dataclass
class DecoderLM:
    cfg: ModelConfig
    dtype: Any = jnp.float32
    attn_impl: str = "xla_flash"
    remat: bool = True
    remat_policy: str | None = None   # None = full remat; "dots" saves matmuls
    loss_chunk: int = 512
    max_positions: int = 32_768   # sinusoidal table rows (non-RoPE archs)

    # ------------------------------------------------------------------ init

    def init(self, key: jax.Array) -> dict:
        cfg = self.cfg
        k_embed, k_blocks, k_shared, k_head = jax.random.split(key, 4)
        defs = _block_defs(cfg)
        block_keys = jax.random.split(k_blocks, cfg.num_layers)
        blocks = jax.vmap(lambda k: init_params(k, defs, self.dtype))(block_keys)
        params = {
            "embed": (jax.random.normal(k_embed, (cfg.vocab_size, cfg.d_model), jnp.float32)
                      * 0.02).astype(self.dtype),
            "final_norm": jnp.zeros((cfg.d_model,), self.dtype),
            "blocks": blocks,
        }
        if cfg.arch_type == "hybrid":
            params["shared_attn"] = init_params(k_shared, _shared_attn_defs(cfg), self.dtype)
        if not cfg.tie_embeddings:
            params["head"] = (jax.random.normal(k_head, (cfg.d_model, cfg.vocab_size), jnp.float32)
                              / jnp.sqrt(float(cfg.d_model))).astype(self.dtype)
        return params

    def pspecs(self) -> dict:
        cfg = self.cfg
        defs = _block_defs(cfg)
        blocks = {k: ("layers",) + v for k, v in logical_specs(defs).items()}
        specs = {
            "embed": ("vocab", "embed"),
            "final_norm": (None,),
            "blocks": blocks,
        }
        if cfg.arch_type == "hybrid":
            specs["shared_attn"] = logical_specs(_shared_attn_defs(cfg))
        if not cfg.tie_embeddings:
            specs["head"] = ("embed", "vocab")
        return specs

    # --------------------------------------------------------------- blocks

    def _apply_block(self, bp, x, *, positions, cache=None, decode_pos=None):
        cfg = self.cfg
        if cfg.arch_type in ("dense", "vlm", "moe"):
            h = rms_norm(x, bp["ln1"], cfg.norm_eps)
            a, cache = attn_mod.attention_apply(
                bp, h, cfg, positions=positions, cache=cache,
                decode_pos=decode_pos, impl=self.attn_impl)
            if cfg.parallel_block:
                m = rms_norm(x, bp["ln2"], cfg.norm_eps)
                if cfg.arch_type == "moe":
                    f, aux = moe_mod.moe_apply(bp, m, cfg)
                else:
                    f, aux = mlp_mod.mlp_apply(bp, m, cfg), 0.0
                return x + a + f, cache, aux
            x = x + a
            m = rms_norm(x, bp["ln2"], cfg.norm_eps)
            if cfg.arch_type == "moe":
                f, aux = moe_mod.moe_apply(bp, m, cfg)
            else:
                f, aux = mlp_mod.mlp_apply(bp, m, cfg), 0.0
            return x + f, cache, aux
        # ssm / hybrid mamba block
        h = rms_norm(x, bp["ln1"], cfg.norm_eps)
        y, cache = ssm_mod.ssm_apply(bp, h, cfg, cache=cache)
        return x + y, cache, 0.0

    def _apply_shared_attn(self, sp, x, *, positions, cache=None, decode_pos=None):
        cfg = self.cfg
        h = rms_norm(x, sp["ln1"], cfg.norm_eps)
        a, cache = attn_mod.attention_apply(
            sp, h, cfg, positions=positions, cache=cache,
            decode_pos=decode_pos, impl=self.attn_impl)
        x = x + a
        m = rms_norm(x, sp["ln2"], cfg.norm_eps)
        return x + mlp_mod.mlp_apply(sp, m, cfg), cache

    def _stack(self, params, x, *, positions, caches=None, decode_pos=None):
        """Run all blocks. caches: None (train) or pytree of stacked caches."""
        cfg = self.cfg
        body = self._apply_block
        if self.remat and caches is None:
            policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                      if self.remat_policy == "dots" else None)
            body = jax.checkpoint(body, static_argnums=(), policy=policy)

        if cfg.arch_type != "hybrid":
            def scan_fn(carry, xs):
                h, aux = carry
                bp, cache = xs
                h, cache, aux_i = body(bp, h, positions=positions, cache=cache,
                                       decode_pos=decode_pos)
                return (h, aux + aux_i), cache

            caches_in = caches["blocks"] if caches is not None else None
            xs = (params["blocks"], caches_in) if caches is not None else (params["blocks"], None)
            if caches is None:
                (x, aux), _ = jax.lax.scan(
                    lambda c, bp: scan_fn(c, (bp, None)), (x, 0.0), params["blocks"])
                return x, aux, None
            (x, aux), new_caches = jax.lax.scan(scan_fn, (x, 0.0), xs)
            return x, aux, {"blocks": new_caches}

        # hybrid: super-blocks of `every` mamba layers + shared attention
        every = cfg.hybrid_attn_every
        n_super = cfg.num_layers // every
        grouped = jax.tree_util.tree_map(
            lambda a: a.reshape((n_super, every) + a.shape[1:]), params["blocks"])
        sp = params["shared_attn"]

        def super_fn(carry, xs):
            h = carry
            bp_group, ssm_cache_group, attn_cache = xs

            def inner(hc, xs2):
                bp, cache = xs2
                hh, cache, _ = self._apply_block(bp, hc, positions=positions, cache=cache,
                                                 decode_pos=decode_pos)
                return hh, cache

            h, new_ssm = jax.lax.scan(inner, h, (bp_group, ssm_cache_group))
            h, new_attn = self._apply_shared_attn(sp, h, positions=positions,
                                                  cache=attn_cache, decode_pos=decode_pos)
            return h, (new_ssm, new_attn)

        if caches is None:
            empty = jax.tree_util.tree_map(lambda a: None, ())  # unused
            def super_nocache(carry, bp_group):
                h = carry

                def inner(hc, bp):
                    hh, _, _ = body(bp, hc, positions=positions)
                    return hh, None

                h, _ = jax.lax.scan(inner, h, bp_group)
                h, _ = self._apply_shared_attn(sp, h, positions=positions)
                return h, None

            x, _ = jax.lax.scan(super_nocache, x, grouped)
            return x, 0.0, None

        ssm_caches = jax.tree_util.tree_map(
            lambda a: a.reshape((n_super, every) + a.shape[1:]), caches["ssm"])
        x, (new_ssm, new_attn) = jax.lax.scan(
            super_fn, x, (grouped, ssm_caches, caches["attn"]))
        new_caches = {
            "ssm": jax.tree_util.tree_map(
                lambda a: a.reshape((n_super * every,) + a.shape[2:]), new_ssm),
            "attn": new_attn,
        }
        return x, 0.0, new_caches

    # -------------------------------------------------------------- forward

    def _embed(self, params, tokens, positions):
        x = jnp.take(params["embed"], tokens, axis=0).astype(self.dtype)
        if not self.cfg.use_rope:
            # sinusoidal table computed inline (static max_positions rows) and
            # gathered at the actual positions (supports decode offsets).
            pe = sinusoidal_positions(self.max_positions, self.cfg.d_model, self.dtype)
            x = x + jnp.take(pe, jnp.minimum(positions, self.max_positions - 1), axis=0)
        return shard(x, "batch", "seq", None)

    def forward(self, params, tokens):
        positions = jnp.broadcast_to(jnp.arange(tokens.shape[1]), tokens.shape)
        x = self._embed(params, tokens, positions)
        x, aux, _ = self._stack(params, x, positions=positions)
        return rms_norm(x, params["final_norm"], self.cfg.norm_eps), aux

    def _head_matrix(self, params):
        return params["embed"].T if self.cfg.tie_embeddings else params["head"]

    def logits(self, params, h):
        out = h @ self._head_matrix(params)
        return shard(out, "batch", "seq", "vocab")

    def loss(self, params, tokens, labels):
        """Mean next-token CE (+ MoE aux). Chunked over the sequence.

        Chunks are taken with dynamic_slice inside the scan — reshaping to a
        leading (nchunk, ...) stack transposes the sharded hidden tensor and
        GSPMD inserts all-to-all/collective-permute per chunk (§Perf
        hillclimb 3, iteration 3); slicing keeps the layout intact.
        """
        h, aux = self.forward(params, tokens)
        w = self._head_matrix(params)
        b, s, d = h.shape
        chunk = min(self.loss_chunk, s)
        pad = (-s) % chunk
        if pad:
            h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
            labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
        nchunk = h.shape[1] // chunk

        @jax.checkpoint
        def body(carry, i):
            hh = jax.lax.dynamic_slice_in_dim(h, i * chunk, chunk, axis=1)
            ll = jax.lax.dynamic_slice_in_dim(labels, i * chunk, chunk, axis=1)
            logits = (hh @ w).astype(jnp.float32)
            logits = shard(logits, "batch", "seq", "vocab")
            lse = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, jnp.maximum(ll, 0)[..., None], axis=-1)[..., 0]
            valid = (ll >= 0).astype(jnp.float32)
            nll = jnp.sum((lse - gold) * valid)
            return (carry[0] + nll, carry[1] + jnp.sum(valid)), None

        (total, count), _ = jax.lax.scan(body, (0.0, 0.0), jnp.arange(nchunk))
        ce = total / jnp.maximum(count, 1.0)
        return ce + _MOE_AUX_COEF * aux

    # ------------------------------------------------------------- serving

    def init_cache(self, batch: int, seq_len: int, dtype=None):
        cfg = self.cfg
        dtype = dtype or self.dtype
        if cfg.arch_type in ("dense", "vlm", "moe"):
            one = attn_mod.init_kv_cache(cfg, batch, seq_len, dtype)
            return {"blocks": jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a, (cfg.num_layers,) + a.shape).copy(), one)}
        if cfg.arch_type == "ssm":
            one = ssm_mod.init_ssm_cache(cfg, batch)
            return {"blocks": jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a, (cfg.num_layers,) + a.shape).copy(), one)}
        # hybrid
        n_super = cfg.num_layers // cfg.hybrid_attn_every
        ssm_one = ssm_mod.init_ssm_cache(cfg, batch)
        attn_one = attn_mod.init_kv_cache(cfg, batch, seq_len, dtype)
        return {
            "ssm": jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a, (cfg.num_layers,) + a.shape).copy(), ssm_one),
            "attn": jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a, (n_super,) + a.shape).copy(), attn_one),
        }

    def prefill(self, params, tokens, caches):
        positions = jnp.broadcast_to(jnp.arange(tokens.shape[1]), tokens.shape)
        x = self._embed(params, tokens, positions)
        x, _, caches = self._stack(params, x, positions=positions, caches=caches)
        h = rms_norm(x[:, -1:], params["final_norm"], self.cfg.norm_eps)
        return self.logits(params, h)[:, 0], caches

    def decode_step(self, params, token, pos, caches):
        """token: (B,) int32; pos: scalar int32 (uniform across batch)."""
        positions = jnp.full((token.shape[0], 1), pos, jnp.int32)
        x = self._embed(params, token[:, None], positions)
        x, _, caches = self._stack(params, x, positions=positions,
                                   caches=caches, decode_pos=pos)
        h = rms_norm(x, params["final_norm"], self.cfg.norm_eps)
        return self.logits(params, h)[:, 0], caches
