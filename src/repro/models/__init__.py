"""Model zoo: paper-scale CNNs/linear + the datacenter transformer stack."""

from repro.models.cnn import CNNModel, accuracy_fn, make_cnn, masked_xent_loss
from repro.models.encdec import EncDecLM
from repro.models.transformer import DecoderLM


def build_model(cfg, **kwargs):
    """Factory: ModelConfig -> DecoderLM or EncDecLM."""
    if cfg.arch_type == "audio":
        return EncDecLM(cfg, **kwargs)
    return DecoderLM(cfg, **kwargs)


__all__ = ["CNNModel", "make_cnn", "masked_xent_loss", "accuracy_fn",
           "DecoderLM", "EncDecLM", "build_model"]
