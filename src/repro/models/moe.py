"""Mixture-of-Experts block: top-k routing with capacity-bounded dispatch.

Gather/scatter dispatch (not the GShard (T, E, C) einsum, whose dispatch
tensor would be ~5e9 elements for llama4): tokens are scattered into
capacity-bounded per-expert buffers (E, C, D), experts run as one batched
einsum with E sharded over the model axis, and outputs are gathered back.
The loop over the k routing slots is unrolled (k <= 8), so peak memory is
O(T*D + E*C*D) instead of O(T*k*D).

Capacity C = ceil(cf * T * k / E); overflowing tokens are dropped (their
combine weight is zero) — standard capacity-factor semantics, and the router
load-balance auxiliary loss keeps drops rare.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import Param
from repro.models.sharding import group_count, shard

__all__ = ["moe_defs", "moe_apply"]


def moe_defs(cfg: ModelConfig, prefix: str = "moe_") -> dict[str, Param]:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    gated = cfg.activation in ("swiglu", "geglu")
    defs = {
        prefix + "router": Param((d, e), ("embed", None), fan_in=d),
        prefix + "wi": Param((e, d, (2 if gated else 1) * f), ("experts", "embed", "ff"), fan_in=d),
        prefix + "wo": Param((e, f, d), ("experts", "ff", "embed"), fan_in=f),
    }
    if cfg.moe_shared_expert:
        defs[prefix + "shared_wi"] = Param((d, (2 if gated else 1) * f), ("embed", "ff"), fan_in=d)
        defs[prefix + "shared_wo"] = Param((f, d), ("ff", "embed"), fan_in=f)
    return defs


def _act(cfg: ModelConfig, h: jax.Array) -> jax.Array:
    if cfg.activation in ("swiglu", "geglu"):
        gate, up = jnp.split(h, 2, axis=-1)
        return (jax.nn.silu(gate) if cfg.activation == "swiglu" else jax.nn.gelu(gate)) * up
    return jax.nn.gelu(h)


def moe_apply(params: dict, x: jax.Array, cfg: ModelConfig, prefix: str = "moe_"):
    """x: (B, S, D) -> (y, aux_loss).

    Dispatch is GROUP-LOCAL: tokens are reshaped to (G, T/G) with G = the
    shard count behind the logical "batch" axis, so the capacity scatter and
    the gather-back are local to each data shard (GShard local-dispatch
    semantics). Without the grouping, the scatter indexes a global (E*C, D)
    buffer and GSPMD all-gathers the FULL token matrix every layer — the
    dominant collective of the MoE serve path (§Perf hillclimb 2). Capacity
    is per-group and per-slot: cap = ceil(cf * T/G / E), floor 4 so tiny
    decode batches stay drop-free. (Sizing by the total k-slot load — the
    GShard shared-buffer convention — made every slot einsum k x too large:
    §Perf hillclimb 1.)
    """
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    t = b * s
    from repro.models.sharding import current_rules
    rules = current_rules() or {}
    g = group_count("batch") if rules.get("moe_group_dispatch", True) else 1
    # batch-major grouping must align with the batch sharding (g | b), and
    # each group needs at least ~E tokens to be worth dispatching locally.
    if g > 1 and (b % g or (t // g) < e):
        g = 1
    tg = t // g
    cap = int(max(4, -(-int(cfg.capacity_factor * tg) // e)))

    xf = shard(x.reshape(g, tg, d), "batch", None, None)
    logits = (xf @ params[prefix + "router"]).astype(jnp.float32)   # (G, Tg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)                   # (G, Tg, k)
    gate_vals = gate_vals / jnp.maximum(jnp.sum(gate_vals, -1, keepdims=True), 1e-9)

    # Load-balance auxiliary loss (Switch): E * sum_e f_e * p_e.
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(jax.nn.one_hot(gate_idx[..., 0], e), axis=(0, 1))
    aux_loss = e * jnp.sum(me * ce)

    # combine accumulator in the model dtype when a single slot feeds it
    # (top-1): the cross-expert combine lowers to a collective over the
    # expert axis and an f32 accumulator doubles its bytes. Multi-slot sums
    # keep f32 for accuracy.
    acc_dtype = x.dtype if k == 1 else jnp.float32
    y = jnp.zeros((g, tg, d), acc_dtype)
    for slot in range(k):
        eid = gate_idx[..., slot]                                   # (G, Tg)
        onehot = jax.nn.one_hot(eid, e, dtype=jnp.int32)            # (G, Tg, E)
        pos = jnp.cumsum(onehot, axis=1) - 1                        # per-group
        pos_tok = jnp.sum(pos * onehot, axis=2)                     # (G, Tg)
        keep = pos_tok < cap
        slot_idx = jnp.where(keep, eid * cap + pos_tok, e * cap)    # overflow -> sentinel

        if g == 1:  # unbatched scatter (faster on the CPU test path)
            buf = jnp.zeros((1, e * cap + 1, d), xf.dtype).at[0, slot_idx[0]].set(xf[0])
        else:
            buf = jax.vmap(lambda sx, si: jnp.zeros((e * cap + 1, d), sx.dtype).at[si].set(sx))(
                xf, slot_idx)                                       # (G, E*C+1, D)
        buf = shard(buf[:, : e * cap].reshape(g, e, cap, d), "batch", "experts", None, None)

        h = jnp.einsum("gecd,edf->gecf", buf, params[prefix + "wi"])
        h = shard(_act(cfg, h), "batch", "experts", None, "ff")
        out = jnp.einsum("gecf,efd->gecd", h, params[prefix + "wo"])  # (G, E, C, D)

        # combine in the model dtype: the masked gather across expert shards
        # lowers to an all-reduce over the expert axis, and an f32 combine
        # doubles its bytes (§Perf hillclimb 2, iteration 2).
        out_flat = jnp.concatenate([out.reshape(g, e * cap, d),
                                    jnp.zeros((g, 1, d), out.dtype)], axis=1).astype(x.dtype)
        gathered = jax.vmap(lambda of, si: of[si])(out_flat, slot_idx)  # (G, Tg, D)
        y = y + gathered.astype(acc_dtype) * (gate_vals[..., slot] * keep)[..., None].astype(acc_dtype)

    if cfg.moe_shared_expert:
        h = _act(cfg, xf @ params[prefix + "shared_wi"])
        y = y + (h @ params[prefix + "shared_wo"]).astype(acc_dtype)

    return shard(y.reshape(b, s, d).astype(x.dtype), "batch", "seq", None), aux_loss
