"""Attention layer: GQA/MQA/MHA, causal & sliding-window, KV cache, qk-norm.

Three execution paths:
  - ``xla_flash``: blockwise online-softmax attention in pure jnp (lax.scan
    over KV blocks) — the default; never materializes the S x S matrix, so
    prefill_32k lowers with sane memory. Masked blocks are still computed
    (baseline; see EXPERIMENTS.md §Perf for the banded variant).
  - ``dense``: plain einsum (tiny smoke shapes and the oracle path).
  - ``pallas``: repro.kernels.flash_attention (TPU target kernel).

Decode path: single-query attention against a KV cache; sliding-window layers
keep a ring-buffer cache of ``window`` slots (the long_500k enabler for
h2o-danube3).
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import Param, rms_norm, rope, softcap
from repro.models.sharding import shard

__all__ = ["attention_defs", "attention_apply", "init_kv_cache", "decode_attention",
           "blockwise_attention", "dense_attention"]

_NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Parameter definitions
# ---------------------------------------------------------------------------

def attention_defs(cfg: ModelConfig, prefix: str = "attn_") -> dict[str, Param]:
    d, dh = cfg.d_model, cfg.resolved_head_dim
    hq, hkv = cfg.num_heads, cfg.num_kv_heads
    defs = {
        prefix + "wq": Param((d, hq * dh), ("embed", "heads"), fan_in=d),
        prefix + "wk": Param((d, hkv * dh), ("embed", "heads"), fan_in=d),
        prefix + "wv": Param((d, hkv * dh), ("embed", "heads"), fan_in=d),
        prefix + "wo": Param((hq * dh, d), ("heads", "embed"), fan_in=hq * dh),
    }
    if cfg.use_bias:
        defs[prefix + "wq_b"] = Param((hq * dh,), ("heads",))
        defs[prefix + "wv_b"] = Param((hkv * dh,), ("heads",))
        defs[prefix + "wo_b"] = Param((d,), ("embed",))
    if cfg.qk_norm:
        defs[prefix + "qnorm"] = Param((dh,), (None,))
        defs[prefix + "knorm"] = Param((dh,), (None,))
    return defs


# ---------------------------------------------------------------------------
# Core attention math
# ---------------------------------------------------------------------------

def dense_attention(q, k, v, *, causal, window, q_offset=0, softcap_val=None):
    """q: (B, Sq, Hq, dh); k/v: (B, Skv, Hkv, dh). Dense reference path."""
    b, sq, hq, dh = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    group = hq // hkv
    qg = q.reshape(b, sq, hkv, group, dh)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32), k.astype(jnp.float32))
    s = softcap(s * (1.0 / math.sqrt(dh)), softcap_val)
    q_idx = q_offset + jnp.arange(sq)[:, None]
    k_idx = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= k_idx <= q_idx
    if window is not None:
        mask &= k_idx > q_idx - window
    s = jnp.where(mask[None, None, None], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(b, sq, hq, dh).astype(q.dtype)


def blockwise_attention(q, k, v, *, causal, window, block_k: int = 512, softcap_val=None):
    """Online-softmax attention scanning KV blocks; same signature as dense."""
    b, sq, hq, dh = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    group = hq // hkv
    bk = min(block_k, skv)
    pad = (-skv) % bk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nk = k.shape[1] // bk
    scale = 1.0 / math.sqrt(dh)

    qg = (q.astype(jnp.float32) * scale).reshape(b, sq, hkv, group, dh)
    kb = k.reshape(b, nk, bk, hkv, dh).astype(jnp.float32)
    vb = v.reshape(b, nk, bk, hkv, dh).astype(jnp.float32)
    q_idx = jnp.arange(sq)[:, None]

    def step(carry, xs):
        m_prev, l_prev, acc = carry
        kblk, vblk, ik = xs
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kblk)
        s = softcap(s, softcap_val)
        k_idx = ik * bk + jnp.arange(bk)[None, :]
        mask = k_idx < skv
        if causal:
            mask &= k_idx <= q_idx
        if window is not None:
            mask &= k_idx > q_idx - window
        s = jnp.where(mask[None, None, None], s, _NEG_INF)
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_cur[..., None])
        alpha = jnp.exp(m_prev - m_cur)
        l_cur = alpha * l_prev + jnp.sum(p, axis=-1)
        acc = alpha[..., None] * acc + jnp.einsum("bhgqk,bkhd->bhgqd", p, vblk)
        return (m_cur, l_cur, acc), None

    m0 = jnp.full((b, hkv, group, sq), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, group, sq), jnp.float32)
    acc0 = jnp.zeros((b, hkv, group, sq, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, acc0),
        (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0), jnp.arange(nk)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return jnp.moveaxis(out, 3, 1).reshape(b, sq, hq, dh).astype(q.dtype)


def chunked_attention(q, k, v, *, causal, window, block_q: int = 512, softcap_val=None):
    """Q-chunked attention: dense scores per chunk, no carried accumulator.

    The online-softmax scan (``blockwise_attention``) carries a full
    (sq x dh) f32 accumulator through every KV step — per-layer HBM traffic
    of ~n_kv_blocks x acc bytes, which dominates the §Roofline memory term
    for training shapes. Materializing one (block_q x skv) score block per
    q-chunk costs a little peak memory and ~2x masked flops but removes the
    carried-accumulator traffic entirely (§Perf hillclimb 3, iteration 4).
    On TPU the Pallas kernel (repro.kernels.flash_attention) is the real
    answer — the accumulator lives in VMEM; this is the XLA-level analogue.
    """
    b, sq, hq, dh = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    group = hq // hkv
    bq = min(block_q, sq)
    pad = (-sq) % bq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nq = q.shape[1] // bq
    scale = 1.0 / math.sqrt(dh)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    k_idx = jnp.arange(skv)[None, :]

    def one_chunk(_, i):
        qi = jax.lax.dynamic_slice_in_dim(q, i * bq, bq, axis=1)
        qg = (qi.astype(jnp.float32) * scale).reshape(b, bq, hkv, group, dh)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kf)
        s = softcap(s, softcap_val)
        q_idx = i * bq + jnp.arange(bq)[:, None]
        mask = jnp.ones((bq, skv), bool)
        if causal:
            mask &= k_idx <= q_idx
        if window is not None:
            mask &= k_idx > q_idx - window
        s = jnp.where(mask[None, None, None], s, _NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhgqk,bkhd->bqhgd", p, vf)
        return None, o.reshape(b, bq, hq, dh).astype(q.dtype)

    _, chunks = jax.lax.scan(one_chunk, None, jnp.arange(nq))
    out = jnp.moveaxis(chunks, 0, 1).reshape(b, nq * bq, hq, dh)
    return out[:, :sq]


def decode_attention(q, k_cache, v_cache, cache_positions, pos, *, window, softcap_val=None):
    """Single-token attention against a cache.

    q: (B, 1, Hq, dh); caches: (B, Smax, Hkv, dh); cache_positions: (Smax,)
    absolute positions stored in each slot (-1 = empty); pos: current step.
    """
    b, _, hq, dh = q.shape
    hkv = k_cache.shape[2]
    group = hq // hkv
    qg = q.reshape(b, hkv, group, dh).astype(jnp.float32) / math.sqrt(dh)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache.astype(jnp.float32))
    s = softcap(s, softcap_val)
    valid = (cache_positions >= 0) & (cache_positions <= pos)
    if window is not None:
        valid &= cache_positions > pos - window
    s = jnp.where(valid[None, None, None], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache.astype(jnp.float32))
    return o.reshape(b, 1, hq, dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# Layer apply (projections + cache management)
# ---------------------------------------------------------------------------

def init_kv_cache(cfg: ModelConfig, batch: int, seq_len: int, dtype=jnp.bfloat16):
    """Cache for ONE attention layer. Window layers get a ring of `window` slots."""
    smax = min(seq_len, cfg.sliding_window) if cfg.sliding_window else seq_len
    dh, hkv = cfg.resolved_head_dim, cfg.num_kv_heads
    return {
        "k": jnp.zeros((batch, smax, hkv, dh), dtype),
        "v": jnp.zeros((batch, smax, hkv, dh), dtype),
        "slot_pos": jnp.full((smax,), -1, jnp.int32),
    }


def attention_apply(
    params: dict,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    positions: jax.Array,
    causal: bool = True,
    cache: dict | None = None,
    decode_pos: jax.Array | None = None,
    cross_kv: tuple[jax.Array, jax.Array] | None = None,
    impl: str = "xla_flash",
    prefix: str = "attn_",
) -> tuple[jax.Array, dict | None]:
    """Returns (output, updated_cache)."""
    d, dh = cfg.d_model, cfg.resolved_head_dim
    hq, hkv = cfg.num_heads, cfg.num_kv_heads
    b, s, _ = x.shape
    window = cfg.sliding_window

    # constrain the FLATTENED (h*dh) projections: the flat dim divides the
    # model axis even when the head count does not (MQA/GQA, e.g. 8 q-heads
    # on a 16-way axis), so XLA keeps a factorized sharding through the
    # 4-D reshape instead of remat-copying (§Perf hillclimb 3).
    q = shard(x @ params[prefix + "wq"], "batch", "seq", "heads").reshape(b, s, hq, dh)
    if prefix + "wq_b" in params:
        q = q + params[prefix + "wq_b"].reshape(hq, dh)
    if cross_kv is None:
        k = shard(x @ params[prefix + "wk"], "batch", "seq", "heads").reshape(b, s, hkv, dh)
        v = shard(x @ params[prefix + "wv"], "batch", "seq", "heads").reshape(b, s, hkv, dh)
        if prefix + "wv_b" in params:
            v = v + params[prefix + "wv_b"].reshape(hkv, dh)
    else:
        k, v = cross_kv

    if cfg.qk_norm:
        q = rms_norm(q, params[prefix + "qnorm"], cfg.norm_eps)
        if cross_kv is None:
            k = rms_norm(k, params[prefix + "knorm"], cfg.norm_eps)
    if cfg.use_rope and cross_kv is None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)

    q = shard(q, "batch", "seq", "heads", None)

    if cache is not None and cross_kv is None:
        smax = cache["k"].shape[1]
        if s == 1:  # decode: write the new KV into its (ring) slot
            slot = (decode_pos % smax).astype(jnp.int32)
            cache = dict(
                k=jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0)),
                v=jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0)),
                slot_pos=jax.lax.dynamic_update_slice(cache["slot_pos"], decode_pos[None].astype(jnp.int32), (slot,)),
            )
            out = decode_attention(q, cache["k"], cache["v"], cache["slot_pos"],
                                   decode_pos, window=window, softcap_val=cfg.attn_logit_softcap)
        else:  # prefill into cache (window layers keep only the last smax KVs,
            # written at their ring slots pos % smax so later decode writes at
            # pos % smax evict exactly the oldest position)
            keep = min(s, smax)
            pos_arr = jnp.arange(s - keep, s, dtype=jnp.int32)
            slots = pos_arr % smax
            cache = dict(
                k=cache["k"].at[:, slots].set(k[:, -keep:].astype(cache["k"].dtype)),
                v=cache["v"].at[:, slots].set(v[:, -keep:].astype(cache["v"].dtype)),
                slot_pos=cache["slot_pos"].at[slots].set(pos_arr),
            )
            out = _self_attention(q, k, v, causal, window, impl, cfg)
    elif cross_kv is not None:
        if impl == "dense" or s == 1:
            out = dense_attention(q, k, v, causal=False, window=None)
        else:
            out = blockwise_attention(q, k, v, causal=False, window=None)
    else:
        out = _self_attention(q, k, v, causal, window, impl, cfg)

    y = shard(out.reshape(b, s, hq * dh), "batch", "seq", "heads") @ params[prefix + "wo"]
    if prefix + "wo_b" in params:
        y = y + params[prefix + "wo_b"]
    return shard(y, "batch", "seq", None), cache


def _self_attention(q, k, v, causal, window, impl, cfg: ModelConfig):
    sc = cfg.attn_logit_softcap
    if impl == "dense":
        return dense_attention(q, k, v, causal=causal, window=window, softcap_val=sc)
    if impl == "chunked":
        return chunked_attention(q, k, v, causal=causal, window=window, softcap_val=sc)
    if impl == "pallas":
        from repro.kernels.flash_attention import flash_attention

        out = flash_attention(
            jnp.moveaxis(q, 2, 1), jnp.moveaxis(k, 2, 1), jnp.moveaxis(v, 2, 1),
            causal=causal, window=window)
        return jnp.moveaxis(out, 1, 2)
    return blockwise_attention(q, k, v, causal=causal, window=window, softcap_val=sc)
