"""Whisper-style encoder-decoder (audio backbone; conv frontend stubbed).

The mel-spectrogram + conv feature extractor is a STUB per the assignment:
``input_specs()`` provides precomputed frame embeddings (B, T, d_model) for
the encoder. This module implements the transformer backbone:

  encoder: N layers of bidirectional self-attention + GELU MLP
  decoder: N layers of causal self-attention + cross-attention + GELU MLP

Cross-attention K/V are computed once from the encoder output and reused for
every decode step (the standard serving cache layout).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import mlp as mlp_mod
from repro.models.common import Param, init_params, layer_norm, logical_specs, sinusoidal_positions
from repro.models.sharding import shard

__all__ = ["EncDecLM"]


def _enc_block_defs(cfg: ModelConfig) -> dict[str, Param]:
    return {
        "ln1": Param((cfg.d_model,), (None,)), "ln1_b": Param((cfg.d_model,), (None,)),
        "ln2": Param((cfg.d_model,), (None,)), "ln2_b": Param((cfg.d_model,), (None,)),
        **attn_mod.attention_defs(cfg, "attn_"),
        **mlp_mod.mlp_defs(cfg, "mlp_"),
    }


def _dec_block_defs(cfg: ModelConfig) -> dict[str, Param]:
    return {
        "ln1": Param((cfg.d_model,), (None,)), "ln1_b": Param((cfg.d_model,), (None,)),
        "ln2": Param((cfg.d_model,), (None,)), "ln2_b": Param((cfg.d_model,), (None,)),
        "ln3": Param((cfg.d_model,), (None,)), "ln3_b": Param((cfg.d_model,), (None,)),
        **attn_mod.attention_defs(cfg, "attn_"),
        **attn_mod.attention_defs(cfg, "xattn_"),
        **mlp_mod.mlp_defs(cfg, "mlp_"),
    }


@dataclasses.dataclass
class EncDecLM:
    cfg: ModelConfig
    dtype: Any = jnp.float32
    attn_impl: str = "xla_flash"
    remat: bool = True
    max_positions: int = 32_768

    def init(self, key: jax.Array) -> dict:
        cfg = self.cfg
        k_e, k_enc, k_dec = jax.random.split(key, 3)
        enc_keys = jax.random.split(k_enc, cfg.num_encoder_layers)
        dec_keys = jax.random.split(k_dec, cfg.num_layers)
        return {
            "embed": (jax.random.normal(k_e, (cfg.vocab_size, cfg.d_model), jnp.float32)
                      * 0.02).astype(self.dtype),
            "enc_blocks": jax.vmap(lambda k: init_params(k, _enc_block_defs(cfg), self.dtype))(enc_keys),
            "dec_blocks": jax.vmap(lambda k: init_params(k, _dec_block_defs(cfg), self.dtype))(dec_keys),
            "enc_norm": jnp.zeros((cfg.d_model,), self.dtype),
            "enc_norm_b": jnp.zeros((cfg.d_model,), self.dtype),
            "dec_norm": jnp.zeros((cfg.d_model,), self.dtype),
            "dec_norm_b": jnp.zeros((cfg.d_model,), self.dtype),
        }

    def pspecs(self) -> dict:
        cfg = self.cfg
        return {
            "embed": ("vocab", "embed"),
            "enc_blocks": {k: ("layers",) + v for k, v in logical_specs(_enc_block_defs(cfg)).items()},
            "dec_blocks": {k: ("layers",) + v for k, v in logical_specs(_dec_block_defs(cfg)).items()},
            "enc_norm": (None,), "enc_norm_b": (None,),
            "dec_norm": (None,), "dec_norm_b": (None,),
        }

    # ----------------------------------------------------------------- encoder

    def encode(self, params, frames):
        """frames: (B, T, d_model) stubbed frontend embeddings."""
        cfg = self.cfg
        t = frames.shape[1]
        pe = sinusoidal_positions(t, cfg.d_model, self.dtype)
        x = shard(frames.astype(self.dtype) + pe[None], "batch", "seq", None)
        positions = jnp.broadcast_to(jnp.arange(t), frames.shape[:2])

        def block(h, bp):
            a_in = layer_norm(h, bp["ln1"], bp["ln1_b"], cfg.norm_eps)
            a, _ = attn_mod.attention_apply(bp, a_in, cfg, positions=positions,
                                            causal=False, impl=self.attn_impl)
            h = h + a
            m = layer_norm(h, bp["ln2"], bp["ln2_b"], cfg.norm_eps)
            return h + mlp_mod.mlp_apply(bp, m, cfg), None

        if self.remat:
            block = jax.checkpoint(block)
        x, _ = jax.lax.scan(block, x, params["enc_blocks"])
        return layer_norm(x, params["enc_norm"], params["enc_norm_b"], cfg.norm_eps)

    def cross_kv(self, params, enc_out):
        """Precompute per-layer cross-attention K/V from the encoder output."""
        cfg = self.cfg
        dh, hkv = cfg.resolved_head_dim, cfg.num_kv_heads
        b, t, _ = enc_out.shape

        def one(bp):
            k = (enc_out @ bp["xattn_wk"]).reshape(b, t, hkv, dh)
            v = (enc_out @ bp["xattn_wv"]).reshape(b, t, hkv, dh)
            if "xattn_wv_b" in bp:
                v = v + bp["xattn_wv_b"].reshape(hkv, dh)
            return k, v

        return jax.lax.map(one, params["dec_blocks"])

    # ----------------------------------------------------------------- decoder

    def _dec_block(self, bp, h, *, positions, xkv, cache=None, decode_pos=None):
        cfg = self.cfg
        a_in = layer_norm(h, bp["ln1"], bp["ln1_b"], cfg.norm_eps)
        a, cache = attn_mod.attention_apply(bp, a_in, cfg, positions=positions,
                                            cache=cache, decode_pos=decode_pos,
                                            impl=self.attn_impl, prefix="attn_")
        h = h + a
        x_in = layer_norm(h, bp["ln2"], bp["ln2_b"], cfg.norm_eps)
        xa, _ = attn_mod.attention_apply(bp, x_in, cfg, positions=positions,
                                         cross_kv=xkv, impl=self.attn_impl, prefix="xattn_")
        h = h + xa
        m = layer_norm(h, bp["ln3"], bp["ln3_b"], cfg.norm_eps)
        return h + mlp_mod.mlp_apply(bp, m, cfg), cache

    def decode(self, params, tokens, enc_out, caches=None, decode_pos=None):
        cfg = self.cfg
        b, s = tokens.shape
        if decode_pos is None:
            positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        else:
            positions = jnp.full((b, s), decode_pos, jnp.int32)
        pe = sinusoidal_positions(self.max_positions, cfg.d_model, self.dtype)
        x = jnp.take(params["embed"], tokens, axis=0).astype(self.dtype)
        x = x + jnp.take(pe, jnp.minimum(positions, self.max_positions - 1), axis=0)
        x = shard(x, "batch", "seq", None)
        xkvs = self.cross_kv(params, enc_out)

        def block(carry, xs):
            h = carry
            bp, xkv, cache = xs
            h, cache = self._dec_block(bp, h, positions=positions, xkv=xkv,
                                       cache=cache, decode_pos=decode_pos)
            return h, cache

        if caches is None:
            body = jax.checkpoint(lambda c, xs: block(c, xs + (None,))) if self.remat \
                else (lambda c, xs: block(c, xs + (None,)))
            x, _ = jax.lax.scan(body, x, (params["dec_blocks"], xkvs))
            new_caches = None
        else:
            x, new_caches = jax.lax.scan(block, x, (params["dec_blocks"], xkvs, caches))
        x = layer_norm(x, params["dec_norm"], params["dec_norm_b"], cfg.norm_eps)
        logits = x @ params["embed"].T
        return shard(logits, "batch", "seq", "vocab"), new_caches

    # ----------------------------------------------------------------- losses

    def loss(self, params, frames, tokens, labels):
        enc_out = self.encode(params, frames)
        logits, _ = self.decode(params, tokens, enc_out)
        logits = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
        valid = (labels >= 0).astype(jnp.float32)
        return jnp.sum((lse - gold) * valid) / jnp.maximum(jnp.sum(valid), 1.0)

    def init_cache(self, batch: int, seq_len: int, dtype=None):
        cfg = self.cfg
        one = attn_mod.init_kv_cache(cfg, batch, seq_len, dtype or self.dtype)
        return jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a, (cfg.num_layers,) + a.shape).copy(), one)

    def decode_step(self, params, token, pos, enc_out, caches):
        logits, caches = self.decode(params, token[:, None], enc_out,
                                     caches=caches, decode_pos=pos)
        return logits[:, 0], caches
