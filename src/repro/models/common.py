"""Common building blocks for the transformer/SSM model zoo."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["rms_norm", "layer_norm", "rope", "sinusoidal_positions",
           "dense_init", "Param", "softcap"]


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * (1.0 + scale)).astype(x.dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    mean = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    # zero-init friendly: effective scale is (1 + scale), as for rms_norm
    return ((x32 - mean) * jax.lax.rsqrt(var + eps) * (1.0 + scale) + bias).astype(x.dtype)


def softcap(x: jax.Array, cap: float | None) -> jax.Array:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """Rotary embedding. x: (..., S, H, Dh); positions: (..., S)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(length: int, dim: int, dtype=jnp.float32) -> jax.Array:
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, dim, 2, dtype=jnp.float32) * (-jnp.log(10000.0) / dim))
    pe = jnp.zeros((length, dim), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div[: (dim + 1) // 2]))
    return pe.astype(dtype)


def dense_init(key: jax.Array, shape: tuple[int, ...], fan_in: int, dtype) -> jax.Array:
    return (jax.random.normal(key, shape, jnp.float32) / jnp.sqrt(float(fan_in))).astype(dtype)


class Param:
    """(shape, fan_in, logical axes) triple used to build init + pspec trees."""

    def __init__(self, shape, logical, fan_in=None):
        self.shape = tuple(shape)
        self.logical = tuple(logical)
        self.fan_in = fan_in if fan_in is not None else (shape[0] if len(shape) > 1 else 1)
        assert len(self.shape) == len(self.logical), (shape, logical)


def init_params(key: jax.Array, defs: dict[str, Param], dtype) -> dict:
    keys = jax.random.split(key, len(defs))
    out = {}
    for k, (name, p) in zip(keys, sorted(defs.items())):
        if len(p.shape) == 1 or name.endswith("_b") or "norm" in name:
            out[name] = jnp.zeros(p.shape, dtype)
        else:
            out[name] = dense_init(k, p.shape, p.fan_in, dtype)
    return out


def logical_specs(defs: dict[str, Param]) -> dict:
    return {name: p.logical for name, p in defs.items()}
