"""Logical-axis sharding for the model zoo (MaxText-style axis rules).

Model code annotates activations/params with *logical* axis names
(``shard(x, "batch", "seq", None, "heads")``); the launch layer installs a
mapping from logical names to physical mesh axes. With no rules installed
(CPU unit tests) every annotation is the identity, so the same model code
runs single-device and on the 512-chip production mesh.

Rule sets (see DESIGN.md §4):
  - standard archs: clients->data, heads/ff/vocab/experts->model
  - giant archs (>= ~30B params): clients->pod (or none), batch->data,
    heads/ff/vocab/experts->model, param embed dim->data (FSDP-style storage)
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import PartitionSpec as P

__all__ = ["axis_rules", "shard", "logical_to_pspec", "current_rules",
           "client_axis_rules"]

_STATE = threading.local()


def current_rules() -> dict | None:
    return getattr(_STATE, "rules", None)


@contextlib.contextmanager
def axis_rules(rules: dict[str, str | tuple[str, ...] | None]):
    """Install logical->mesh axis rules for the enclosed region."""
    prev = current_rules()
    _STATE.rules = rules
    try:
        yield
    finally:
        _STATE.rules = prev


AXIS_SIZES_KEY = "__axis_sizes__"   # installed by the launch layer (mesh sizes)


def client_axis_rules(mesh, *, axis: str = "clients") -> dict:
    """Rule set mapping the logical ``clients`` axis onto a client mesh.

    The fedsim client-sharded engine uses these rules (via
    ``logical_to_pspec``) to derive the PartitionSpec of every client-batch
    leaf and of the padding mask, so the cohort partitioning logic lives here
    with the rest of the logical-axis layer rather than being hand-rolled in
    the engine.
    """
    return {
        "clients": axis,
        AXIS_SIZES_KEY: dict(zip(mesh.axis_names, mesh.devices.shape)),
    }


def logical_to_pspec(names: tuple[str | None, ...], rules: dict | None = None,
                     dims: tuple[int, ...] | None = None) -> P:
    """Map logical axis names to a PartitionSpec under the installed rules.

    If ``dims`` is given and the rules carry mesh axis sizes (AXIS_SIZES_KEY),
    any mesh axis that does not evenly divide its dim is dropped — forcing a
    non-dividing constraint (e.g. 8 query heads over a 16-way model axis)
    makes GSPMD insert involuntary full rematerializations (§Perf hillclimb 3);
    left unconstrained, XLA keeps its natural factorized sharding.
    """
    rules = rules if rules is not None else (current_rules() or {})
    sizes = rules.get(AXIS_SIZES_KEY)
    axes = []
    used: set[str] = set()
    for i, n in enumerate(names):
        ax = rules.get(n) if n is not None else None
        # a mesh axis may appear at most once in a PartitionSpec
        if ax is not None:
            flat = (ax,) if isinstance(ax, str) else tuple(ax)
            flat = tuple(a for a in flat if a not in used)
            if flat and sizes is not None and dims is not None:
                total = 1
                for a in flat:
                    total *= sizes.get(a, 1)
                if dims[i] % total != 0:
                    flat = ()
            used.update(flat)
            ax = None if not flat else (flat[0] if len(flat) == 1 else flat)
        axes.append(ax)
    return P(*axes)


def group_count(logical_name: str) -> int:
    """Number of mesh shards behind a logical axis under the current rules.

    Used by the MoE block to pick its dispatch-group count G: with tokens
    grouped (G, T/G) and G sharded like the token batch, the capacity
    scatter/gather is shard-local (GShard local-dispatch semantics) instead
    of an all-gather of the full token matrix (§Perf hillclimb 2).
    """
    rules = current_rules()
    if not rules:
        return 1
    sizes = rules.get(AXIS_SIZES_KEY)
    ax = rules.get(logical_name)
    if ax is None or sizes is None:
        return 1
    axes = (ax,) if isinstance(ax, str) else tuple(ax)
    g = 1
    for a in axes:
        g *= sizes.get(a, 1)
    return g


def shard(x: jax.Array, *names: str | None) -> jax.Array:
    """Constrain ``x`` to the PartitionSpec implied by logical axis names."""
    rules = current_rules()
    if rules is None:
        return x
    assert len(names) == x.ndim, (names, x.shape)
    return jax.lax.with_sharding_constraint(
        x, logical_to_pspec(names, rules, dims=tuple(x.shape)))
