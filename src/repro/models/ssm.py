"""Mamba2 block (SSD — state-space duality, arXiv:2405.21060).

Layer structure (single group, matching the mamba2 reference):
    in_proj -> [z | x | B | C | dt]
    causal depthwise conv1d (width 4) over [x | B | C]
    dt = softplus(dt + dt_bias);  A = -exp(A_log)  (per head)
    y = SSD(x, dt, A, B, C) + D * x          (selective state space scan)
    out = out_proj( RMSNorm(y) * silu(z) )

The SSD scan runs in the chunked dual form (quadratic intra-chunk matmuls +
small inter-chunk state carry) — pure-jnp here, with the Pallas kernel
``repro.kernels.ssd_scan`` as the TPU target (identical math; see its tests).

Sharding: d_inner (and the SSD heads along it) shard over the model axis;
B/C/dt projections are small and replicated; in/out projections are the
usual column/row-parallel pair.

Decode: O(1) state update — the long_500k enabler for mamba2/zamba2.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import Param, rms_norm
from repro.models.sharding import shard

__all__ = ["ssm_defs", "ssm_apply", "init_ssm_cache", "ssd_chunked"]


def ssm_defs(cfg: ModelConfig, prefix: str = "ssm_") -> dict[str, Param]:
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    w = cfg.ssm_conv_width
    conv_ch = di + 2 * n
    return {
        prefix + "in_zx": Param((d, 2 * di), ("embed", "ff"), fan_in=d),
        prefix + "in_bcdt": Param((d, 2 * n + h), ("embed", None), fan_in=d),
        prefix + "conv_w": Param((w, conv_ch), (None, None), fan_in=w),
        prefix + "conv_b": Param((conv_ch,), (None,)),
        prefix + "a_log": Param((h,), (None,)),
        prefix + "d_skip": Param((h,), (None,)),
        prefix + "dt_bias": Param((h,), (None,)),
        prefix + "norm": Param((di,), (None,)),
        prefix + "out": Param((di, d), ("ff", "embed"), fan_in=di),
    }


def ssd_chunked(x, dt, a, bmat, cmat, chunk: int = 128):
    """Chunked SSD. x: (B,S,H,P), dt: (B,S,H), a: (H,), bmat/cmat: (B,S,N)."""
    bsz, s, h, p = x.shape
    n = bmat.shape[-1]
    c = min(chunk, s)
    pad = (-s) % c
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
    sp = x.shape[1]
    nc = sp // c

    xc = x.reshape(bsz, nc, c, h, p).astype(jnp.float32)
    dtc = dt.reshape(bsz, nc, c, h).astype(jnp.float32)
    bc = bmat.reshape(bsz, nc, c, n).astype(jnp.float32)
    cc = cmat.reshape(bsz, nc, c, n).astype(jnp.float32)

    li = jnp.arange(c)[:, None]
    lj = jnp.arange(c)[None, :]
    tril = lj <= li

    def step(hstate, xs):
        xk, dtk, bk, ck = xs                      # (B,c,H,P), (B,c,H), (B,c,N), (B,c,N)
        log_a = a[None, None, :] * dtk            # (B,c,H)
        sdec = jnp.cumsum(log_a, axis=1)          # (B,c,H)
        xbar = xk * dtk[..., None]
        decay = jnp.where(tril[None, :, :, None],
                          jnp.exp(sdec[:, :, None, :] - sdec[:, None, :, :]), 0.0)  # (B,c,c,H)
        scores = jnp.einsum("bln,bmn->blm", ck, bk)                                  # (B,c,c)
        y = jnp.einsum("blmh,bmhp->blhp", scores[..., None] * decay, xbar)
        y = y + jnp.exp(sdec)[..., None] * jnp.einsum("bln,bhnp->blhp", ck, hstate)
        s_last = sdec[:, -1, :]                   # (B,H)
        wdec = jnp.exp(s_last[:, None, :] - sdec)  # (B,c,H)
        hstate = jnp.exp(s_last)[:, :, None, None] * hstate + jnp.einsum(
            "bln,blhp->bhnp", bk, xbar * wdec[..., None])
        return hstate, y

    h0 = jnp.zeros((bsz, h, n, p), jnp.float32)
    _, ys = jax.lax.scan(step, h0, (jnp.moveaxis(xc, 1, 0), jnp.moveaxis(dtc, 1, 0),
                                    jnp.moveaxis(bc, 1, 0), jnp.moveaxis(cc, 1, 0)))
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, sp, h, p)
    return y[:, :s].astype(x.dtype)


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    di, n = cfg.d_inner, cfg.ssm_state
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, di + 2 * n), dtype),
        "state": jnp.zeros((batch, cfg.ssm_heads, n, cfg.ssm_head_dim), dtype),
    }


def _causal_conv(h, w, b):
    """Depthwise causal conv1d. h: (B, S, C); w: (W, C)."""
    width = w.shape[0]
    hp = jnp.pad(h, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(hp[:, i : i + h.shape[1], :] * w[i] for i in range(width))
    return jax.nn.silu(out + b)


def ssm_apply(params: dict, x: jax.Array, cfg: ModelConfig, *,
              cache: dict | None = None, prefix: str = "ssm_"):
    """x: (B, S, D) -> (y, updated_cache). S=1 with cache = decode step."""
    b, s, d = x.shape
    di, n, heads, p = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim

    zx = x @ params[prefix + "in_zx"]                      # (B,S,2*di)
    zx = shard(zx, "batch", "seq", "ff")
    z, xin = jnp.split(zx, 2, axis=-1)
    bcdt = x @ params[prefix + "in_bcdt"]                  # (B,S,2N+H)
    bmat, cmat, dt_raw = jnp.split(bcdt, [n, 2 * n], axis=-1)

    conv_in = jnp.concatenate([xin, bmat, cmat], axis=-1)  # (B,S,di+2N)
    a = -jnp.exp(params[prefix + "a_log"].astype(jnp.float32))
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params[prefix + "dt_bias"])

    if cache is not None and s == 1:
        hist = jnp.concatenate([cache["conv"], conv_in.astype(cache["conv"].dtype)], axis=1)
        conv_out = _causal_conv(hist, params[prefix + "conv_w"], params[prefix + "conv_b"])[:, -1:]
        new_conv = hist[:, 1:]
        xc, bc, cc = jnp.split(conv_out, [di, di + n], axis=-1)
        xh = xc.reshape(b, heads, p).astype(jnp.float32)
        decay = jnp.exp(a[None] * dt[:, 0])                # (B,H)
        inject = bc[:, 0][:, None, :, None] * (xh * dt[:, 0][..., None])[:, :, None, :]
        state = decay[:, :, None, None] * cache["state"] + inject
        y = jnp.einsum("bn,bhnp->bhp", cc[:, 0].astype(jnp.float32), state)
        y = y + params[prefix + "d_skip"][None, :, None] * xh
        y = y.reshape(b, 1, di)
        cache = {"conv": new_conv, "state": state}
    else:
        conv_out = _causal_conv(conv_in, params[prefix + "conv_w"], params[prefix + "conv_b"])
        xc, bc, cc = jnp.split(conv_out, [di, di + n], axis=-1)
        xh = shard(xc.reshape(b, s, heads, p), "batch", "seq", "ff", None)
        dth = dt.reshape(b, s, heads)
        y = ssd_chunked(xh.astype(jnp.float32), dth, a,
                        bc.astype(jnp.float32), cc.astype(jnp.float32))
        y = y + params[prefix + "d_skip"][None, None, :, None] * xh.astype(jnp.float32)
        y = y.reshape(b, s, di)
        if cache is not None:  # prefill: leave a valid decode cache behind
            # recompute final state cheaply via one extra scan over chunks is
            # wasteful; instead run the recurrence on the last conv window +
            # full state from ssd. For simplicity we rebuild the state with a
            # dedicated pass (used only in serving prefill).
            state = _final_state(xh.astype(jnp.float32), dth, a,
                                 bc.astype(jnp.float32), cc.astype(jnp.float32))
            cache = {"conv": conv_in[:, -(cfg.ssm_conv_width - 1):, :].astype(jnp.float32),
                     "state": state}

    y = shard(y, "batch", "seq", "ff")
    y = rms_norm(y, params[prefix + "norm"], cfg.norm_eps) * jax.nn.silu(z)
    out = (y @ params[prefix + "out"]).astype(x.dtype)
    return shard(out, "batch", "seq", None), cache


def _final_state(x, dt, a, bmat, cmat, chunk: int = 128):
    """State after consuming the full sequence (for prefill->decode handoff)."""
    bsz, s, h, p = x.shape
    n = bmat.shape[-1]
    c = min(chunk, s)
    pad = (-s) % c
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
    nc = x.shape[1] // c
    xc = x.reshape(bsz, nc, c, h, p)
    dtc = dt.reshape(bsz, nc, c, h)
    bc = bmat.reshape(bsz, nc, c, n)

    def step(hstate, xs):
        xk, dtk, bk = xs
        log_a = a[None, None, :] * dtk
        sdec = jnp.cumsum(log_a, axis=1)
        s_last = sdec[:, -1, :]
        wdec = jnp.exp(s_last[:, None, :] - sdec)
        xbar = xk * dtk[..., None]
        hstate = jnp.exp(s_last)[:, :, None, None] * hstate + jnp.einsum(
            "bln,blhp->bhnp", bk, xbar * wdec[..., None])
        return hstate, None

    h0 = jnp.zeros((bsz, h, n, p), jnp.float32)
    hstate, _ = jax.lax.scan(step, h0, (jnp.moveaxis(xc, 1, 0), jnp.moveaxis(dtc, 1, 0),
                                        jnp.moveaxis(bc, 1, 0)))
    return hstate
