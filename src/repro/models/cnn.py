"""The paper's MNIST CNNs (Appendix E, Table 3) as flat-parameter models.

CDP model:  conv(4 filters, 4x4) -> conv(8, 4x4) -> FC 128->32 -> ReLU -> FC 32->10
LDP model:  conv(2, 4x4) -> conv(1, 4x4) -> FC 16->10

Strides are not stated in the paper; we use stride 2 then 3 (VALID), which is
the unique choice making the flatten widths equal the stated FC fan-ins
(28 -> 13 -> 4: 4*4*8 = 128 for CDP, 4*4*1 = 16 for LDP).  ReLU follows each
conv (the paper's table lists only the FC ReLU; a linear conv stack cannot
learn the task — deviation noted).  Softmax is folded into the cross-entropy.

Parameter counts: CDP d = 5,046; LDP d = 237 — small enough that LDP noise
O(d sigma^2) stays informative, matching the paper's LDP/CDP model split.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.fedsim.flat import flatten_model

__all__ = ["CNNModel", "make_cnn", "make_cnn_params", "masked_xent_loss",
           "pytree_xent_loss", "accuracy_fn", "pytree_accuracy_fn"]


def _conv(x, w, b, stride):
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + b


def _forward(params, x):
    h = jax.nn.relu(_conv(x, params["c1_w"], params["c1_b"], 2))
    h = jax.nn.relu(_conv(h, params["c2_w"], params["c2_b"], 3))
    h = h.reshape(h.shape[0], -1)
    if "f1_w" in params:
        h = jax.nn.relu(h @ params["f1_w"] + params["f1_b"])
    return h @ params["out_w"] + params["out_b"]


@dataclasses.dataclass
class CNNModel:
    init_flat: jax.Array
    unravel: Callable
    dim: int

    def apply(self, w_flat: jax.Array, x: jax.Array) -> jax.Array:
        return _forward(self.unravel(w_flat), x)


def make_cnn_params(key: jax.Array, variant: str = "cdp") -> dict:
    """The raw parameter PYTREE of the paper's CNNs (He-init convs + FCs).

    The pytree is a first-class model for the session API: pass it straight
    to ``FederatedSession`` with ``pytree_xent_loss()`` and the session
    ravels at the clip/aggregate boundary (DESIGN.md §10/§11).  ``make_cnn``
    wraps it into the historical flat-vector ``CNNModel``.
    """
    ks = jax.random.split(key, 6)
    he = lambda k, shape, fan_in: jax.random.normal(k, shape) * jnp.sqrt(2.0 / fan_in)
    if variant == "cdp":
        return {
            "c1_w": he(ks[0], (4, 4, 1, 4), 16), "c1_b": jnp.zeros(4),
            "c2_w": he(ks[1], (4, 4, 4, 8), 64), "c2_b": jnp.zeros(8),
            "f1_w": he(ks[2], (128, 32), 128), "f1_b": jnp.zeros(32),
            "out_w": he(ks[3], (32, 10), 32), "out_b": jnp.zeros(10),
        }
    if variant == "ldp":
        return {
            "c1_w": he(ks[0], (4, 4, 1, 2), 16), "c1_b": jnp.zeros(2),
            "c2_w": he(ks[1], (4, 4, 2, 1), 32), "c2_b": jnp.zeros(1),
            "out_w": he(ks[2], (16, 10), 16), "out_b": jnp.zeros(10),
        }
    raise ValueError(f"unknown CNN variant {variant!r}")


def make_cnn(key: jax.Array, variant: str = "cdp") -> CNNModel:
    """variant: 'cdp' (4/8 filters + hidden FC) or 'ldp' (2/1 filters)."""
    params = make_cnn_params(key, variant)
    flat, unravel = flatten_model(params)
    return CNNModel(init_flat=flat, unravel=unravel, dim=flat.shape[0])


def _masked_xent(logits, batch):
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, batch["y"][:, None], axis=-1)[:, 0]
    mask = batch.get("mask")
    if mask is None:
        return jnp.mean(nll)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def masked_xent_loss(model: CNNModel):
    """Client loss on the flat model: mask-weighted mean softmax xent."""

    def loss(w_flat, batch):
        return _masked_xent(model.apply(w_flat, batch["x"]), batch)

    return loss


def pytree_xent_loss():
    """Client loss on the raw parameter pytree (``make_cnn_params``) — what a
    ``LocalSpec`` minibatch session trains without any hand-written flat
    wrapper."""

    def loss(params, batch):
        return _masked_xent(_forward(params, batch["x"]), batch)

    return loss


def accuracy_fn(model: CNNModel, x: jax.Array, y: jax.Array, chunk: int = 1000):
    """Eval closure: test accuracy (Fig. 1 right metric)."""

    def fn(w_flat):
        n = x.shape[0]
        correct = 0.0
        for s in range(0, n, chunk):
            logits = model.apply(w_flat, jax.lax.dynamic_slice_in_dim(x, s, min(chunk, n - s)))
            correct += jnp.sum(jnp.argmax(logits, -1) == jax.lax.dynamic_slice_in_dim(y, s, min(chunk, n - s)))
        return correct / n

    return fn


def pytree_accuracy_fn(x: jax.Array, y: jax.Array, chunk: int = 1000):
    """``accuracy_fn`` for raw parameter pytrees (``make_cnn_params``)."""

    def fn(params):
        n = x.shape[0]
        correct = 0.0
        for s in range(0, n, chunk):
            logits = _forward(params, jax.lax.dynamic_slice_in_dim(x, s, min(chunk, n - s)))
            correct += jnp.sum(jnp.argmax(logits, -1) == jax.lax.dynamic_slice_in_dim(y, s, min(chunk, n - s)))
        return correct / n

    return fn
