"""Feed-forward blocks: SwiGLU / GeGLU / plain GELU."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import Param
from repro.models.sharding import shard

__all__ = ["mlp_defs", "mlp_apply"]


def mlp_defs(cfg: ModelConfig, prefix: str = "mlp_", d_ff: int | None = None) -> dict[str, Param]:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    gated = cfg.activation in ("swiglu", "geglu")
    defs = {
        prefix + "wi": Param((d, (2 if gated else 1) * f), ("embed", "ff"), fan_in=d),
        prefix + "wo": Param((f, d), ("ff", "embed"), fan_in=f),
    }
    if cfg.use_bias:
        defs[prefix + "wi_b"] = Param(((2 if gated else 1) * f,), ("ff",))
        defs[prefix + "wo_b"] = Param((d,), ("embed",))
    return defs


def mlp_apply(params: dict, x: jax.Array, cfg: ModelConfig, prefix: str = "mlp_") -> jax.Array:
    h = x @ params[prefix + "wi"]
    if prefix + "wi_b" in params:
        h = h + params[prefix + "wi_b"]
    h = shard(h, "batch", "seq", "ff")
    if cfg.activation in ("swiglu", "geglu"):
        gate, up = jnp.split(h, 2, axis=-1)
        act = jax.nn.silu(gate) if cfg.activation == "swiglu" else jax.nn.gelu(gate)
        h = act * up
    else:
        h = jax.nn.gelu(h)
    y = h @ params[prefix + "wo"]
    if prefix + "wo_b" in params:
        y = y + params[prefix + "wo_b"]
    return shard(y, "batch", "seq", None)
