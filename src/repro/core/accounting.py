"""Privacy accounting for DP-FedEXP (Propositions 4.1 / 4.2 + tight numerics).

Three accountants:

1. **RDP** (Mironov 2017) — the paper's stated guarantees:
   Gaussian with sensitivity ``s`` and noise std ``sigma`` is
   (alpha, alpha * s^2 / (2 sigma^2))-RDP; composition adds; conversion via
   Lemma C.2: eps = eps_rdp + log(1/delta)/(alpha - 1), minimized over alpha.

2. **GDP / analytic Gaussian ("numerical composition")** — the paper audits
   with Gopi et al.'s numerical composition.  For compositions of *Gaussian*
   mechanisms the privacy-loss distribution is exactly Gaussian, so numerical
   composition reduces to f-DP algebra: each mechanism contributes
   mu_j = s_j / sigma_j and the T-fold composition has
   mu_tot = sqrt(sum_j T_j mu_j^2).  The exact (eps, delta) curve is the
   Balle & Wang (2018) analytic formula
        delta(eps) = Phi(mu/2 - eps/mu) - e^eps * Phi(-mu/2 - eps/mu),
   inverted for eps by bisection.  This is tight (it *is* the numerical
   composition answer, computed in closed form).

3. **Pure DP** for PrivUnit: eps = eps0 + eps1 + eps2 (Lemma B.1).

All math is float64 Python (no jax) — accounting is config-time.
"""
from __future__ import annotations

import dataclasses
import math

__all__ = [
    "gaussian_rdp_epsilon",
    "gdp_epsilon",
    "gdp_delta",
    "gdp_mu_for_epsilon",
    "sigma_for_epsilon",
    "subsampled_gdp_mu",
    "composed_gdp_mu",
    "realized_participation",
    "ldp_gaussian_budget",
    "cdp_budget",
    "schedule_ldp_budget",
    "schedule_cdp_budget",
    "privunit_budget",
    "PrivacyReport",
]


def realized_participation(sampling_q: float, dropout: float = 0.0) -> float:
    """Per-round participation rate the accountant should compose with.

    Under the §13 fault model a sampled client DROPS OUT independently with
    probability ``dropout`` before contributing, so the realized per-round
    participation is q * (1 - dropout) — a client's data enters round t's
    release only if it is both sampled AND alive, two independent Bernoulli
    events.  Budgets must compose against this realized rate, not the
    nominal q: the dropped clients' updates never touch the release, so
    amplification-by-subsampling applies at the realized rate (and the
    conditional-sensitivity inflation of ``cdp_budget`` inflates by the same
    realized rate — accounting stays honest in both directions).
    """
    if not 0.0 <= dropout < 1.0:
        raise ValueError(f"dropout must be in [0, 1), got {dropout}")
    return sampling_q * (1.0 - dropout)


def _phi(x: float) -> float:
    return 0.5 * (1.0 + math.erf(x / math.sqrt(2.0)))


def _log_phi(x: float) -> float:
    """log Phi(x), stable for very negative x (Mills-ratio asymptotic).

    The underflow floor is applied WITHOUT constructing a denormal: XLA's
    CPU compute threads run with flush-to-zero/denormals-are-zero, and the
    telemetry ledger (§15) evaluates this inside an ``io_callback`` on such
    a thread — there ``max(p, 5e-324)`` flushes to 0.0 and ``math.log``
    raises.  The precomputed constant is ``log(5e-324)``, so results are
    bit-identical to the historical expression on normal threads.
    """
    if x > -30.0:
        p = _phi(x)
        return math.log(p) if p > 0.0 else -744.4400719213812
    a = -x
    return -0.5 * a * a - 0.5 * math.log(2.0 * math.pi) - math.log(a)


# ---------------------------------------------------------------------------
# RDP
# ---------------------------------------------------------------------------

def gaussian_rdp_epsilon(rho: float, delta: float) -> float:
    """min over alpha of  alpha * rho + log(1/delta)/(alpha - 1).

    ``rho`` is the per-unit-alpha RDP rate (paper notation: Gaussian with
    sensitivity 2C and std sigma has rho = 2 C^2 / sigma^2).  The optimum is
    alpha* = 1 + sqrt(log(1/delta)/rho), giving eps = rho + 2 sqrt(rho log(1/delta)).
    """
    if rho <= 0.0:
        return 0.0
    l = math.log(1.0 / delta)
    return rho + 2.0 * math.sqrt(rho * l)


# ---------------------------------------------------------------------------
# GDP / analytic Gaussian
# ---------------------------------------------------------------------------

def gdp_delta(mu: float, eps: float) -> float:
    """Balle-Wang delta(eps) for a mu-GDP (Gaussian) mechanism.

    The second term is evaluated in log space: exp(eps) overflows float64 past
    eps ~ 709 while Phi(-mu/2 - eps/mu) underflows, but their product is <= 1.
    """
    if mu <= 0.0:
        return 0.0
    first = _phi(mu / 2.0 - eps / mu)
    log_second = eps + _log_phi(-mu / 2.0 - eps / mu)
    second = math.exp(log_second) if log_second < 700.0 else float("inf")
    return first - second


def gdp_epsilon(mu: float, delta: float) -> float:
    """Invert delta(eps) for eps >= 0 by bisection (delta(eps) is decreasing)."""
    if mu <= 0.0:
        return 0.0
    if gdp_delta(mu, 0.0) <= delta:
        return 0.0  # the delta target is met with no epsilon at all
    lo, hi = 0.0, 1.0
    while gdp_delta(mu, hi) > delta:
        hi *= 2.0
        if hi > 1e6:
            return float("inf")
    for _ in range(200):
        mid = 0.5 * (lo + hi)
        if gdp_delta(mu, mid) > delta:
            lo = mid
        else:
            hi = mid
    return 0.5 * (lo + hi)


def gdp_mu_for_epsilon(eps: float, delta: float) -> float:
    """Largest GDP parameter mu whose (eps, delta) curve meets the target.

    The inverse of ``gdp_epsilon`` in mu: ``gdp_epsilon`` is increasing in mu
    (more privacy loss per unit noise), so bisection on mu finds the largest
    mechanism the budget admits.  This is how a per-client epsilon budget
    turns into a per-client noise scale (``sigma_for_epsilon``).
    """
    if eps <= 0.0:
        raise ValueError(f"eps must be > 0, got {eps}")
    if not 0.0 < delta < 1.0:
        raise ValueError(f"delta must be in (0, 1), got {delta}")
    lo, hi = 0.0, 1.0
    while gdp_epsilon(hi, delta) < eps:
        lo = hi
        hi *= 2.0
        if hi > 1e8:  # pragma: no cover - astronomically loose budget
            return hi
    for _ in range(200):
        mid = 0.5 * (lo + hi)
        if gdp_epsilon(mid, delta) < eps:
            lo = mid
        else:
            hi = mid
    return 0.5 * (lo + hi)


def sigma_for_epsilon(eps: float, delta: float,
                      sensitivity: float = 1.0) -> float:
    """Noise std giving a Gaussian release of ``sensitivity`` exactly
    (eps, delta)-DP (via the tight GDP curve: sigma = sensitivity / mu).

    This is the per-client calibration of the heterogeneous-privacy
    mechanism (``PerClientGaussian``): client i's budget eps_i maps to
    sigma_i = 2C / gdp_mu_for_epsilon(eps_i, delta) — larger budgets, less
    noise.  Float64 Python, config time only.
    """
    if sensitivity <= 0.0:
        raise ValueError(f"sensitivity must be > 0, got {sensitivity}")
    return sensitivity / gdp_mu_for_epsilon(eps, delta)


def subsampled_gdp_mu(mu_round: float, q: float, rounds: int) -> float:
    """Total GDP parameter of T q-subsampled rounds — amplification by
    subsampling (Bu, Dong, Long & Su 2020, "Deep learning with Gaussian
    differential privacy", Thm. 5 CLT).

    Each round releases through a mu_round-GDP Gaussian mechanism on a
    Poisson-sampled cohort (every client participates independently w.p. q —
    exactly ``CohortSpec(q=...)``); the T-fold composition converges to

        mu_total = q * sqrt(T * (e^{mu_round^2} - 1)).

    q = 1 short-circuits to the exact full-participation composition
    ``mu_round * sqrt(T)`` (the CLT expression is an over-estimate there, and
    no amplification applies).  The CLT is asymptotic in T with q*sqrt(T)
    held moderate — the federated regime (T in the tens-to-thousands,
    q << 1) it was derived for.
    """
    if q >= 1.0:
        return mu_round * math.sqrt(rounds)
    if q <= 0.0 or rounds <= 0:
        return 0.0
    x = mu_round * mu_round
    if x > 700.0:
        # exp overflows float64 here; the budget is effectively infinite
        # (a 1/q-inflated conditional release at tiny q) — report inf, and
        # gdp_epsilon(inf, delta) propagates it as eps=inf rather than
        # crashing the report
        return float("inf")
    return q * math.sqrt(rounds * (math.exp(x) - 1.0))


def composed_gdp_mu(mus, q: float = 1.0) -> float:
    """Total GDP parameter of a NON-UNIFORM per-round sequence ``mus``.

    The schedule generalization of ``subsampled_gdp_mu``: round t releases
    through a mu_t-GDP Gaussian mechanism (a sigma(t) noise schedule gives a
    different mu_t each round), and

        q = 1:  mu_total = sqrt(sum_t mu_t^2)                 (exact — the
                 PLD of a Gaussian composition is Gaussian regardless of
                 whether the per-round scales match)
        q < 1:  mu_total = q * sqrt(sum_t (e^{mu_t^2} - 1))   (the Bu et al.
                 2020 CLT with the per-round Berry-Esseen terms summed
                 instead of multiplied by T — uniform schedules reduce to
                 ``subsampled_gdp_mu`` exactly)

    A uniform sequence reproduces ``subsampled_gdp_mu(mu, q, T)`` bit-for-bit
    in both regimes (pinned by tests/test_schedules.py).
    """
    mus = list(mus)
    if not mus:
        return 0.0
    if any(m < 0.0 for m in mus):
        raise ValueError("per-round mu must be >= 0")
    if len(set(mus)) == 1:
        # uniform schedules delegate to the uniform accountant so the
        # homogeneous reduction is EXACT (same floats, not same-to-ulps)
        return subsampled_gdp_mu(mus[0], q, len(mus))
    if q >= 1.0:
        return math.sqrt(sum(m * m for m in mus))
    if q <= 0.0:
        return 0.0
    total = 0.0
    for m in mus:
        x = m * m
        if x > 700.0:
            return float("inf")  # same overflow contract as subsampled_gdp_mu
        total += math.exp(x) - 1.0
    return q * math.sqrt(total)


# ---------------------------------------------------------------------------
# Paper-level budget helpers
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PrivacyReport:
    """Privacy budget of one algorithm/run: numerical (GDP) and RDP epsilons at delta."""
    setting: str
    eps_numerical: float      # tight (GDP/analytic) — comparable to Table 1
    eps_rdp: float            # the paper's stated RDP bound (Props. 4.1/4.2)
    delta: float
    mu: float                 # total GDP parameter (0 for pure-DP mechanisms)

    def __str__(self) -> str:  # pragma: no cover - cosmetic
        return (f"{self.setting}: eps={self.eps_numerical:.3f} (numerical), "
                f"{self.eps_rdp:.3f} (RDP bound), delta={self.delta:g}")


def ldp_gaussian_budget(clip_norm: float, sigma: float, delta: float) -> PrivacyReport:
    """Proposition 4.1 (Gaussian): per-release client guarantee.

    Sensitivity of one client's clipped update is 2C (substitution), noise std
    sigma => rho = 2 C^2 / sigma^2 and mu = 2C / sigma.  Identical for
    DP-FedAvg and LDP-FedEXP (the step size is computed server-side from the
    already-released c_i).
    """
    mu = 2.0 * clip_norm / sigma
    rho = 2.0 * clip_norm**2 / sigma**2
    return PrivacyReport("LDP (Gaussian)", gdp_epsilon(mu, delta),
                         gaussian_rdp_epsilon(rho, delta), delta, mu)


def cdp_budget(clip_norm: float, sigma: float, num_clients: int, rounds: int,
               delta: float, sigma_xi: float | None = None,
               sampling_q: float = 1.0) -> PrivacyReport:
    """Proposition 4.2: T-round central guarantee, amplification-aware.

    Per round: mean release has sensitivity 2C/M with noise std sigma/sqrt(M)
    (the paper's eps^(t) ~ N(0, sigma^2/M)), i.e. mu_mean = 2C/(sigma sqrt(M));
    the FedEXP numerator has sensitivity C^2/M with std sigma_xi, i.e.
    mu_xi = C^2/(M sigma_xi).  Pass ``sigma_xi=None`` for DP-FedAvg (no
    numerator release).

    ``sampling_q < 1`` is the per-round client sampling rate (``CohortSpec``)
    and models the engine's ACTUAL sampled release: the mean is normalized by
    the realized cohort (~qM clients) while the noise std stays sigma/sqrt(M),
    so the CONDITIONAL per-round sensitivity (given the swapped client
    participates, which happens w.p. q) is 2C/(qM) — the full-participation
    mu inflated by 1/q — and the same inflation applies to the numerator
    release.  The tight eps_numerical then composes via the subsampled-GDP
    CLT (``subsampled_gdp_mu``); note the inflation and the amplification
    cancel to first order, so sampling at a FIXED sigma is not a free privacy
    win — honest accounting, not the naive q-discount.  eps_rdp composes the
    inflated conditional release UNAMPLIFIED — a valid (loose) upper bound,
    flagged by the report name, since subsampled-RDP has no closed form here.
    Fixed-size cohorts are approximated as Poisson at rate size/M.
    """
    m = float(num_clients)
    q = sampling_q if 0.0 < sampling_q < 1.0 else 1.0
    mu_mean = 2.0 * clip_norm / (sigma * math.sqrt(m)) / q
    rho = rounds * 2.0 * clip_norm**2 / (m * sigma**2) / q**2
    mu_round_sq = mu_mean**2
    if sigma_xi is not None and sigma_xi > 0.0:
        mu_xi = clip_norm**2 / (m * sigma_xi) / q
        mu_round_sq += mu_xi**2
        rho += rounds * clip_norm**4 / (2.0 * m**2 * sigma_xi**2) / q**2
    mu = subsampled_gdp_mu(math.sqrt(mu_round_sq), q, rounds)
    name = "CDP (FedEXP)" if sigma_xi else "CDP (FedAvg)"
    if sampling_q < 1.0:
        name += f", q={sampling_q:g} subsampled"
    return PrivacyReport(name, gdp_epsilon(mu, delta),
                         gaussian_rdp_epsilon(rho, delta), delta, mu)


def schedule_ldp_budget(clip_norm: float, sigmas, delta: float) -> PrivacyReport:
    """T-round LDP budget of a NON-UNIFORM noise schedule sigma(t).

    Unlike the uniform ``ldp_gaussian_budget`` (per-release — every round's
    release carries the same guarantee), a schedule's rounds differ, so the
    honest client-level guarantee is the COMPOSITION over the executed
    rounds: per-round mu_t = 2C / sigma_t summed in GDP (exact — Gaussian
    PLDs compose in closed form), rho_t = 2 C^2 / sigma_t^2 summed for the
    RDP upper bound.  No subsampling amplification is applied: local
    guarantees hold against the client's own releases and do not amplify
    under central sampling of who participates.

    A length-1 schedule with sigma_0 == sigma reproduces
    ``ldp_gaussian_budget(C, sigma, delta)``'s numbers exactly.
    """
    sigmas = list(sigmas)
    if not sigmas:
        raise ValueError("schedule_ldp_budget needs at least one round")
    if any(s <= 0.0 for s in sigmas):
        raise ValueError("every scheduled sigma must be > 0")
    mu = composed_gdp_mu([2.0 * clip_norm / s for s in sigmas], q=1.0)
    rho = sum(2.0 * clip_norm**2 / s**2 for s in sigmas)
    return PrivacyReport(f"LDP (Gaussian, {len(sigmas)}-round schedule)",
                         gdp_epsilon(mu, delta),
                         gaussian_rdp_epsilon(rho, delta), delta, mu)


def schedule_cdp_budget(clip_norm: float, sigmas, num_clients: int,
                        delta: float, sigma_xis=None,
                        sampling_q: float = 1.0) -> PrivacyReport:
    """T-round central budget of a NON-UNIFORM noise schedule sigma(t).

    The schedule generalization of ``cdp_budget``: round t's mean release
    has mu_t = 2C/(sigma_t sqrt(M))/q (conditional-sensitivity inflation as
    in ``cdp_budget``) and, when ``sigma_xis`` names per-round numerator
    noise scales, the numerator release adds (C^2/(M sigma_xi_t)/q)^2 to
    mu_t^2.  The per-round mus compose via ``composed_gdp_mu`` (exact
    Gaussian composition at q=1, summed-CLT amplification at q<1); rho sums
    per round for the RDP upper bound (composed unamplified — same
    upper-bound caveat as ``cdp_budget``).

    A uniform schedule reproduces ``cdp_budget(C, sigma, M, T, delta, ...)``
    exactly (the composition helpers short-circuit uniform sequences to the
    uniform accountants).
    """
    sigmas = list(sigmas)
    if not sigmas:
        raise ValueError("schedule_cdp_budget needs at least one round")
    if any(s <= 0.0 for s in sigmas):
        raise ValueError("every scheduled sigma must be > 0")
    if sigma_xis is not None:
        sigma_xis = list(sigma_xis)
        if len(sigma_xis) != len(sigmas):
            raise ValueError(
                f"sigma_xis has {len(sigma_xis)} entries for a "
                f"{len(sigmas)}-round schedule")
    m = float(num_clients)
    q = sampling_q if 0.0 < sampling_q < 1.0 else 1.0
    mus, rho = [], 0.0
    for t, s in enumerate(sigmas):
        mu_sq = (2.0 * clip_norm / (s * math.sqrt(m)) / q) ** 2
        rho += 2.0 * clip_norm**2 / (m * s**2) / q**2
        if sigma_xis is not None and sigma_xis[t] > 0.0:
            mu_sq += (clip_norm**2 / (m * sigma_xis[t]) / q) ** 2
            rho += clip_norm**4 / (2.0 * m**2 * sigma_xis[t]**2) / q**2
        mus.append(math.sqrt(mu_sq))
    mu = composed_gdp_mu(mus, q)
    name = ("CDP (FedEXP" if sigma_xis is not None else "CDP (FedAvg")
    name += f", {len(sigmas)}-round schedule)"
    if sampling_q < 1.0:
        name += f", q={sampling_q:g} subsampled"
    return PrivacyReport(name, gdp_epsilon(mu, delta),
                         gaussian_rdp_epsilon(rho, delta), delta, mu)


def privunit_budget(eps0: float, eps1: float, eps2: float) -> PrivacyReport:
    """Lemma B.1: PrivUnit x ScalarDP is pure (eps0 + eps1 + eps2)-LDP."""
    eps = eps0 + eps1 + eps2
    return PrivacyReport("LDP (PrivUnit)", eps, eps, 0.0, 0.0)
