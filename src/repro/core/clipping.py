"""L2 clipping of client updates (DP-FedAvg / DP-FedEXP, Algorithms 1 & 2).

Each client clips its local update before release:

    Delta_i <- min{ C / ||Delta~_i||, 1 } * Delta~_i

which bounds the l2-sensitivity of the round release by C (LDP) / 2C/M (CDP
mean, substitution adjacency).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["l2_norm", "clip_by_l2", "clip_batch", "global_l2_norm_tree", "clip_tree"]

_EPS = 1e-12


def l2_norm(x: jax.Array) -> jax.Array:
    """L2 norm of a flat vector (stable for zero vectors)."""
    return jnp.sqrt(jnp.sum(jnp.square(x)))


def clip_by_l2(x: jax.Array, clip_norm: float | jax.Array) -> jax.Array:
    """``min(1, C/||x||) * x`` for a flat update vector."""
    nrm = l2_norm(x)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(nrm, _EPS))
    return x * scale


def clip_batch(updates: jax.Array, clip_norm: float | jax.Array) -> jax.Array:
    """Clip a batch of client updates of shape ``(M, d)`` row-wise."""
    return jax.vmap(lambda u: clip_by_l2(u, clip_norm))(updates)


def global_l2_norm_tree(tree) -> jax.Array:
    """Global L2 norm across all leaves of a parameter pytree."""
    leaves = jax.tree_util.tree_leaves(tree)
    sq = sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    return jnp.sqrt(sq)


def clip_tree(tree, clip_norm: float | jax.Array):
    """Clip a pytree update by its *global* L2 norm (one scale for all leaves).

    This is the form used in the datacenter path, where a client's update is a
    sharded parameter pytree rather than a materialized flat vector.
    """
    nrm = global_l2_norm_tree(tree)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(nrm, _EPS))
    return jax.tree_util.tree_map(lambda l: (l.astype(jnp.float32) * scale).astype(l.dtype), tree), nrm
