"""Compressed-communication primitives: rand-k and count-sketch (DESIGN.md §16).

Both compressors are LINEAR maps R^d -> R^kc applied per client row, which is
the whole trick: linearity means ``sum_i compress(c_i) == compress(sum_i c_i)``,
so a compressed partial sum satisfies the §12 additive-moment invariant
verbatim — compressed moments add across clients, stream chunks, and shard
psums, and every engine's O(d) round collective shrinks to O(kc) without any
engine change.  Linearity also commutes with per-row scalar clipping
(``compress(u * s) == compress(u) * s``), so the moment path can compress the
RAW rows and apply the clip scales to the compressed rows — the clipped
(M, d) matrix never materializes, which is where the rand-k speedup lives.

Shared randomness: each round's compression plan (the rand-k index set / the
sketch's bucket+sign tables) is derived from ``fold_in(round_key,
COMPRESS_TAG)``.  The round key is replicated across shards and stream chunks,
so every partition compresses with the IDENTICAL plan — the precondition for
the partial sums to be summands of one linear map.  No per-client state, so
both compose with million-client sampling (§14).

All functions here are pure jnp math with no repro imports (``compose.py``
builds the Aggregation layers on top; ``aggregation.py`` threads the
``compress_fn`` closure through the moment reductions).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "COMPRESS_TAG",
    "randk_plan",
    "randk_compress",
    "randk_decompress",
    "sketch_plan",
    "sketch_compress",
    "sketch_decompress",
    "topk_select",
]

# fold_in tag deriving the per-round COMPRESSION-PLAN key (rand-k index draw,
# sketch bucket/sign tables) from the round key.  Sits next to the fedsim
# tags (SAMPLING_TAG = 2**31 - 1, LOCAL_TRAIN_TAG = 2**31 - 2, FAULT_TAG =
# 2**31 - 3), far outside any plausible client index, so the plan stream
# never collides with sampling, local-training, fault, or client-randomizer
# streams.  Defined HERE (not fedsim.specs) because core must not import
# fedsim; specs re-exports it for spec-level callers.
COMPRESS_TAG = 2**31 - 4


# ---------------------------------------------------------------------------
# Rand-k: unbiased random coordinate subsampling
# ---------------------------------------------------------------------------

def randk_plan(plan_key: jax.Array, d: int, k: int) -> jax.Array:
    """(k,) distinct coordinate indices with inclusion probability k/d each.

    Unbiasedness of the d/k decompression scale only needs the MARGINAL
    ``P(i in S) = k/d`` (``E[(d/k) * x_i * 1[i in S]] = x_i``), so when
    ``k | d`` the draw is STRATIFIED: the d coordinates split into k
    contiguous blocks of d/k and one uniform offset is drawn per block —
    every coordinate lands in exactly one block, giving the exact k/d
    marginal with k independent O(1) draws.  A uniform d-choose-k subset
    (``jax.random.permutation(d)[:k]``) has the same marginal but costs an
    O(d log d) sort of the FULL dimension per round — measured ~1.2 s at
    d = 2**20, several times the whole dense round it is meant to beat.
    The permutation fallback remains for k that does not divide d.
    """
    if k >= d:
        return jnp.arange(d, dtype=jnp.int32)  # lossless: S is everything
    if d % k == 0:
        stride = d // k
        offs = jax.random.randint(plan_key, (k,), 0, stride, dtype=jnp.int32)
        return jnp.arange(k, dtype=jnp.int32) * stride + offs
    return jax.random.permutation(plan_key, d)[:k]


def randk_compress(u: jax.Array, idx: jax.Array) -> jax.Array:
    """Select the plan's coordinates of ``u`` (last axis): (..., d) -> (..., k).

    A coordinate projection — linear, and an L2 CONTRACTION (operator norm
    1), so a C-clipped row stays within sensitivity C in the compressed
    domain (the §16 noise argument needs no re-clip here).
    """
    return jnp.take(u, idx, axis=-1)


def randk_decompress(comp: jax.Array, idx: jax.Array, d: int) -> jax.Array:
    """Unbiased (d,) estimate from the (k,) compressed sum: scatter * d/k."""
    k = idx.shape[0]
    scale = jnp.float32(d / k)
    return jnp.zeros((d,), comp.dtype).at[idx].set(comp * scale)


# ---------------------------------------------------------------------------
# Count-sketch: bucket+sign hashing with median-of-depth recovery
# ---------------------------------------------------------------------------

def sketch_plan(plan_key: jax.Array, d: int, width: int,
                depth: int) -> tuple[jax.Array, jax.Array]:
    """Per-round sketch tables: ``(h, s)`` with ``h`` (depth, d) int32 bucket
    ids in [0, width) and ``s`` (depth, d) float32 Rademacher signs.

    Materializing the hash tables (instead of evaluating a hash function
    per lookup) costs O(depth * d) memory once per round but keeps both
    compress and decompress pure gathers/scatters — the jnp-friendly form.
    """
    kh, ks = jax.random.split(plan_key)
    h = jax.random.randint(kh, (depth, d), 0, width, dtype=jnp.int32)
    s = jax.random.rademacher(ks, (depth, d), dtype=jnp.float32)
    return h, s


def sketch_compress(u: jax.Array, plan: tuple[jax.Array, jax.Array],
                    width: int) -> jax.Array:
    """Count-sketch rows of ``u``: (..., d) -> (..., depth * width).

    Row r of the result is depth stacked width-wide tables,
    ``S[t, b] = sum_{j : h[t,j]=b} s[t,j] * u[r, j]`` — linear in ``u``, so
    compressed rows sum exactly like raw rows (bit-for-bit on integer-valued
    inputs; the sign multiply is exact and the scatter-add accumulates each
    bucket in the same j-order either way).  ``width`` is the static table
    width (the plan's arrays carry no static shape for it).  The depth loop
    is a static Python loop (depth is a small config constant), keeping the
    peak temporary at one (m, d) signed copy rather than (m, depth, d).
    """
    h, s = plan
    depth, _ = h.shape
    squeeze = u.ndim == 1
    rows = u[None] if squeeze else u
    m = rows.shape[0]
    tables = []
    for t in range(depth):
        tab = jnp.zeros((m, width), rows.dtype).at[:, h[t]].add(rows * s[t])
        tables.append(tab)
    comp = jnp.concatenate(tables, axis=-1)
    return comp[0] if squeeze else comp


def sketch_decompress(comp: jax.Array, plan: tuple[jax.Array, jax.Array],
                      d: int) -> jax.Array:
    """Median-of-depth unsketch: (depth * width,) -> (d,) heavy-hitter
    estimate ``median_t(s[t, j] * S[t, h[t, j]])``."""
    h, s = plan
    depth, _ = h.shape
    tables = comp.reshape(depth, -1)
    est = jax.vmap(lambda tab, ht, st: st * jnp.take(tab, ht))(tables, h, s)
    return jnp.median(est, axis=0)


def topk_select(x: jax.Array, k: int) -> jax.Array:
    """Keep exactly the k largest-|x| coordinates of a (d,) vector, zero the
    rest (scatter by top-k indices, so ties never keep more than k)."""
    if k >= x.shape[-1]:
        return x
    _, idx = jax.lax.top_k(jnp.abs(x), k)
    return jnp.zeros_like(x).at[idx].set(jnp.take(x, idx))
