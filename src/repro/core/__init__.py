"""Core DP-FedEXP library — the paper's contribution as composable JAX modules."""

from repro.core import accounting, aggregation, clipping, compose, mechanisms, stepsize
from repro.core.aggregation import RoundStats, aggregate_stats, fused_clip_aggregate
from repro.core.clipping import clip_batch, clip_by_l2, clip_tree, global_l2_norm_tree
from repro.core.compose import (
    AdaptiveClipStep,
    CentralGaussian,
    ComposedAlgorithm,
    FedEXPStep,
    FixedEta,
    GaussianLDP,
    MeanAggregation,
    NoPrivacy,
    PrivUnitLDP,
    ServerOpt,
    WeightedAggregation,
    compose_algorithm,
)
from repro.core.fedexp import (
    CDPFedEXP,
    DPFedAvgCDP,
    DPFedAvgLDPGaussian,
    DPFedAvgPrivUnit,
    FedAvg,
    FedEXP,
    LDPFedEXPGaussian,
    LDPFedEXPPrivUnit,
    RoundAux,
    ServerAlgorithm,
    list_algorithms,
    make_algorithm,
)

__all__ = [
    "accounting", "aggregation", "clipping", "compose", "mechanisms", "stepsize",
    "RoundStats", "aggregate_stats", "fused_clip_aggregate",
    "clip_batch", "clip_by_l2", "clip_tree", "global_l2_norm_tree",
    "ServerAlgorithm", "RoundAux", "make_algorithm", "list_algorithms",
    "FedAvg", "FedEXP", "DPFedAvgLDPGaussian", "LDPFedEXPGaussian",
    "DPFedAvgPrivUnit", "LDPFedEXPPrivUnit", "DPFedAvgCDP", "CDPFedEXP",
    "ComposedAlgorithm", "compose_algorithm",
    "NoPrivacy", "GaussianLDP", "PrivUnitLDP", "CentralGaussian",
    "MeanAggregation", "WeightedAggregation",
    "FixedEta", "FedEXPStep", "ServerOpt", "AdaptiveClipStep",
]
