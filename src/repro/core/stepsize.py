"""Adaptive global step-size rules — the paper's core contribution.

All rules consume *aggregate statistics* of the round (means over the client
axis), so the same functions serve the single-host simulation (`repro.fedsim`,
where the stats are plain means over an (M, d) array) and the datacenter path
(`repro.launch`, where the means are psums over the client mesh axes).

Rules
-----
- ``fedexp``          Eq. (2)  — non-private FedEXP (Jhunjhunwala'23 / Li'24 form).
- ``naive_noisy``     Eq. (3)  — the broken naive extension (for Fig. 2 only).
- ``target``          Eq. (5)  — oracle eta_target (needs true Delta_i; diagnostics).
- ``ldp_gaussian``    Eq. (6)  — bias-corrected numerator: mean ||c_i||^2 - d sigma^2.
- ``ldp_privunit``    Eq. (7)  — mean of Algorithm-4 estimates s_hat_i.
- ``cdp``             Eq. (8)  — true numerator + scalar Gaussian noise xi.
- ``fedavg``                   — constant 1 (DP-FedAvg).
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = [
    "fedavg",
    "fedexp",
    "naive_noisy",
    "target",
    "ldp_gaussian",
    "ldp_gaussian_mixed",
    "ldp_privunit",
    "cdp",
]

_EPS = 1e-12


def _ratio(numerator, denom_sq):
    return numerator / jnp.maximum(denom_sq, _EPS)


def fedavg(*_args, **_kwargs):
    """DP-FedAvg global step size: eta_g = 1."""
    return jnp.float32(1.0)


def fedexp(mean_sq_norm, agg_sq_norm):
    """Eq. (2): eta = max{1, (1/M sum ||Delta_i||^2) / ||mean Delta||^2}.

    We follow Li et al. (2024) and the paper in dropping FedEXP's 1/2 factor
    and denominator epsilon; the max{1, .} keeps eta_g >= 1 so T1 shrinks.
    """
    return jnp.maximum(1.0, _ratio(mean_sq_norm, agg_sq_norm))


def naive_noisy(mean_sq_noisy_norm, agg_sq_norm):
    """Eq. (3): the naive noisy rule — biased upward by d*sigma^2 (Fig. 2).

    Exposed only for the bias-correction ablation; never used for training.
    """
    return _ratio(mean_sq_noisy_norm, agg_sq_norm)


def target(mean_sq_true_norm, agg_sq_noisy_norm):
    """Eq. (5): eta_target — requires the true per-client norms (oracle)."""
    return _ratio(mean_sq_true_norm, agg_sq_noisy_norm)


def ldp_gaussian(mean_sq_noisy_norm, agg_sq_norm, dim, sigma):
    """Eq. (6): LDP-FedEXP with Gaussian mechanism.

    ``mean ||c_i||^2 - d sigma^2`` is an unbiased estimator of
    ``mean ||Delta_i||^2``; max{1,.} guards the (rare, high-noise) negative case.
    """
    corrected = mean_sq_noisy_norm - dim * sigma**2
    return jnp.maximum(1.0, _ratio(corrected, agg_sq_norm))


def ldp_gaussian_mixed(mean_sq_noisy_norm, agg_sq_norm, dim, mean_sigma_sq):
    """Eq. (6) under HETEROGENEOUS per-client noise (PerClientGaussian).

    With client i noised at sigma_i, ``E[mean ||c_i||^2] = mean ||Delta_i||^2
    + d * mean(sigma_i^2)`` over the realized cohort, so the bias correction
    subtracts ``d * mean_sigma_sq`` — the (mask/weight-averaged) mean of the
    participating clients' sigma_i^2, supplied by the mechanism's scalar
    extras.  Uniform sigmas reduce to ``ldp_gaussian`` exactly.
    """
    corrected = mean_sq_noisy_norm - dim * mean_sigma_sq
    return jnp.maximum(1.0, _ratio(corrected, agg_sq_norm))


def ldp_privunit(mean_s_hat, agg_sq_norm):
    """Eq. (7): LDP-FedEXP with PrivUnit; numerator = mean of Alg.-4 estimates."""
    return jnp.maximum(1.0, _ratio(mean_s_hat, agg_sq_norm))


def cdp(mean_sq_true_norm, xi, agg_sq_norm):
    """Eq. (8): CDP-FedEXP — true numerator privatized by scalar noise xi.

    xi ~ N(0, sigma_xi^2) with the hyperparameter-free sigma_xi = d sigma^2 / M;
    sensitivity of the numerator is C^2/M (Prop. 4.2).
    """
    return jnp.maximum(1.0, _ratio(mean_sq_true_norm + xi, agg_sq_norm))
