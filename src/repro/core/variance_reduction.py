"""Control-variate server algorithms (DP-SCAFFOLD on the engine stack, §17).

SCAFFOLD (Karimireddy et al. 2020) removes client drift with control
variates: client i steps with ``g - c_i + c`` and refreshes its variate via
option-II ``c_i+ = c_i - c + (w - y_i)/(tau * eta_l)``.  Under client-level
DP the client releases TWO vectors per round — the model update ``dy`` and
the variate update ``dc`` — each clipped and noised at std ``sigma*sqrt(2)``
(scaled by the variate scale for ``dc``), so the per-round GDP budget
composes to exactly a single release at std ``sigma`` (Noble et al. 2022;
the "noise doubling" the paper's §5 points at).

``DPScaffoldServer`` is that baseline as an engine-facing
``ServerAlgorithm``: the per-client variates live in the server carry
(``ScaffoldState``), the LocalTrainer receives each round's variate rows
through the ``uses_local_context`` hook (``fedsim/server.py::_local_caller``
appends ``local_context(state, start, m_local)`` to the trainer call), and
the two releases ride the standard dense/moments round halves — so the
legacy ``run_dp_scaffold`` Python loop's algorithm now composes with cohort
sampling, streaming, sparse gather, sharding and fault injection.

Bit-compatibility contract (tests/test_schedules.py):

* the DENSE path (scan/eager engines, full participation) replicates the
  legacy ``run_dp_scaffold`` round verbatim — same key splits, same
  ``jnp.mean`` reductions, same central (d,) draws — so ``central=True``
  runs match the retired loop bit-for-bit at any sigma;
* the MOMENTS path (stream/gather/sharded engines) writes sums (``v @ rows``
  — psum-able, mask-weighted) and re-keys local-mode noise per GLOBAL client
  index (``materialize_ldp_noise``), so engines agree at the stack's usual
  cross-engine tolerance; at sigma=0 both paths are bit-identical and the
  local-mode legacy pin holds exactly.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.core import accounting
from repro.core.aggregation import (
    RoundMoments,
    global_client_indices,
    materialize_ldp_noise,
)
from repro.core.algorithm import RoundAux, ServerAlgorithm
from repro.core.clipping import clip_batch

__all__ = ["ScaffoldState", "DPScaffoldServer"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ScaffoldState:
    """Server carry of a control-variate run: the global variate ``c`` (d,)
    and the per-client variate table ``c_is`` (num_clients, d).

    Rides the engines' existing scan/stream/checkpoint carry exactly like an
    optimizer state — resume, §13 rollback and the divergence watchdog all
    snapshot/restore it with the model vector, no engine changes.
    """

    c: jax.Array
    c_is: jax.Array


@dataclasses.dataclass(frozen=True)
class DPScaffoldServer(ServerAlgorithm):
    """DP-SCAFFOLD (Noble, Bellet, Dieuleveut, AISTATS 2022) as a stateful
    engine algorithm: two clipped+noised releases per round over a
    control-variate local trainer (``LocalSpec(control_variates=True)``).

    ``central=True`` noises the two means server-side at
    ``sigma*sqrt(2)/sqrt(num_clients)`` (CDP); ``central=False`` noises each
    client's releases at ``sigma*sqrt(2)`` before aggregation (LDP).  The
    eta_g is pinned to 1 — SCAFFOLD has no extrapolation rule; that contrast
    IS the paper's baseline comparison.
    """

    clip_norm: float
    sigma: float                 # baseline noise scale (as for DP-FedAvg)
    central: bool                # True: CDP noise on the means
    num_clients: int
    tau: int
    eta_l: float

    name = "dp-scaffold"
    uses_local_context = True    # _local_caller appends (c_i rows, c)

    def __post_init__(self):
        if self.clip_norm <= 0:
            raise ValueError(f"clip_norm must be positive, got {self.clip_norm}")
        if self.sigma < 0:
            raise ValueError(f"sigma must be >= 0, got {self.sigma}")
        if self.num_clients < 1:
            raise ValueError(f"num_clients must be >= 1, got {self.num_clients}")
        if self.tau < 1:
            raise ValueError(f"tau must be >= 1, got {self.tau}")
        if self.eta_l <= 0:
            raise ValueError(f"eta_l must be positive, got {self.eta_l}")

    @property
    def variate_scale(self) -> float:
        """Option-II refresh scale 1/(tau * eta_l): dc = -c - vs * dy."""
        return 1.0 / (self.tau * self.eta_l)

    def comm_floats(self, d: int) -> int:
        """Two (d,) releases ride every round reduction (the §16 model
        counts the variate-update vector next to the usual payload)."""
        return 2 * d + 3

    def init_state(self, w):
        """Zero variates: the legacy loop's exact starting carry."""
        d = w.shape[-1]
        return ScaffoldState(c=jnp.zeros_like(w),
                             c_is=jnp.zeros((self.num_clients, d), w.dtype))

    # -- LocalTrainer context (fedsim/server.py::_local_caller) -------------

    def local_context(self, state, start, m_local: int):
        """This shard/chunk's variate rows + the global variate: ``(c_i, c)``.

        ``start`` follows the engines' global-index contract: a static 0
        (dense full cohort — returns the table itself, bit-identical), a
        traced scalar (shard/chunk slices; the table is zero-padded by
        ``m_local`` rows so fully-padded tail chunks clamp onto inert zero
        rows), or a (m_local,) gather-slot vector (§14; padding slots point
        at client 0 and are mask-zeroed downstream).
        """
        c_is = state.c_is
        m = c_is.shape[0]
        if getattr(start, "ndim", 0) == 1:
            rows = jnp.take(c_is, jnp.minimum(start, m - 1), axis=0)
            return rows, state.c
        if isinstance(start, int) and start == 0 and m_local == m:
            return c_is, state.c
        padded = jnp.concatenate(
            [c_is, jnp.zeros((m_local,) + c_is.shape[1:], c_is.dtype)])
        rows = jax.lax.dynamic_slice_in_dim(padded, start, m_local)
        return rows, state.c

    def _dc(self, deltas, c_i, c):
        """Variate updates from the raw dy rows — the legacy loop's exact
        op order ``(c_i - c - vs*dy) - c_i`` (NOT the algebraic ``-c -
        vs*dy``: fp non-associativity makes those differ bitwise, and the
        dense path is pinned bit-for-bit against the retired loop)."""
        c_i_new = c_i - c - deltas * self.variate_scale
        return c_i_new - c_i

    # -- dense round (scan/eager engines; legacy-verbatim) ------------------

    def apply_round(self, key, w, raw_deltas):
        """One dense server round: ``(key, w, (M, d) raw deltas) -> (w_next, RoundAux)``."""
        raise TypeError(f"{self.name} is stateful; use apply_round_stateful")

    def apply_round_stateful(self, key, w, raw_deltas, state):
        """Full-participation dense round, replicating the retired
        ``run_dp_scaffold`` body verbatim (same splits, same ``jnp.mean``):
        the bit-for-bit legacy pin.  Local-mode noise is the per-client
        keyed stream (``materialize_ldp_noise``) rather than the loop's one
        monolithic (M, d) draw — identical at sigma=0, where the local pin
        is asserted, and engine-reproducible at sigma>0."""
        m, d = raw_deltas.shape
        vs = self.variate_scale
        dc = self._dc(raw_deltas, state.c_is, state.c)
        dy_clip = clip_batch(raw_deltas, self.clip_norm)
        dc_clip = clip_batch(dc, self.clip_norm * vs)
        k_dy, k_dc = jax.random.split(key)
        if self.central:
            std = self.sigma * math.sqrt(2.0) / math.sqrt(self.num_clients)
            dy_bar = jnp.mean(dy_clip, axis=0) \
                + std * jax.random.normal(k_dy, (d,))
            dc_bar = jnp.mean(dc_clip, axis=0) \
                + std * vs * jax.random.normal(k_dc, (d,))
        else:
            std = self.sigma * math.sqrt(2.0)
            dy_bar = jnp.mean(
                dy_clip + materialize_ldp_noise(k_dy, m, d, std,
                                                raw_deltas.dtype, start=0),
                axis=0)
            dc_bar = jnp.mean(
                dc_clip + materialize_ldp_noise(k_dc, m, d, std * vs,
                                                raw_deltas.dtype, start=0),
                axis=0)
        state_next = ScaffoldState(c=state.c + dc_bar,
                                   c_is=state.c_is + dc_clip)
        return w + dy_bar, RoundAux(eta_g=jnp.float32(1.0)), state_next

    # -- sharded/streamed round halves (DESIGN.md §9/§12/§14) ---------------

    def local_moments(self, key, w, deltas, mask, start, state):
        """Partial SUMS of both releases over the masked rows at global
        ``start``: the dy release rides the standard ``RoundMoments``; the
        dc release sum and the per-client variate-table delta (a scattered
        (num_clients, d) add — additive across shards/chunks, so it psums)
        ride the extras dict."""
        m_local, d = deltas.shape
        vs = self.variate_scale
        if mask is None:
            mask = jnp.ones((m_local,), jnp.float32)
        gidx = global_client_indices(start, m_local)
        c_i = jnp.take(state.c_is, jnp.minimum(gidx, self.num_clients - 1),
                       axis=0)
        # gate BEFORE clipping: a masked row's dc would otherwise be the
        # nonzero -c (its deltas are zeroed, its c_i is a pad/garbage row)
        gate = mask[:, None] > 0
        dc = jnp.where(gate, self._dc(deltas, c_i, state.c), 0.0)
        dy_clip = clip_batch(deltas, self.clip_norm)
        dc_clip = clip_batch(dc, self.clip_norm * vs)
        rel_dy, rel_dc = dy_clip, dc_clip
        if not self.central and self.sigma > 0:
            k_dy, k_dc = jax.random.split(key)
            std = self.sigma * math.sqrt(2.0)
            rel_dy = dy_clip + materialize_ldp_noise(
                k_dy, m_local, d, std, deltas.dtype, start=start)
            rel_dc = dc_clip + materialize_ldp_noise(
                k_dc, m_local, d, std * vs, deltas.dtype, start=start)
        mom = RoundMoments(
            sum_c=mask @ rel_dy,
            sum_sq=mask @ jnp.sum(jnp.square(rel_dy), axis=-1),
            sum_sq_clipped=mask @ jnp.sum(jnp.square(dy_clip), axis=-1),
            count=jnp.sum(mask))
        cis_add = jnp.zeros((self.num_clients, d), deltas.dtype) \
            .at[gidx].add(dc_clip * mask[:, None], mode="drop")
        return mom, {"sum_dc": mask @ rel_dc, "cis_add": cis_add}

    def apply_from_moments(self, key, w, moments, state):
        """Replicated server update from the psummed two-release moments;
        central noise is drawn AFTER the reduction from the replicated round
        key (the same ``split`` the dense path performs), so sharded and
        single-device central runs add identical (d,) draws."""
        mom, extras = moments
        d = w.shape[-1]
        dy_bar = mom.sum_c / mom.count
        dc_bar = extras["sum_dc"] / mom.count
        if self.central:
            k_dy, k_dc = jax.random.split(key)
            # static num_clients, as the legacy loop (and the fixed-sigma
            # CentralGaussian): the Prop.-style accounting is stated for it
            std = self.sigma * math.sqrt(2.0) / math.sqrt(self.num_clients)
            dy_bar = dy_bar + std * jax.random.normal(k_dy, (d,))
            dc_bar = dc_bar + std * self.variate_scale \
                * jax.random.normal(k_dc, (d,))
        state_next = ScaffoldState(c=state.c + dc_bar,
                                   c_is=state.c_is + extras["cis_add"])
        return w + dy_bar, RoundAux(eta_g=jnp.float32(1.0)), state_next

    # -- accounting ---------------------------------------------------------

    def budget(self, delta: float, *, rounds: int, dim: int | None = None,
               sampling_q: float = 1.0) -> accounting.PrivacyReport:
        """Two per-round releases at std sigma*sqrt(2) (dy) and
        sigma*sqrt(2)*vs against sensitivity 2C*vs (dc) each carry GDP
        mu/sqrt(2) of the single-release mechanism; they compose to exactly
        the single-release budget at std sigma, so the report delegates to
        the standard curves (the scale cancels from the dc release's
        sensitivity/noise ratio)."""
        if self.sigma <= 0:
            raise ValueError(f"{self.name} with sigma=0 is not private")
        if self.central:
            rep = accounting.cdp_budget(self.clip_norm, self.sigma,
                                        self.num_clients, rounds, delta,
                                        sampling_q=sampling_q)
            return dataclasses.replace(
                rep, setting="CDP (Gaussian, SCAFFOLD two-release)")
        rep = accounting.ldp_gaussian_budget(self.clip_norm, self.sigma, delta)
        return dataclasses.replace(
            rep, setting="LDP (Gaussian, SCAFFOLD two-release)")
