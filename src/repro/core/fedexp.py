"""Federated server algorithms: DP-FedEXP and its baselines.

Each algorithm is a stateless strategy object with

    apply_round(key, w, raw_deltas) -> (w_next, RoundAux)

where ``raw_deltas`` is the (M, d) matrix of *unclipped* local updates
``w_i^{(t-1,tau)} - w^{(t-1)}`` produced by ``repro.fedsim`` (or, in the
datacenter path, the per-client sharded pytrees flattened on the fly).
Client-side randomization (clipping + LDP noise) is executed inside
``apply_round`` with independent per-client keys — mathematically identical to
clients randomizing locally, which is how the privacy guarantee is stated.

Engine contract (DESIGN.md §8): algorithm dataclasses are FROZEN (hashable by
config, so the scan engine caches one compiled program per configuration) and
``RoundAux`` is fixed-shape — optional diagnostics are NaN sentinels, never
None — so a round can live inside ``jax.lax.scan``.  Algorithms that release
through ``fused_clip_aggregate`` carry a ``backend`` field ("auto" routes to
the Pallas kernel on TPU with in-kernel noise where applicable, and to the
tuned jnp path elsewhere).

Implemented algorithms (paper names):
    FedAvg, FedEXP                       -- non-private references
    DP-FedAvg (LDP-Gaussian / CDP)       -- McMahan et al. 2017b
    LDP-FedEXP (Gaussian)                -- Algorithm 1 + Eq. (6)
    LDP-FedEXP (PrivUnit)                -- Algorithm 1 + Eq. (7) / Algorithm 4
    CDP-FedEXP                           -- Algorithm 2 + Eq. (8)
    DP-FedAvg (PrivUnit)                 -- PrivUnit randomizer, eta_g = 1

Composable stack (DESIGN.md §11).  ``make_algorithm`` now builds every
registry name as a ``repro.core.compose.ComposedAlgorithm`` — a mechanism x
aggregation x step composition pinned bit-for-bit against the monolithic
classes below by ``tests/test_compose.py``.  The monolithic classes remain
the executable specification (and direct-construction API) of each
composition; new cross-product names (``ldp-gauss-fedadam``, ``cdp-fedmom``,
``privunit-fedexp-adaptive-clip``) have no monolithic counterpart.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import compose as _compose
from repro.core import mechanisms as mech
from repro.core import stepsize
from repro.core.aggregation import (
    RoundMoments,
    aggregate_stats,
    fused_clip_aggregate,
    materialize_ldp_noise,
    partial_clip_moments,
    raw_moments as _raw_moments,
)
from repro.core.algorithm import (
    RoundAux,
    ServerAlgorithm,
    clamp_moment_counts,
    client_keys,
    set_moment_count,
)

__all__ = [
    "RoundAux",
    "ServerAlgorithm",
    "client_keys",
    "FedAvg",
    "FedEXP",
    "DPFedAvgLDPGaussian",
    "LDPFedEXPGaussian",
    "DPFedAvgPrivUnit",
    "LDPFedEXPPrivUnit",
    "DPFedAvgCDP",
    "CDPFedEXP",
    "make_algorithm",
    "list_algorithms",
    "set_moment_count",
    "clamp_moment_counts",
]


# ---------------------------------------------------------------------------
# Non-private references
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FedAvg(ServerAlgorithm):
    """Non-private FedAvg: ``w <- w + mean_i Delta_i`` (McMahan et al. 2017)."""
    name: str = "fedavg"
    is_private: bool = False

    def apply_round(self, key, w, raw_deltas):
        """One dense server round: ``(key, w, (M, d) raw deltas) -> (w_next, RoundAux)``."""
        stats = aggregate_stats(raw_deltas)
        w_next = w + stats.cbar
        return w_next, RoundAux(eta_g=jnp.float32(1.0), update_norm=jnp.linalg.norm(stats.cbar))

    def local_moments(self, key, w, deltas, mask, start, state):
        """Shard/chunk-local partial sums of this algorithm's release (SUMS, psum-able)."""
        return _raw_moments(deltas, mask)

    def apply_from_moments(self, key, w, moments, state):
        """Server update from the globally reduced moments (replicated math)."""
        cbar = moments.sum_c / moments.count
        aux = RoundAux(eta_g=jnp.float32(1.0), update_norm=jnp.linalg.norm(cbar))
        return w + cbar, aux, state


@dataclasses.dataclass(frozen=True)
class FedEXP(ServerAlgorithm):
    """Non-private FedEXP: the adaptive extrapolated step size of Eq. (2)."""
    name: str = "fedexp"
    is_private: bool = False

    def apply_round(self, key, w, raw_deltas):
        """One dense server round: ``(key, w, (M, d) raw deltas) -> (w_next, RoundAux)``."""
        stats = aggregate_stats(raw_deltas)
        eta = stepsize.fedexp(stats.mean_sq, stats.agg_sq)
        return w + eta * stats.cbar, RoundAux(eta_g=eta, update_norm=eta * jnp.linalg.norm(stats.cbar))

    def local_moments(self, key, w, deltas, mask, start, state):
        """Shard/chunk-local partial sums of this algorithm's release (SUMS, psum-able)."""
        return _raw_moments(deltas, mask)

    def apply_from_moments(self, key, w, moments, state):
        """Server update from the globally reduced moments (replicated math)."""
        stats = moments.stats()
        eta = stepsize.fedexp(stats.mean_sq, stats.agg_sq)
        aux = RoundAux(eta_g=eta, update_norm=eta * jnp.linalg.norm(stats.cbar))
        return w + eta * stats.cbar, aux, state


# ---------------------------------------------------------------------------
# LDP — Gaussian mechanism
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DPFedAvgLDPGaussian(ServerAlgorithm):
    """DP-FedAvg under the Gaussian LDP randomizer: per-client clip + noise, eta_g = 1."""
    clip_norm: float
    sigma: float
    name: str = "dp-fedavg-ldp-gauss"
    backend: str = "auto"

    def _release(self, key, raw_deltas):
        return fused_clip_aggregate(raw_deltas, self.clip_norm,
                                    noise_key=key, noise_sigma=self.sigma,
                                    backend=self.backend)

    def apply_round(self, key, w, raw_deltas):
        """One dense server round: ``(key, w, (M, d) raw deltas) -> (w_next, RoundAux)``."""
        stats = self._release(key, raw_deltas)
        return w + stats.cbar, RoundAux(eta_g=jnp.float32(1.0))

    def local_moments(self, key, w, deltas, mask, start, state):
        # Per-client noise rows keyed by global index: the same rows the
        # single-device release materializes for this round key — bit-parity
        # wherever the unsharded backend materializes noise (jnp / kernel).
        # On TPU, unsharded "auto" resolves to kernel-fused, whose in-kernel
        # stream is shard-oblivious (every shard would repeat the same
        # block), so the sharded path always materializes and the TPU-auto
        # comparison is distributional, not bitwise (DESIGN.md §9).
        """Shard/chunk-local partial sums of this algorithm's release (SUMS, psum-able)."""
        noise = materialize_ldp_noise(key, *deltas.shape, self.sigma,
                                      deltas.dtype, start=start)
        return partial_clip_moments(deltas, self.clip_norm, noise,
                                    weight_mask=mask, backend=self.backend)

    def apply_from_moments(self, key, w, moments, state):
        """Server update from the globally reduced moments (replicated math)."""
        return w + moments.sum_c / moments.count, RoundAux(eta_g=jnp.float32(1.0)), state


@dataclasses.dataclass(frozen=True)
class LDPFedEXPGaussian(DPFedAvgLDPGaussian):
    """Algorithm 1 with the bias-corrected step size, Eq. (6)."""

    name: str = "ldp-fedexp-gauss"

    def _stepped(self, w, stats):
        d = w.shape[-1]
        eta = stepsize.ldp_gaussian(stats.mean_sq, stats.agg_sq, d, self.sigma)
        aux = RoundAux(
            eta_g=eta,
            eta_naive=stepsize.naive_noisy(stats.mean_sq, stats.agg_sq),
            eta_target=stepsize.target(stats.mean_sq_clipped, stats.agg_sq),
        )
        return w + eta * stats.cbar, aux

    def apply_round(self, key, w, raw_deltas):
        """One dense server round: ``(key, w, (M, d) raw deltas) -> (w_next, RoundAux)``."""
        return self._stepped(w, self._release(key, raw_deltas))

    def apply_from_moments(self, key, w, moments, state):
        """Server update from the globally reduced moments (replicated math)."""
        w_next, aux = self._stepped(w, moments.stats())
        return w_next, aux, state


# ---------------------------------------------------------------------------
# LDP — PrivUnit
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DPFedAvgPrivUnit(ServerAlgorithm):
    """DP-FedAvg under PrivUnit (direction) x ScalarDP (magnitude), eta_g = 1."""
    clip_norm: float
    eps0: float
    eps1: float
    eps2: float
    dim: int
    name: str = "dp-fedavg-privunit"

    def __post_init__(self):
        object.__setattr__(self, "pu", mech.make_privunit_params(self.dim, self.eps0, self.eps1))
        object.__setattr__(self, "sc", mech.make_scalardp_params(self.eps2, self.clip_norm))

    def _randomize(self, key, raw_deltas, start=0):
        """Per-client clip + PrivUnit release, keys by GLOBAL client index
        (``client_keys``), so shards reproduce their rows of the cohort."""
        m, _ = raw_deltas.shape
        keys = client_keys(key, m, start)
        norms = jnp.linalg.norm(raw_deltas, axis=-1)
        scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(norms, 1e-12))
        clipped = raw_deltas * scale[:, None]
        released = jax.vmap(lambda k, dlt: mech.privunit_randomize(k, dlt, self.pu, self.sc))(keys, clipped)
        return released, clipped

    def _release(self, key, raw_deltas):
        released, clipped = self._randomize(key, raw_deltas)
        stats = aggregate_stats(released)
        stats.mean_sq_clipped = (
            jnp.sum(jnp.sum(jnp.square(clipped), axis=-1)) / raw_deltas.shape[0])
        return released, stats

    def _released_moments(self, key, deltas, mask, start):
        released, clipped = self._randomize(key, deltas, start)
        released = jnp.where(mask[:, None] > 0, released, 0.0)
        # dots with the mask, not sum(mask * x): bit-parity with the
        # unsharded reference reductions (see _raw_moments)
        mom = RoundMoments(
            sum_c=mask @ released,
            sum_sq=mask @ jnp.sum(jnp.square(released), axis=-1),
            sum_sq_clipped=mask @ jnp.sum(jnp.square(clipped), axis=-1),
            count=jnp.sum(mask))
        return released, mom

    def local_moments(self, key, w, deltas, mask, start, state):
        """Shard/chunk-local partial sums of this algorithm's release (SUMS, psum-able)."""
        _, mom = self._released_moments(key, deltas, mask, start)
        return mom

    def apply_round(self, key, w, raw_deltas):
        """One dense server round: ``(key, w, (M, d) raw deltas) -> (w_next, RoundAux)``."""
        _, stats = self._release(key, raw_deltas)
        return w + stats.cbar, RoundAux(eta_g=jnp.float32(1.0))

    def apply_from_moments(self, key, w, moments, state):
        """Server update from the globally reduced moments (replicated math)."""
        return w + moments.sum_c / moments.count, RoundAux(eta_g=jnp.float32(1.0)), state


@dataclasses.dataclass(frozen=True)
class LDPFedEXPPrivUnit(DPFedAvgPrivUnit):
    """Algorithm 1 with the PrivUnit norm-estimation step size, Eq. (7)."""

    name: str = "ldp-fedexp-privunit"

    def _stepped(self, w, stats, mean_s_hat):
        eta = stepsize.ldp_privunit(mean_s_hat, stats.agg_sq)
        aux = RoundAux(
            eta_g=eta,
            eta_naive=stepsize.naive_noisy(stats.mean_sq, stats.agg_sq),
            eta_target=stepsize.target(stats.mean_sq_clipped, stats.agg_sq),
        )
        return w + eta * stats.cbar, aux

    def apply_round(self, key, w, raw_deltas):
        """One dense server round: ``(key, w, (M, d) raw deltas) -> (w_next, RoundAux)``."""
        released, stats = self._release(key, raw_deltas)
        s_hat = jax.vmap(lambda c: mech.estimate_norm_sq(c, self.pu, self.sc))(released)
        return self._stepped(w, stats, jnp.sum(s_hat) / raw_deltas.shape[0])

    def local_moments(self, key, w, deltas, mask, start, state):
        """Shard/chunk-local partial sums of this algorithm's release (SUMS, psum-able)."""
        released, mom = self._released_moments(key, deltas, mask, start)
        s_hat = jax.vmap(lambda c: mech.estimate_norm_sq(c, self.pu, self.sc))(released)
        return mom, {"sum_s_hat": mask @ s_hat}

    def apply_from_moments(self, key, w, moments, state):
        """Server update from the globally reduced moments (replicated math)."""
        mom, extras = moments
        w_next, aux = self._stepped(w, mom.stats(), extras["sum_s_hat"] / mom.count)
        return w_next, aux, state


# ---------------------------------------------------------------------------
# CDP
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DPFedAvgCDP(ServerAlgorithm):
    """DP-FedAvg under central DP: clip-only clients + server noise on the mean."""
    clip_norm: float
    sigma: float           # paper's sigma; server noise std is sigma/sqrt(M)
    num_clients: int
    name: str = "dp-fedavg-cdp"
    backend: str = "auto"

    def _noised_cbar(self, key, cbar):
        """Post-reduction server noise — the ONLY randomness in the CDP
        release, drawn from the replicated round key, so the sharded and
        single-device paths add the identical (d,) draw."""
        d = cbar.shape[-1]
        server_noise = (self.sigma / jnp.sqrt(float(self.num_clients))) * jax.random.normal(key, (d,))
        return cbar + server_noise

    def _release(self, key, raw_deltas):
        stats = fused_clip_aggregate(raw_deltas, self.clip_norm, noise=None,
                                     backend=self.backend)
        return stats, self._noised_cbar(key, stats.cbar)

    def apply_round(self, key, w, raw_deltas):
        """One dense server round: ``(key, w, (M, d) raw deltas) -> (w_next, RoundAux)``."""
        _, cbar = self._release(key, raw_deltas)
        return w + cbar, RoundAux(eta_g=jnp.float32(1.0))

    def local_moments(self, key, w, deltas, mask, start, state):
        """Shard/chunk-local partial sums of this algorithm's release (SUMS, psum-able)."""
        return partial_clip_moments(deltas, self.clip_norm, None,
                                    weight_mask=mask, backend=self.backend)

    def apply_from_moments(self, key, w, moments, state):
        """Server update from the globally reduced moments (replicated math)."""
        cbar = self._noised_cbar(key, moments.sum_c / moments.count)
        return w + cbar, RoundAux(eta_g=jnp.float32(1.0)), state


@dataclasses.dataclass(frozen=True)
class CDPFedEXP(DPFedAvgCDP):
    """Algorithm 2 with the privatized-numerator step size, Eq. (8).

    sigma_xi defaults to the hyperparameter-free d * sigma^2 / M (§3.2).
    """

    sigma_xi: float | None = None
    name: str = "cdp-fedexp"

    def _stepped(self, k_xi, w, cbar, mean_sq_clipped):
        d = w.shape[-1]
        sigma_xi = self.sigma_xi if self.sigma_xi is not None else d * self.sigma**2 / self.num_clients
        xi = sigma_xi * jax.random.normal(k_xi, ())
        agg_sq = jnp.sum(jnp.square(cbar))
        eta = stepsize.cdp(mean_sq_clipped, xi, agg_sq)
        aux = RoundAux(
            eta_g=eta,
            eta_target=stepsize.target(mean_sq_clipped, agg_sq),
        )
        return w + eta * cbar, aux

    def apply_round(self, key, w, raw_deltas):
        """One dense server round: ``(key, w, (M, d) raw deltas) -> (w_next, RoundAux)``."""
        k_noise, k_xi = jax.random.split(key)
        stats, cbar = self._release(k_noise, raw_deltas)
        return self._stepped(k_xi, w, cbar, stats.mean_sq_clipped)

    def apply_from_moments(self, key, w, moments, state):
        """Server update from the globally reduced moments (replicated math)."""
        k_noise, k_xi = jax.random.split(key)
        cbar = self._noised_cbar(k_noise, moments.sum_c / moments.count)
        w_next, aux = self._stepped(k_xi, w, cbar, moments.sum_sq_clipped / moments.count)
        return w_next, aux, state


# ---------------------------------------------------------------------------
# Adaptive clipping (Andrew et al. 2021) x CDP-FedEXP — the combination the
# paper mentions but leaves out "for simplicity"
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CDPFedEXPAdaptiveClip(ServerAlgorithm):
    """CDP-FedEXP with a quantile-tracked clipping threshold.

    Per round: clip at the CURRENT C, release mean + FedEXP numerator with
    noise std scaled as z * C (fixed noise MULTIPLIER z, so the privacy
    guarantee is C-independent), update C from the privatized below-threshold
    fraction. The step-size rule reads the same round's C through sigma_xi =
    d * (zC)^2 / M — everything stays hyperparameter-free except gamma=0.5
    (a universal constant in Andrew et al.).

    The clip threshold is a TRACED scalar that changes every round; the
    kernel backend takes it as a prefetched operand, so no recompiles.
    """

    z_mult: float               # noise multiplier; per-round std = z*C/sqrt(M)
    num_clients: int
    dim: int
    c0: float = 1.0
    gamma: float = 0.5
    clip_lr: float = 0.2
    sigma_b: float = 10.0
    name: str = "cdp-fedexp-adaptive-clip"
    backend: str = "auto"

    def init_state(self, w):
        """Initial optimizer/clip carry for a run starting from ``w``."""
        from repro.core import adaptive_clip as ac
        return ac.init_state(self.c0)

    def _serve(self, key, w, cbar_mean, mean_sq_clipped, count_below, m, state):
        """Replicated server half: noise the mean, pick eta, track the clip.
        ``m`` may be a traced count — every use is value-identical to the
        static shape the unsharded path passes."""
        from repro.core import adaptive_clip as ac
        d = w.shape[-1]
        k_noise, k_xi, k_bit = jax.random.split(key, 3)
        c = state.clip
        sigma = self.z_mult * c                     # paper's sigma, tracking C
        server_noise = (sigma / jnp.sqrt(m)) * jax.random.normal(k_noise, (d,))
        cbar = cbar_mean + server_noise
        sigma_xi = d * sigma**2 / m
        xi = sigma_xi * jax.random.normal(k_xi, ())
        eta = stepsize.cdp(mean_sq_clipped, xi, jnp.sum(jnp.square(cbar)))

        cfg = ac.AdaptiveClipConfig(gamma=self.gamma, lr=self.clip_lr,
                                    sigma_b=self.sigma_b)
        state, _ = ac.update_clip_from_stats(k_bit, state, count_below, m, cfg)
        aux = RoundAux(eta_g=eta, update_norm=c)   # report the clip used
        return w + eta * cbar, aux, state

    def apply_round_stateful(self, key, w, raw_deltas, state):
        """Stateful dense round: ``apply_round`` threading the optimizer/clip carry."""
        m = raw_deltas.shape[0]
        stats = fused_clip_aggregate(raw_deltas, state.clip, None, backend=self.backend)
        norms = jnp.linalg.norm(raw_deltas, axis=-1)
        count_below = jnp.sum((norms <= state.clip).astype(jnp.float32))
        return self._serve(key, w, stats.cbar, stats.mean_sq_clipped,
                           count_below, float(m), state)

    def local_moments(self, key, w, deltas, mask, start, state):
        """Shard/chunk-local partial sums of this algorithm's release (SUMS, psum-able)."""
        mom = partial_clip_moments(deltas, state.clip, None,
                                   weight_mask=mask, backend=self.backend)
        norms = jnp.linalg.norm(deltas, axis=-1)
        below = mask @ (norms <= state.clip).astype(jnp.float32)
        return mom, {"count_below": below}

    def apply_from_moments(self, key, w, moments, state):
        """Server update from the globally reduced moments (replicated math)."""
        mom, extras = moments
        return self._serve(key, w, mom.sum_c / mom.count,
                           mom.sum_sq_clipped / mom.count,
                           extras["count_below"], mom.count, state)

    def apply_round(self, key, w, raw_deltas):
        """One dense server round: ``(key, w, (M, d) raw deltas) -> (w_next, RoundAux)``."""
        raise TypeError("stateful algorithm; use apply_round_stateful")


# ---------------------------------------------------------------------------
# FedOpt family (Reddi et al., 2021) — the servers the paper argues against
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DPFedAdamCDP(DPFedAvgCDP):
    """DP-FedAdam: server Adam over the privatized pseudo-gradient.

    Identical privacy release to DP-FedAvg (CDP); the server applies Adam
    with a GLOBAL learning rate ``server_lr`` — the extra hyperparameter
    whose DP-safe tuning the paper identifies as the practical blocker
    (Papernot & Steinke: accounting the tuning can double/triple epsilon).
    Used by the E6 ablation to quantify that sensitivity vs the
    hyperparameter-free CDP-FedEXP.
    """

    server_lr: float = 0.1
    name: str = "dp-fedadam-cdp"

    def __post_init__(self):
        from repro import optim
        object.__setattr__(self, "_opt", optim.adam(lr=self.server_lr))

    def init_state(self, w):
        """Initial optimizer/clip carry for a run starting from ``w``."""
        return self._opt.init(w)

    def apply_round_stateful(self, key, w, raw_deltas, state):
        """Stateful dense round: ``apply_round`` threading the optimizer/clip carry."""
        _, cbar = self._release(key, raw_deltas)
        step, state = self._opt.update(cbar, state)
        return w + step, RoundAux(eta_g=jnp.float32(self.server_lr)), state

    def apply_from_moments(self, key, w, moments, state):
        """Server update from the globally reduced moments (replicated math)."""
        cbar = self._noised_cbar(key, moments.sum_c / moments.count)
        step, state = self._opt.update(cbar, state)
        return w + step, RoundAux(eta_g=jnp.float32(self.server_lr)), state

    def apply_round(self, key, w, raw_deltas):  # stateless misuse guard
        """One dense server round: ``(key, w, (M, d) raw deltas) -> (w_next, RoundAux)``."""
        raise TypeError("DPFedAdamCDP is stateful; use apply_round_stateful")


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

def _backend(kw) -> str:
    return kw.get("backend", "auto")


def _gauss_ldp(kw) -> _compose.GaussianLDP:
    return _compose.GaussianLDP(kw["clip_norm"], kw["sigma"], backend=_backend(kw))


def _privunit(kw) -> _compose.PrivUnitLDP:
    return _compose.PrivUnitLDP(kw["clip_norm"], kw["eps0"], kw["eps1"],
                                kw["eps2"], kw["dim"])


def _cdp(kw) -> _compose.CentralGaussian:
    return _compose.CentralGaussian(clip_norm=kw["clip_norm"], sigma=kw["sigma"],
                                    num_clients=kw["num_clients"],
                                    sigma_xi=kw.get("sigma_xi"),
                                    backend=_backend(kw))


def _adaptive_cdp(kw) -> _compose.CentralGaussian:
    return _compose.CentralGaussian(z_mult=kw["z_mult"],
                                    num_clients=kw["num_clients"],
                                    backend=_backend(kw))


def _adaptive_step(kw) -> _compose.AdaptiveClipStep:
    return _compose.AdaptiveClipStep(c0=kw.get("c0", 1.0),
                                     gamma=kw.get("gamma", 0.5),
                                     clip_lr=kw.get("clip_lr", 0.2),
                                     sigma_b=kw.get("sigma_b", 10.0))


def _composed(name: str, mechanism, step) -> _compose.ComposedAlgorithm:
    return _compose.ComposedAlgorithm(mechanism=mechanism, step=step, name=name)


def _schedule(inner, kw) -> _compose.NoiseSchedule:
    return _compose.NoiseSchedule(inner=inner, decay=kw.get("decay", 1.0),
                                  boundaries=tuple(kw.get("boundaries", ())),
                                  scales=tuple(kw.get("scales", ())))


def _perclient_weighted(kw) -> _compose.ComposedAlgorithm:
    # heterogeneous privacy (§17): per-client sigmas from the public epsilons
    # + the matching public inverse-variance aggregation weights
    mechanism = _compose.PerClientGaussian(kw["clip_norm"],
                                           tuple(kw["epsilons"]), kw["delta"],
                                           backend=_backend(kw))
    return _compose.ComposedAlgorithm(
        mechanism=mechanism, step=_compose.FedEXPStep(),
        aggregation=_compose.WeightedAggregation(
            mechanism.inverse_variance_weights()),
        name="ldp-fedexp-perclient")


def _scaffold(kw) -> ServerAlgorithm:
    from repro.core.variance_reduction import DPScaffoldServer
    return DPScaffoldServer(clip_norm=kw["clip_norm"], sigma=kw["sigma"],
                            central=kw["central"],
                            num_clients=kw["num_clients"],
                            tau=kw["tau"], eta_l=kw["eta_l"])


# Every registry name is a (mechanism, step) composition under the uniform
# MeanAggregation — the first ten reproduce the monolithic classes above
# bit-for-bit (tests/test_compose.py); the rest are cross-product names the
# inheritance design could not express.  README.md tabulates the mapping.
_FACTORIES: dict[str, Callable[..., ServerAlgorithm]] = {
    "fedavg": lambda **kw: _composed(
        "fedavg", _compose.NoPrivacy(), _compose.FixedEta()),
    "fedexp": lambda **kw: _composed(
        "fedexp", _compose.NoPrivacy(), _compose.FedEXPStep()),
    "dp-fedavg-ldp-gauss": lambda **kw: _composed(
        "dp-fedavg-ldp-gauss", _gauss_ldp(kw), _compose.FixedEta()),
    "ldp-fedexp-gauss": lambda **kw: _composed(
        "ldp-fedexp-gauss", _gauss_ldp(kw), _compose.FedEXPStep()),
    "dp-fedavg-privunit": lambda **kw: _composed(
        "dp-fedavg-privunit", _privunit(kw), _compose.FixedEta()),
    "ldp-fedexp-privunit": lambda **kw: _composed(
        "ldp-fedexp-privunit", _privunit(kw), _compose.FedEXPStep()),
    "dp-fedavg-cdp": lambda **kw: _composed(
        "dp-fedavg-cdp", _cdp(kw), _compose.FixedEta()),
    "cdp-fedexp": lambda **kw: _composed(
        "cdp-fedexp", _cdp(kw), _compose.FedEXPStep()),
    "dp-fedadam-cdp": lambda **kw: _composed(
        "dp-fedadam-cdp", _cdp(kw),
        _compose.ServerOpt(kind="adam", lr=kw.get("server_lr", 0.1))),
    "cdp-fedexp-adaptive-clip": lambda **kw: _composed(
        "cdp-fedexp-adaptive-clip", _adaptive_cdp(kw), _adaptive_step(kw)),
    # -- cross-product compositions with no monolithic counterpart ---------
    "ldp-gauss-fedadam": lambda **kw: _composed(
        "ldp-gauss-fedadam", _gauss_ldp(kw),
        _compose.ServerOpt(kind="adam", lr=kw.get("server_lr", 0.1))),
    "cdp-fedmom": lambda **kw: _composed(
        "cdp-fedmom", _cdp(kw),
        _compose.ServerOpt(kind="momentum", lr=kw.get("server_lr", 1.0),
                           beta1=kw.get("server_beta", 0.9))),
    "privunit-fedexp-adaptive-clip": lambda **kw: _composed(
        "privunit-fedexp-adaptive-clip",
        _privunit({**kw, "clip_norm": kw.get("clip_norm", kw.get("c0", 1.0))}),
        _adaptive_step(kw)),
    # -- §17: heterogeneous privacy, noise schedules, control variates ------
    "ldp-fedexp-perclient": lambda **kw: _perclient_weighted(kw),
    "ldp-fedexp-schedule": lambda **kw: _composed(
        "ldp-fedexp-schedule", _schedule(_gauss_ldp(kw), kw),
        _compose.FedEXPStep()),
    "cdp-fedexp-schedule": lambda **kw: _composed(
        "cdp-fedexp-schedule", _schedule(_cdp(kw), kw),
        _compose.FedEXPStep()),
    "dp-scaffold": lambda **kw: _scaffold(kw),
}


def list_algorithms() -> list[str]:
    """Sorted names of every registered server algorithm."""
    return sorted(_FACTORIES)


def make_algorithm(name: str, **kwargs) -> ServerAlgorithm:
    """Build a registered server algorithm by name.

    Args:
      name: one of ``list_algorithms()`` (unknown names raise KeyError
        enumerating the registry).
      **kwargs: the composition's knobs (``clip_norm``, ``sigma``,
        ``num_clients``, ``eps0/1/2``, ``dim``, ``z_mult``, ``server_lr``,
        ... — see the README registry table).

    Returns:
      A frozen, hashable ``ServerAlgorithm`` (a ``ComposedAlgorithm``)
      pinned bit-for-bit against the monolithic classes for the first ten
      names.
    """
    if name not in _FACTORIES:
        raise KeyError(f"unknown algorithm {name!r}; valid names: "
                       f"{', '.join(list_algorithms())}")
    return _FACTORIES[name](**kwargs)
