"""Adaptive clipping (Andrew et al., NeurIPS 2021) — quantile clip tracking.

The paper: "Our framework can be combined with adaptive clipping (Andrew et
al., 2021) but we use a fixed clipping threshold for simplicity." This module
supplies that combination as a first-class feature.

Each round, every client reports one PRIVATIZED bit b_i = 1{||Delta~_i|| <= C}
(randomized response or, in the CDP setting, the bit-sum privatized with
Gaussian noise of std sigma_b). The server tracks the target quantile gamma
with geometric updates:

    C <- C * exp(-lr_C * (b_bar - gamma))

so C converges to the gamma-quantile of the (unclipped) update norms. The
cost is one scalar per client per round; with sigma_b = O(10) the extra
privacy budget is negligible next to the d-dimensional release (the same
argument as the paper's sigma_xi analysis).

DP-FedEXP interaction: the step-size rules read the CURRENT round's C (the
bias correction d*sigma^2 uses sigma = z * C, so both the numerator
correction and the noise scale track the adapting threshold).
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

__all__ = ["AdaptiveClipConfig", "AdaptiveClipState", "init_state", "update_clip",
           "update_clip_from_stats", "adaptive_clip_rho"]


@dataclasses.dataclass(frozen=True)
class AdaptiveClipConfig:
    """Quantile-tracking knobs (Andrew et al. 2021): target gamma, geometric lr, bit noise."""
    gamma: float = 0.5        # target quantile of update norms
    lr: float = 0.2           # geometric-update learning rate
    sigma_b: float = 10.0     # std of the noise on the bit SUM (CDP; Andrew et al. use ~M/20)
    c_min: float = 1e-3
    c_max: float = 1e3


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AdaptiveClipState:
    """Carry of the adaptive-clip tracker: the current threshold C (traced scalar)."""
    clip: jax.Array           # current threshold C (scalar)


def init_state(c0: float) -> AdaptiveClipState:
    """Fresh tracker state at threshold ``c0``."""
    return AdaptiveClipState(clip=jnp.float32(c0))


def update_clip(key: jax.Array, state: AdaptiveClipState, raw_norms: jax.Array,
                cfg: AdaptiveClipConfig) -> tuple[AdaptiveClipState, jax.Array]:
    """One round of quantile tracking.

    raw_norms: (M,) UNclipped per-client update norms (the bit b_i is computed
    client-side in a real deployment; mathematically identical here).
    Returns (new state, noisy fraction b_bar used for the update).
    """
    m = raw_norms.shape[0]
    bits = (raw_norms <= state.clip).astype(jnp.float32)
    return update_clip_from_stats(key, state, jnp.sum(bits), m, cfg)


def update_clip_from_stats(key: jax.Array, state: AdaptiveClipState,
                           count_below, m, cfg: AdaptiveClipConfig
                           ) -> tuple[AdaptiveClipState, jax.Array]:
    """Quantile update from the aggregate bit SUM instead of per-client norms.

    ``count_below = sum_i 1{||Delta~_i|| <= C}`` decomposes over client shards
    (each shard sums its own masked bits, the engine psums), so this is the
    entry point the client-sharded engine uses; ``update_clip`` reduces to it
    and stays numerically identical.
    """
    noisy_sum = count_below + cfg.sigma_b * jax.random.normal(key, ())
    b_bar = jnp.clip(noisy_sum / m, 0.0, 1.0)
    new_c = state.clip * jnp.exp(-cfg.lr * (b_bar - cfg.gamma))
    new_c = jnp.clip(new_c, cfg.c_min, cfg.c_max)
    return AdaptiveClipState(clip=new_c), b_bar


def adaptive_clip_rho(sigma_b: float, rounds: int) -> float:
    """zCDP-style rate of the bit-sum release over T rounds.

    Each bit has sensitivity 1 (client-level), so one round is
    (alpha, alpha/(2 sigma_b^2))-RDP; T rounds compose linearly. With
    sigma_b = 10 and T = 50 this is rho = 0.25 — compare the paper's
    rho = 2 C^2 T / (M sigma^2) main release.
    """
    return rounds / (2.0 * sigma_b**2)
