"""Privacy mechanisms for DP-FedEXP.

Implements the three local/central randomizers used by the paper:

- Gaussian mechanism (LDP: per-client; CDP: server-side on the mean),
- PrivUnit (Bhowmick et al., 2018) — Algorithm 5 — privatizes the *direction*
  of the update on the unit sphere with pure epsilon-DP,
- ScalarDP — Algorithm 6 — privatizes the update *norm* with randomized
  rounding + randomized response,
- the norm-squared estimator of Algorithm 4 used by the LDP-FedEXP(PrivUnit)
  step-size rule (Eq. 7).

Design notes (TPU/JAX adaptation, see DESIGN.md §5)
---------------------------------------------------
Reference implementations of PrivUnit rejection-sample from spherical caps,
which does not lower to XLA. We instead sample the cap *exactly* via the
tangent-normal decomposition: for ``u`` the true direction, a uniform draw
from the cap ``{v : <v,u> >= gamma}`` is ``v = t*u + sqrt(1-t^2)*w_hat`` with
``w_hat`` uniform on the orthogonal sphere and ``(1+t)/2 ~ Beta(alpha, alpha)``
truncated to ``[(1+gamma)/2, 1]``, ``alpha = (d-1)/2``.  The truncated Beta is
inverted by bisection on the regularized incomplete beta function, which is
jittable, vmappable and shardable.

All *static* mechanism constants (gamma, the unbiasing scale m, ScalarDP's
a/b/k and the variance-bound constants c1/c2/c3) are computed once at config
time in float64 Python (see ``_betainc_f64``), so the traced sampling path is
cheap and dtype-stable.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

__all__ = [
    "GaussianLDPConfig",
    "GaussianCDPConfig",
    "PerClientGaussianConfig",
    "per_client_sigmas",
    "gaussian_ldp_randomize",
    "gaussian_cdp_noise",
    "PrivUnitParams",
    "ScalarDPParams",
    "make_privunit_params",
    "make_scalardp_params",
    "privunit_direction",
    "scalardp_magnitude",
    "privunit_randomize",
    "estimate_norm_sq",
]


# ---------------------------------------------------------------------------
# float64 incomplete beta (config time only — scipy is not available offline).
# Continued-fraction evaluation, Numerical Recipes §6.4.
# ---------------------------------------------------------------------------

def _betacf(a: float, b: float, x: float) -> float:
    MAXIT, EPS, FPMIN = 300, 3e-14, 1e-300
    qab, qap, qam = a + b, a + 1.0, a - 1.0
    c = 1.0
    d = 1.0 - qab * x / qap
    if abs(d) < FPMIN:
        d = FPMIN
    d = 1.0 / d
    h = d
    for m in range(1, MAXIT + 1):
        m2 = 2 * m
        aa = m * (b - m) * x / ((qam + m2) * (a + m2))
        d = 1.0 + aa * d
        if abs(d) < FPMIN:
            d = FPMIN
        c = 1.0 + aa / c
        if abs(c) < FPMIN:
            c = FPMIN
        d = 1.0 / d
        h *= d * c
        aa = -(a + m) * (qab + m) * x / ((a + m2) * (qap + m2))
        d = 1.0 + aa * d
        if abs(d) < FPMIN:
            d = FPMIN
        c = 1.0 + aa / c
        if abs(c) < FPMIN:
            c = FPMIN
        d = 1.0 / d
        de = d * c
        h *= de
        if abs(de - 1.0) < EPS:
            break
    return h


def _betainc_f64(a: float, b: float, x: float) -> float:
    """Regularized incomplete beta I_x(a, b) in float64."""
    if x <= 0.0:
        return 0.0
    if x >= 1.0:
        return 1.0
    lbeta = math.lgamma(a) + math.lgamma(b) - math.lgamma(a + b)
    front = math.exp(a * math.log(x) + b * math.log1p(-x) - lbeta)
    if x < (a + 1.0) / (a + b + 2.0):
        return front * _betacf(a, b, x) / a
    return 1.0 - math.exp(b * math.log1p(-x) + a * math.log(x) - lbeta) * _betacf(b, a, 1.0 - x) / b


def _log_beta(a: float, b: float) -> float:
    return math.lgamma(a) + math.lgamma(b) - math.lgamma(a + b)


# ---------------------------------------------------------------------------
# Gaussian mechanisms
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GaussianLDPConfig:
    """Per-client Gaussian randomizer: ``c_i = Delta_i + N(0, sigma^2 I_d)``.

    Paper setting for the LDP experiments: ``sigma = 0.7 * C``.
    """

    sigma: float
    clip_norm: float


@dataclasses.dataclass(frozen=True)
class GaussianCDPConfig:
    """Server-side Gaussian noise on the *mean* update.

    The paper draws ``eps^(t) ~ N(0, sigma^2 / M)`` (coordinate variance), with
    ``sigma = 5 * C / sqrt(M)`` in the experiments, and additionally privatizes
    the FedEXP numerator with a scalar ``xi ~ N(0, sigma_xi^2)``,
    ``sigma_xi = d * sigma^2 / M`` (the hyperparameter-free choice, §3.2).
    """

    sigma: float
    clip_norm: float
    num_clients: int

    @property
    def mean_noise_std(self) -> float:
        """Server-side noise std on the released mean: ``sigma / sqrt(M)``."""
        return self.sigma / math.sqrt(self.num_clients)

    def sigma_xi(self, dim: int) -> float:
        """Hyperparameter-free numerator noise scale ``d sigma^2 / M`` (Eq. 8, §3.2 of the paper)."""
        return dim * self.sigma**2 / self.num_clients


@dataclasses.dataclass(frozen=True)
class PerClientGaussianConfig:
    """Heterogeneous-privacy Gaussian LDP: client i carries its OWN
    ``epsilons[i]`` budget at the shared ``delta`` (DESIGN.md §17).

    ``sigmas`` (derived at config time, f64) inverts the single-release GDP
    curve per client at sensitivity 2C — the same curve the uniform
    ``GaussianLDPConfig`` accounting walks, so equal epsilons derive the
    common sigma exactly.  ``repro.core.compose.PerClientGaussian`` is the
    executable mechanism behind this config.
    """

    clip_norm: float
    epsilons: tuple[float, ...]
    delta: float

    def __post_init__(self):
        object.__setattr__(self, "epsilons",
                           tuple(float(e) for e in self.epsilons))
        object.__setattr__(
            self, "sigmas",
            per_client_sigmas(self.epsilons, self.delta, self.clip_norm))


def per_client_sigmas(epsilons, delta: float,
                      clip_norm: float) -> tuple[float, ...]:
    """Per-client noise stds meeting each (eps_i, delta) at sensitivity 2C.

    Inverts the Gaussian single-release GDP curve (``sigma_for_epsilon``)
    independently per client; monotone in eps_i, so larger budgets get
    strictly smaller sigmas and the 1/sigma_i^2 inverse-variance aggregation
    weights favor the better-resourced clients.
    """
    from repro.core import accounting
    eps = tuple(float(e) for e in epsilons)
    if not eps:
        raise ValueError("per_client_sigmas requires at least one epsilon")
    if any(e <= 0 for e in eps):
        raise ValueError("per-client epsilons must be positive")
    return tuple(
        accounting.sigma_for_epsilon(e, delta, sensitivity=2.0 * clip_norm)
        for e in eps)


def gaussian_ldp_randomize(key: jax.Array, delta: jax.Array, sigma: float) -> jax.Array:
    """LocalRandomizer for the Gaussian LDP setting (one client)."""
    return delta + sigma * jax.random.normal(key, delta.shape, delta.dtype)


def gaussian_cdp_noise(key: jax.Array, shape, std: float, dtype=jnp.float32) -> jax.Array:
    """Server noise for the CDP setting (added once to the aggregated mean)."""
    return std * jax.random.normal(key, shape, dtype)


# ---------------------------------------------------------------------------
# PrivUnit (Algorithm 5)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PrivUnitParams:
    """Static constants for PrivUnit(eps0, eps1) in dimension d."""

    dim: int
    eps0: float
    eps1: float
    p: float          # cap probability  e^{eps0} / (1 + e^{eps0})
    gamma: float      # cap height
    m: float          # unbiasing normalizer; ||z|| = 1/m
    alpha: float      # (d-1)/2
    tau: float        # (1+gamma)/2
    i_tau: float      # I_tau(alpha, alpha)


def _gamma_from_eps1(d: int, eps1: float) -> float:
    """Select the largest cap height gamma permitted by Algorithm 5.

    Two sufficient conditions from Bhowmick et al. (2018) — we take the max of
    the two admissible gammas:
      (A)  gamma <= (e^{eps1}-1)/(e^{eps1}+1) * sqrt(pi / (2(d-1)))
      (B)  eps1 >= 0.5*log d + log 6 - (d-1)/2 * log(1-gamma^2) + log gamma,
           with gamma >= sqrt(2/d).
    """
    gamma_a = (math.expm1(eps1) / (math.exp(eps1) + 1.0)) * math.sqrt(math.pi / (2.0 * (d - 1)))

    def rhs(g: float) -> float:
        """Condition (B)'s right-hand side as a function of gamma."""
        return 0.5 * math.log(d) + math.log(6.0) - 0.5 * (d - 1) * math.log1p(-g * g) + math.log(g)

    g_lo = math.sqrt(2.0 / d)
    gamma_b = -1.0
    if g_lo < 1.0 and rhs(g_lo) <= eps1:
        lo, hi = g_lo, 1.0 - 1e-12
        if rhs(hi) <= eps1:
            gamma_b = hi
        else:
            for _ in range(200):
                mid = 0.5 * (lo + hi)
                if rhs(mid) <= eps1:
                    lo = mid
                else:
                    hi = mid
            gamma_b = lo
    gamma = max(gamma_a, gamma_b)
    return min(max(gamma, 1e-8), 1.0 - 1e-9)


def make_privunit_params(dim: int, eps0: float, eps1: float) -> PrivUnitParams:
    """PrivUnit parameters for dimension ``dim`` at budgets (eps0, eps1).

    Derives the cap probability p from eps0, the cap width gamma from eps1
    (the larger of the two admissible regimes), and the debiasing
    normalizer m; raises when the configuration admits no positive finite
    normalizer (increase eps0).
    """
    if dim < 2:
        raise ValueError("PrivUnit requires d >= 2")
    p = math.exp(eps0) / (1.0 + math.exp(eps0))
    gamma = _gamma_from_eps1(dim, eps1)
    alpha = 0.5 * (dim - 1)
    tau = 0.5 * (1.0 + gamma)
    i_tau = _betainc_f64(alpha, alpha, tau)
    i_tau = min(max(i_tau, 1e-300), 1.0 - 1e-16)
    # m = (1-gamma^2)^alpha / (2^{d-2} (d-1)) * [ p/(B - B_tau) - (1-p)/B_tau ]
    # with B = B(alpha, alpha), B_tau = B(tau; alpha, alpha) = I_tau * B.
    log_common = alpha * math.log1p(-gamma * gamma) - (dim - 2) * math.log(2.0) \
        - math.log(dim - 1) - _log_beta(alpha, alpha)
    term_cap = p * math.exp(log_common - math.log1p(-i_tau))
    term_comp = (1.0 - p) * math.exp(log_common - math.log(i_tau))
    m = term_cap - term_comp
    if not (m > 0.0) or not math.isfinite(m):
        raise ValueError(
            f"PrivUnit normalizer m={m!r} is not positive/finite for d={dim}, "
            f"eps0={eps0}, eps1={eps1}; increase eps0."
        )
    return PrivUnitParams(dim=dim, eps0=eps0, eps1=eps1, p=p, gamma=gamma, m=m,
                          alpha=alpha, tau=tau, i_tau=i_tau)


def _betainc_inv_bisect(alpha: float, y: jax.Array, iters: int = 60) -> jax.Array:
    """Invert x -> I_x(alpha, alpha) by bisection (jittable)."""

    def body(_, state):
        """One bisection step narrowing [lo, hi] around the target quantile."""
        lo, hi = state
        mid = 0.5 * (lo + hi)
        val = jax.scipy.special.betainc(alpha, alpha, mid)
        lo = jnp.where(val < y, mid, lo)
        hi = jnp.where(val < y, hi, mid)
        return lo, hi

    lo = jnp.zeros_like(y)
    hi = jnp.ones_like(y)
    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    return 0.5 * (lo + hi)


def privunit_direction(key: jax.Array, unit: jax.Array, params: PrivUnitParams) -> jax.Array:
    """PrivUnit (Algorithm 5): eps0+eps1 pure-DP randomization of a unit vector.

    Returns ``z`` with ``||z|| = 1/m`` and ``E[z] = unit``.
    """
    d = params.dim
    k_cap, k_t, k_w = jax.random.split(key, 3)

    in_cap = jax.random.uniform(k_cap) < params.p
    u01 = jax.random.uniform(k_t)
    # Truncated Beta(alpha, alpha): cap -> x in [tau, 1]; complement -> [0, tau).
    y_cap = params.i_tau + u01 * (1.0 - params.i_tau)
    y_comp = u01 * params.i_tau
    y = jnp.where(in_cap, y_cap, y_comp)
    x = _betainc_inv_bisect(params.alpha, y)
    t = 2.0 * x - 1.0
    t = jnp.clip(t, -1.0 + 1e-7, 1.0 - 1e-7)

    g = jax.random.normal(k_w, unit.shape, unit.dtype)
    g_perp = g - jnp.dot(g, unit) * unit
    w_hat = g_perp / jnp.maximum(jnp.linalg.norm(g_perp), 1e-12)
    v = t * unit + jnp.sqrt(jnp.maximum(1.0 - t * t, 0.0)) * w_hat
    return v / params.m


# ---------------------------------------------------------------------------
# ScalarDP (Algorithm 6)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ScalarDPParams:
    """Static constants for ScalarDP(eps2) with magnitudes in [0, r_max]."""

    eps2: float
    r_max: float       # = clipping threshold C
    k: int             # ceil(e^{eps2/3})
    a: float           # debias scale
    b: float           # debias offset
    c1: float          # variance-bound constants of Algorithm 4
    c2: float
    c3: float


def make_scalardp_params(eps2: float, r_max: float) -> ScalarDPParams:
    """ScalarDP magnitude-release lattice for budget eps2 on [0, r_max].

    k = ceil(e^{eps2/3}) lattice points with the debias transform (a, b)
    and the variance-bound constants (c1, c2, c3) of Algorithm 4.
    """
    k = int(math.ceil(math.exp(eps2 / 3.0)))
    e = math.exp(eps2)
    a = ((e + k) / (e - 1.0)) * (r_max / k)
    b = k * (k + 1.0) / (2.0 * (e + k))
    c1 = (k + 1.0) / (e - 1.0)
    c2 = -c1 * r_max
    c3 = (c1 + 1.0) * r_max**2 / (4.0 * k * k) + c1 * r_max**2 * (
        (2.0 * k + 1.0) * (e + k) / (6.0 * k * (e - 1.0)) - (k + 1.0) / (4.0 * (e - 1.0))
    )
    return ScalarDPParams(eps2=eps2, r_max=r_max, k=k, a=a, b=b, c1=c1, c2=c2, c3=c3)


def scalardp_magnitude(key: jax.Array, r: jax.Array, params: ScalarDPParams) -> jax.Array:
    """ScalarDP (Algorithm 6): eps2 pure-DP unbiased estimate of ``r in [0, C]``."""
    k = params.k
    k_round, k_rr, k_unif = jax.random.split(key, 3)

    scaled = jnp.clip(r / params.r_max, 0.0, 1.0) * k
    j_floor = jnp.floor(scaled)
    p_floor = jnp.ceil(scaled) - scaled  # w.p. ceil - x take floor
    take_floor = jax.random.uniform(k_round) < p_floor
    j = jnp.where(take_floor, j_floor, jnp.ceil(scaled)).astype(jnp.int32)
    j = jnp.clip(j, 0, k)

    keep = jax.random.uniform(k_rr) < math.exp(params.eps2) / (math.exp(params.eps2) + k)
    # uniform over {0..k} \ {j}: draw in {0..k-1} and shift past j.
    u = jax.random.randint(k_unif, (), 0, k)
    u = jnp.where(u >= j, u + 1, u)
    j_hat = jnp.where(keep, j, u)
    return params.a * (j_hat.astype(jnp.float32) - params.b)


# ---------------------------------------------------------------------------
# Combined randomizer + norm estimation (Algorithm 4)
# ---------------------------------------------------------------------------

def privunit_randomize(key: jax.Array, delta: jax.Array,
                       pu: PrivUnitParams, sc: ScalarDPParams) -> jax.Array:
    """LocalRandomizer for LDP(PrivUnit): ``c = ScalarDP(||d||) * PrivUnit(d/||d||)``.

    Unbiased: ``E[c] = delta`` (Lemma B.1); pure (eps0+eps1+eps2)-LDP.
    """
    k_dir, k_mag = jax.random.split(key)
    nrm = jnp.linalg.norm(delta)
    unit = delta / jnp.maximum(nrm, 1e-12)
    z = privunit_direction(k_dir, unit, pu)
    r_hat = scalardp_magnitude(k_mag, nrm, sc)
    return r_hat * z


def estimate_norm_sq(c: jax.Array, pu: PrivUnitParams, sc: ScalarDPParams) -> jax.Array:
    """Algorithm 4: estimate ``||Delta||^2`` from the PrivUnit release ``c``.

    Recovers the signed ScalarDP output from ``||c|| = |r_hat| / m`` using the
    lattice structure of ScalarDP (r_hat/a + b is an integer iff the sign is
    positive, under the paper's assumption k(k+1)/(e^{eps2}+k) not in Z), then
    debiases through the variance upper bound:
        s_hat = (r_hat^2 - c2 * r_hat - c3) / (1 + c1),   E[s_hat] <= ||Delta||^2.
    """
    r_tilde = pu.m * jnp.linalg.norm(c)
    j_pos = r_tilde / sc.a + sc.b
    j_neg = -r_tilde / sc.a + sc.b
    dist_pos = jnp.abs(j_pos - jnp.round(j_pos))
    dist_neg = jnp.abs(j_neg - jnp.round(j_neg))
    r_hat = jnp.where(dist_pos <= dist_neg, r_tilde, -r_tilde)
    return (r_hat**2 - sc.c2 * r_hat - sc.c3) / (1.0 + sc.c1)
