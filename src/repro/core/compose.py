"""Composable algorithm stack: privacy mechanism x aggregation x global step.

The paper's core claim is that DP-FedEXP is a *composition*: any client
randomizer (Gaussian LDP, PrivUnit, central Gaussian) under any clipping
regime can feed the adaptive extrapolated step size.  This module makes that
literal (DESIGN.md §11).  A server algorithm is

    ComposedAlgorithm(mechanism, step, aggregator, name)

built from three orthogonal frozen-dataclass layers (the fourth layer of the
stack — ``LocalTrainer`` / ``LocalSpec`` — lives in ``repro.fedsim`` because
it runs client-side, before the server ever sees an update):

    PrivacyMechanism   owns clipping + noise + the step-size bias correction
                       + the privacy-accounting hook for ITS release:
                       ``NoPrivacy``, ``GaussianLDP``, ``PrivUnitLDP``,
                       ``CentralGaussian`` (fixed sigma or the adaptive-clip
                       noise multiplier ``z_mult``).
    Aggregation        how released updates combine: ``MeanAggregation``
                       (the paper) or ``WeightedAggregation`` (per-client
                       priority/size weights, Talaei et al. 2024), both
                       riding the masked-moment machinery of DESIGN.md §9
                       (``partial_clip_moments`` / the ``dp_aggregate``
                       kernel path, unchanged).
    GlobalStep         what the server does with the released mean:
                       ``FixedEta`` (DP-FedAvg), ``FedEXPStep`` (the paper's
                       adaptive extrapolation, Eqs. 2/6/7/8 — it asks the
                       MECHANISM for its debiased numerator, so one step
                       class serves every randomizer), ``ServerOpt`` (FedOpt
                       family: server Adam / momentum), ``AdaptiveClipStep``
                       (Andrew et al. 2021 quantile clip tracking — owns the
                       clip state and overrides every mechanism's threshold).

Layer contract (who may touch what — DESIGN.md §11):

* The MECHANISM is stateless.  It reads the round key and the clip threshold
  (its own static ``clip_norm`` unless the step overrides it), draws ALL
  randomness of the release (per-client LDP noise keyed by global client
  index; central noise from the replicated post-psum key), and is the only
  layer that sees per-client rows.
* The AGGREGATION layer only reweights rows AFTER the per-client release
  (weights are public), so the DP guarantee is untouched.
* The STEP owns the carry state (optimizer moments, clip threshold) and the
  extra PRNG streams (xi, clip-bit noise) — split off the round key exactly
  as the monolithic classes did, so compositions are bit-identical to them.
* Accounting: ``ComposedAlgorithm.budget`` delegates to
  ``mechanism.budget(...)`` with ``with_numerator`` set when the step
  releases the FedEXP numerator; the session's ``privacy_report`` calls it.

Every legacy registry name (``repro.core.fedexp.make_algorithm``) is now one
of these compositions, pinned bit-for-bit against the monolithic classes by
``tests/test_compose.py``.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.core import accounting, compression, stepsize
from repro.core import mechanisms as mech
from repro.core.aggregation import (
    RoundMoments,
    RoundStats,
    aggregate_stats,
    fused_clip_aggregate,
    materialize_ldp_noise,
    partial_clip_moments,
    raw_moments,
)
from repro.core.algorithm import (
    RoundAux,
    ServerAlgorithm,
    client_keys,
    set_moment_count,
)

__all__ = [
    "PrivacyMechanism",
    "NoPrivacy",
    "GaussianLDP",
    "PerClientGaussian",
    "PrivUnitLDP",
    "CentralGaussian",
    "NoiseSchedule",
    "Aggregation",
    "MeanAggregation",
    "WeightedAggregation",
    "RandKAggregation",
    "CountSketchAggregation",
    "CompressionCarry",
    "with_compression",
    "GlobalStep",
    "FixedEta",
    "FedEXPStep",
    "ServerOpt",
    "AdaptiveClipStep",
    "ComposedAlgorithm",
    "compose_algorithm",
]


# ---------------------------------------------------------------------------
# Privacy mechanisms
# ---------------------------------------------------------------------------

class PrivacyMechanism:
    """One client randomizer + its clipping regime + its accounting.

    ``clip`` arguments below are ``None`` (use the mechanism's own static
    ``clip_norm`` — the historical, bit-pinned path) or a traced per-round
    threshold injected by ``AdaptiveClipStep``.

    Methods (all pure; ``key`` is the round key, NEVER pre-split — the step
    layer owns key splitting so compositions match the monolithic classes):

        release(key, deltas, clip, m)           dense (M, d) -> (RoundStats, extras)
        moments(key, deltas, mask, start, clip, row_weights)
                                                -> (RoundMoments, extras) partial SUMS
        finalize(key, mom, extras, clip, m_eff) psummed moments -> (RoundStats, extras')
        extrapolation(k_xi, stats, extras, dim, clip, m_eff)
                                                -> (eta, eta_naive, eta_target)
        budget(delta, rounds, dim, sampling_q, with_numerator) -> PrivacyReport
    """

    is_private = True
    needs_xi_key = False            # CDP-style post-aggregation numerator noise
    # compression (DESIGN.md §16): only mechanisms whose release randomness is
    # drawn AFTER the aggregation (central noise) — or not at all — can ride a
    # compressed sum.  An LDP release is a full R^d vector per client; there
    # is no sound way to compress it server-side, so LDP mechanisms leave
    # this False and ComposedAlgorithm rejects the composition at build time.
    supports_compression = False
    # scalar extras psummed alongside the moments (PrivUnit's sum_s_hat);
    # counted by the §16 communication model
    n_scalar_extras = 0
    # round-indexed mechanisms (NoiseSchedule) resolve to a per-round release
    # via ``at_round(t)``; engines thread t only when this is True, so every
    # fixed-noise composition keeps its exact pre-§17 trace
    is_round_indexed = False

    def at_round(self, t):
        """The mechanism governing round ``t`` (self unless round-indexed)."""
        return self

    @property
    def clip_independent_budget(self) -> bool:
        """True when this mechanism's guarantee does not depend on the clip
        threshold (so an AdaptiveClipStep override keeps the budget sound):
        PrivUnit (pure-DP in eps0/eps1/eps2) and the z-tracking
        CentralGaussian (noise std scales with C).  Fixed-sigma Gaussian
        mechanisms are NOT — their sensitivity/noise ratio moves with C."""
        return False

    def _clip(self, clip):
        # subclasses with a clipping regime define a clip_norm field
        return getattr(self, "clip_norm", None) if clip is None else clip

    def release(self, key, deltas, clip, m):
        """Dense release: clip + randomize + reduce M rows to ``(RoundStats, extras)``."""
        raise NotImplementedError

    def moments(self, key, deltas, mask, start, clip, row_weights=None):
        """Shard-local partial SUMS of the release over masked rows at global ``start``."""
        raise NotImplementedError

    def finalize(self, key, mom, extras, clip, m_eff):
        """Globally reduced moments -> the ``RoundStats`` the step layer consumes."""
        return mom.stats(), {}

    def compressed_noise(self, key, shape, clip, m_eff, sens_factor):
        """Release noise for a COMPRESSED aggregate mean of the given shape
        (DESIGN.md §16), or None when this release adds no central noise.
        ``sens_factor`` is the compressor's worst-case row-norm growth
        (enforced pre-aggregation by the moment path's row re-clip), so the
        per-cell noise std scales by it and the C/sigma ratio — all the
        accounting sees — is unchanged from the dense release."""
        raise NotImplementedError(
            f"{type(self).__name__} does not support compressed aggregation")

    def extrapolation(self, k_xi, stats, extras, dim, clip, m_eff):
        """This mechanism's debiased step size: ``(eta_g, eta_naive, eta_target)``."""
        raise NotImplementedError

    def budget(self, delta, *, rounds, dim, sampling_q, with_numerator):
        """Privacy budget of a ``rounds``-round run of this release (``PrivacyReport``)."""
        raise ValueError(f"{type(self).__name__} is not a private mechanism")


@dataclasses.dataclass(frozen=True)
class NoPrivacy(PrivacyMechanism):
    """No clipping, no noise: the FedAvg/FedEXP reference release."""

    is_private = False
    supports_compression = True     # nothing to privatize; compression is free

    def release(self, key, deltas, clip, m):
        """Dense release: clip + randomize + reduce M rows to ``(RoundStats, extras)``."""
        return aggregate_stats(deltas), {}

    def moments(self, key, deltas, mask, start, clip, row_weights=None,
                compress_fn=None, compress_row_bound=None):
        """Shard-local partial SUMS of the release over masked rows at global ``start``."""
        return raw_moments(deltas, mask, row_weights,
                           compress_fn=compress_fn), {}

    def compressed_noise(self, key, shape, clip, m_eff, sens_factor):
        """No release noise; the compressed aggregate passes through."""
        return None

    def extrapolation(self, k_xi, stats, extras, dim, clip, m_eff):
        """This mechanism's debiased step size: ``(eta_g, eta_naive, eta_target)``."""
        return stepsize.fedexp(stats.mean_sq, stats.agg_sq), None, None


@dataclasses.dataclass(frozen=True)
class GaussianLDP(PrivacyMechanism):
    """Per-client clip + Gaussian noise (the paper's LDP setting).

    Noise rows are keyed by GLOBAL client index (``materialize_ldp_noise``)
    so shards reproduce the single-device randomization bit-for-bit; the
    dense release routes through ``fused_clip_aggregate`` (kernel-fused noise
    on TPU, tuned jnp elsewhere — DESIGN.md §5/§8).
    """

    clip_norm: float
    sigma: float
    backend: str = "auto"

    def release(self, key, deltas, clip, m):
        """Dense release: clip + randomize + reduce M rows to ``(RoundStats, extras)``."""
        return fused_clip_aggregate(deltas, self._clip(clip), noise_key=key,
                                    noise_sigma=self.sigma,
                                    backend=self.backend), {}

    def moments(self, key, deltas, mask, start, clip, row_weights=None):
        """Shard-local partial SUMS of the release over masked rows at global ``start``."""
        noise = materialize_ldp_noise(key, *deltas.shape, self.sigma,
                                      deltas.dtype, start=start)
        return partial_clip_moments(deltas, self._clip(clip), noise,
                                    weight_mask=mask, row_weights=row_weights,
                                    backend=self.backend), {}

    def extrapolation(self, k_xi, stats, extras, dim, clip, m_eff):
        """This mechanism's debiased step size: ``(eta_g, eta_naive, eta_target)``."""
        eta = stepsize.ldp_gaussian(stats.mean_sq, stats.agg_sq, dim, self.sigma)
        return (eta,
                stepsize.naive_noisy(stats.mean_sq, stats.agg_sq),
                stepsize.target(stats.mean_sq_clipped, stats.agg_sq))

    def budget(self, delta, *, rounds, dim, sampling_q, with_numerator):
        # per-release local guarantee (Prop. 4.1): identical for FedAvg /
        # FedEXP / FedOpt steps — the step size is computed server-side from
        # already-released updates — and unamplified by central subsampling
        """Privacy budget of a ``rounds``-round run of this release (``PrivacyReport``)."""
        return accounting.ldp_gaussian_budget(self.clip_norm, self.sigma, delta)


@dataclasses.dataclass(frozen=True)
class PerClientGaussian(PrivacyMechanism):
    """Heterogeneous-privacy Gaussian LDP: client i carries its OWN epsilon.

    Each client's sigma_i is derived at build time from its (eps_i, delta)
    budget by inverting the GDP single-release curve (``sigma_for_epsilon``
    with sensitivity 2C), so the per-client guarantee is exact, not a shared
    worst case.  sigma_i is indexed by GLOBAL client index — the same
    contract as ``WeightedAggregation.weights`` — and the noise rows reuse
    the globally-keyed ``materialize_ldp_noise`` stream scaled per row, so
    shards/chunks reproduce the single-device randomization bit-for-bit.

    The FedEXP bias correction under mixed noise subtracts
    ``d * mean(sigma_i^2)`` over the realized cohort (``ldp_gaussian_mixed``);
    the cohort's sum of sigma_i^2 rides the psum as a scalar extra, exactly
    like PrivUnit's sum_s_hat.  When every epsilon is equal the whole path
    short-circuits to ``GaussianLDP``'s expressions with the common sigma —
    the degenerate composition is bit-identical, by construction.

    ``inverse_variance_weights()`` exposes the public 1/sigma_i^2 weights the
    registry pairs with ``WeightedAggregation`` (noisier clients count less;
    the weights depend only on the PUBLIC epsilons, not the data).
    """

    clip_norm: float
    epsilons: tuple[float, ...]
    delta: float
    backend: str = "auto"

    def __post_init__(self):
        eps = tuple(float(e) for e in self.epsilons)
        if not eps:
            raise ValueError("PerClientGaussian requires per-client epsilons")
        object.__setattr__(self, "epsilons", eps)
        sigmas = mech.per_client_sigmas(eps, self.delta, self.clip_norm)
        object.__setattr__(self, "sigmas", sigmas)
        object.__setattr__(self, "_uniform", len(set(sigmas)) == 1)

    @property
    def n_scalar_extras(self):
        """sum_sigma_sq rides the psum only when sigmas actually differ."""
        return 0 if self._uniform else 1

    def inverse_variance_weights(self) -> tuple[float, ...]:
        """Public 1/sigma_i^2 aggregation weights (for WeightedAggregation)."""
        return tuple(1.0 / (s * s) for s in self.sigmas)

    def _sigma_rows(self, start, m_local):
        """(m_local,) per-row sigmas at global ``start`` — the exact slicing
        contract of ``WeightedAggregation.row_weights`` (scalar start or a
        gather-index vector; padding rows past M pick up sigma 0 => no noise,
        and they are masked out of every reduction anyway)."""
        s = jnp.asarray(self.sigmas, jnp.float32)
        if getattr(start, "ndim", 0) == 1:
            padded = jnp.concatenate([s, jnp.zeros((m_local,), jnp.float32)])
            return jnp.take(padded, jnp.minimum(start, len(self.sigmas)),
                            axis=0)
        if isinstance(start, int) and start == 0 and m_local == len(self.sigmas):
            return s
        padded = jnp.concatenate([s, jnp.zeros((m_local,), jnp.float32)])
        return jax.lax.dynamic_slice(padded, (start,), (m_local,))

    def _noise(self, key, shape, dtype, start):
        """Per-row noise: the unit-sigma globally-keyed stream scaled by
        sigma_i — same draws as GaussianLDP, heterogeneous scale."""
        rows = materialize_ldp_noise(key, *shape, 1.0, dtype, start=start)
        return rows * self._sigma_rows(start, shape[0])[:, None]

    def release(self, key, deltas, clip, m):
        """Dense release: clip + randomize + reduce M rows to ``(RoundStats, extras)``."""
        if self._uniform:
            return fused_clip_aggregate(deltas, self._clip(clip), noise_key=key,
                                        noise_sigma=self.sigmas[0],
                                        backend=self.backend), {}
        noise = self._noise(key, deltas.shape, deltas.dtype, 0)
        stats = fused_clip_aggregate(deltas, self._clip(clip), noise,
                                     backend=self.backend)
        sig_sq = jnp.square(self._sigma_rows(0, deltas.shape[0]))
        return stats, {"mean_sigma_sq": jnp.sum(sig_sq) / m}

    def moments(self, key, deltas, mask, start, clip, row_weights=None):
        """Shard-local partial SUMS of the release over masked rows at global ``start``."""
        if self._uniform:
            noise = materialize_ldp_noise(key, *deltas.shape, self.sigmas[0],
                                          deltas.dtype, start=start)
            return partial_clip_moments(deltas, self._clip(clip), noise,
                                        weight_mask=mask,
                                        row_weights=row_weights,
                                        backend=self.backend), {}
        noise = self._noise(key, deltas.shape, deltas.dtype, start)
        mom = partial_clip_moments(deltas, self._clip(clip), noise,
                                   weight_mask=mask, row_weights=row_weights,
                                   backend=self.backend)
        v = mask if row_weights is None else mask * row_weights
        sig_sq = jnp.square(self._sigma_rows(start, deltas.shape[0]))
        return mom, {"sum_sigma_sq": v @ sig_sq}

    def finalize(self, key, mom, extras, clip, m_eff):
        """Globally reduced moments -> the ``RoundStats`` the step layer consumes."""
        if self._uniform:
            return mom.stats(), {}
        return mom.stats(), {"mean_sigma_sq": extras["sum_sigma_sq"] / mom.count}

    def extrapolation(self, k_xi, stats, extras, dim, clip, m_eff):
        """This mechanism's debiased step size: ``(eta_g, eta_naive, eta_target)``."""
        if self._uniform:
            eta = stepsize.ldp_gaussian(stats.mean_sq, stats.agg_sq, dim,
                                        self.sigmas[0])
        else:
            eta = stepsize.ldp_gaussian_mixed(stats.mean_sq, stats.agg_sq, dim,
                                              extras["mean_sigma_sq"])
        return (eta,
                stepsize.naive_noisy(stats.mean_sq, stats.agg_sq),
                stepsize.target(stats.mean_sq_clipped, stats.agg_sq))

    def budget(self, delta, *, rounds, dim, sampling_q, with_numerator):
        """Worst-client budget: the report is the LDP guarantee of the
        smallest-sigma (largest-epsilon) client; every other client's release
        is strictly more private (its eps_i at the same delta is smaller)."""
        rep = accounting.ldp_gaussian_budget(self.clip_norm, min(self.sigmas),
                                             delta)
        return dataclasses.replace(
            rep, setting=f"LDP (Gaussian, per-client worst of "
                         f"{len(self.epsilons)})")


@dataclasses.dataclass(frozen=True)
class PrivUnitLDP(PrivacyMechanism):
    """Per-client clip + PrivUnit direction x ScalarDP magnitude (pure LDP).

    With a traced clip override (adaptive clipping) the static ScalarDP
    lattice built at ``clip_norm`` is reused through exact public rescaling:
    magnitudes are released on the reference scale and multiplied back by
    ``clip / clip_norm`` (ScalarDP's debias transform is linear in ``r_max``,
    so this is the r_max=clip release, not an approximation).
    """

    clip_norm: float
    eps0: float
    eps1: float
    eps2: float
    dim: int

    n_scalar_extras = 1      # sum_s_hat rides the psum next to the moments

    def __post_init__(self):
        object.__setattr__(self, "pu", mech.make_privunit_params(self.dim, self.eps0, self.eps1))
        object.__setattr__(self, "sc", mech.make_scalardp_params(self.eps2, self.clip_norm))

    @property
    def clip_independent_budget(self) -> bool:
        """True when the guarantee does not move with the clip threshold."""
        return True  # pure (eps0+eps1+eps2)-LDP at ANY clip threshold

    def _randomize(self, key, deltas, start, clip):
        """Per-client clip + PrivUnit release, keys by GLOBAL client index."""
        m, _ = deltas.shape
        keys = client_keys(key, m, start)
        c = self._clip(clip)
        norms = jnp.linalg.norm(deltas, axis=-1)
        scale = jnp.minimum(1.0, c / jnp.maximum(norms, 1e-12))
        clipped = deltas * scale[:, None]
        if clip is None:
            released = jax.vmap(
                lambda k, dlt: mech.privunit_randomize(k, dlt, self.pu, self.sc))(keys, clipped)
        else:  # traced clip: release on the reference scale, rescale publicly
            to_ref = self.clip_norm / c
            released = jax.vmap(
                lambda k, dlt: mech.privunit_randomize(k, dlt, self.pu, self.sc))(
                keys, clipped * to_ref) / to_ref
        return released, clipped

    def _s_hat(self, released, clip):
        est = jax.vmap(lambda v: mech.estimate_norm_sq(v, self.pu, self.sc))
        if clip is None:
            return est(released)
        to_ref = self.clip_norm / self._clip(clip)
        return est(released * to_ref) / jnp.square(to_ref)

    def release(self, key, deltas, clip, m):
        """Dense release: clip + randomize + reduce M rows to ``(RoundStats, extras)``."""
        released, clipped = self._randomize(key, deltas, 0, clip)
        stats = aggregate_stats(released)
        stats.mean_sq_clipped = (
            jnp.sum(jnp.sum(jnp.square(clipped), axis=-1)) / m)
        return stats, {"mean_s_hat": jnp.sum(self._s_hat(released, clip)) / m}

    def moments(self, key, deltas, mask, start, clip, row_weights=None):
        """Shard-local partial SUMS of the release over masked rows at global ``start``."""
        released, clipped = self._randomize(key, deltas, start, clip)
        # where-zero BOTH row sets (released and pre-noise clipped): the
        # engine zeroes masked deltas at the source, but a garbage row must
        # not leak as 0 * inf = NaN through the mask dots below
        keep = mask[:, None] > 0
        released = jnp.where(keep, released, 0.0)
        clipped = jnp.where(keep, clipped, 0.0)
        # dots with the mask, not sum(mask * x): bit-parity with the
        # unsharded reference reductions (see ``raw_moments``)
        v = mask if row_weights is None else mask * row_weights
        mom = RoundMoments(
            sum_c=v @ released,
            sum_sq=v @ jnp.sum(jnp.square(released), axis=-1),
            sum_sq_clipped=v @ jnp.sum(jnp.square(clipped), axis=-1),
            count=jnp.sum(v))
        return mom, {"sum_s_hat": v @ self._s_hat(released, clip)}

    def finalize(self, key, mom, extras, clip, m_eff):
        """Globally reduced moments -> the ``RoundStats`` the step layer consumes."""
        return mom.stats(), {"mean_s_hat": extras["sum_s_hat"] / mom.count}

    def extrapolation(self, k_xi, stats, extras, dim, clip, m_eff):
        """This mechanism's debiased step size: ``(eta_g, eta_naive, eta_target)``."""
        eta = stepsize.ldp_privunit(extras["mean_s_hat"], stats.agg_sq)
        return (eta,
                stepsize.naive_noisy(stats.mean_sq, stats.agg_sq),
                stepsize.target(stats.mean_sq_clipped, stats.agg_sq))

    def budget(self, delta, *, rounds, dim, sampling_q, with_numerator):
        """Privacy budget of a ``rounds``-round run of this release (``PrivacyReport``)."""
        return accounting.privunit_budget(self.eps0, self.eps1, self.eps2)


@dataclasses.dataclass(frozen=True)
class CentralGaussian(PrivacyMechanism):
    """Clip-only clients + server-side Gaussian noise on the mean (CDP).

    Two noise modes:
      * fixed ``sigma`` (the paper): server noise std ``sigma / sqrt(M)``
        with the STATIC configured client count — the release the
        Proposition 4.2 accounting is stated for;
      * ``z_mult`` (adaptive clipping, Andrew et al.): std ``z*C / sqrt(m)``
        tracking the CURRENT clip threshold and the REALIZED cohort size, so
        the guarantee is C-independent.
    Noise is drawn from the replicated round key AFTER the psum, so sharded
    and single-device releases add the identical (d,) draw (DESIGN.md §9).
    """

    clip_norm: float | None = None
    sigma: float | None = None
    num_clients: int = 0
    sigma_xi: float | None = None     # numerator noise; None = d sigma^2 / M
    z_mult: float | None = None       # adaptive mode: sigma = z * C
    backend: str = "auto"

    needs_xi_key = True
    supports_compression = True     # noise is drawn POST-aggregation (§16)

    def __post_init__(self):
        if (self.sigma is None) == (self.z_mult is None):
            raise ValueError("set exactly one of sigma (fixed) / z_mult (adaptive)")
        if self.sigma is not None and self.clip_norm is None:
            raise ValueError("fixed-sigma CentralGaussian requires clip_norm")
        if self.num_clients < 1:
            raise ValueError("CentralGaussian requires num_clients >= 1")

    @property
    def clip_independent_budget(self) -> bool:
        """True when the guarantee does not move with the clip threshold."""
        return self.z_mult is not None  # noise tracks z*C => C cancels

    def _sigma(self, clip):
        return self.sigma if self.z_mult is None else self.z_mult * self._clip(clip)

    def _m_noise(self, m_eff):
        """Divisor of the server-noise std: the static configured M for the
        fixed-sigma release, the realized cohort for the z-tracking one.
        A traced realized count is floored at 1 (a weight-sum count < 1 must
        not inflate the noise; the static dense count is left untouched —
        the monolithic classes' exact expression)."""
        if self.z_mult is None:
            return float(self.num_clients)
        return m_eff if isinstance(m_eff, float) else jnp.maximum(m_eff, 1.0)

    def _noised(self, key, cbar, clip, m_eff):
        d = cbar.shape[-1]
        noise = (self._sigma(clip) / jnp.sqrt(self._m_noise(m_eff))) \
            * jax.random.normal(key, (d,))
        return cbar + noise

    def release(self, key, deltas, clip, m):
        """Dense release: clip + randomize + reduce M rows to ``(RoundStats, extras)``."""
        stats = fused_clip_aggregate(deltas, self._clip(clip), None,
                                     backend=self.backend)
        cbar = self._noised(key, stats.cbar, clip, m)
        return RoundStats(cbar=cbar, mean_sq=stats.mean_sq,
                          agg_sq=jnp.sum(jnp.square(cbar)),
                          mean_sq_clipped=stats.mean_sq_clipped), {}

    def moments(self, key, deltas, mask, start, clip, row_weights=None,
                compress_fn=None, compress_row_bound=None):
        """Shard-local partial SUMS of the release over masked rows at global ``start``."""
        return partial_clip_moments(deltas, self._clip(clip), None,
                                    weight_mask=mask, row_weights=row_weights,
                                    backend=self.backend,
                                    compress_fn=compress_fn,
                                    compress_row_bound=compress_row_bound), {}

    def finalize(self, key, mom, extras, clip, m_eff):
        """Globally reduced moments -> the ``RoundStats`` the step layer consumes."""
        cbar = self._noised(key, mom.sum_c / mom.count, clip, m_eff)
        return RoundStats(cbar=cbar, mean_sq=mom.sum_sq / mom.count,
                          agg_sq=jnp.sum(jnp.square(cbar)),
                          mean_sq_clipped=mom.sum_sq_clipped / mom.count), {}

    def compressed_noise(self, key, shape, clip, m_eff, sens_factor):
        """Gaussian noise on the compressed aggregate mean (DESIGN.md §16).

        Per-client sensitivity of the compressed SUM is ``sens_factor * C``
        (rand-k is a contraction, sens_factor 1; count-sketch rows are
        re-clipped to ``sqrt(depth) * C`` by the moment path), so the mean's
        noise std is the dense release's ``sigma(C) / sqrt(m)`` scaled by the
        same factor — the C/sigma ratio, hence ``budget()``, is unchanged."""
        return (sens_factor * self._sigma(clip)
                / jnp.sqrt(self._m_noise(m_eff))) \
            * jax.random.normal(key, shape)

    def extrapolation(self, k_xi, stats, extras, dim, clip, m_eff):
        """This mechanism's debiased step size: ``(eta_g, eta_naive, eta_target)``."""
        sigma = self._sigma(clip)
        sigma_xi = (self.sigma_xi if self.sigma_xi is not None
                    else dim * sigma**2 / self._m_noise(m_eff))
        xi = sigma_xi * jax.random.normal(k_xi, ())
        eta = stepsize.cdp(stats.mean_sq_clipped, xi, stats.agg_sq)
        return eta, None, stepsize.target(stats.mean_sq_clipped, stats.agg_sq)

    def budget(self, delta, *, rounds, dim, sampling_q, with_numerator):
        """Privacy budget of a ``rounds``-round run of this release (``PrivacyReport``)."""
        q = sampling_q
        if self.z_mult is not None:
            # noise std tracks z*C, so the C/sigma ratio — all the budget
            # sees — is the constant 1/z; stated in C=1 units.  The noise
            # scales with the REALIZED cohort (sigma/sqrt(|S_t|)), so the
            # conditional per-round mu inflates by 1/sqrt(q) only; feeding
            # cdp_budget the effective count M/q composes exactly that.  The
            # clip-bit release adds adaptive_clip_rho, negligible by
            # construction (sigma_b ~ 10).
            return accounting.cdp_budget(
                1.0, self.z_mult, self.num_clients / q, rounds, delta,
                sigma_xi=(dim * self.z_mult**2 / self.num_clients
                          if with_numerator else None),
                sampling_q=q)
        sigma_xi = None
        if with_numerator:
            sigma_xi = (self.sigma_xi if self.sigma_xi is not None
                        else dim * self.sigma**2 / self.num_clients)
        return accounting.cdp_budget(self.clip_norm, self.sigma,
                                     self.num_clients, rounds, delta,
                                     sigma_xi=sigma_xi, sampling_q=q)


@dataclasses.dataclass(frozen=True)
class NoiseSchedule(PrivacyMechanism):
    """Round-indexed noise schedule sigma(t) over a fixed-sigma mechanism.

    A pure CONFIG wrapper (DESIGN.md §17): it never executes a release
    itself.  Engines that see ``is_round_indexed`` thread the round index t
    into the composition, and ``at_round(t)`` resolves the wrapper to its
    inner mechanism with ``sigma = sigma(t)`` — a traced scalar riding the
    existing clip/sigma plumbing, so no engine grows a schedule branch.

        sigma(t) = sigma0 * decay**t * step_factor(t)

    where ``step_factor`` is 1 before the first boundary and ``scales[i]``
    from ``boundaries[i]`` on (Adap-DP-FL-style decay, plus step drops).
    A CONSTANT schedule (decay 1, no boundaries) resolves to the inner
    mechanism UNCHANGED — same object, same trace, bit-for-bit the fixed-
    sigma run — which is the degenerate case the parity suite pins.

    ``budget()`` composes the non-uniform sequence honestly: per-round
    mu_t summed in GDP (``composed_gdp_mu``) with the RDP upper bound kept
    (``schedule_ldp_budget`` / ``schedule_cdp_budget``); a constant schedule
    delegates to the inner mechanism's own accounting for an exactly equal
    report.
    """

    inner: PrivacyMechanism = None
    decay: float = 1.0
    boundaries: tuple[int, ...] = ()
    scales: tuple[float, ...] = ()

    def __post_init__(self):
        if not isinstance(self.inner, (GaussianLDP, CentralGaussian)):
            raise ValueError(
                "NoiseSchedule wraps a fixed-sigma Gaussian mechanism "
                "(GaussianLDP or CentralGaussian); got "
                f"{type(self.inner).__name__}")
        if isinstance(self.inner, CentralGaussian) and self.inner.sigma is None:
            raise ValueError(
                "NoiseSchedule needs a fixed-sigma CentralGaussian; the "
                "z_mult (adaptive-clip) mode already rescales its noise per "
                "round and has no static sigma to schedule")
        if not (isinstance(self.decay, (int, float)) and self.decay > 0):
            raise ValueError(f"decay must be positive, got {self.decay!r}")
        bounds = tuple(int(b) for b in self.boundaries)
        if any(b < 0 for b in bounds) or list(bounds) != sorted(set(bounds)):
            raise ValueError(
                "boundaries must be strictly increasing nonnegative rounds")
        scales = tuple(float(s) for s in self.scales)
        if len(scales) != len(bounds):
            raise ValueError("scales must match boundaries one-to-one")
        if any(s <= 0 for s in scales):
            raise ValueError("scales must be positive")
        object.__setattr__(self, "boundaries", bounds)
        object.__setattr__(self, "scales", scales)

    # -- schedule ----------------------------------------------------------

    @property
    def is_constant(self) -> bool:
        """True when sigma(t) == sigma0 for every t (degenerate schedule)."""
        return self.decay == 1.0 and not self.boundaries

    @property
    def is_round_indexed(self):
        """Engines thread t only for genuinely varying schedules."""
        return not self.is_constant

    def at_round(self, t):
        """The inner mechanism at round ``t`` (traced-sigma replace); the
        inner object ITSELF for a constant schedule — same trace, bit-for-bit
        the fixed-sigma composition."""
        if self.is_constant:
            return self.inner
        return dataclasses.replace(self.inner, sigma=self._sigma_at(t))

    def _sigma_at(self, t):
        """sigma(t) as a traced f32 scalar (t is the traced round index)."""
        tf = jnp.asarray(t, jnp.float32)
        s = jnp.float32(self.inner.sigma) \
            * jnp.power(jnp.float32(self.decay), tf)
        if self.boundaries:
            factors = jnp.asarray((1.0,) + self.scales, jnp.float32)
            idx = jnp.sum((jnp.asarray(self.boundaries) <= t).astype(jnp.int32))
            s = s * factors[idx]
        return s

    def sigma_value(self, t: int) -> float:
        """sigma(t) as a Python float (accounting / telemetry validation).

        f64 mirror of ``_sigma_at``; the traced release uses the f32 value,
        so cross-checks against emitted telemetry compare at f32 rtol.
        """
        factor = 1.0
        for b, sc in zip(self.boundaries, self.scales):
            if t >= b:
                factor = sc
        return float(self.inner.sigma) * float(self.decay) ** int(t) * factor

    # -- delegation to the inner mechanism ---------------------------------

    @property
    def needs_xi_key(self):
        """The wrapper splits keys exactly as its inner mechanism would."""
        return self.inner.needs_xi_key

    @property
    def supports_compression(self):
        """Compression composes iff the inner release does (§16)."""
        return self.inner.supports_compression

    @property
    def n_scalar_extras(self):
        """The inner release's psummed scalar extras (none for Gaussians)."""
        return self.inner.n_scalar_extras

    def __getattr__(self, item):
        if item.startswith("__") or item == "inner":
            raise AttributeError(item)
        d = object.__getattribute__(self, "__dict__")
        inner = d.get("inner")
        if inner is None:
            raise AttributeError(item)
        return getattr(inner, item)

    # -- accounting --------------------------------------------------------

    def budget(self, delta, *, rounds, dim, sampling_q, with_numerator):
        """GDP composition of the non-uniform sigma sequence (DESIGN.md §17);
        constant schedules delegate to the inner mechanism's own accounting
        so the degenerate report is exactly the fixed-sigma one."""
        if self.is_constant:
            return self.inner.budget(delta, rounds=rounds, dim=dim,
                                     sampling_q=sampling_q,
                                     with_numerator=with_numerator)
        sigmas = [self.sigma_value(t) for t in range(rounds)]
        if isinstance(self.inner, GaussianLDP):
            # local guarantee: unamplified by sampling, xi is server-side
            return accounting.schedule_ldp_budget(self.inner.clip_norm,
                                                  sigmas, delta)
        sigma_xis = None
        if with_numerator:
            # mirror CentralGaussian.extrapolation: the hyperparameter-free
            # numerator noise tracks the CURRENT sigma(t) unless pinned
            sigma_xis = [self.inner.sigma_xi if self.inner.sigma_xi is not None
                         else dim * s ** 2 / self.inner.num_clients
                         for s in sigmas]
        return accounting.schedule_cdp_budget(self.inner.clip_norm, sigmas,
                                              self.inner.num_clients, delta,
                                              sigma_xis=sigma_xis,
                                              sampling_q=sampling_q)


# ---------------------------------------------------------------------------
# Aggregation layer
# ---------------------------------------------------------------------------

class Aggregation:
    """How released client updates combine into the round's moments.

    Two orthogonal capabilities ride this layer: per-client WEIGHTS
    (``is_weighted``; public reweighting after each DP release) and
    per-round COMPRESSION (``is_compressed``, DESIGN.md §16; a linear
    per-row map shrinking the O(d) round collective to the compressed
    width).  A compressed layer's plan is re-derived each round from
    ``fold_in(round_key, COMPRESS_TAG)`` — replicated, so every shard and
    stream chunk compresses with the identical plan and the compressed
    partial sums stay additive (§12).
    """

    is_weighted: bool = False
    is_compressed = False
    # worst-case L2 growth of a compressed row vs its dense norm; the moment
    # path re-clips compressed rows to sens_factor * C so central noise can
    # scale by exactly this factor (§16)
    sens_factor = 1.0
    uses_error_feedback = False

    def row_weights(self, start, m_local):
        """Per-client aggregation weights for the rows [start, start + m_local)."""
        return None

    def comm_floats(self, d: int) -> int:
        """Floats in one client's released update / the round's vector sum."""
        return d

    # -- compression API (no-ops for dense layers) --------------------------

    def plan(self, plan_key, d):
        """Per-round shared-randomness tables (indices / hashes); None = dense."""
        return None

    def compress_fn(self, plan):
        """The linear per-row compressor ``(..., d) -> (..., kc)`` for this plan."""
        return None

    def decompress(self, comp, plan, d):
        """(kc,) compressed aggregate -> (d,) estimate (identity when dense)."""
        return comp

    def select(self, g):
        """Post-decompression support selection (top-k); identity by default."""
        return g


def _as_int(name: str, v) -> int:
    if not isinstance(v, int) or isinstance(v, bool) or v < 1:
        raise ValueError(f"{name} must be a positive int, got {v!r}")
    return v


@dataclasses.dataclass(frozen=True)
class MeanAggregation(Aggregation):
    """Uniform mean over the (masked) cohort — the paper's aggregation.
    ``sum / count`` through the masked-moment machinery, bit-identical to
    the monolithic classes."""


@dataclasses.dataclass(frozen=True)
class WeightedAggregation(Aggregation):
    """Per-client aggregation weights (priority / dataset-size weighting,
    Talaei et al. 2024): the round releases ``Σ v_i c_i / Σ v_i``.

    Weights are PUBLIC and applied AFTER each client's DP release, so the
    per-client guarantee is unchanged (the central sensitivity of the
    weighted mean is ``2C·max_i v_i / Σv`` — budget reporting stays the
    mechanism's; see DESIGN.md §11).  ``weights`` is a static per-client
    tuple indexed by GLOBAL client index; shards slice their own rows.
    Weighted counts are real-valued, so the engine's static-count
    substitution is disabled for these compositions.
    """

    weights: tuple[float, ...] = ()

    is_weighted = True

    def __post_init__(self):
        if not self.weights:
            raise ValueError("WeightedAggregation requires per-client weights")
        if any(w < 0 for w in self.weights) or sum(self.weights) <= 0:
            raise ValueError("weights must be nonnegative with positive sum")

    def row_weights(self, start, m_local):
        """Per-client aggregation weights for the rows [start, start + m_local).

        ``start`` is the scalar global index of row 0 (contiguous shard/chunk
        slices) or a (m_local,) vector of global indices (the sparse-gather
        path, DESIGN.md §14) — padding rows index past M and pick up zeros.
        """
        w = jnp.asarray(self.weights, jnp.float32)
        if getattr(start, "ndim", 0) == 1:
            padded = jnp.concatenate([w, jnp.zeros((m_local,), jnp.float32)])
            return jnp.take(padded, jnp.minimum(start, len(self.weights)),
                            axis=0)
        if isinstance(start, int) and start == 0 and m_local == len(self.weights):
            return w
        # shard slice by (possibly traced) global start; zero-pad so padding
        # clients past M slice zeros
        padded = jnp.concatenate([w, jnp.zeros((m_local,), jnp.float32)])
        return jax.lax.dynamic_slice(padded, (start,), (m_local,))


@dataclasses.dataclass(frozen=True)
class RandKAggregation(Aggregation):
    """Unbiased random-k coordinate aggregation (DESIGN.md §16).

    Each round draws k distinct coordinates (shared plan from the round
    key — no per-client state, so it composes with §14 sampling); clients'
    clipped updates are projected onto them, the round reduces a (k,) sum,
    and the server's d/k-scaled scatter is an UNBIASED estimate of the dense
    mean: ``E[decompress(compress(x))] = x`` over the index draw.  A
    coordinate projection is an L2 contraction, so the compressed release
    keeps sensitivity C exactly (sens_factor 1) and central noise is the
    dense std per compressed coordinate.  Unbiased => no error feedback.
    """

    k: int

    is_compressed = True

    def __post_init__(self):
        _as_int("k", self.k)

    def comm_floats(self, d: int) -> int:
        """Floats in one client's released update / the round's vector sum."""
        return min(self.k, d)

    def plan(self, plan_key, d):
        """(k,) distinct coordinate indices drawn for this round."""
        return compression.randk_plan(plan_key, d, min(self.k, d))

    def compress_fn(self, plan):
        """The linear per-row compressor ``(..., d) -> (..., k)``."""
        return lambda u: compression.randk_compress(u, plan)

    def decompress(self, comp, plan, d):
        """Unbiased (d,) estimate: scatter the (k,) sum back, scaled by d/k."""
        return compression.randk_decompress(comp, plan, d)


@dataclasses.dataclass(frozen=True)
class CountSketchAggregation(Aggregation):
    """Count-sketch aggregation with heavy-hitter recovery (DESIGN.md §16).

    Clients sketch their clipped update into a (depth, width) bucket table
    (shared per-round hashes from the round key), the round reduces the
    (depth * width,) flattened sketch, and the server unsketches by
    median-of-depth, optionally keeping only the ``top_k`` largest-|.|
    coordinates (heavy hitters).  The sketch is BIASED once ``top_k``
    truncates the support, so ``error_feedback=True`` carries the
    truncation residual server-side (in the scan state) and re-injects it
    next round — the EF accumulator restores convergence for the biased
    variant.  Worst-case row growth: a (depth, d) sign-hash sketch of a
    C-clipped row has L2 at most ``sqrt(depth) * C`` in expectation-exact
    cases and up to ``sqrt(depth) * ||u||_1`` adversarially, so the moment
    path RE-CLIPS each compressed row to ``sqrt(depth) * C`` (sens_factor)
    before summing — sensitivity is enforced, not assumed, and central
    noise scales by the same factor (the C/sigma accounting is unchanged).
    """

    width: int
    depth: int = 3
    top_k: int | None = None
    error_feedback: bool = False

    is_compressed = True

    def __post_init__(self):
        _as_int("width", self.width)
        _as_int("depth", self.depth)
        if self.top_k is not None:
            _as_int("top_k", self.top_k)
        if self.error_feedback and self.top_k is None:
            raise ValueError(
                "error_feedback without top_k has nothing to feed back: the "
                "un-truncated median unsketch is already the best estimate "
                "this sketch offers.  Set top_k=<support size> (the biased "
                "variant EF exists to correct) or drop error_feedback.")

    @property
    def sens_factor(self):
        """Worst-case compressed-row L2 growth: sqrt(depth) sign-hash tables."""
        return math.sqrt(self.depth)

    @property
    def uses_error_feedback(self):
        """Whether the carry grows a server-side EF residual (§16)."""
        return self.error_feedback

    def comm_floats(self, d: int) -> int:
        """Floats in one client's released update / the round's vector sum."""
        return self.width * self.depth

    def plan(self, plan_key, d):
        """This round's (depth, d) bucket ids + Rademacher signs."""
        return compression.sketch_plan(plan_key, d, self.width, self.depth)

    def compress_fn(self, plan):
        """The linear per-row sketcher ``(..., d) -> (..., depth * width)``."""
        return lambda u: compression.sketch_compress(u, plan, self.width)

    def decompress(self, comp, plan, d):
        """Median-of-depth unsketch to a dense (d,) estimate (no truncation
        here — ``select`` applies top-k AFTER error feedback so the EF
        residual sees the full estimate)."""
        return compression.sketch_decompress(comp, plan, d)

    def select(self, g):
        """Keep the top_k largest-|.| coordinates (identity when top_k unset)."""
        return g if self.top_k is None else compression.topk_select(g, self.top_k)


# ---------------------------------------------------------------------------
# Global step layer
# ---------------------------------------------------------------------------

class GlobalStep:
    """Server-side update policy + owner of the carry state and extra keys.

    ``n_extra_keys`` declares how many PRNG streams beyond the mechanism's
    must be split off the round key (xi for CDP extrapolation, the clip-bit
    stream) — EXACTLY the splits the monolithic classes performed, which is
    what keeps compositions bit-identical.
    """

    stateful: bool = False
    needs_clip_bits: bool = False
    uses_extrapolation: bool = False

    def n_extra_keys(self, mechanism) -> int:
        """PRNG streams beyond the mechanism's to split off the round key."""
        return 0

    def clip_override(self, state):
        """Traced per-round clip threshold from the carry; None = mechanism's static."""
        return None

    def init(self, w):
        """Initial step-owned carry state (optimizer moments / clip threshold)."""
        return ()

    def apply(self, extra_keys, w, stats, extras, mechanism, clip, m_eff, state):
        """Apply this server-update policy to the released round statistics."""
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class FixedEta(GlobalStep):
    """w <- w + eta_g * cbar with a constant eta_g (DP-FedAvg: eta_g = 1)."""

    eta: float = 1.0

    def apply(self, extra_keys, w, stats, extras, mechanism, clip, m_eff, state):
        """Apply this server-update policy to the released round statistics."""
        w_next = w + stats.cbar if self.eta == 1.0 else w + self.eta * stats.cbar
        return w_next, RoundAux(eta_g=jnp.float32(self.eta)), state


@dataclasses.dataclass(frozen=True)
class FedEXPStep(GlobalStep):
    """The paper's adaptive extrapolation (Eqs. 2/6/7/8).

    The mechanism supplies its own debiased numerator (it owns the noise it
    must correct for); this step owns the policy — extrapolate by the ratio,
    floored at 1 — and the xi key when the mechanism privatizes the
    numerator post-aggregation.
    """

    uses_extrapolation = True

    def n_extra_keys(self, mechanism):
        """PRNG streams beyond the mechanism's to split off the round key."""
        return 1 if mechanism.needs_xi_key else 0

    def apply(self, extra_keys, w, stats, extras, mechanism, clip, m_eff, state):
        """Apply this server-update policy to the released round statistics."""
        k_xi = extra_keys[0] if extra_keys else None
        eta, naive, target = mechanism.extrapolation(
            k_xi, stats, extras, w.shape[-1], clip,
            extras.get("n_clients", m_eff))
        aux = RoundAux(eta_g=eta, eta_naive=naive, eta_target=target,
                       update_norm=eta * jnp.linalg.norm(stats.cbar))
        return w + eta * stats.cbar, aux, state


@dataclasses.dataclass(frozen=True)
class ServerOpt(GlobalStep):
    """FedOpt servers (Reddi et al. 2021): Adam / momentum over the released
    pseudo-gradient — the extra-hyperparameter family the paper argues
    against, kept for the E6 ablation and now composable with ANY mechanism
    (e.g. LDP-Gaussian + server Adam)."""

    kind: str = "adam"
    lr: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8

    stateful = True

    def __post_init__(self):
        from repro import optim
        if self.kind == "adam":
            opt = optim.adam(lr=self.lr, b1=self.beta1, b2=self.beta2, eps=self.eps)
        elif self.kind == "momentum":
            opt = optim.momentum(lr=self.lr, beta=self.beta1)
        else:
            raise ValueError(f"unknown ServerOpt kind {self.kind!r}")
        object.__setattr__(self, "_opt", opt)

    def init(self, w):
        """Initial step-owned carry state (optimizer moments / clip threshold)."""
        return self._opt.init(w)

    def apply(self, extra_keys, w, stats, extras, mechanism, clip, m_eff, state):
        """Apply this server-update policy to the released round statistics."""
        step, state = self._opt.update(stats.cbar, state)
        return w + step, RoundAux(eta_g=jnp.float32(self.lr)), state


@dataclasses.dataclass(frozen=True)
class AdaptiveClipStep(GlobalStep):
    """Quantile-tracked clipping (Andrew et al. 2021) composed over any
    mechanism: the clip threshold C lives in the carry, overrides the
    mechanism's static threshold each round (a TRACED scalar — the kernel
    backend prefetches it, no recompiles), and updates from the privatized
    below-threshold bit sum.  The step size is the mechanism's extrapolation
    rule read at the CURRENT C (for CentralGaussian(z_mult=z) that is the
    hyperparameter-free sigma_xi = d (zC)^2 / m of §3.2)."""

    c0: float = 1.0
    gamma: float = 0.5
    clip_lr: float = 0.2
    sigma_b: float = 10.0

    stateful = True
    needs_clip_bits = True
    uses_extrapolation = True

    def n_extra_keys(self, mechanism):
        """PRNG streams beyond the mechanism's to split off the round key."""
        return (1 if mechanism.needs_xi_key else 0) + 1

    def clip_override(self, state):
        """Traced per-round clip threshold from the carry; None = mechanism's static."""
        return state.clip

    def init(self, w):
        """Initial step-owned carry state (optimizer moments / clip threshold)."""
        from repro.core import adaptive_clip as ac
        return ac.init_state(self.c0)

    def apply(self, extra_keys, w, stats, extras, mechanism, clip, m_eff, state):
        """Apply this server-update policy to the released round statistics."""
        from repro.core import adaptive_clip as ac
        if len(extra_keys) == 2:
            k_xi, k_bit = extra_keys
        else:
            k_xi, (k_bit,) = None, extra_keys
        c = state.clip
        # quantile tracking and realized-cohort noise run on the CLIENT
        # count; weighted compositions deliver it separately because their
        # moment count is a weight sum (extras["n_clients"]); everywhere
        # else m_eff IS the client count — the monolithic classes' exact arg
        m_clients = extras.get("n_clients", m_eff)
        eta, _, _ = mechanism.extrapolation(
            k_xi, stats, extras, w.shape[-1], clip, m_clients)
        cfg = ac.AdaptiveClipConfig(gamma=self.gamma, lr=self.clip_lr,
                                    sigma_b=self.sigma_b)
        state, _ = ac.update_clip_from_stats(k_bit, state,
                                             extras["count_below"],
                                             m_clients, cfg)
        aux = RoundAux(eta_g=eta, update_norm=c)   # report the clip used
        return w + eta * stats.cbar, aux, state


# ---------------------------------------------------------------------------
# The composed server algorithm
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CompressionCarry:
    """Server carry of an error-feedback compressed composition (§16).

    Wraps the step's own state with the (d,) EF residual so EF rides the
    engines' existing scan/stream/checkpoint carry unchanged; every state
    touchpoint in ComposedAlgorithm unwraps ``inner`` before the step sees
    it.  Only built when the aggregation layer asks for error feedback —
    every other composition's carry shape is untouched.
    """

    ef: jax.Array
    inner: object


@dataclasses.dataclass(frozen=True)
class ComposedAlgorithm(ServerAlgorithm):
    """mechanism x aggregation x step as one engine-facing ServerAlgorithm.

    Frozen and hashable by configuration (every layer is a frozen dataclass),
    so compositions key the engine's compile cache exactly like the
    monolithic classes.  Unknown attributes forward to the layers
    (``alg.sigma_xi`` -> ``mechanism.sigma_xi``), preserving the monolithic
    classes' attribute surface.
    """

    mechanism: PrivacyMechanism
    step: GlobalStep
    aggregation: Aggregation = MeanAggregation()
    name: str = "composed"

    def __post_init__(self):
        if (self.aggregation.is_compressed
                and not self.mechanism.supports_compression):
            raise ValueError(
                f"{self.name!r} composes {type(self.mechanism).__name__} with "
                f"{type(self.aggregation).__name__}, but an LDP mechanism "
                "releases a full R^d vector per client — its noise is drawn "
                "BEFORE aggregation, so there is no sound compressed release "
                "(DESIGN.md §16).  Use CentralGaussian (noise is added to the "
                "compressed aggregate) or NoPrivacy, or drop the compression "
                "layer.")

    @property
    def is_private(self):
        """Whether the composed release carries a DP guarantee (the mechanism's)."""
        return self.mechanism.is_private

    @property
    def supports_static_count(self):
        """False for weighted aggregation: the moment count is a weight sum, not M."""
        return not self.aggregation.is_weighted

    @property
    def needs_round_index(self):
        """True when the mechanism is a genuinely varying NoiseSchedule —
        the engines thread the round index t into the round calls only then,
        so every fixed-noise composition keeps its exact pre-§17 trace."""
        return getattr(self.mechanism, "is_round_indexed", False)

    def _mech_at(self, t):
        """The mechanism executing this round: ``at_round(t)`` resolution for
        round-indexed mechanisms (traced sigma(t)), the mechanism itself —
        or a constant schedule's inner — otherwise."""
        if self.needs_round_index:
            if t is None:
                raise ValueError(
                    f"{self.name!r} carries a round-indexed noise schedule "
                    "but the engine did not thread the round index t into "
                    "this call")
            return self.mechanism.at_round(t)
        return self.mechanism.at_round(None)

    def comm_floats(self, d: int) -> int:
        """The §16 communication model: floats one client uploads / the round
        collective reduces — the aggregation layer's vector payload (d dense,
        k rand-k, width*depth sketch) + the three scalar moments + any
        psummed scalar extras (PrivUnit's sum_s_hat, the clip-bit count,
        weighted aggregation's client count)."""
        n = self.aggregation.comm_floats(d) + 3
        n += self.mechanism.n_scalar_extras
        if self.step.needs_clip_bits:
            n += 1                      # count_below rides the reduction
        if self.aggregation.is_weighted:
            n += 1                      # n_clients rides next to the weight sum
        return n

    def __getattr__(self, item):
        if item.startswith("__"):
            raise AttributeError(item)
        d = object.__getattribute__(self, "__dict__")
        for layer in ("mechanism", "step", "aggregation"):
            obj = d.get(layer)
            if obj is not None and hasattr(obj, item):
                return getattr(obj, item)
        raise AttributeError(
            f"{type(self).__name__} {d.get('name')!r} has no attribute {item!r}")

    # -- key / clip plumbing ----------------------------------------------

    def _split_keys(self, key):
        """(mechanism key, step extra keys) — the monolithic classes' exact
        splits: none unless the step needs xi and/or clip-bit streams."""
        n = self.step.n_extra_keys(self.mechanism)
        if n == 0:
            return key, ()
        ks = jax.random.split(key, n + 1)
        return ks[0], tuple(ks[i] for i in range(1, n + 1))

    # -- compression plumbing (DESIGN.md §16) -------------------------------

    def _inner_state(self, state):
        """The step's own carry, unwrapped from an EF CompressionCarry."""
        return state.inner if isinstance(state, CompressionCarry) else state

    def _round_plan(self, key, d):
        """This round's shared compression plan: derived from the ROUND key
        (fold_in with COMPRESS_TAG, outside every client-index stream), so
        shards, stream chunks, and the replicated finalize all rebuild the
        identical tables — the precondition for compressed additivity."""
        return self.aggregation.plan(
            jax.random.fold_in(key, compression.COMPRESS_TAG), d)

    def _compress_row_bound(self, clip):
        """L2 re-clip bound for compressed rows: sens_factor * C for private
        mechanisms whose compressor can grow a row (count-sketch); None when
        nothing binds (no clipping, or a contraction compressor)."""
        if not self.mechanism.is_private:
            return None
        sf = self.aggregation.sens_factor
        if sf <= 1.0:
            return None
        return sf * self.mechanism._clip(clip)

    # -- engine interface --------------------------------------------------

    def init_state(self, w):
        """Initial optimizer/clip carry for a run starting from ``w``."""
        inner = self.step.init(w)
        if self.aggregation.uses_error_feedback:
            return CompressionCarry(ef=jnp.zeros_like(w), inner=inner)
        return inner

    def apply_round_stateful(self, key, w, raw_deltas, state, t=None):
        """Stateful dense round: ``apply_round`` threading the optimizer/clip carry."""
        clip = self.step.clip_override(self._inner_state(state))
        k_mech, extra = self._split_keys(key)
        mech_t = self._mech_at(t)
        m = raw_deltas.shape[0]
        if self.aggregation.is_weighted or self.aggregation.is_compressed:
            # weighted and compressed compositions route the dense round
            # through the moment machinery (the reweighting / the compressed
            # partial sum live there).  The compressed route passes mask=None:
            # full participation needs no gate, and the all-ones where pass
            # is an O(M*d) tax the compressed path exists to shed (compression
            # excludes weighted and LDP aggregations, so only the None-aware
            # reductions ever see it); weighted aggregation keeps the ones
            # mask — its mechanisms index the mask directly.
            mask = (None if self.aggregation.is_compressed
                    else jnp.ones((m,), jnp.float32))
            moments = self.local_moments(key, w, raw_deltas, mask, 0, state,
                                         t=t)
            return self.apply_from_moments(key, w, moments, state, t=t)
        stats, extras = mech_t.release(k_mech, raw_deltas, clip, float(m))
        if self.step.needs_clip_bits:
            norms = jnp.linalg.norm(raw_deltas, axis=-1)
            extras = dict(extras)
            extras["count_below"] = jnp.sum((norms <= clip).astype(jnp.float32))
        return self.step.apply(extra, w, stats, extras, mech_t, clip,
                               float(m), state)

    def apply_round(self, key, w, raw_deltas, t=None):
        """One dense server round: ``(key, w, (M, d) raw deltas) -> (w_next, RoundAux)``."""
        if self.step.stateful:
            raise TypeError(f"{self.name} is stateful; use apply_round_stateful")
        w_next, aux, _ = self.apply_round_stateful(key, w, raw_deltas, (), t=t)
        return w_next, aux

    def local_moments(self, key, w, deltas, mask, start, state, t=None):
        """Shard/chunk-local partial sums of this algorithm's release (SUMS, psum-able)."""
        clip = self.step.clip_override(self._inner_state(state))
        mech_t = self._mech_at(t)
        weights = self.aggregation.row_weights(start, deltas.shape[0])
        # split exactly as the dense path does, so per-client randomness
        # (LDP noise rows, PrivUnit keys) is identical on every engine even
        # when the step reserves extra streams (e.g. PrivUnit x adaptive
        # clip).  For the monolithic-parity names this is the raw key
        # (no-split steps) or a key their mechanisms never read (CDP).
        k_mech, _ = self._split_keys(key)
        if self.aggregation.is_compressed:
            plan = self._round_plan(key, deltas.shape[-1])
            mom, extras = mech_t.moments(
                k_mech, deltas, mask, start, clip, weights,
                compress_fn=self.aggregation.compress_fn(plan),
                compress_row_bound=self._compress_row_bound(clip))
        else:
            mom, extras = mech_t.moments(k_mech, deltas, mask, start,
                                         clip, weights)
        if self.step.needs_clip_bits:
            norms = jnp.linalg.norm(deltas, axis=-1)
            below = (norms <= clip).astype(jnp.float32)
            extras = dict(extras)
            extras["count_below"] = (jnp.sum(below) if mask is None
                                     else mask @ below)
        if self.aggregation.is_weighted:
            # under weighted aggregation mom.count is a weight SUM; the
            # clip-quantile update and any realized-cohort noise need the
            # true participating-CLIENT count (psums additively)
            extras = dict(extras)
            extras["n_clients"] = (jnp.float32(deltas.shape[0])
                                   if mask is None else jnp.sum(mask))
        return mom, extras

    def apply_from_moments(self, key, w, moments, state, t=None):
        """Server update from the globally reduced moments (replicated math)."""
        mom, extras = moments
        inner = self._inner_state(state)
        clip = self.step.clip_override(inner)
        k_mech, extra = self._split_keys(key)
        mech_t = self._mech_at(t)
        # realized cohort size for mechanism noise: the CLIENT count, which
        # weighted compositions carry in extras (mom.count is their weight
        # sum); everywhere else mom.count is exactly it
        m_eff = extras.get("n_clients", mom.count) if isinstance(extras, dict) \
            else mom.count
        if self.aggregation.is_compressed:
            return self._apply_compressed(key, k_mech, extra, w, mom, extras,
                                          clip, m_eff, state, mech_t)
        stats, more = mech_t.finalize(k_mech, mom, extras, clip, m_eff)
        if more:
            extras = {**extras, **more}
        return self.step.apply(extra, w, stats, extras, mech_t, clip,
                               mom.count, state)

    def apply_round_sharded(self, key, w, deltas, mask, state, axis_name,
                            m_total=None, t=None):
        """Sharded round with the round index threaded into both halves
        (the base implementation is otherwise unchanged — DESIGN.md §9)."""
        start = jax.lax.axis_index(axis_name) * deltas.shape[0]
        moments = self.local_moments(key, w, deltas, mask, start, state, t=t)
        moments = jax.lax.psum(moments, axis_name)
        if m_total is not None and self.supports_static_count:
            moments = set_moment_count(moments, m_total)
        return self.apply_from_moments(key, w, moments, state, t=t)

    def _apply_compressed(self, key, k_mech, extra, w, mom, extras, clip,
                          m_eff, state, mech_t):
        """Compressed finalize (DESIGN.md §16): noise in the compressed
        domain -> decompress -> error feedback -> support selection -> step.

        The mechanism's dense ``finalize`` is bypassed — its noise shape is
        (d,) and its agg_sq would be a compressed-domain norm.  Here the
        scalar moments pass through UNCOMPRESSED (they are the dense clipped
        values by construction of the moment path), central noise is added
        per compressed cell with the sens_factor-scaled std, and ``agg_sq``
        is the norm of the actually-applied (d,) estimate.
        """
        inner = self._inner_state(state)
        d = w.shape[-1]
        plan = self._round_plan(key, d)
        comp_mean = mom.sum_c / mom.count
        noise = mech_t.compressed_noise(
            k_mech, comp_mean.shape, clip, m_eff, self.aggregation.sens_factor)
        if noise is not None:
            comp_mean = comp_mean + noise
        g = self.aggregation.decompress(comp_mean, plan, d)
        if self.aggregation.uses_error_feedback:
            corrected = g + state.ef
            applied = self.aggregation.select(corrected)
            ef_next = corrected - applied
        else:
            applied = self.aggregation.select(g)
            ef_next = None
        stats = RoundStats(cbar=applied,
                           mean_sq=mom.sum_sq / mom.count,
                           agg_sq=jnp.sum(jnp.square(applied)),
                           mean_sq_clipped=mom.sum_sq_clipped / mom.count)
        w_next, aux, inner_next = self.step.apply(
            extra, w, stats, extras, mech_t, clip, mom.count, inner)
        if ef_next is not None:
            return w_next, aux, CompressionCarry(ef=ef_next, inner=inner_next)
        return w_next, aux, inner_next

    # -- accounting --------------------------------------------------------

    def budget(self, delta: float, *, rounds: int, dim: int,
               sampling_q: float = 1.0) -> accounting.PrivacyReport:
        """Privacy budget of a ``rounds``-round run of this composition —
        the mechanism's accounting hook, told whether the step also releases
        the privatized FedEXP numerator (DESIGN.md §11)."""
        if not self.mechanism.is_private:
            raise ValueError(f"{self.name!r} is not a private algorithm")
        if self.step.needs_clip_bits and not self.mechanism.clip_independent_budget:
            # a fixed-sigma mechanism under an adaptive clip override has a
            # sensitivity/noise ratio that MOVES with the traced C; reporting
            # the static-clip_norm figure would be silently unsound
            raise ValueError(
                f"{self.name!r} composes a fixed-noise mechanism with adaptive "
                "clipping: its per-round guarantee tracks the realized clip "
                "threshold and has no static budget.  Use CentralGaussian("
                "z_mult=...) (noise tracks C) or PrivUnitLDP (pure-DP, "
                "C-independent) under AdaptiveClipStep.")
        with_num = self.step.uses_extrapolation and self.mechanism.needs_xi_key
        return self.mechanism.budget(delta, rounds=rounds, dim=dim,
                                     sampling_q=sampling_q,
                                     with_numerator=with_num)


def with_compression(alg: ComposedAlgorithm,
                     aggregation: Aggregation) -> ComposedAlgorithm:
    """A compressed variant of an existing composition (DESIGN.md §16).

    Swaps the aggregation layer and re-runs composition validation (LDP
    mechanisms reject compression with an actionable error), deriving a
    ``<name>+<layer>`` name so benchmark/telemetry output distinguishes the
    variants.  The mechanism and step are untouched — clip thresholds, key
    splits, and the budget accounting are exactly the base composition's.
    """
    if not isinstance(alg, ComposedAlgorithm):
        raise TypeError(
            f"with_compression needs a ComposedAlgorithm, got {type(alg).__name__}")
    if alg.aggregation.is_weighted:
        raise ValueError(
            f"{alg.name!r} uses weighted aggregation; replacing it with "
            f"{type(aggregation).__name__} would silently drop the per-client "
            "weights.  Compose a weighted-and-compressed layer explicitly if "
            "that is intended.")
    if isinstance(aggregation, RandKAggregation):
        tag = f"randk{aggregation.k}"
    elif isinstance(aggregation, CountSketchAggregation):
        tag = f"sketch{aggregation.width}x{aggregation.depth}"
        if aggregation.top_k is not None:
            tag += f"-top{aggregation.top_k}"
        if aggregation.error_feedback:
            tag += "-ef"
    else:
        tag = type(aggregation).__name__.lower()
    return dataclasses.replace(alg, aggregation=aggregation,
                               name=f"{alg.name}+{tag}")


def compose_algorithm(mechanism: PrivacyMechanism, step: GlobalStep,
                      aggregation: Aggregation | None = None,
                      *, name: str | None = None) -> ComposedAlgorithm:
    """Build a ComposedAlgorithm with a derived name when none is given."""
    agg = MeanAggregation() if aggregation is None else aggregation
    if name is None:
        parts = [type(mechanism).__name__.lower(), type(step).__name__.lower()]
        if agg.is_weighted:
            parts.insert(1, "weighted")
        name = "-".join(parts)
    return ComposedAlgorithm(mechanism=mechanism, step=step, aggregation=agg,
                             name=name)
