"""Server-algorithm base contract shared by the monolithic classes and the
composable stack.

``ServerAlgorithm`` is the engine-facing interface (DESIGN.md §8/§9): a round
is either one dense call (``apply_round`` / ``apply_round_stateful``) or the
two sharded halves (``local_moments`` -> psum -> ``apply_from_moments``).
This module holds that contract plus the moment-count helpers and the
per-client key derivation — everything both ``repro.core.fedexp`` (the legacy
monolithic algorithms) and ``repro.core.compose`` (the mechanism x
aggregation x step compositions) depend on, so neither imports the other.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.aggregation import RoundMoments, global_client_indices

__all__ = [
    "RoundAux",
    "ServerAlgorithm",
    "client_keys",
    "set_moment_count",
    "clamp_moment_counts",
]


def _map_moments(moments, fix):
    """Apply ``fix`` to every RoundMoments in an algorithm's moments pytree
    (a bare RoundMoments or a (RoundMoments, extras) tuple)."""
    def one(x):
        """Apply ``fix`` when the element is a RoundMoments, else pass through."""
        return fix(x) if isinstance(x, RoundMoments) else x

    if isinstance(moments, tuple):
        return tuple(one(e) for e in moments)
    return one(moments)


def set_moment_count(moments, m_total: int):
    """Swap the traced client count for its statically-known value in every
    RoundMoments of an algorithm's moments pytree.

    Used when the true count is known at trace time (the full cohort size on
    the sharded path, the fixed cohort size on the sampled path): the static
    constant lets XLA fold the 1/M normalizations exactly as the unsampled
    single-device reference does, keeping engines bit-compatible (see
    ``ServerAlgorithm.apply_round_sharded``)."""
    c = jnp.float32(m_total)
    return _map_moments(moments, lambda x: dataclasses.replace(x, count=c))


def clamp_moment_counts(moments, floor: float = 1.0):
    """Clamp every RoundMoments count to >= ``floor``.

    Bernoulli cohort sampling can draw an empty round; with all sums already
    zero, a clamped count turns the 0/0 mean into a zero update (the round is
    a no-op) instead of NaN-poisoning the carry.  Weighted-aggregation
    counts are weight SUMS (legitimately < 1), so the engine clamps those
    with a tiny ``floor`` that only guards the empty round — clamping to 1
    would silently rescale every light-cohort mean."""
    return _map_moments(
        moments,
        lambda x: dataclasses.replace(x, count=jnp.maximum(x.count, floor)))


def client_keys(key: jax.Array, m: int, start: int | jax.Array = 0) -> jax.Array:
    """(m,) per-client PRNG keys: row i is ``fold_in(key, start + i)``.

    Keyed by GLOBAL client index so a client shard derives exactly its own
    clients' keys (pass ``start = shard_index * m_local``) and the sharded
    release reproduces the single-device randomization bit-for-bit.  A (m,)
    vector ``start`` names the global index of each row directly (the
    sparse-gather path, DESIGN.md §14).
    """
    idx = global_client_indices(start, m)
    return jax.vmap(lambda i: jax.random.fold_in(key, i))(idx)


@dataclasses.dataclass
class RoundAux:
    """Diagnostics for one round (logged by fedsim / benchmarks).

    Every field is a fixed-shape scalar array: diagnostics an algorithm does
    not produce are NaN, NOT None, so one round is scan-compatible (the
    engine stacks these across rounds without Python-level branching).
    """

    eta_g: jax.Array
    eta_naive: jax.Array | None = None   # Eq. (3), for the Fig. 2 ablation
    eta_target: jax.Array | None = None  # Eq. (5), oracle diagnostic
    update_norm: jax.Array | None = None

    def __post_init__(self):
        for f in ("eta_naive", "eta_target", "update_norm"):
            if getattr(self, f) is None:
                setattr(self, f, jnp.float32(jnp.nan))


class ServerAlgorithm:
    """Base class; subclasses set `name` and implement apply_round.

    Stateless algorithms implement ``apply_round``; stateful servers (the
    FedOpt family — server Adam/momentum over pseudo-gradients) override
    ``init_state`` / ``apply_round_stateful``, which the training loop
    threads through its carry. Default wrappers keep the two interchangeable.

    Sharded-round protocol (DESIGN.md §9).  A round is also expressible as
    two halves the client-sharded engine splits across the ``clients`` mesh
    axis:

        local_moments(key, w, deltas, mask, start, state)  -> pytree of SUMS
        apply_from_moments(key, w, global_moments, state)  -> (w', aux, state)

    ``local_moments`` runs per-device on that shard's (m_local, d) slice of
    the cohort (``start`` = global index of its first client, ``mask``
    zero-weights padding rows) and returns only partial sums; the engine
    ``psum``s them and every device applies the identical server update —
    noise is drawn AFTER the reduction from the replicated round key, so DP
    semantics match the single-device path exactly.
    """

    name: str = "base"
    is_private: bool = True
    # set_moment_count / fixed-size-count substitution is valid: the count of
    # a RoundMoments really is the number of participating clients.  The
    # weighted-aggregation compositions (count = sum of client weights) set
    # this False and the engine leaves their counts traced (DESIGN.md §11).
    supports_static_count: bool = True

    def apply_round(self, key: jax.Array, w: jax.Array, raw_deltas: jax.Array):
        """One dense server round: ``(key, w, (M, d) raw deltas) -> (w_next, RoundAux)``."""
        raise NotImplementedError

    def comm_floats(self, d: int) -> int:
        """Floats of per-round reduced state (the communication model,
        DESIGN.md §16): what one client uploads and the round collective
        moves — ``sum_c`` plus the three scalar moments by default.
        Compressed compositions override this with their O(k) /
        O(width·depth) payload; the telemetry tap reports
        ``4 * comm_floats(d)`` as ``bytes_per_round``."""
        return d + 3

    def init_state(self, w: jax.Array):
        """Initial optimizer/clip carry for a run starting from ``w``."""
        return ()

    def apply_round_stateful(self, key, w, raw_deltas, state):
        """Stateful dense round: ``apply_round`` threading the optimizer/clip carry."""
        w_next, aux = self.apply_round(key, w, raw_deltas)
        return w_next, aux, state

    def local_moments(self, key, w, deltas, mask, start, state):
        """Shard-local partial sums (a psum-able pytree; SUMS, never means)."""
        raise NotImplementedError(f"{self.name} has no sharded-round support")

    def apply_from_moments(self, key, w, moments, state):
        """Server update from globally-reduced moments; replicated math."""
        raise NotImplementedError(f"{self.name} has no sharded-round support")

    def apply_round_sharded(self, key, w, deltas, mask, state, axis_name,
                            m_total: int | None = None):
        """One round on a client shard (call inside ``shard_map``).

        ``m_total`` is the STATIC true client count when the caller knows it
        (the engine always does — it built the padding mask).  Replacing the
        psummed mask-sum with the static constant lets XLA fold the 1/M
        normalizations exactly as the single-device reference's static
        ``sum / m`` does, keeping the two engines bit-compatible instead of
        one ULP apart."""
        start = jax.lax.axis_index(axis_name) * deltas.shape[0]
        moments = self.local_moments(key, w, deltas, mask, start, state)
        moments = jax.lax.psum(moments, axis_name)
        if m_total is not None and self.supports_static_count:
            moments = set_moment_count(moments, m_total)
        return self.apply_from_moments(key, w, moments, state)
