"""Server-side aggregation of client updates + the FedEXP round statistics.

The server consumes the (possibly randomized) client updates ``c_i`` and needs
exactly three reductions per round (Algorithms 1 & 2):

    cbar      = (1/M) sum_i c_i                  -- the pseudo-gradient
    mean_sq   = (1/M) sum_i ||c_i||^2            -- FedEXP numerator statistic
    agg_sq    = ||cbar||^2                       -- FedEXP denominator

``aggregate_stats`` is the jnp reference; ``fused_clip_aggregate`` performs
clip -> (optional noise) -> the three reductions and routes between backends
(see DESIGN.md §5 and §8):

    "jnp"          one elementwise pass + BLAS reductions.  The column sum is
                   expressed as ``ones @ u`` because XLA:CPU's strided
                   axis-0 reduce runs ~15x below memcpy bandwidth while the
                   BLAS matvec saturates it; the per-row square norms use the
                   contiguous axis-1 reduce.  This is the cross-backend
                   fallback and the oracle for the kernel tests.
    "kernel"       the fused Pallas ``dp_aggregate`` kernel (one pass over
                   HBM; compiled on TPU, interpret elsewhere), with the
                   LDP noise matrix materialized by the caller or from
                   ``noise_key``.
    "kernel-fused" the same kernel drawing the Gaussian noise *inside* the
                   kernel (per-block PRNG, DESIGN.md §8), eliminating the
                   (M, d) noise write+read from HBM entirely.
    "auto"         kernel-fused (when noise is requested) or kernel on TPU;
                   the tuned jnp path on CPU/GPU, where interpret-mode Pallas
                   cannot beat BLAS.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["RoundStats", "aggregate_stats", "fused_clip_aggregate", "resolve_backend"]

_EPS = 1e-12


@dataclasses.dataclass
class RoundStats:
    """Aggregate statistics of one federated round (all scalars but cbar)."""

    cbar: jax.Array           # (d,) mean of released updates
    mean_sq: jax.Array        # scalar, mean_i ||c_i||^2
    agg_sq: jax.Array         # scalar, ||cbar||^2
    mean_sq_clipped: jax.Array | None = None  # mean_i ||Delta_i||^2 (pre-noise; CDP only)


def _colmean(updates: jax.Array) -> jax.Array:
    """Column mean via matvec: XLA:CPU's axis-0 reduce is ~15x slower."""
    m = updates.shape[0]
    ones = jnp.ones((m,), jnp.float32)
    return (ones @ updates) / m


def aggregate_stats(updates: jax.Array) -> RoundStats:
    """Reference reductions over an ``(M, d)`` matrix of released updates."""
    cbar = _colmean(updates)
    mean_sq = jnp.mean(jnp.sum(jnp.square(updates), axis=-1))
    agg_sq = jnp.sum(jnp.square(cbar))
    return RoundStats(cbar=cbar, mean_sq=mean_sq, agg_sq=agg_sq)


def resolve_backend(backend: str | None, *, wants_noise_gen: bool = False) -> str:
    """Map "auto"/None to a concrete backend for the current JAX platform."""
    if backend in (None, "auto"):
        if jax.default_backend() == "tpu":
            return "kernel-fused" if wants_noise_gen else "kernel"
        return "jnp"
    return backend


def fused_clip_aggregate(
    raw_updates: jax.Array,
    clip_norm,
    noise: jax.Array | None = None,
    *,
    noise_key: jax.Array | None = None,
    noise_sigma=None,
    backend: str = "auto",
    use_kernel: bool = False,
    interpret: bool | None = None,
    block_m: int | None = None,
) -> RoundStats:
    """Clip rows to L2 <= C, optionally add per-client noise, and reduce.

    Args:
      raw_updates: (M, d) raw client updates.
      clip_norm: clipping threshold C (python float or traced scalar).
      noise: optional pre-materialized (M, d) noise matrix (LDP Gaussian);
        None for CDP (noise is added to the *mean* by the caller, which needs
        ``mean_sq_clipped``).
      noise_key: PRNG key for LDP Gaussian noise of std ``noise_sigma``;
        the backend decides whether to materialize it (jnp / kernel) or draw
        it inside the kernel (kernel-fused).  Mutually exclusive with
        ``noise``.
      noise_sigma: noise std (python float or traced scalar), with noise_key.
      backend: "auto" | "jnp" | "kernel" | "kernel-fused" (see module doc).
      use_kernel: legacy alias for backend="kernel".
      interpret: run the Pallas kernel in interpreter mode; None = auto
        (interpret everywhere but TPU).
      block_m: kernel row-block size; None = shape-based heuristic.

    Returns RoundStats where ``mean_sq`` is computed on the *released* c_i
    (post-noise if noise given) and ``mean_sq_clipped`` on the clipped
    deltas (pre-noise).
    """
    if noise is not None and noise_key is not None:
        raise ValueError("pass either a materialized `noise` or `noise_key`, not both")
    if noise_key is not None and noise_sigma is None:
        # without this, the kernel-fused path would default sigma to 0 and
        # silently release UN-noised updates — a privacy-guarantee violation
        raise ValueError("`noise_key` requires `noise_sigma`")
    wants_noise_gen = noise_key is not None
    if use_kernel and backend == "auto":
        backend = "kernel"
    backend = resolve_backend(backend, wants_noise_gen=wants_noise_gen)

    if backend in ("kernel", "kernel-fused"):
        from repro.kernels.dp_aggregate import ops as _ops

        if backend == "kernel" and wants_noise_gen:
            noise = noise_sigma * jax.random.normal(
                noise_key, raw_updates.shape, raw_updates.dtype)
            noise_key = None
        return _ops.dp_aggregate(
            raw_updates, clip_norm, noise,
            noise_key=noise_key if backend == "kernel-fused" else None,
            noise_sigma=noise_sigma if backend == "kernel-fused" else None,
            interpret=interpret, block_m=block_m)

    if backend != "jnp":
        raise ValueError(f"unknown aggregation backend {backend!r}")

    if wants_noise_gen:
        noise = noise_sigma * jax.random.normal(noise_key, raw_updates.shape,
                                                raw_updates.dtype)
    sq_norms = jnp.sum(jnp.square(raw_updates), axis=-1)      # contiguous reduce
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(jnp.sqrt(sq_norms), _EPS))
    clipped = raw_updates * scale[:, None]
    mean_sq_clipped = jnp.mean(sq_norms * jnp.square(scale))
    if noise is None:
        released = clipped
        mean_sq = mean_sq_clipped
    else:
        released = clipped + noise
        mean_sq = jnp.mean(jnp.sum(jnp.square(released), axis=-1))
    cbar = _colmean(released)
    return RoundStats(
        cbar=cbar,
        mean_sq=mean_sq,
        agg_sq=jnp.sum(jnp.square(cbar)),
        mean_sq_clipped=mean_sq_clipped,
    )
