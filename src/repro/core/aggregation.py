"""Server-side aggregation of client updates + the FedEXP round statistics.

The server consumes the (possibly randomized) client updates ``c_i`` and needs
exactly three reductions per round (Algorithms 1 & 2):

    cbar      = (1/M) sum_i c_i                  -- the pseudo-gradient
    mean_sq   = (1/M) sum_i ||c_i||^2            -- FedEXP numerator statistic
    agg_sq    = ||cbar||^2                       -- FedEXP denominator

``aggregate_stats`` is the pure-jnp reference; ``fused_clip_aggregate``
performs clip -> (optional noise) -> the three reductions in one pass and can
be served by the Pallas TPU kernel ``repro.kernels.dp_aggregate`` (the naive
composition makes three passes over the (M, d) update matrix; the fused kernel
makes one — see DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["RoundStats", "aggregate_stats", "fused_clip_aggregate"]

_EPS = 1e-12


@dataclasses.dataclass
class RoundStats:
    """Aggregate statistics of one federated round (all scalars but cbar)."""

    cbar: jax.Array           # (d,) mean of released updates
    mean_sq: jax.Array        # scalar, mean_i ||c_i||^2
    agg_sq: jax.Array         # scalar, ||cbar||^2
    mean_sq_clipped: jax.Array | None = None  # mean_i ||Delta_i||^2 (pre-noise; CDP only)


def aggregate_stats(updates: jax.Array) -> RoundStats:
    """Reference reductions over an ``(M, d)`` matrix of released updates."""
    cbar = jnp.mean(updates, axis=0)
    mean_sq = jnp.mean(jnp.sum(jnp.square(updates), axis=-1))
    agg_sq = jnp.sum(jnp.square(cbar))
    return RoundStats(cbar=cbar, mean_sq=mean_sq, agg_sq=agg_sq)


def fused_clip_aggregate(
    raw_updates: jax.Array,
    clip_norm: float,
    noise: jax.Array | None = None,
    *,
    use_kernel: bool = False,
    interpret: bool = True,
) -> RoundStats:
    """Clip rows to L2 <= C, optionally add per-client noise, and reduce.

    Args:
      raw_updates: (M, d) raw client updates.
      clip_norm: clipping threshold C.
      noise: optional (M, d) noise matrix (LDP Gaussian); None for CDP (noise
        is added to the *mean* by the caller, which needs ``mean_sq_clipped``).
      use_kernel: route through the Pallas ``dp_aggregate`` kernel.
      interpret: run the kernel in interpreter mode (CPU container).

    Returns RoundStats where ``mean_sq`` is computed on the *released* c_i
    (post-noise if noise given) and ``mean_sq_clipped`` on the clipped
    deltas (pre-noise).
    """
    if use_kernel:
        from repro.kernels.dp_aggregate import ops as _ops

        return _ops.dp_aggregate(raw_updates, clip_norm, noise, interpret=interpret)

    norms = jnp.linalg.norm(raw_updates, axis=-1)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(norms, _EPS))
    clipped = raw_updates * scale[:, None]
    mean_sq_clipped = jnp.mean(jnp.sum(jnp.square(clipped), axis=-1))
    released = clipped if noise is None else clipped + noise
    stats = aggregate_stats(released)
    stats.mean_sq_clipped = mean_sq_clipped
    return stats
