"""Server-side aggregation of client updates + the FedEXP round statistics.

The server consumes the (possibly randomized) client updates ``c_i`` and needs
exactly three reductions per round (Algorithms 1 & 2):

    cbar      = (1/M) sum_i c_i                  -- the pseudo-gradient
    mean_sq   = (1/M) sum_i ||c_i||^2            -- FedEXP numerator statistic
    agg_sq    = ||cbar||^2                       -- FedEXP denominator

``aggregate_stats`` is the jnp reference; ``fused_clip_aggregate`` performs
clip -> (optional noise) -> the three reductions and routes between backends
(see DESIGN.md §5 and §8):

    "jnp"          one elementwise pass + BLAS reductions.  The column sum is
                   expressed as ``ones @ u`` because XLA:CPU's strided
                   axis-0 reduce runs ~15x below memcpy bandwidth while the
                   BLAS matvec saturates it; the per-row square norms use the
                   contiguous axis-1 reduce.  This is the cross-backend
                   fallback and the oracle for the kernel tests.
    "kernel"       the fused Pallas ``dp_aggregate`` kernel (one pass over
                   HBM; compiled on TPU, interpret elsewhere), with the
                   LDP noise matrix materialized by the caller or from
                   ``noise_key``.
    "kernel-fused" the same kernel drawing the Gaussian noise *inside* the
                   kernel (per-block PRNG, DESIGN.md §8), eliminating the
                   (M, d) noise write+read from HBM entirely.
    "auto"         kernel-fused (when noise is requested) or kernel on TPU;
                   the tuned jnp path on CPU/GPU, where interpret-mode Pallas
                   cannot beat BLAS.

Moment-based API (DESIGN.md §9).  The three reductions above are exact sums
over clients, so they decompose over any partition of the cohort:
``partial_clip_moments`` computes one shard's *partial sums* (Σ c_i,
Σ ||c_i||^2, Σ ||Delta_i||^2, Σ mask_i), which the client-sharded engine
``psum``s across the ``clients`` mesh axis before ``RoundMoments.stats``
normalizes them into the same ``RoundStats`` the step-size rules consume.
A ``weight_mask`` row weight (0.0 for padding clients when M % n_shards != 0)
keeps padded rows out of every sum, including the client count.

Streaming (DESIGN.md §12).  The same additivity lets the reductions run in
ROW CHUNKS: ``streamed_clip_moments`` accumulates per-chunk
``partial_clip_moments`` in a ``lax.scan`` carry, bounding the working set
by the chunk size — the in-core form of the decomposition the streaming
cohort engine applies one level higher (per-chunk local training, so the
full (M, d) matrix never materializes at all).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = [
    "RoundStats",
    "RoundMoments",
    "aggregate_stats",
    "fused_clip_aggregate",
    "partial_clip_moments",
    "streamed_clip_moments",
    "raw_moments",
    "global_client_indices",
    "materialize_ldp_noise",
    "resolve_backend",
]

_EPS = 1e-12


def global_client_indices(start, m: int) -> jax.Array:
    """(m,) GLOBAL client indices for a block of m cohort rows.

    Every per-client randomness derivation (LDP noise rows, randomizer keys,
    local-training shuffles) keys by global client index so that any
    partition of the cohort — shards, stream chunks, or a sparse gathered
    block — reproduces the dense single-device draw bit-for-bit.  ``start``
    is either the scalar global index of row 0 (contiguous shard/chunk
    slices: indices are ``start + arange(m)``) or already a (m,) vector of
    global indices (the §14 sparse-gather path, where row j holds client
    ``slots[j]``), which passes through unchanged.
    """
    if getattr(start, "ndim", 0) == 1:
        return start
    return start + jnp.arange(m)


@dataclasses.dataclass
class RoundStats:
    """Aggregate statistics of one federated round (all scalars but cbar)."""

    cbar: jax.Array           # (d,) mean of released updates
    mean_sq: jax.Array        # scalar, mean_i ||c_i||^2
    agg_sq: jax.Array         # scalar, ||cbar||^2
    mean_sq_clipped: jax.Array | None = None  # mean_i ||Delta_i||^2 (pre-noise; CDP only)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class RoundMoments:
    """Per-shard partial sums of one round's release — a psum-able pytree.

    Every field is a SUM over the shard's (mask-weighted) clients, never a
    mean, so moments from different shards combine by addition alone:
    ``psum(local_moments, 'clients')`` is the global moments.
    """

    sum_c: jax.Array           # (d,) sum of released updates
    sum_sq: jax.Array          # scalar, sum_i ||c_i||^2 (post-noise)
    sum_sq_clipped: jax.Array  # scalar, sum_i ||clip(Delta_i)||^2 (pre-noise)
    count: jax.Array           # scalar, sum of row weights (true client count)

    def stats(self) -> RoundStats:
        """Normalize global sums into the RoundStats the stepsize rules eat."""
        return RoundStats(
            cbar=self.sum_c / self.count,
            mean_sq=self.sum_sq / self.count,
            agg_sq=jnp.sum(jnp.square(self.sum_c / self.count)),
            mean_sq_clipped=self.sum_sq_clipped / self.count,
        )


def materialize_ldp_noise(noise_key: jax.Array, m: int, d: int, sigma,
                          dtype=jnp.float32, *, start: int | jax.Array = 0) -> jax.Array:
    """(m, d) per-client LDP Gaussian noise, row i drawn from
    ``fold_in(noise_key, start + i)``.

    Keying rows by GLOBAL client index (not by one (M, d) tensor draw) is what
    lets a client shard materialize exactly its own rows of the cohort noise:
    shard s passes ``start = s * m_local`` and reproduces rows [start, start+m)
    of the single-device matrix bit-for-bit.  Mathematically this is clients
    randomizing locally with independent keys — the form in which the LDP
    guarantee is stated.  ``start`` may also be a (m,) vector of global
    indices (the sparse-gather path, DESIGN.md §14): row j then draws client
    ``start[j]``'s noise.
    """
    idx = global_client_indices(start, m)
    keys = jax.vmap(lambda i: jax.random.fold_in(noise_key, i))(idx)
    rows = jax.vmap(lambda k: jax.random.normal(k, (d,), dtype))(keys)
    return (sigma * rows).astype(dtype)


def _colmean(updates: jax.Array) -> jax.Array:
    """Column mean via matvec: XLA:CPU's axis-0 reduce is ~15x slower."""
    m = updates.shape[0]
    ones = jnp.ones((m,), jnp.float32)
    return (ones @ updates) / m


def aggregate_stats(updates: jax.Array) -> RoundStats:
    """Reference reductions over an ``(M, d)`` matrix of released updates.

    Means are written ``sum / m`` (NOT ``jnp.mean``, which lowers to a
    reciprocal-multiply one ULP away) so they are bit-identical to the
    moment path's psummed-sums-then-divide normalization.
    """
    m = updates.shape[0]
    cbar = _colmean(updates)
    mean_sq = jnp.sum(jnp.sum(jnp.square(updates), axis=-1)) / m
    agg_sq = jnp.sum(jnp.square(cbar))
    return RoundStats(cbar=cbar, mean_sq=mean_sq, agg_sq=agg_sq)


def resolve_backend(backend: str | None, *, wants_noise_gen: bool = False) -> str:
    """Map "auto"/None to a concrete backend for the current JAX platform."""
    if backend in (None, "auto"):
        if jax.default_backend() == "tpu":
            return "kernel-fused" if wants_noise_gen else "kernel"
        return "jnp"
    return backend


def fused_clip_aggregate(
    raw_updates: jax.Array,
    clip_norm,
    noise: jax.Array | None = None,
    *,
    noise_key: jax.Array | None = None,
    noise_sigma=None,
    backend: str = "auto",
    use_kernel: bool = False,
    interpret: bool | None = None,
    block_m: int | None = None,
) -> RoundStats:
    """Clip rows to L2 <= C, optionally add per-client noise, and reduce.

    Args:
      raw_updates: (M, d) raw client updates.
      clip_norm: clipping threshold C (python float or traced scalar).
      noise: optional pre-materialized (M, d) noise matrix (LDP Gaussian);
        None for CDP (noise is added to the *mean* by the caller, which needs
        ``mean_sq_clipped``).
      noise_key: PRNG key for LDP Gaussian noise of std ``noise_sigma``;
        the backend decides whether to materialize it (jnp / kernel) or draw
        it inside the kernel (kernel-fused).  Mutually exclusive with
        ``noise``.
      noise_sigma: noise std (python float or traced scalar), with noise_key.
      backend: "auto" | "jnp" | "kernel" | "kernel-fused" (see module doc).
      use_kernel: legacy alias for backend="kernel".
      interpret: run the Pallas kernel in interpreter mode; None = auto
        (interpret everywhere but TPU).
      block_m: kernel row-block size; None = shape-based heuristic.

    Returns RoundStats where ``mean_sq`` is computed on the *released* c_i
    (post-noise if noise given) and ``mean_sq_clipped`` on the clipped
    deltas (pre-noise).
    """
    if noise is not None and noise_key is not None:
        raise ValueError("pass either a materialized `noise` or `noise_key`, not both")
    if noise_key is not None and noise_sigma is None:
        # without this, the kernel-fused path would default sigma to 0 and
        # silently release UN-noised updates — a privacy-guarantee violation
        raise ValueError("`noise_key` requires `noise_sigma`")
    wants_noise_gen = noise_key is not None
    if use_kernel and backend == "auto":
        backend = "kernel"
    backend = resolve_backend(backend, wants_noise_gen=wants_noise_gen)

    if backend in ("kernel", "kernel-fused"):
        from repro.kernels.dp_aggregate import ops as _ops

        if backend == "kernel" and wants_noise_gen:
            noise = materialize_ldp_noise(noise_key, *raw_updates.shape,
                                          noise_sigma, raw_updates.dtype)
            noise_key = None
        return _ops.dp_aggregate(
            raw_updates, clip_norm, noise,
            noise_key=noise_key if backend == "kernel-fused" else None,
            noise_sigma=noise_sigma if backend == "kernel-fused" else None,
            interpret=interpret, block_m=block_m)

    if backend != "jnp":
        raise ValueError(f"unknown aggregation backend {backend!r}")

    if wants_noise_gen:
        noise = materialize_ldp_noise(noise_key, *raw_updates.shape,
                                      noise_sigma, raw_updates.dtype)
    m = raw_updates.shape[0]
    sq_norms = jnp.sum(jnp.square(raw_updates), axis=-1)      # contiguous reduce
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(jnp.sqrt(sq_norms), _EPS))
    clipped = raw_updates * scale[:, None]
    # sum/m (not jnp.mean) to stay bit-identical to the sharded moment path
    mean_sq_clipped = jnp.sum(sq_norms * jnp.square(scale)) / m
    if noise is None:
        released = clipped
        mean_sq = mean_sq_clipped
    else:
        released = clipped + noise
        mean_sq = jnp.sum(jnp.sum(jnp.square(released), axis=-1)) / m
    cbar = _colmean(released)
    return RoundStats(
        cbar=cbar,
        mean_sq=mean_sq,
        agg_sq=jnp.sum(jnp.square(cbar)),
        mean_sq_clipped=mean_sq_clipped,
    )


def partial_clip_moments(
    raw_updates: jax.Array,
    clip_norm,
    noise: jax.Array | None = None,
    *,
    weight_mask: jax.Array | None = None,
    row_weights: jax.Array | None = None,
    backend: str = "auto",
    interpret: bool | None = None,
    block_m: int | None = None,
    compress_fn=None,
    compress_row_bound=None,
) -> RoundMoments:
    """Shard-local clip -> (optional noise) -> PARTIAL SUMS over the rows.

    The moment-producing half of ``fused_clip_aggregate``: identical
    clip/noise math, but the reductions stay un-normalized sums so shards
    combine by ``psum`` (DESIGN.md §9).  ``noise`` must be materialized by the
    caller (per-client rows via ``materialize_ldp_noise`` with the shard's
    global ``start``) — the in-kernel PRNG path is deliberately excluded here
    because its seed derivation is shard-oblivious: every shard would draw the
    SAME noise block, silently correlating "independent" client randomizers.

    ``weight_mask`` (float (M,) of {0., 1.}) GATES each row's contribution
    to all four sums; padding rows (mask 0) are zeroed BEFORE the clip so a
    NaN from local training on dummy data cannot poison the reduction.
    KNOWN LIMITATION: a with-replacement multiplicity mask (values > 1,
    ``CohortSpec(replace=True)``) only inflates ``count`` here — repeated
    clients are gated in once, not multiplicity-weighted as
    ``raw_moments``/the PrivUnit moments do (weighting the gated sums is not
    bit-compatible with the plain sums the dense reference lowers to, and
    the kernel's fixed sums cannot row-weight).  Exact multiplicity
    weighting is available through ``row_weights``.

    ``row_weights`` (float (M,), optional) additionally weights each RELEASED
    row multiplicatively — the weighted-aggregation layer (DESIGN.md §11):
    ``sum_c = Σ v_i c_i``, the scalar sums weight per-row, and ``count``
    becomes ``Σ gate_i v_i`` so ``sum_c / count`` is the weighted mean.
    Weighting happens AFTER clip+noise, so each client's DP release is
    untouched; ``None`` is bit-identical to the historical unweighted path.
    Weighted reductions always use the jnp path (the kernel's fixed sums
    don't take per-row weights).

    ``compress_fn`` (optional, DESIGN.md §16) is a LINEAR per-row map
    (..., d) -> (..., kc) — rand-k selection or count-sketch — applied to the
    released rows so ``sum_c`` becomes the (kc,) compressed partial sum while
    the three SCALAR sums stay the dense values (FedEXP's step-size inputs
    are exact under compression).  Linearity lets the clip scales commute:
    the raw rows are compressed once and the per-row scale multiplies the
    (m, kc) compressed block, so the clipped (M, d) matrix never
    materializes — one O(M·d) pass (the row norms) instead of the dense
    path's three.  Per-row ``noise`` is rejected (an LDP release is a full
    R^d vector; compression composes with CENTRAL noise added after the
    reduction) and the kernel backend is bypassed (its fixed sums are
    dense).  ``compress_row_bound`` re-clips each COMPRESSED row to that L2
    bound — the count-sketch sensitivity enforcement (worst-case row growth
    sqrt(depth); the bound is a no-op for rows the sketch didn't inflate).
    """
    m = raw_updates.shape[0]
    backend = resolve_backend(backend)
    if backend == "kernel-fused":   # no key routed here; see docstring
        backend = "kernel"
    if compress_fn is not None:
        if noise is not None:
            raise ValueError(
                "compress_fn cannot combine with per-row (LDP) noise: each "
                "client's release is a full R^d vector, so there is nothing "
                "sound to compress.  Use central noise (added to the "
                "compressed aggregate) or drop the compression layer.")
        backend = "jnp"   # the kernel's fixed dense sums cannot compress
    if row_weights is not None:
        backend = "jnp"
    if weight_mask is not None:
        keep = weight_mask[:, None] > 0
        raw_updates = jnp.where(keep, raw_updates, 0.0)
        if noise is not None:
            noise = jnp.where(keep, noise, 0.0)
        gate = weight_mask
    else:
        gate = jnp.ones((m,), jnp.float32)
    count = (jnp.sum(gate) if row_weights is None
             else jnp.sum(gate * row_weights))
    if weight_mask is None and row_weights is None:
        count = jnp.float32(m)  # static-shape constant, as historically

    if backend == "kernel":
        from repro.kernels.dp_aggregate import ops as _ops

        sum_c, sum_sq, sum_sq_clipped = _ops.dp_aggregate_sums(
            raw_updates, clip_norm, noise, interpret=interpret, block_m=block_m)
        return RoundMoments(sum_c=sum_c, sum_sq=sum_sq,
                            sum_sq_clipped=sum_sq_clipped, count=count)
    if backend != "jnp":
        raise ValueError(f"unknown aggregation backend {backend!r}")

    sq_norms = jnp.sum(jnp.square(raw_updates), axis=-1)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(jnp.sqrt(sq_norms), _EPS))
    if compress_fn is not None:
        # clip commutes with the linear compressor: compress the raw rows,
        # then scale the (m, kc) block — never the (m, d) clipped matrix
        comp = compress_fn(raw_updates) * scale[:, None]
        if compress_row_bound is not None:
            comp_sq = jnp.sum(jnp.square(comp), axis=-1)
            comp = comp * jnp.minimum(
                1.0, compress_row_bound / jnp.maximum(jnp.sqrt(comp_sq),
                                                      _EPS))[:, None]
        # scalar sums are the DENSE clipped values (exact step-size inputs)
        if row_weights is not None:
            v = gate * row_weights
            sum_sq_clipped = v @ (sq_norms * jnp.square(scale))
            return RoundMoments(sum_c=v @ comp, sum_sq=sum_sq_clipped,
                                sum_sq_clipped=sum_sq_clipped, count=count)
        sum_sq_clipped = jnp.sum(sq_norms * jnp.square(scale))
        ones = jnp.ones((m,), jnp.float32)
        return RoundMoments(sum_c=ones @ comp, sum_sq=sum_sq_clipped,
                            sum_sq_clipped=sum_sq_clipped, count=count)
    clipped = raw_updates * scale[:, None]
    released = clipped if noise is None else clipped + noise
    if row_weights is not None:
        v = gate * row_weights
        sum_sq_clipped = v @ (sq_norms * jnp.square(scale))
        sum_sq = (sum_sq_clipped if noise is None
                  else v @ jnp.sum(jnp.square(released), axis=-1))
        return RoundMoments(sum_c=v @ released, sum_sq=sum_sq,
                            sum_sq_clipped=sum_sq_clipped, count=count)
    sum_sq_clipped = jnp.sum(sq_norms * jnp.square(scale))
    sum_sq = (sum_sq_clipped if noise is None
              else jnp.sum(jnp.sum(jnp.square(released), axis=-1)))
    ones = jnp.ones((released.shape[0],), jnp.float32)
    return RoundMoments(sum_c=ones @ released, sum_sq=sum_sq,
                        sum_sq_clipped=sum_sq_clipped, count=count)


def streamed_clip_moments(
    raw_updates: jax.Array,
    clip_norm,
    noise: jax.Array | None = None,
    *,
    chunk_clients: int,
    weight_mask: jax.Array | None = None,
    row_weights: jax.Array | None = None,
    backend: str = "auto",
    interpret: bool | None = None,
    block_m: int | None = None,
    compress_fn=None,
    compress_row_bound=None,
) -> RoundMoments:
    """``partial_clip_moments`` streamed over row chunks (DESIGN.md §12).

    Splits the (M, d) update matrix into ceil(M / chunk_clients) row chunks,
    reduces each chunk with the identical clip/noise math, and accumulates
    the additive ``RoundMoments`` in a ``lax.scan`` carry — the reference
    formulation of the streaming engine's inner loop for callers that hold a
    dense matrix but want the chunk-grid numerics (testing, or bounding a
    kernel launch's working set).  The engine itself streams one level
    higher (per-chunk LOCAL TRAINING, so the (M, d) matrix never exists);
    this entry point only re-associates the reductions at chunk boundaries
    — all values, including the materialized noise rows, are the dense
    path's (rtol ~1e-6; exact when ``chunk_clients >= M``).

    Args:
      raw_updates: (M, d) raw client updates.
      clip_norm: clip threshold C (python float or traced scalar).
      noise: optional (M, d) pre-materialized per-client noise.
      chunk_clients: rows reduced per scan step (>= 1).
      weight_mask: optional (M,) float {0., 1.} row gate (padding/sampling).
      row_weights: optional (M,) per-client aggregation weights (§11).
      backend: per-chunk reduction backend, as ``partial_clip_moments``.
      interpret / block_m: kernel knobs, forwarded per chunk.
      compress_fn / compress_row_bound: optional §16 per-row compressor,
        forwarded per chunk; the scan carry's ``sum_c`` takes the COMPRESSED
        width (from ``jax.eval_shape``), so chunk partial sums stay additive
        in the compressed domain — the stream form of the §16 invariant.

    Returns:
      The cohort's ``RoundMoments`` partial SUMS, count included —
      ``sum(weight_mask)`` (or the weight sum) exactly as the un-streamed
      entry computes it.
    """
    if chunk_clients < 1:
        raise ValueError(f"chunk_clients must be >= 1, got {chunk_clients}")
    m = raw_updates.shape[0]
    c = min(chunk_clients, m)
    pad = (-m) % c
    n_chunks = (m + pad) // c

    mask = (jnp.ones((m,), jnp.float32) if weight_mask is None
            else weight_mask.astype(jnp.float32))
    had_mask = weight_mask is not None

    def grid(x, fill=0.0):
        """Pad the trailing rows and lay a leaf on the (n_chunks, c, ...) grid."""
        if pad:
            widths = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
            x = jnp.pad(x, widths, constant_values=fill)
        return x.reshape((n_chunks, c) + x.shape[1:])

    xs = {"u": grid(raw_updates), "mask": grid(mask)}
    if noise is not None:
        xs["noise"] = grid(noise)
    if row_weights is not None:
        xs["w"] = grid(row_weights.astype(jnp.float32))

    def body(acc, chunk):
        """Scan body: accumulate one chunk's additive moments into the carry."""
        mom = partial_clip_moments(
            chunk["u"], clip_norm, chunk.get("noise"),
            weight_mask=chunk["mask"], row_weights=chunk.get("w"),
            backend=backend, interpret=interpret, block_m=block_m,
            compress_fn=compress_fn, compress_row_bound=compress_row_bound)
        return jax.tree_util.tree_map(jnp.add, acc, mom), None

    if compress_fn is None:
        sum_c_zero = jnp.zeros(raw_updates.shape[1:], jnp.float32)
    else:   # the carry accumulates COMPRESSED partial sums
        kc = jax.eval_shape(
            compress_fn, jax.ShapeDtypeStruct((1,) + raw_updates.shape[1:],
                                              jnp.float32)).shape[-1]
        sum_c_zero = jnp.zeros((kc,), jnp.float32)
    zero = RoundMoments(sum_c=sum_c_zero,
                        sum_sq=jnp.float32(0.0),
                        sum_sq_clipped=jnp.float32(0.0),
                        count=jnp.float32(0.0))
    moments, _ = jax.lax.scan(body, zero, xs)
    if not had_mask and row_weights is None and pad == 0:
        # mirror the un-streamed entry's static-count constant when no mask
        # gates rows (each chunk's count is the static chunk size anyway)
        moments = dataclasses.replace(moments, count=jnp.float32(m))
    return moments


def raw_moments(deltas: jax.Array, mask: jax.Array | None,
                row_weights: jax.Array | None = None, *,
                compress_fn=None) -> RoundMoments:
    """Unclipped per-shard sums (non-private algorithms); mask-weighted.

    ``compress_fn`` (optional, DESIGN.md §16): a linear per-row compressor
    applied to the rows feeding ``sum_c`` only — the scalar sums stay the
    dense values, exactly as in ``partial_clip_moments``.  Where-zeroed
    masked rows compress to zero rows (linearity), so padding clients
    contribute nothing to the compressed sum either.

    Every masked scalar sum is a dot with the mask: on XLA:CPU a fused
    ``sum(mask * x)`` accumulates in a different order than the plain
    ``sum(x)`` the unsharded reference lowers to, while ``mask @ x`` matches
    it bit-for-bit (and the column sum already rides the same matvec idiom as
    ``aggregate_stats``).  ``row_weights`` folds per-client aggregation
    weights into the same dot (weighted mean via ``sum_c / count``).

    Masked rows are where-zeroed first: the engine already zeroes them at
    the source (so this is a numeric no-op on that path), but a direct
    caller's garbage row must not leak as ``0 * inf = NaN`` through the
    mask dot — masked clients contribute exactly zero, always.

    ``mask=None`` means full participation with no gate at all: the where
    pass and the traced count are skipped (an all-ones dot is kept so the
    reduction order — hence bitwise value — matches the masked path).
    """
    if mask is None:
        v = (jnp.ones((deltas.shape[0],), jnp.float32) if row_weights is None
             else row_weights)
        count = (jnp.float32(deltas.shape[0]) if row_weights is None
                 else jnp.sum(row_weights))
    else:
        deltas = jnp.where(mask[:, None] > 0, deltas, 0.0)
        v = mask if row_weights is None else mask * row_weights
        count = jnp.sum(v)
    sum_sq = v @ jnp.sum(jnp.square(deltas), axis=-1)
    rows = deltas if compress_fn is None else compress_fn(deltas)
    return RoundMoments(sum_c=v @ rows, sum_sq=sum_sq,
                        sum_sq_clipped=sum_sq, count=count)
