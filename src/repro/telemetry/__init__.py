"""repro.telemetry — streaming round trackers for long runs (DESIGN.md §15).

Public surface: the ``Tracker`` protocol and its concrete sinks.  The engine
tap internals live in ``repro.telemetry.tap`` and are wired by
``fedsim/session.py``; user code only ever constructs a tracker and passes
it to ``FederatedSession.run(tracker=...)``.
"""
from repro.telemetry.trackers import (
    CompositeTracker,
    JsonlTracker,
    NullTracker,
    StdoutTracker,
    Tracker,
    WandbTracker,
)

__all__ = ["Tracker", "NullTracker", "StdoutTracker", "JsonlTracker",
           "CompositeTracker", "WandbTracker"]
