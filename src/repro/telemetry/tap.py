"""Host side of the §15 engine tap.

The compiled engines emit one fixed-layout float32 vector per round through
``jax.experimental.io_callback`` (see ``fedsim/server.py``); this module is
where those device emissions become tracker events.

Ordering contract (DESIGN.md §15): non-sharded engines emit with
``ordered=True`` inside their round scan, so emissions arrive in round
order.  ``shard_map`` engines emit with ``ordered=False`` — ordered
callbacks inside shard_map are not reliable on this jax version — and EVERY
shard executes the callback, so the device passes its ``axis_index`` along
and the host (a) drops every emission with shard != 0 and (b) reorders by
round index in a buffer, delivering strictly consecutive rounds to the
tracker.  Both cases funnel through ``device_emit``.

A ``TapSession`` is installed for the duration of one ``run()`` (module
global — io_callback gives the device no way to address a specific host
object, and sessions never run concurrently in-process).  It owns:

* the reorder buffer + next-expected round (reset on §13 rollback),
* wall-clock round timing (perf_counter delta between deliveries),
* the cumulative privacy ledger (``ledger_fn(rounds_executed)`` →
  ``PrivacyReport``; retried rounds charge the ledger per §13 because every
  EXECUTED round increments the count, including rounds later rolled back),
* watchdog-freeze handling: frozen rounds (t > fault_t) emit NaN payloads
  on-device; the host logs them as frozen without charging the ledger.

The payload layout must match ``fedsim/server.py::_tap_payload`` exactly.
"""
from __future__ import annotations

import math
import time

import numpy as np

__all__ = ["TapSession", "install", "uninstall", "active", "device_emit",
           "PAYLOAD_LEN"]

# float32 payload slots (device side builds this in _tap_payload)
_ETA, _NAIVE, _TARGET, _METRIC, _CLIP, _PART, _REAL, _DROP, _STRAG, _CORR, \
    _FAULT_T, _SIGMA = range(12)
PAYLOAD_LEN = 12

_ACTIVE: "TapSession | None" = None


class TapSession:
    def __init__(self, tracker, *, start_round: int = 0, ledger_fn=None,
                 faults_active: bool = False,
                 bytes_per_round: float | None = None):
        self.tracker = tracker
        self.expected_t = int(start_round)
        self.ledger_fn = ledger_fn
        self.faults_active = faults_active
        # §16 communication footprint: 4 * algorithm.comm_floats(d), STATIC
        # per spec — attached host-side to every executed round event, so the
        # device payload layout is untouched and tap-on stays bit-identical
        self.bytes_per_round = (None if bytes_per_round is None
                                else float(bytes_per_round))
        # rounds actually run (incl. later rolled back); a resume starts at
        # the checkpoint round so the cumulative ledger counts from round 0
        self.executed = int(start_round)
        self.buffer: dict[int, np.ndarray] = {}
        self._t0 = time.perf_counter()

    # -- device-facing -----------------------------------------------------
    def emit(self, t: int, shard: int, vec: np.ndarray) -> None:
        if shard != 0:
            return  # every shard fires the callback; only shard 0 reports
        self.buffer[t] = np.asarray(vec)
        # deliver any consecutive run starting at expected_t (unordered
        # shard_map emissions can arrive out of round order)
        while self.expected_t in self.buffer:
            v = self.buffer.pop(self.expected_t)
            self._deliver(self.expected_t, v)
            self.expected_t += 1

    # -- host-facing (rollback notifications from _run_scan) ---------------
    def rollback(self, to_round: int, fault_round: int, attempt: int) -> None:
        self.buffer.clear()
        self.expected_t = int(to_round)
        self._t0 = time.perf_counter()
        self.tracker.log(int(fault_round), {
            "event": "rollback", "to_round": int(to_round),
            "attempt": int(attempt)})

    def profile_event(self, action: str, round_: int, trace_dir: str) -> None:
        self.tracker.log(int(round_), {
            "event": f"profile_{action}", "trace_dir": trace_dir})

    # -- internals ----------------------------------------------------------
    def _deliver(self, t: int, v: np.ndarray) -> None:
        now = time.perf_counter()
        dt, self._t0 = now - self._t0, now
        ft = int(v[_FAULT_T]) if math.isfinite(float(v[_FAULT_T])) else -1
        frozen = ft >= 0 and t > ft
        event = {"round_time_s": dt}
        if frozen:
            # watchdog froze the carry at fault_t; this round did not run
            event["frozen"] = True
            event["watchdog_fault_round"] = ft
            self.tracker.log(t, event)
            return
        self.executed += 1
        event.update(
            eta=float(v[_ETA]), eta_naive=float(v[_NAIVE]),
            eta_target=float(v[_TARGET]))
        if self.bytes_per_round is not None:
            event["bytes_per_round"] = self.bytes_per_round
        if math.isfinite(float(v[_METRIC])):
            event["metric"] = float(v[_METRIC])
        if math.isfinite(float(v[_CLIP])):
            event["clip"] = float(v[_CLIP])
        if len(v) > _SIGMA and math.isfinite(float(v[_SIGMA])):
            # §17 per-round noise std: round-indexed schedules emit sigma(t);
            # fixed-sigma releases emit the constant; mechanisms with no
            # shared noise std (NoPrivacy, PrivUnit, heterogeneous
            # per-client) emit NaN and the field is omitted
            event["sigma"] = float(v[_SIGMA])
        event["participants"] = int(v[_PART])
        if self.faults_active:
            event.update(
                realized_clients=int(v[_REAL]), dropped=int(v[_DROP]),
                stragglers=int(v[_STRAG]), corrupt=int(v[_CORR]))
        if ft >= 0:
            event["watchdog_fault_round"] = ft
        if self.ledger_fn is not None:
            # observability must never kill a run: an accounting failure
            # surfaces once as an event field and disables the ledger
            try:
                rep = self.ledger_fn(self.executed)
            except Exception as e:  # noqa: BLE001 - deliberate firewall
                event["ledger_error"] = repr(e)
                self.ledger_fn = None
            else:
                event.update(
                    ledger_rounds=self.executed, mu=float(rep.mu),
                    eps=float(rep.eps_numerical), eps_rdp=float(rep.eps_rdp))
        self.tracker.log(t, event)


def install(session: TapSession) -> None:
    global _ACTIVE
    if _ACTIVE is not None:
        raise RuntimeError("a telemetry TapSession is already active; "
                           "sessions may not run concurrently in-process")
    _ACTIVE = session


def uninstall() -> None:
    global _ACTIVE
    _ACTIVE = None


def active() -> "TapSession | None":
    return _ACTIVE


def device_emit(t, shard, vec) -> None:
    """The io_callback target.  A late callback flushed after uninstall()
    (jax.effects_barrier runs before uninstall, so this is belt-and-braces)
    is dropped rather than crashed on."""
    s = _ACTIVE
    if s is not None:
        s.emit(int(t), int(shard), np.asarray(vec))
