"""Pluggable per-round event sinks (DESIGN.md §15).

A ``Tracker`` receives one event dict per federated round while the compiled
engines run — streamed out of the scan via the §15 engine tap — plus control
events (rollbacks, profile windows).  The protocol is deliberately tiny so
sinks stay trivial to write:

    log(step, event)          one dict per round (or control event)
    start_phase(name, step)   run/resume/replay boundaries (no round payload)
    finish()                  flush/close; the session calls it when run() ends

Concrete sinks:

* ``NullTracker`` — the default.  A session run with a ``NullTracker`` (or no
  tracker at all) compiles the tap OUT of the program entirely: the engines
  receive ``tap=False`` and the compiled HLO is the historical one.
* ``StdoutTracker`` — one human-readable line per event, optional cadence.
* ``JsonlTracker`` — one JSON object per line, atomic append (open/write/
  close per event, one buffered write each), non-finite floats sanitized to
  null so every line is strict JSON.
* ``CompositeTracker`` — fan out to several sinks.
* ``WandbTracker`` — optional adapter; constructing it without wandb
  installed raises ImportError (tests guard it with importorskip).

Every tracker supports ``sub(tag)`` — a child view that stamps ``seed: tag``
into each event and forwards to the parent, with a no-op ``finish`` so
``run_batched`` can hand one child per seed without closing the parent.
"""
from __future__ import annotations

import json
import math
import os
from typing import Any

__all__ = ["Tracker", "NullTracker", "StdoutTracker", "JsonlTracker",
           "CompositeTracker", "WandbTracker"]


class Tracker:
    """Base protocol; subclasses override what they need."""

    def log(self, step: int, event: dict[str, Any]) -> None:
        raise NotImplementedError

    def start_phase(self, name: str, step: int = 0) -> None:
        """A run boundary: 'run' at 0, 'resume' at the resumed round,
        'replay' for run_batched's post-hoc per-seed replays."""

    def finish(self) -> None:
        """Flush/close.  Called by ``FederatedSession.run`` when it returns."""

    def sub(self, tag) -> "Tracker":
        """Per-seed child view: stamps ``seed: tag``, no-op finish."""
        return _SubTracker(self, tag)


class NullTracker(Tracker):
    """Swallow everything.  Passing one to ``run(tracker=...)`` keeps the
    engine tap compiled OUT (tap=False) — the default, zero-cost path."""

    def log(self, step: int, event: dict[str, Any]) -> None:
        pass


class StdoutTracker(Tracker):
    """One line per event on stdout; ``every`` thins round events (control
    events — anything carrying an ``event`` key — always print)."""

    def __init__(self, every: int = 1, prefix: str = ""):
        if every < 1:
            raise ValueError(f"every must be >= 1, got {every}")
        self.every = every
        self.prefix = prefix

    def log(self, step: int, event: dict[str, Any]) -> None:
        if "event" not in event and step % self.every != 0:
            return
        body = "  ".join(f"{k}={_fmt(v)}" for k, v in event.items())
        print(f"{self.prefix}[round {step:5d}] {body}", flush=True)

    def start_phase(self, name: str, step: int = 0) -> None:
        print(f"{self.prefix}-- {name} from round {step} --", flush=True)


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)


def _sanitize(v):
    """Strict-JSON scrub: non-finite floats become null, containers recurse."""
    if isinstance(v, float) and not math.isfinite(v):
        return None
    if isinstance(v, dict):
        return {k: _sanitize(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_sanitize(x) for x in v]
    return v


class JsonlTracker(Tracker):
    """One JSON object per line, appended atomically.

    Each ``log`` opens the file in append mode, writes ONE buffered line and
    closes — a single write() per event at close, so concurrent writers (CI
    matrix legs pointing at per-leg paths should not share files anyway)
    never interleave partial lines.  Round events carry ``round``; control
    events carry ``event``.  Non-finite floats are written as null so every
    line parses under strict JSON (``tools/check_telemetry.py`` validates).
    """

    def __init__(self, path: str, *, append: bool = False):
        self.path = path
        parent = os.path.dirname(path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        if not append and os.path.exists(path):
            os.remove(path)

    def _write(self, obj: dict[str, Any]) -> None:
        line = json.dumps(_sanitize(obj), sort_keys=True)
        with open(self.path, "a") as f:
            f.write(line + "\n")

    def log(self, step: int, event: dict[str, Any]) -> None:
        self._write({"round": int(step), **event})

    def start_phase(self, name: str, step: int = 0) -> None:
        # phases are bookkeeping, not rounds: no line, so a plain T-round run
        # emits exactly T lines (the §15 exact-count invariant tests pin)
        self._last_phase = (name, int(step))


class CompositeTracker(Tracker):
    """Fan every call out to each child sink, in order."""

    def __init__(self, *trackers: Tracker):
        self.trackers = tuple(trackers)

    def log(self, step: int, event: dict[str, Any]) -> None:
        for t in self.trackers:
            t.log(step, event)

    def start_phase(self, name: str, step: int = 0) -> None:
        for t in self.trackers:
            t.start_phase(name, step)

    def finish(self) -> None:
        for t in self.trackers:
            t.finish()


class WandbTracker(Tracker):
    """Optional wandb adapter.  Importing this module never touches wandb;
    CONSTRUCTING the tracker does, and raises ImportError when the package
    is absent (tests use ``pytest.importorskip('wandb')``)."""

    def __init__(self, run=None, **init_kwargs):
        import wandb  # deferred: repo does not depend on wandb
        self._run = run if run is not None else wandb.init(**init_kwargs)

    def log(self, step: int, event: dict[str, Any]) -> None:
        self._run.log(dict(event), step=int(step))

    def finish(self) -> None:
        self._run.finish()


class _SubTracker(Tracker):
    """Per-seed view over a parent tracker (``run_batched``'s subtrackers)."""

    def __init__(self, parent: Tracker, tag):
        self.parent = parent
        self.tag = tag

    def log(self, step: int, event: dict[str, Any]) -> None:
        self.parent.log(step, {"seed": self.tag, **event})

    def start_phase(self, name: str, step: int = 0) -> None:
        self.parent.start_phase(f"{name}[seed={self.tag}]", step)

    def finish(self) -> None:
        pass  # the parent outlives every per-seed view
