"""repro — production-grade JAX framework implementing DP-FedEXP.

Paper: "Accelerating Differentially Private Federated Learning via Adaptive
Extrapolation" (Takakura, Liew, Hasegawa, 2025).

Layers
------
- ``repro.core``     — the paper's contribution: DP mechanisms, adaptive global
  step-size rules (LDP/CDP-FedEXP), clipping, privacy accounting, baselines.
- ``repro.fedsim``   — vectorized M-client federated simulation engine used for
  the paper-faithful experiments (synthetic + MNIST-like).
- ``repro.models``   — pure-JAX model zoo (dense/GQA/SWA, MoE, Mamba2 SSD,
  hybrid, early-fusion VLM, enc-dec audio) used by the datacenter DP-FL path.
- ``repro.kernels``  — Pallas TPU kernels (dp_aggregate, flash_attention,
  ssd_scan) with jnp oracles; validated in interpret mode on CPU.
- ``repro.launch``   — mesh construction, federated train_step / serve_step,
  multi-pod dry-run and roofline tooling.
- ``repro.configs``  — assigned architecture configs + the paper's own models
  + the four canonical input shapes.
"""

__version__ = "1.0.0"
