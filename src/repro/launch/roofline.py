"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds (see EXPERIMENTS.md):

    compute    = HLO_FLOPs    / (chips x peak_FLOP/s)
    memory     = HLO_bytes    / (chips x HBM_bw)
    collective = coll_bytes   / (chips x link_bw)

``compiled.cost_analysis()`` provides HLO_FLOPs / HLO_bytes; collective bytes
are NOT in cost_analysis, so we parse the post-SPMD HLO text and sum the
result-shape bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute (the ``-start`` variant counted, ``-done``
skipped to avoid double counting).

Hardware model: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI.

Note on per-device vs global numbers: XLA reports cost_analysis for the
*partitioned per-device module*, so FLOPs/bytes are per-chip and the terms
divide by peak per chip (chips appears only via the partitioning itself).
We verify this convention against MODEL_FLOPS = 6*N*D in the dry-run report
(the ratio column would be off by exactly `chips` x if the convention
flipped in a jax upgrade).
"""
from __future__ import annotations

import dataclasses
import re

__all__ = ["HW", "Hardware", "collective_bytes", "roofline_terms", "model_flops"]


@dataclasses.dataclass(frozen=True)
class Hardware:
    peak_flops: float = 197e12       # bf16 / chip
    hbm_bw: float = 819e9            # bytes/s / chip
    ici_bw: float = 50e9             # bytes/s / link


HW = Hardware()

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
# result type(s) precede `op-name(`; `-done` ops forward the -start buffer.
_OP_RE = re.compile(
    r"=\s*([^=]*?)\s+(" + "|".join(_COLLECTIVES) + r")(?:-start)?\(")
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum result-shape bytes per collective kind over a post-SPMD HLO module."""
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue
        m = _OP_RE.search(line)
        if not m:
            continue
        out[m.group(2)] += _shape_bytes(m.group(1))
    return out


def roofline_terms(flops: float, bytes_accessed: float, coll_bytes: float,
                   hw: Hardware = HW) -> dict[str, float]:
    """Per-chip seconds for each roofline term + the dominant one."""
    terms = {
        "compute_s": flops / hw.peak_flops,
        "memory_s": bytes_accessed / hw.hbm_bw,
        "collective_s": coll_bytes / hw.ici_bw,
    }
    terms["bottleneck"] = max(
        ("compute_s", "memory_s", "collective_s"), key=lambda k: terms[k])
    return terms


def model_flops(num_params: int, active_params: int, tokens: int, kind: str) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE); D = tokens in the step.

    For decode, tokens = batch (one new token per request). Train counts the
    backward (the 6x already includes fwd+bwd); serve kinds use 2*N*D.
    """
    n = active_params
    mult = 6.0 if kind == "train" else 2.0
    return mult * n * tokens
