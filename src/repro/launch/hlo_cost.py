"""Structural cost analysis of post-SPMD HLO text — the dry-run "profiler".

``compiled.cost_analysis()`` counts every while-loop body ONCE, which under-
states scanned-layer models by ~num_layers x tau. This walker parses the
scheduled HLO (``compiled.as_text()``), multiplies while bodies by their
``known_trip_count`` (emitted by XLA in backend_config), recurses into
fusions/calls, and accumulates:

  - flops:  dot ops (2 * prod(result dims) * prod(contracted lhs dims)),
            convolutions approximated, elementwise ignored (matmul-dominated
            workloads; the elementwise contribution is covered by bytes),
  - bytes:  operands + results of every top-level op (fusion internals are
            excluded — the fusion boundary is the HBM traffic model),
  - collective bytes per kind (result-shape convention; ring-factor
    (n-1)/n and the 2x all-reduce factor are applied in the roofline layer
    if desired — we report raw result bytes and document the convention).

All shapes in post-SPMD HLO are PER-DEVICE, so every number this module
returns is per-chip, matching the roofline denominators.
"""
from __future__ import annotations

import dataclasses
import re

__all__ = ["parse_hlo", "hlo_cost", "COLLECTIVE_KINDS"]

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

COLLECTIVE_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                    "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->")
_ATTR_COMP = re.compile(r"(?:calls|body|to_apply)=%([\w\.\-]+)")
_COND_COMP = re.compile(r"condition=%([\w\.\-]+)")
_BRANCH_COMP = re.compile(r"branch_computations=\{([^}]*)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERAND_NAME = re.compile(r"%([\w\.\-]+)")

# ops that are free (layout/bookkeeping) for the bytes model
_FREE_OPS = {"parameter", "get-tuple-element", "tuple", "bitcast", "constant",
             "after-all", "partition-id", "replica-id", "iota", "opt-barrier"}


def _shape_dims(type_str: str) -> list[tuple[str, list[int]]]:
    out = []
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        out.append((dtype, [int(d) for d in dims.split(",")] if dims else []))
    return out


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _shape_dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class Op:
    name: str
    result_type: str
    opcode: str
    operands: list[str]
    attrs: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: list[Op]
    symbols: dict[str, str]  # op name -> result type


def _split_op_line(line: str) -> Op | None:
    s = line.strip()
    if s.startswith("ROOT "):
        s = s[5:]
    if not s.startswith("%"):
        return None
    eq = s.find(" = ")
    if eq < 0:
        return None
    name = s[1:eq].strip()
    rest = s[eq + 3:]
    # result type: tuple "( ... )" (match parens) or token up to first space
    if rest.startswith("("):
        depth, i = 0, 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        result_type = rest[: i + 1]
        rest = rest[i + 1:].strip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        result_type = rest[:sp]
        rest = rest[sp + 1:].strip()
    par = rest.find("(")
    if par < 0:
        return None
    opcode = rest[:par]
    # operand section: up to the matching close paren
    depth, i = 0, par
    for i in range(par, len(rest)):
        if rest[i] == "(":
            depth += 1
        elif rest[i] == ")":
            depth -= 1
            if depth == 0:
                break
    operand_str = rest[par + 1: i]
    attrs = rest[i + 1:]
    operands = _OPERAND_NAME.findall(operand_str)
    return Op(name=name, result_type=result_type, opcode=opcode,
              operands=operands, attrs=attrs, line=line)


def parse_hlo(text: str) -> tuple[dict[str, Computation], str | None]:
    """Returns ({computation name: Computation}, entry computation name)."""
    comps: dict[str, Computation] = {}
    entry = None
    cur: Computation | None = None
    for line in text.splitlines():
        if not line:
            continue
        if not line.startswith(" "):  # possible computation header
            m = _COMP_HDR.match(line)
            if m and line.rstrip().endswith("{"):
                cur = Computation(name=m.group(2), ops=[], symbols={})
                comps[cur.name] = cur
                if m.group(1):
                    entry = cur.name
            elif line.startswith("}"):
                cur = None
            continue
        if cur is None:
            continue
        op = _split_op_line(line)
        if op is not None:
            cur.ops.append(op)
            cur.symbols[op.name] = op.result_type
    return comps, entry


def _dot_flops(op: Op, symbols: dict[str, str]) -> float:
    result_elems = 1
    for _, dims in _shape_dims(op.result_type):
        for d in dims:
            result_elems *= d
    m = _CONTRACT_RE.search(op.attrs)
    contract = 1
    if m and op.operands:
        lhs_type = symbols.get(op.operands[0], "")
        shapes = _shape_dims(lhs_type)
        if shapes:
            _, lhs_dims = shapes[0]
            for idx in (int(x) for x in m.group(1).split(",") if x):
                if idx < len(lhs_dims):
                    contract *= lhs_dims[idx]
    return 2.0 * result_elems * contract


def _conv_flops(op: Op, symbols: dict[str, str]) -> float:
    # approx: 2 * result_elems * (rhs elems / out_channels); fine for the
    # fedsim CNNs, no convs appear in the big-model dry-runs.
    result_elems = 1
    for _, dims in _shape_dims(op.result_type):
        for d in dims:
            result_elems *= d
    rhs_elems = 1
    if len(op.operands) > 1:
        for _, dims in _shape_dims(symbols.get(op.operands[1], "")):
            for d in dims:
                rhs_elems *= d
    out_ch = 1
    shapes = _shape_dims(op.result_type)
    if shapes and shapes[0][1]:
        out_ch = shapes[0][1][-1]
    return 2.0 * result_elems * max(1, rhs_elems // max(1, out_ch))


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVE_KINDS})
    unknown_loops: int = 0

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += mult * other.flops
        self.bytes += mult * other.bytes
        for k in COLLECTIVE_KINDS:
            self.coll[k] += mult * other.coll[k]
        self.unknown_loops += other.unknown_loops

    @property
    def coll_total(self) -> float:
        return sum(self.coll.values())


def _comp_cost(comps: dict[str, Computation], name: str,
               memo: dict[str, Cost], *, count_bytes: bool) -> Cost:
    key = (name, count_bytes)
    if key in memo:
        return memo[key]
    comp = comps[name]
    total = Cost()
    for op in comp.ops:
        oc = op.opcode
        base = oc[:-6] if oc.endswith("-start") else oc[:-5] if oc.endswith("-done") else oc

        # --- control flow / nested computations ---
        if oc == "while":
            m = _TRIP_RE.search(op.attrs)
            trip = int(m.group(1)) if m else 1
            if not m:
                total.unknown_loops += 1
            bm = _ATTR_COMP.search(op.attrs)
            cm = _COND_COMP.search(op.attrs)
            if bm:
                total.add(_comp_cost(comps, bm.group(1), memo, count_bytes=count_bytes), trip)
            if cm:
                total.add(_comp_cost(comps, cm.group(1), memo, count_bytes=count_bytes), trip)
            continue
        if oc == "conditional":
            mb = _BRANCH_COMP.search(op.attrs)
            if mb:
                branches = _OPERAND_NAME.findall(mb.group(1))
                for b in branches:  # upper bound: sum of branches / len
                    total.add(_comp_cost(comps, b, memo, count_bytes=count_bytes),
                              1.0 / max(1, len(branches)))
            continue
        if oc == "fusion":
            cm = _ATTR_COMP.search(op.attrs)
            if cm:
                # flops + collectives from inside; bytes at the boundary only
                total.add(_comp_cost(comps, cm.group(1), memo, count_bytes=False))
            if count_bytes:
                total.bytes += _shape_bytes(op.result_type)
                for o in op.operands:
                    total.bytes += _shape_bytes(comp.symbols.get(o, ""))
            continue
        if oc in ("call", "async-start"):
            cm = _ATTR_COMP.search(op.attrs)
            if cm:
                total.add(_comp_cost(comps, cm.group(1), memo, count_bytes=count_bytes))
            continue

        # --- collectives ---
        if base in COLLECTIVE_KINDS:
            if oc.endswith("-start"):
                continue  # counted at -done
            total.coll[base] += _shape_bytes(op.result_type)
            if count_bytes:
                total.bytes += _shape_bytes(op.result_type)
            continue

        # --- compute ---
        if oc == "dot":
            total.flops += _dot_flops(op, comp.symbols)
        elif oc == "convolution":
            total.flops += _conv_flops(op, comp.symbols)

        # --- bytes ---
        if count_bytes and oc not in _FREE_OPS and not oc.endswith("-done"):
            total.bytes += _shape_bytes(op.result_type)
            for o in op.operands:
                total.bytes += _shape_bytes(comp.symbols.get(o, ""))
    memo[key] = total
    return total


def hlo_cost(text: str) -> dict:
    """Walk the scheduled HLO module; returns per-device flops/bytes/collectives."""
    comps, entry = parse_hlo(text)
    if entry is None:
        raise ValueError("no ENTRY computation found")
    memo: dict = {}
    c = _comp_cost(comps, entry, memo, count_bytes=True)
    return {
        "flops": c.flops,
        "bytes": c.bytes,
        "collective_bytes": dict(c.coll),
        "collective_total": c.coll_total,
        "unknown_loops": c.unknown_loops,
        "num_computations": len(comps),
    }
