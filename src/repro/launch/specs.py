"""ShapeDtypeStruct input specs + sharding specs for every (arch x shape).

The dry-run lowers against these stand-ins (weak-type-correct, shardable, no
device allocation). For the stubbed frontends ([audio]/[vlm]) the specs carry
precomputed frame embeddings / VQ token ids, per the assignment carve-out.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import FederatedConfig, ModelConfig, ShapeConfig
from repro.launch.rules import safe_pspec

__all__ = ["cohort_size", "train_input_specs", "decode_input_specs",
           "prefill_input_specs", "cache_logical", "tree_input_shardings",
           "WHISPER_DECODER_LEN", "WHISPER_ENC_FRAMES"]

WHISPER_DECODER_LEN = 256    # decoder tokens per utterance in train/prefill
WHISPER_ENC_FRAMES = 1500    # whisper's fixed 30 s encoder length (decode mode)


def cohort_size(mesh: Mesh, rules: dict) -> int:
    ax = rules.get("clients")
    if ax is None:
        return 1
    axes = (ax,) if isinstance(ax, str) else tuple(ax)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return math.prod(sizes[a] for a in axes)


# ---------------------------------------------------------------------------
# logical axes for pytrees whose structure we don't enumerate by hand
# ---------------------------------------------------------------------------

def cache_logical(cache_shapes) -> Any:
    """Logical axes for a KV/SSM cache pytree, keyed on leaf names/ranks."""

    def leaf_logical(path, leaf):
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        nd = len(leaf.shape)
        # "kv_seq" (not "seq"): the KV cache shards its sequence dim over the
        # model axis in serve mode (sequence-sharded cache). KV heads rarely
        # divide the model axis (GQA kv=8 vs model=16) so head sharding would
        # replicate the cache; the 32k/500k seq dim always divides.
        if name in ("k", "v"):
            return ("layers", "batch", "kv_seq", "heads", None)[:nd] if nd == 5 \
                else ("batch", "kv_seq", "heads", None)[:nd]
        if name == "slot_pos":
            return ("layers", "kv_seq")[:nd] if nd == 2 else ("kv_seq",)
        if name == "conv":
            return ("layers", "batch", None, "ff")[:nd] if nd == 4 else ("batch", None, "ff")
        if name == "state":
            return ("layers", "batch", "ff", None, None)[:nd] if nd == 5 \
                else ("batch", "ff", None, None)
        return (None,) * nd

    return jax.tree_util.tree_map_with_path(leaf_logical, cache_shapes)


def tree_input_shardings(mesh: Mesh, shapes, logical, rules):
    return jax.tree_util.tree_map(
        lambda s, l: NamedSharding(mesh, safe_pspec(s.shape, l, rules, mesh)),
        shapes, logical)


# ---------------------------------------------------------------------------
# per-mode specs
# ---------------------------------------------------------------------------

def train_input_specs(cfg: ModelConfig, shape: ShapeConfig, fed: FederatedConfig,
                      mesh: Mesh, rules: dict):
    """Returns (shapes dict, logical dict). Batch layout: (K, tau, b, S)."""
    k = cohort_size(mesh, rules)
    assert shape.global_batch % k == 0, (shape.global_batch, k)
    b = shape.global_batch // k
    tau = fed.local_steps
    s = shape.seq_len
    tok = jax.ShapeDtypeStruct((k, tau, b, s), jnp.int32)
    logical_tok = ("clients", None, "batch", None)
    shapes = {"tokens": tok, "labels": tok}
    logical = {"tokens": logical_tok, "labels": logical_tok}
    if cfg.arch_type == "audio":
        # stub frontend: precomputed frame embeddings for the encoder; the
        # decoder consumes WHISPER_DECODER_LEN text tokens per utterance.
        shapes["frames"] = jax.ShapeDtypeStruct((k, tau, b, s, cfg.d_model), jnp.bfloat16)
        logical["frames"] = ("clients", None, "batch", "seq", None)
        dec = jax.ShapeDtypeStruct((k, tau, b, WHISPER_DECODER_LEN), jnp.int32)
        shapes["tokens"] = dec
        shapes["labels"] = dec
    return shapes, logical


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                       rules: dict, model):
    """ONE new token against a cache of shape.seq_len (serve_step)."""
    b, s = shape.global_batch, shape.seq_len
    caches = jax.eval_shape(lambda: model.init_cache(b, s, dtype=jnp.bfloat16))
    shapes = {
        "token": jax.ShapeDtypeStruct((b,), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
        "caches": caches,
    }
    logical = {
        "token": ("batch",),
        "pos": (),
        "caches": cache_logical(caches),
    }
    if cfg.arch_type == "audio":
        shapes["enc_out"] = jax.ShapeDtypeStruct((b, WHISPER_ENC_FRAMES, cfg.d_model), jnp.bfloat16)
        logical["enc_out"] = ("batch", "seq", None)
    return shapes, logical


def prefill_input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                        rules: dict, model):
    b, s = shape.global_batch, shape.seq_len
    if cfg.arch_type == "audio":
        caches = jax.eval_shape(lambda: model.init_cache(b, WHISPER_DECODER_LEN, dtype=jnp.bfloat16))
        shapes = {
            "frames": jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16),
            "tokens": jax.ShapeDtypeStruct((b, WHISPER_DECODER_LEN), jnp.int32),
            "caches": caches,
        }
        logical = {
            "frames": ("batch", "seq", None),
            "tokens": ("batch", None),
            "caches": cache_logical(caches),
        }
        return shapes, logical
    caches = jax.eval_shape(lambda: model.init_cache(b, s, dtype=jnp.bfloat16))
    shapes = {
        "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
        "caches": caches,
    }
    logical = {
        "tokens": ("batch", None),
        "caches": cache_logical(caches),
    }
    return shapes, logical
