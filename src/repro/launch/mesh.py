"""Production mesh construction (single-pod 16x16, multi-pod 2x16x16).

A function, not a module-level constant, so importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before any jax import;
tests and benches must keep seeing 1 device).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_test_mesh", "make_client_mesh",
           "client_shard_spec"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips with a leading pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many (CPU) devices exist — for unit tests."""
    return jax.make_mesh((data, model), ("data", "model"))


def make_client_mesh(n_shards: int | None = None, *, axis: str = "clients"):
    """1-D ``clients`` mesh for the client-sharded round engine (DESIGN.md §9).

    ``n_shards`` defaults to every visible device.  On CPU, force multiple
    host devices BEFORE the first jax import to exercise real sharding:

        XLA_FLAGS=--xla_force_host_platform_device_count=8
    """
    n = n_shards if n_shards is not None else len(jax.devices())
    return jax.make_mesh((n,), (axis,))


def client_shard_spec(n_shards: int | None = None, *, axis: str = "clients"):
    """A ready ``ShardSpec`` for the session API over a fresh client mesh:

        FederatedSession(..., shard=client_shard_spec())

    is the one-liner for "shard the cohort over every visible device"
    (DESIGN.md §10).  Imported lazily so this module still never touches
    fedsim at import time.
    """
    from repro.fedsim.specs import ShardSpec
    return ShardSpec(mesh=make_client_mesh(n_shards, axis=axis), client_axis=axis)
