"""Production mesh construction (single-pod 16x16, multi-pod 2x16x16).

A function, not a module-level constant, so importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before any jax import;
tests and benches must keep seeing 1 device).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_test_mesh", "make_client_mesh",
           "auto_shard_count", "client_shard_spec"]

# Minimum clients per shard for the "auto" shard-count heuristic.  Measured
# on the e7 quick geometry (M=96, 8 forced host devices): 8 shards put only
# 12 clients on each device and throughput COLLAPSED to ~0.37x of the
# 4-shard mesh (BENCH_engine.json history) — per-round shard_map/psum
# overhead dominates once the per-device slice is that thin.  24 clients per
# shard is the knee of that curve (4 shards at M=96).
MIN_CLIENTS_PER_SHARD = 24


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips with a leading pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many (CPU) devices exist — for unit tests."""
    return jax.make_mesh((data, model), ("data", "model"))


def make_client_mesh(n_shards: int | None = None, *, axis: str = "clients"):
    """1-D ``clients`` mesh for the client-sharded round engine (DESIGN.md §9).

    ``n_shards`` defaults to every visible device.  On CPU, force multiple
    host devices BEFORE the first jax import to exercise real sharding:

        XLA_FLAGS=--xla_force_host_platform_device_count=8
    """
    n = n_shards if n_shards is not None else len(jax.devices())
    return jax.make_mesh((n,), (axis,))


def auto_shard_count(num_clients: int, *, n_devices: int | None = None,
                     min_clients_per_shard: int = MIN_CLIENTS_PER_SHARD) -> int:
    """Shard count capped so every shard holds >= ``min_clients_per_shard``.

    Using every visible device is NOT always fastest: past the point where a
    device's cohort slice is thin, per-round shard_map/psum overhead eats the
    parallelism (the 8-shard collapse recorded in BENCH_engine.json — see
    ``MIN_CLIENTS_PER_SHARD``).  This caps the mesh at
    ``num_clients // min_clients_per_shard`` shards, floored at 1.
    """
    n_dev = n_devices if n_devices is not None else len(jax.devices())
    return max(1, min(n_dev, num_clients // min_clients_per_shard))


def client_shard_spec(n_shards: int | str | None = None, *,
                      axis: str = "clients",
                      num_clients: int | None = None):
    """A ready ``ShardSpec`` for the session API over a fresh client mesh:

        FederatedSession(..., shard=client_shard_spec())

    is the one-liner for "shard the cohort over every visible device"
    (DESIGN.md §10), and

        client_shard_spec("auto", num_clients=M)

    applies the ``auto_shard_count`` heuristic — every device, but never so
    many that a shard's cohort slice drops below the measured efficiency
    floor.  Imported lazily so this module still never touches fedsim at
    import time.
    """
    if n_shards == "auto":
        if num_clients is None:
            raise ValueError("client_shard_spec('auto') requires num_clients=")
        n_shards = auto_shard_count(num_clients)
    from repro.fedsim.specs import ShardSpec
    return ShardSpec(mesh=make_client_mesh(n_shards, axis=axis), client_axis=axis)
