"""Production mesh construction (single-pod 16x16, multi-pod 2x16x16).

A function, not a module-level constant, so importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before any jax import;
tests and benches must keep seeing 1 device).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_test_mesh", "make_client_mesh",
           "auto_shard_count", "auto_chunk_clients", "client_shard_spec"]

# Minimum clients per shard for the "auto" shard-count heuristic.  Measured
# on the e7 quick geometry (M=96, 8 forced host devices): 8 shards put only
# 12 clients on each device and throughput COLLAPSED to ~0.37x of the
# 4-shard mesh (BENCH_engine.json history) — per-round shard_map/psum
# overhead dominates once the per-device slice is that thin.  24 clients per
# shard is the knee of that curve (4 shards at M=96).
MIN_CLIENTS_PER_SHARD = 24


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips with a leading pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many (CPU) devices exist — for unit tests."""
    return jax.make_mesh((data, model), ("data", "model"))


def make_client_mesh(n_shards: int | None = None, *, axis: str = "clients"):
    """1-D ``clients`` mesh for the client-sharded round engine (DESIGN.md §9).

    ``n_shards`` defaults to every visible device.  On CPU, force multiple
    host devices BEFORE the first jax import to exercise real sharding:

        XLA_FLAGS=--xla_force_host_platform_device_count=8
    """
    n = n_shards if n_shards is not None else len(jax.devices())
    return jax.make_mesh((n,), (axis,))


def auto_shard_count(num_clients: int, *, n_devices: int | None = None,
                     min_clients_per_shard: int = MIN_CLIENTS_PER_SHARD) -> int:
    """Shard count capped so every shard holds >= ``min_clients_per_shard``.

    Using every visible device is NOT always fastest: past the point where a
    device's cohort slice is thin, per-round shard_map/psum overhead eats the
    parallelism (the 8-shard collapse recorded in BENCH_engine.json — see
    ``MIN_CLIENTS_PER_SHARD``).  This caps the mesh at
    ``num_clients // min_clients_per_shard`` shards, floored at 1.
    """
    n_dev = n_devices if n_devices is not None else len(jax.devices())
    return max(1, min(n_dev, num_clients // min_clients_per_shard))


def device_memory_budget(*, fraction: float = 0.25,
                         fallback_bytes: int = 4 << 30) -> int:
    """Bytes of device memory the streaming engine may spend on one chunk.

    Reads the live device's ``memory_stats()["bytes_limit"]`` when the
    backend exposes it (GPU/TPU) and budgets ``fraction`` of it — the rest
    stays free for the model, optimizer state, moments, and XLA temporaries.
    CPU backends report no limit; the documented fallback is 4 GiB, matching
    the host-RAM assumption of the docs/scaling.md sizing table.
    """
    try:
        stats = jax.devices()[0].memory_stats() or {}
        limit = int(stats.get("bytes_limit", 0))
    except Exception:
        limit = 0
    return int((limit if limit > 0 else fallback_bytes) * fraction)


def auto_chunk_clients(dim: int, client_bytes: int = 0, *,
                       n_shards: int = 1,
                       budget_bytes: int | None = None) -> int:
    """Chunk size for ``StreamSpec(chunk_clients="auto")`` (DESIGN.md §12/§14).

    The docs/scaling.md sizing rule, inverted: a streamed chunk's peak device
    footprint is ~``chunk * (2 * 4 * dim + client_bytes)`` — the (c, d)
    update block, an equal-shape randomization block (the LDP noise
    materialization doubles the update memory; clip-only mechanisms simply
    leave headroom), and the chunk's staged client data — so the chunk is the
    memory budget divided by that per-client cost.  Mirrors
    ``auto_shard_count``: a heuristic with an explicit knob
    (``budget_bytes``), not a guarantee.  With ``n_shards`` > 1 each shard
    streams concurrently on its own device, so the budget is per-shard
    already and no division applies.

    Raises when even ``chunk_clients=1`` exceeds the budget — streaming
    cannot help then, and silently returning 1 would OOM one client at a
    time.
    """
    if dim < 1:
        raise ValueError(f"dim must be >= 1, got {dim}")
    per_client = 2 * 4 * int(dim) + max(0, int(client_bytes))
    budget = budget_bytes if budget_bytes is not None else device_memory_budget()
    chunk = budget // per_client
    if chunk < 1:
        raise ValueError(
            f"chunk_clients='auto': one client costs ~{per_client} bytes "
            f"(2 * 4 * dim={dim} update/noise rows + {client_bytes} data "
            f"bytes) but the device budget is {budget} bytes — even "
            "chunk_clients=1 cannot fit.  Shrink the model dimension, shard "
            "clients over more devices, or pass a larger budget_bytes.")
    return int(chunk)


def client_shard_spec(n_shards: int | str | None = None, *,
                      axis: str = "clients",
                      num_clients: int | None = None):
    """A ready ``ShardSpec`` for the session API over a fresh client mesh:

        FederatedSession(..., shard=client_shard_spec())

    is the one-liner for "shard the cohort over every visible device"
    (DESIGN.md §10), and

        client_shard_spec("auto", num_clients=M)

    applies the ``auto_shard_count`` heuristic — every device, but never so
    many that a shard's cohort slice drops below the measured efficiency
    floor.  Imported lazily so this module still never touches fedsim at
    import time.
    """
    if n_shards == "auto":
        if num_clients is None:
            raise ValueError("client_shard_spec('auto') requires num_clients=")
        n_shards = auto_shard_count(num_clients)
    from repro.fedsim.specs import ShardSpec
    return ShardSpec(mesh=make_client_mesh(n_shards, axis=axis), client_axis=axis)
