"""Datacenter federated train step: DP-FedEXP over large models on a mesh.

One jitted ``train_step`` executes a full federated round (Algorithms 1/2 of
the paper) for a cohort of K clients laid out on the client mesh axes:

  1. vmapped local training — each client runs tau local SGD steps on its own
     token microbatches (zero cross-client communication by construction;
     tensor-parallel collectives run *inside* each client),
  2. per-client global-norm clipping of the parameter-update pytrees,
  3. the mechanism's randomization (per-client Gaussian for LDP, server noise
     on the mean for CDP), applied leaf-wise to the update pytrees,
  4. the FedEXP statistics — mean ||c_i||^2, ||cbar||^2 — which GSPMD lowers
     to scalar all-reduces over the client axes (the paper's O(1)-overhead
     claim, checked structurally in EXPERIMENTS.md §Roofline),
  5. the adaptive global step size (Eqs. 6/8) and the model update.

The server rule is NOT hand-rolled here: ``FederatedConfig.algorithm`` is
resolved through ``repro.core.fedexp.make_algorithm`` — the same registry the
``fedsim`` engines use — and the composed ``mechanism x step`` layers supply
the clip threshold, the noise placement/scale, the round-key splits, and the
extrapolation rule (``mechanism.extrapolation``).  This module only owns the
pytree plumbing the flat (M, d) engines cannot: model-parallel local training
and leaf-wise clip/noise/mean over parameter trees.  The local phase is
declared by the session-era ``TrainSpec`` (``trainer.train``).

Supports sequential "virtual clients" per mesh slot (scan) to reach
realistic cohort sizes M >> K without extra memory.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import FederatedConfig
from repro.core.aggregation import RoundStats
from repro.core.compose import CentralGaussian, ComposedAlgorithm, GaussianLDP, NoPrivacy
from repro.core.fedexp import make_algorithm
from repro.fedsim.specs import TrainSpec

__all__ = ["FederatedTrainer"]

# mechanisms with a leaf-wise (pytree) release: clip + Gaussian noise commute
# with flattening, so the flat-engine semantics transfer exactly.  PrivUnit
# does not (its cap sampler needs the whole flat vector) and stays flat-only.
_PYTREE_MECHANISMS = (NoPrivacy, GaussianLDP, CentralGaussian)


def _tree_sq_norm(tree, axes_are_client: bool = False):
    """Sum of squares over all dims except (optionally) the leading client dim."""
    leaves = jax.tree_util.tree_leaves(tree)
    if axes_are_client:
        return sum(jnp.sum(jnp.square(l.astype(jnp.float32)),
                           axis=tuple(range(1, l.ndim))) for l in leaves)
    return sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)


def _tree_noise(key, tree, std):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(key, len(leaves))
    noise = [std * jax.random.normal(k, l.shape, jnp.float32).astype(l.dtype)
             for k, l in zip(keys, leaves)]
    return jax.tree_util.tree_unflatten(treedef, noise)


def _tree_client_mean(tree):
    return jax.tree_util.tree_map(lambda l: jnp.mean(l, axis=0), tree)


@dataclasses.dataclass
class FederatedTrainer:
    model: Any                      # DecoderLM | EncDecLM
    fed: FederatedConfig
    num_params: int                 # d, for the hyperparameter-free sigma_xi

    def __post_init__(self):
        # session-era declaration of the local phase: one train_step is one
        # round of tau local SGD steps at eta_l (TrainSpec validates both)
        self.train = TrainSpec(rounds=1, tau=self.fed.local_steps,
                               eta_l=self.fed.local_lr)

    # ------------------------------------------------------------------

    def server_algorithm(self, m_total: int) -> ComposedAlgorithm:
        """Resolve ``fed.algorithm`` to the composed ``ServerAlgorithm`` for a
        cohort of ``m_total`` clients — the same registry the fedsim engines
        use, restricted to what a stateless pytree train_step can execute."""
        fed = self.fed
        try:
            alg = make_algorithm(fed.algorithm, clip_norm=fed.clip_norm,
                                 sigma=fed.noise_sigma, num_clients=m_total)
        except KeyError as e:
            raise ValueError(
                f"unsupported datacenter algorithm {fed.algorithm!r}: {e}") from e
        if alg.step.stateful:
            raise ValueError(
                f"{fed.algorithm!r} carries server state (FedOpt moments / "
                "adaptive clip); the stateless datacenter train_step supports "
                "fixed-eta and FedEXP steps only — use the fedsim engines")
        if not isinstance(alg.mechanism, _PYTREE_MECHANISMS):
            raise ValueError(
                f"{fed.algorithm!r} uses {type(alg.mechanism).__name__}, which "
                "has no leaf-wise pytree release; the datacenter path supports "
                "NoPrivacy, GaussianLDP and CentralGaussian mechanisms")
        return alg

    # ------------------------------------------------------------------

    def _local_loss(self, params, step_batch):
        if "frames" in step_batch:
            return self.model.loss(params, step_batch["frames"],
                                   step_batch["tokens"], step_batch["labels"])
        return self.model.loss(params, step_batch["tokens"], step_batch["labels"])

    def _local_train(self, params, client_batch):
        """tau local SGD steps (Algorithm 3). client_batch leaves: (tau, b, ...)."""
        eta_l = self.train.eta_l

        def sgd(p, step_batch):
            loss, g = jax.value_and_grad(self._local_loss)(p, step_batch)
            p = jax.tree_util.tree_map(lambda a, b: a - eta_l * b.astype(a.dtype), p, g)
            return p, loss

        p_tau, losses = jax.lax.scan(sgd, params, client_batch)
        delta = jax.tree_util.tree_map(lambda a, b: a - b, p_tau, params)
        return delta, jnp.mean(losses)

    # ------------------------------------------------------------------

    def make_train_step(self, cohort_k: int):
        m_total = cohort_k * self.fed.virtual_clients
        alg = self.server_algorithm(m_total)
        mech = alg.mechanism
        d = self.num_params
        # the mechanism owns the clipping regime: None (NoPrivacy) = no clip,
        # exactly the flat engines' semantics for the same registry name
        clip = getattr(mech, "clip_norm", None)

        def train_step(params, batch, key):
            # batch leaves: (K, tau, b, ...) — vmap over the client axis.
            deltas, losses = jax.vmap(self._local_train, in_axes=(None, 0))(params, batch)

            # --- clip (per-client global L2 over the update pytree) ---
            sq = _tree_sq_norm(deltas, axes_are_client=True)          # (K,)
            norms = jnp.sqrt(jnp.maximum(sq, 1e-24))
            if clip is None:
                clipped = deltas
                mean_sq_clipped = jnp.mean(sq)
            else:
                scale = jnp.minimum(1.0, clip / norms)                # (K,)

                def bcast(s, leaf):
                    return s.reshape((-1,) + (1,) * (leaf.ndim - 1)).astype(jnp.float32)

                clipped = jax.tree_util.tree_map(
                    lambda l: (l.astype(jnp.float32) * bcast(scale, l)).astype(l.dtype),
                    deltas)
                mean_sq_clipped = jnp.mean(jnp.square(jnp.minimum(norms, clip)))

            # --- the composed algorithm's round-key discipline ---
            k_mech, extra_keys = alg._split_keys(key)
            k_xi = extra_keys[0] if extra_keys else None

            # --- mechanism release, leaf-wise over the update pytrees ---
            if isinstance(mech, GaussianLDP):
                noise = _tree_noise(k_mech, clipped, mech.sigma)      # per-client (K, ...)
                released = jax.tree_util.tree_map(jnp.add, clipped, noise)
                mean_sq = jnp.mean(_tree_sq_norm(released, axes_are_client=True))
                cbar = _tree_client_mean(released)
            elif isinstance(mech, CentralGaussian):
                cbar = _tree_client_mean(clipped)
                server_std = mech.sigma / math.sqrt(mech.num_clients)
                cbar = jax.tree_util.tree_map(
                    jnp.add, cbar, _tree_noise(k_mech, cbar, server_std))
                mean_sq = mean_sq_clipped
            else:                                                     # NoPrivacy
                cbar = _tree_client_mean(clipped)
                mean_sq = mean_sq_clipped
            agg_sq = _tree_sq_norm(cbar)

            # --- step size: the mechanism's debiased extrapolation rule ---
            if alg.step.uses_extrapolation:
                # extrapolation reads only the scalar moments; the pytree
                # cbar is applied below, so the stats row slot is a dummy
                stats = RoundStats(cbar=jnp.zeros(()), mean_sq=mean_sq,
                                   agg_sq=agg_sq,
                                   mean_sq_clipped=mean_sq_clipped)
                eta, _, _ = mech.extrapolation(k_xi, stats, {}, d, None,
                                               float(m_total))
            else:
                eta = jnp.float32(alg.step.eta)

            new_params = jax.tree_util.tree_map(
                lambda p, u: (p.astype(jnp.float32) + eta * u.astype(jnp.float32)).astype(p.dtype),
                params, cbar)
            metrics = {
                "loss": jnp.mean(losses),
                "eta_g": eta,
                "mean_update_norm": jnp.mean(norms),
                "agg_sq": agg_sq,
            }
            return new_params, metrics

        return train_step
