"""Datacenter federated train step: DP-FedEXP over large models on a mesh.

One jitted ``train_step`` executes a full federated round (Algorithms 1/2 of
the paper) for a cohort of K clients laid out on the client mesh axes:

  1. vmapped local training — each client runs tau local SGD steps on its own
     token microbatches (zero cross-client communication by construction;
     tensor-parallel collectives run *inside* each client),
  2. per-client global-norm clipping of the parameter-update pytrees,
  3. (LDP) per-client Gaussian randomization / (CDP) server noise on the mean,
  4. the FedEXP statistics — mean ||c_i||^2, ||cbar||^2 — which GSPMD lowers
     to scalar all-reduces over the client axes (the paper's O(1)-overhead
     claim, checked structurally in EXPERIMENTS.md §Roofline),
  5. the adaptive global step size (Eqs. 6/8) and the model update.

Supports sequential "virtual clients" per mesh slot (scan) to reach
realistic cohort sizes M >> K without extra memory.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import FederatedConfig, ModelConfig
from repro.core import stepsize

__all__ = ["FederatedTrainer"]


def _tree_sq_norm(tree, axes_are_client: bool = False):
    """Sum of squares over all dims except (optionally) the leading client dim."""
    leaves = jax.tree_util.tree_leaves(tree)
    if axes_are_client:
        return sum(jnp.sum(jnp.square(l.astype(jnp.float32)),
                           axis=tuple(range(1, l.ndim))) for l in leaves)
    return sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)


def _tree_noise(key, tree, std):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(key, len(leaves))
    noise = [std * jax.random.normal(k, l.shape, jnp.float32).astype(l.dtype)
             for k, l in zip(keys, leaves)]
    return jax.tree_util.tree_unflatten(treedef, noise)


@dataclasses.dataclass
class FederatedTrainer:
    model: Any                      # DecoderLM | EncDecLM
    fed: FederatedConfig
    num_params: int                 # d, for the hyperparameter-free sigma_xi

    # ------------------------------------------------------------------

    def _local_loss(self, params, step_batch):
        if "frames" in step_batch:
            return self.model.loss(params, step_batch["frames"],
                                   step_batch["tokens"], step_batch["labels"])
        return self.model.loss(params, step_batch["tokens"], step_batch["labels"])

    def _local_train(self, params, client_batch):
        """tau local SGD steps (Algorithm 3). client_batch leaves: (tau, b, ...)."""
        eta_l = self.fed.local_lr

        def sgd(p, step_batch):
            loss, g = jax.value_and_grad(self._local_loss)(p, step_batch)
            p = jax.tree_util.tree_map(lambda a, b: a - eta_l * b.astype(a.dtype), p, g)
            return p, loss

        p_tau, losses = jax.lax.scan(sgd, params, client_batch)
        delta = jax.tree_util.tree_map(lambda a, b: a - b, p_tau, params)
        return delta, jnp.mean(losses)

    # ------------------------------------------------------------------

    def make_train_step(self, cohort_k: int):
        fed = self.fed
        alg = fed.algorithm
        c = fed.clip_norm
        sigma = fed.noise_sigma
        m_total = cohort_k * fed.virtual_clients
        d = self.num_params
        sigma_xi = d * sigma**2 / m_total

        def train_step(params, batch, key):
            # batch leaves: (K, tau, b, ...) — vmap over the client axis.
            deltas, losses = jax.vmap(self._local_train, in_axes=(None, 0))(params, batch)

            # --- clip (per-client global L2 over the update pytree) ---
            sq = _tree_sq_norm(deltas, axes_are_client=True)          # (K,)
            norms = jnp.sqrt(jnp.maximum(sq, 1e-24))
            scale = jnp.minimum(1.0, c / norms)                       # (K,)

            def bcast(s, leaf):
                return s.reshape((-1,) + (1,) * (leaf.ndim - 1)).astype(jnp.float32)

            clipped = jax.tree_util.tree_map(
                lambda l: (l.astype(jnp.float32) * bcast(scale, l)).astype(l.dtype), deltas)
            clipped_sq = jnp.square(jnp.minimum(norms, c))            # (K,)
            mean_sq_clipped = jnp.mean(clipped_sq)

            k_noise, k_xi = jax.random.split(key)

            if alg in ("ldp-fedexp-gauss", "dp-fedavg-ldp-gauss"):
                noise = _tree_noise(k_noise, clipped, sigma)          # per-client (K, ...)
                released = jax.tree_util.tree_map(jnp.add, clipped, noise)
                mean_sq = jnp.mean(_tree_sq_norm(released, axes_are_client=True))
                cbar = jax.tree_util.tree_map(lambda l: jnp.mean(l, axis=0), released)
                agg_sq = _tree_sq_norm(cbar)
                if alg == "ldp-fedexp-gauss":
                    eta = stepsize.ldp_gaussian(mean_sq, agg_sq, d, sigma)
                else:
                    eta = jnp.float32(1.0)
            elif alg in ("cdp-fedexp", "dp-fedavg-cdp"):
                cbar = jax.tree_util.tree_map(lambda l: jnp.mean(l, axis=0), clipped)
                server_std = sigma / math.sqrt(m_total)
                noise = _tree_noise(k_noise, cbar, server_std)
                cbar = jax.tree_util.tree_map(jnp.add, cbar, noise)
                agg_sq = _tree_sq_norm(cbar)
                if alg == "cdp-fedexp":
                    xi = sigma_xi * jax.random.normal(k_xi, ())
                    eta = stepsize.cdp(mean_sq_clipped, xi, agg_sq)
                else:
                    eta = jnp.float32(1.0)
            elif alg in ("fedexp", "fedavg"):
                cbar = jax.tree_util.tree_map(lambda l: jnp.mean(l, axis=0), clipped)
                agg_sq = _tree_sq_norm(cbar)
                eta = stepsize.fedexp(mean_sq_clipped, agg_sq) if alg == "fedexp" \
                    else jnp.float32(1.0)
            else:
                raise ValueError(f"unknown datacenter algorithm {alg!r}")

            new_params = jax.tree_util.tree_map(
                lambda p, u: (p.astype(jnp.float32) + eta * u.astype(jnp.float32)).astype(p.dtype),
                params, cbar)
            metrics = {
                "loss": jnp.mean(losses),
                "eta_g": eta,
                "mean_update_norm": jnp.mean(norms),
                "agg_sq": agg_sq,
            }
            return new_params, metrics

        return train_step
