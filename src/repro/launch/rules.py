"""Physical sharding rules: logical axis names -> mesh axes, per arch x mode.

See DESIGN.md §4. Two regimes:

- **standard** (fits replicated-per-client): clients enumerate the data axis
  (x pod axis multi-pod); tensor parallelism over the model axis.
- **giant** (>= ~20B params — command-r-plus-104b, llama4-maverick-400b,
  chameleon-34b): a client's parameters diverge during local steps, so they
  cannot be FSDP-sharded *across clients*; instead ONE client spans the whole
  (data, model) grid — batch parallel over data, tensor parallel over model,
  param storage additionally sharded over data on the embed dim (FSDP-style;
  XLA all-gathers per layer inside the scan) — and the cohort axis is the pod
  axis (multi-pod) or handled by sequential virtual clients (single-pod).

``safe_pspec`` drops any mesh axis that does not divide the concrete dim
(e.g. vocab 49155 % 16 != 0 -> replicated embedding), so every (arch x shape)
pair lowers without manual case work; the roofline table shows what it costs.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.sharding import logical_to_pspec

__all__ = ["GIANT_PARAM_THRESHOLD", "count_params", "is_giant", "make_rules",
           "safe_pspec", "tree_shardings"]

GIANT_PARAM_THRESHOLD = 20e9


def count_params(model, key=None) -> int:
    """Exact parameter count via eval_shape (no allocation)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    shapes = jax.eval_shape(model.init, key)
    return int(sum(np.prod(l.shape) for l in jax.tree_util.tree_leaves(shapes)))


def is_giant(cfg: ModelConfig, num_params: int) -> bool:
    return num_params >= GIANT_PARAM_THRESHOLD


def make_rules(cfg: ModelConfig, mesh: Mesh, *, mode: str, num_params: int) -> dict[str, Any]:
    """mode: 'train' | 'serve'."""
    has_pod = "pod" in mesh.axis_names
    giant = is_giant(cfg, num_params)
    from repro.models.sharding import AXIS_SIZES_KEY
    rules: dict[str, Any] = {
        AXIS_SIZES_KEY: dict(zip(mesh.axis_names, mesh.devices.shape)),
        "heads": "model",
        "ff": "model",
        "vocab": "model",
        "experts": "model",
        "embed": None,
        "layers": None,
        "seq": None,
    }
    if mode == "train":
        if giant:
            rules["clients"] = "pod" if has_pod else None
            rules["batch"] = "data"
            rules["embed"] = "data"           # FSDP-style param storage
            # group-local MoE dispatch measured a 6x collective REGRESSION
            # for giant-arch training (expert-combine AR over the model
            # axis, x remat/backward) with no memory benefit — serve keeps
            # it (it is what makes prefill/decode fit HBM). §Perf HC2.
            rules["moe_group_dispatch"] = False
        else:
            rules["clients"] = ("pod", "data") if has_pod else "data"
            rules["batch"] = None
    else:
        rules["clients"] = None
        rules["batch"] = ("pod", "data") if has_pod else "data"
        # sequence-sharded KV cache: kv heads rarely divide the model axis
        # (GQA kv=8 vs 16) which would replicate the cache + all-gather it
        # every step; the 32k/500k cache seq dim always divides. Scores are
        # then psum'ed over the model axis (tiny next to the cache reads).
        rules["kv_seq"] = "model"
        if giant:
            rules["embed"] = "data"
    return rules


def _axis_sizes(mesh: Mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def safe_pspec(shape: tuple[int, ...], logical: tuple, rules: dict, mesh: Mesh) -> P:
    """logical names -> PartitionSpec, dropping axes that don't divide dims."""
    sizes = _axis_sizes(mesh)
    raw = logical_to_pspec(tuple(logical), rules)
    out = []
    for dim, ax in zip(shape, tuple(raw) + (None,) * (len(shape) - len(raw))):
        if ax is None:
            out.append(None)
            continue
        axes = (ax,) if isinstance(ax, str) else tuple(ax)
        total = math.prod(sizes[a] for a in axes)
        out.append(ax if dim % total == 0 else None)
    return P(*out)


def tree_shardings(mesh: Mesh, shapes_tree, logical_tree, rules: dict):
    """Build a NamedSharding pytree from shapes + logical-axes pytrees."""
    # leaves of shapes_tree are ShapeDtypeStructs; the matching nodes of
    # logical_tree (tuples of axis names) are treated as leaves by tree_map.
    return jax.tree_util.tree_map(
        lambda s, l: NamedSharding(mesh, safe_pspec(s.shape, l, rules, mesh)),
        shapes_tree, logical_tree)
