import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input shape) on the
production mesh, and extract the roofline terms from the compiled artifact.

MUST be invoked as its own process (``python -m repro.launch.dryrun``) so the
XLA_FLAGS above precede any jax initialization — do not import this module
from tests/benches (they must keep seeing 1 device).

Usage:
    python -m repro.launch.dryrun --arch gemma-2b --shape train_4k
    python -m repro.launch.dryrun --arch all --shape all --multi-pod
    python -m repro.launch.dryrun ... --out results/dryrun

Per combo it writes ``<out>/<arch>__<shape>__<mesh>.json`` with:
  memory_analysis (bytes per device), cost_analysis (flops/bytes), collective
  bytes by kind, the roofline terms, MODEL_FLOPS, the useful-compute ratio
  and, for train shapes, the registry-resolved federated algorithm.
"""
import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, SHAPES, FederatedConfig
from repro.launch import specs as specs_mod
from repro.launch.hlo_cost import hlo_cost
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import model_flops, roofline_terms, HW
from repro.launch.rules import count_params, make_rules, safe_pspec, tree_shardings
from repro.launch.serve import ServeEngine
from repro.launch.train import FederatedTrainer
from repro.models.encdec import EncDecLM
from repro.models.sharding import axis_rules
from repro.models.transformer import DecoderLM


def build_model(cfg, *, attn_impl: str = "xla_flash", remat_policy: str | None = None):
    if cfg.arch_type == "audio":
        return EncDecLM(cfg, dtype=jnp.bfloat16, attn_impl=attn_impl)
    return DecoderLM(cfg, dtype=jnp.bfloat16, attn_impl=attn_impl,
                     remat_policy=remat_policy)


def active_params(cfg, model, total: int) -> int:
    """6*N_active*D convention for MoE: router always, top_k/E of expert mass."""
    if not cfg.num_experts:
        return total
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    expert_mass = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        if name in ("moe_wi", "moe_wo"):
            expert_mass += int(np.prod(leaf.shape))
    return total - expert_mass + int(expert_mass * cfg.top_k / cfg.num_experts)


def _param_shardings(mesh, model, rules):
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    return shapes, tree_shardings(mesh, shapes, model.pspecs(), rules)


def _key_spec(mesh):
    spec = jax.eval_shape(lambda: jax.random.PRNGKey(0))
    return spec, NamedSharding(mesh, P())


def lower_one(arch: str, shape_name: str, *, multi_pod: bool,
              fed: FederatedConfig, attn_impl: str = "xla_flash",
              remat_policy: str | None = None):
    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(mesh.devices.shape))
    model = build_model(cfg, attn_impl=attn_impl, remat_policy=remat_policy)
    n_params = count_params(model)
    mode = "train" if shape.kind == "train" else "serve"
    rules = make_rules(cfg, mesh, mode=mode, num_params=n_params)

    with axis_rules(rules):
        pshapes, pshard = _param_shardings(mesh, model, rules)
        kspec, kshard = _key_spec(mesh)

        if shape.kind == "train":
            k = specs_mod.cohort_size(mesh, rules)
            bshapes, blogical = specs_mod.train_input_specs(cfg, shape, fed, mesh, rules)
            bshard = specs_mod.tree_input_shardings(mesh, bshapes, blogical, rules)
            trainer = FederatedTrainer(model, fed, n_params)
            # resolve through the fedsim registry up front: an unsupported
            # algorithm fails here with a clear message, not deep in lowering
            alg = trainer.server_algorithm(k * fed.virtual_clients)
            # full frozen-spec identity (§15): the same deterministic string
            # FederatedSession.spec_identity() renders, so a dry-run artifact
            # is attributable to the exact spec set a launched run binds
            spec_identity = " | ".join([
                f"algorithm={alg.name}",
                f"train={trainer.train!r}",
                f"fed={fed!r}",
                f"mesh[{','.join(f'{a}={n}' for a, n in sorted(dict(mesh.shape).items()))}]",
                f"cohort_k={k}", f"virtual_clients={fed.virtual_clients}"])
            fed_info = {"algorithm": alg.name, "is_private": alg.is_private,
                        "cohort_k": k, "tau": trainer.train.tau,
                        "eta_l": trainer.train.eta_l,
                        "spec_identity": spec_identity}
            step = trainer.make_train_step(cohort_k=k)
            jitted = jax.jit(step, in_shardings=(pshard, bshard, kshard),
                             out_shardings=(pshard, None))
            with mesh:
                lowered = jitted.lower(pshapes, bshapes, kspec)
            tokens = shape.global_batch * fed.local_steps * (
                specs_mod.WHISPER_DECODER_LEN if cfg.arch_type == "audio" else shape.seq_len)
        else:
            engine = ServeEngine(model, is_encdec=cfg.arch_type == "audio")
            if shape.kind == "decode":
                ishapes, ilogical = specs_mod.decode_input_specs(cfg, shape, mesh, rules, model)
                ishard = specs_mod.tree_input_shardings(mesh, ishapes, ilogical, rules)
                step = engine.make_decode_step()
                args = (pshapes, ishapes["token"], ishapes["pos"], ishapes["caches"])
                shards = (pshard, ishard["token"], ishard["pos"], ishard["caches"])
                if cfg.arch_type == "audio":
                    args += (ishapes["enc_out"],)
                    shards += (ishard["enc_out"],)
                jitted = jax.jit(step, in_shardings=shards,
                                 out_shardings=(None, None, ishard["caches"]))
                with mesh:
                    lowered = jitted.lower(*args)
                tokens = shape.global_batch
            else:  # prefill
                ishapes, ilogical = specs_mod.prefill_input_specs(cfg, shape, mesh, rules, model)
                ishard = specs_mod.tree_input_shardings(mesh, ishapes, ilogical, rules)
                step = engine.make_prefill_step()
                if cfg.arch_type == "audio":
                    args = (pshapes, ishapes["frames"], ishapes["tokens"], ishapes["caches"])
                    shards = (pshard, ishard["frames"], ishard["tokens"], ishard["caches"])
                    out_shards = (None, ishard["caches"], None)
                else:
                    args = (pshapes, ishapes["tokens"], ishapes["caches"])
                    shards = (pshard, ishard["tokens"], ishard["caches"])
                    out_shards = (None, ishard["caches"])
                jitted = jax.jit(step, in_shardings=shards, out_shardings=out_shards)
                with mesh:
                    lowered = jitted.lower(*args)
                tokens = shape.global_batch * shape.seq_len

    return lowered, dict(cfg=cfg, model=model, n_params=n_params, chips=chips,
                         tokens=tokens, kind=shape.kind, rules=rules,
                         fed_info=fed_info if shape.kind == "train" else None)


def run_one(arch: str, shape_name: str, *, multi_pod: bool, out_dir: str,
            fed: FederatedConfig, attn_impl: str = "xla_flash",
            tag: str = "", remat_policy: str | None = None) -> dict:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    t0 = time.time()
    lowered, info = lower_one(arch, shape_name, multi_pod=multi_pod, fed=fed,
                              attn_impl=attn_impl, remat_policy=remat_policy)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    hlo = compiled.as_text()
    # structural walk with while-trip-count multiplication (hlo_cost.py);
    # the raw cost_analysis (loop bodies counted once) is kept for reference.
    walked = hlo_cost(hlo)
    coll = walked["collective_bytes"]
    coll_total = walked["collective_total"]

    flops = walked["flops"]
    bytes_acc = walked["bytes"]
    terms = roofline_terms(flops, bytes_acc, coll_total)
    mflops = model_flops(info["n_params"],
                         active_params(info["cfg"], info["model"], info["n_params"]),
                         info["tokens"], info["kind"])

    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "chips": info["chips"],
        "kind": info["kind"],
        "fed": info["fed_info"],
        "num_params": info["n_params"],
        "tokens_per_step": info["tokens"],
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
        "cost": {"flops": flops, "bytes_accessed": bytes_acc,
                 "raw_xla_flops": float(cost.get("flops", 0.0)),
                 "raw_xla_bytes": float(cost.get("bytes accessed", 0.0)),
                 "unknown_loops": walked["unknown_loops"]},
        "collective_bytes": coll,
        "collective_total": coll_total,
        "roofline": terms,
        "model_flops": mflops,
        "useful_ratio": (mflops / info["chips"]) / flops if flops else None,
        "hlo_lines": hlo.count("\n"),
    }
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        name = f"{arch}__{shape_name}__{mesh_name}{tag}.json"
        with open(os.path.join(out_dir, name), "w") as f:
            json.dump(result, f, indent=1)
    return result


def eligible(arch: str, shape_name: str) -> bool:
    cfg = ARCHS[arch]
    if shape_name == "long_500k" and not cfg.subquadratic:
        return False  # dense full-attention archs skip 500k decode (DESIGN.md §6)
    return True


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--attn-impl", default="xla_flash")
    ap.add_argument("--remat-policy", default=None)
    ap.add_argument("--tau", type=int, default=2)
    ap.add_argument("--algorithm", default="cdp-fedexp")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    archs = list(ARCHS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    fed = FederatedConfig(algorithm=args.algorithm, local_steps=args.tau)

    failures = []
    for arch in archs:
        for shape in shapes:
            if not eligible(arch, shape):
                print(f"SKIP  {arch} x {shape} (full-attention arch; long_500k gate)")
                continue
            try:
                r = run_one(arch, shape, multi_pod=args.multi_pod, out_dir=args.out,
                            fed=fed, attn_impl=args.attn_impl, tag=args.tag,
                            remat_policy=args.remat_policy)
                rt = r["roofline"]
                print(f"OK    {arch} x {shape} [{r['mesh']}] "
                      f"compile={r['compile_s']}s flops={r['cost']['flops']:.3g} "
                      f"coll={r['collective_total']:.3g}B "
                      f"bottleneck={rt['bottleneck']}", flush=True)
            except Exception as e:  # noqa: BLE001 - report-and-continue driver
                failures.append((arch, shape, repr(e)))
                print(f"FAIL  {arch} x {shape}: {e!r}", flush=True)
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} failures:")
        for f in failures:
            print(" ", f)
        sys.exit(1)
    print("\nall dry-runs passed")


if __name__ == "__main__":
    main()
