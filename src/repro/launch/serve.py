"""Serving steps for the decode/prefill input shapes.

``decode_32k`` / ``long_500k`` lower ``serve_step`` — ONE new token against a
KV/SSM cache of ``seq_len`` — and ``prefill_32k`` lowers the prefill step.
Batched requests share a uniform position counter (the continuous-batching
generalization would carry per-request positions; uniform pos is the shape-
and collective-identical case and keeps the dry-run honest).

In a DP-FL deployment these serve the *global* model, so there is no client
axis: batch shards over (pod, data) and tensor parallelism over model.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["ServeEngine"]


@dataclasses.dataclass
class ServeEngine:
    model: Any                 # DecoderLM | EncDecLM
    is_encdec: bool = False

    def make_decode_step(self):
        model = self.model

        if self.is_encdec:
            def decode_step(params, token, pos, caches, enc_out):
                logits, caches = model.decode_step(params, token, pos, enc_out, caches)
                next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                return next_tok, logits, caches
        else:
            def decode_step(params, token, pos, caches):
                logits, caches = model.decode_step(params, token, pos, caches)
                next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                return next_tok, logits, caches

        return decode_step

    def make_prefill_step(self):
        model = self.model

        if self.is_encdec:
            def prefill_step(params, frames, tokens, caches):
                enc_out = model.encode(params, frames)
                logits, caches = model.decode(params, tokens, enc_out, caches=caches)
                next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
                return next_tok, caches, enc_out
        else:
            def prefill_step(params, tokens, caches):
                logits, caches = model.prefill(params, tokens, caches)
                next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                return next_tok, caches

        return prefill_step

    def generate(self, params, prompt_tokens, max_new: int, cache_len: int, dtype=None):
        """Greedy generation loop (examples / integration tests; CPU-sized)."""
        model = self.model
        b, s = prompt_tokens.shape
        caches = model.init_cache(b, cache_len, dtype=dtype)
        logits, caches = model.prefill(params, prompt_tokens, caches)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        decode = jax.jit(self.make_decode_step())

        out = [tok]
        pos = jnp.int32(s)
        for _ in range(max_new - 1):
            tok, _, caches = decode(params, tok, pos, caches)
            out.append(tok)
            pos = pos + 1
        return jnp.stack(out, axis=1)  # (B, max_new)
