"""Pallas TPU kernels for the compute hot-spots (validated in interpret mode).

- ``dp_aggregate``    — fused clip+noise+aggregate server reduction (the
  paper's per-round hot loop over the (M, d) update matrix).
- ``flash_attention`` — blockwise online-softmax attention (causal, sliding
  window, GQA/MQA) for the transformer architectures.
- ``ssd_scan``        — Mamba2 chunked state-space-duality scan for the
  SSM/hybrid architectures.
"""

from repro.kernels.dp_aggregate import dp_aggregate
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssd_scan import ssd_scan

__all__ = ["dp_aggregate", "flash_attention", "ssd_scan"]
