"""Jitted public wrapper for the flash attention kernel (pads to tile grid)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_kernel_call

__all__ = ["flash_attention"]


def _pad_to(x: jax.Array, axis: int, multiple: int) -> jax.Array:
    pad = (-x.shape[axis]) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k", "interpret"))
def flash_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    causal: bool = True,
    window: int | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Blockwise attention; q (B, Hq, Sq, Dh), k/v (B, Hkv, Skv, Dh)."""
    sq, skv, dh = q.shape[2], k.shape[2], q.shape[3]
    bq = min(block_q, max(8, sq))
    bk = min(block_k, max(8, skv))
    qp = _pad_to(q, 2, bq)
    kp = _pad_to(k, 2, bk)
    vp = _pad_to(v, 2, bk)
    out = flash_attention_kernel_call(
        qp, kp, vp, causal=causal, window=window, kv_len=skv,
        block_q=bq, block_k=bk, interpret=interpret)
    return out[:, :, :sq, :]
