from repro.kernels.flash_attention import ops, ref
from repro.kernels.flash_attention.ops import flash_attention

__all__ = ["ops", "ref", "flash_attention"]
