"""Blockwise (flash) attention kernel for TPU (Pallas).

Online-softmax attention with causal and sliding-window masking and GQA/MQA
head sharing. This is the compute hot-spot of every attention architecture in
the assigned pool; on TPU the kernel holds a (block_q x head_dim) accumulator
plus running max/denominator in VMEM scratch while streaming (block_k x
head_dim) K/V tiles from HBM, so the S x S score matrix is never materialized.

Tiling: grid = (batch*q_heads, S_q/block_q, S_kv/block_k) with the k-block
axis innermost (TPU grids execute sequentially in row-major order, which is
what makes the scratch carry correct). Blocks outside the causal/window band
are skipped via pl.when (a production variant would shrink the grid; masking
keeps the kernel simple and the skipped-block cost is loads only).

MXU alignment: block_q/block_k default to 128 and head_dim is padded to a
multiple of 128 by the wrapper.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_kernel_call"]

_NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, window: int | None,
            kv_len: int, block_q: int, block_k: int, num_kb: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = iq * block_q
    k_start = ik * block_k
    # Band check: is any (q, k) pair in this block-pair visible?
    needed = k_start < kv_len
    if causal:
        needed &= k_start <= q_start + block_q - 1
    if window is not None:
        needed &= k_start + block_k - 1 >= q_start - window + 1

    @pl.when(needed)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale              # (bq, dh)
        k = k_ref[0].astype(jnp.float32)                      # (bk, dh)
        v = v_ref[0].astype(jnp.float32)                      # (bk, dh)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)

        q_idx = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        k_idx = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = k_idx < kv_len
        if causal:
            mask &= k_idx <= q_idx
        if window is not None:
            mask &= k_idx > q_idx - window
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[...]                                   # (bq, 1)
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_cur)
        alpha = jnp.exp(m_prev - m_cur)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot(p, v)
        m_ref[...] = m_cur

    @pl.when(ik == num_kb - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_kernel_call(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    causal: bool = True,
    window: int | None = None,
    kv_len: int,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """q: (B, Hq, Sq, Dh); k, v: (B, Hkv, Skv, Dh), Sq/Skv multiples of blocks.

    Returns (B, Hq, Sq, Dh).  ``kv_len`` is the un-padded KV length (padding
    columns are masked inside the kernel).
    """
    b, hq, sq, dh = q.shape
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    num_qb, num_kb = sq // block_q, skv // block_k

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        kv_len=kv_len, block_q=block_q, block_k=block_k, num_kb=num_kb)

    grid = (b * hq, num_qb, num_kb)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, block_k, dh), lambda bh, iq, ik, g=group, h=hq: (bh // h * (h // g) + (bh % h) // g, ik, 0)),
            pl.BlockSpec((1, block_k, dh), lambda bh, iq, ik, g=group, h=hq: (bh // h * (h // g) + (bh % h) // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dh), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, dh), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q.reshape(b * hq, sq, dh), k.reshape(b * hkv, skv, dh), v.reshape(b * hkv, skv, dh))
    return out.reshape(b, hq, sq, dh)
