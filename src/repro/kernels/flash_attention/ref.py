"""Dense-softmax oracle for the flash attention kernel."""
from __future__ import annotations

import math

import jax.numpy as jnp


def attention_ref(q, k, v, *, causal=True, window=None, scale=None):
    """q: (B, Hq, Sq, Dh); k, v: (B, Hkv, Skv, Dh). Dense reference."""
    b, hq, sq, dh = q.shape
    _, hkv, skv, _ = k.shape
    group = hq // hkv
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    q_idx = jnp.arange(sq)[:, None]
    k_idx = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= k_idx <= q_idx
    if window is not None:
        mask &= k_idx > q_idx - window
    s = jnp.where(mask, s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)
