"""Fused DP aggregation kernel (Pallas TPU).

Server hot loop of Algorithms 1/2: given the raw (M, d) client-update matrix
(and optionally an (M, d) LDP noise matrix), produce in ONE pass over HBM:

    sum_released     (d,)  = sum_i clip(u_i) + n_i
    sum_sq_released  (1,1) = sum_i ||clip(u_i) + n_i||^2     (FedEXP numerator)
    sum_sq_clipped   (1,1) = sum_i ||clip(u_i)||^2           (CDP numerator)

The naive composition (norms pass, scale pass, reduce pass) reads the update
matrix three times; at fedsim scale (M=1000, d up to ~1e5) the op is purely
memory-bound, so the fusion is a ~3x bandwidth win on TPU.

Tiling: grid over row blocks; each program holds a (block_m, d) tile in VMEM
(d padded to the 128-lane boundary by the wrapper). TPU grid execution is
sequential, so outputs are accumulated across grid steps with a first-step
initialization guard — the standard Pallas reduction pattern.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["dp_aggregate_kernel_call"]

_EPS = 1e-12


def _kernel(u_ref, n_ref, sum_ref, sq_rel_ref, sq_clip_ref, *, clip_norm: float, with_noise: bool):
    step = pl.program_id(0)

    u = u_ref[...].astype(jnp.float32)                      # (bm, d)
    sq_norms = jnp.sum(u * u, axis=1, keepdims=True)        # (bm, 1)
    scale = jnp.minimum(1.0, clip_norm / jnp.sqrt(jnp.maximum(sq_norms, _EPS)))
    clipped = u * scale
    sq_clipped = jnp.sum(clipped * clipped, axis=1)         # (bm,)

    if with_noise:
        released = clipped + n_ref[...].astype(jnp.float32)
    else:
        released = clipped
    sq_released = jnp.sum(released * released, axis=1)      # (bm,)

    part_sum = jnp.sum(released, axis=0, keepdims=True)     # (1, d)
    part_sq_rel = jnp.sum(sq_released)[None, None]          # (1, 1)
    part_sq_clip = jnp.sum(sq_clipped)[None, None]

    @pl.when(step == 0)
    def _init():
        sum_ref[...] = part_sum
        sq_rel_ref[...] = part_sq_rel
        sq_clip_ref[...] = part_sq_clip

    @pl.when(step != 0)
    def _accum():
        sum_ref[...] += part_sum
        sq_rel_ref[...] += part_sq_rel
        sq_clip_ref[...] += part_sq_clip


def dp_aggregate_kernel_call(
    updates: jax.Array,
    noise: jax.Array | None,
    clip_norm: float,
    *,
    block_m: int = 8,
    interpret: bool = True,
):
    """Invoke the fused kernel. Expects M % block_m == 0 and d % 128 == 0
    (the ops.py wrapper pads). Returns (sum_released, sum_sq_released,
    sum_sq_clipped)."""
    m, d = updates.shape
    assert m % block_m == 0, (m, block_m)
    with_noise = noise is not None
    if noise is None:  # dummy operand keeps the kernel signature static
        noise = jnp.zeros((block_m, d), updates.dtype)
        noise_spec = pl.BlockSpec((block_m, d), lambda i: (0, 0))
    else:
        noise_spec = pl.BlockSpec((block_m, d), lambda i: (i, 0))

    kernel = functools.partial(_kernel, clip_norm=float(clip_norm), with_noise=with_noise)
    out = pl.pallas_call(
        kernel,
        grid=(m // block_m,),
        in_specs=[pl.BlockSpec((block_m, d), lambda i: (i, 0)), noise_spec],
        out_specs=[
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, d), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(updates, noise)
    sum_released, sq_rel, sq_clip = out
    return sum_released[0], sq_rel[0, 0], sq_clip[0, 0]
