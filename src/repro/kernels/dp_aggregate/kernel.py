"""Fused DP aggregation kernel (Pallas TPU) with optional in-kernel noise.

Server hot loop of Algorithms 1/2: given the raw (M, d) client-update matrix
(and optionally an (M, d) LDP noise matrix), produce in ONE pass over HBM:

    sum_released     (d,)  = sum_i clip(u_i) + n_i
    sum_sq_released  (1,1) = sum_i ||clip(u_i) + n_i||^2     (FedEXP numerator)
    sum_sq_clipped   (1,1) = sum_i ||clip(u_i)||^2           (CDP numerator)

The naive composition (norms pass, scale pass, reduce pass) reads the update
matrix three times; at fedsim scale (M=1000, d up to ~1e5) the op is purely
memory-bound, so the fusion is a ~3x bandwidth win on TPU.

Noise modes (DESIGN.md §8):
    "none"      CDP — no per-client noise.
    "operand"   LDP with a pre-materialized (M, d) noise matrix streamed in.
    "fused"     LDP with the Gaussian noise drawn INSIDE the kernel from a
                scalar-prefetched seed: on compiled TPU via the hardware PRNG
                (``pltpu.prng_seed`` + ``prng_random_bits``), in interpreter
                mode via an in-kernel Threefry-2x32 counter PRF (the same PRF
                family JAX's host RNG uses); both feed a Box-Muller transform.
                This removes the (M, d) noise write+read from HBM entirely —
                a further ~3x traffic cut over "operand" for the LDP round.

Scalars (clip threshold, noise sigma, seed, true M/d before padding) arrive
via scalar prefetch so traced values — e.g. the adaptive-clip threshold that
changes every round — do not force recompilation.

Tiling: grid over row blocks; each program holds a (block_m, d) tile in VMEM
(d padded to the 128-lane boundary by the wrapper, M padded to the row-block).
TPU grid execution is sequential, so outputs are accumulated across grid steps
with a first-step initialization guard — the standard Pallas reduction
pattern.  The column sum is computed as ``ones @ tile`` (MXU on TPU, BLAS in
interpreter mode) because plain axis-0 reduces are far off bandwidth on both.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["dp_aggregate_kernel_call", "ldp_noise_kernel_call"]

_EPS = 1e-12
_THREEFRY_C = 0x1BD11BDA     # Threefry key-schedule constant
_GOLDEN = 0x9E3779B9         # second key word for the in-kernel PRF


def _rotl(x, r: int):
    return (x << jnp.uint32(r)) | (x >> jnp.uint32(32 - r))


def _threefry2x32(k0, k1, x0, x1):
    """Vectorized 20-round Threefry-2x32 block cipher (counter-mode PRF)."""
    rot = ((13, 15, 26, 6), (17, 29, 16, 24))
    ks = (k0, k1, k0 ^ k1 ^ jnp.uint32(_THREEFRY_C))
    x0 = x0 + ks[0]
    x1 = x1 + ks[1]
    for j in range(1, 6):
        for r in rot[(j - 1) % 2]:
            x0 = x0 + x1
            x1 = _rotl(x1, r)
            x1 = x1 ^ x0
        x0 = x0 + ks[j % 3]
        x1 = x1 + ks[(j + 1) % 3] + jnp.uint32(j)
    return x0, x1


def _bits_to_unit(bits):
    """uint32 -> float32 uniform in the OPEN interval (0, 1) (top 24 bits)."""
    return ((bits >> jnp.uint32(8)).astype(jnp.float32) + 0.5) * jnp.float32(2.0**-24)


def _noise_block(seed, step, shape, *, tpu_prng: bool):
    """One (block_m, d) tile of standard Gaussian noise.

    ``seed`` is an int32 scalar; ``step`` the row-block index, mixed into the
    stream so every block draws independent noise.  Returns float32 N(0, 1).
    """
    if tpu_prng:
        pltpu.prng_seed(seed, step)
        b0 = pltpu.bitcast(pltpu.prng_random_bits(shape), jnp.uint32)
        b1 = pltpu.bitcast(pltpu.prng_random_bits(shape), jnp.uint32)
    else:
        bm, d = shape
        lane = (jax.lax.broadcasted_iota(jnp.uint32, shape, 0) * jnp.uint32(d)
                + jax.lax.broadcasted_iota(jnp.uint32, shape, 1))
        k0 = jax.lax.bitcast_convert_type(seed, jnp.uint32)
        b0, b1 = _threefry2x32(k0, jnp.uint32(_GOLDEN), lane,
                               jnp.full(shape, step, jnp.uint32))
    # Box-Muller: two uniform streams -> one standard-normal tile.
    r = jnp.sqrt(-2.0 * jnp.log(_bits_to_unit(b0)))
    return r * jnp.cos(jnp.float32(2.0 * jnp.pi) * _bits_to_unit(b1))


def _kernel(meta_i_ref, meta_f_ref, u_ref, *refs,
            noise_mode: str, tpu_prng: bool):
    if noise_mode == "operand":
        n_ref, sum_ref, sq_rel_ref, sq_clip_ref = refs
    else:
        sum_ref, sq_rel_ref, sq_clip_ref = refs
    step = pl.program_id(0)
    clip_norm = meta_f_ref[0]
    sigma = meta_f_ref[1]
    seed = meta_i_ref[0]
    m_true = meta_i_ref[1]
    d_true = meta_i_ref[2]

    u = u_ref[...].astype(jnp.float32)                      # (bm, d)
    bm, d = u.shape
    sq_norms = jnp.sum(u * u, axis=1, keepdims=True)        # (bm, 1)
    scale = jnp.minimum(1.0, clip_norm / jnp.sqrt(jnp.maximum(sq_norms, _EPS)))
    clipped = u * scale
    sq_clipped = sq_norms[:, 0] * scale[:, 0] ** 2          # (bm,)

    if noise_mode == "operand":
        released = clipped + n_ref[...].astype(jnp.float32)
        sq_released = jnp.sum(released * released, axis=1)
    elif noise_mode == "fused":
        # Padded rows/cols must draw ZERO noise: the wrapper pads u with
        # zeros, which clip to zero, but generated noise would otherwise
        # leak into the sums.
        rows = jax.lax.broadcasted_iota(jnp.int32, (bm, d), 0) + step * bm
        cols = jax.lax.broadcasted_iota(jnp.int32, (bm, d), 1)
        valid = (rows < m_true) & (cols < d_true)
        noise = jnp.where(valid, sigma * _noise_block(seed, step, (bm, d),
                                                      tpu_prng=tpu_prng), 0.0)
        released = clipped + noise
        sq_released = jnp.sum(released * released, axis=1)
    else:
        released = clipped
        sq_released = sq_clipped

    ones = jnp.ones((1, bm), jnp.float32)
    part_sum = jax.lax.dot_general(                         # (1, d) column sum
        ones, released, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    part_sq_rel = jnp.sum(sq_released)[None, None]          # (1, 1)
    part_sq_clip = jnp.sum(sq_clipped)[None, None]

    @pl.when(step == 0)
    def _init():
        sum_ref[...] = part_sum
        sq_rel_ref[...] = part_sq_rel
        sq_clip_ref[...] = part_sq_clip

    @pl.when(step != 0)
    def _accum():
        sum_ref[...] += part_sum
        sq_rel_ref[...] += part_sq_rel
        sq_clip_ref[...] += part_sq_clip


def dp_aggregate_kernel_call(
    updates: jax.Array,
    noise: jax.Array | None,
    clip_norm,
    *,
    noise_sigma=None,
    noise_seed=None,
    m_true: int | None = None,
    d_true: int | None = None,
    block_m: int = 8,
    interpret: bool = True,
):
    """Invoke the fused kernel.  Expects M % block_m == 0 and d % 128 == 0
    (the ops.py wrapper pads).  ``noise_seed`` (int32 scalar) switches on
    in-kernel noise generation of std ``noise_sigma``; a materialized
    ``noise`` operand is streamed instead when given.  Returns
    (sum_released, sum_sq_released, sum_sq_clipped)."""
    m, d = updates.shape
    assert m % block_m == 0, (m, block_m)
    if noise is not None and noise_seed is not None:
        raise ValueError("materialized noise and in-kernel noise are exclusive")
    noise_mode = "operand" if noise is not None else (
        "fused" if noise_seed is not None else "none")

    meta_i = jnp.stack([
        jnp.asarray(noise_seed if noise_seed is not None else 0, jnp.int32),
        jnp.asarray(m_true if m_true is not None else m, jnp.int32),
        jnp.asarray(d_true if d_true is not None else d, jnp.int32),
    ])
    meta_f = jnp.stack([
        jnp.asarray(clip_norm, jnp.float32),
        jnp.asarray(noise_sigma if noise_sigma is not None else 0.0, jnp.float32),
    ])

    in_specs = [pl.BlockSpec((block_m, d), lambda i, *_: (i, 0))]
    operands = [updates]
    if noise_mode == "operand":
        in_specs.append(pl.BlockSpec((block_m, d), lambda i, *_: (i, 0)))
        operands.append(noise)

    kernel = functools.partial(_kernel, noise_mode=noise_mode,
                               tpu_prng=not interpret)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(m // block_m,),
            in_specs=in_specs,
            out_specs=[
                pl.BlockSpec((1, d), lambda i, *_: (0, 0)),
                pl.BlockSpec((1, 1), lambda i, *_: (0, 0)),
                pl.BlockSpec((1, 1), lambda i, *_: (0, 0)),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((1, d), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(meta_i, meta_f, *operands)
    sum_released, sq_rel, sq_clip = out
    return sum_released[0], sq_rel[0, 0], sq_clip[0, 0]


def _noise_only_kernel(meta_i_ref, meta_f_ref, out_ref, *, tpu_prng: bool):
    step = pl.program_id(0)
    bm, d = out_ref.shape
    sigma = meta_f_ref[0]
    rows = jax.lax.broadcasted_iota(jnp.int32, (bm, d), 0) + step * bm
    cols = jax.lax.broadcasted_iota(jnp.int32, (bm, d), 1)
    valid = (rows < meta_i_ref[1]) & (cols < meta_i_ref[2])
    z = _noise_block(meta_i_ref[0], step, (bm, d), tpu_prng=tpu_prng)
    out_ref[...] = jnp.where(valid, sigma * z, 0.0)


def ldp_noise_kernel_call(
    m: int,
    d: int,
    noise_seed,
    noise_sigma,
    *,
    block_m: int = 8,
    interpret: bool = True,
):
    """Materialize the exact noise the fused kernel would draw (test oracle;
    shapes must already satisfy the kernel tiling contract)."""
    assert m % block_m == 0, (m, block_m)
    meta_i = jnp.stack([jnp.asarray(noise_seed, jnp.int32),
                        jnp.asarray(m, jnp.int32), jnp.asarray(d, jnp.int32)])
    meta_f = jnp.asarray(noise_sigma, jnp.float32)[None]
    kernel = functools.partial(_noise_only_kernel, tpu_prng=not interpret)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(m // block_m,),
            in_specs=[],
            out_specs=pl.BlockSpec((block_m, d), lambda i, *_: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((m, d), jnp.float32),
        interpret=interpret,
    )(meta_i, meta_f)
