"""Jitted public wrapper for the fused DP aggregation kernel.

Pads (M, d) to the kernel's tiling contract, invokes the Pallas kernel (or the
jnp oracle on request) and converts raw sums into the ``RoundStats`` consumed
by the step-size rules.  The clip threshold, noise sigma, and noise seed are
traced operands (scalar-prefetched by the kernel), so per-round values — e.g.
the adaptive-clip threshold — do not trigger recompilation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.aggregation import RoundStats
from repro.kernels.dp_aggregate.kernel import (
    dp_aggregate_kernel_call,
    ldp_noise_kernel_call,
)
from repro.kernels.dp_aggregate.ref import dp_aggregate_ref

__all__ = ["dp_aggregate", "dp_aggregate_sums", "dp_aggregate_sums_chunked",
           "generate_ldp_noise", "pick_block_m"]

# VMEM budget per input tile on TPU (bytes); conservative vs the ~16 MB arena
# since the kernel holds the tile plus a handful of same-shape temporaries.
_TPU_TILE_BYTES = 2 * 1024 * 1024
_INTERPRET_MAX_BLOCK_M = 2048


def pick_block_m(m: int, d_padded: int, interpret: bool) -> int:
    """Shape-based row-block heuristic (replaces the old hardcoded 8).

    Interpreter mode: one grid step when feasible — each extra step is an
    extra python-traced block copy, and there is no VMEM to respect.
    Compiled TPU: the largest multiple of 8 whose f32 tile fits the VMEM
    budget, clamped to [8, 1024].
    """
    m8 = -(-m // 8) * 8
    if interpret:
        if m8 <= _INTERPRET_MAX_BLOCK_M:
            return m8
        # split into the fewest blocks under the cap and size them evenly, so
        # row padding stays < 8 * nblocks (a naive cap of 2048 would pad
        # M=2100 all the way to 4096)
        nblocks = -(-m8 // _INTERPRET_MAX_BLOCK_M)
        per_block = -(-m8 // nblocks)
        return -(-per_block // 8) * 8
    rows = _TPU_TILE_BYTES // (4 * d_padded)
    return max(8, min(1024, (rows // 8) * 8, m8))


def _resolve_defaults(m: int, d: int, interpret: bool | None,
                      block_m: int | None) -> tuple[bool, int]:
    """One home for the backend/tiling defaults every entry point shares."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if block_m is None:
        block_m = pick_block_m(m, -(-d // 128) * 128, interpret)
    return interpret, block_m


def _pad_axis(x: jax.Array, axis: int, multiple: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _key_to_seed(key: jax.Array) -> jax.Array:
    """Fold a JAX PRNG key (typed or raw uint32 pair) to one int32 scalar."""
    if jnp.issubdtype(key.dtype, jax.dtypes.prng_key):
        key = jax.random.key_data(key)
    mixed = key.reshape(-1)[0] ^ key.reshape(-1)[-1]
    return jax.lax.bitcast_convert_type(mixed.astype(jnp.uint32), jnp.int32)


@functools.partial(jax.jit, static_argnames=("use_ref", "interpret", "block_m", "fused"))
def _impl(updates, noise, clip_norm, sigma, seed, use_ref, interpret, block_m, fused):
    m, d = updates.shape
    if use_ref:
        s, sq_rel, sq_clip = dp_aggregate_ref(updates, noise, clip_norm)
    else:
        u = _pad_axis(_pad_axis(updates, 1, 128), 0, block_m)
        n = None if noise is None else _pad_axis(_pad_axis(noise, 1, 128), 0, block_m)
        s, sq_rel, sq_clip = dp_aggregate_kernel_call(
            u, n, clip_norm,
            noise_sigma=sigma if fused else None,
            noise_seed=seed if fused else None,
            m_true=m, d_true=d,
            block_m=block_m, interpret=interpret)
        s = s[:d]
    # raw SUMS, not means: the client-sharded engine psums these across the
    # `clients` mesh axis before normalizing (dp_aggregate divides below)
    return s, sq_rel, sq_clip


def dp_aggregate(
    updates: jax.Array,
    clip_norm,
    noise: jax.Array | None = None,
    *,
    noise_key: jax.Array | None = None,
    noise_sigma=None,
    use_ref: bool = False,
    interpret: bool | None = None,
    block_m: int | None = None,
) -> RoundStats:
    """Fused clip(+noise)+aggregate returning FedEXP round statistics.

    Pass a materialized ``noise`` matrix OR (``noise_key``, ``noise_sigma``)
    to draw the Gaussian noise inside the kernel (fused-noise path).
    """
    interpret, block_m = _resolve_defaults(*updates.shape, interpret, block_m)
    fused = noise_key is not None
    if fused and noise_sigma is None:
        raise ValueError("`noise_key` requires `noise_sigma` (sigma=0 would "
                         "silently release un-noised updates)")
    if fused and use_ref:
        raise ValueError("in-kernel noise has no jnp reference path; "
                         "materialize the noise for use_ref=True")
    seed = _key_to_seed(noise_key) if fused else jnp.int32(0)
    sigma = jnp.asarray(noise_sigma if noise_sigma is not None else 0.0, jnp.float32)
    s, sq_rel, sq_clip = _impl(
        updates, noise, jnp.asarray(clip_norm, jnp.float32), sigma, seed,
        use_ref, interpret, block_m, fused)
    m = updates.shape[0]
    cbar = s / m
    return RoundStats(
        cbar=cbar,
        mean_sq=sq_rel / m,
        agg_sq=jnp.sum(jnp.square(cbar)),
        mean_sq_clipped=sq_clip / m,
    )


def dp_aggregate_sums(
    updates: jax.Array,
    clip_norm,
    noise: jax.Array | None = None,
    *,
    use_ref: bool = False,
    interpret: bool | None = None,
    block_m: int | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Partial-sum entry point: ``(sum_c, sum_sq_released, sum_sq_clipped)``.

    The same fused clip(+noise)+reduce kernel as ``dp_aggregate``, but the raw
    per-shard SUMS are returned un-normalized so the client-sharded engine can
    ``psum`` them across the ``clients`` mesh axis and divide once globally
    (DESIGN.md §9).  In-kernel noise generation is not offered here: the
    kernel's seed derivation has no notion of a shard offset, so every shard
    would draw identical noise — materialize per-client rows instead
    (``repro.core.aggregation.materialize_ldp_noise``).
    """
    interpret, block_m = _resolve_defaults(*updates.shape, interpret, block_m)
    return _impl(updates, noise, jnp.asarray(clip_norm, jnp.float32),
                 jnp.float32(0.0), jnp.int32(0), use_ref, interpret,
                 block_m, False)


def dp_aggregate_sums_chunked(
    updates: jax.Array,
    clip_norm,
    noise: jax.Array | None = None,
    *,
    chunk_m: int,
    slots: jax.Array | None = None,
    slot_mask: jax.Array | None = None,
    use_ref: bool = False,
    interpret: bool | None = None,
    block_m: int | None = None,
    compress_fn=None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """``dp_aggregate_sums`` accumulated over row chunks (DESIGN.md §12/§14).

    Reduces the (M, d) update matrix ``chunk_m`` rows at a time — one kernel
    launch per chunk inside a ``lax.scan`` — and adds the three partial sums
    into an O(d) carry.  The kernel's working set (its padded input copy and
    VMEM tiles) is bounded by ``chunk_m * d`` instead of ``M * d``, which is
    what the streaming cohort engine needs from the kernel layer when a
    round's cohort is too large to stage densely.  In-kernel noise
    generation is excluded exactly as in ``dp_aggregate_sums``: the kernel
    seed derivation is chunk-oblivious, so every chunk would repeat the same
    noise block — materialize per-client rows keyed by global index instead
    (``repro.core.aggregation.materialize_ldp_noise``).

    ``slots`` is the §14 sparse-cohort entry: a (cap,) slot table (as packed
    by ``fedsim.local.gather_slots``) restricts the reduction to the sampled
    rows, gathered from ``updates`` one chunk at a time right before its
    kernel launch — never a dense (cap, d) staging block — so a q-sampled
    round's kernel work is O(cap·d).  Padding slots hold index 0 (client 0's
    real row), so the accompanying ``slot_mask`` where-zeroes each gathered
    chunk before the kernel sees it — the engines' ``mask_rows`` discipline,
    applied here because only this layer ever materializes the gathered rows.
    With ``slots``, ``noise`` must already be slot-aligned ((cap, d),
    materialized for the GATHERED global indices, zero rows on padding
    slots).

    Args:
      updates: (M, d) raw client updates; M (or ``cap`` when ``slots`` is
        given) must be a multiple of ``chunk_m`` (the engine's chunk/slot
        grids guarantee this — pad with zero-weight rows otherwise).
      clip_norm: clip threshold C (python float or traced scalar).
      noise: optional pre-materialized per-client noise — (M, d), or (cap, d)
        slot-aligned when ``slots`` is given.
      chunk_m: rows per kernel launch (>= 1).
      slots: optional (cap,) int32 slot table of sampled-row indices.
      slot_mask: (cap,) float {0., 1.} validity of each slot; required with
        ``slots`` (without it a padding slot would double-count client 0).
      use_ref / interpret / block_m: forwarded to each chunk's reduction.
      compress_fn: optional linear per-row map ``(chunk_m, d) -> (chunk_m,
        kc)`` (DESIGN.md §16).  Each chunk's rows are clipped then compressed
        before summation, so the carry holds a (kc,) vector instead of (d,);
        linearity of the map makes the chunked sum equal the dense compressed
        sum.  Incompatible with per-row ``noise`` — LDP noise lives in R^d and
        compressing a noised row breaks its privacy accounting.

    Returns:
      ``(sum_c, sum_sq_released, sum_sq_clipped)`` raw SUMS over the reduced
      rows — the dense entry's values re-associated at chunk boundaries only.
      With ``compress_fn``, ``sum_c`` is the (kc,) compressed-domain sum and
      released == clipped (no per-row noise enters the compressed path).
    """
    if compress_fn is not None and noise is not None:
        raise ValueError(
            "compress_fn cannot combine with per-row noise: LDP noise is a "
            "full R^d vector per client, drawn BEFORE aggregation — "
            "compressing it afterwards breaks the privacy accounting "
            "(DESIGN.md §16)")
    m, d = updates.shape
    rows = m if slots is None else slots.shape[0]
    if chunk_m < 1:
        raise ValueError(f"chunk_m must be >= 1, got {chunk_m}")
    chunk_m = min(chunk_m, rows)
    if rows % chunk_m:
        what = "M" if slots is None else "cap"
        raise ValueError(
            f"{what}={rows} is not a multiple of chunk_m={chunk_m}; pad the "
            "cohort to the chunk grid first (zero-weight rows contribute "
            "nothing)")
    n_chunks = rows // chunk_m
    interpret, block_m = _resolve_defaults(chunk_m, d, interpret, block_m)
    clip = jnp.asarray(clip_norm, jnp.float32)

    if slots is None:
        xs = {"u": updates.reshape(n_chunks, chunk_m, d)}
        if noise is not None:
            xs["noise"] = noise.reshape(n_chunks, chunk_m, d)
    else:
        if slot_mask is None:
            raise ValueError(
                "slots requires slot_mask (padding slots hold index 0; an "
                "unmasked gather would double-count client 0's update)")
        xs = {"slots": slots.reshape(n_chunks, chunk_m),
              "mask": slot_mask.reshape(n_chunks, chunk_m)}
        if noise is not None:
            if noise.shape[0] != rows:
                raise ValueError(
                    f"with slots, noise must be slot-aligned: expected "
                    f"({rows}, {d}), got {noise.shape} — materialize it for "
                    "the gathered global indices, not the full cohort")
            xs["noise"] = noise.reshape(n_chunks, chunk_m, d)

    def body(acc, chunk):
        if slots is None:
            u = chunk["u"]
        else:
            u = jnp.take(updates, chunk["slots"], axis=0)
            u = jnp.where(chunk["mask"][:, None] > 0, u, 0.0)
        if compress_fn is not None:
            # clip scale commutes with the linear map, so the compressed sum
            # never materializes the clipped (chunk_m, d) block
            sq = jnp.sum(jnp.square(u), axis=-1)
            scale = jnp.minimum(1.0, clip / jnp.maximum(jnp.sqrt(sq), 1e-12))
            s = jnp.sum(compress_fn(u) * scale[:, None], axis=0)
            sq_clip = jnp.sum(sq * jnp.square(scale))
            sq_rel = sq_clip
        else:
            s, sq_rel, sq_clip = _impl(
                u, chunk.get("noise"), clip, jnp.float32(0.0),
                jnp.int32(0), use_ref, interpret, block_m, False)
        a_s, a_rel, a_clip = acc
        return (a_s + s, a_rel + sq_rel, a_clip + sq_clip), None

    if compress_fn is None:
        sum_c_zero = jnp.zeros((d,), jnp.float32)
    else:
        kc = jax.eval_shape(
            compress_fn,
            jax.ShapeDtypeStruct((chunk_m, d), jnp.float32)).shape[-1]
        sum_c_zero = jnp.zeros((kc,), jnp.float32)
    zero = (sum_c_zero, jnp.float32(0.0), jnp.float32(0.0))
    (s, sq_rel, sq_clip), _ = jax.lax.scan(body, zero, xs)
    return s, sq_rel, sq_clip


def generate_ldp_noise(
    m: int,
    d: int,
    noise_key: jax.Array,
    noise_sigma,
    *,
    interpret: bool | None = None,
    block_m: int | None = None,
) -> jax.Array:
    """Materialize the (m, d) Gaussian noise the fused kernel draws in-kernel
    for ``noise_key`` — the test oracle for the in-kernel PRNG statistics."""
    interpret, block_m = _resolve_defaults(m, d, interpret, block_m)
    d_padded = -(-d // 128) * 128
    m_padded = -(-m // block_m) * block_m
    full = ldp_noise_kernel_call(
        m_padded, d_padded, _key_to_seed(noise_key), noise_sigma,
        block_m=block_m, interpret=interpret)
    return full[:m, :d]
