"""Jitted public wrapper for the fused DP aggregation kernel.

Pads (M, d) to the kernel's tiling contract, invokes the Pallas kernel (or the
jnp oracle on request) and converts raw sums into the ``RoundStats`` consumed
by the step-size rules.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.aggregation import RoundStats
from repro.kernels.dp_aggregate.kernel import dp_aggregate_kernel_call
from repro.kernels.dp_aggregate.ref import dp_aggregate_ref

__all__ = ["dp_aggregate"]


def _pad_axis(x: jax.Array, axis: int, multiple: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("clip_norm", "use_ref", "interpret", "block_m"))
def _impl(updates, noise, clip_norm, use_ref, interpret, block_m):
    m = updates.shape[0]
    if use_ref:
        s, sq_rel, sq_clip = dp_aggregate_ref(updates, noise, clip_norm)
    else:
        u = _pad_axis(_pad_axis(updates, 1, 128), 0, block_m)
        n = None if noise is None else _pad_axis(_pad_axis(noise, 1, 128), 0, block_m)
        s, sq_rel, sq_clip = dp_aggregate_kernel_call(
            u, n, clip_norm, block_m=block_m, interpret=interpret)
        s = s[: updates.shape[1]]
    cbar = s / m
    return cbar, sq_rel / m, sq_clip / m


def dp_aggregate(
    updates: jax.Array,
    clip_norm: float,
    noise: jax.Array | None = None,
    *,
    use_ref: bool = False,
    interpret: bool = True,
    block_m: int = 8,
) -> RoundStats:
    """Fused clip(+noise)+aggregate returning FedEXP round statistics."""
    cbar, mean_sq, mean_sq_clipped = _impl(
        updates, noise, float(clip_norm), use_ref, interpret, block_m)
    return RoundStats(
        cbar=cbar,
        mean_sq=mean_sq,
        agg_sq=jnp.sum(jnp.square(cbar)),
        mean_sq_clipped=mean_sq_clipped,
    )
