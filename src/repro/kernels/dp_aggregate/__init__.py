from repro.kernels.dp_aggregate import ops, ref
from repro.kernels.dp_aggregate.ops import dp_aggregate

__all__ = ["ops", "ref", "dp_aggregate"]
