from repro.kernels.dp_aggregate import ops, ref
from repro.kernels.dp_aggregate.ops import dp_aggregate, generate_ldp_noise

__all__ = ["ops", "ref", "dp_aggregate", "generate_ldp_noise"]
