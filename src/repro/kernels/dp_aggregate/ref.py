"""Pure-jnp oracle for the fused DP aggregation kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

_EPS = 1e-12


def dp_aggregate_ref(updates: jax.Array, noise: jax.Array | None, clip_norm: float):
    """Returns (sum_released (d,), sum_sq_released (), sum_sq_clipped ())."""
    u = updates.astype(jnp.float32)
    norms = jnp.linalg.norm(u, axis=-1)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(norms, _EPS))
    clipped = u * scale[:, None]
    released = clipped if noise is None else clipped + noise.astype(jnp.float32)
    return (
        jnp.sum(released, axis=0),
        jnp.sum(jnp.square(released)),
        jnp.sum(jnp.square(clipped)),
    )
