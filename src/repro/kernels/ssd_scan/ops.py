"""Jitted public wrapper for the SSD scan kernel (pads S to the chunk grid)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.kernel import ssd_scan_kernel_call

__all__ = ["ssd_scan"]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, a, bmat, cmat, *, chunk: int = 128, interpret: bool = True):
    """Chunked SSD scan; pads the sequence with dt=0 steps (exact no-ops)."""
    s = x.shape[1]
    c = min(chunk, max(8, s))
    pad = (-s) % c
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))  # dt=0 -> decay 1, no inject
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
    y = ssd_scan_kernel_call(x, dt, a, bmat, cmat, chunk=c, interpret=interpret)
    return y[:, :s]
