"""Mamba2 SSD (state-space duality) chunked scan kernel (Pallas TPU).

Computes the selective-state-space recurrence

    h_t = exp(A_h * dt_t) * h_{t-1} + dt_t * (B_t  ⊗ x_t)     (N x P state)
    y_t = C_t^T h_t

in the chunked dual form of Dao & Gu (arXiv:2405.21060): within a chunk of
length L the output is a masked (L x L) matmul (MXU-friendly), across chunks a
small (N x P) state is carried. This replaces the GPU warp-parallel scan with
a TPU-native schedule: the quadratic intra-chunk term maps onto the MXU and
the inter-chunk recurrence is the sequential grid carry in VMEM scratch.

    y_intra = ((C K^T) ⊙ D) xbar      D_ij = exp(s_i - s_j) for j <= i
    h'      = exp(s_L) h + sum_j exp(s_L - s_j) B_j ⊗ xbar_j
    y_inter = exp(s_i) * (C_i h)

with s the within-chunk cumulative log-decay and xbar = dt * x.

Grid: (batch, heads, n_chunks) — chunk axis innermost so the (N, P) scratch
state carries across sequential grid steps of the same (b, h).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ssd_scan_kernel_call"]


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, h_ref, *, chunk: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)          # (L, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)           # (L,)
    a = a_ref[0]                                       # scalar A_h (negative)
    bmat = b_ref[0].astype(jnp.float32)                # (L, N)
    cmat = c_ref[0].astype(jnp.float32)                # (L, N)

    log_a = a * dt                                     # (L,) log decay per step
    s = jnp.cumsum(log_a)                              # (L,) cumulative log decay
    xbar = x * dt[:, None]                             # (L, P)

    # Intra-chunk: ((C B^T) ⊙ D) @ xbar, D_ij = exp(s_i - s_j + log_a_j ... )
    # careful with convention: h_t includes decay a_t applied to h_{t-1} but the
    # input B_t xbar_t enters *undecayed* at step t. So for j <= i:
    #   weight(i, j) = exp(s_i - s_j)  (product of a_{j+1..i}), weight(i, i) = 1.
    diff = s[:, None] - s[None, :]                     # (L, L)
    li = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    lj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.where(lj <= li, jnp.exp(diff), 0.0)
    scores = jax.lax.dot_general(cmat, bmat, (((1,), (1,)), ((), ())))  # (L, L)
    y = jax.lax.dot(scores * decay, xbar)              # (L, P)

    # Inter-chunk: contribution of the carried state.
    h = h_ref[...]                                     # (N, P)
    y += jnp.exp(s)[:, None] * jax.lax.dot(cmat, h)    # (L, P)

    # State update for the next chunk.
    s_last = s[-1]
    w = jnp.exp(s_last - s)                            # (L,)
    h_ref[...] = jnp.exp(s_last) * h + jax.lax.dot_general(
        bmat * w[:, None], xbar, (((0,), (0,)), ((), ())))  # (N, P)

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)


def ssd_scan_kernel_call(
    x: jax.Array,       # (B, S, H, P)
    dt: jax.Array,      # (B, S, H)   positive step sizes
    a: jax.Array,       # (H,)        negative decay rates
    bmat: jax.Array,    # (B, S, N)   input projections (shared across heads)
    cmat: jax.Array,    # (B, S, N)   output projections
    *,
    chunk: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Returns y (B, S, H, P). S must be a multiple of ``chunk``."""
    b, s, h, p = x.shape
    n = bmat.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    kernel = functools.partial(_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda ib, ih, ic: (ib, ic, ih, 0)),
            pl.BlockSpec((1, chunk, 1), lambda ib, ih, ic: (ib, ic, ih)),
            pl.BlockSpec((1,), lambda ib, ih, ic: (ih,)),
            pl.BlockSpec((1, chunk, n), lambda ib, ih, ic: (ib, ic, 0)),
            pl.BlockSpec((1, chunk, n), lambda ib, ih, ic: (ib, ic, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, 1, p), lambda ib, ih, ic: (ib, ic, ih, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, h, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(x, dt, a, bmat, cmat)
