from repro.kernels.ssd_scan import ops, ref
from repro.kernels.ssd_scan.ops import ssd_scan

__all__ = ["ops", "ref", "ssd_scan"]
