"""Step-by-step recurrence oracle for the SSD scan kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_scan_ref(x, dt, a, bmat, cmat):
    """Naive per-step recurrence.

    x: (B, S, H, P), dt: (B, S, H), a: (H,), bmat/cmat: (B, S, N).
    Returns y: (B, S, H, P).
    """
    bsz, s, h, p = x.shape
    n = bmat.shape[-1]

    def step(hstate, inputs):
        xt, dtt, bt, ct = inputs          # (B,H,P), (B,H), (B,N), (B,N)
        decay = jnp.exp(a[None, :] * dtt)                     # (B, H)
        inject = bt[:, None, :, None] * (xt * dtt[..., None])[:, :, None, :]  # (B,H,N,P)
        hstate = decay[:, :, None, None] * hstate + inject
        yt = jnp.einsum("bn,bhnp->bhp", ct, hstate)
        return hstate, yt

    h0 = jnp.zeros((bsz, h, n, p), jnp.float32)
    xs = (jnp.moveaxis(x, 1, 0).astype(jnp.float32),
          jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
          jnp.moveaxis(bmat, 1, 0).astype(jnp.float32),
          jnp.moveaxis(cmat, 1, 0).astype(jnp.float32))
    _, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype)
