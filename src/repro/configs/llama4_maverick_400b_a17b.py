"""llama4-maverick-400b-a17b [moe] — Meta, hf:meta-llama/Llama-4-Scout-17B-16E family.

48L, d_model 5120, 40 heads / 8 KV (GQA), per-expert d_ff 8192, vocab 202048,
128 experts with top-1 routing + one always-on shared expert; early fusion
(text+image tokens in one vocab — frontend stubbed as for chameleon).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    arch_type="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202_048,
    activation="swiglu",
    num_experts=128,
    top_k=1,
    moe_shared_expert=True,
    tie_embeddings=True,
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
    notes="~400B total / 17B active; giant arch -> cohort spans full grid (DESIGN.md §4).",
)
