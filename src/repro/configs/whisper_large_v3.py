"""whisper-large-v3 [audio] — OpenAI, arXiv:2212.04356.

Enc-dec: 32 encoder + 32 decoder layers, d_model 1280, 20 heads (MHA),
d_ff 5120, GELU, vocab 51866, sinusoidal positions. The mel-spectrogram +
conv feature extractor frontend is STUBBED: input_specs() provides
precomputed frame embeddings (B, T, d_model) directly.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    arch_type="audio",
    num_layers=32,
    num_encoder_layers=32,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab_size=51_866,
    activation="gelu",
    use_bias=True,
    use_rope=False,
    tie_embeddings=True,
    source="arXiv:2212.04356",
    notes="decode_32k exceeds Whisper's trained 448 positions; shape/lowering exercise (DESIGN.md §6).",
)
