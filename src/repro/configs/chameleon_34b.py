"""chameleon-34b [vlm] — Meta, arXiv:2405.09818.

48L, d_model 8192, 64 heads / 8 KV (GQA), d_ff 22016, vocab 65536 including
VQ image codes (early fusion), qk-layernorm for stability. The VQ-VAE image
tokenizer is the stubbed frontend: input_specs() provides mixed text/image
token ids directly (discrete early fusion IS token-level).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    arch_type="vlm",
    num_layers=48,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab_size=65_536,
    activation="swiglu",
    qk_norm=True,
    tie_embeddings=False,
    source="arXiv:2405.09818",
)
