"""Config system: architectures, input shapes, federated/DP round settings.

Every assigned architecture is a ``ModelConfig`` (see repro/configs/<id>.py,
each citing its source); the four canonical input shapes are ``ShapeConfig``s.
``FederatedConfig`` carries the DP-FedEXP round parameters into the datacenter
path (launch/train.py).
"""
from __future__ import annotations

import dataclasses
from typing import Literal

ArchType = Literal["dense", "moe", "ssm", "hybrid", "vlm", "audio"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: ArchType
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None          # default d_model // num_heads
    activation: str = "swiglu"           # swiglu | geglu | gelu
    sliding_window: int | None = None    # SWA width (h2o-danube3)
    qk_norm: bool = False                # chameleon-style qk layernorm
    attn_logit_softcap: float | None = None   # gemma-style softcap
    use_bias: bool = False
    tie_embeddings: bool = True
    rope_theta: float = 10000.0
    use_rope: bool = True                # False -> sinusoidal abs positions (whisper)
    parallel_block: bool = False         # command-r parallel attn+FFN residual
    norm_eps: float = 1e-6
    # --- MoE ---
    num_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_shared_expert: bool = False      # llama4-style always-on shared expert
    # --- SSM (Mamba2) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    # --- hybrid (zamba2-style): one shared attention block applied every k ---
    hybrid_attn_every: int = 0
    # --- enc-dec (whisper): encoder layers with non-causal attention ---
    num_encoder_layers: int = 0
    # --- notes / provenance ---
    source: str = ""
    notes: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.num_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def attention_free(self) -> bool:
        return self.arch_type == "ssm"

    @property
    def subquadratic(self) -> bool:
        """Eligible for long_500k: SSM, hybrid, or sliding-window attention."""
        return self.arch_type in ("ssm", "hybrid") or self.sliding_window is not None


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class FederatedConfig:
    """DP-FedEXP round parameters for the datacenter path.

    ``algorithm`` selects the server rule: cdp-fedexp (default: the paper's
    hyperparameter-free central setting), dp-fedavg-cdp, ldp-fedexp-gauss,
    dp-fedavg-ldp-gauss, fedexp, fedavg.
    """

    algorithm: str = "cdp-fedexp"
    clip_norm: float = 1.0
    noise_sigma: float = 1.0          # paper's sigma (CDP server std = sigma/sqrt(M))
    local_steps: int = 2              # tau (kept small for dry-run compile cost)
    local_lr: float = 0.01            # eta_l
    # cohort geometry (see DESIGN.md §4): which mesh axes enumerate clients.
    client_axes: tuple[str, ...] = ("data",)
    virtual_clients: int = 1          # sequential cohort members per client slot


def reduced(cfg: ModelConfig, *, layers: int = 2, d_model: int = 256) -> ModelConfig:
    """Reduced same-family variant for CPU smoke tests (<=4 experts etc.)."""
    head_dim = 64
    heads = max(2, d_model // 128)
    kv = max(1, min(cfg.num_kv_heads, heads // 2)) if cfg.num_kv_heads < cfg.num_heads else heads
    if cfg.num_heads > 0 and cfg.num_kv_heads == cfg.num_heads:
        kv = heads
    changes = dict(
        name=cfg.name + "-smoke",
        num_layers=layers,
        d_model=d_model,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=head_dim,
        d_ff=2 * d_model,
        vocab_size=512,
    )
    if cfg.num_experts:
        changes.update(num_experts=4, top_k=min(cfg.top_k, 2))
    if cfg.ssm_state:
        changes.update(ssm_state=16, ssm_head_dim=32)
    if cfg.hybrid_attn_every:
        changes.update(hybrid_attn_every=2)
    if cfg.num_encoder_layers:
        changes.update(num_encoder_layers=layers)
    if cfg.sliding_window:
        changes.update(sliding_window=64)
    return dataclasses.replace(cfg, **changes)
