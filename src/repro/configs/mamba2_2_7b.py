"""mamba2-2.7b [ssm] — Dao & Gu, arXiv:2405.21060 (SSD / state-space duality).

64 Mamba2 layers, d_model 2560 (attention-free), ssm_state 128,
head_dim 64 (d_inner 5120 -> 80 SSD heads), vocab 50280.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    arch_type="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=0,
    num_kv_heads=0,
    head_dim=None,
    d_ff=0,
    vocab_size=50_280,
    ssm_state=128,
    ssm_head_dim=64,
    tie_embeddings=True,
    source="arXiv:2405.21060",
    notes="attention-free; DP-FedEXP applies unchanged (update-space technique).",
)
