"""gemma-2b [dense] — Gemma Team, arXiv:2403.08295.

18L, d_model 2048, 8 heads with MQA (1 KV head), head_dim 256, GeGLU
d_ff 16384, vocab 256000, tied embeddings, RoPE.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    arch_type="dense",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=256_000,
    activation="geglu",
    tie_embeddings=True,
    source="arXiv:2403.08295",
    notes="MQA on the 2b variant; head_dim 256 (8*256 != d_model, separate o-proj fan-in).",
)
