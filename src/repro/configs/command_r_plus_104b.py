"""command-r-plus-104b [dense] — Cohere, hf:CohereForAI/c4ai-command-r-v01.

64L, d_model 12288, 96 heads / 8 KV (GQA), d_ff 33792, vocab 256000,
no biases, parallel attention+FFN residual block.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b",
    arch_type="dense",
    num_layers=64,
    d_model=12288,
    num_heads=96,
    num_kv_heads=8,
    head_dim=128,
    d_ff=33792,
    vocab_size=256_000,
    activation="swiglu",
    use_bias=False,
    parallel_block=True,
    tie_embeddings=True,
    source="hf:CohereForAI/c4ai-command-r-v01",
    notes="104B params; client cohort must span the full device grid (DESIGN.md §4).",
)
