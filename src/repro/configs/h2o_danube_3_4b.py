"""h2o-danube-3-4b [dense] — H2O.ai, arXiv:2401.16818 (danube series).

24L, d_model 3840, 32 heads / 8 KV (GQA), d_ff 10240, vocab 32000,
llama+mistral mix with sliding-window attention (window 4096).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b",
    arch_type="dense",
    num_layers=24,
    d_model=3840,
    num_heads=32,
    num_kv_heads=8,
    head_dim=120,
    d_ff=10240,
    vocab_size=32_000,
    activation="swiglu",
    sliding_window=4096,
    tie_embeddings=False,
    source="arXiv:2401.16818",
    notes="SWA makes this dense arch eligible for long_500k decode (window-bounded KV).",
)
