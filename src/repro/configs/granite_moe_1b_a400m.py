"""granite-moe-1b-a400m [moe] — IBM, hf:ibm-granite/granite-3.0-1b-a400m-base.

24L, d_model 1024, 16 heads / 8 KV (GQA), per-expert d_ff 512, vocab 49155,
32 experts with top-8 routing.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    arch_type="moe",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49_155,
    activation="swiglu",
    num_experts=32,
    top_k=8,
    tie_embeddings=True,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    notes="1B total / ~400M active; experts sharded over the model axis (all-to-all dispatch).",
)
