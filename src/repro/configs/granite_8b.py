"""granite-8b [dense] — IBM Granite Code, arXiv:2405.04324.

36L, d_model 4096, 32 heads / 8 KV (GQA), d_ff 14336, vocab 49152,
llama-style SwiGLU decoder.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-8b",
    arch_type="dense",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=49_152,
    activation="swiglu",
    tie_embeddings=False,
    source="arXiv:2405.04324",
)
