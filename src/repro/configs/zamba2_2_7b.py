"""zamba2-2.7b [hybrid] — Zyphra, arXiv:2411.15242.

54 Mamba2 blocks, d_model 2560, ssm_state 64, plus ONE weight-shared
attention(+MLP) block applied every 6 Mamba2 blocks (32 heads, MHA,
d_ff 10240). vocab 32000.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    arch_type="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab_size=32_000,
    activation="swiglu",
    ssm_state=64,
    ssm_head_dim=64,
    hybrid_attn_every=6,
    tie_embeddings=True,
    source="arXiv:2411.15242",
    notes="shared attn block = tied weights; its grads sum over the 9 application sites.",
)
