"""Architecture registry: the 10 assigned archs + the paper's own models."""

from repro.configs import (
    chameleon_34b,
    command_r_plus_104b,
    gemma_2b,
    granite_8b,
    granite_moe_1b_a400m,
    h2o_danube_3_4b,
    llama4_maverick_400b_a17b,
    mamba2_2_7b,
    whisper_large_v3,
    zamba2_2_7b,
)
from repro.configs.base import SHAPES, FederatedConfig, ModelConfig, ShapeConfig, reduced

ARCHS: dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        gemma_2b, h2o_danube_3_4b, command_r_plus_104b, granite_moe_1b_a400m,
        zamba2_2_7b, llama4_maverick_400b_a17b, chameleon_34b, mamba2_2_7b,
        granite_8b, whisper_large_v3,
    )
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


__all__ = ["ARCHS", "SHAPES", "get_config", "reduced",
           "ModelConfig", "ShapeConfig", "FederatedConfig"]
