"""FederatedSession: the spec-driven, resumable simulation entry point.

DESIGN.md §10.  A session binds (algorithm, loss_fn, model, client data) to
four frozen specs and owns the compiled chunk program:

    session = FederatedSession(
        algorithm, loss_fn, params, client_batches,
        train=TrainSpec(rounds=50, tau=20, eta_l=0.1),
        local=LocalSpec(batch_size=8),      # minibatch local SGD (§11)
        cohort=CohortSpec(q=0.25),          # per-round Poisson sampling
        eval_fn=eval_fn)
    result = session.run(jax.random.PRNGKey(0))

Three properties the kwargs-style API could not offer:

* **Pytree-native models.**  ``params`` may be any parameter pytree (the
  ``models/`` zoo plugs in directly); the session ravels it once via
  ``fedsim.flat.flatten_model``, wraps the loss/eval closures, and unravels
  ``RunResult.final_w`` / ``last_w`` back to the caller's structure.  Flat
  (d,) vectors pass through untouched — zero overhead, bit-identical.

* **Per-round client sampling.**  ``CohortSpec`` draws the participation
  mask inside the scan body (static shapes, one compiled program per chunk)
  and routes the round through the masked-moment protocol; the sampling rate
  feeds ``core.accounting`` for amplification-aware epsilon reporting
  (``privacy_report``).

* **Resumable runs.**  ``run(key, checkpoint_dir=...)`` threads the round
  counter, RNG key, model, optimizer/clip state, and histories through
  ``repro.checkpoint``; ``resume(checkpoint_dir)`` continues to
  ``train.rounds`` and returns the same RunResult an uninterrupted run
  produces — bit-exactly, because per-round keys are ``fold_in(key, t)`` by
  GLOBAL round index and the carry round-trips losslessly.

The session holds its loss/eval closures for its lifetime, so the engine's
cross-call compile cache (keyed on closure identity + hashable specs) hits on
every ``run``/``resume``/``run_batched`` after the first.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt
from repro.core import accounting
from repro.core.fedexp import ServerAlgorithm
from repro.fedsim import server as _srv
from repro.fedsim.data import ClientDataSource, as_data_source
from repro.fedsim.flat import flatten_model
from repro.fedsim.local import (
    build_cohort_local_fn,
    chunk_cohort,
    gather_slots,
    pad_cohort,
)
from repro.fedsim.server import RunResult
from repro.fedsim.specs import (
    CohortSpec,
    DataSpec,
    EngineSpec,
    FaultSpec,
    LocalSpec,
    ShardSpec,
    StreamSpec,
    TelemetrySpec,
    TrainSpec,
)
from repro.telemetry import NullTracker, Tracker
from repro.telemetry import tap as _tap_mod

__all__ = ["FederatedSession", "RecoveryPolicy"]


@dataclasses.dataclass(frozen=True)
class RecoveryPolicy:
    """Auto-recovery for watchdog-tripped runs (DESIGN.md §13).

    ``run(key, checkpoint_dir=..., on_divergence=RecoveryPolicy(...))`` rolls
    a tripped run back to the newest intact checkpoint, sleeps
    ``backoff * attempt`` seconds (0 disables), and re-runs — at most
    ``max_retries`` times, after which the fault is surfaced in
    ``RunResult.fault_round`` instead.  Every rolled-back round was still
    EXECUTED against client data, so retried rounds join the privacy
    composition (``FederatedSession.privacy_report``).
    """
    max_retries: int = 3
    backoff: float = 0.0

    def __post_init__(self):
        if self.max_retries < 1:
            raise ValueError(
                f"max_retries must be >= 1, got {self.max_retries} "
                "(omit on_divergence to disable recovery)")
        if self.backoff < 0.0:
            raise ValueError(f"backoff must be >= 0, got {self.backoff}")


def _is_flat_params(w0) -> bool:
    """True when w0 is already a bare array (the historical flat contract).

    A bare array of ANY rank passes through unwrapped — run_batched's
    ``batched_w0`` stacks seeds on axis 0 of a flat (S, d) array, which must
    not be mistaken for a pytree model.  Anything with tree structure (dict,
    tuple, dataclass of arrays) is a model pytree and gets raveled.
    """
    leaves = jax.tree_util.tree_leaves(w0)
    return len(leaves) == 1 and leaves[0] is w0


class FederatedSession:
    """A reusable, compiled federated run bound to declarative specs."""

    def __init__(self, algorithm: ServerAlgorithm, loss_fn: Callable,
                 w0: Any, client_batches, *, train: TrainSpec,
                 local: LocalSpec = LocalSpec(),
                 engine: EngineSpec = EngineSpec(),
                 shard: ShardSpec = ShardSpec(),
                 cohort: CohortSpec = CohortSpec(),
                 stream: StreamSpec = StreamSpec(),
                 fault: FaultSpec = FaultSpec(),
                 data: DataSpec | None = None,
                 telemetry: TelemetrySpec = TelemetrySpec(),
                 eval_fn: Callable | None = None,
                 num_clients: int | None = None):
        """Bind (algorithm, loss, model, client data) to declarative specs.

        Args:
          algorithm: a ``ServerAlgorithm`` (typically ``make_algorithm(...)``
            or a ``compose_algorithm(...)`` composition).
          loss_fn: per-client loss ``loss_fn(params, client_batch) -> scalar``
            on the caller's parameter structure.
          w0: initial model — any parameter pytree, or a flat (d,) vector
            (passes through unwrapped).
          client_batches: pytree of per-client data; every leaf carries the
            client axis leading (axis 1 for ``run_batched(batched_data=True)``).
            Also accepts a ``ClientDataSource`` (DESIGN.md §14): an
            ``ArraySource`` unwraps to the historical device-resident engine
            bit-for-bit; host/npz/synthetic sources stream chunk-staged data
            through ``engine="stream"``, bounding M by host storage.
          train: what to train (rounds, tau, eta_l, averaging, eval cadence).
          local: how clients train locally (DESIGN.md §11).
          engine: how the round loop compiles — scan / eager / stream (§8, §12).
          shard: optional ``clients`` mesh the cohort shards over (§9).
          cohort: per-round client sampling (§10).
          stream: client-chunk grid of the streaming engine (§12); only
            consulted when ``engine="stream"`` (a non-default spec under any
            other engine raises, rather than being silently ignored).
          fault: deterministic fault injection + divergence watchdog (§13);
            the default (no faults, watchdog off) is normalized away and
            reproduces the fault-free program bit-for-bit.
          data: where the client data lives + prefetch depth (§14).  Derived
            from ``client_batches`` when omitted (the eighth spec — joins the
            compile-cache key); passing one whose ``kind`` contradicts the
            actual input raises rather than silently mis-staging.
          telemetry: how the run is observed (§15): ledger δ and profiler
            window.  Deliberately NOT part of any compile-cache key — only
            the presence of a non-null ``run(tracker=...)`` flips the
            single on/off tap flag the engines compile against.
          eval_fn: optional metric closure ``eval_fn(params) -> scalar``.
          num_clients: explicit cohort size, required only when the client
            axis is not leaf axis 0 (``run_batched(batched_data=True)``).
        """
        self.algorithm = algorithm
        self.train = train
        self.local = local
        self.engine = engine
        self.shard = shard
        self.stream = stream
        self.telemetry = telemetry
        if engine.engine != "stream" and stream != StreamSpec():
            raise ValueError(
                "a non-default StreamSpec requires engine='stream' "
                "(EngineSpec(engine='stream')); it would be silently "
                f"ignored under engine={engine.engine!r}")
        # normalize full participation to None so unsampled sessions share
        # compile-cache entries with pre-cohort callers (and with each other
        # regardless of how "no sampling" was spelled)
        self.cohort = cohort if cohort.is_sampled else None
        # same normalization for the fault model: FaultSpec() is structurally
        # the fault-free engine — identical compile-cache key, identical
        # program, bit-exact with pre-fault sessions (DESIGN.md §13)
        self.fault = fault if fault.is_active else None
        # privacy compositions consumed by rolled-back rounds (recovery);
        # privacy_report folds these into the round count
        self._rounds_retried = 0
        # test hook: callable (carry, attempt) -> carry applied before the
        # first chunk of each recovery attempt — lets tests inject a
        # TRANSIENT divergence (poison attempt 0 only) so the retried run is
        # bit-exact with an unkilled reference
        self._inject_divergence = None
        # unified data entry (§14): a ClientDataSource of kind "device"
        # unwraps to the historical device-resident path (bit-for-bit); other
        # kinds stay behind the source and stream host-staged chunks
        source = as_data_source(client_batches)
        if source is not None and source.kind == "device":
            client_batches, source = source.batches, None
        self._source = source
        kind = "device" if source is None else source.kind
        if data is None:
            data = DataSpec(kind=kind)
        elif data.kind != kind:
            raise ValueError(
                f"DataSpec(kind={data.kind!r}) contradicts the client data "
                f"actually passed ({kind!r}); drop data= (the kind is "
                "derived) or pass the matching ClientDataSource")
        self.data = data
        if source is not None:
            if engine.engine != "stream":
                raise ValueError(
                    f"a {kind!r} ClientDataSource requires engine='stream' "
                    "(the scan/eager engines assume device-resident "
                    "batches); pass EngineSpec(engine='stream') or stage the "
                    "data yourself and pass device arrays")
            if shard.mesh is not None:
                raise ValueError(
                    "host-resident sources stream on a single device (chunk "
                    "staging does not compose with the clients mesh yet); "
                    "drop ShardSpec or pass device-resident batches")
            if self.fault is not None:
                raise ValueError(
                    "fault injection requires device-resident batches (the "
                    "fault engines draw per-client faults inside the "
                    "compiled round); drop FaultSpec or pass device arrays")
        self.client_batches = client_batches
        # leaf axis 0 is the client axis EXCEPT for run_batched(batched_data=
        # True), where a seed axis leads — pass num_clients= explicitly there
        # (run_batched re-derives it for its own masks either way)
        if source is not None:
            self.num_clients = source.num_clients
        else:
            self.num_clients = (num_clients if num_clients is not None else
                                jax.tree_util.tree_leaves(
                                    client_batches)[0].shape[0])

        if _is_flat_params(w0):
            self._w0 = jnp.asarray(w0)
            self._unravel = None
            self.loss_fn = loss_fn
            self.eval_fn = eval_fn
        else:
            flat, unravel = flatten_model(w0)
            self._w0 = flat
            self._unravel = unravel
            # the session OWNS these wrappers: their identity is the compile-
            # cache key, so they must live exactly as long as the session
            self.loss_fn = lambda wf, batch: loss_fn(unravel(wf), batch)
            self.eval_fn = (None if eval_fn is None
                            else (lambda wf: eval_fn(unravel(wf))))
        if engine.engine == "stream" and self.stream.is_auto:
            # resolve chunk_clients="auto" from the live device budget (the
            # docs/scaling.md sizing rule, mirroring auto_shard_count); the
            # resolved value is recorded on self.stream so benchmarks can
            # name it in their config identity
            from repro.launch.mesh import auto_chunk_clients
            n_shards = (1 if shard.mesh is None
                        else shard.mesh.shape[shard.client_axis])
            self.stream = StreamSpec(chunk_clients=auto_chunk_clients(
                self.dim, self._client_bytes(), n_shards=n_shards))
        # the LocalTrainer closure (DESIGN.md §11): binds loss, LocalSpec and
        # tau once — its identity keys the engine's compile cache, and the
        # default spec reproduces the pre-LocalSpec program bit-for-bit.
        # Straggler cutoffs need the with_steps variant (arity +1, §13).
        # Context-consuming algorithms (DP-SCAFFOLD, §17) and the
        # control-variate trainer come as a pair: the engine appends the
        # algorithm's per-client context to the trainer call, so a mismatch
        # would surface as an opaque arity error deep in the compiled round.
        wants_ctx = bool(getattr(self.algorithm, "uses_local_context", False))
        has_cv = self.local is not None and getattr(
            self.local, "control_variates", False)
        if wants_ctx != has_cv:
            if wants_ctx:
                raise ValueError(
                    f"{self.algorithm.name!r} trains with per-client control "
                    "variates; pass local=LocalSpec(control_variates=True) "
                    "so the LocalTrainer consumes the (c_i, c) context")
            raise ValueError(
                "LocalSpec(control_variates=True) needs a control-variate "
                f"algorithm (e.g. make_algorithm('dp-scaffold', ...)); "
                f"{self.algorithm.name!r} supplies no local context")
        with_steps = self.fault is not None and self.fault.straggler > 0.0
        self._local_fn = build_cohort_local_fn(self.loss_fn, self.local,
                                               int(train.tau),
                                               with_steps=with_steps)

    # -- helpers -----------------------------------------------------------

    def _validate_cohort(self, m: int) -> None:
        if self.cohort is not None and self.cohort.size is not None \
                and not self.cohort.replace and self.cohort.size > m:
            raise ValueError(
                f"CohortSpec.size={self.cohort.size} exceeds the "
                f"{m}-client cohort (without replacement)")
        agg = getattr(self.algorithm, "aggregation", None)
        if agg is not None and getattr(agg, "is_weighted", False) \
                and len(agg.weights) != m:
            raise ValueError(
                f"WeightedAggregation carries {len(agg.weights)} weights for "
                f"a {m}-client cohort; weights are indexed by global client "
                "index and must match exactly (a short tuple would silently "
                "zero-weight the tail clients)")
        alg_m = getattr(self.algorithm, "num_clients", None)
        if getattr(self.algorithm, "uses_local_context", False) \
                and alg_m is not None and alg_m != m:
            raise ValueError(
                f"{self.algorithm.name!r} carries a {alg_m}-client variate "
                f"table for a {m}-client cohort; num_clients indexes the "
                "per-client state by global client index and must match")

    @property
    def dim(self) -> int:
        """Flat model dimension d (after any pytree ravel)."""
        return self._w0.shape[-1]

    def _client_bytes(self) -> int:
        """Approximate bytes of ONE client's data (the auto-chunk sizing
        term): one fetched row for a source, total-bytes / M for arrays."""
        if self._source is not None:
            rows = self._source.fetch(np.zeros((1,), np.int64))
            return int(sum(np.asarray(x).nbytes
                           for x in jax.tree_util.tree_leaves(rows)))
        total = sum(x.nbytes
                    for x in jax.tree_util.tree_leaves(self.client_batches))
        return int(total // max(1, self.num_clients))

    def _tail_n(self) -> int:
        return max(1, min(self.train.avg_last, self.train.rounds))

    def _donate(self) -> bool:
        if self.engine.donate is not None:
            return self.engine.donate
        return jax.default_backend() in ("tpu", "gpu")

    def _restore_params(self, w):
        return w if self._unravel is None else self._unravel(w)

    @property
    def _watchdog(self) -> bool:
        return self.fault is not None and self.fault.watchdog

    def _restore_batched(self, w):
        return w if self._unravel is None else jax.vmap(self._unravel)(w)

    def _chunk_callable(self, donate: bool, tap: bool = False):
        """The compiled chunk program + the extra positional args it takes.

        ``tap`` is the §15 on/off engine-tap flag — the ONLY telemetry bit
        that reaches the builders (and hence the compile-cache keys).
        """
        t, e, s = self.train, self.engine, self.shard
        if e.engine == "stream":
            n_shards = 1 if s.mesh is None else s.mesh.shape[s.client_axis]
            # cap the chunk at the cohort size: chunk >= M is the one-chunk
            # degenerate grid either way, and normalizing the spec keeps a
            # small cohort from being padded up to a large default chunk
            # (and lets all such sessions share one compiled program)
            stream = StreamSpec(chunk_clients=min(self.stream.chunk_clients,
                                                  max(1, self.num_clients)))
            if self._source is not None:
                # host-resident driver (§14): chunk-staged fetch + prefetch,
                # one compiled chunk program — the source rides the batches
                # slot of the fn(carry, key, ts, batches, eta_l) contract
                return (self._host_chunk_callable(stream.chunk_clients,
                                                  tap=tap),
                        self._source, ())
            if self.cohort is not None and self.cohort.gather:
                # gather-stream (§14): the cohort stays UN-chunked; the
                # round packs its slot table and the inner scan walks slots
                batches, mask = pad_cohort(self.client_batches, n_shards)
                m_pad = mask.shape[0]
                if s.mesh is None:
                    fn = _srv._gather_stream_chunk_fn(
                        self.algorithm, self._local_fn, self.eval_fn, donate,
                        e.scan_unroll, stream.chunk_clients,
                        self.num_clients, m_pad, t.eval_every, self.cohort,
                        self.fault, int(t.tau), tap)
                    return fn, batches, (mask,)
                leaves, treedef = jax.tree_util.tree_flatten(batches)
                fn = _srv._sharded_gather_stream_chunk_fn(
                    self.algorithm, self._local_fn, self.eval_fn, donate,
                    e.scan_unroll, stream.chunk_clients, s.mesh,
                    s.client_axis, treedef, tuple(x.ndim for x in leaves),
                    m_pad, self.num_clients, t.eval_every, self.cohort,
                    self.fault, int(t.tau), tap)
                return fn, batches, (mask,)
            batches, mask = chunk_cohort(self.client_batches,
                                         stream.chunk_clients,
                                         n_shards=n_shards)
            n_chunks = mask.shape[0]
            m_pad = n_chunks * stream.chunk_clients
            if s.mesh is None:
                fn = _srv._stream_chunk_fn(
                    self.algorithm, self._local_fn, self.eval_fn, donate,
                    e.scan_unroll, stream, self.num_clients, m_pad,
                    t.eval_every, self.cohort, self.fault, int(t.tau), tap)
                return fn, batches, (mask,)
            leaves, treedef = jax.tree_util.tree_flatten(batches)
            fn = _srv._sharded_stream_chunk_fn(
                self.algorithm, self._local_fn, self.eval_fn, donate,
                e.scan_unroll, stream, s.mesh, s.client_axis, treedef,
                tuple(x.ndim for x in leaves), n_chunks, self.num_clients,
                m_pad, t.eval_every, self.cohort, self.fault, int(t.tau), tap)
            return fn, batches, (mask,)
        if s.mesh is not None:
            m_true = self.num_clients
            batches, mask = pad_cohort(self.client_batches,
                                       s.mesh.shape[s.client_axis])
            leaves, treedef = jax.tree_util.tree_flatten(batches)
            fn = _srv._sharded_chunk_fn(
                self.algorithm, self._local_fn, self.eval_fn, donate,
                e.scan_unroll, s.mesh, s.client_axis, treedef,
                tuple(x.ndim for x in leaves), mask.shape[0], m_true,
                t.eval_every, self.cohort, self.fault, int(t.tau), tap)
            return fn, batches, (mask,)
        fn = _srv._scan_chunk_fn(self.algorithm, self._local_fn, self.eval_fn,
                                 donate, e.scan_unroll,
                                 t.eval_every, self.cohort, self.fault,
                                 int(t.tau), tap)
        return fn, self.client_batches, ()

    def _host_chunk_callable(self, chunk_clients: int, tap: bool = False):
        """The host-resident stream driver (DESIGN.md §14).

        Returns a callable with the engine contract ``fn(carry, key, ts,
        batches, eta_l)`` — so ``_run_scan``'s chunking, checkpointing, and
        resume machinery drive it unchanged — that loops rounds in Python:
        per round it derives the round key and participation mask eagerly
        (the same pure-jax draws the compiled engines trace), plans the
        chunk grid, and pumps ``source.fetch`` + ``jax.device_put`` through
        a ``DataSpec.prefetch``-deep staging deque so the next chunk's
        host→device transfer overlaps the current chunk's compiled moments
        program.  Chunks accumulate in the device-resident stream engine's
        exact order and arithmetic, so host-staged results are bit-exact
        with device-resident ones.

        With ``tap`` the driver emits each round's §15 telemetry payload
        directly from the Python loop (no io_callback needed — the loop IS
        on the host), through the same ``TapSession.emit`` funnel the
        compiled engines reach, so sinks cannot tell the paths apart.  The
        host path never injects faults (the session forbids the combination),
        so the fault slots are inert.
        """
        m = self.num_clients
        cohort = self.cohort
        gathering = cohort is not None and cohort.gather
        if gathering:
            cap = cohort.resolved_cap(m)
            c = min(chunk_clients, cap)
            n_chunks = -(-cap // c)
        else:
            c = chunk_clients
            n_chunks = -(-m // c)
        grid = n_chunks * c
        depth = max(1, self.data.prefetch)
        source = self._source
        moments_fn = _srv._host_moments_fn(self.algorithm, self._local_fn,
                                           self.data)
        finalize = _srv._host_finalize_fn(self.algorithm, self.eval_fn,
                                          self.train.eval_every, cohort, m)
        if not gathering:
            # dense grid: chunk j is global rows [j*c, (j+1)*c); rows past M
            # fetch client 0 (pad_cohort's repeat-row-0 pad, zero-masked) but
            # keep their padded-grid GLOBAL index for key-fold parity
            dense_gidx = [jnp.arange(j * c, (j + 1) * c, dtype=jnp.int32)
                          for j in range(n_chunks)]
            dense_idx = [np.where(g < m, g, 0)
                         for g in (np.arange(j * c, (j + 1) * c)
                                   for j in range(n_chunks))]

        clip_fn = _srv._tap_clip_fn(self.algorithm) if tap else None
        sigma_fn = _srv._tap_sigma_fn(self.algorithm) if tap else None

        def run_rounds(carry, key, ts, src, eta_l):
            """Python round loop with prefetch-staged chunk programs."""
            del src  # the engine contract's batches slot; == self._source
            w, opt_state, tail = carry
            cols = ([], [], [], [])
            for t_host in np.asarray(ts):
                t = jnp.int32(int(t_host))
                rk = jax.random.fold_in(key, t)
                if gathering:
                    round_mask = cohort.round_mask(rk, m)
                    slots, slot_mask, _ = gather_slots(round_mask, grid)
                    slots_np = np.asarray(jax.device_get(slots))
                    sgrid = slots.reshape(n_chunks, c)
                    mgrid = slot_mask.reshape(n_chunks, c)
                    plan = ((slots_np[j * c:(j + 1) * c], mgrid[j], sgrid[j])
                            for j in range(n_chunks))
                else:
                    round_mask = (cohort.round_mask(rk, m)
                                  if cohort is not None
                                  else jnp.ones((m,), jnp.float32))
                    full = jnp.concatenate(
                        [round_mask, jnp.zeros((grid - m,), jnp.float32)])
                    mgrid = full.reshape(n_chunks, c)
                    plan = ((dense_idx[j], mgrid[j], dense_gidx[j])
                            for j in range(n_chunks))

                buf = collections.deque()

                def stage(plan=plan, buf=buf):
                    """Fetch + device_put the next planned chunk, if any."""
                    p = next(plan, None)
                    if p is None:
                        return
                    idx_np, mask_j, gidx_j = p
                    buf.append((jax.device_put(source.fetch(idx_np)),
                                mask_j, gidx_j))

                for _ in range(depth):
                    stage()
                moments = None
                while buf:
                    batches_j, mask_j, gidx_j = buf.popleft()
                    mom = moments_fn(w, opt_state, rk, batches_j, mask_j,
                                     gidx_j, eta_l, t)
                    # refill AFTER dispatch: the next fetch/transfer overlaps
                    # the asynchronously executing chunk program
                    stage()
                    moments = (mom if moments is None
                               else _srv._host_add_moments(moments, mom))
                clip_val = clip_fn(opt_state) if tap else None
                w, opt_state, tail, outs = finalize(w, opt_state, tail,
                                                    rk, t, moments)
                for col, v in zip(cols, outs):
                    col.append(v)
                if tap:
                    sess = _tap_mod.active()
                    if sess is not None:
                        eta, metric, naive, target = outs
                        part = jnp.sum(round_mask)
                        payload = np.asarray(jax.device_get(jnp.stack([
                            jnp.float32(eta), jnp.float32(naive),
                            jnp.float32(target), jnp.float32(metric),
                            jnp.float32(clip_val), part, part,
                            jnp.float32(0.0), jnp.float32(0.0),
                            jnp.float32(0.0), jnp.float32(-1.0),
                            sigma_fn(t)])))
                        sess.emit(int(t_host), 0, payload)
            hist = tuple(jnp.stack(col) if col
                         else jnp.zeros((0,), jnp.float32) for col in cols)
            return (w, opt_state, tail), hist

        return run_rounds

    @staticmethod
    def _chunk_bounds(start: int, rounds: int, chunk_rounds: int | None,
                      checkpoint_every: int | None = None,
                      profile: tuple[int, int] | None = None):
        """[start, rounds) split at the chunk grid (anchored at ``start``,
        matching the historical one-shot behavior) union the checkpoint grid
        (anchored at round 0, so checkpoints land on stable global rounds)
        union the §15 profiler-window edges (so ``TelemetrySpec.
        profile_rounds=(a, b)`` traces exactly rounds [a, b) — the trace
        starts/stops at chunk boundaries)."""
        stops = set()
        chunk = (rounds - start) if not chunk_rounds else max(1, int(chunk_rounds))
        stops.update(range(start + chunk, rounds, chunk))
        if checkpoint_every:
            stops.update(b for b in range(checkpoint_every, rounds,
                                          checkpoint_every) if b > start)
        if profile is not None:
            stops.update(edge for edge in (profile[0], min(profile[1], rounds))
                         if start < edge < rounds)
        stops.add(rounds)
        edges = [start] + sorted(stops)
        return list(zip(edges[:-1], edges[1:]))

    # -- checkpoint plumbing ----------------------------------------------

    def _save(self, directory: str, step: int, key, carry, hist) -> str:
        key_arr, typed = _key_data(key)
        return ckpt.save_checkpoint(
            directory, step, {"carry": carry, "hist": hist},
            extra={"key": [int(x) for x in key_arr.reshape(-1)],
                   "key_typed": typed,
                   "algorithm": self.algorithm.name,
                   "rounds_total": self.train.rounds})

    def _carry_template(self):
        """Zero carry matching this session's structure (+ watchdog slot)."""
        w = jnp.asarray(self._w0)
        carry = (w, self.algorithm.init_state(w),
                 jnp.zeros((self._tail_n(),) + w.shape, w.dtype))
        if self._watchdog:
            carry = carry + (jnp.int32(-1),)
        return carry

    def _load(self, directory: str, *, retries: int = 0, backoff: float = 0.0):
        """Newest INTACT checkpoint (corrupt ones are skipped — §13), with
        optional transient-I/O retries; raises FileNotFoundError when the
        directory holds no checkpoints at all."""

        def template(step):
            return {
                "carry": self._carry_template(),
                "hist": tuple(jnp.zeros((step,), jnp.float32)
                              for _ in range(4)),
            }

        step, payload, meta = ckpt.load_latest_intact(
            directory, template, retries=retries, backoff=backoff)
        carry = jax.tree_util.tree_map(jnp.asarray, payload["carry"])
        hist = tuple(jnp.asarray(h) for h in payload["hist"])
        key = _key_restore(meta["key"], meta.get("key_typed", False))
        if meta.get("algorithm") not in (None, self.algorithm.name):
            raise ValueError(
                f"checkpoint was written by algorithm {meta['algorithm']!r}, "
                f"this session runs {self.algorithm.name!r}")
        return step, key, carry, hist

    # -- telemetry plumbing (§15) -----------------------------------------

    @staticmethod
    def _tap_on(tracker) -> bool:
        """The one telemetry bit that reaches the engines: a NullTracker (or
        no tracker) compiles the tap OUT entirely — the historical program."""
        return tracker is not None and not isinstance(tracker, NullTracker)

    def _ledger_fn(self):
        """Per-round cumulative privacy callable for ledger events, or None.

        Probing once at round count 1 classifies the session: non-private
        algorithms raise and get no ledger (the run proceeds untracked
        rather than erroring — observability must never kill a run).
        """
        delta = self.telemetry.ledger_delta
        if delta is None:
            return None
        try:
            self._budget_at(delta, 1)
        except (ValueError, AttributeError, TypeError):
            return None
        return lambda executed: self._budget_at(delta, executed)

    def _bytes_per_round(self) -> float | None:
        """§16 modeled communication footprint: ``4 * comm_floats(d)``.

        Static per spec (the compression plan changes per round, its SIZE
        does not), so it is computed once host-side and attached to every
        executed round event — the device payload is untouched."""
        comm = getattr(self.algorithm, "comm_floats", None)
        if comm is None:
            return None
        try:
            return 4.0 * float(comm(self.dim))
        except (TypeError, ValueError):
            return None

    def _tap_session(self, tracker, start_round: int) -> "_tap_mod.TapSession":
        return _tap_mod.TapSession(
            tracker, start_round=start_round, ledger_fn=self._ledger_fn(),
            faults_active=self.fault is not None and self.fault.injects,
            bytes_per_round=self._bytes_per_round())

    # -- entry points ------------------------------------------------------

    def run(self, key: jax.Array, *, tracker: Tracker | None = None,
            checkpoint_dir: str | None = None,
            checkpoint_every: int | None = None,
            on_divergence: RecoveryPolicy | None = None) -> RunResult:
        """Run all ``train.rounds`` rounds from round 0.

        ``tracker`` streams per-round §15 telemetry (η, metric on cadence,
        clip, realized cohort, fault totals, wall-clock, cumulative privacy
        ledger) to the sink while the compiled engines run.  Results are
        bit-identical to the untracked run; ``None`` or a ``NullTracker``
        compiles the tap out entirely.

        ``checkpoint_dir`` saves the full resumable state (carry + histories
        + RNG key + round counter) every ``checkpoint_every`` rounds (plus
        once at the end); ``resume`` picks it up bit-exactly.

        ``on_divergence`` (requires ``checkpoint_dir`` and an armed
        ``FaultSpec(watchdog=True)``) auto-recovers a watchdog-tripped run:
        roll back to the newest intact checkpoint, back off, re-run — see
        ``RecoveryPolicy`` and DESIGN.md §13.  Retried rounds join the
        privacy composition reported by ``privacy_report`` (and charge the
        live ledger), and each rollback is logged as a tracker event.
        """
        self._validate_cohort(self.num_clients)
        if checkpoint_every is not None and checkpoint_dir is None:
            raise ValueError("checkpoint_every requires checkpoint_dir "
                             "(nothing would be saved)")
        if on_divergence is not None:
            if not self._watchdog:
                raise ValueError(
                    "on_divergence requires FaultSpec(watchdog=True) — "
                    "without the watchdog a diverged run never trips")
            if checkpoint_dir is None:
                raise ValueError("on_divergence requires checkpoint_dir "
                                 "(rollback needs a checkpoint target)")
        if not self._tap_on(tracker):
            return self._run_dispatch(key, checkpoint_dir, checkpoint_every,
                                      on_divergence, tap=False)
        _tap_mod.install(self._tap_session(tracker, 0))
        tracker.start_phase("run", 0)
        try:
            return self._run_dispatch(key, checkpoint_dir, checkpoint_every,
                                      on_divergence, tap=True)
        finally:
            # flush every in-flight io_callback BEFORE detaching the session,
            # so no emission lands after finish()
            jax.effects_barrier()
            _tap_mod.uninstall()
            tracker.finish()

    def _run_dispatch(self, key, checkpoint_dir, checkpoint_every,
                      on_divergence, *, tap: bool) -> RunResult:
        """Engine dispatch shared by tracked and untracked ``run``."""
        if self.engine.engine == "eager":
            if self.shard.mesh is not None:
                raise ValueError("client sharding requires engine='scan'")
            if checkpoint_dir is not None:
                raise ValueError("checkpointing requires engine='scan'")
            t = self.train
            out = _srv._run_eager(
                self.algorithm, self._local_fn, self._w0, self.client_batches,
                rounds=t.rounds, eta_l=t.eta_l, key=key,
                eval_fn=self.eval_fn, avg_last=t.avg_last,
                eval_every=t.eval_every, cohort=self.cohort,
                fault=self.fault, tau=int(t.tau), tap=tap)
            out.final_w = self._restore_params(out.final_w)
            out.last_w = self._restore_params(out.last_w)
            return out
        return self._run_scan(key, start=0, carry=None, hist=[],
                              checkpoint_dir=checkpoint_dir,
                              checkpoint_every=checkpoint_every,
                              on_divergence=on_divergence, tap=tap)

    def resume(self, checkpoint_dir: str, *,
               checkpoint_every: int | None = None,
               tracker: Tracker | None = None) -> RunResult:
        """Continue the latest checkpoint in ``checkpoint_dir`` up to
        ``train.rounds`` and return the FULL RunResult (pre-checkpoint
        histories included) — bit-exactly what the uninterrupted run with the
        same chunk boundaries returns.

        A ``tracker`` is told the resume round (``start_phase('resume',
        step)``) and receives events for the RESUMED rounds only — never a
        duplicate of a round the checkpointed run already emitted; the
        cumulative ledger still counts from round 0.
        """
        self._validate_cohort(self.num_clients)
        step, key, carry, hist = self._load(checkpoint_dir)
        if step > self.train.rounds:
            raise ValueError(f"checkpoint is at round {step}, past this "
                             f"session's train.rounds={self.train.rounds}")
        if step == self.train.rounds:
            return self._assemble(carry, [hist])
        if not self._tap_on(tracker):
            return self._run_scan(key, start=step, carry=carry, hist=[hist],
                                  checkpoint_dir=checkpoint_dir,
                                  checkpoint_every=checkpoint_every)
        _tap_mod.install(self._tap_session(tracker, step))
        tracker.start_phase("resume", step)
        try:
            return self._run_scan(key, start=step, carry=carry, hist=[hist],
                                  checkpoint_dir=checkpoint_dir,
                                  checkpoint_every=checkpoint_every, tap=True)
        finally:
            jax.effects_barrier()
            _tap_mod.uninstall()
            tracker.finish()

    def run_batched(self, keys: jax.Array, *, batched_w0: bool = False,
                    batched_data: bool = False,
                    tracker: Tracker | None = None) -> RunResult:
        """One batched program over S seeds (``keys`` is (S,)-stacked PRNG
        keys); set ``batched_w0`` / ``batched_data`` when w0 / client_batches
        carry a matching leading seed axis.  Every RunResult field gains a
        leading (S,) axis.  The mesh shards the client axis exactly as in
        ``run`` (seeds stay vmapped inside each shard).  The batched engine
        is always one full-length scan program (``chunk_rounds`` /
        ``scan_unroll`` do not apply); it has no eager counterpart.

        A ``tracker`` fans out to per-seed sub-trackers (events gain a
        ``"seed"`` field).  The stream path streams live per seed; the
        vmapped scan path has no per-round host hook (a tap inside vmap
        would serialize the seed axis), so its events are REPLAYED from the
        returned histories after the program finishes — same schema, minus
        wall-clock timing and fault fields.
        """
        if self.fault is not None:
            raise ValueError(
                "run_batched has no fault-injection/watchdog support; run "
                "seeds through run() when a FaultSpec is active (a silently "
                "fault-free sweep would misreport the fault model)")
        if self.engine.engine == "stream":
            # streamed seed sweep: the seeds run SEQUENTIALLY through the one
            # compiled stream program (this session's cache entry compiles on
            # the first seed and hits on the rest) — a vmapped stream would
            # multiply peak chunk memory by S, defeating the engine's point.
            # Results match per-seed run() bit-for-bit by construction.
            if batched_w0 or batched_data:
                raise ValueError(
                    "run_batched(engine='stream') sweeps seeds through one "
                    "compiled stream program; per-seed w0/data axes are not "
                    "supported — loop run() with per-seed sessions instead")
            results = [
                self.run(k, tracker=tracker.sub(i) if self._tap_on(tracker)
                         else None)
                for i, k in enumerate(keys)]

            def stack(field: str):
                vals = [getattr(r, field) for r in results]
                return jax.tree_util.tree_map(
                    lambda *xs: jnp.stack(xs), *vals)

            return RunResult(final_w=stack("final_w"),
                             last_w=stack("last_w"),
                             eta_history=stack("eta_history"),
                             metric_history=stack("metric_history"),
                             eta_naive_history=stack("eta_naive_history"),
                             eta_target_history=stack("eta_target_history"))
        if self.engine.engine != "scan":
            raise ValueError(
                f"run_batched has no {self.engine.engine!r} engine; use "
                "engine='scan' (the default) or loop run() — a batched eager "
                "loop is just a Python loop over run()")
        if batched_w0 and self._unravel is not None:
            raise ValueError(
                "batched_w0 with a pytree model is ambiguous (the seed axis "
                "would be raveled into the parameters); stack flat vectors "
                "via flatten_model and unravel per seed instead")
        # with batched_data the client axis is 1 (seed axis leads)
        self._validate_cohort(jax.tree_util.tree_leaves(
            self.client_batches)[0].shape[1 if batched_data else 0])
        t, s = self.train, self.shard
        tail_n = self._tail_n()
        ts = jnp.arange(t.rounds, dtype=jnp.int32)
        eta_l = jnp.float32(t.eta_l)
        if s.mesh is not None:
            client_axis_pos = 1 if batched_data else 0
            m_true = jax.tree_util.tree_leaves(
                self.client_batches)[0].shape[client_axis_pos]
            batches, mask = pad_cohort(self.client_batches,
                                       s.mesh.shape[s.client_axis],
                                       axis=client_axis_pos)
            leaves, treedef = jax.tree_util.tree_flatten(batches)
            fn = _srv._sharded_batched_fn(
                self.algorithm, self._local_fn, self.eval_fn, tail_n,
                bool(batched_w0), bool(batched_data), s.mesh, s.client_axis,
                treedef, tuple(x.ndim for x in leaves), mask.shape[0], m_true,
                t.eval_every, self.cohort)
            final_w, last_w, etas, metrics, naives, targets = fn(
                self._w0, keys, batches, mask, eta_l, ts)
        else:
            fn = _srv._batched_run_fn(
                self.algorithm, self._local_fn, self.eval_fn, tail_n,
                bool(batched_w0), bool(batched_data), t.eval_every, self.cohort)
            final_w, last_w, etas, metrics, naives, targets = fn(
                self._w0, keys, self.client_batches, eta_l, ts)
        result = RunResult(final_w=self._restore_batched(final_w),
                           last_w=self._restore_batched(last_w),
                           eta_history=etas, metric_history=metrics,
                           eta_naive_history=naives,
                           eta_target_history=targets)
        if self._tap_on(tracker):
            self._replay_batched(tracker, result)
        return result

    def _replay_batched(self, tracker: "Tracker", result: RunResult) -> None:
        """Post-hoc per-seed event replay for the vmapped scan path (§15)."""
        import math as _math
        ledger = self._ledger_fn()
        bytes_pr = self._bytes_per_round()
        etas = np.asarray(jax.device_get(result.eta_history))
        metrics = np.asarray(jax.device_get(result.metric_history))
        naives = np.asarray(jax.device_get(result.eta_naive_history))
        targets = np.asarray(jax.device_get(result.eta_target_history))
        for i in range(etas.shape[0]):
            sub = tracker.sub(i)
            sub.start_phase("replay", 0)
            for t in range(etas.shape[1]):
                event = {"eta": float(etas[i, t]),
                         "eta_naive": float(naives[i, t]),
                         "eta_target": float(targets[i, t])}
                if bytes_pr is not None:
                    event["bytes_per_round"] = bytes_pr
                if _math.isfinite(float(metrics[i, t])):
                    event["metric"] = float(metrics[i, t])
                if ledger is not None:
                    rep = ledger(t + 1)
                    event.update(ledger_rounds=t + 1, mu=float(rep.mu),
                                 eps=float(rep.eps_numerical),
                                 eps_rdp=float(rep.eps_rdp))
                sub.log(t, event)
        tracker.finish()

    def spec_identity(self) -> str:
        """One-line frozen-spec identity string for run manifests (§15).

        Deterministic across processes for one configuration: the frozen
        specs repr their fields; the mesh contributes only its axis shape
        (device objects are process-local).  ``launch/dryrun`` records this
        so a launched run is attributable to its exact spec set.
        """
        s = self.shard
        mesh = ("none" if s.mesh is None else ",".join(
            f"{k}={v}" for k, v in sorted(dict(s.mesh.shape).items())))
        parts = [
            f"algorithm={self.algorithm.name}",
            f"train={self.train!r}",
            f"local={self.local!r}",
            f"engine={self.engine!r}",
            f"stream={self.stream!r}",
            f"cohort={(self.cohort if self.cohort is not None else CohortSpec())!r}",
            f"fault={(self.fault if self.fault is not None else FaultSpec())!r}",
            f"data={self.data!r}",
            f"telemetry={self.telemetry!r}",
            f"shard=mesh[{mesh}] axis={s.client_axis}",
        ]
        return " | ".join(parts)

    def privacy_report(self, delta: float) -> accounting.PrivacyReport:
        """Privacy budget of this session's full run, amplification-aware.

        CDP algorithms compose over ``train.rounds`` with the cohort's
        per-round sampling rate feeding the subsampled-GDP accounting
        (``accounting.cdp_budget(sampling_q=...)`` — conditional-sensitivity
        inflation plus CLT amplification, see its docstring); LDP reports are
        per-release (local guarantees do not amplify under central
        subsampling of who participates).  Raises for non-private algorithms.
        The sampling rate uses ``self.num_clients`` — construct the session
        with an explicit ``num_clients=`` when client data carries a leading
        seed axis (``run_batched(batched_data=True)``).

        Faults enter the accounting in both directions (DESIGN.md §13): the
        per-round rate is the REALIZED participation q * (1 - dropout) (a
        dropped client's data never touches the release), and every round
        re-executed by ``run(on_divergence=...)`` recovery joins the
        composition — call after ``run`` to fold that run's retries in.
        """
        return self._budget_at(delta, self.train.rounds + self._rounds_retried)

    def _budget_at(self, delta: float, rounds: int) -> accounting.PrivacyReport:
        """``privacy_report`` at an explicit executed-round count.

        The live telemetry ledger (§15) calls this every round with the
        rounds executed SO FAR (retries included), so the streamed ε/μ
        curve composes exactly like the end-of-run report — the final
        ledger entry equals ``privacy_report(delta)`` by construction.
        """
        alg = self.algorithm
        q = 1.0 if self.cohort is None else self.cohort.sampling_rate(self.num_clients)
        dropout = (self.fault.dropout
                   if self.fault is not None and self.fault.injects else 0.0)
        q = accounting.realized_participation(q, dropout)
        if hasattr(alg, "budget"):
            # composed algorithms (DESIGN.md §11): the mechanism owns its
            # accounting; the hook reproduces the name-dispatch below exactly
            # for every legacy registry name (pinned by tests/test_session.py)
            return alg.budget(delta, rounds=rounds, dim=self.dim,
                              sampling_q=q)
        name = alg.name
        if name in ("dp-fedavg-ldp-gauss", "ldp-fedexp-gauss"):
            return accounting.ldp_gaussian_budget(alg.clip_norm, alg.sigma, delta)
        if name in ("dp-fedavg-privunit", "ldp-fedexp-privunit"):
            return accounting.privunit_budget(alg.eps0, alg.eps1, alg.eps2)
        if name == "cdp-fedexp":
            sigma_xi = (alg.sigma_xi if alg.sigma_xi is not None
                        else self.dim * alg.sigma**2 / alg.num_clients)
            return accounting.cdp_budget(alg.clip_norm, alg.sigma,
                                         alg.num_clients, rounds,
                                         delta, sigma_xi=sigma_xi, sampling_q=q)
        if name in ("dp-fedavg-cdp", "dp-fedadam-cdp"):
            return accounting.cdp_budget(alg.clip_norm, alg.sigma,
                                         alg.num_clients, rounds,
                                         delta, sampling_q=q)
        if name == "cdp-fedexp-adaptive-clip":
            # single source of truth for the z-tracking accounting (the
            # 1/sqrt(q) realized-cohort inflation) lives on the mechanism
            from repro.core.compose import CentralGaussian
            return CentralGaussian(z_mult=alg.z_mult,
                                   num_clients=alg.num_clients).budget(
                delta, rounds=rounds, dim=self.dim,
                sampling_q=q, with_numerator=True)
        raise ValueError(f"{name!r} is not a private algorithm")

    # -- scan-engine internals --------------------------------------------

    @staticmethod
    def _cat_hist(outs):
        """Concatenate per-chunk history tuples (length-0 arrays when empty)."""
        return tuple(
            jnp.concatenate([jnp.asarray(o[i]) for o in outs])
            if outs else jnp.zeros((0,), jnp.float32)
            for i in range(4))

    def _assemble(self, carry, outs) -> RunResult:
        etas, metrics, naives, targets = self._cat_hist(outs)
        if len(carry) == 4:  # watchdog carry (§13)
            w_last, _, tail, fault_t = carry
            ft = int(jax.device_get(fault_t))
            fault_round = ft if ft >= 0 else None
        else:
            w_last, _, tail = carry
            fault_round = None
        return RunResult(
            final_w=self._restore_params(jnp.mean(tail, axis=0)),
            last_w=self._restore_params(w_last),
            eta_history=etas,
            metric_history=metrics,
            eta_naive_history=naives,
            eta_target_history=targets,
            fault_round=fault_round,
        )

    def _run_scan(self, key, *, start: int, carry, hist,
                  checkpoint_dir: str | None,
                  checkpoint_every: int | None,
                  on_divergence: RecoveryPolicy | None = None,
                  tap: bool = False) -> RunResult:
        t = self.train
        policy = on_divergence
        watchdog = self._watchdog
        donate = self._donate()
        if carry is None:
            # Donation would consume the caller's w0 buffer; hand a copy.
            w = (jnp.array(self._w0, copy=True) if donate
                 else jnp.asarray(self._w0))
            carry = (w, self.algorithm.init_state(w),
                     jnp.zeros((self._tail_n(),) + w.shape, w.dtype))
        if watchdog and len(carry) == 3:
            carry = carry + (jnp.int32(-1),)
        fn, batches, extra = self._chunk_callable(donate, tap=tap)
        eta_l = jnp.float32(t.eta_l)

        # §15 profiler window: (a, b) splits chunks at a and b so the traced
        # region covers exactly rounds [a, b) of the compiled program
        profile = self.telemetry.profile_rounds
        prof_dir = self.telemetry.profile_dir
        prof_active = False

        def _prof_stop(round_edge: int) -> None:
            nonlocal prof_active
            if not prof_active:
                return
            jax.block_until_ready(carry)
            jax.profiler.stop_trace()
            prof_active = False
            sess = _tap_mod.active()
            if tap and sess is not None:
                sess.profile_event("stop", round_edge, prof_dir)

        outs = list(hist)  # resumed histories (if any) lead the concat
        if policy is not None and ckpt.latest_step(checkpoint_dir) is None:
            # a rollback target must exist before any round runs
            self._save(checkpoint_dir, start, key, carry, self._cat_hist(outs))
        bounds = self._chunk_bounds(start, t.rounds, self.engine.chunk_rounds,
                                    checkpoint_every, profile)
        retries = 0
        inject_pending = self._inject_divergence is not None
        idx = 0
        while idx < len(bounds):
            s, e = bounds[idx]
            if inject_pending:
                carry = self._inject_divergence(carry, retries)
                inject_pending = False
            if profile is not None and s == profile[0] and not prof_active:
                jax.profiler.start_trace(prof_dir)
                prof_active = True
                sess = _tap_mod.active()
                if tap and sess is not None:
                    sess.profile_event("start", s, prof_dir)
            carry, chunk_outs = fn(carry, key,
                                   jnp.arange(s, e, dtype=jnp.int32),
                                   batches, *extra, eta_l)
            fault_t = int(jax.device_get(carry[3])) if watchdog else -1
            if prof_active and e >= min(profile[1], t.rounds):
                _prof_stop(e)
            if fault_t >= 0 and policy is not None \
                    and retries < policy.max_retries:
                # rollback: newest intact checkpoint, backoff, re-run.  The
                # rounds past the rollback step were EXECUTED (their releases
                # happened) and will re-run — they join the privacy
                # composition (privacy_report)
                _prof_stop(e)  # never leave a trace spanning a rollback
                retries += 1
                if policy.backoff > 0.0:
                    time.sleep(policy.backoff * retries)
                step, key, carry, restored = self._load(
                    checkpoint_dir, retries=2, backoff=policy.backoff)
                self._rounds_retried += fault_t + 1 - step
                if tap:
                    # flush the doomed chunk's emissions, then rewind the
                    # reorder buffer so re-run rounds deliver again; the
                    # executed count keeps the rolled-back rounds (§13)
                    jax.effects_barrier()
                    sess = _tap_mod.active()
                    if sess is not None:
                        sess.rollback(step, fault_t, retries)
                outs = [restored]
                bounds = self._chunk_bounds(step, t.rounds,
                                            self.engine.chunk_rounds,
                                            checkpoint_every, profile)
                idx = 0
                inject_pending = self._inject_divergence is not None
                continue
            outs.append(chunk_outs)
            # never persist a tripped carry — the rollback target must stay
            # the last HEALTHY state
            if checkpoint_dir is not None and fault_t < 0 and (
                    e == t.rounds
                    or (checkpoint_every and e % checkpoint_every == 0)):
                self._save(checkpoint_dir, e, key, carry,
                           self._cat_hist(outs))
            idx += 1
        return self._assemble(carry, outs)


def _key_data(key):
    """(raw uint32 key data, was_typed) for old- and new-style PRNG keys."""
    if jnp.issubdtype(jnp.asarray(key).dtype, jax.dtypes.prng_key):
        return jax.device_get(jax.random.key_data(key)), True
    return jax.device_get(jnp.asarray(key)), False


def _key_restore(data, typed: bool):
    arr = jnp.asarray(data, dtype=jnp.uint32)
    return jax.random.wrap_key_data(arr) if typed else arr
