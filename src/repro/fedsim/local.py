"""Client-side local training (Algorithm 3) — vectorized over the cohort.

Each client runs ``tau`` full-batch gradient steps on its own local dataset
starting from the broadcast global model and returns the raw local update
``Delta~_i = w_i^{(t-1,tau)} - w^{(t-1)}``.  The whole cohort is a single
``vmap`` so M=1000 clients execute as one batched XLA program.

Client sharding (DESIGN.md §9): when the engine partitions the cohort across
a ``clients`` mesh axis, each device vmaps only its (M/n_shards, d) slice.
``pad_cohort`` rounds M up to a multiple of the shard count by repeating row 0
(real data, so the padded rows' local training stays numerically tame for any
loss) and returns a {1., 0.} weight mask; every aggregation moment is
mask-weighted, so padded clients contribute exactly zero to the round.
``masked_cohort_updates`` additionally zeroes the padded rows' updates right
at the source, before they can reach a reduction.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

__all__ = ["local_update", "cohort_updates", "masked_cohort_updates", "pad_cohort"]


def local_update(loss_fn: Callable, w0: jax.Array, client_batch, tau: int, eta_l: float) -> jax.Array:
    """tau steps of (full-batch) GD on one client's data; returns the update."""

    def step(w, _):
        g = jax.grad(loss_fn)(w, client_batch)
        return w - eta_l * g, None

    # Unrolling trivial tau removes the inner while-loop, which otherwise
    # blocks XLA from fusing the local steps with the server-side reductions
    # when the whole round lives inside the scan engine's loop body; larger
    # tau keeps the loop — unrolling it multiplies compile time for heavy
    # per-step graphs (e.g. CNN grads) with no measured runtime win.
    w_tau, _ = jax.lax.scan(step, w0, None, length=tau,
                            unroll=tau if tau <= 2 else 1)
    return w_tau - w0


def cohort_updates(loss_fn: Callable, w: jax.Array, client_batches, tau: int, eta_l: float) -> jax.Array:
    """(M, d) matrix of raw local updates for the full cohort (vmapped)."""
    fn = lambda batch: local_update(loss_fn, w, batch, tau, eta_l)
    return jax.vmap(fn)(client_batches)


def masked_cohort_updates(loss_fn: Callable, w: jax.Array, client_batches,
                          tau: int, eta_l: float, mask: jax.Array) -> jax.Array:
    """``cohort_updates`` with padding rows forced to zero.

    The where (not a multiply) means a non-finite update from a padding
    client's dummy batch cannot leak into the shard's moments as 0 * nan.
    """
    deltas = cohort_updates(loss_fn, w, client_batches, tau, eta_l)
    return jnp.where(mask[:, None] > 0, deltas, 0.0)


def pad_cohort(client_batches, n_shards: int, *, axis: int = 0):
    """Pad every client-batch leaf to M % n_shards == 0; returns (batches, mask).

    Padding repeats client 0's data (finite, in-distribution) rather than
    zeros so arbitrary user losses don't see degenerate inputs; the returned
    float mask is 0. on padded rows and the moment reductions weight by it,
    which keeps the padded clients out of Σc_i, Σ||c_i||², the client count,
    and the adaptive-clip bit sum alike.  ``axis`` is the client axis of the
    leaves (1 in the batched engine, where a seed axis leads).
    """
    leaves = jax.tree_util.tree_leaves(client_batches)
    if not leaves:
        raise ValueError("client_batches has no array leaves to shard")
    m = leaves[0].shape[axis]
    pad = (-m) % n_shards
    mask = jnp.concatenate([jnp.ones((m,), jnp.float32),
                            jnp.zeros((pad,), jnp.float32)])
    if pad == 0:
        return client_batches, mask

    def pad_leaf(x):
        first = jax.lax.slice_in_dim(x, 0, 1, axis=axis)
        shape = x.shape[:axis] + (pad,) + x.shape[axis + 1:]
        return jnp.concatenate([x, jnp.broadcast_to(first, shape)], axis=axis)

    return jax.tree_util.tree_map(pad_leaf, client_batches), mask
