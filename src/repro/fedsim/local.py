"""Client-side local training (Algorithm 3) — vectorized over the cohort.

Each client runs local gradient steps on its own dataset starting from the
broadcast global model and returns the raw local update
``Delta~_i = w_i^{(t-1,tau)} - w^{(t-1)}``.  The whole cohort is a single
``vmap`` so M=1000 clients execute as one batched XLA program.

The LocalTrainer layer (DESIGN.md §11).  ``local_update`` is the historical
full-batch GD of Algorithm 3; ``local_update_spec`` is the pytree-native
spec-driven trainer behind ``LocalSpec`` — minibatch SGD with local epochs,
a FedProx proximal term, and client momentum.  The spec trainers are written
entirely with ``jax.tree_util`` maps, so they train ANY parameter pytree
(the ``models/`` zoo plugs in directly) as well as the engine's flat
vectors; gradients are taken on whatever structure the loss sees and only
the resulting update is raveled at the clip/aggregate boundary.
``build_cohort_local_fn`` binds (loss, LocalSpec, tau) into the one
``local_fn(w, batches, eta_l, round_key, start)`` closure the round engine
compiles — the default spec routes through ``cohort_updates`` unchanged,
bit-for-bit.

Client sharding (DESIGN.md §9): when the engine partitions the cohort across
a ``clients`` mesh axis, each device vmaps only its (M/n_shards, d) slice.
``pad_cohort`` rounds M up to a multiple of the shard count by repeating row 0
(real data, so the padded rows' local training stays numerically tame for any
loss) and returns a {1., 0.} weight mask; every aggregation moment is
mask-weighted, so padded clients contribute exactly zero to the round.
``masked_cohort_updates`` additionally zeroes the padded rows' updates right
at the source, before they can reach a reduction.  Spec trainers key their
minibatch shuffles by GLOBAL client index (``start`` offset), so a shard
draws exactly the batches the single-device engine would.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.aggregation import global_client_indices
from repro.fedsim.specs import LOCAL_TRAIN_TAG, LocalSpec

__all__ = [
    "local_update",
    "local_update_spec",
    "local_update_scaffold",
    "cohort_updates",
    "cohort_updates_spec",
    "cohort_updates_scaffold",
    "build_cohort_local_fn",
    "masked_cohort_updates",
    "mask_rows",
    "pad_cohort",
    "chunk_cohort",
    "gather_slots",
    "gather_rows",
]


def local_update(loss_fn: Callable, w0: jax.Array, client_batch, tau: int,
                 eta_l: float, steps: jax.Array | None = None) -> jax.Array:
    """tau steps of (full-batch) GD on one client's data; returns the update.

    ``steps`` (optional traced int32 scalar) is the straggler cutoff
    (DESIGN.md §13): the client commits only its first ``steps`` of the
    ``tau`` local steps — the partial update a deadline-missing device
    uploads.  Shapes stay static (all tau steps are traced; later ones are
    where-frozen), and ``steps=None`` is the historical path, bit-for-bit.
    """

    def step(w, _):
        """One full-batch gradient-descent step on this client's data."""
        g = jax.grad(loss_fn)(w, client_batch)
        return w - eta_l * g, None

    # Unrolling trivial tau removes the inner while-loop, which otherwise
    # blocks XLA from fusing the local steps with the server-side reductions
    # when the whole round lives inside the scan engine's loop body; larger
    # tau keeps the loop — unrolling it multiplies compile time for heavy
    # per-step graphs (e.g. CNN grads) with no measured runtime win.
    if steps is None:
        w_tau, _ = jax.lax.scan(step, w0, None, length=tau,
                                unroll=tau if tau <= 2 else 1)
        return w_tau - w0

    def gated(w, i):
        """Step i, committed only while i < steps (straggler cutoff)."""
        w_new, _ = step(w, None)
        return jnp.where(i < steps, w_new, w), None

    w_tau, _ = jax.lax.scan(gated, w0, jnp.arange(tau, dtype=jnp.int32),
                            unroll=tau if tau <= 2 else 1)
    return w_tau - w0


def _tmap(f, *trees):
    return jax.tree_util.tree_map(f, *trees)


def local_update_spec(loss_fn: Callable, w0, client_batch, key: jax.Array,
                      spec: LocalSpec, tau: int, eta_l,
                      steps: jax.Array | None = None):
    """Spec-driven local training for ONE client; returns the update pytree.

    ``w0`` may be any parameter pytree (a flat (d,) vector is the one-leaf
    case) — every update is a ``tree_map``, and gradients are taken on the
    structure ``loss_fn`` consumes.  Static shapes throughout: the step
    count, minibatch size and epoch layout are trace-time constants, so one
    compiled program serves every round.

    Semantics (see ``LocalSpec``): with ``batch_size`` set, step ``s`` of
    epoch ``e`` trains on rows ``perm_e[s*b : (s+1)*b]`` of a per-epoch
    shuffle drawn from ``fold_in(key, e)``; otherwise ``tau`` full-batch
    steps.  FedProx adds ``prox_mu * (w - w0)`` to each gradient; client
    momentum accumulates a velocity that starts at zero every round.
    """
    grad_fn = jax.grad(loss_fn)

    def gd_step(carry, batch):
        """One local gradient step (FedProx pull and momentum per the spec)."""
        w, v = carry
        g = grad_fn(w, batch)
        if spec.prox_mu:
            g = _tmap(lambda gg, ww, w0l: gg + spec.prox_mu * (ww - w0l), g, w, w0)
        if spec.momentum:
            v = _tmap(lambda vv, gg: spec.momentum * vv + gg, v, g)
            d = v
        else:
            d = g
        w = _tmap(lambda ww, dd: ww - eta_l * dd, w, d)
        return (w, v), None

    def gate(i, new, old):
        """Commit a (w, v) carry update only while i < steps (§13 cutoff)."""
        return _tmap(lambda a, b: jnp.where(i < steps, a, b), new, old)

    carry0 = (w0, _tmap(jnp.zeros_like, w0))
    if spec.batch_size is None:
        if steps is None:
            (w_tau, _), _ = jax.lax.scan(lambda c, _: gd_step(c, client_batch),
                                         carry0, None, length=tau,
                                         unroll=tau if tau <= 2 else 1)
        else:
            (w_tau, _), _ = jax.lax.scan(
                lambda c, i: (gate(i, gd_step(c, client_batch)[0], c), None),
                carry0, jnp.arange(tau, dtype=jnp.int32),
                unroll=tau if tau <= 2 else 1)
        return _tmap(lambda a, c: a - c, w_tau, w0)

    leaves, treedef = jax.tree_util.tree_flatten(client_batch)
    if not leaves or leaves[0].ndim < 1:
        raise ValueError("LocalSpec(batch_size=...) needs client batches "
                         "with a leading per-sample axis")
    n = leaves[0].shape[0]
    b = min(spec.batch_size, n)
    n_batches = max(1, n // b)

    # ALL PRNG work and ALL minibatch gathers happen up front: one shuffle
    # per epoch (vmapped), then one (steps, b, ...) gather per leaf, and the
    # training scan consumes the pre-gathered minibatches as plain xs.  This
    # keeps fold_in/permutation/gather out of the grad-bearing scan body —
    # one O(n log n) shuffle per epoch instead of per minibatch, and it is
    # the formulation that compiles correctly inside vmap-under-shard_map
    # with a downstream psum (gather+grad inside the scan body miscompiled
    # per-client randomness on forced-host-device meshes, jax 0.4.37 —
    # tests/test_local.py pins the sharded == single-device equivalence
    # this guards).  Cost: epochs extra copies of each client's sample set.
    perms = jax.vmap(lambda e: jax.random.permutation(
        jax.random.fold_in(key, e), n))(jnp.arange(spec.epochs, dtype=jnp.int32))
    idxs = perms[:, : n_batches * b].reshape(spec.epochs * n_batches, b)
    # only leaves carrying the per-sample axis are sliced; scalars and
    # differently-shaped leaves (per-client constants) ride along whole
    sliceable = [x.ndim >= 1 and x.shape[0] == n for x in leaves]
    xs = [jnp.take(x, idxs, axis=0)
          for x, ok in zip(leaves, sliceable) if ok]

    def batch_step(carry, mb_leaves):
        """One minibatch step over the pre-gathered minibatch leaves."""
        mb = list(mb_leaves)
        merged = [mb.pop(0) if ok else x for x, ok in zip(leaves, sliceable)]
        return gd_step(carry, jax.tree_util.tree_unflatten(treedef, merged))

    if steps is None:
        (w_tau, _), _ = jax.lax.scan(batch_step, carry0, tuple(xs))
    else:
        n_steps = spec.epochs * n_batches
        (w_tau, _), _ = jax.lax.scan(
            lambda c, x: (gate(x[1], batch_step(c, x[0])[0], c), None),
            carry0, (tuple(xs), jnp.arange(n_steps, dtype=jnp.int32)))
    return _tmap(lambda a, c: a - c, w_tau, w0)


def cohort_updates(loss_fn: Callable, w: jax.Array, client_batches, tau: int,
                   eta_l: float, steps: jax.Array | None = None) -> jax.Array:
    """(M, d) matrix of raw local updates for the full cohort (vmapped).

    ``steps`` (optional (M,) int32) is the per-client straggler cutoff
    (§13); None is the historical all-tau path, bit-for-bit.
    """
    if steps is None:
        fn = lambda batch: local_update(loss_fn, w, batch, tau, eta_l)
        return jax.vmap(fn)(client_batches)
    fn = lambda batch, s: local_update(loss_fn, w, batch, tau, eta_l, steps=s)
    return jax.vmap(fn)(client_batches, steps)


def local_update_scaffold(loss_fn: Callable, w0: jax.Array, client_batch,
                          c_i: jax.Array, c: jax.Array, tau: int, eta_l: float,
                          steps: jax.Array | None = None) -> jax.Array:
    """tau SCAFFOLD control-variate steps on one client (DESIGN.md §17).

    Each step moves by the drift-corrected direction ``g - c_i + c`` — the
    exact op order (and rolled ``length=tau`` scan) of the retired
    ``run_dp_scaffold`` local solve, so the migrated dense round is pinned
    bit-for-bit against it.  ``steps`` is the §13 straggler cutoff, gated
    exactly as ``local_update``.
    """

    def step(y, _):
        """One control-variate-corrected gradient step."""
        g = jax.grad(loss_fn)(y, client_batch)
        return y - eta_l * (g - c_i + c), None

    if steps is None:
        y_tau, _ = jax.lax.scan(step, w0, None, length=tau)
        return y_tau - w0

    def gated(y, i):
        """Step i, committed only while i < steps (straggler cutoff)."""
        y_new, _ = step(y, None)
        return jnp.where(i < steps, y_new, y), None

    y_tau, _ = jax.lax.scan(gated, w0, jnp.arange(tau, dtype=jnp.int32))
    return y_tau - w0


def cohort_updates_scaffold(loss_fn: Callable, w: jax.Array, client_batches,
                            tau: int, eta_l: float, ctx,
                            steps: jax.Array | None = None) -> jax.Array:
    """(m, d) control-variate cohort updates; ``ctx`` is the algorithm's
    per-round local context ``(c_i rows, global c)`` sliced by the engine
    (``DPScaffoldServer.local_context``), vmapped alongside the batches."""
    c_is, c = ctx
    if steps is None:
        fn = lambda batch, ci: local_update_scaffold(loss_fn, w, batch, ci, c,
                                                     tau, eta_l)
        return jax.vmap(fn)(client_batches, c_is)
    fn = lambda batch, ci, s: local_update_scaffold(loss_fn, w, batch, ci, c,
                                                    tau, eta_l, steps=s)
    return jax.vmap(fn)(client_batches, c_is, steps)


def cohort_updates_spec(loss_fn: Callable, w, client_batches, spec: LocalSpec,
                        tau: int, eta_l, round_key: jax.Array,
                        start: int | jax.Array = 0,
                        steps: jax.Array | None = None):
    """Spec-driven cohort updates, vmapped with per-client local PRNG keys.

    Client ``i`` of the shard draws its minibatch shuffles from
    ``fold_in(fold_in(round_key, LOCAL_TRAIN_TAG), start + i)`` — keyed by
    GLOBAL index so sharded and single-device engines shuffle identically.
    A (m,) vector ``start`` names each row's global index directly (the
    sparse-gather path, DESIGN.md §14).  ``steps`` (optional (M,) int32) is
    the per-client straggler cutoff (§13).
    """
    m = jax.tree_util.tree_leaves(client_batches)[0].shape[0]
    base = jax.random.fold_in(round_key, LOCAL_TRAIN_TAG)
    idx = global_client_indices(start, m)
    keys = jax.vmap(lambda i: jax.random.fold_in(base, i))(idx)
    if steps is None:
        fn = lambda batch, k: local_update_spec(loss_fn, w, batch, k, spec, tau, eta_l)
        return jax.vmap(fn)(client_batches, keys)
    fn = lambda batch, k, s: local_update_spec(loss_fn, w, batch, k, spec,
                                               tau, eta_l, steps=s)
    return jax.vmap(fn)(client_batches, keys, steps)


def _build_cohort_local_fn(loss_fn: Callable, spec: LocalSpec | None, tau: int,
                           with_steps: bool = False):
    if spec is not None and spec.control_variates:
        # SCAFFOLD trainer (§17): one extra trailing arg — the algorithm's
        # per-client context (c_i rows, c), appended by _local_caller when
        # the algorithm declares uses_local_context
        if with_steps:
            def local_fn(w, client_batches, eta_l, round_key, start, steps,
                         ctx):
                """Control-variate closure with straggler cutoffs (§13/§17)."""
                return cohort_updates_scaffold(loss_fn, w, client_batches,
                                               tau, eta_l, ctx, steps=steps)
            return local_fn

        def local_fn(w, client_batches, eta_l, round_key, start, ctx):
            """The engine's control-variate local-training closure (§17)."""
            return cohort_updates_scaffold(loss_fn, w, client_batches, tau,
                                           eta_l, ctx)
        return local_fn

    if with_steps:
        if spec is None or spec.is_default:
            def local_fn(w, client_batches, eta_l, round_key, start, steps):
                """Local-training closure with per-client straggler cutoffs (§13)."""
                return cohort_updates(loss_fn, w, client_batches, tau, eta_l,
                                      steps=steps)
            return local_fn

        def local_fn(w, client_batches, eta_l, round_key, start, steps):
            """Local-training closure with per-client straggler cutoffs (§13)."""
            return cohort_updates_spec(loss_fn, w, client_batches, spec, tau,
                                       eta_l, round_key, start, steps=steps)
        return local_fn

    if spec is None or spec.is_default:
        def local_fn(w, client_batches, eta_l, round_key, start):
            """The engine's local-training closure: cohort deltas for one round."""
            return cohort_updates(loss_fn, w, client_batches, tau, eta_l)
        return local_fn

    def local_fn(w, client_batches, eta_l, round_key, start):
        """The engine's local-training closure: cohort deltas for one round."""
        return cohort_updates_spec(loss_fn, w, client_batches, spec, tau,
                                   eta_l, round_key, start)
    return local_fn


_cached_cohort_local_fn = functools.lru_cache(maxsize=64)(_build_cohort_local_fn)


def build_cohort_local_fn(loss_fn: Callable, spec: LocalSpec | None, tau: int,
                          with_steps: bool = False):
    """Bind (loss, LocalSpec, tau) into the engine's local-training closure:

        local_fn(w, client_batches, eta_l, round_key, start) -> (M, d) deltas

    The default spec returns the historical ``cohort_updates`` computation —
    the identical jaxpr, so pre-LocalSpec sessions stay bit-for-bit.  The
    closure's identity keys the engine's compile cache, so it is MEMOIZED on
    (loss_fn identity, spec, tau): two sessions sharing a loss closure and
    equal specs receive the same ``local_fn`` object and keep sharing one
    compiled chunk program, exactly as the pre-LocalSpec engine keyed on
    ``loss_fn`` directly.  An unhashable loss falls back to an uncached
    build (a per-session retrace — the cost the engine's builder fallback
    already documents, never an error).

    ``with_steps=True`` (straggler faults, §13) returns the variant closure

        local_fn(w, client_batches, eta_l, round_key, start, steps)

    taking a per-client (m,) int32 step-count vector; it keys the memo
    separately, so fault-free sessions keep sharing the historical closure.
    """
    try:
        return _cached_cohort_local_fn(loss_fn, spec, tau, with_steps)
    except TypeError:
        return _build_cohort_local_fn(loss_fn, spec, tau, with_steps)


def mask_rows(deltas: jax.Array, mask: jax.Array) -> jax.Array:
    """Zero the masked-out rows of a delta matrix AT THE SOURCE.

    The where (not a multiply) means a non-finite update from a padding or
    non-sampled client's dummy batch cannot leak into the round's moments
    as 0 * nan.
    """
    return jnp.where(mask[:, None] > 0, deltas, 0.0)


def masked_cohort_updates(loss_fn: Callable, w: jax.Array, client_batches,
                          tau: int, eta_l: float, mask: jax.Array) -> jax.Array:
    """``cohort_updates`` with padding rows forced to zero (see mask_rows)."""
    deltas = cohort_updates(loss_fn, w, client_batches, tau, eta_l)
    return mask_rows(deltas, mask)


def pad_cohort(client_batches, n_shards: int, *, axis: int = 0):
    """Pad every client-batch leaf to M % n_shards == 0; returns (batches, mask).

    Padding repeats client 0's data (finite, in-distribution) rather than
    zeros so arbitrary user losses don't see degenerate inputs; the returned
    float mask is 0. on padded rows and the moment reductions weight by it,
    which keeps the padded clients out of Σc_i, Σ||c_i||², the client count,
    and the adaptive-clip bit sum alike.  ``axis`` is the client axis of the
    leaves (1 in the batched engine, where a seed axis leads).
    """
    leaves = jax.tree_util.tree_leaves(client_batches)
    if not leaves:
        raise ValueError("client_batches has no array leaves to shard")
    m = leaves[0].shape[axis]
    pad = (-m) % n_shards
    mask = jnp.concatenate([jnp.ones((m,), jnp.float32),
                            jnp.zeros((pad,), jnp.float32)])
    if pad == 0:
        return client_batches, mask

    def pad_leaf(x):
        """Append ``pad`` copies of row 0 along the client axis of one leaf."""
        first = jax.lax.slice_in_dim(x, 0, 1, axis=axis)
        shape = x.shape[:axis] + (pad,) + x.shape[axis + 1:]
        return jnp.concatenate([x, jnp.broadcast_to(first, shape)], axis=axis)

    return jax.tree_util.tree_map(pad_leaf, client_batches), mask


def chunk_cohort(client_batches, chunk_clients: int, *, n_shards: int = 1):
    """Lay the cohort on the streaming engine's chunk grid (DESIGN.md §12).

    Pads M to a multiple of ``chunk_clients * n_shards`` (zero-weight
    clients, exactly as ``pad_cohort``) and reshapes every client-batch leaf
    from (m_pad, ...) to (n_chunks, chunk_clients, ...); the weight mask
    comes back as (n_chunks, chunk_clients).  Chunk j holds the clients with
    global indices [j*c, (j+1)*c), so contiguous chunk blocks are contiguous
    client blocks — under §9 sharding the leading CHUNK axis shards over the
    ``clients`` mesh and every device receives the same client rows the
    dense sharded engine would.

    Args:
      client_batches: pytree of per-client leaves, client axis leading.
      chunk_clients: clients per chunk (``StreamSpec.chunk_clients``).
      n_shards: client-mesh size the chunk grid must also divide by.

    Returns:
      ``(chunk_batches, chunk_mask)`` — the reshaped pytree and the float
      {1., 0.} weight mask on the same grid.
    """
    if chunk_clients < 1:
        raise ValueError(f"chunk_clients must be >= 1, got {chunk_clients}")
    batches, mask = pad_cohort(client_batches, chunk_clients * n_shards)
    n_chunks = mask.shape[0] // chunk_clients

    def to_grid(x):
        """Reshape one padded leaf onto the (n_chunks, chunk_clients, ...) grid."""
        return x.reshape((n_chunks, chunk_clients) + x.shape[1:])

    return (jax.tree_util.tree_map(to_grid, batches),
            mask.reshape(n_chunks, chunk_clients))


def gather_slots(mask: jax.Array, cap: int):
    """Pack a sparse participation mask into a dense slot table (§14).

    Given the (m,) per-round mask (0. = non-participant), returns

        slots:       (cap,) int32 — slot j holds the global index of the
                     j-th participant (in index order); padding slots hold 0
        slot_mask:   (cap,) float32 — the participant's mask value in its
                     slot (1., or the multiplicity weight), 0. on padding
        overflow:    scalar float32 — how many participants did NOT fit in
                     ``cap`` slots (0. when the cap held)

    Pure jax with static shapes (mask → positions via cumsum, one scatter
    with ``mode="drop"``), so it runs inside the scan body.  Padding slots
    point at client 0 — REAL data, so padded rows' local training stays
    finite for any loss — and carry a zero ``slot_mask``, which the §9/§13
    masked-moment protocol already guarantees keeps them out of every sum.
    Participants beyond ``cap`` are dropped from the round (their scatter
    target falls off the table); ``overflow`` lets callers surface that.
    """
    m = mask.shape[0]
    on = mask > 0
    pos = jnp.cumsum(on.astype(jnp.int32)) - 1          # participant rank
    target = jnp.where(on & (pos < cap), pos, cap)      # cap = off-table
    slots = jnp.full((cap,), m, jnp.int32).at[target].set(
        jnp.arange(m, dtype=jnp.int32), mode="drop")
    valid = slots < m
    slots = jnp.where(valid, slots, 0)
    slot_mask = jnp.where(valid, jnp.take(mask, slots, axis=0), 0.0)
    overflow = jnp.maximum(jnp.sum(on.astype(jnp.float32)) - float(cap), 0.0)
    return slots, slot_mask.astype(jnp.float32), overflow


def gather_rows(tree, slots: jax.Array, *, axis: int = 0):
    """Gather the slot rows out of every leaf of a per-client pytree.

    ``jnp.take`` along the client axis — the §14 pre-gather that shrinks a
    (m, ...) cohort block to the (cap, ...) sampled block before local
    training runs.  Slot indices are always in-range (``gather_slots`` clamps
    padding to client 0), so no gather-mode games are needed.
    """
    return jax.tree_util.tree_map(
        lambda x: jnp.take(x, slots, axis=axis), tree)
