"""Client-side local training (Algorithm 3) — vectorized over the cohort.

Each client runs ``tau`` full-batch gradient steps on its own local dataset
starting from the broadcast global model and returns the raw local update
``Delta~_i = w_i^{(t-1,tau)} - w^{(t-1)}``.  The whole cohort is a single
``vmap`` so M=1000 clients execute as one batched XLA program.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

__all__ = ["local_update", "cohort_updates"]


def local_update(loss_fn: Callable, w0: jax.Array, client_batch, tau: int, eta_l: float) -> jax.Array:
    """tau steps of (full-batch) GD on one client's data; returns the update."""

    def step(w, _):
        g = jax.grad(loss_fn)(w, client_batch)
        return w - eta_l * g, None

    # Unrolling trivial tau removes the inner while-loop, which otherwise
    # blocks XLA from fusing the local steps with the server-side reductions
    # when the whole round lives inside the scan engine's loop body; larger
    # tau keeps the loop — unrolling it multiplies compile time for heavy
    # per-step graphs (e.g. CNN grads) with no measured runtime win.
    w_tau, _ = jax.lax.scan(step, w0, None, length=tau,
                            unroll=tau if tau <= 2 else 1)
    return w_tau - w0


def cohort_updates(loss_fn: Callable, w: jax.Array, client_batches, tau: int, eta_l: float) -> jax.Array:
    """(M, d) matrix of raw local updates for the full cohort (vmapped)."""
    fn = lambda batch: local_update(loss_fn, w, batch, tau, eta_l)
    return jax.vmap(fn)(client_batches)
