"""DP-SCAFFOLD baseline (Noble, Bellet, Dieuleveut, AISTATS 2022).

SCAFFOLD removes client drift with control variates: client i steps with
``g - c_i + c`` and refreshes its variate via option-II
``c_i+ = c_i - c + (w - y_i)/(tau * eta_l)``.  Under *client-level* DP the
client releases TWO vectors per round (the model update and the variate
update); we clip each to C and add Gaussian noise of std sigma*sqrt(2) to each
release so the per-round GDP budget matches a single-release algorithm with
std sigma (two mechanisms at mu/sqrt(2) compose to mu).  This is the
"noise doubling" that makes DP-SCAFFOLD weak at client-level DP — exactly the
paper's observation in §5.

Note: clients are stateful here (they keep c_i), which is the paper's stated
practical objection to SCAFFOLD-style methods.
"""
from __future__ import annotations

import dataclasses
import math
import warnings
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.clipping import clip_batch
from repro.fedsim.server import RunResult

__all__ = ["DPScaffoldConfig", "run_dp_scaffold"]

# one-shot deprecation flag: the warning fires on the FIRST run_dp_scaffold
# call per process, not per round loop — sweeps that launch hundreds of
# baseline runs would otherwise drown their logs in repeats
_WARNED = False


@dataclasses.dataclass(frozen=True)
class DPScaffoldConfig:
    """DP-SCAFFOLD knobs: clip, noise scale, central vs local noising, cohort size."""
    clip_norm: float
    sigma: float                 # baseline noise scale (as for DP-FedAvg)
    central: bool                # True: CDP (noise std sigma*sqrt(2)/sqrt(M) on means)
    num_clients: int


def run_dp_scaffold(
    cfg: DPScaffoldConfig,
    loss_fn: Callable,
    w0: jax.Array,
    client_batches,
    *,
    rounds: int,
    tau: int,
    eta_l: float,
    key: jax.Array,
    eval_fn: Callable | None = None,
    avg_last: int = 2,
) -> RunResult:
    """Run T rounds of DP-SCAFFOLD (two clipped+noised releases per round).

    Same calling convention as the deprecated ``run_federated``: flat (d,)
    ``w0``, per-client batches on leaf axis 0, fold_in(key, t) round keys.
    Returns a ``RunResult`` with eta_history pinned to 1.

    .. deprecated::
        This standalone Python round loop predates the composable stack and
        gets none of its engines, telemetry, or compression.  Its algorithm
        is now ``make_algorithm("dp-scaffold", ...)`` run under
        ``FederatedSession`` with ``LocalSpec(control_variates=True)`` —
        pinned bit-for-bit against this loop by ``tests/test_schedules.py``;
        this entry point will be removed.
    """
    global _WARNED
    if not _WARNED:
        _WARNED = True
        warnings.warn(
            "run_dp_scaffold is deprecated: it is a standalone Python round "
            "loop outside the engine stack (no scan/stream/sharded engines, "
            "no §15 telemetry, no §16 compression). Build the algorithm via "
            "make_algorithm('dp-scaffold', ...) and run it under "
            "FederatedSession with LocalSpec(control_variates=True).",
            DeprecationWarning, stacklevel=2)
    m = cfg.num_clients
    d = w0.shape[0]
    variate_scale = 1.0 / (tau * eta_l)

    def local_update(w, c, c_i, batch):
        """One client's SCAFFOLD local solve: returns (dy, variate update)."""
        def step(y, _):
            """One local step with the SCAFFOLD control-variate correction."""
            g = jax.grad(loss_fn)(y, batch)
            return y - eta_l * (g - c_i + c), None

        y, _ = jax.lax.scan(step, w, None, length=tau)
        dy = y - w
        c_i_new = c_i - c - dy * variate_scale
        return dy, c_i_new - c_i

    def one_round(state, round_key):
        """One jitted round dispatched from the Python loop."""
        w, c, c_is = state
        k_dy, k_dc = jax.random.split(round_key)
        dy, dc = jax.vmap(lambda ci, b: local_update(w, c, ci, b))(c_is, client_batches)
        dy_clip = clip_batch(dy, cfg.clip_norm)
        dc_clip = clip_batch(dc, cfg.clip_norm * variate_scale)
        if cfg.central:
            std = cfg.sigma * math.sqrt(2.0) / math.sqrt(m)
            dy_bar = jnp.mean(dy_clip, axis=0) + std * jax.random.normal(k_dy, (d,))
            dc_bar = jnp.mean(dc_clip, axis=0) + std * variate_scale * jax.random.normal(k_dc, (d,))
        else:
            std = cfg.sigma * math.sqrt(2.0)
            dy_bar = jnp.mean(dy_clip + std * jax.random.normal(k_dy, dy.shape), axis=0)
            dc_bar = jnp.mean(dc_clip + std * variate_scale * jax.random.normal(k_dc, dc.shape), axis=0)
        c_is_new = c_is + dc_clip  # clients keep their (clipped) variate update
        w_next = w + dy_bar
        c_next = c + dc_bar
        metric = eval_fn(w_next) if eval_fn is not None else jnp.nan
        return (w_next, c_next, c_is_new), metric

    round_jit = jax.jit(one_round)
    state = (w0, jnp.zeros_like(w0), jnp.zeros((m, d), w0.dtype))
    tail, metrics = [], []
    for t in range(rounds):
        state, metric = round_jit(state, jax.random.fold_in(key, t))
        metrics.append(metric)
        tail.append(state[0])
        if len(tail) > avg_last:
            tail.pop(0)

    final_w = jnp.mean(jnp.stack(tail), axis=0)
    return RunResult(
        final_w=final_w,
        last_w=state[0],
        eta_history=jnp.ones(rounds),
        metric_history=jnp.stack(metrics),
    )
