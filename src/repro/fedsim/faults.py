"""Deterministic per-round fault injection + degradation helpers (DESIGN.md §13).

Production federated rounds are defined by failure: clients drop out,
stragglers miss the deadline with partial local training, and devices return
corrupted (non-finite) updates.  ``FaultSpec`` declares the fault model;
this module owns the draws and the degradation plumbing the engines share:

* **Draw discipline.**  All fault randomness for round t derives from
  ``fold_in(round_key, FAULT_TAG)`` — one substream per fault class — and
  every vector is drawn FULL-COHORT from the replicated round key, indexed
  by GLOBAL client index.  Shards and stream chunks slice their rows of the
  one replicated draw (the §9/§10 full-mask-then-slice pattern), so a
  faulty run is bit-reproducible across the scan / eager / sharded / stream
  engines and across checkpoint resumes.

* **Degradation discipline.**  A failed client becomes a ZERO-WEIGHT row in
  the existing masked-moment protocol: the effective participation mask is
  the product of the sampling/padding mask, the dropout survival mask, and
  a server-side finite screen (``finite_rows``) that catches injected NaN
  rows and genuinely diverged clients alike.  Rows are where-zeroed at the
  source (``mask_rows``), never multiplied, so a non-finite update can
  never poison a reduction as ``0 * nan``.  The realized (not nominal)
  count then flows through the clamped-count resolution — an all-failed
  round is a zero-update no-op, never NaN.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.fedsim.local import mask_rows
from repro.fedsim.specs import FAULT_TAG, FaultSpec

__all__ = [
    "fault_masks",
    "gather_fault_rows",
    "resolve_steps",
    "inject_corruption",
    "finite_rows",
    "apply_faults",
    "sanitize_moments",
]

# substream tags under the round's FAULT_TAG key, one per fault class
_DROPOUT_SUB, _STRAGGLER_SUB, _CORRUPT_SUB = 0, 1, 2


def fault_masks(fault: FaultSpec, round_key: jax.Array, num_clients: int):
    """One round's full-cohort fault draws from the replicated round key.

    Returns ``(alive, straggler, corrupt)`` — each a (num_clients,) float32
    {0., 1.} vector, or ``None`` when that fault class is disabled (so the
    inactive classes add nothing to the compiled program).  Position i is
    GLOBAL client index i; callers slice shard/chunk rows out of the full
    vectors exactly as they slice the sampling mask.
    """
    k = jax.random.fold_in(round_key, FAULT_TAG)

    def draw(sub: int, rate: float):
        """Bernoulli(rate) over the cohort from substream ``sub``; None if off."""
        if rate <= 0.0:
            return None
        kk = jax.random.fold_in(k, sub)
        return jax.random.bernoulli(kk, rate, (num_clients,)).astype(jnp.float32)

    dropped = draw(_DROPOUT_SUB, fault.dropout)
    alive = None if dropped is None else 1.0 - dropped
    return alive, draw(_STRAGGLER_SUB, fault.straggler), draw(_CORRUPT_SUB, fault.corrupt)


def gather_fault_rows(slots: jax.Array, *vectors):
    """Gather each (m,) fault vector's slot rows for a §14 gathered block.

    Fault draws stay FULL-COHORT (position i is global client i — the same
    discipline as ``fault_masks``); the sparse engines gather the sampled
    clients' rows through the same slot table as their data, so a gathered
    faulty round degrades exactly as its dense reference.  ``None`` entries
    (disabled fault classes) pass through as ``None``; padding slots pick up
    client 0's draw, which the zero slot mask already excludes from every
    moment.
    """
    return tuple(None if v is None else jnp.take(v, slots, axis=0)
                 for v in vectors)


def resolve_steps(fault: FaultSpec, straggler: jax.Array, tau: int) -> jax.Array:
    """Per-client local step counts: ``straggler_steps`` for flagged clients
    (capped at tau — a straggler never trains MORE), ``tau`` otherwise."""
    cut = min(int(fault.straggler_steps), int(tau))
    return jnp.where(straggler > 0, jnp.int32(cut), jnp.int32(tau))


def inject_corruption(deltas: jax.Array, corrupt: jax.Array) -> jax.Array:
    """Replace flagged rows of an (m, d) delta block with NaN — the update a
    corrupted device would return.  The server's finite screen must catch
    these downstream; injecting real NaN (not a sentinel) exercises exactly
    that degradation path."""
    return jnp.where(corrupt[:, None] > 0, jnp.float32(jnp.nan), deltas)


def finite_rows(deltas: jax.Array) -> jax.Array:
    """(m,) float32 {0., 1.} server-side finite screen: 1 for rows whose
    every coordinate is finite.  Catches injected corruption and genuinely
    diverged clients alike."""
    return jnp.all(jnp.isfinite(deltas), axis=-1).astype(jnp.float32)


def apply_faults(deltas: jax.Array, mask: jax.Array,
                 alive: jax.Array | None, corrupt: jax.Array | None):
    """Apply one round's faults to a shard/chunk's delta rows.

    ``mask`` is the block's existing participation mask (sampling x padding);
    ``alive`` / ``corrupt`` are this block's rows of the full-cohort draws
    (or None when that class is off).  Returns ``(deltas, eff_mask)`` with
    failed rows where-zeroed at the source and the effective mask carrying
    the REALIZED participation — the count every downstream normalization
    must use (DESIGN.md §13).
    """
    if corrupt is not None:
        deltas = inject_corruption(deltas, corrupt)
    eff = mask if alive is None else mask * alive
    # the finite screen runs whenever faults are active: corruption is the
    # injected cause, but a genuinely diverged client degrades identically
    eff = eff * finite_rows(deltas)
    return mask_rows(deltas, eff), eff


def sanitize_moments(moments):
    """Belt-and-braces guard on an accumulated moments pytree: any non-finite
    field (an Inf that survived clipping, an overflowed square) is zeroed so
    the FedEXP numerator and the adaptive-clip carry stay finite.  Finite
    moments pass through untouched (``where`` is the identity on them)."""
    def clean(x):
        x = jnp.asarray(x)
        if not jnp.issubdtype(x.dtype, jnp.floating):
            return x
        return jnp.where(jnp.isfinite(x), x, jnp.zeros_like(x))
    return jax.tree_util.tree_map(clean, moments)
