"""Vectorized federated-learning simulation engine (paper experiments)."""

from repro.fedsim.flat import flatten_model
from repro.fedsim.local import cohort_updates, local_update
from repro.fedsim.scaffold import DPScaffoldConfig, run_dp_scaffold
from repro.fedsim.server import RunResult, run_federated, run_federated_batched

__all__ = [
    "flatten_model", "local_update", "cohort_updates",
    "run_federated", "run_federated_batched", "RunResult",
    "DPScaffoldConfig", "run_dp_scaffold",
]
