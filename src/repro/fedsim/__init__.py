"""Vectorized federated-learning simulation engine (paper experiments).

Entry point: ``FederatedSession`` + the declarative specs (DESIGN.md §10):
TrainSpec / LocalSpec / EngineSpec / StreamSpec / ShardSpec / CohortSpec /
FaultSpec / DataSpec / TelemetrySpec.  ``EngineSpec(engine="stream")`` +
``StreamSpec(chunk_clients=c)`` run each round in client chunks with O(c·d)
peak update memory (§12); ``CohortSpec(gather=True)`` skips non-participants
entirely, making a q-sampled round cost O(q·M·d) (§14); a
``ClientDataSource`` (host / npz / synthetic) bounds M by host storage
instead of HBM (§14).  The kwargs-style ``run_federated`` /
``run_federated_batched`` are deprecated shims over a one-shot session.
"""

from repro.fedsim.data import (
    ArraySource,
    ClientDataSource,
    HostArraySource,
    NpzSource,
    SyntheticSource,
)
from repro.fedsim.flat import flatten_model
from repro.fedsim.local import (
    chunk_cohort,
    cohort_updates,
    cohort_updates_spec,
    gather_rows,
    gather_slots,
    local_update,
    local_update_spec,
)
from repro.fedsim.scaffold import DPScaffoldConfig, run_dp_scaffold
from repro.fedsim.server import RunResult, run_federated, run_federated_batched
from repro.fedsim.session import FederatedSession, RecoveryPolicy
from repro.fedsim.specs import (
    CohortSpec,
    DataSpec,
    EngineSpec,
    FaultSpec,
    LocalSpec,
    ShardSpec,
    StreamSpec,
    TelemetrySpec,
    TrainSpec,
)

__all__ = [
    "flatten_model", "local_update", "cohort_updates",
    "local_update_spec", "cohort_updates_spec", "chunk_cohort",
    "gather_slots", "gather_rows",
    "FederatedSession", "RecoveryPolicy", "TrainSpec", "LocalSpec",
    "EngineSpec", "ShardSpec", "StreamSpec", "CohortSpec", "FaultSpec",
    "DataSpec", "TelemetrySpec", "ClientDataSource", "ArraySource", "HostArraySource",
    "NpzSource", "SyntheticSource",
    "run_federated", "run_federated_batched", "RunResult",
    "DPScaffoldConfig", "run_dp_scaffold",
]
