"""Vectorized federated-learning simulation engine (paper experiments).

Entry point: ``FederatedSession`` + the declarative specs (DESIGN.md §10):
TrainSpec / LocalSpec / EngineSpec / StreamSpec / ShardSpec / CohortSpec.
``EngineSpec(engine="stream")`` + ``StreamSpec(chunk_clients=c)`` run each
round in client chunks with O(c·d) peak update memory (§12).  The
kwargs-style ``run_federated`` / ``run_federated_batched`` are deprecated
shims over a one-shot session.
"""

from repro.fedsim.flat import flatten_model
from repro.fedsim.local import (
    chunk_cohort,
    cohort_updates,
    cohort_updates_spec,
    local_update,
    local_update_spec,
)
from repro.fedsim.scaffold import DPScaffoldConfig, run_dp_scaffold
from repro.fedsim.server import RunResult, run_federated, run_federated_batched
from repro.fedsim.session import FederatedSession, RecoveryPolicy
from repro.fedsim.specs import (
    CohortSpec,
    EngineSpec,
    FaultSpec,
    LocalSpec,
    ShardSpec,
    StreamSpec,
    TrainSpec,
)

__all__ = [
    "flatten_model", "local_update", "cohort_updates",
    "local_update_spec", "cohort_updates_spec", "chunk_cohort",
    "FederatedSession", "RecoveryPolicy", "TrainSpec", "LocalSpec",
    "EngineSpec", "ShardSpec", "StreamSpec", "CohortSpec", "FaultSpec",
    "run_federated", "run_federated_batched", "RunResult",
    "DPScaffoldConfig", "run_dp_scaffold",
]
