"""Client data sources: host- and disk-resident cohorts (DESIGN.md §14).

The engines' client batches historically had to be device-resident jax
arrays, which caps the cohort size M at HBM.  A ``ClientDataSource`` breaks
that bound: it is anything that can serve *rows of clients by global index*
from wherever the data actually lives — host NumPy arrays, an on-disk
``.npz`` archive, or a pure generator function for synthetic cohorts.  The
streaming engine (§12) then stages one chunk of clients at a time with
``jax.device_put``, double-buffered ``DataSpec.prefetch`` chunks ahead of
the inner scan, so M is bounded by host storage (or by nothing at all, for
generated data) instead of device memory.

Contract.  A source must provide:

    num_clients          total cohort size M (property or attribute)
    kind                 "device" | "host" | "npz" | "synthetic" — recorded
                         in the session's DataSpec and compile-cache key
    fetch(idx)           rows for the GLOBAL client indices ``idx`` (a
                         1-D numpy int array, possibly non-monotone or with
                         repeats — the §14 gather path fetches by slot):
                         a pytree of numpy arrays with len(idx) leading

``fetch`` must be deterministic: the same indices return the same rows on
every call, which is what makes host-resident runs reproducible and
checkpoint/resume exact.  Sources are plain Python — they are *not* traced;
the session's host driver calls them between compiled chunk programs.

``ArraySource`` wraps already-device-resident arrays and exists so
``FederatedSession(batches=...)`` has one uniform entry: the session
detects it and routes through the historical device-resident engine
unchanged — bit-for-bit, no staging, no host copies.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import numpy as np

__all__ = [
    "ClientDataSource",
    "ArraySource",
    "HostArraySource",
    "NpzSource",
    "SyntheticSource",
    "as_data_source",
]


def _leading_dim(tree) -> int:
    leaves = [x for x in jax.tree_util.tree_leaves(tree)]
    if not leaves:
        raise ValueError("client batches have no array leaves")
    m = leaves[0].shape[0]
    for x in leaves:
        if x.shape[0] != m:
            raise ValueError("every client-batch leaf needs the same leading "
                             f"(client) dimension; got {x.shape[0]} vs {m}")
    return int(m)


class ClientDataSource:
    """Base class / protocol for index-addressable client data (§14)."""

    kind: str = "host"

    @property
    def num_clients(self) -> int:
        """Total cohort size M."""
        raise NotImplementedError

    def fetch(self, idx: np.ndarray):
        """Rows for global client indices ``idx`` (pytree of numpy arrays)."""
        raise NotImplementedError


class ArraySource(ClientDataSource):
    """Device-resident batches behind the source interface.

    The bit-exact default: the session unwraps ``.batches`` and runs the
    historical device-resident engine — identical program, identical
    results.  ``fetch`` still works (via host transfer) so code written
    against the protocol runs unchanged, just without the memory win.
    """

    kind = "device"

    def __init__(self, batches):
        self.batches = batches
        self._m = _leading_dim(batches)

    @property
    def num_clients(self) -> int:
        """Total cohort size M."""
        return self._m

    def fetch(self, idx: np.ndarray):
        """Rows for global client indices ``idx`` (pytree of numpy arrays)."""
        idx = np.asarray(idx)
        return jax.tree_util.tree_map(
            lambda x: np.asarray(x)[idx], self.batches)


class HostArraySource(ClientDataSource):
    """Host NumPy arrays: the cohort lives in host RAM, never wholly on
    device.  ``fetch`` is a fancy-index copy of the requested rows."""

    kind = "host"

    def __init__(self, batches):
        self.batches = jax.tree_util.tree_map(np.asarray, batches)
        self._m = _leading_dim(self.batches)

    @property
    def num_clients(self) -> int:
        """Total cohort size M."""
        return self._m

    def fetch(self, idx: np.ndarray):
        """Rows for global client indices ``idx`` (pytree of numpy arrays)."""
        idx = np.asarray(idx)
        return jax.tree_util.tree_map(lambda x: x[idx], self.batches)


class NpzSource(ClientDataSource):
    """On-disk ``.npz`` archive of per-client arrays.

    Each archive member is one client-batch leaf with the client axis
    leading; members load lazily on first access (``np.load`` keeps the zip
    handle open and decompresses per member), so startup cost is O(1) and
    peak host memory is bounded by the members actually touched.  Leaf
    structure is the flat dict of member names — save with
    ``np.savez(path, x=..., y=...)`` and the session sees ``{"x": ..., "y":
    ...}`` batches.
    """

    kind = "npz"

    def __init__(self, path: str):
        self.path = str(path)
        self._npz = np.load(self.path)
        self._cache: dict[str, np.ndarray] = {}
        if not self._npz.files:
            raise ValueError(f"{path!r} holds no arrays")
        self._m = int(self._npz[self._npz.files[0]].shape[0])

    @property
    def num_clients(self) -> int:
        """Total cohort size M."""
        return self._m

    def _leaf(self, name: str) -> np.ndarray:
        if name not in self._cache:
            self._cache[name] = self._npz[name]
        return self._cache[name]

    def fetch(self, idx: np.ndarray):
        """Rows for global client indices ``idx`` (pytree of numpy arrays)."""
        idx = np.asarray(idx)
        return {name: self._leaf(name)[idx] for name in self._npz.files}


class SyntheticSource(ClientDataSource):
    """Generated client data: ``fn(idx) -> pytree`` of numpy rows.

    No storage at all — the M=10⁶ benchmark regime.  ``fn`` MUST be a pure
    function of the indices (derive any randomness from them, e.g. one
    ``np.random.default_rng(seed + i)`` per client) so repeated fetches and
    checkpoint resumes see identical data.
    """

    kind = "synthetic"

    def __init__(self, fn: Callable[[np.ndarray], Any], num_clients: int):
        if num_clients < 1:
            raise ValueError(f"num_clients must be >= 1, got {num_clients}")
        self._fn = fn
        self._m = int(num_clients)

    @property
    def num_clients(self) -> int:
        """Total cohort size M."""
        return self._m

    def fetch(self, idx: np.ndarray):
        """Rows for global client indices ``idx`` (pytree of numpy arrays)."""
        return self._fn(np.asarray(idx))


def as_data_source(batches) -> ClientDataSource | None:
    """The session's input normalization: ``ClientDataSource`` passes
    through; arrays / pytrees-of-arrays return ``None`` (the historical
    device-resident path — bit-for-bit, nothing wrapped)."""
    return batches if isinstance(batches, ClientDataSource) else None
