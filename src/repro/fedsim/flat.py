"""Flat-parameter utilities for the federated simulation engine.

DP-FedEXP operates on flattened update vectors (clipping, noise, norms are all
over R^d).  The simulation keeps every model as a flat (d,) vector plus an
unravel function, so the (M, d) client-update matrix is a first-class array
that vmaps/shards/kernels cleanly.
"""
from __future__ import annotations

from typing import Callable

import jax
from jax.flatten_util import ravel_pytree

__all__ = ["flatten_model"]


def flatten_model(params_tree) -> tuple[jax.Array, Callable]:
    """Return (flat_params, unravel_fn) for a parameter pytree."""
    flat, unravel = ravel_pytree(params_tree)
    return flat, unravel
