"""Federated training loop for the simulation engine.

Runs T rounds of: broadcast -> vmapped local training (Algorithm 3) ->
clip/randomize/aggregate + adaptive step size (Algorithms 1/2) -> global
update.  One round is one jitted XLA program; the server algorithm object is
closed over (its float fields are compile-time constants).

Following §5 of the paper, the returned final model is the average of the last
two iterates ("to mitigate the oscillating behaviour of DP-FedEXP").
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.fedexp import ServerAlgorithm
from repro.fedsim.local import cohort_updates

__all__ = ["RunResult", "run_federated"]


@dataclasses.dataclass
class RunResult:
    final_w: jax.Array            # average of the last `avg_last` iterates
    last_w: jax.Array
    eta_history: jax.Array        # (T,)
    metric_history: jax.Array     # (T,) eval metric per round (nan if no eval_fn)
    eta_naive_history: jax.Array | None = None
    eta_target_history: jax.Array | None = None


def run_federated(
    algorithm: ServerAlgorithm,
    loss_fn: Callable,
    w0: jax.Array,
    client_batches,
    *,
    rounds: int,
    tau: int,
    eta_l: float,
    key: jax.Array,
    eval_fn: Callable | None = None,
    avg_last: int = 2,
) -> RunResult:
    """Run T federated rounds and return the iterate-averaged final model."""

    def one_round(w, opt_state, round_key):
        deltas = cohort_updates(loss_fn, w, client_batches, tau, eta_l)
        w_next, aux, opt_state = algorithm.apply_round_stateful(
            round_key, w, deltas, opt_state)
        metric = eval_fn(w_next) if eval_fn is not None else jnp.nan
        outs = (
            aux.eta_g,
            metric,
            aux.eta_naive if aux.eta_naive is not None else jnp.nan,
            aux.eta_target if aux.eta_target is not None else jnp.nan,
        )
        return w_next, opt_state, outs

    round_jit = jax.jit(one_round)

    w = w0
    opt_state = algorithm.init_state(w0)
    tail: list[jax.Array] = []
    etas, metrics, naives, targets = [], [], [], []
    for t in range(rounds):
        w, opt_state, (eta, metric, naive, target) = round_jit(
            w, opt_state, jax.random.fold_in(key, t))
        etas.append(eta)
        metrics.append(metric)
        naives.append(naive)
        targets.append(target)
        tail.append(w)
        if len(tail) > avg_last:
            tail.pop(0)

    final_w = jnp.mean(jnp.stack(tail), axis=0)
    return RunResult(
        final_w=final_w,
        last_w=w,
        eta_history=jnp.stack(etas),
        metric_history=jnp.stack(metrics),
        eta_naive_history=jnp.stack(naives),
        eta_target_history=jnp.stack(targets),
    )
