"""Federated round engine: compiled scan chunks, sharding, cohort sampling.

Runs T rounds of: broadcast -> vmapped local training (Algorithm 3) ->
clip/randomize/aggregate + adaptive step size (Algorithms 1/2) -> global
update.

This module owns the ENGINE MACHINERY — the round-step builders, the scan
bodies, and the compile caches.  The public entry point is
``repro.fedsim.session.FederatedSession`` (DESIGN.md §10), which composes
these builders from declarative specs; ``run_federated`` /
``run_federated_batched`` below are thin deprecated shims over a session and
keep their historical behavior bit-for-bit.

Engine (DESIGN.md §8).  The default scan engine compiles the whole round
loop as ``jax.lax.scan`` programs: T rounds run as ceil(T/chunk_rounds) XLA
dispatches (one, by default), per-round PRNG keys are ``fold_in``-derived
inside the scan, the eta/metric/naive/target histories come back as stacked
scan outputs, and the trailing ``avg_last`` iterates ride in the scan carry
so the §5 iterate average needs no host-side tail.  The carry is donated on
accelerators, and the compiled chunk program is cached across calls keyed on
the (frozen, hashable) algorithm + spec configuration.

Client sharding (DESIGN.md §9): a 1-D ``clients`` mesh wraps the same scan
program in ``shard_map``; each device holds a (M/n_shards, d) cohort slice
and only the O(d) aggregation moments cross devices via one ``psum`` per
round.  Cohorts with M % n_shards != 0 are padded with zero-weight clients
(``pad_cohort``) that every moment masks out.

Cohort sampling (DESIGN.md §10): a ``CohortSpec`` with q<1 or a fixed size
draws a per-round participation mask INSIDE the scan body (static shapes —
sampled rounds stay one compiled program per chunk) and routes the round
through the same masked-moment machinery sharding uses: non-participants'
updates are zero-weighted at the source and every reduction is mask-weighted,
so the release is mathematically the sampled-cohort release.  The sampling
mask is derived from the replicated round key, so sharded and single-device
sampled runs see the identical cohort.

Streaming cohorts (DESIGN.md §12): ``engine="stream"`` iterates each round's
cohort in ``StreamSpec.chunk_clients``-sized chunks via an INNER ``lax.scan``
nested in the round scan: every chunk runs local training + the per-client
release on its (c, d) block and only the additive ``RoundMoments`` (plus the
PrivUnit / adaptive-clip extras, all SUMS) accumulate in the inner carry, so
peak update-matrix memory is O(chunk_clients * d) instead of O(M * d).  All
per-client randomness keys by GLOBAL client index, so the streamed release
draws exactly the dense engine's randomization; the chunk-boundary
re-association of the sums is the only difference (rtol 1e-5, bit-exact when
one chunk covers the cohort).  Composes with sampling (the full mask is
derived from the replicated round key and sliced per chunk) and with §9
sharding (each shard streams its own cohort slice; one O(d) psum per round,
after the inner scan).

Compressed communication (DESIGN.md §16): a compressed ``Aggregation`` layer
(rand-k / count-sketch) shrinks ``RoundMoments.sum_c`` from (d,) to the
compressed width at the source — inside ``algorithm.local_moments`` — and
every engine path here inherits it with NO structural change, because each
one only ever ADDS moments: the sharded psum is pytree-shaped by the local
moments, the stream inner-scan carry is zero-initialized from
``jax.eval_shape`` of the chunk program, the gather engine reduces the same
moments over slots, and the count-resolution helpers
(``set_moment_count`` / ``clamp_moment_counts`` / ``sanitize_moments``) are
field-targeted tree_maps that never look at ``sum_c``'s shape.  The per-round
collective is therefore O(k) / O(width·depth) on all four paths.  The shared
per-round compression plan derives from the replicated round key
(COMPRESS_TAG), so shard/chunk partial sums are summands of one linear map.

Following §5 of the paper, the returned final model is the average of the
last two iterates ("to mitigate the oscillating behaviour of DP-FedEXP").
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.experimental import io_callback
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.fedexp import ServerAlgorithm, clamp_moment_counts, set_moment_count
from repro.fedsim.faults import apply_faults, fault_masks, gather_fault_rows, resolve_steps, sanitize_moments
from repro.fedsim.local import gather_rows, gather_slots, mask_rows
from repro.fedsim.specs import CohortSpec, FaultSpec, StreamSpec
from repro.models.sharding import client_axis_rules, logical_to_pspec

__all__ = ["RunResult", "run_federated", "run_federated_batched"]


@dataclasses.dataclass
class RunResult:
    """Outputs of a federated run: final/last weights + per-round histories."""
    final_w: Any                  # average of the last `avg_last` iterates
    last_w: Any                   # pytree-shaped when the session got a pytree
    eta_history: jax.Array        # (T,)
    metric_history: jax.Array     # (T,) eval metric per round (nan if no
    #                               eval_fn or the round is off cadence)
    eta_naive_history: jax.Array | None = None
    eta_target_history: jax.Array | None = None
    fault_round: int | None = None  # watchdog: first diverged round (§13)

    def eval_rounds(self) -> list[tuple[int, float]]:
        """(round, metric) pairs for the rounds the eval cadence actually
        evaluated — the NaN sentinels that pad ``metric_history`` off the
        ``eval_every`` grid (and past a watchdog trip) are dropped, so
        consumers never NaN-filter by hand.  Batched results (leading seed
        axis) have no single eval trace; index the history yourself there.
        """
        hist = jax.device_get(self.metric_history)
        if hist.ndim != 1:
            raise ValueError(
                "eval_rounds() needs a single-run (T,) metric history; this "
                f"result's is {hist.shape} — a run_batched result carries a "
                "leading seed axis, slice it per seed instead")
        import math
        return [(t, float(v)) for t, v in enumerate(hist)
                if math.isfinite(float(v))]


def _eval_metric(eval_fn, eval_every: int, w_next, t):
    """Per-round metric honoring the eval cadence.

    eval_every == 1 keeps the historical unconditional call (bit-identical
    program); a larger cadence guards the eval behind ``lax.cond`` so skipped
    rounds cost nothing and record NaN (fixed-shape histories).
    """
    if eval_fn is None:
        return jnp.float32(jnp.nan)
    if eval_every == 1:
        return eval_fn(w_next)
    return jax.lax.cond((t + 1) % eval_every == 0,
                        lambda w: jnp.asarray(eval_fn(w), jnp.float32),
                        lambda w: jnp.float32(jnp.nan), w_next)


def _resolve_sampled_count(moments, cohort: CohortSpec, algorithm):
    """Fix the moments' client count for a sampled round.

    Fixed-size cohorts have a statically known count — substituting it lets
    XLA fold the 1/|S_t| normalizations identically on every engine (the same
    trick as ``m_total`` on the sharded path).  Bernoulli counts are traced
    and can be zero on an unlucky round; clamping to >= 1 turns the empty
    round into a zero update instead of NaN poison.  Algorithms whose count
    is not a client count (weighted aggregation: count = sum of weights)
    opt out of the static substitution via ``supports_static_count``.
    """
    if getattr(algorithm, "supports_static_count", True):
        if cohort.size is not None:
            return set_moment_count(moments, cohort.size)
        return clamp_moment_counts(moments)
    # weighted aggregation: the count is a weight sum, legitimately < 1 —
    # only guard the 0/0 of an empty Bernoulli round
    return clamp_moment_counts(moments, floor=1e-12)


def _resolve_realized_count(moments, algorithm):
    """Count resolution for fault-active rounds (DESIGN.md §13).

    Under injected faults the realized participation is traced and strictly
    below the nominal cohort, so the static-count substitution of
    ``_resolve_sampled_count`` never applies — always clamp instead, so an
    all-failed round resolves as a zero update, never NaN.
    """
    if getattr(algorithm, "supports_static_count", True):
        return clamp_moment_counts(moments)
    return clamp_moment_counts(moments, floor=1e-12)


def _round_kwargs(algorithm, t):
    """Round-index kwargs for the algorithm's round calls (DESIGN.md §17).

    Round-indexed algorithms (a genuinely varying ``NoiseSchedule``) receive
    ``t=t`` so the mechanism can resolve sigma(t); every other algorithm —
    including the legacy monoliths, whose round methods have no ``t``
    parameter at all — keeps its exact historical call, so fixed-noise
    programs are untouched bit-for-bit.
    """
    if getattr(algorithm, "needs_round_index", False):
        return {"t": t}
    return {}


def _local_caller(local_fn, fault: FaultSpec | None, tau: int,
                  algorithm=None):
    """Adapter calling the LocalTrainer with or without per-client steps
    and/or per-client server context.

    When the fault model cuts stragglers short, the session built the
    ``with_steps`` LocalTrainer variant (arity +1) and every engine resolves
    the per-client step counts from the straggler draw.  When the algorithm
    declares ``uses_local_context`` (DP-SCAFFOLD control variates, §17), the
    trainer takes one more trailing argument — the algorithm's per-client
    context rows sliced from the carry at the round's global start.  With
    neither active, the historical closure is called untouched
    (bit-identical program).
    """
    straggling = fault is not None and fault.straggler > 0.0
    with_ctx = algorithm is not None and getattr(
        algorithm, "uses_local_context", False)

    def call(w, batches, eta_l, round_key, start, straggler_rows=None,
             opt_state=None):
        args = (w, batches, eta_l, round_key, start)
        if straggling:
            args += (resolve_steps(fault, straggler_rows, tau),)
        if with_ctx:
            m_local = jax.tree_util.tree_leaves(batches)[0].shape[0]
            args += (algorithm.local_context(opt_state, start, m_local),)
        return local_fn(*args)

    return call


def _pad_slice(v, m_pad: int, start, m_local: int):
    """Zero-pad a full-cohort fault vector to the padded grid and slice this
    shard/chunk's rows — the §9/§10 full-mask-then-slice pattern.  Zero is
    the inert pad for every fault class (dead / on-time / uncorrupted); pad
    rows are masked out regardless."""
    if v is None:
        return None
    if m_pad > v.shape[0]:
        v = jnp.concatenate(
            [v, jnp.zeros((m_pad - v.shape[0],), v.dtype)])
    return jax.lax.dynamic_slice(v, (start,), (m_local,))


def _round_step(algorithm, local_fn, eval_fn, eval_every: int = 1,
                cohort: CohortSpec | None = None,
                fault: FaultSpec | None = None, tau: int = 1):
    """One server round; identical computation for scan and eager engines.

    ``local_fn`` is the LocalTrainer closure built by
    ``repro.fedsim.local.build_cohort_local_fn`` — full-batch GD (the
    historical path, bit-for-bit) or a LocalSpec trainer.  With no (active)
    cohort spec this is the full-participation round; a sampling spec
    reroutes the round through the masked-moment protocol: all M clients
    still compute local updates (static shapes), the participation mask
    zero-weights non-participants, and the algorithm consumes mask-weighted
    moments exactly as on a client shard.

    An injecting ``FaultSpec`` reroutes even full-participation rounds
    through the same masked protocol: the round's fault draws turn failed
    clients into zero-weight rows (``apply_faults``) and the REALIZED count
    flows through the clamped resolution (DESIGN.md §13).

    ``CohortSpec(gather=True)`` (DESIGN.md §14) replaces the all-M masked
    round with the sparse fast path: the participation mask is packed into a
    static (cap,) slot table, client batches (and fault rows) are gathered by
    slot, local training runs on the gathered block only, and the moments are
    keyed by the slots' GLOBAL indices — the identical release in O(q·M·d)
    work.

    Compressed aggregation layers (§16) ride both branches untouched: the
    dense branch routes compressed compositions through the moment protocol
    (``apply_round_stateful`` does internally), and the masked branch's
    moments simply carry a compressed-width ``sum_c``.
    """
    sampled = cohort is not None and cohort.is_sampled
    gathering = sampled and cohort.gather
    injecting = fault is not None and fault.injects
    local = _local_caller(local_fn, fault, tau, algorithm)

    def step(w, opt_state, round_key, t, client_batches, eta_l):
        """One server round inside the compiled scan body."""
        tkw = _round_kwargs(algorithm, t)
        if not sampled and not injecting:
            deltas = local(w, client_batches, eta_l, round_key, 0,
                           None, opt_state)
            w_next, aux, opt_state = algorithm.apply_round_stateful(
                round_key, w, deltas, opt_state, **tkw)
        else:
            m = jax.tree_util.tree_leaves(client_batches)[0].shape[0]
            mask = (cohort.round_mask(round_key, m) if sampled
                    else jnp.ones((m,), jnp.float32))
            if gathering:
                slots, mask, _ = gather_slots(mask, cohort.resolved_cap(m))
                client_batches = gather_rows(client_batches, slots)
                start = slots
            else:
                start = 0
            if injecting:
                alive, straggler, corrupt = fault_masks(fault, round_key, m)
                if gathering:
                    alive, straggler, corrupt = gather_fault_rows(
                        slots, alive, straggler, corrupt)
                deltas = local(w, client_batches, eta_l, round_key, start,
                               straggler, opt_state)
                deltas, mask = apply_faults(deltas, mask, alive, corrupt)
            else:
                deltas = mask_rows(
                    local(w, client_batches, eta_l, round_key, start,
                          None, opt_state), mask)
            moments = algorithm.local_moments(round_key, w, deltas, mask,
                                              start, opt_state, **tkw)
            if injecting:
                moments = sanitize_moments(moments)
                moments = _resolve_realized_count(moments, algorithm)
            else:
                moments = _resolve_sampled_count(moments, cohort, algorithm)
            w_next, aux, opt_state = algorithm.apply_from_moments(
                round_key, w, moments, opt_state, **tkw)
        metric = _eval_metric(eval_fn, eval_every, w_next, t)
        outs = (aux.eta_g, metric, aux.eta_naive, aux.eta_target)
        return w_next, opt_state, outs

    return step


def _sharded_round_step(algorithm, local_fn, eval_fn, axis, m_true,
                        m_pad: int | None = None, eval_every: int = 1,
                        cohort: CohortSpec | None = None,
                        fault: FaultSpec | None = None, tau: int = 1):
    """One round on a client shard; runs inside ``shard_map`` over ``axis``.

    Same round semantics as ``_round_step``, but local training and the
    clip/randomize reductions see only this device's cohort slice, and the
    algorithm's partial moments are psummed before the replicated server
    update.  ``m_true`` is the static pre-padding client count.  Local
    training receives the shard's GLOBAL start index, so spec trainers
    shuffle exactly as the single-device engine.  With cohort sampling,
    every device derives the FULL participation mask from the replicated
    round key and slices its own rows, so the sampled cohort is identical to
    the single-device engine's.  Fault draws follow the same full-cohort-
    then-slice pattern (DESIGN.md §13), so a faulty sharded run degrades
    exactly as its single-device reference.

    With ``CohortSpec(gather=True)`` (§14) each shard packs ITS slice of the
    participation mask into a per-shard slot table (static cap bounded by the
    shard's client count) and trains only the gathered rows; the moments key
    by ``shard_start + slot`` — the same global indices the dense engines
    use — and cross shards in the identical single psum.

    With a compressed aggregation layer (§16) the psummed ``sum_c`` is the
    compressed partial sum — every shard builds the identical plan from the
    replicated round key, so the psum is a sum of one linear map's outputs
    and the per-round collective drops from O(d) to the compressed width.
    """
    sampled = cohort is not None and cohort.is_sampled
    gathering = sampled and cohort.gather
    injecting = fault is not None and fault.injects
    local = _local_caller(local_fn, fault, tau, algorithm)

    def step(w, opt_state, round_key, t, batches_and_mask, eta_l):
        """One server round inside the compiled scan body."""
        tkw = _round_kwargs(algorithm, t)
        local_batches, pad_mask = batches_and_mask
        m_local = pad_mask.shape[0]
        start = jax.lax.axis_index(axis) * m_local
        if not sampled and not injecting:
            deltas = mask_rows(
                local(w, local_batches, eta_l, round_key, start,
                      None, opt_state), pad_mask)
            w_next, aux, opt_state = algorithm.apply_round_sharded(
                round_key, w, deltas, pad_mask, opt_state, axis,
                m_total=m_true, **tkw)
        else:
            if sampled:
                full = cohort.round_mask(round_key, m_true)
                full = jnp.concatenate(
                    [full, jnp.zeros((m_pad - m_true,), jnp.float32)])
                mask = jax.lax.dynamic_slice(full, (start,),
                                             (m_local,)) * pad_mask
            else:
                mask = pad_mask
            if gathering:
                slots, mask, _ = gather_slots(mask,
                                              cohort.resolved_cap(m_local))
                local_batches = gather_rows(local_batches, slots)
                start = start + slots   # (cap,) vector of GLOBAL indices
            if injecting:
                alive, straggler, corrupt = (
                    _pad_slice(v, m_pad, jax.lax.axis_index(axis) * m_local,
                               m_local)
                    for v in fault_masks(fault, round_key, m_true))
                if gathering:
                    alive, straggler, corrupt = gather_fault_rows(
                        slots, alive, straggler, corrupt)
                deltas = local(w, local_batches, eta_l, round_key, start,
                               straggler, opt_state)
                deltas, mask = apply_faults(deltas, mask, alive, corrupt)
            else:
                deltas = mask_rows(
                    local(w, local_batches, eta_l, round_key, start,
                          None, opt_state), mask)
            moments = algorithm.local_moments(round_key, w, deltas, mask,
                                              start, opt_state, **tkw)
            moments = jax.lax.psum(moments, axis)
            if injecting:
                moments = sanitize_moments(moments)
                moments = _resolve_realized_count(moments, algorithm)
            else:
                moments = _resolve_sampled_count(moments, cohort, algorithm)
            w_next, aux, opt_state = algorithm.apply_from_moments(
                round_key, w, moments, opt_state, **tkw)
        metric = _eval_metric(eval_fn, eval_every, w_next, t)
        outs = (aux.eta_g, metric, aux.eta_naive, aux.eta_target)
        return w_next, opt_state, outs

    return step


def _stream_round_step(algorithm, local_fn, eval_fn,
                       m_true: int, m_pad: int, eval_every: int = 1,
                       cohort: CohortSpec | None = None, axis: str | None = None,
                       fault: FaultSpec | None = None, tau: int = 1):
    """One server round streamed over client chunks (DESIGN.md §12).

    The cohort arrives pre-chunked: every client-batch leaf is
    (n_chunks, chunk_clients, ...) and the weight mask (n_chunks,
    chunk_clients), zero on the rows that pad M up to the chunk grid.  An
    inner ``lax.scan`` walks the chunks; chunk j computes its clients' local
    updates and ``algorithm.local_moments`` on global client indices
    [start + j*c, start + (j+1)*c) and adds the resulting moments pytree
    (SUMS, plus any additive extras — the PrivUnit Σŝ, the adaptive-clip
    below-threshold bit count) into a zero-initialized running carry.  Only
    that O(d) carry and one (c, d) update block are ever live, which is the
    engine's whole point: peak update memory is chunk-sized, not
    cohort-sized.

    ``axis`` is the §9 ``clients`` mesh axis when each SHARD streams its
    slice (``m_pad`` stays the GLOBAL padded cohort so every device derives
    the identical full sampling mask); the accumulated shard moments cross
    devices in the same single post-scan psum the dense sharded engine
    performs.  Count resolution matches the engine the stream replaces:
    sampled rounds go through ``_resolve_sampled_count``, full-participation
    rounds substitute the static true client count (``set_moment_count``)
    exactly as ``apply_round_sharded`` does.

    With a compressed aggregation layer (§16) the inner-scan carry is
    compressed-width (its zero init comes from ``jax.eval_shape`` of the
    chunk moments), so the streamed accumulation and the post-scan psum move
    O(k) floats — every chunk compresses with the identical round-key plan.
    """
    sampled = cohort is not None and cohort.is_sampled
    injecting = fault is not None and fault.injects
    local_call = _local_caller(local_fn, fault, tau, algorithm)

    def step(w, opt_state, round_key, t, batches_and_mask, eta_l):
        """One server round inside the compiled scan body."""
        tkw = _round_kwargs(algorithm, t)
        chunk_batches, chunk_mask = batches_and_mask
        n_chunks, c = chunk_mask.shape
        if axis is None:
            shard_start = 0
        else:
            shard_start = jax.lax.axis_index(axis) * (n_chunks * c)
        if sampled:
            # full participation mask from the replicated round key — the
            # SAME draw as the dense/sharded engines — padded with zeros and
            # sliced to this shard's rows, then laid on the chunk grid
            full = cohort.round_mask(round_key, m_true)
            full = jnp.concatenate(
                [full, jnp.zeros((m_pad - m_true,), jnp.float32)])
            local = jax.lax.dynamic_slice(full, (shard_start,), (n_chunks * c,))
            chunk_mask = chunk_mask * local.reshape(n_chunks, c)
        if injecting:
            # fault draws: same full-cohort-then-slice pattern as the
            # sampling mask, laid on this shard's chunk grid so they can
            # ride the inner scan's xs (inactive classes materialize their
            # inert value — the grid rides the scan either way)
            alive_f, strag_f, corr_f = fault_masks(fault, round_key, m_true)
            grid_len = n_chunks * c

            def grid(v, default: float):
                if v is None:
                    v = jnp.full((m_true,), default, jnp.float32)
                v = jnp.concatenate(
                    [v, jnp.zeros((m_pad - m_true,), jnp.float32)])
                v = jax.lax.dynamic_slice(v, (shard_start,), (grid_len,))
                return v.reshape(n_chunks, c)

            fault_grid = (grid(alive_f, 1.0), grid(strag_f, 0.0),
                          grid(corr_f, 0.0))
        else:
            fault_grid = ()

        def chunk_moments(j, batches_j, mask_j, fault_j):
            """Local training + release moments for chunk ``j`` of the cohort."""
            start = shard_start + j * c
            if injecting:
                alive_j, strag_j, corr_j = fault_j
                deltas = local_call(w, batches_j, eta_l, round_key, start,
                                    strag_j, opt_state)
                deltas, mask_j = apply_faults(deltas, mask_j, alive_j, corr_j)
            else:
                deltas = mask_rows(
                    local_call(w, batches_j, eta_l, round_key, start,
                               None, opt_state), mask_j)
            return algorithm.local_moments(round_key, w, deltas, mask_j,
                                           start, opt_state, **tkw)

        # zero-initialize the running moments from the chunk computation's
        # abstract shape (no FLOPs traced): every field is an additive SUM,
        # so zeros is the correct identity for the accumulation
        row_sds = jax.ShapeDtypeStruct((c,), jnp.float32)
        shapes = jax.eval_shape(
            chunk_moments, jax.ShapeDtypeStruct((), jnp.int32),
            jax.tree_util.tree_map(
                lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype),
                chunk_batches),
            jax.ShapeDtypeStruct((c,), chunk_mask.dtype),
            (row_sds,) * 3 if injecting else ())
        acc0 = jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype), shapes)

        def body(acc, xs):
            """Scan body: accumulate one chunk's additive moments into the carry."""
            j, batches_j, mask_j, fault_j = xs
            mom = chunk_moments(j, batches_j, mask_j, fault_j)
            return jax.tree_util.tree_map(jnp.add, acc, mom), None

        js = jnp.arange(n_chunks, dtype=jnp.int32)
        moments, _ = jax.lax.scan(
            body, acc0, (js, chunk_batches, chunk_mask, fault_grid))
        if axis is not None:
            moments = jax.lax.psum(moments, axis)
        if injecting:
            moments = sanitize_moments(moments)
            moments = _resolve_realized_count(moments, algorithm)
        elif sampled:
            moments = _resolve_sampled_count(moments, cohort, algorithm)
        elif getattr(algorithm, "supports_static_count", True):
            # full participation: the accumulated count is exactly m_true;
            # substituting the static constant folds the 1/M normalizations
            # as the dense engine does (same trick as apply_round_sharded)
            moments = set_moment_count(moments, m_true)
        else:
            # weighted aggregation: the count is a weight sum — keep the
            # accumulated traced value, only guard an (impossible here)
            # zero count
            moments = clamp_moment_counts(moments, floor=1e-12)
        w_next, aux, opt_state = algorithm.apply_from_moments(
            round_key, w, moments, opt_state, **tkw)
        metric = _eval_metric(eval_fn, eval_every, w_next, t)
        outs = (aux.eta_g, metric, aux.eta_naive, aux.eta_target)
        return w_next, opt_state, outs

    return step


def _build_stream_chunk_fn(algorithm: ServerAlgorithm, local_fn, eval_fn,
                           donate: bool, unroll: int, stream: StreamSpec,
                           m_true: int, m_pad: int,
                           eval_every: int, cohort: CohortSpec | None,
                           fault: FaultSpec | None, tau: int,
                           tap: bool = False):
    step_round = _stream_round_step(algorithm, local_fn, eval_fn,
                                    m_true, m_pad, eval_every, cohort,
                                    fault=fault, tau=tau)
    tap_ctx = ((m_true, cohort, fault, None, _tap_clip_fn(algorithm),
                _tap_sigma_fn(algorithm))
               if tap else None)

    def chunk(carry, key, ts, chunk_batches, chunk_mask, eta_l):
        """Compiled scan over one chunk of rounds."""
        keys = _fold_round_keys(key, ts)
        body = _scan_body(step_round, (chunk_batches, chunk_mask), eta_l,
                          fault, tap_ctx)
        return jax.lax.scan(body, carry, (keys, ts), unroll=min(unroll, len(ts)))

    return jax.jit(chunk, donate_argnums=(0,) if donate else ())


_cached_stream_chunk_fn = functools.lru_cache(maxsize=32)(_build_stream_chunk_fn)


def _stream_chunk_fn(algorithm: ServerAlgorithm, local_fn, eval_fn,
                     donate: bool, unroll: int, stream: StreamSpec,
                     m_true: int, m_pad: int, eval_every: int = 1,
                     cohort: CohortSpec | None = None,
                     fault: FaultSpec | None = None, tau: int = 1,
                     tap: bool = False):
    """Compiled streaming scan chunk, cached like ``_scan_chunk_fn`` (the
    StreamSpec and padded-cohort geometry join the key; same
    unhashable-algorithm fallback)."""
    try:
        return _cached_stream_chunk_fn(algorithm, local_fn, eval_fn, donate,
                                       unroll, stream, m_true, m_pad,
                                       eval_every, cohort, fault, tau, tap)
    except TypeError:
        return _build_stream_chunk_fn(algorithm, local_fn, eval_fn, donate,
                                      unroll, stream, m_true, m_pad,
                                      eval_every, cohort, fault, tau, tap)


def _build_sharded_stream_chunk_fn(algorithm: ServerAlgorithm, local_fn,
                                   eval_fn, donate: bool, unroll: int,
                                   stream: StreamSpec, mesh, axis: str,
                                   batch_treedef, leaf_ndims,
                                   n_chunks: int, m_true: int, m_pad: int,
                                   eval_every: int, cohort: CohortSpec | None,
                                   fault: FaultSpec | None, tau: int,
                                   tap: bool = False):
    """Each shard streams its own slice of the chunk grid (DESIGN.md §12).

    The pre-chunked leaves are (n_chunks_total, c, ...) with chunks laid out
    so contiguous chunk blocks are contiguous client blocks; sharding the
    leading CHUNK axis over the ``clients`` mesh therefore hands each device
    the same client rows the dense sharded engine would, and the inner
    scan's shard-local moments cross devices in one psum per round.
    """
    step_round = _stream_round_step(algorithm, local_fn, eval_fn,
                                    m_true, m_pad, eval_every, cohort,
                                    axis=axis, fault=fault, tau=tau)
    rules = client_axis_rules(mesh, axis=axis)
    specs = [logical_to_pspec(("clients",) + (None,) * (nd - 1), rules)
             for nd in leaf_ndims]
    batch_specs = jax.tree_util.tree_unflatten(batch_treedef, specs)
    mask_spec = logical_to_pspec(("clients", None), rules,
                                 dims=(n_chunks, stream.chunk_clients))
    tap_ctx = ((m_true, cohort, fault, axis, _tap_clip_fn(algorithm),
                _tap_sigma_fn(algorithm))
               if tap else None)

    def chunk(carry, key, ts, chunk_batches, chunk_mask, eta_l):
        """Compiled scan over one chunk of rounds."""
        keys = _fold_round_keys(key, ts)
        body = _scan_body(step_round, (chunk_batches, chunk_mask), eta_l,
                          fault, tap_ctx)
        return jax.lax.scan(body, carry, (keys, ts), unroll=min(unroll, len(ts)))

    sharded = shard_map(
        chunk, mesh=mesh,
        in_specs=(P(), P(), P(), batch_specs, mask_spec, P()),
        out_specs=P(),
        check_rep=False)  # psum-then-replicated-update, as the dense engine
    return jax.jit(sharded, donate_argnums=(0,) if donate else ())


_cached_sharded_stream_chunk_fn = (
    functools.lru_cache(maxsize=32)(_build_sharded_stream_chunk_fn))


def _sharded_stream_chunk_fn(algorithm, local_fn, eval_fn, donate, unroll,
                             stream, mesh, axis, batch_treedef, leaf_ndims,
                             n_chunks, m_true, m_pad, eval_every: int = 1,
                             cohort: CohortSpec | None = None,
                             fault: FaultSpec | None = None, tau: int = 1,
                             tap: bool = False):
    """Compiled sharded+streamed scan chunk, cached like ``_scan_chunk_fn``."""
    try:
        return _cached_sharded_stream_chunk_fn(
            algorithm, local_fn, eval_fn, donate, unroll, stream, mesh, axis,
            batch_treedef, leaf_ndims, n_chunks, m_true, m_pad, eval_every,
            cohort, fault, tau, tap)
    except TypeError:
        return _build_sharded_stream_chunk_fn(
            algorithm, local_fn, eval_fn, donate, unroll, stream, mesh, axis,
            batch_treedef, leaf_ndims, n_chunks, m_true, m_pad, eval_every,
            cohort, fault, tau, tap)


def _gather_stream_round_step(algorithm, local_fn, eval_fn,
                              m_true: int, m_pad: int, chunk_clients: int,
                              eval_every: int = 1,
                              cohort: CohortSpec | None = None,
                              axis: str | None = None,
                              fault: FaultSpec | None = None, tau: int = 1):
    """One sampled round streamed over the GATHERED cohort (DESIGN.md §14).

    The sparse × streaming composition: the cohort arrives UN-chunked (each
    shard holds its (m_local, ...) slice plus the padding mask), the round
    packs the participation mask into a static slot table as the dense-gather
    engines do, and the §12 inner scan then walks the slot table — not the
    cohort — in ``chunk_clients``-sized chunks, gathering each chunk's client
    rows by slot right before its local training.  Peak update memory stays
    O(chunk·d) AND the round's work is O(cap·d) instead of O(M·d): the inner
    scan runs ceil(cap / c) steps, not ceil(M / c).

    Moments key by the slots' GLOBAL indices (``shard_start + slot``), fault
    rows gather through the same slots, and count resolution matches the
    dense sampled engines — so gather × stream × shard × fault all reproduce
    the dense sampled release at rtol 1e-5.

    Compressed aggregation layers (§16) compose transparently: each gathered
    chunk's moments carry a compressed-width ``sum_c`` (same round-key plan
    on every chunk and shard), so a q-sampled round's collective is O(k)
    while its local-training work stays O(cap·d).
    """
    injecting = fault is not None and fault.injects
    local_call = _local_caller(local_fn, fault, tau, algorithm)

    def step(w, opt_state, round_key, t, batches_and_mask, eta_l):
        """One server round inside the compiled scan body."""
        tkw = _round_kwargs(algorithm, t)
        local_batches, pad_mask = batches_and_mask
        m_local = pad_mask.shape[0]
        shard_start = (0 if axis is None
                       else jax.lax.axis_index(axis) * m_local)
        full = cohort.round_mask(round_key, m_true)
        full = jnp.concatenate(
            [full, jnp.zeros((m_pad - m_true,), jnp.float32)])
        mask = jax.lax.dynamic_slice(full, (shard_start,),
                                     (m_local,)) * pad_mask
        # static slot grid: cap rounded up to the chunk size, so the slot
        # table reshapes onto the (n_chunks, c) inner-scan grid exactly as
        # chunk_cohort lays out the dense stream's clients
        cap = cohort.resolved_cap(m_local)
        c = min(chunk_clients, cap)
        n_chunks = -(-cap // c)
        slots, slot_mask, _ = gather_slots(mask, n_chunks * c)
        slot_grid = slots.reshape(n_chunks, c)
        mask_grid = slot_mask.reshape(n_chunks, c)
        if injecting:
            alive_f, strag_f, corr_f = (
                _pad_slice(v, m_pad, shard_start, m_local)
                for v in fault_masks(fault, round_key, m_true))
            alive_f, strag_f, corr_f = gather_fault_rows(
                slots, alive_f, strag_f, corr_f)

            def fgrid(v, default: float):
                if v is None:
                    v = jnp.full((slots.shape[0],), default, jnp.float32)
                return v.reshape(n_chunks, c)

            fault_grid = (fgrid(alive_f, 1.0), fgrid(strag_f, 0.0),
                          fgrid(corr_f, 0.0))
        else:
            fault_grid = ()

        def chunk_moments(slots_j, mask_j, fault_j):
            """Gather + local training + release moments for one slot chunk."""
            batches_j = gather_rows(local_batches, slots_j)
            gidx = shard_start + slots_j
            if injecting:
                alive_j, strag_j, corr_j = fault_j
                deltas = local_call(w, batches_j, eta_l, round_key, gidx,
                                    strag_j, opt_state)
                deltas, mask_j = apply_faults(deltas, mask_j, alive_j, corr_j)
            else:
                deltas = mask_rows(
                    local_call(w, batches_j, eta_l, round_key, gidx,
                               None, opt_state), mask_j)
            return algorithm.local_moments(round_key, w, deltas, mask_j,
                                           gidx, opt_state, **tkw)

        row_sds = jax.ShapeDtypeStruct((c,), jnp.float32)
        shapes = jax.eval_shape(
            chunk_moments, jax.ShapeDtypeStruct((c,), jnp.int32),
            jax.ShapeDtypeStruct((c,), jnp.float32),
            (row_sds,) * 3 if injecting else ())
        acc0 = jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype), shapes)

        def body(acc, xs):
            """Scan body: accumulate one chunk's additive moments into the carry."""
            slots_j, mask_j, fault_j = xs
            mom = chunk_moments(slots_j, mask_j, fault_j)
            return jax.tree_util.tree_map(jnp.add, acc, mom), None

        moments, _ = jax.lax.scan(body, acc0,
                                  (slot_grid, mask_grid, fault_grid))
        if axis is not None:
            moments = jax.lax.psum(moments, axis)
        if injecting:
            moments = sanitize_moments(moments)
            moments = _resolve_realized_count(moments, algorithm)
        else:
            moments = _resolve_sampled_count(moments, cohort, algorithm)
        w_next, aux, opt_state = algorithm.apply_from_moments(
            round_key, w, moments, opt_state, **tkw)
        metric = _eval_metric(eval_fn, eval_every, w_next, t)
        outs = (aux.eta_g, metric, aux.eta_naive, aux.eta_target)
        return w_next, opt_state, outs

    return step


def _build_gather_stream_chunk_fn(algorithm: ServerAlgorithm, local_fn,
                                  eval_fn, donate: bool, unroll: int,
                                  chunk_clients: int, m_true: int, m_pad: int,
                                  eval_every: int, cohort: CohortSpec | None,
                                  fault: FaultSpec | None, tau: int,
                                  tap: bool = False):
    step_round = _gather_stream_round_step(algorithm, local_fn, eval_fn,
                                           m_true, m_pad, chunk_clients,
                                           eval_every, cohort,
                                           fault=fault, tau=tau)
    tap_ctx = ((m_true, cohort, fault, None, _tap_clip_fn(algorithm),
                _tap_sigma_fn(algorithm))
               if tap else None)

    def chunk(carry, key, ts, local_batches, pad_mask, eta_l):
        """Compiled scan over one chunk of rounds."""
        keys = _fold_round_keys(key, ts)
        body = _scan_body(step_round, (local_batches, pad_mask), eta_l, fault,
                          tap_ctx)
        return jax.lax.scan(body, carry, (keys, ts), unroll=min(unroll, len(ts)))

    return jax.jit(chunk, donate_argnums=(0,) if donate else ())


_cached_gather_stream_chunk_fn = (
    functools.lru_cache(maxsize=32)(_build_gather_stream_chunk_fn))


def _gather_stream_chunk_fn(algorithm: ServerAlgorithm, local_fn, eval_fn,
                            donate: bool, unroll: int, chunk_clients: int,
                            m_true: int, m_pad: int, eval_every: int = 1,
                            cohort: CohortSpec | None = None,
                            fault: FaultSpec | None = None, tau: int = 1,
                            tap: bool = False):
    """Compiled gather-stream scan chunk, cached like ``_scan_chunk_fn``."""
    try:
        return _cached_gather_stream_chunk_fn(
            algorithm, local_fn, eval_fn, donate, unroll, chunk_clients,
            m_true, m_pad, eval_every, cohort, fault, tau, tap)
    except TypeError:
        return _build_gather_stream_chunk_fn(
            algorithm, local_fn, eval_fn, donate, unroll, chunk_clients,
            m_true, m_pad, eval_every, cohort, fault, tau, tap)


def _build_sharded_gather_stream_chunk_fn(algorithm: ServerAlgorithm,
                                          local_fn, eval_fn, donate: bool,
                                          unroll: int, chunk_clients: int,
                                          mesh, axis: str, batch_treedef,
                                          leaf_ndims, mask_len: int,
                                          m_true: int,
                                          eval_every: int,
                                          cohort: CohortSpec | None,
                                          fault: FaultSpec | None, tau: int,
                                          tap: bool = False):
    """Each shard gather-streams its own cohort slice (§9 × §14): the
    UN-chunked client leaves shard over the ``clients`` mesh exactly as the
    dense sharded engine's, each device packs its slice's slot table, and
    the accumulated shard moments cross devices in one psum per round."""
    step_round = _gather_stream_round_step(algorithm, local_fn, eval_fn,
                                           m_true, mask_len, chunk_clients,
                                           eval_every, cohort, axis=axis,
                                           fault=fault, tau=tau)
    rules = client_axis_rules(mesh, axis=axis)
    batch_specs, mask_spec = _client_batch_specs(batch_treedef, leaf_ndims,
                                                 mask_len, rules)
    tap_ctx = ((m_true, cohort, fault, axis, _tap_clip_fn(algorithm),
                _tap_sigma_fn(algorithm))
               if tap else None)

    def chunk(carry, key, ts, local_batches, pad_mask, eta_l):
        """Compiled scan over one chunk of rounds."""
        keys = _fold_round_keys(key, ts)
        body = _scan_body(step_round, (local_batches, pad_mask), eta_l, fault,
                          tap_ctx)
        return jax.lax.scan(body, carry, (keys, ts), unroll=min(unroll, len(ts)))

    sharded = shard_map(
        chunk, mesh=mesh,
        in_specs=(P(), P(), P(), batch_specs, mask_spec, P()),
        out_specs=P(),
        check_rep=False)  # psum-then-replicated-update, as the dense engine
    return jax.jit(sharded, donate_argnums=(0,) if donate else ())


_cached_sharded_gather_stream_chunk_fn = (
    functools.lru_cache(maxsize=32)(_build_sharded_gather_stream_chunk_fn))


def _sharded_gather_stream_chunk_fn(algorithm, local_fn, eval_fn, donate,
                                    unroll, chunk_clients, mesh, axis,
                                    batch_treedef, leaf_ndims, mask_len,
                                    m_true, eval_every: int = 1,
                                    cohort: CohortSpec | None = None,
                                    fault: FaultSpec | None = None,
                                    tau: int = 1, tap: bool = False):
    """Compiled sharded gather-stream chunk, cached like ``_scan_chunk_fn``."""
    try:
        return _cached_sharded_gather_stream_chunk_fn(
            algorithm, local_fn, eval_fn, donate, unroll, chunk_clients, mesh,
            axis, batch_treedef, leaf_ndims, mask_len, m_true, eval_every,
            cohort, fault, tau, tap)
    except TypeError:
        return _build_sharded_gather_stream_chunk_fn(
            algorithm, local_fn, eval_fn, donate, unroll, chunk_clients, mesh,
            axis, batch_treedef, leaf_ndims, mask_len, m_true, eval_every,
            cohort, fault, tau, tap)


def _build_host_moments_fn(algorithm: ServerAlgorithm, local_fn, data):
    """Per-chunk moments program of the host-resident driver (DESIGN.md §14).

    One compiled function per session, applied to every staged chunk of every
    round: local training + release moments for the chunk's rows, keyed by
    the chunk's GLOBAL client indices (a (c,) vector — slot indices on the
    gather path, ``j*c + arange(c)`` on the dense path; both are exactly the
    indices the device-resident stream engine derives, so the host-staged
    release is the identical computation).  ``data`` (the frozen DataSpec) is
    part of the compile-cache key, as for every other spec.
    """
    del data  # cache key only: the compiled program is data-location blind
    local = _local_caller(local_fn, None, 1, algorithm)

    def chunk_moments(w, opt_state, round_key, batches_j, mask_j, gidx_j,
                      eta_l, t):
        """Local training + release moments for one host-staged chunk."""
        deltas = mask_rows(
            local(w, batches_j, eta_l, round_key, gidx_j, None, opt_state),
            mask_j)
        return algorithm.local_moments(round_key, w, deltas, mask_j,
                                       gidx_j, opt_state,
                                       **_round_kwargs(algorithm, t))

    return jax.jit(chunk_moments)


_cached_host_moments_fn = functools.lru_cache(maxsize=32)(_build_host_moments_fn)


def _host_moments_fn(algorithm: ServerAlgorithm, local_fn, data):
    """Compiled host-driver chunk program, cached like ``_scan_chunk_fn``."""
    try:
        return _cached_host_moments_fn(algorithm, local_fn, data)
    except TypeError:
        return _build_host_moments_fn(algorithm, local_fn, data)


def _build_host_finalize_fn(algorithm: ServerAlgorithm, eval_fn,
                            eval_every: int, cohort: CohortSpec | None,
                            m_true: int):
    """Per-round tail of the host-resident driver: count resolution +
    server update + eval + iterate-tail roll — exactly the post-inner-scan
    logic of ``_stream_round_step`` and the tail semantics of ``_scan_body``,
    so a host-staged run reproduces the device-resident stream engine."""
    sampled = cohort is not None and cohort.is_sampled

    def finalize(w, opt_state, tail, round_key, t, moments):
        """Resolve counts, apply the server update, roll the iterate tail."""
        if sampled:
            moments = _resolve_sampled_count(moments, cohort, algorithm)
        elif getattr(algorithm, "supports_static_count", True):
            moments = set_moment_count(moments, m_true)
        else:
            moments = clamp_moment_counts(moments, floor=1e-12)
        w_next, aux, opt_state = algorithm.apply_from_moments(
            round_key, w, moments, opt_state,
            **_round_kwargs(algorithm, t))
        metric = _eval_metric(eval_fn, eval_every, w_next, t)
        tail = jnp.concatenate([tail[1:], w_next[None]], axis=0)
        outs = (aux.eta_g, metric, aux.eta_naive, aux.eta_target)
        return w_next, opt_state, tail, outs

    return jax.jit(finalize)


_cached_host_finalize_fn = (
    functools.lru_cache(maxsize=32)(_build_host_finalize_fn))


def _host_finalize_fn(algorithm: ServerAlgorithm, eval_fn,
                      eval_every: int = 1, cohort: CohortSpec | None = None,
                      m_true: int = 1):
    """Compiled host-driver round finalizer, cached like ``_scan_chunk_fn``."""
    try:
        return _cached_host_finalize_fn(algorithm, eval_fn, eval_every,
                                        cohort, m_true)
    except TypeError:
        return _build_host_finalize_fn(algorithm, eval_fn, eval_every,
                                       cohort, m_true)


@jax.jit
def _host_add_moments(acc, mom):
    """Accumulate one chunk's additive moments (the inner-scan ``jnp.add``)."""
    return jax.tree_util.tree_map(jnp.add, acc, mom)


def _client_batch_specs(treedef, leaf_ndims, mask_len, rules):
    """PartitionSpecs for the (padded) client-batch pytree + mask, derived
    through the logical-axis layer: every leaf is ("clients", None, ...)."""
    specs = [logical_to_pspec(("clients",) + (None,) * (nd - 1), rules)
             for nd in leaf_ndims]
    mask_spec = logical_to_pspec(("clients",), rules, dims=(mask_len,))
    return jax.tree_util.tree_unflatten(treedef, specs), mask_spec


def _fold_round_keys(key, ts):
    """Per-round keys, derived identically by every engine."""
    return jax.vmap(lambda t: jax.random.fold_in(key, t))(ts)


# ---------------------------------------------------------------------------
# Engine tap (DESIGN.md §15): per-round diagnostics streamed to the host
# ---------------------------------------------------------------------------

def _tap_clip_fn(algorithm):
    """Best-effort clip threshold C for the telemetry payload.

    Resolution order mirrors where composed vs legacy algorithms keep the
    threshold: the GlobalStep's ``clip_override`` (adaptive clipping carries
    it in opt_state), a bare ``opt_state.clip`` (the legacy adaptive-clip
    monolith), then the static ``clip_norm`` on the algorithm or its
    mechanism.  NaN when the algorithm has no clipping at all — the host
    omits the field.  Runs at TRACE time inside the tap, never on the
    non-tap program.
    """

    def clip_of(opt_state):
        # an error-feedback compressed composition (§16) wraps the step's
        # carry in a CompressionCarry; the clip threshold lives on .inner
        opt_state = getattr(opt_state, "inner", opt_state)
        step = getattr(algorithm, "step", None)
        if step is not None:
            try:
                c = step.clip_override(opt_state)
                if c is not None:
                    return jnp.float32(c)
            except Exception:
                pass
        c = getattr(opt_state, "clip", None)
        if c is not None:
            return jnp.float32(c)
        for holder in (algorithm, getattr(algorithm, "mechanism", None)):
            c = getattr(holder, "clip_norm", None)
            if c is not None:
                return jnp.float32(c)
        return jnp.float32(jnp.nan)

    return clip_of


def _tap_sigma_fn(algorithm):
    """Best-effort per-round noise std sigma(t) for the telemetry payload
    (DESIGN.md §15/§17).

    A round-indexed NoiseSchedule emits its traced sigma(t); a fixed-sigma
    algorithm (monolith or composition — ``sigma`` forwards through the
    composed ``__getattr__``, a constant schedule forwards to its inner
    mechanism) emits the constant; NaN when the release has no shared noise
    std at all (NoPrivacy, PrivUnit's pure-DP release, heterogeneous
    per-client sigmas) — the host omits the field.  Trace-time only, like
    ``_tap_clip_fn``.
    """
    mech = getattr(algorithm, "mechanism", None)
    if mech is not None and getattr(mech, "is_round_indexed", False):
        return lambda t: jnp.float32(mech._sigma_at(t))
    sigma = getattr(algorithm, "sigma", None)
    if isinstance(sigma, (int, float)):
        return lambda t: jnp.float32(sigma)
    return lambda t: jnp.float32(jnp.nan)


def _tap_emit(tap_ctx, round_key, t, opt_state, outs, fault_t):
    """Emit one round's diagnostics to the host tracker (DESIGN.md §15).

    Only ever traced when a tracker is attached (``tap=True`` builders) —
    the default program contains no callback at all.  All diagnostics
    derive from REPLICATED draws (the cohort mask and fault vectors come
    from the replicated round key), so the tap needs nothing from the
    engines' per-shard internals; duplicating the mask draw here costs one
    extra O(M) bernoulli on tap runs only and keeps the emission math
    read-only — the engine's own computation is untouched, which is what
    makes tap-on results bit-identical to tap-off.

    Ordering (§15): non-sharded engines emit ``ordered=True`` (the scan
    delivers rounds in order); ``shard_map`` engines emit ``ordered=False``
    — EVERY shard fires the callback, so the payload carries ``axis_index``
    and the host drops shard != 0 and reorders by round index.  Ordered
    callbacks inside shard_map are not used: they are unreliable on this
    jax version (see DESIGN.md §15).
    """
    from repro.telemetry import tap as _tap

    m_true, cohort, fault, axis, clip_fn, sigma_fn = tap_ctx
    eta, metric, naive, target = outs
    sampled = cohort is not None and cohort.is_sampled
    participants = (jnp.sum(cohort.round_mask(round_key, m_true))
                    if sampled else jnp.float32(m_true))
    if fault is not None and fault.injects:
        alive, strag, corr = fault_masks(fault, round_key, m_true)
        ones = jnp.ones((m_true,), jnp.float32)
        zeros = jnp.zeros((m_true,), jnp.float32)
        alive = ones if alive is None else alive
        strag = zeros if strag is None else strag
        corr = zeros if corr is None else corr
        mask = (cohort.round_mask(round_key, m_true) if sampled else ones)
        realized = jnp.sum(mask * alive * (1.0 - corr))
        dropped = jnp.sum(mask * (1.0 - alive))
        stragglers = jnp.sum(mask * alive * strag)
        corrupt = jnp.sum(mask * alive * corr)
    else:
        realized = participants
        dropped = stragglers = corrupt = jnp.float32(0.0)
    payload = jnp.stack([
        jnp.float32(eta), jnp.float32(naive), jnp.float32(target),
        jnp.float32(metric), clip_fn(opt_state), participants, realized,
        dropped, stragglers, corrupt, jnp.float32(fault_t), sigma_fn(t)])
    shard = jnp.int32(0) if axis is None else jax.lax.axis_index(axis)
    io_callback(_tap.device_emit, None, t, shard, payload,
                ordered=(axis is None))


def _scan_body(step_round, client_batches, eta_l,
               fault: FaultSpec | None = None, tap_ctx=None):
    """The one scan body every engine compiles — the tail-carry and key
    semantics the bit-exactness tests pin down.  xs is (round_keys, ts): the
    round index rides along for eval cadence and diagnostics.

    With an armed watchdog (``FaultSpec(watchdog=True)``, DESIGN.md §13) the
    carry grows a fourth element ``fault_t`` (int32, -1 while healthy): after
    each round the body checks the global model for non-finite coordinates
    and the step size for NaN / explosion past ``eta_max``; a tripped round
    is NOT committed (the carry rolls back to the pre-round state, so
    recovery resumes from the last healthy iterate), ``fault_t`` records the
    faulting GLOBAL round index, and every remaining round in the chunk is
    frozen behind ``lax.cond`` — no local training, NaN histories.

    ``tap_ctx`` (DESIGN.md §15) arms the telemetry tap: one ``_tap_emit``
    per round, placed AFTER the round's watchdog/rollback resolution so the
    emitted fault state is the committed one.  The emission only reads —
    every carry value flows through it untouched — so tap-on results stay
    bit-identical to tap-off.
    """
    watchdog = fault is not None and fault.watchdog

    def body(carry, key_t):
        """Round-scan body: one server round, w_next appended to the iterate tail."""
        round_key, t = key_t
        if not watchdog:
            w, opt_state, tail = carry
            w_next, opt_next, outs = step_round(
                w, opt_state, round_key, t, client_batches, eta_l)
            if tap_ctx is not None:
                _tap_emit(tap_ctx, round_key, t, opt_state, outs,
                          jnp.int32(-1))
            tail = jnp.concatenate([tail[1:], w_next[None]], axis=0)
            return (w_next, opt_next, tail), outs

        w, opt_state, tail, fault_t = carry
        tripped = fault_t >= 0

        def frozen(operand):
            """Post-trip round: carry passes through, histories record NaN."""
            w, opt_state, tail = operand
            nanf = jnp.float32(jnp.nan)
            return w, opt_state, tail, (nanf, nanf, nanf, nanf)

        def live(operand):
            """Healthy round: the exact computation the unwatched body runs."""
            w, opt_state, tail = operand
            w_next, opt_next, outs = step_round(
                w, opt_state, round_key, t, client_batches, eta_l)
            tail_next = jnp.concatenate([tail[1:], w_next[None]], axis=0)
            return w_next, opt_next, tail_next, outs

        w_next, opt_next, tail_next, outs = jax.lax.cond(
            tripped, frozen, live, (w, opt_state, tail))
        eta = outs[0]
        healthy = (jnp.all(jnp.isfinite(w_next))
                   & jnp.isfinite(eta)
                   & (eta <= jnp.float32(fault.eta_max)))
        bad = jnp.logical_and(~tripped, ~healthy)
        # the faulting round's update is NOT committed — roll this round's
        # carry back so recovery resumes from the last healthy iterate
        w_next = jnp.where(bad, w, w_next)
        opt_next = jax.tree_util.tree_map(
            lambda a, b: jnp.where(bad, a, b), opt_state, opt_next)
        tail_next = jnp.where(bad, tail, tail_next)
        fault_t = jnp.where(bad, t, fault_t)
        if tap_ctx is not None:
            # post-resolution emission: the host sees the committed fault
            # state — the tripping round reports fault_t == t (it executed,
            # so it charges the ledger); frozen rounds report t > fault_t
            _tap_emit(tap_ctx, round_key, t, opt_state, outs, fault_t)
        return (w_next, opt_next, tail_next, fault_t), outs

    return body


def _build_scan_chunk_fn(algorithm: ServerAlgorithm, local_fn, eval_fn,
                         donate: bool, unroll: int,
                         eval_every: int, cohort: CohortSpec | None,
                         fault: FaultSpec | None, tau: int,
                         tap: bool = False):
    step_round = _round_step(algorithm, local_fn, eval_fn, eval_every, cohort,
                             fault, tau)

    def chunk(carry, key, ts, client_batches, eta_l):
        """Compiled scan over one chunk of rounds."""
        keys = _fold_round_keys(key, ts)
        tap_ctx = None
        if tap:
            m = jax.tree_util.tree_leaves(client_batches)[0].shape[0]
            tap_ctx = (m, cohort, fault, None, _tap_clip_fn(algorithm),
                       _tap_sigma_fn(algorithm))
        body = _scan_body(step_round, client_batches, eta_l, fault, tap_ctx)
        return jax.lax.scan(body, carry, (keys, ts), unroll=min(unroll, len(ts)))

    return jax.jit(chunk, donate_argnums=(0,) if donate else ())


_cached_scan_chunk_fn = functools.lru_cache(maxsize=32)(_build_scan_chunk_fn)


def _scan_chunk_fn(algorithm: ServerAlgorithm, local_fn, eval_fn,
                   donate: bool, unroll: int, eval_every: int = 1,
                   cohort: CohortSpec | None = None,
                   fault: FaultSpec | None = None, tau: int = 1,
                   tap: bool = False):
    """Compiled scan over a chunk of rounds, cached by configuration.

    The cache key is (algorithm config, local-trainer/eval *identity*,
    donation, unroll, eval cadence, cohort spec, §15 tap on/off — the ONLY
    telemetry bit that may enter any cache key); round count, eta_l, and all
    array shapes are traced, so any two calls with equal configuration share
    one compiled program per chunk length.  For the cache to hit, callers
    must hold onto their local/eval closures — a fresh closure per call
    retraces (exactly the legacy cost, no worse); ``FederatedSession`` builds
    its ``local_fn`` once (binding loss_fn, LocalSpec and tau) and owns it,
    so repeated ``run`` calls on one session always hit.  ``unroll`` packs
    that many rounds per loop trip — XLA:CPU penalizes ops inside while-loop
    bodies, and a small unroll claws most of it back for ~proportional
    compile time (results are bit-identical).

    Algorithms with unhashable fields (arrays, user-defined non-frozen
    dataclasses) can't be cache keys; they get an uncached build — again the
    legacy per-call-retrace cost, never an error.
    """
    try:
        return _cached_scan_chunk_fn(algorithm, local_fn, eval_fn,
                                     donate, unroll, eval_every, cohort,
                                     fault, tau, tap)
    except TypeError:
        return _build_scan_chunk_fn(algorithm, local_fn, eval_fn,
                                    donate, unroll, eval_every, cohort,
                                    fault, tau, tap)


def _build_sharded_chunk_fn(algorithm: ServerAlgorithm, local_fn, eval_fn,
                            donate: bool, unroll: int,
                            mesh, axis: str, batch_treedef, leaf_ndims,
                            mask_len: int, m_true: int,
                            eval_every: int, cohort: CohortSpec | None,
                            fault: FaultSpec | None, tau: int,
                            tap: bool = False):
    step_round = _sharded_round_step(algorithm, local_fn, eval_fn, axis,
                                     m_true, mask_len, eval_every, cohort,
                                     fault, tau)
    rules = client_axis_rules(mesh, axis=axis)
    batch_specs, mask_spec = _client_batch_specs(batch_treedef, leaf_ndims,
                                                 mask_len, rules)
    tap_ctx = ((m_true, cohort, fault, axis, _tap_clip_fn(algorithm),
                _tap_sigma_fn(algorithm))
               if tap else None)

    def chunk(carry, key, ts, local_batches, mask, eta_l):
        """Compiled scan over one chunk of rounds."""
        keys = _fold_round_keys(key, ts)
        body = _scan_body(step_round, (local_batches, mask), eta_l, fault,
                          tap_ctx)
        return jax.lax.scan(body, carry, (keys, ts), unroll=min(unroll, len(ts)))

    sharded = shard_map(
        chunk, mesh=mesh,
        in_specs=(P(), P(), P(), batch_specs, mask_spec, P()),
        out_specs=P(),
        check_rep=False)  # psum-then-replicated-update; rep checker can't see it
    return jax.jit(sharded, donate_argnums=(0,) if donate else ())


_cached_sharded_chunk_fn = functools.lru_cache(maxsize=32)(_build_sharded_chunk_fn)


def _sharded_chunk_fn(algorithm, local_fn, eval_fn, donate, unroll,
                      mesh, axis, batch_treedef, leaf_ndims, mask_len, m_true,
                      eval_every: int = 1, cohort: CohortSpec | None = None,
                      fault: FaultSpec | None = None, tau: int = 1,
                      tap: bool = False):
    """Compiled shard_mapped scan chunk, cached like `_scan_chunk_fn` (the
    mesh, client-batch treedef and leaf ranks join the key; same unhashable-
    algorithm fallback)."""
    try:
        return _cached_sharded_chunk_fn(algorithm, local_fn, eval_fn,
                                        donate, unroll, mesh, axis,
                                        batch_treedef, leaf_ndims, mask_len,
                                        m_true, eval_every, cohort,
                                        fault, tau, tap)
    except TypeError:
        return _build_sharded_chunk_fn(algorithm, local_fn, eval_fn,
                                       donate, unroll, mesh, axis,
                                       batch_treedef, leaf_ndims, mask_len,
                                       m_true, eval_every, cohort,
                                       fault, tau, tap)


def _build_batched_run_fn(algorithm: ServerAlgorithm, local_fn, eval_fn,
                          tail_n: int, batched_w0: bool,
                          batched_data: bool, eval_every: int,
                          cohort: CohortSpec | None):
    step_round = _round_step(algorithm, local_fn, eval_fn, eval_every, cohort)

    def run_one(w0, key, client_batches, eta_l, ts):
        """Full single-seed run: scan all rounds and average the iterate tail."""
        keys = _fold_round_keys(key, ts)
        carry = (w0, algorithm.init_state(w0),
                 jnp.zeros((tail_n,) + w0.shape, w0.dtype))
        body = _scan_body(step_round, client_batches, eta_l)
        (w, _, tail), outs = jax.lax.scan(body, carry, (keys, ts))
        return (jnp.mean(tail, axis=0), w) + outs

    in_axes = (0 if batched_w0 else None, 0, 0 if batched_data else None,
               None, None)
    return jax.jit(jax.vmap(run_one, in_axes=in_axes))


_cached_batched_run_fn = functools.lru_cache(maxsize=32)(_build_batched_run_fn)


def _build_sharded_batched_run_fn(algorithm: ServerAlgorithm, local_fn, eval_fn,
                                  tail_n: int, batched_w0: bool,
                                  batched_data: bool, mesh, axis: str,
                                  batch_treedef, leaf_ndims, mask_len: int,
                                  m_true: int, eval_every: int,
                                  cohort: CohortSpec | None):
    """Seeds vmapped INSIDE shard_map: every device runs all S seeds over its
    own client slice, so one program serves the whole sweep sharded."""
    step_round = _sharded_round_step(algorithm, local_fn, eval_fn, axis,
                                     m_true, mask_len, eval_every, cohort)
    rules = client_axis_rules(mesh, axis=axis)
    # with batched_data the seed axis leads and `clients` moves to axis 1
    names = [(None, "clients") if batched_data else ("clients",)] * len(leaf_ndims)
    specs = [logical_to_pspec(tuple(n) + (None,) * (nd - len(n)), rules)
             for n, nd in zip(names, leaf_ndims)]
    batch_specs = jax.tree_util.tree_unflatten(batch_treedef, specs)
    mask_spec = logical_to_pspec(("clients",), rules, dims=(mask_len,))

    def run_one(w0, key, local_batches, mask, eta_l, ts):
        """Full single-seed run: scan all rounds and average the iterate tail."""
        keys = _fold_round_keys(key, ts)
        carry = (w0, algorithm.init_state(w0),
                 jnp.zeros((tail_n,) + w0.shape, w0.dtype))
        body = _scan_body(step_round, (local_batches, mask), eta_l)
        (w, _, tail), outs = jax.lax.scan(body, carry, (keys, ts))
        return (jnp.mean(tail, axis=0), w) + outs

    def batched(w0, keys, local_batches, mask, eta_l, ts):
        """Vmap ``run_one`` over the seed axis inside the shard."""
        in_axes = (0 if batched_w0 else None, 0, 0 if batched_data else None,
                   None, None, None)
        return jax.vmap(run_one, in_axes=in_axes)(
            w0, keys, local_batches, mask, eta_l, ts)

    sharded = shard_map(
        batched, mesh=mesh,
        in_specs=(P(), P(), batch_specs, mask_spec, P(), P()),
        out_specs=P(),
        check_rep=False)
    return jax.jit(sharded)


_cached_sharded_batched_run_fn = (
    functools.lru_cache(maxsize=32)(_build_sharded_batched_run_fn))


def _batched_run_fn(algorithm: ServerAlgorithm, local_fn, eval_fn,
                    tail_n: int, batched_w0: bool, batched_data: bool,
                    eval_every: int = 1, cohort: CohortSpec | None = None):
    """vmapped-over-seeds full run (single scan, no chunking); cached with
    the same hashability fallback as `_scan_chunk_fn`."""
    try:
        return _cached_batched_run_fn(algorithm, local_fn, eval_fn,
                                      tail_n, batched_w0, batched_data,
                                      eval_every, cohort)
    except TypeError:
        return _build_batched_run_fn(algorithm, local_fn, eval_fn,
                                     tail_n, batched_w0, batched_data,
                                     eval_every, cohort)


def _sharded_batched_fn(algorithm, local_fn, eval_fn, tail_n, batched_w0,
                        batched_data, mesh, axis, batch_treedef, leaf_ndims,
                        mask_len, m_true, eval_every: int = 1,
                        cohort: CohortSpec | None = None):
    try:
        return _cached_sharded_batched_run_fn(
            algorithm, local_fn, eval_fn, tail_n, batched_w0, batched_data,
            mesh, axis, batch_treedef, leaf_ndims, mask_len, m_true,
            eval_every, cohort)
    except TypeError:
        return _build_sharded_batched_run_fn(
            algorithm, local_fn, eval_fn, tail_n, batched_w0, batched_data,
            mesh, axis, batch_treedef, leaf_ndims, mask_len, m_true,
            eval_every, cohort)


def _run_eager(algorithm, local_fn, w0, client_batches, *, rounds, eta_l,
               key, eval_fn, avg_last, eval_every: int = 1,
               cohort: CohortSpec | None = None,
               fault: FaultSpec | None = None, tau: int = 1,
               tap: bool = False):
    """Legacy engine: one jitted XLA program per round, dispatched from a
    Python loop (re-traced per call — kept as the e7 throughput baseline).

    The divergence watchdog runs HOST-side here (the loop is already on the
    host): a tripped round is not committed, the remaining rounds are
    skipped with NaN histories, and ``RunResult.fault_round`` records the
    faulting round — the same semantics the compiled scan's in-carry
    watchdog produces (DESIGN.md §13).

    The §15 tap emits from inside the jitted round (ordered io_callback —
    one program per round, dispatched in order).  The host-side watchdog
    runs AFTER the emission, so the tripping round is reported (it executed)
    and skipped rounds are simply never emitted — no frozen-round events,
    unlike the in-scan watchdog whose frozen rounds still flow through the
    scan body.
    """
    step_round = _round_step(algorithm, local_fn, eval_fn, eval_every, cohort,
                             fault, tau)
    watchdog = fault is not None and fault.watchdog
    tap_ctx = None
    if tap:
        m = jax.tree_util.tree_leaves(client_batches)[0].shape[0]
        tap_ctx = (m, cohort, fault, None, _tap_clip_fn(algorithm),
                       _tap_sigma_fn(algorithm))

    def one_round(w, opt_state, round_key, t):
        """One jitted round dispatched from the Python loop."""
        out = step_round(w, opt_state, round_key, t, client_batches, eta_l)
        if tap_ctx is not None:
            _tap_emit(tap_ctx, round_key, t, opt_state, out[2], jnp.int32(-1))
        return out

    round_jit = jax.jit(one_round)

    w = w0
    opt_state = algorithm.init_state(w0)
    tail: list[jax.Array] = []
    etas, metrics, naives, targets = [], [], [], []
    fault_round = None
    for t in range(rounds):
        w_next, opt_next, (eta, metric, naive, target) = round_jit(
            w, opt_state, jax.random.fold_in(key, t), jnp.int32(t))
        etas.append(eta)
        metrics.append(metric)
        naives.append(naive)
        targets.append(target)
        if watchdog:
            eta_host = float(jax.device_get(eta))
            healthy = (bool(jax.device_get(jnp.all(jnp.isfinite(w_next))))
                       and eta_host == eta_host  # not NaN
                       and eta_host <= fault.eta_max)
            if not healthy:
                fault_round = t
                nanf = jnp.float32(jnp.nan)
                for _ in range(rounds - t - 1):
                    etas.append(nanf)
                    metrics.append(nanf)
                    naives.append(nanf)
                    targets.append(nanf)
                break
        w, opt_state = w_next, opt_next
        tail.append(w)
        if len(tail) > avg_last:
            tail.pop(0)

    if not tail:  # watchdog tripped on round 0: w0 is the last healthy iterate
        tail = [w]
    final_w = jnp.mean(jnp.stack(tail), axis=0)
    return RunResult(
        final_w=final_w,
        last_w=w,
        eta_history=jnp.stack(etas),
        metric_history=jnp.stack(metrics),
        eta_naive_history=jnp.stack(naives),
        eta_target_history=jnp.stack(targets),
        fault_round=fault_round,
    )


# ---------------------------------------------------------------------------
# Deprecated kwargs-style entry points (shims over FederatedSession)
# ---------------------------------------------------------------------------

_deprecation_warned = False


def _warn_deprecated(name: str) -> None:
    """One DeprecationWarning per process — the shims stay quiet afterwards."""
    global _deprecation_warned
    if not _deprecation_warned:
        _deprecation_warned = True
        warnings.warn(
            f"{name} is deprecated; build a repro.fedsim.FederatedSession "
            "with TrainSpec/EngineSpec/ShardSpec/CohortSpec instead "
            "(DESIGN.md §10). The shim delegates to a session and keeps "
            "historical behavior bit-for-bit.",
            DeprecationWarning, stacklevel=3)


def run_federated(
    algorithm: ServerAlgorithm,
    loss_fn: Callable,
    w0: jax.Array,
    client_batches,
    *,
    rounds: int,
    tau: int,
    eta_l: float,
    key: jax.Array,
    eval_fn: Callable | None = None,
    avg_last: int = 2,
    engine: str = "scan",
    chunk_rounds: int | None = None,
    scan_unroll: int = 2,
    mesh=None,
    client_axis: str = "clients",
) -> RunResult:
    """DEPRECATED shim: run T federated rounds via a one-shot session.

    Equivalent to ``FederatedSession(algorithm, loss_fn, w0, client_batches,
    train=TrainSpec(...), engine=EngineSpec(...), shard=ShardSpec(...)).run(key)``
    — same engines, same compile caches, same results bit-for-bit.  New code
    should build the session directly (it also adds cohort sampling, eval
    cadence, pytree models, and checkpoint/resume).
    """
    _warn_deprecated("run_federated")
    from repro.fedsim.session import FederatedSession
    from repro.fedsim.specs import EngineSpec, ShardSpec, TrainSpec

    session = FederatedSession(
        algorithm, loss_fn, w0, client_batches,
        train=TrainSpec(rounds=rounds, tau=tau, eta_l=eta_l,
                        avg_last=max(1, int(avg_last))),
        engine=EngineSpec(engine=engine,
                          chunk_rounds=int(chunk_rounds) if chunk_rounds else None,
                          scan_unroll=max(1, int(scan_unroll))),
        shard=ShardSpec(mesh=mesh, client_axis=client_axis),
        eval_fn=eval_fn)
    return session.run(key)


def run_federated_batched(
    algorithm: ServerAlgorithm,
    loss_fn: Callable,
    w0: jax.Array,
    client_batches,
    *,
    rounds: int,
    tau: int,
    eta_l: float,
    keys: jax.Array,
    eval_fn: Callable | None = None,
    avg_last: int = 2,
    batched_w0: bool = False,
    batched_data: bool = False,
    mesh=None,
    client_axis: str = "clients",
) -> RunResult:
    """DEPRECATED shim: S-seed batched run via ``FederatedSession.run_batched``.

    ``keys`` is (S,)-stacked PRNG keys; set ``batched_w0`` / ``batched_data``
    when w0 / client_batches carry a matching leading seed axis.  Every
    RunResult field gains a leading (S,) axis.
    """
    _warn_deprecated("run_federated_batched")
    from repro.fedsim.session import FederatedSession
    from repro.fedsim.specs import ShardSpec, TrainSpec

    session = FederatedSession(
        algorithm, loss_fn, w0, client_batches,
        train=TrainSpec(rounds=rounds, tau=tau, eta_l=eta_l,
                        avg_last=max(1, int(avg_last))),
        shard=ShardSpec(mesh=mesh, client_axis=client_axis),
        eval_fn=eval_fn)
    return session.run_batched(keys, batched_w0=batched_w0,
                               batched_data=batched_data)
