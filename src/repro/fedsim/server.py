"""Federated training loop for the simulation engine.

Runs T rounds of: broadcast -> vmapped local training (Algorithm 3) ->
clip/randomize/aggregate + adaptive step size (Algorithms 1/2) -> global
update.

Engine (DESIGN.md §8).  The default ``engine="scan"`` compiles the whole
round loop as ``jax.lax.scan`` programs: T rounds run as ceil(T/chunk_rounds)
XLA dispatches (one, by default) instead of T, per-round PRNG keys are
``fold_in``-derived inside the scan, the eta/metric/naive/target histories
come back as stacked scan outputs, and the trailing ``avg_last`` iterates ride
in the scan carry so the §5 iterate average needs no host-side tail. The
carry is donated on accelerators, reusing the weight buffer in place, and the
compiled chunk program is cached across calls keyed on the (frozen, hashable)
algorithm configuration — repeated runs of the same setting pay zero
retrace/recompile, where the per-round loop re-jits every invocation.

``engine="eager"`` preserves the original loop — one jitted XLA program per
round, dispatched from Python — as the baseline that
``benchmarks/e7_engine_throughput.py`` measures the scan engine against.

``run_federated_batched`` vmaps the scan engine over seeds (optionally also
over per-seed initializations and client data), so a whole mean±std sweep is
ONE batched XLA program.

Client sharding (DESIGN.md §9).  Passing ``mesh=`` (a 1-D mesh with a
``clients`` axis, e.g. ``repro.launch.mesh.make_client_mesh()``) wraps the
same scan program in ``shard_map`` over the client axis: each device holds a
(M/n_shards, d) slice of the cohort for the whole run, computes local updates
plus the clip/randomize partial sums there, and only the O(d) aggregation
moments DP-FedEXP needs (Σc_i, Σ||c_i||², Σ||clip(Δ_i)||², M_i) cross devices
via ``psum`` per round.  The server half (post-reduction DP noise, adaptive
step size, optimizer state) runs replicated from the shared round key, so the
sharded engine matches the single-device engine up to partial-sum reordering.
Cohorts with M % n_shards != 0 are padded with zero-weight clients
(``pad_cohort``) that every moment masks out.

Following §5 of the paper, the returned final model is the average of the last
two iterates ("to mitigate the oscillating behaviour of DP-FedEXP").
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.fedexp import ServerAlgorithm
from repro.fedsim.local import cohort_updates, masked_cohort_updates, pad_cohort
from repro.models.sharding import client_axis_rules, logical_to_pspec

__all__ = ["RunResult", "run_federated", "run_federated_batched"]


@dataclasses.dataclass
class RunResult:
    final_w: jax.Array            # average of the last `avg_last` iterates
    last_w: jax.Array
    eta_history: jax.Array        # (T,)
    metric_history: jax.Array     # (T,) eval metric per round (nan if no eval_fn)
    eta_naive_history: jax.Array | None = None
    eta_target_history: jax.Array | None = None


def _round_step(algorithm, loss_fn, eval_fn, tau):
    """One server round; identical computation for both engines."""

    def step(w, opt_state, round_key, client_batches, eta_l):
        deltas = cohort_updates(loss_fn, w, client_batches, tau, eta_l)
        w_next, aux, opt_state = algorithm.apply_round_stateful(
            round_key, w, deltas, opt_state)
        metric = eval_fn(w_next) if eval_fn is not None else jnp.float32(jnp.nan)
        outs = (aux.eta_g, metric, aux.eta_naive, aux.eta_target)
        return w_next, opt_state, outs

    return step


def _sharded_round_step(algorithm, loss_fn, eval_fn, tau, axis, m_true):
    """One round on a client shard; runs inside ``shard_map`` over ``axis``.

    Same round semantics as ``_round_step``, but local training and the
    clip/randomize reductions see only this device's cohort slice, and the
    algorithm's partial moments are psummed before the replicated server
    update (the only cross-device communication of the round).  ``m_true`` is
    the static pre-padding client count the 1/M normalizations fold in.
    """

    def step(w, opt_state, round_key, batches_and_mask, eta_l):
        local_batches, mask = batches_and_mask
        deltas = masked_cohort_updates(loss_fn, w, local_batches, tau, eta_l, mask)
        w_next, aux, opt_state = algorithm.apply_round_sharded(
            round_key, w, deltas, mask, opt_state, axis, m_total=m_true)
        metric = eval_fn(w_next) if eval_fn is not None else jnp.float32(jnp.nan)
        outs = (aux.eta_g, metric, aux.eta_naive, aux.eta_target)
        return w_next, opt_state, outs

    return step


def _client_batch_specs(treedef, leaf_ndims, mask_len, rules):
    """PartitionSpecs for the (padded) client-batch pytree + mask, derived
    through the logical-axis layer: every leaf is ("clients", None, ...)."""
    specs = [logical_to_pspec(("clients",) + (None,) * (nd - 1), rules)
             for nd in leaf_ndims]
    mask_spec = logical_to_pspec(("clients",), rules, dims=(mask_len,))
    return jax.tree_util.tree_unflatten(treedef, specs), mask_spec


def _fold_round_keys(key, ts):
    """Per-round keys, derived identically by every engine."""
    return jax.vmap(lambda t: jax.random.fold_in(key, t))(ts)


def _scan_body(step_round, client_batches, eta_l):
    """The one scan body both the chunked and the batched engine compile —
    the tail-carry and key semantics the bit-exactness tests pin down."""

    def body(carry, round_key):
        w, opt_state, tail = carry
        w_next, opt_state, outs = step_round(
            w, opt_state, round_key, client_batches, eta_l)
        tail = jnp.concatenate([tail[1:], w_next[None]], axis=0)
        return (w_next, opt_state, tail), outs

    return body


def _build_scan_chunk_fn(algorithm: ServerAlgorithm, loss_fn, eval_fn,
                         tau: int, donate: bool, unroll: int):
    step_round = _round_step(algorithm, loss_fn, eval_fn, tau)

    def chunk(carry, key, ts, client_batches, eta_l):
        keys = _fold_round_keys(key, ts)
        body = _scan_body(step_round, client_batches, eta_l)
        return jax.lax.scan(body, carry, keys, unroll=min(unroll, len(ts)))

    return jax.jit(chunk, donate_argnums=(0,) if donate else ())


_cached_scan_chunk_fn = functools.lru_cache(maxsize=32)(_build_scan_chunk_fn)


def _scan_chunk_fn(algorithm: ServerAlgorithm, loss_fn, eval_fn, tau: int,
                   donate: bool, unroll: int):
    """Compiled scan over a chunk of rounds, cached by configuration.

    The cache key is (algorithm config, loss/eval *identity*, tau, donation,
    unroll); round count, eta_l, and all array shapes are traced, so any two
    calls with equal configuration share one compiled program per chunk
    length.  For the cache to hit, callers must hold onto their loss/eval
    closures — a fresh closure per call retraces (exactly the legacy cost,
    no worse).  ``unroll`` packs that many rounds per loop trip — XLA:CPU
    penalizes ops inside while-loop bodies, and a small unroll claws most of
    it back for ~proportional compile time (results are bit-identical).

    Algorithms with unhashable fields (arrays, user-defined non-frozen
    dataclasses) can't be cache keys; they get an uncached build — again the
    legacy per-call-retrace cost, never an error.
    """
    try:
        return _cached_scan_chunk_fn(algorithm, loss_fn, eval_fn, tau,
                                     donate, unroll)
    except TypeError:
        return _build_scan_chunk_fn(algorithm, loss_fn, eval_fn, tau,
                                    donate, unroll)


def _build_sharded_chunk_fn(algorithm: ServerAlgorithm, loss_fn, eval_fn,
                            tau: int, donate: bool, unroll: int,
                            mesh, axis: str, batch_treedef, leaf_ndims,
                            mask_len: int, m_true: int):
    step_round = _sharded_round_step(algorithm, loss_fn, eval_fn, tau, axis, m_true)
    rules = client_axis_rules(mesh, axis=axis)
    batch_specs, mask_spec = _client_batch_specs(batch_treedef, leaf_ndims,
                                                 mask_len, rules)

    def chunk(carry, key, ts, local_batches, mask, eta_l):
        keys = _fold_round_keys(key, ts)
        body = _scan_body(step_round, (local_batches, mask), eta_l)
        return jax.lax.scan(body, carry, keys, unroll=min(unroll, len(ts)))

    sharded = shard_map(
        chunk, mesh=mesh,
        in_specs=(P(), P(), P(), batch_specs, mask_spec, P()),
        out_specs=P(),
        check_rep=False)  # psum-then-replicated-update; rep checker can't see it
    return jax.jit(sharded, donate_argnums=(0,) if donate else ())


_cached_sharded_chunk_fn = functools.lru_cache(maxsize=32)(_build_sharded_chunk_fn)


def _sharded_chunk_fn(algorithm, loss_fn, eval_fn, tau, donate, unroll,
                      mesh, axis, batch_treedef, leaf_ndims, mask_len, m_true):
    """Compiled shard_mapped scan chunk, cached like `_scan_chunk_fn` (the
    mesh, client-batch treedef and leaf ranks join the key; same unhashable-
    algorithm fallback)."""
    try:
        return _cached_sharded_chunk_fn(algorithm, loss_fn, eval_fn, tau,
                                        donate, unroll, mesh, axis,
                                        batch_treedef, leaf_ndims, mask_len,
                                        m_true)
    except TypeError:
        return _build_sharded_chunk_fn(algorithm, loss_fn, eval_fn, tau,
                                       donate, unroll, mesh, axis,
                                       batch_treedef, leaf_ndims, mask_len,
                                       m_true)


def _build_batched_run_fn(algorithm: ServerAlgorithm, loss_fn, eval_fn,
                          tau: int, tail_n: int, batched_w0: bool,
                          batched_data: bool):
    step_round = _round_step(algorithm, loss_fn, eval_fn, tau)

    def run_one(w0, key, client_batches, eta_l, ts):
        keys = _fold_round_keys(key, ts)
        carry = (w0, algorithm.init_state(w0),
                 jnp.zeros((tail_n,) + w0.shape, w0.dtype))
        body = _scan_body(step_round, client_batches, eta_l)
        (w, _, tail), outs = jax.lax.scan(body, carry, keys)
        return (jnp.mean(tail, axis=0), w) + outs

    in_axes = (0 if batched_w0 else None, 0, 0 if batched_data else None,
               None, None)
    return jax.jit(jax.vmap(run_one, in_axes=in_axes))


_cached_batched_run_fn = functools.lru_cache(maxsize=32)(_build_batched_run_fn)


def _build_sharded_batched_run_fn(algorithm: ServerAlgorithm, loss_fn, eval_fn,
                                  tau: int, tail_n: int, batched_w0: bool,
                                  batched_data: bool, mesh, axis: str,
                                  batch_treedef, leaf_ndims, mask_len: int,
                                  m_true: int):
    """Seeds vmapped INSIDE shard_map: every device runs all S seeds over its
    own client slice, so one program serves the whole sweep sharded."""
    step_round = _sharded_round_step(algorithm, loss_fn, eval_fn, tau, axis, m_true)
    rules = client_axis_rules(mesh, axis=axis)
    # with batched_data the seed axis leads and `clients` moves to axis 1
    names = [(None, "clients") if batched_data else ("clients",)] * len(leaf_ndims)
    specs = [logical_to_pspec(tuple(n) + (None,) * (nd - len(n)), rules)
             for n, nd in zip(names, leaf_ndims)]
    batch_specs = jax.tree_util.tree_unflatten(batch_treedef, specs)
    mask_spec = logical_to_pspec(("clients",), rules, dims=(mask_len,))

    def run_one(w0, key, local_batches, mask, eta_l, ts):
        keys = _fold_round_keys(key, ts)
        carry = (w0, algorithm.init_state(w0),
                 jnp.zeros((tail_n,) + w0.shape, w0.dtype))
        body = _scan_body(step_round, (local_batches, mask), eta_l)
        (w, _, tail), outs = jax.lax.scan(body, carry, keys)
        return (jnp.mean(tail, axis=0), w) + outs

    def batched(w0, keys, local_batches, mask, eta_l, ts):
        in_axes = (0 if batched_w0 else None, 0, 0 if batched_data else None,
                   None, None, None)
        return jax.vmap(run_one, in_axes=in_axes)(
            w0, keys, local_batches, mask, eta_l, ts)

    sharded = shard_map(
        batched, mesh=mesh,
        in_specs=(P(), P(), batch_specs, mask_spec, P(), P()),
        out_specs=P(),
        check_rep=False)
    return jax.jit(sharded)


_cached_sharded_batched_run_fn = (
    functools.lru_cache(maxsize=32)(_build_sharded_batched_run_fn))


def _batched_run_fn(algorithm: ServerAlgorithm, loss_fn, eval_fn, tau: int,
                    tail_n: int, batched_w0: bool, batched_data: bool):
    """vmapped-over-seeds full run (single scan, no chunking); cached with
    the same hashability fallback as `_scan_chunk_fn`."""
    try:
        return _cached_batched_run_fn(algorithm, loss_fn, eval_fn, tau,
                                      tail_n, batched_w0, batched_data)
    except TypeError:
        return _build_batched_run_fn(algorithm, loss_fn, eval_fn, tau,
                                     tail_n, batched_w0, batched_data)


def _sharded_batched_fn(algorithm, loss_fn, eval_fn, tau, tail_n, batched_w0,
                        batched_data, mesh, axis, batch_treedef, leaf_ndims,
                        mask_len, m_true):
    try:
        return _cached_sharded_batched_run_fn(
            algorithm, loss_fn, eval_fn, tau, tail_n, batched_w0, batched_data,
            mesh, axis, batch_treedef, leaf_ndims, mask_len, m_true)
    except TypeError:
        return _build_sharded_batched_run_fn(
            algorithm, loss_fn, eval_fn, tau, tail_n, batched_w0, batched_data,
            mesh, axis, batch_treedef, leaf_ndims, mask_len, m_true)


def _chunk_bounds(rounds: int, chunk_rounds: int | None):
    chunk = rounds if not chunk_rounds else max(1, int(chunk_rounds))
    return [(s, min(s + chunk, rounds)) for s in range(0, rounds, chunk)]


def run_federated(
    algorithm: ServerAlgorithm,
    loss_fn: Callable,
    w0: jax.Array,
    client_batches,
    *,
    rounds: int,
    tau: int,
    eta_l: float,
    key: jax.Array,
    eval_fn: Callable | None = None,
    avg_last: int = 2,
    engine: str = "scan",
    chunk_rounds: int | None = None,
    scan_unroll: int = 2,
    mesh=None,
    client_axis: str = "clients",
) -> RunResult:
    """Run T federated rounds and return the iterate-averaged final model.

    engine="scan" (default): chunked-scan engine — ceil(T/chunk_rounds)
    compiled programs (one when chunk_rounds is None), donated carry,
    cross-call program cache, ``scan_unroll`` rounds per loop trip.
    engine="eager": the legacy one-program-per-round dispatch loop.

    mesh: optional 1-D ``jax.sharding.Mesh`` with a ``client_axis`` axis
    (``make_client_mesh()``): the scan engine runs under ``shard_map`` with
    the cohort partitioned across that axis and only the per-round aggregation
    moments psummed — same results as single-device up to reduction order
    (DESIGN.md §9).  Requires engine="scan".
    """
    if engine == "eager":
        if mesh is not None:
            raise ValueError("client sharding requires engine='scan'")
        return _run_eager(algorithm, loss_fn, w0, client_batches, rounds=rounds,
                          tau=tau, eta_l=eta_l, key=key, eval_fn=eval_fn,
                          avg_last=avg_last)
    if engine != "scan":
        raise ValueError(f"unknown engine {engine!r}; use 'scan' or 'eager'")

    tail_n = max(1, min(avg_last, rounds))
    donate = jax.default_backend() in ("tpu", "gpu")
    # Donation would consume the caller's w0 buffer; hand the engine a copy.
    w = jnp.array(w0, copy=True) if donate else jnp.asarray(w0)
    carry = (w, algorithm.init_state(w),
             jnp.zeros((tail_n,) + w.shape, w.dtype))
    if mesh is not None:
        m_true = jax.tree_util.tree_leaves(client_batches)[0].shape[0]
        client_batches, mask = pad_cohort(client_batches, mesh.shape[client_axis])
        leaves, treedef = jax.tree_util.tree_flatten(client_batches)
        fn = _sharded_chunk_fn(algorithm, loss_fn, eval_fn, int(tau), donate,
                               max(1, int(scan_unroll)), mesh, client_axis,
                               treedef, tuple(x.ndim for x in leaves),
                               mask.shape[0], m_true)
        extra = (mask,)
    else:
        fn = _scan_chunk_fn(algorithm, loss_fn, eval_fn, int(tau), donate,
                            max(1, int(scan_unroll)))
        extra = ()
    eta_l_arr = jnp.float32(eta_l)

    outs = []
    for start, stop in _chunk_bounds(rounds, chunk_rounds):
        carry, chunk_outs = fn(carry, key, jnp.arange(start, stop, dtype=jnp.int32),
                               client_batches, *extra, eta_l_arr)
        outs.append(chunk_outs)
    etas, metrics, naives, targets = (
        jnp.concatenate([o[i] for o in outs]) for i in range(4))
    w_last, _, tail = carry
    return RunResult(
        final_w=jnp.mean(tail, axis=0),
        last_w=w_last,
        eta_history=etas,
        metric_history=metrics,
        eta_naive_history=naives,
        eta_target_history=targets,
    )


def run_federated_batched(
    algorithm: ServerAlgorithm,
    loss_fn: Callable,
    w0: jax.Array,
    client_batches,
    *,
    rounds: int,
    tau: int,
    eta_l: float,
    keys: jax.Array,
    eval_fn: Callable | None = None,
    avg_last: int = 2,
    batched_w0: bool = False,
    batched_data: bool = False,
    mesh=None,
    client_axis: str = "clients",
) -> RunResult:
    """Run one batched program over S seeds: ``keys`` is (S,)-stacked PRNG
    keys; set ``batched_w0`` / ``batched_data`` when w0 / client_batches carry
    a matching leading seed axis.  Every RunResult field gains a leading (S,)
    axis.  ``mesh`` shards the client axis exactly as in ``run_federated``
    (seeds stay vmapped inside each shard)."""
    tail_n = max(1, min(avg_last, rounds))
    if mesh is not None:
        client_axis_pos = 1 if batched_data else 0
        m_true = jax.tree_util.tree_leaves(client_batches)[0].shape[client_axis_pos]
        client_batches, mask = pad_cohort(
            client_batches, mesh.shape[client_axis], axis=client_axis_pos)
        leaves, treedef = jax.tree_util.tree_flatten(client_batches)
        fn = _sharded_batched_fn(algorithm, loss_fn, eval_fn, int(tau), tail_n,
                                 bool(batched_w0), bool(batched_data), mesh,
                                 client_axis, treedef,
                                 tuple(x.ndim for x in leaves), mask.shape[0],
                                 m_true)
        final_w, last_w, etas, metrics, naives, targets = fn(
            w0, keys, client_batches, mask, jnp.float32(eta_l),
            jnp.arange(rounds, dtype=jnp.int32))
        return RunResult(final_w=final_w, last_w=last_w, eta_history=etas,
                         metric_history=metrics, eta_naive_history=naives,
                         eta_target_history=targets)
    fn = _batched_run_fn(algorithm, loss_fn, eval_fn, int(tau), tail_n,
                         bool(batched_w0), bool(batched_data))
    final_w, last_w, etas, metrics, naives, targets = fn(
        w0, keys, client_batches, jnp.float32(eta_l),
        jnp.arange(rounds, dtype=jnp.int32))
    return RunResult(final_w=final_w, last_w=last_w, eta_history=etas,
                     metric_history=metrics, eta_naive_history=naives,
                     eta_target_history=targets)


def _run_eager(algorithm, loss_fn, w0, client_batches, *, rounds, tau, eta_l,
               key, eval_fn, avg_last):
    """Legacy engine: one jitted XLA program per round, dispatched from a
    Python loop (re-traced per call — kept as the e7 throughput baseline)."""
    step_round = _round_step(algorithm, loss_fn, eval_fn, tau)

    def one_round(w, opt_state, round_key):
        return step_round(w, opt_state, round_key, client_batches, eta_l)

    round_jit = jax.jit(one_round)

    w = w0
    opt_state = algorithm.init_state(w0)
    tail: list[jax.Array] = []
    etas, metrics, naives, targets = [], [], [], []
    for t in range(rounds):
        w, opt_state, (eta, metric, naive, target) = round_jit(
            w, opt_state, jax.random.fold_in(key, t))
        etas.append(eta)
        metrics.append(metric)
        naives.append(naive)
        targets.append(target)
        tail.append(w)
        if len(tail) > avg_last:
            tail.pop(0)

    final_w = jnp.mean(jnp.stack(tail), axis=0)
    return RunResult(
        final_w=final_w,
        last_w=w,
        eta_history=jnp.stack(etas),
        metric_history=jnp.stack(metrics),
        eta_naive_history=jnp.stack(naives),
        eta_target_history=jnp.stack(targets),
    )
