"""Federated training loop for the simulation engine.

Runs T rounds of: broadcast -> vmapped local training (Algorithm 3) ->
clip/randomize/aggregate + adaptive step size (Algorithms 1/2) -> global
update.

Engine (DESIGN.md §8).  The default ``engine="scan"`` compiles the whole
round loop as ``jax.lax.scan`` programs: T rounds run as ceil(T/chunk_rounds)
XLA dispatches (one, by default) instead of T, per-round PRNG keys are
``fold_in``-derived inside the scan, the eta/metric/naive/target histories
come back as stacked scan outputs, and the trailing ``avg_last`` iterates ride
in the scan carry so the §5 iterate average needs no host-side tail. The
carry is donated on accelerators, reusing the weight buffer in place, and the
compiled chunk program is cached across calls keyed on the (frozen, hashable)
algorithm configuration — repeated runs of the same setting pay zero
retrace/recompile, where the per-round loop re-jits every invocation.

``engine="eager"`` preserves the original loop — one jitted XLA program per
round, dispatched from Python — as the baseline that
``benchmarks/e7_engine_throughput.py`` measures the scan engine against.

``run_federated_batched`` vmaps the scan engine over seeds (optionally also
over per-seed initializations and client data), so a whole mean±std sweep is
ONE batched XLA program.

Following §5 of the paper, the returned final model is the average of the last
two iterates ("to mitigate the oscillating behaviour of DP-FedEXP").
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.fedexp import ServerAlgorithm
from repro.fedsim.local import cohort_updates

__all__ = ["RunResult", "run_federated", "run_federated_batched"]


@dataclasses.dataclass
class RunResult:
    final_w: jax.Array            # average of the last `avg_last` iterates
    last_w: jax.Array
    eta_history: jax.Array        # (T,)
    metric_history: jax.Array     # (T,) eval metric per round (nan if no eval_fn)
    eta_naive_history: jax.Array | None = None
    eta_target_history: jax.Array | None = None


def _round_step(algorithm, loss_fn, eval_fn, tau):
    """One server round; identical computation for both engines."""

    def step(w, opt_state, round_key, client_batches, eta_l):
        deltas = cohort_updates(loss_fn, w, client_batches, tau, eta_l)
        w_next, aux, opt_state = algorithm.apply_round_stateful(
            round_key, w, deltas, opt_state)
        metric = eval_fn(w_next) if eval_fn is not None else jnp.float32(jnp.nan)
        outs = (aux.eta_g, metric, aux.eta_naive, aux.eta_target)
        return w_next, opt_state, outs

    return step


def _fold_round_keys(key, ts):
    """Per-round keys, derived identically by every engine."""
    return jax.vmap(lambda t: jax.random.fold_in(key, t))(ts)


def _scan_body(step_round, client_batches, eta_l):
    """The one scan body both the chunked and the batched engine compile —
    the tail-carry and key semantics the bit-exactness tests pin down."""

    def body(carry, round_key):
        w, opt_state, tail = carry
        w_next, opt_state, outs = step_round(
            w, opt_state, round_key, client_batches, eta_l)
        tail = jnp.concatenate([tail[1:], w_next[None]], axis=0)
        return (w_next, opt_state, tail), outs

    return body


def _build_scan_chunk_fn(algorithm: ServerAlgorithm, loss_fn, eval_fn,
                         tau: int, donate: bool, unroll: int):
    step_round = _round_step(algorithm, loss_fn, eval_fn, tau)

    def chunk(carry, key, ts, client_batches, eta_l):
        keys = _fold_round_keys(key, ts)
        body = _scan_body(step_round, client_batches, eta_l)
        return jax.lax.scan(body, carry, keys, unroll=min(unroll, len(ts)))

    return jax.jit(chunk, donate_argnums=(0,) if donate else ())


_cached_scan_chunk_fn = functools.lru_cache(maxsize=32)(_build_scan_chunk_fn)


def _scan_chunk_fn(algorithm: ServerAlgorithm, loss_fn, eval_fn, tau: int,
                   donate: bool, unroll: int):
    """Compiled scan over a chunk of rounds, cached by configuration.

    The cache key is (algorithm config, loss/eval *identity*, tau, donation,
    unroll); round count, eta_l, and all array shapes are traced, so any two
    calls with equal configuration share one compiled program per chunk
    length.  For the cache to hit, callers must hold onto their loss/eval
    closures — a fresh closure per call retraces (exactly the legacy cost,
    no worse).  ``unroll`` packs that many rounds per loop trip — XLA:CPU
    penalizes ops inside while-loop bodies, and a small unroll claws most of
    it back for ~proportional compile time (results are bit-identical).

    Algorithms with unhashable fields (arrays, user-defined non-frozen
    dataclasses) can't be cache keys; they get an uncached build — again the
    legacy per-call-retrace cost, never an error.
    """
    try:
        return _cached_scan_chunk_fn(algorithm, loss_fn, eval_fn, tau,
                                     donate, unroll)
    except TypeError:
        return _build_scan_chunk_fn(algorithm, loss_fn, eval_fn, tau,
                                    donate, unroll)


def _build_batched_run_fn(algorithm: ServerAlgorithm, loss_fn, eval_fn,
                          tau: int, tail_n: int, batched_w0: bool,
                          batched_data: bool):
    step_round = _round_step(algorithm, loss_fn, eval_fn, tau)

    def run_one(w0, key, client_batches, eta_l, ts):
        keys = _fold_round_keys(key, ts)
        carry = (w0, algorithm.init_state(w0),
                 jnp.zeros((tail_n,) + w0.shape, w0.dtype))
        body = _scan_body(step_round, client_batches, eta_l)
        (w, _, tail), outs = jax.lax.scan(body, carry, keys)
        return (jnp.mean(tail, axis=0), w) + outs

    in_axes = (0 if batched_w0 else None, 0, 0 if batched_data else None,
               None, None)
    return jax.jit(jax.vmap(run_one, in_axes=in_axes))


_cached_batched_run_fn = functools.lru_cache(maxsize=32)(_build_batched_run_fn)


def _batched_run_fn(algorithm: ServerAlgorithm, loss_fn, eval_fn, tau: int,
                    tail_n: int, batched_w0: bool, batched_data: bool):
    """vmapped-over-seeds full run (single scan, no chunking); cached with
    the same hashability fallback as `_scan_chunk_fn`."""
    try:
        return _cached_batched_run_fn(algorithm, loss_fn, eval_fn, tau,
                                      tail_n, batched_w0, batched_data)
    except TypeError:
        return _build_batched_run_fn(algorithm, loss_fn, eval_fn, tau,
                                     tail_n, batched_w0, batched_data)


def _chunk_bounds(rounds: int, chunk_rounds: int | None):
    chunk = rounds if not chunk_rounds else max(1, int(chunk_rounds))
    return [(s, min(s + chunk, rounds)) for s in range(0, rounds, chunk)]


def run_federated(
    algorithm: ServerAlgorithm,
    loss_fn: Callable,
    w0: jax.Array,
    client_batches,
    *,
    rounds: int,
    tau: int,
    eta_l: float,
    key: jax.Array,
    eval_fn: Callable | None = None,
    avg_last: int = 2,
    engine: str = "scan",
    chunk_rounds: int | None = None,
    scan_unroll: int = 2,
) -> RunResult:
    """Run T federated rounds and return the iterate-averaged final model.

    engine="scan" (default): chunked-scan engine — ceil(T/chunk_rounds)
    compiled programs (one when chunk_rounds is None), donated carry,
    cross-call program cache, ``scan_unroll`` rounds per loop trip.
    engine="eager": the legacy one-program-per-round dispatch loop.
    """
    if engine == "eager":
        return _run_eager(algorithm, loss_fn, w0, client_batches, rounds=rounds,
                          tau=tau, eta_l=eta_l, key=key, eval_fn=eval_fn,
                          avg_last=avg_last)
    if engine != "scan":
        raise ValueError(f"unknown engine {engine!r}; use 'scan' or 'eager'")

    tail_n = max(1, min(avg_last, rounds))
    donate = jax.default_backend() in ("tpu", "gpu")
    # Donation would consume the caller's w0 buffer; hand the engine a copy.
    w = jnp.array(w0, copy=True) if donate else jnp.asarray(w0)
    carry = (w, algorithm.init_state(w),
             jnp.zeros((tail_n,) + w.shape, w.dtype))
    fn = _scan_chunk_fn(algorithm, loss_fn, eval_fn, int(tau), donate,
                        max(1, int(scan_unroll)))
    eta_l_arr = jnp.float32(eta_l)

    outs = []
    for start, stop in _chunk_bounds(rounds, chunk_rounds):
        carry, chunk_outs = fn(carry, key, jnp.arange(start, stop, dtype=jnp.int32),
                               client_batches, eta_l_arr)
        outs.append(chunk_outs)
    etas, metrics, naives, targets = (
        jnp.concatenate([o[i] for o in outs]) for i in range(4))
    w_last, _, tail = carry
    return RunResult(
        final_w=jnp.mean(tail, axis=0),
        last_w=w_last,
        eta_history=etas,
        metric_history=metrics,
        eta_naive_history=naives,
        eta_target_history=targets,
    )


def run_federated_batched(
    algorithm: ServerAlgorithm,
    loss_fn: Callable,
    w0: jax.Array,
    client_batches,
    *,
    rounds: int,
    tau: int,
    eta_l: float,
    keys: jax.Array,
    eval_fn: Callable | None = None,
    avg_last: int = 2,
    batched_w0: bool = False,
    batched_data: bool = False,
) -> RunResult:
    """Run one batched program over S seeds: ``keys`` is (S,)-stacked PRNG
    keys; set ``batched_w0`` / ``batched_data`` when w0 / client_batches carry
    a matching leading seed axis.  Every RunResult field gains a leading (S,)
    axis."""
    tail_n = max(1, min(avg_last, rounds))
    fn = _batched_run_fn(algorithm, loss_fn, eval_fn, int(tau), tail_n,
                         bool(batched_w0), bool(batched_data))
    final_w, last_w, etas, metrics, naives, targets = fn(
        w0, keys, client_batches, jnp.float32(eta_l),
        jnp.arange(rounds, dtype=jnp.int32))
    return RunResult(final_w=final_w, last_w=last_w, eta_history=etas,
                     metric_history=metrics, eta_naive_history=naives,
                     eta_target_history=targets)


def _run_eager(algorithm, loss_fn, w0, client_batches, *, rounds, tau, eta_l,
               key, eval_fn, avg_last):
    """Legacy engine: one jitted XLA program per round, dispatched from a
    Python loop (re-traced per call — kept as the e7 throughput baseline)."""
    step_round = _round_step(algorithm, loss_fn, eval_fn, tau)

    def one_round(w, opt_state, round_key):
        return step_round(w, opt_state, round_key, client_batches, eta_l)

    round_jit = jax.jit(one_round)

    w = w0
    opt_state = algorithm.init_state(w0)
    tail: list[jax.Array] = []
    etas, metrics, naives, targets = [], [], [], []
    for t in range(rounds):
        w, opt_state, (eta, metric, naive, target) = round_jit(
            w, opt_state, jax.random.fold_in(key, t))
        etas.append(eta)
        metrics.append(metric)
        naives.append(naive)
        targets.append(target)
        tail.append(w)
        if len(tail) > avg_last:
            tail.pop(0)

    final_w = jnp.mean(jnp.stack(tail), axis=0)
    return RunResult(
        final_w=final_w,
        last_w=w,
        eta_history=jnp.stack(etas),
        metric_history=jnp.stack(metrics),
        eta_naive_history=jnp.stack(naives),
        eta_target_history=jnp.stack(targets),
    )
