"""Declarative run specifications for the federated simulation engine.

The session API (DESIGN.md §10) replaces ``run_federated``'s ever-growing
kwargs list with four small frozen dataclasses, each owning one orthogonal
axis of a run:

    TrainSpec   what to train: rounds, local steps, client LR, iterate
                averaging, eval cadence
    LocalSpec   how clients train locally: full-batch GD (default) or
                minibatch SGD with local epochs, plus FedProx proximal pull
                and client momentum (DESIGN.md §11)
    EngineSpec  how to compile it: scan vs eager vs stream, chunking,
                unroll, donation
    StreamSpec  how big a client chunk the streaming engine materializes at
                once (DESIGN.md §12)
    ShardSpec   where it runs: optional ``clients`` mesh (DESIGN.md §9)
    CohortSpec  who participates: per-round client sampling (Bernoulli or
                fixed-size, with/without replacement), optionally with the
                §14 sparse gather fast path
    DataSpec    where client data lives and how it is staged to the device
                (derived from ``batches`` automatically; DESIGN.md §14)
    TelemetrySpec  how the run is observed: privacy-ledger δ and profiler
                window (DESIGN.md §15; never enters the compile-cache key
                beyond the on/off tap flag)

All specs are FROZEN and HASHABLE, so a spec tuple slots directly into the
engine's cross-call compile cache (``functools.lru_cache`` over the builder
arguments): two sessions with equal specs share one compiled chunk program.

CohortSpec sampling semantics.  ``q < 1`` draws an independent Bernoulli(q)
participation mask per round ("Poisson sampling" — the setting privacy
amplification by subsampling is stated for); ``size=k`` draws a uniformly
random k-client cohort per round, without replacement by default or with
replacement (multiplicity-weighted) when ``replace=True``.  The engine keeps
the cohort shape STATIC: every client computes its local update each round
and a {0,1}-(or multiplicity-)mask zero-weights the non-participants through
the same masked-moment machinery the client-sharded engine uses for padding
(``pad_cohort`` / ``masked_cohort_updates``), so sampled rounds stay one
compiled scan program per chunk and shard cleanly.  The per-round sampling
PRNG is ``fold_in(round_key, SAMPLING_TAG)`` — derived from the same
fold_in-chain as everything else, so sampled runs are reproducible, resumable,
and identical between the sharded and single-device engines.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

__all__ = ["TrainSpec", "LocalSpec", "EngineSpec", "StreamSpec", "ShardSpec",
           "CohortSpec", "FaultSpec", "DataSpec", "TelemetrySpec",
           "SAMPLING_TAG", "LOCAL_TRAIN_TAG", "FAULT_TAG", "COMPRESS_TAG"]

# fold_in tag deriving the per-round sampling key from the round key.  Client
# randomization folds the GLOBAL CLIENT INDEX (0..M-1) into the same round
# key, so the tag must sit outside any plausible cohort size: 2**31 - 1 is the
# largest int32 and can never collide with a client index.
SAMPLING_TAG = 2**31 - 1

# fold_in tag deriving the per-round LOCAL-TRAINING key (minibatch shuffles)
# from the round key; sits next to SAMPLING_TAG, far outside client indices.
# Per-client local keys then fold in the GLOBAL client index, so shards
# shuffle exactly as the single-device engine does.
LOCAL_TRAIN_TAG = 2**31 - 2

# fold_in tag deriving the per-round FAULT-INJECTION key (dropouts,
# straggler cutoffs, corrupted updates — DESIGN.md §13) from the round key;
# next to the other tags, far outside any client index, so fault draws never
# collide with sampling, local-training, or client-randomizer streams.
FAULT_TAG = 2**31 - 3

# fold_in tag deriving the per-round COMPRESSION-PLAN key (rand-k indices,
# sketch hash tables — DESIGN.md §16).  Defined in repro.core.compression
# (core must not import fedsim); re-exported here so spec-level callers see
# the full tag family in one place.
from repro.core.compression import COMPRESS_TAG  # noqa: E402  (tag family)


@dataclasses.dataclass(frozen=True)
class TrainSpec:
    """What to train: the paper-level knobs of one federated run."""

    rounds: int                 # T server rounds
    tau: int                    # local GD steps per client per round
    eta_l: float                # client learning rate
    avg_last: int = 2           # §5 iterate average over the trailing iterates
    eval_every: int = 1         # eval cadence; non-eval rounds record NaN

    def __post_init__(self):
        if self.rounds < 1:
            raise ValueError(f"rounds must be >= 1, got {self.rounds}")
        if self.tau < 1:
            raise ValueError(f"tau must be >= 1, got {self.tau}")
        if self.avg_last < 1:
            raise ValueError(f"avg_last must be >= 1, got {self.avg_last}")
        if self.eval_every < 1:
            raise ValueError(f"eval_every must be >= 1, got {self.eval_every}")


@dataclasses.dataclass(frozen=True)
class LocalSpec:
    """How each client trains locally (the LocalTrainer layer, DESIGN.md §11).

    The default (all fields at rest) is the historical full-batch GD of
    Algorithm 3 — ``tau`` steps on the whole client batch — and routes
    through the identical code path bit-for-bit.  Any non-default field
    switches to the pytree-native spec trainer (``repro.fedsim.local``):

    * ``batch_size`` enables minibatch SGD: every leaf of one client's batch
      must carry a leading per-sample axis; each of ``epochs`` local epochs
      visits ``n // batch_size`` full minibatches of a fresh per-epoch
      shuffle (remainder samples are dropped that epoch, standard SGD
      practice).  ``TrainSpec.tau`` is ignored when set — the step count is
      ``epochs * (n // batch_size)``.
    * ``prox_mu`` adds the FedProx proximal pull ``mu * (w - w_global)`` to
      every local gradient (Li et al. 2020).
    * ``momentum`` runs classical client momentum over the local steps
      (velocity reset each round — no cross-round client state leaks into
      the DP release).

    Minibatch shuffles draw from ``fold_in(round_key, LOCAL_TRAIN_TAG)``
    folded with the GLOBAL client index, so they are reproducible, resumable
    and identical on every engine (scan / eager / sharded / batched).
    """

    batch_size: int | None = None   # None = full batch (legacy path)
    epochs: int = 1                 # local epochs when batch_size is set
    prox_mu: float = 0.0            # FedProx proximal coefficient
    momentum: float = 0.0           # client momentum over the local steps
    control_variates: bool = False  # SCAFFOLD steps g - c_i + c (§17)

    def __post_init__(self):
        if self.batch_size is not None and self.batch_size < 1:
            raise ValueError(f"batch_size must be >= 1, got {self.batch_size}")
        if self.epochs < 1:
            raise ValueError(f"epochs must be >= 1, got {self.epochs}")
        if self.epochs > 1 and self.batch_size is None:
            raise ValueError("epochs > 1 requires batch_size (full-batch GD "
                             "counts steps with TrainSpec.tau)")
        if self.prox_mu < 0.0:
            raise ValueError(f"prox_mu must be >= 0, got {self.prox_mu}")
        if not 0.0 <= self.momentum < 1.0:
            raise ValueError(f"momentum must be in [0, 1), got {self.momentum}")
        if self.control_variates and not (
                self.batch_size is None and self.epochs == 1
                and self.prox_mu == 0.0 and self.momentum == 0.0):
            raise ValueError(
                "control_variates is the full-batch SCAFFOLD trainer "
                "(tau steps of g - c_i + c, matching the option-II variate "
                "refresh scale 1/(tau*eta_l)); it does not compose with "
                "minibatch/prox/momentum fields")

    @property
    def is_default(self) -> bool:
        """True when this spec is exactly the historical full-batch GD."""
        return (self.batch_size is None and self.epochs == 1
                and self.prox_mu == 0.0 and self.momentum == 0.0
                and not self.control_variates)


@dataclasses.dataclass(frozen=True)
class EngineSpec:
    """How to compile the round loop (DESIGN.md §8, §12).

    ``engine`` selects one of three round-loop compilations:

    * ``"scan"`` — the default: T rounds as chunked ``jax.lax.scan``
      programs, every client's update materialized at once (O(M·d) peak).
    * ``"eager"`` — one jitted XLA program per round, dispatched from a
      Python loop (the legacy baseline).
    * ``"stream"`` — the §12 streaming cohort engine: inside each round an
      inner ``lax.scan`` iterates the cohort in ``StreamSpec.chunk_clients``
      sized chunks and accumulates the additive ``RoundMoments`` carry, so
      peak update memory is O(chunk_clients·d) instead of O(M·d).
    """

    engine: str = "scan"            # "scan" | "eager" | "stream" (§12)
    chunk_rounds: int | None = None  # rounds per compiled chunk (None = all)
    scan_unroll: int = 2            # rounds unrolled per scan-loop trip
    donate: bool | None = None      # donate the carry; None = auto (tpu/gpu)

    def __post_init__(self):
        if self.engine not in ("scan", "eager", "stream"):
            raise ValueError(f"unknown engine {self.engine!r}; "
                             "use 'scan', 'eager', or 'stream'")
        if self.chunk_rounds is not None and self.chunk_rounds < 1:
            raise ValueError(f"chunk_rounds must be >= 1, got {self.chunk_rounds}")
        if self.scan_unroll < 1:
            raise ValueError(f"scan_unroll must be >= 1, got {self.scan_unroll}")


@dataclasses.dataclass(frozen=True)
class StreamSpec:
    """Client-chunk grid of the streaming cohort engine (DESIGN.md §12).

    With ``EngineSpec(engine="stream")`` each round iterates the cohort in
    ``chunk_clients``-sized chunks via an inner ``lax.scan``: local training
    and the per-client release see one (chunk_clients, d) block at a time,
    and only the O(d) additive ``RoundMoments`` (plus the PrivUnit /
    adaptive-clip extras) accumulate across chunks.  Peak update-matrix
    memory is ``chunk_clients * d`` floats — independent of the cohort size
    M, which is what makes million-client rounds fit on one device.

    The cohort is padded to a multiple of ``chunk_clients`` (times the shard
    count under §9 sharding) with zero-weight clients; all per-client
    randomness is keyed by GLOBAL client index, so the streamed release is
    the same randomization the dense engine draws.  ``chunk_clients >= M``
    degenerates to a single chunk — the dense moments computation exactly.

    Attributes:
      chunk_clients: clients materialized per inner-scan step (>= 1), or the
        string ``"auto"`` to derive the largest chunk that fits the live
        device memory budget at session-build time (the docs/scaling.md
        sizing rule, automated like ``auto_shard_count``; the session
        records the resolved value as ``session.stream.chunk_clients``).  Pick the
        largest chunk whose (chunk_clients, d) update block fits memory;
        see docs/scaling.md for the sizing playbook.
    """

    chunk_clients: int | str = 1024

    def __post_init__(self):
        if isinstance(self.chunk_clients, str):
            if self.chunk_clients != "auto":
                raise ValueError(
                    f"chunk_clients must be an int >= 1 or 'auto', "
                    f"got {self.chunk_clients!r}")
        elif self.chunk_clients < 1:
            raise ValueError(
                f"chunk_clients must be >= 1, got {self.chunk_clients}")

    @property
    def is_auto(self) -> bool:
        """True when the chunk size is derived from the device memory budget."""
        return self.chunk_clients == "auto"


@dataclasses.dataclass(frozen=True)
class ShardSpec:
    """Where the cohort lives: optional client sharding (DESIGN.md §9).

    ``mesh`` is a 1-D ``jax.sharding.Mesh`` with a ``client_axis`` axis (see
    ``repro.launch.mesh.make_client_mesh``); ``jax.sharding.Mesh`` is hashable,
    so the spec still keys the compile cache.
    """

    mesh: object | None = None      # jax.sharding.Mesh | None
    client_axis: str = "clients"


@dataclasses.dataclass(frozen=True)
class CohortSpec:
    """Who participates each round: per-round client sampling.

    q=1.0 and size=None (the default) is FULL participation and takes exactly
    the unsampled engine path — bit-for-bit the pre-session behavior.
    ``q < 1`` is per-round Bernoulli (Poisson) sampling; ``size=k`` is a
    fixed-size uniform cohort, with multiplicity weights when ``replace``.

    ``gather=True`` turns on the §14 sparse fast path: instead of computing
    all M local updates and zero-weighting non-participants (static shapes,
    O(M·d) per round), the engine packs the sampled cohort into a dense
    ``(cap, ...)`` block via ``gather_slots`` and trains ONLY those rows —
    O(q·M·d) per round.  ``cap`` is static: the fixed cohort ``size`` when
    set, else ``gather_cap``, else a Bernoulli high-probability bound
    (``resolved_cap``).  Per-client randomness still keys by GLOBAL client
    index, so gathered rounds equal dense rounds at rtol 1e-5 on every
    engine.  Participants beyond the cap are dropped from the round
    (vanishingly rare at the default headroom; see DESIGN.md §14).
    """

    q: float = 1.0              # Bernoulli participation probability
    size: int | None = None     # fixed cohort size (mutually exclusive with q<1)
    replace: bool = False       # fixed-size sampling with replacement
    gather: bool = False        # §14 sparse fast path: pre-gather participants
    gather_cap: int | None = None  # static slot-table size; None = derived

    def __post_init__(self):
        if not (0.0 < self.q <= 1.0):
            raise ValueError(f"q must be in (0, 1], got {self.q}")
        if self.size is not None and self.size < 1:
            raise ValueError(f"size must be >= 1, got {self.size}")
        if self.q < 1.0 and self.size is not None:
            raise ValueError("specify q<1 (Bernoulli) OR size (fixed), not both")
        if self.replace and self.size is None:
            raise ValueError("replace=True requires a fixed cohort size")
        if self.gather and not self.is_sampled:
            raise ValueError("gather=True requires sampling (q < 1 or size=k); "
                             "a full-participation round has nothing to skip")
        if self.gather and self.replace:
            # a with-replacement multiplicity mask is gate-only in the moment
            # reductions (see partial_clip_moments); a gathered block would
            # need true row duplication to stay exact, so refuse loudly
            raise ValueError("gather=True does not support replace=True "
                             "(multiplicity-weighted cohorts); drop gather or "
                             "sample without replacement")
        if self.gather_cap is not None:
            if self.gather_cap < 1:
                raise ValueError(f"gather_cap must be >= 1, got {self.gather_cap}")
            if not self.gather:
                raise ValueError("gather_cap requires gather=True")

    @property
    def is_sampled(self) -> bool:
        """True when this spec actually subsamples (q < 1 or fixed size)."""
        return self.q < 1.0 or self.size is not None

    def resolved_cap(self, num_clients: int) -> int:
        """Static slot-table size of the §14 gathered block for an M-client
        cohort: the fixed cohort size when set (exact); ``gather_cap`` when
        given; else a Bernoulli(q) high-probability bound
        ``qM + 6·sqrt(qM) + 16`` (≈ 6-sigma headroom plus a small-M floor —
        overflow odds far below any rtol-1e-5 test's flake budget), clamped
        to M."""
        if self.size is not None:
            return min(self.size, num_clients)
        if self.gather_cap is not None:
            return min(self.gather_cap, num_clients)
        qm = self.q * num_clients
        return min(num_clients, int(math.ceil(qm + 6.0 * math.sqrt(qm) + 16.0)))

    def sampling_rate(self, num_clients: int) -> float:
        """Expected per-round participation fraction (for accounting)."""
        if self.size is not None:
            return min(1.0, self.size / float(num_clients))
        return self.q

    def round_mask(self, round_key: jax.Array, num_clients: int) -> jax.Array:
        """(num_clients,) float participation mask for one round.

        The sampling key is ``fold_in(round_key, SAMPLING_TAG)``; the mask is
        {0,1}-valued (Bernoulli / without-replacement) or multiplicity-valued
        (with replacement, summing to ``size``).  Pure jax, static shapes —
        safe inside the scan body and identical on every shard.
        """
        k = jax.random.fold_in(round_key, SAMPLING_TAG)
        if self.size is not None:
            if self.replace:
                idx = jax.random.randint(k, (self.size,), 0, num_clients)
                return jnp.zeros((num_clients,), jnp.float32).at[idx].add(1.0)
            # positions holding values < size in a random permutation form a
            # uniformly random size-subset — one draw, no index scatter
            perm = jax.random.permutation(k, num_clients)
            return (perm < self.size).astype(jnp.float32)
        return jax.random.bernoulli(k, self.q, (num_clients,)).astype(jnp.float32)


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """What goes wrong each round: deterministic fault injection + detection
    (DESIGN.md §13).

    The default (all fields at rest) is a FAULT-FREE run and normalizes to
    the unfaulted engine path — bit-for-bit today's behavior, exactly like
    ``CohortSpec()``'s full-participation normalization.  Any non-default
    field routes rounds through the masked-moment protocol with a per-round
    fault draw keyed by ``fold_in(round_key, FAULT_TAG)`` and GLOBAL client
    index, so faulty runs are bit-reproducible across the scan / eager /
    sharded / stream engines and across resumes.

    Injection fields (per-round, per-client, independent):

    * ``dropout`` — probability a client silently drops out of the round:
      its update becomes a zero-weight row (the §9/§10 mask machinery), and
      the realized cohort count shrinks accordingly.
    * ``straggler`` + ``straggler_steps`` — probability a client misses the
      round deadline having completed only ``straggler_steps`` of the
      configured ``tau`` local steps; its (partial) update still aggregates.
    * ``corrupt`` — probability a surviving client returns a corrupted
      (non-finite) update.  The engine injects NaN rows and the server-side
      finite screen zero-weights them — exercising exactly the degradation
      path a real corrupted device would hit.

    Detection fields (the divergence watchdog, §13):

    * ``watchdog`` — arm the in-scan divergence watchdog: a non-finite
      global model or a step size above ``eta_max`` freezes the remaining
      rounds of the chunk (``lax.cond``) and surfaces the faulting round
      index as ``RunResult.fault_round``; ``session.run(on_divergence=...)``
      turns that into rollback-and-retry.
    """

    dropout: float = 0.0        # P(client drops out of a round)
    straggler: float = 0.0      # P(client misses the deadline)
    straggler_steps: int = 1    # local steps a straggler completes (< tau)
    corrupt: float = 0.0        # P(surviving client returns non-finite rows)
    watchdog: bool = False      # arm the in-scan divergence watchdog
    eta_max: float = 1e6        # watchdog: eta_g above this = divergence

    def __post_init__(self):
        for field in ("dropout", "straggler", "corrupt"):
            v = getattr(self, field)
            if not 0.0 <= v < 1.0:
                raise ValueError(f"{field} must be in [0, 1), got {v}")
        if self.straggler_steps < 1:
            raise ValueError(
                f"straggler_steps must be >= 1, got {self.straggler_steps}")
        if not self.eta_max > 0.0:
            raise ValueError(f"eta_max must be > 0, got {self.eta_max}")

    @property
    def injects(self) -> bool:
        """True when this spec actually perturbs rounds (any nonzero rate)."""
        return self.dropout > 0.0 or self.straggler > 0.0 or self.corrupt > 0.0

    @property
    def is_active(self) -> bool:
        """True when the engine must deviate from the unfaulted program
        (injection or watchdog); ``FaultSpec()`` normalizes to None."""
        return self.injects or self.watchdog


@dataclasses.dataclass(frozen=True)
class TelemetrySpec:
    """How a run is observed: the §15 telemetry knobs.

    The ninth spec.  Unlike every other spec, telemetry config must NOT
    change the compiled program beyond the single on/off tap flag — the
    engine builders receive only ``tap: bool`` (tracker attached or not),
    never this spec, so changing the ledger delta or a profile window can
    never force a recompile or (worse) silently fork the compile cache.
    The tracker itself is a runtime argument (``run(tracker=...)``), not
    spec state: trackers hold open files and are not hashable.

    Attributes:
      ledger_delta: δ at which the per-round cumulative privacy ledger is
        evaluated (``session._budget_at(ledger_delta, rounds_executed)``
        appended to every round event).  ``None`` disables ledger events.
        Sessions whose algorithm has no accounting hook skip the ledger
        automatically — the probe failure is per-run, not an error.
      profile_rounds: optional ``(a, b)`` half-open round window wrapped in
        a ``jax.profiler`` trace (scan engine: chunk boundaries are split at
        a and b so the trace covers exactly those rounds).  ``None`` = off.
      profile_dir: where the profiler writes its artifact; recorded in the
        profile_start/profile_stop tracker events.
    """

    ledger_delta: float | None = 1e-5
    profile_rounds: tuple[int, int] | None = None
    profile_dir: str = "results/profile"

    def __post_init__(self):
        if self.ledger_delta is not None and not (0.0 < self.ledger_delta < 1.0):
            raise ValueError(
                f"ledger_delta must be in (0, 1) or None, got {self.ledger_delta}")
        if self.profile_rounds is not None:
            a, b = self.profile_rounds
            if not (0 <= a < b):
                raise ValueError("profile_rounds must be (a, b) with "
                                 f"0 <= a < b, got {self.profile_rounds}")


@dataclasses.dataclass(frozen=True)
class DataSpec:
    """Where client data lives and how it reaches the device (DESIGN.md §14).

    The eighth spec.  Sessions derive it automatically from what ``batches``
    is — a device array / pytree yields ``kind="device"`` (the historical
    path, bit-for-bit), a ``ClientDataSource`` yields its ``kind`` — so
    existing callers never construct one.  Pass ``data=DataSpec(prefetch=...)``
    to tune the host→device double-buffer depth of a host-resident run.

    Frozen and hashable like every spec: ``kind`` and ``prefetch`` join the
    engine's compile-cache key, so a host-resident session never silently
    shares a compiled program whose input-staging assumptions differ.

    Attributes:
      kind: ``"device"`` (resident arrays, the default), ``"host"`` (NumPy
        arrays on the host), ``"npz"`` (on-disk archive), ``"synthetic"``
        (generated per fetch) — whatever the source reports.
      prefetch: chunks kept in flight ahead of the §12 inner scan on the
        host-resident path (>= 1; 2 = classic double buffering).  Ignored
        for device-resident data.
    """

    kind: str = "device"
    prefetch: int = 2

    def __post_init__(self):
        if self.kind not in ("device", "host", "npz", "synthetic"):
            raise ValueError(f"unknown data kind {self.kind!r}; use 'device', "
                             "'host', 'npz', or 'synthetic'")
        if self.prefetch < 1:
            raise ValueError(f"prefetch must be >= 1, got {self.prefetch}")
