"""Checkpointing: save/restore parameter pytrees + server state as ``.npz``.

Offline container has no msgpack/orbax, so checkpoints are flat ``npz``
archives keyed by ``/``-joined tree paths, with a tiny JSON sidecar recording
the round counter and RNG key. Round-trips exactly (dtype- and
structure-preserving) and is host-memory streaming (numpy mmap on load).
"""
from __future__ import annotations

import json
import os
import re
from typing import Any

import jax
import numpy as np

__all__ = ["save_checkpoint", "load_checkpoint", "latest_step"]

_SEP = "/"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(directory: str, step: int, params, extra: dict | None = None) -> str:
    """Write ``<dir>/ckpt_<step>.npz`` (+ meta json). Returns the path."""
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    np.savez(path, **_flatten(params))
    meta = {"step": step, **(extra or {})}
    with open(path.replace(".npz", ".json"), "w") as f:
        json.dump(meta, f)
    return path


def load_checkpoint(directory: str, template, step: int | None = None):
    """Restore into the structure of ``template``. Returns (params, meta)."""
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    data = np.load(path)
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, leaf in paths:
        key = _SEP.join(str(q.key) if hasattr(q, "key") else str(q.idx) for q in p)
        arr = data[key]
        assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        leaves.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr)
    with open(path.replace(".npz", ".json")) as f:
        meta = json.load(f)
    return jax.tree_util.tree_unflatten(treedef, leaves), meta


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for f in os.listdir(directory)
             if (m := re.match(r"ckpt_(\d+)\.npz$", f))]
    return max(steps) if steps else None
