"""Checkpointing: save/restore parameter pytrees + server state as ``.npz``.

Offline container has no msgpack/orbax, so checkpoints are flat ``npz``
archives keyed by ``/``-joined tree paths, with a tiny JSON sidecar recording
the round counter and RNG key. Round-trips exactly (dtype- and
structure-preserving) and is host-memory streaming (numpy mmap on load).

Path keys cover every jax key type (dict keys, sequence indices, dataclass
attributes), so registered-dataclass states — e.g. the adaptive-clip
``AdaptiveClipState`` threaded through a session's carry — round-trip like
plain dicts.  Both files are written atomically (tmp file + rename), sidecar
FIRST and the ``.npz`` last: a checkpoint only becomes discoverable
(``latest_step`` keys on the ``.npz`` listing) once both halves are durable,
so a kill at any point mid-save leaves at worst a harmless orphan sidecar or
tmp file, never a latest step that cannot be loaded.
"""
from __future__ import annotations

import json
import os
import re
from typing import Any

import jax
import numpy as np

__all__ = ["save_checkpoint", "load_checkpoint", "latest_step"]

_SEP = "/"


def _path_str(path) -> str:
    """``/``-joined key path; supports DictKey(.key), SequenceKey(.idx),
    GetAttrKey(.name) and FlattenedIndexKey(.key)."""
    parts = []
    for p in path:
        for attr in ("key", "idx", "name"):
            if hasattr(p, attr):
                parts.append(str(getattr(p, attr)))
                break
        else:
            parts.append(str(p))
    return _SEP.join(parts)


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat[_path_str(path)] = np.asarray(leaf)
    return flat


def _atomic_json_dump(obj: Any, path: str) -> None:
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(obj, f)
    os.replace(tmp, path)


def save_checkpoint(directory: str, step: int, params, extra: dict | None = None) -> str:
    """Write ``<dir>/ckpt_<step>.npz`` (+ meta json). Returns the path."""
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    # sidecar FIRST, npz last: latest_step keys on the npz listing, so the
    # step only becomes visible once both halves exist — a crash between the
    # writes leaves a harmless orphan sidecar, never a latest checkpoint
    # whose load raises FileNotFoundError
    meta = {"step": step, **(extra or {})}
    _atomic_json_dump(meta, path.replace(".npz", ".json"))
    tmp = path + ".tmp.npz"
    np.savez(tmp, **_flatten(params))
    os.replace(tmp, path)
    return path


def load_checkpoint(directory: str, template, step: int | None = None):
    """Restore into the structure of ``template``. Returns (params, meta)."""
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    data = np.load(path)
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, leaf in paths:
        key = _path_str(p)
        if key not in data:
            raise ValueError(
                f"checkpoint {path} is missing leaf {key!r} required by the "
                f"template (have: {sorted(data.files)[:10]}...)")
        arr = data[key]
        if arr.shape != tuple(leaf.shape):
            raise ValueError(
                f"checkpoint leaf {key!r} has shape {arr.shape}, template "
                f"expects {tuple(leaf.shape)} — checkpoint and session "
                "configuration (model dim, avg_last, optimizer) must match")
        leaves.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr)
    with open(path.replace(".npz", ".json")) as f:
        meta = json.load(f)
    return jax.tree_util.tree_unflatten(treedef, leaves), meta


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for f in os.listdir(directory)
             if (m := re.match(r"ckpt_(\d+)\.npz$", f))]
    return max(steps) if steps else None
