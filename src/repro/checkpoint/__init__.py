"""Checkpointing: save/restore parameter pytrees + server state as ``.npz``.

Offline container has no msgpack/orbax, so checkpoints are flat ``npz``
archives keyed by ``/``-joined tree paths, with a tiny JSON sidecar recording
the round counter and RNG key. Round-trips exactly (dtype- and
structure-preserving) and is host-memory streaming (numpy mmap on load).

Path keys cover every jax key type (dict keys, sequence indices, dataclass
attributes), so registered-dataclass states — e.g. the adaptive-clip
``AdaptiveClipState`` threaded through a session's carry — round-trip like
plain dicts.  Both files are written atomically (tmp file + rename), sidecar
FIRST and the ``.npz`` last: a checkpoint only becomes discoverable
(``latest_step`` keys on the ``.npz`` listing) once both halves are durable,
so a kill at any point mid-save leaves at worst a harmless orphan sidecar or
tmp file, never a latest step that cannot be loaded.

Corruption hardening (DESIGN.md §13): the sidecar records the ``.npz``'s
sha256, verified on load; ANY unreadable half (truncated archive, garbage
bytes, mangled json, checksum mismatch) surfaces as a ``ValueError`` naming
the file — never a zipfile/pickle traceback.  ``load_checkpoint`` retries
transient ``OSError`` with linear backoff, and ``load_latest_intact`` walks
the step listing newest-first past corrupt checkpoints to the newest one
that loads cleanly — the rollback target of auto-recovering runs.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import time
from typing import Any, Callable

import jax
import numpy as np

__all__ = [
    "save_checkpoint",
    "load_checkpoint",
    "load_latest_intact",
    "latest_step",
    "checkpoint_steps",
]

_SEP = "/"


def _path_str(path) -> str:
    """``/``-joined key path; supports DictKey(.key), SequenceKey(.idx),
    GetAttrKey(.name) and FlattenedIndexKey(.key)."""
    parts = []
    for p in path:
        for attr in ("key", "idx", "name"):
            if hasattr(p, attr):
                parts.append(str(getattr(p, attr)))
                break
        else:
            parts.append(str(p))
    return _SEP.join(parts)


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat[_path_str(path)] = np.asarray(leaf)
    return flat


def _atomic_json_dump(obj: Any, path: str) -> None:
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(obj, f)
    os.replace(tmp, path)


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for block in iter(lambda: f.read(1 << 20), b""):
            h.update(block)
    return h.hexdigest()


def save_checkpoint(directory: str, step: int, params, extra: dict | None = None) -> str:
    """Write ``<dir>/ckpt_<step>.npz`` (+ meta json). Returns the path."""
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    # npz to a tmp file first (so its sha256 can ride the sidecar), sidecar
    # second, npz rename LAST: latest_step keys on the npz listing, so the
    # step only becomes visible once both halves exist — a crash between the
    # writes leaves a harmless orphan sidecar or tmp file, never a latest
    # checkpoint whose load raises FileNotFoundError
    tmp = path + ".tmp.npz"
    np.savez(tmp, **_flatten(params))
    meta = {"step": step, "npz_sha256": _sha256(tmp), **(extra or {})}
    _atomic_json_dump(meta, path.replace(".npz", ".json"))
    os.replace(tmp, path)
    return path


def _read_meta(path: str) -> dict:
    """The sidecar as a dict; mangled json is a corrupt checkpoint, not a
    JSONDecodeError traceback."""
    meta_path = path.replace(".npz", ".json")
    try:
        with open(meta_path) as f:
            return json.load(f)
    except FileNotFoundError:
        raise
    except (json.JSONDecodeError, UnicodeDecodeError, ValueError) as exc:
        raise ValueError(
            f"corrupt checkpoint sidecar {meta_path}: {exc}") from exc


def _load_once(directory: str, template, step: int):
    """One load attempt — every corruption mode resolves to ValueError."""
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    meta = _read_meta(path)
    recorded = meta.get("npz_sha256")
    if recorded is not None and _sha256(path) != recorded:
        raise ValueError(
            f"corrupt checkpoint {path}: sha256 mismatch with sidecar "
            "(truncated or modified archive)")
    try:
        data = np.load(path, allow_pickle=False)
    except FileNotFoundError:
        raise
    except Exception as exc:  # zipfile.BadZipFile, OSError on garbage, ...
        raise ValueError(f"corrupt checkpoint {path}: {exc}") from exc
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, leaf in paths:
        key = _path_str(p)
        if key not in data:
            raise ValueError(
                f"checkpoint {path} is missing leaf {key!r} required by the "
                f"template (have: {sorted(data.files)[:10]}...)")
        try:
            arr = data[key]
        except Exception as exc:  # truncated member in a pre-sha archive
            raise ValueError(
                f"corrupt checkpoint {path}: leaf {key!r} unreadable: "
                f"{exc}") from exc
        if arr.shape != tuple(leaf.shape):
            raise ValueError(
                f"checkpoint leaf {key!r} has shape {arr.shape}, template "
                f"expects {tuple(leaf.shape)} — checkpoint and session "
                "configuration (model dim, avg_last, optimizer) must match")
        leaves.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr)
    return jax.tree_util.tree_unflatten(treedef, leaves), meta


def load_checkpoint(directory: str, template, step: int | None = None,
                    retries: int = 0, backoff: float = 0.0):
    """Restore into the structure of ``template``. Returns (params, meta).

    ``retries`` re-attempts the read after a transient ``OSError`` (NFS blip,
    EBUSY), sleeping ``backoff * attempt`` seconds between tries.  A missing
    checkpoint (FileNotFoundError) and a corrupt one (ValueError) are
    permanent and never retried.
    """
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoints in {directory}")
    for attempt in range(max(0, int(retries)) + 1):
        try:
            return _load_once(directory, template, step)
        except (FileNotFoundError, ValueError):
            raise
        except OSError:
            if attempt >= retries:
                raise
            if backoff > 0.0:
                time.sleep(backoff * (attempt + 1))


def load_latest_intact(directory: str, template, retries: int = 0,
                       backoff: float = 0.0):
    """Newest checkpoint that loads cleanly: ``(step, params, meta)``.

    Walks the step listing newest-first; a corrupt or unreadable checkpoint
    is skipped (this is the fallback path of auto-recovering runs —
    DESIGN.md §13).  ``template`` may be a pytree or a callable
    ``step -> pytree`` when the template's shapes depend on the step (e.g.
    per-round history arrays).  Raises ``FileNotFoundError`` when the
    directory holds no checkpoints at all, ``ValueError`` (listing every
    per-step failure) when none of them is intact.
    """
    steps = sorted(checkpoint_steps(directory), reverse=True)
    if not steps:
        raise FileNotFoundError(f"no checkpoints in {directory}")
    failures = []
    for step in steps:
        tpl = template(step) if callable(template) else template
        try:
            params, meta = load_checkpoint(directory, tpl, step=step,
                                           retries=retries, backoff=backoff)
            return step, params, meta
        except (ValueError, OSError) as exc:
            failures.append(f"step {step}: {exc}")
    raise ValueError(
        f"no intact checkpoint in {directory}; " + "; ".join(failures))


def checkpoint_steps(directory: str) -> list[int]:
    """All discoverable checkpoint steps (ascending; [] when none)."""
    if not os.path.isdir(directory):
        return []
    return sorted(int(m.group(1)) for f in os.listdir(directory)
                  if (m := re.match(r"ckpt_(\d+)\.npz$", f)))


def latest_step(directory: str) -> int | None:
    steps = checkpoint_steps(directory)
    return max(steps) if steps else None
