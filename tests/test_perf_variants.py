"""Correctness of the §Perf variants: chunked attention, group-local MoE."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.kernels.flash_attention.ref import attention_ref
from repro.models.attention import blockwise_attention, chunked_attention
from repro.models.moe import moe_apply, moe_defs
from repro.models.sharding import AXIS_SIZES_KEY, axis_rules
from repro.models.common import init_params


class TestChunkedAttention:
    @pytest.mark.parametrize("causal", [True, False])
    @pytest.mark.parametrize("sq", [64, 96])
    def test_matches_ref(self, causal, sq):
        b, hq, hkv, dh = 2, 4, 2, 32
        key = jax.random.PRNGKey(0)
        q = jax.random.normal(key, (b, sq, hq, dh))
        k = jax.random.normal(jax.random.fold_in(key, 1), (b, sq, hkv, dh))
        v = jax.random.normal(jax.random.fold_in(key, 2), (b, sq, hkv, dh))
        got = chunked_attention(q, k, v, causal=causal, window=None, block_q=32)
        # ref takes (B, H, S, D)
        want = attention_ref(jnp.moveaxis(q, 2, 1), jnp.moveaxis(k, 2, 1),
                             jnp.moveaxis(v, 2, 1), causal=causal)
        np.testing.assert_allclose(np.asarray(got), np.asarray(jnp.moveaxis(want, 1, 2)),
                                   rtol=2e-4, atol=2e-4)

    def test_matches_blockwise_with_window(self):
        b, h, s, dh = 1, 2, 128, 16
        key = jax.random.PRNGKey(3)
        q = jax.random.normal(key, (b, s, h, dh))
        k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, h, dh))
        v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, h, dh))
        a = chunked_attention(q, k, v, causal=True, window=32, block_q=32)
        bw = blockwise_attention(q, k, v, causal=True, window=32, block_k=32)
        np.testing.assert_allclose(np.asarray(a), np.asarray(bw), rtol=2e-4, atol=2e-4)


class TestGroupLocalMoE:
    def _setup(self, e=4, k=2, d=64, f=128):
        cfg = dataclasses.replace(
            reduced(ARCHS["granite-moe-1b-a400m"], d_model=d),
            num_experts=e, top_k=k, d_ff=f, capacity_factor=8.0)
        defs = moe_defs(cfg)
        params = init_params(jax.random.PRNGKey(0), defs, jnp.float32)
        # router init is zeros-protected? router is 2D -> dense init; fine
        return cfg, params

    def test_grouped_matches_ungrouped(self):
        """g>1 dispatch == g=1 dispatch when capacity is drop-free."""
        cfg, params = self._setup()
        b, s = 4, 16
        x = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model))
        y1, aux1 = moe_apply(params, x, cfg)  # no rules -> g=1
        # pretend sizes say 4 data shards (drives g=4); the real 1-device
        # mesh satisfies every constraint trivially, so this exercises the
        # grouped dispatch MATH against the ungrouped path.
        rules = {"batch": "data", AXIS_SIZES_KEY: {"data": 4, "model": 1}}
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        with mesh, axis_rules(rules):
            y4, aux4 = jax.jit(lambda p, xx: moe_apply(p, xx, cfg))(params, x)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y4), rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(float(aux1), float(aux4), rtol=1e-4)

    def test_capacity_drops_are_weighted_zero(self):
        cfg, params = self._setup()
        cfg = dataclasses.replace(cfg, capacity_factor=0.01)  # force drops
        x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, cfg.d_model))
        y, _ = moe_apply(params, x, cfg)
        assert np.all(np.isfinite(np.asarray(y)))

    def test_group_fallback_small_batch(self):
        """b % g != 0 falls back to g=1 silently."""
        cfg, params = self._setup()
        x = jax.random.normal(jax.random.PRNGKey(3), (3, 8, cfg.d_model))
        rules = {"batch": "data", AXIS_SIZES_KEY: {"data": 2, "model": 1}}
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        with mesh, axis_rules(rules):
            y, _ = jax.jit(lambda p, xx: moe_apply(p, xx, cfg))(params, x)
        assert y.shape == (3, 8, cfg.d_model)
