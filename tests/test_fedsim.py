"""Integration tests: the paper's training loop on the synthetic problem.

These are miniature versions of the paper's experiments (fewer clients/rounds)
asserting the *qualitative claims*: FedEXP >= FedAvg, DP-FedEXP >= DP-FedAvg,
eta_g >= 1, and the bias-correction behaviour of Fig. 2.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fedexp import make_algorithm
from repro.data.synthetic import distance_to_opt, linreg_loss, make_synthetic_linreg
from repro.fedsim import FederatedSession, TrainSpec
from repro.fedsim.scaffold import DPScaffoldConfig, run_dp_scaffold

M, D, TAU, ETA_L, ROUNDS = 200, 50, 10, 0.01, 15


@pytest.fixture(scope="module")
def problem():
    data = make_synthetic_linreg(jax.random.PRNGKey(0), M, D)
    w0 = jnp.zeros(D)
    return data, w0


def _run(problem, alg, rounds=ROUNDS, **kw):
    data, w0 = problem
    algorithm = make_algorithm(alg, **kw)
    session = FederatedSession(
        algorithm, linreg_loss, w0, data.client_batches(),
        train=TrainSpec(rounds=rounds, tau=TAU, eta_l=ETA_L),
        eval_fn=distance_to_opt(data.w_star))
    return session.run(jax.random.PRNGKey(42))


class TestNonPrivate:
    def test_fedexp_beats_fedavg(self, problem):
        r_avg = _run(problem, "fedavg")
        r_exp = _run(problem, "fedexp")
        assert float(r_exp.metric_history[-1]) < float(r_avg.metric_history[-1])
        assert float(jnp.min(r_exp.eta_history)) >= 1.0
        # both make progress
        assert float(r_avg.metric_history[-1]) < float(r_avg.metric_history[0])

    def test_iterate_averaging(self, problem):
        r = _run(problem, "fedexp")
        # final_w = mean of last 2 iterates, close to but not equal to last_w
        assert not np.allclose(np.asarray(r.final_w), np.asarray(r.last_w))


class TestLDP:
    def test_ldp_fedexp_beats_dp_fedavg(self, problem):
        kw = dict(clip_norm=0.3, sigma=0.7 * 0.3)
        r_avg = _run(problem, "dp-fedavg-ldp-gauss", **kw)
        r_exp = _run(problem, "ldp-fedexp-gauss", **kw)
        assert float(r_exp.metric_history[-1]) < float(r_avg.metric_history[-1])
        assert float(jnp.min(r_exp.eta_history)) >= 1.0

    def test_bias_correction_fig2(self, problem):
        """Naive eta (Eq. 3) >> corrected eta (Eq. 6) ~ target (Eq. 5) at t=0."""
        r = _run(problem, "ldp-fedexp-gauss", rounds=1, clip_norm=0.3, sigma=0.21)
        naive = float(r.eta_naive_history[0])
        corrected = float(r.eta_history[0])
        target = float(r.eta_target_history[0])
        assert naive > 3 * max(corrected, 1.0)
        assert corrected <= naive
        # corrected is within a factor ~2 of max(1, target)
        assert corrected / max(target, 1.0) < 3.0

    def test_privunit_runs_and_eta_ge_one(self, problem):
        r = _run(problem, "ldp-fedexp-privunit", rounds=3,
                 clip_norm=0.3, eps0=2.0, eps1=2.0, eps2=2.0, dim=D)
        assert float(jnp.min(r.eta_history)) >= 1.0
        assert np.all(np.isfinite(np.asarray(r.metric_history)))


class TestCDP:
    def test_cdp_fedexp_beats_dp_fedavg(self, problem):
        kw = dict(clip_norm=0.3, sigma=5 * 0.3 / np.sqrt(M), num_clients=M)
        r_avg = _run(problem, "dp-fedavg-cdp", **kw)
        r_exp = _run(problem, "cdp-fedexp", **kw)
        assert float(r_exp.metric_history[-1]) < float(r_avg.metric_history[-1])

    def test_sigma_xi_default_is_hyperparameter_free(self, problem):
        data, w0 = problem
        alg = make_algorithm("cdp-fedexp", clip_norm=0.3,
                             sigma=5 * 0.3 / np.sqrt(M), num_clients=M)
        assert alg.sigma_xi is None  # resolved to d*sigma^2/M inside apply_round


class TestScaffold:
    def test_dp_scaffold_runs(self, problem):
        data, w0 = problem
        cfg = DPScaffoldConfig(clip_norm=0.3, sigma=5 * 0.3 / np.sqrt(M),
                               central=True, num_clients=M)
        r = run_dp_scaffold(cfg, linreg_loss, w0, data.client_batches(),
                            rounds=5, tau=TAU, eta_l=ETA_L,
                            key=jax.random.PRNGKey(1),
                            eval_fn=distance_to_opt(data.w_star))
        assert np.all(np.isfinite(np.asarray(r.metric_history)))

    def test_import_emits_no_warning(self):
        """Deprecation is a CALL-time concern: merely importing (or
        re-importing) the module — e.g. via ``from repro.fedsim import ...``
        — must stay silent, so downstream imports don't trip -W error."""
        import importlib
        import warnings

        from repro.fedsim import scaffold as scaffold_mod

        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            importlib.reload(scaffold_mod)

    def test_deprecation_warns_exactly_once(self, problem, monkeypatch):
        """The scaffold loop is deprecated in favor of the session engines;
        the warning fires on the FIRST call of a process only (a sweep over
        rounds must not spam per call) and names the migration target."""
        import warnings

        from repro.fedsim import scaffold as scaffold_mod

        monkeypatch.setattr(scaffold_mod, "_WARNED", False)
        data, w0 = problem
        cfg = DPScaffoldConfig(clip_norm=0.3, sigma=0.1, central=True,
                               num_clients=M)
        kw = dict(rounds=1, tau=1, eta_l=ETA_L, key=jax.random.PRNGKey(2))
        with pytest.warns(DeprecationWarning, match="run_dp_scaffold is "
                          "deprecated"):
            run_dp_scaffold(cfg, linreg_loss, w0, data.client_batches(), **kw)
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            run_dp_scaffold(cfg, linreg_loss, w0, data.client_batches(), **kw)


class TestDeterminism:
    def test_same_seed_same_result(self, problem):
        r1 = _run(problem, "ldp-fedexp-gauss", rounds=3, clip_norm=0.3, sigma=0.21)
        r2 = _run(problem, "ldp-fedexp-gauss", rounds=3, clip_norm=0.3, sigma=0.21)
        np.testing.assert_array_equal(np.asarray(r1.final_w), np.asarray(r2.final_w))
