"""Property-based tests (hypothesis) for the system's invariants."""
import math

import pytest

hypothesis = pytest.importorskip("hypothesis")

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.core import accounting as acc
from repro.core import mechanisms as mech
from repro.core import stepsize
from repro.core.aggregation import aggregate_stats
from repro.core.clipping import clip_batch, clip_by_l2, clip_tree, global_l2_norm_tree

SETTINGS = dict(deadline=None, max_examples=25,
                suppress_health_check=[hypothesis.HealthCheck.too_slow])

finite_f = st.floats(-1e3, 1e3, allow_nan=False, allow_infinity=False, width=32)


@st.composite
def update_matrix(draw, max_m=16, max_d=32):
    m = draw(st.integers(1, max_m))
    d = draw(st.integers(2, max_d))
    seed = draw(st.integers(0, 2**31 - 1))
    scale = draw(st.floats(1e-3, 1e3))
    return np.float32(scale) * np.asarray(
        jax.random.normal(jax.random.PRNGKey(seed), (m, d)))


class TestClipping:
    @given(u=update_matrix(), c=st.floats(1e-3, 1e2))
    @settings(**SETTINGS)
    def test_norm_bounded_and_direction_preserved(self, u, c):
        clipped = np.asarray(clip_batch(jnp.asarray(u), c))
        norms = np.linalg.norm(clipped, axis=-1)
        assert np.all(norms <= c * (1 + 1e-5))
        # direction preserved: clipped is a nonnegative multiple of u
        for i in range(u.shape[0]):
            nu = np.linalg.norm(u[i])
            if nu > 1e-6:
                cos = np.dot(clipped[i], u[i]) / (np.linalg.norm(clipped[i]) * nu + 1e-12)
                assert cos > 1 - 1e-4

    @given(u=update_matrix(), c=st.floats(1e-3, 1e2))
    @settings(**SETTINGS)
    def test_idempotent(self, u, c):
        once = clip_batch(jnp.asarray(u), c)
        twice = clip_batch(once, c)
        np.testing.assert_allclose(np.asarray(once), np.asarray(twice),
                                   rtol=1e-5, atol=1e-6)

    @given(u=update_matrix(max_m=4), c=st.floats(1e-2, 1e2))
    @settings(**SETTINGS)
    def test_tree_clip_matches_flat(self, u, c):
        """Clipping a pytree by global norm == clipping its flat concat."""
        tree = {"a": jnp.asarray(u[:, : u.shape[1] // 2]),
                "b": jnp.asarray(u[:, u.shape[1] // 2:])}
        clipped_tree, nrm = clip_tree(tree, c)
        flat = jnp.concatenate([u.reshape(-1)[: u.size]])
        want_norm = float(jnp.linalg.norm(jnp.asarray(u)))
        assert abs(float(nrm) - want_norm) < 1e-3 * max(1.0, want_norm)
        got = np.concatenate([np.asarray(clipped_tree["a"]).ravel(),
                              np.asarray(clipped_tree["b"]).ravel()])
        want = np.asarray(clip_by_l2(jnp.asarray(u).ravel(), c))
        np.testing.assert_allclose(np.sort(np.abs(got)), np.sort(np.abs(want)),
                                   rtol=1e-4, atol=1e-5)


class TestStepsizeInvariants:
    @given(u=update_matrix())
    @settings(**SETTINGS)
    def test_fedexp_ge_one_and_scale_invariant(self, u):
        s = aggregate_stats(jnp.asarray(u))
        eta = float(stepsize.fedexp(s.mean_sq, s.agg_sq))
        assert eta >= 1.0
        # eta is invariant to scaling all updates by the same c > 0
        s2 = aggregate_stats(jnp.asarray(3.7 * u))
        eta2 = float(stepsize.fedexp(s2.mean_sq, s2.agg_sq))
        assert abs(eta - eta2) < 1e-2 * max(1.0, eta)

    @given(u=update_matrix(), dim=st.integers(2, 1000), sigma=st.floats(1e-3, 10))
    @settings(**SETTINGS)
    def test_ldp_rule_ge_one(self, u, dim, sigma):
        s = aggregate_stats(jnp.asarray(u))
        eta = float(stepsize.ldp_gaussian(s.mean_sq, s.agg_sq, dim, sigma))
        assert eta >= 1.0
        assert math.isfinite(eta)

    @given(u=update_matrix(), xi=finite_f)
    @settings(**SETTINGS)
    def test_cdp_rule_ge_one(self, u, xi):
        s = aggregate_stats(jnp.asarray(u))
        eta = float(stepsize.cdp(s.mean_sq, jnp.float32(xi), s.agg_sq))
        assert eta >= 1.0


class TestAggregationInvariants:
    @given(u=update_matrix())
    @settings(**SETTINGS)
    def test_cauchy_schwarz(self, u):
        """||cbar||^2 <= mean ||c_i||^2 (why eta >= 1 is achievable)."""
        s = aggregate_stats(jnp.asarray(u))
        assert float(s.agg_sq) <= float(s.mean_sq) * (1 + 1e-4) + 1e-6

    @given(u=update_matrix())
    @settings(**SETTINGS)
    def test_mean_linearity(self, u):
        s = aggregate_stats(jnp.asarray(u))
        np.testing.assert_allclose(np.asarray(s.cbar), u.mean(0), rtol=1e-4, atol=1e-4)


class TestAccountingInvariants:
    @given(mu=st.floats(0.01, 50), delta=st.floats(1e-9, 0.4))
    @settings(**SETTINGS)
    def test_gdp_roundtrip(self, mu, delta):
        eps = acc.gdp_epsilon(mu, delta)
        if math.isfinite(eps) and eps > 0.0:
            assert abs(acc.gdp_delta(mu, eps) - delta) < 1e-6 * max(1.0, delta)
        else:
            # eps = 0 already satisfies the target delta
            assert acc.gdp_delta(mu, 0.0) <= delta * (1 + 1e-9)

    @given(c=st.floats(0.01, 10), s1=st.floats(0.1, 5), ratio=st.floats(1.1, 10))
    @settings(**SETTINGS)
    def test_eps_monotone_in_sigma(self, c, s1, ratio):
        e_low_noise = acc.ldp_gaussian_budget(c, s1, 1e-5).eps_numerical
        e_high_noise = acc.ldp_gaussian_budget(c, s1 * ratio, 1e-5).eps_numerical
        assert e_high_noise <= e_low_noise + 1e-9


class TestScalarDPProperties:
    @given(r=st.floats(0.0, 1.0), eps2=st.floats(0.5, 6.0), seed=st.integers(0, 2**31 - 1))
    @settings(**SETTINGS)
    def test_output_always_on_lattice(self, r, eps2, seed):
        sc = mech.make_scalardp_params(eps2, 1.0)
        out = float(mech.scalardp_magnitude(jax.random.PRNGKey(seed), jnp.float32(r), sc))
        j = out / sc.a + sc.b
        assert abs(j - round(j)) < 1e-3
        assert 0 <= round(j) <= sc.k

    @given(eps2=st.floats(0.5, 6.0))
    @settings(**SETTINGS)
    def test_debias_constants_positive(self, eps2):
        sc = mech.make_scalardp_params(eps2, 1.0)
        assert sc.a > 0 and sc.b >= 0 and sc.c1 > 0 and sc.c3 > 0


class TestSafePspec:
    @given(dim=st.integers(1, 4096), axes=st.sampled_from(["model", "data", None]))
    @settings(**SETTINGS)
    def test_divisibility_respected(self, dim, axes):
        import jax as _jax
        from repro.launch.rules import safe_pspec
        mesh = _jax.make_mesh((1, 1), ("data", "model"))
        rules = {"x": axes}
        spec = safe_pspec((dim,), ("x",), rules, mesh)
        # axis sizes are 1 here, so everything divides; just structural checks
        assert len(spec) <= 1

    def test_drops_non_dividing_axis(self):
        import jax as _jax
        from repro.launch.rules import safe_pspec
        # simulate 16-way axis with a fake mesh via devices reshape is not
        # possible on 1 CPU; use the sizes logic directly instead.
        from repro.launch import rules as r
        mesh = _jax.make_mesh((1, 1), ("data", "model"))
        sizes = r._axis_sizes(mesh)
        assert sizes == {"data": 1, "model": 1}
