"""Tests for the beyond-paper extensions: adaptive clipping, FedOpt servers."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import adaptive_clip as ac
from repro.core.fedexp import make_algorithm
from repro.data.synthetic import distance_to_opt, linreg_loss, make_synthetic_linreg
from repro.fedsim import FederatedSession, TrainSpec


class TestAdaptiveClip:
    def test_converges_to_quantile(self):
        """C tracks the gamma-quantile of stationary norms."""
        cfg = ac.AdaptiveClipConfig(gamma=0.5, lr=0.3, sigma_b=0.0)
        norms = jnp.asarray(np.random.default_rng(0).lognormal(0.0, 0.5, size=512),
                            jnp.float32)
        true_median = float(jnp.median(norms))
        state = ac.init_state(10.0)  # start far above
        for t in range(60):
            state, _ = ac.update_clip(jax.random.PRNGKey(t), state, norms, cfg)
        assert abs(float(state.clip) - true_median) / true_median < 0.15

    def test_noise_robust(self):
        cfg = ac.AdaptiveClipConfig(gamma=0.5, lr=0.2, sigma_b=10.0)
        norms = jnp.ones(200) * 2.0
        state = ac.init_state(0.1)
        for t in range(80):
            state, _ = ac.update_clip(jax.random.PRNGKey(t), state, norms, cfg)
        # all norms equal 2.0: C should hover near 2 (quantile boundary)
        assert 0.5 < float(state.clip) < 8.0

    def test_bounds_respected(self):
        cfg = ac.AdaptiveClipConfig(gamma=0.99, lr=5.0, sigma_b=0.0, c_min=0.01, c_max=5.0)
        state = ac.init_state(1.0)
        for t in range(50):
            state, _ = ac.update_clip(jax.random.PRNGKey(t), state,
                                      jnp.full((16,), 100.0), cfg)
        assert 0.01 <= float(state.clip) <= 5.0

    def test_budget_rate(self):
        # sigma_b=10, T=50 -> rho=0.25, small next to the paper's main release
        assert ac.adaptive_clip_rho(10.0, 50) == pytest.approx(0.25)


class TestAdaptiveClipFedEXP:
    def test_trains_and_tracks_quantile(self):
        """The combined algorithm: C adapts, eta >= 1, model improves."""
        m, d = 128, 40
        data = make_synthetic_linreg(jax.random.PRNGKey(4), m, d)
        # sane starting C (Andrew et al. start small: with sigma = z*C an
        # oversized C0 floods the release with noise before C descends)
        alg = make_algorithm("cdp-fedexp-adaptive-clip", z_mult=5 / math.sqrt(m),
                             num_clients=m, dim=d, c0=1.0)
        r = FederatedSession(alg, linreg_loss, jnp.zeros(d), data.client_batches(),
                             train=TrainSpec(rounds=12, tau=10, eta_l=0.1),
                             eval_fn=distance_to_opt(data.w_star)).run(jax.random.PRNGKey(5))
        hist = np.asarray(r.metric_history)
        assert np.all(np.isfinite(hist))
        assert hist[-1] < hist[0]
        assert float(jnp.min(r.eta_history)) >= 1.0

    def test_clip_state_descends_from_oversized_start(self):
        m, d = 64, 20
        data = make_synthetic_linreg(jax.random.PRNGKey(6), m, d)
        alg = make_algorithm("cdp-fedexp-adaptive-clip", z_mult=0.1,
                             num_clients=m, dim=d, c0=100.0)
        state = alg.init_state(jnp.zeros(d))
        from repro.fedsim.local import cohort_updates
        w = jnp.zeros(d)
        for t in range(15):
            deltas = cohort_updates(linreg_loss, w, data.client_batches(), 10, 0.1)
            w, aux, state = alg.apply_round_stateful(
                jax.random.PRNGKey(100 + t), w, deltas, state)
        assert float(state.clip) < 50.0  # pulled down toward the norm quantile


class TestFedOptServers:
    def test_dp_fedadam_trains(self):
        m, d = 100, 30
        data = make_synthetic_linreg(jax.random.PRNGKey(0), m, d)
        alg = make_algorithm("dp-fedadam-cdp", clip_norm=0.3,
                             sigma=5 * 0.3 / math.sqrt(m), num_clients=m,
                             server_lr=0.05)
        r = FederatedSession(alg, linreg_loss, jnp.zeros(d), data.client_batches(),
                             train=TrainSpec(rounds=10, tau=10, eta_l=0.1),
                             eval_fn=distance_to_opt(data.w_star)).run(jax.random.PRNGKey(1))
        hist = np.asarray(r.metric_history)
        assert np.all(np.isfinite(hist))
        assert hist[-1] < hist[0]  # makes progress

    def test_stateless_wrapper_unchanged(self):
        """Existing stateless algorithms still run through the stateful loop."""
        m, d = 64, 16
        data = make_synthetic_linreg(jax.random.PRNGKey(2), m, d)
        alg = make_algorithm("cdp-fedexp", clip_norm=0.3,
                             sigma=5 * 0.3 / math.sqrt(m), num_clients=m)
        assert alg.init_state(jnp.zeros(d)) == ()
        r = FederatedSession(alg, linreg_loss, jnp.zeros(d), data.client_batches(),
                             train=TrainSpec(rounds=3, tau=5, eta_l=0.1),
                             eval_fn=distance_to_opt(data.w_star)).run(jax.random.PRNGKey(3))
        assert np.all(np.isfinite(np.asarray(r.metric_history)))

    def test_stateful_misuse_guard(self):
        alg = make_algorithm("dp-fedadam-cdp", clip_norm=1.0, sigma=0.1,
                             num_clients=4, server_lr=0.1)
        with pytest.raises(TypeError):
            alg.apply_round(jax.random.PRNGKey(0), jnp.zeros(4), jnp.zeros((4, 4)))
