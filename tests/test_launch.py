"""Launch-layer tests: rules, specs, serve engine, optim, checkpoint, hlo_cost."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro import optim
from repro.configs import ARCHS, SHAPES, FederatedConfig, reduced
from repro.launch import specs as specs_mod
from repro.launch.hlo_cost import hlo_cost, parse_hlo
from repro.launch.rules import count_params, is_giant, make_rules, safe_pspec
from repro.launch.serve import ServeEngine
from repro.models.transformer import DecoderLM


class TestRules:
    def test_giant_classification(self):
        sizes = {}
        for name in ("command-r-plus-104b", "llama4-maverick-400b-a17b", "gemma-2b",
                     "mamba2-2.7b"):
            cfg = ARCHS[name]
            model = DecoderLM(cfg, dtype=jnp.bfloat16)
            sizes[name] = count_params(model)
        assert is_giant(ARCHS["command-r-plus-104b"], sizes["command-r-plus-104b"])
        assert is_giant(ARCHS["llama4-maverick-400b-a17b"],
                        sizes["llama4-maverick-400b-a17b"])
        assert not is_giant(ARCHS["gemma-2b"], sizes["gemma-2b"])
        # assigned sizes are in the right ballpark
        assert 90e9 < sizes["command-r-plus-104b"] < 120e9
        # the assignment pins MoE-128e in EVERY layer (Maverick itself
        # interleaves MoE/dense); the literal config is ~780B total, ~17B active
        assert 600e9 < sizes["llama4-maverick-400b-a17b"] < 900e9
        assert 2e9 < sizes["mamba2-2.7b"] < 3.5e9

    def test_param_counts_all_archs(self):
        """Every full config's parameter count is within its nameplate band."""
        from repro.models.encdec import EncDecLM
        bands = {
            "gemma-2b": (2.0e9, 3.2e9),
            "h2o-danube-3-4b": (3.0e9, 4.5e9),
            "granite-8b": (7e9, 9e9),
            "granite-moe-1b-a400m": (0.8e9, 1.6e9),
            "zamba2-2.7b": (2.2e9, 3.5e9),
            "chameleon-34b": (30e9, 38e9),
            "whisper-large-v3": (1.2e9, 2.2e9),
        }
        for name, (lo, hi) in bands.items():
            cfg = ARCHS[name]
            model = (EncDecLM if cfg.arch_type == "audio" else DecoderLM)(cfg, dtype=jnp.bfloat16)
            n = count_params(model)
            assert lo < n < hi, (name, n)

    def test_make_rules_modes(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        cfg = ARCHS["gemma-2b"]
        r_train = make_rules(cfg, mesh, mode="train", num_params=2.5e9)
        assert r_train["clients"] == "data"
        r_serve = make_rules(cfg, mesh, mode="serve", num_params=2.5e9)
        assert r_serve["clients"] is None and r_serve["batch"] == "data"
        r_giant = make_rules(cfg, mesh, mode="train", num_params=1e11)
        assert r_giant["clients"] is None and r_giant["embed"] == "data"


class TestSpecs:
    def test_train_specs_shapes(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        cfg = ARCHS["gemma-2b"]
        fed = FederatedConfig(local_steps=2)
        rules = make_rules(cfg, mesh, mode="train", num_params=2.5e9)
        shapes, logical = specs_mod.train_input_specs(cfg, SHAPES["train_4k"], fed, mesh, rules)
        k = specs_mod.cohort_size(mesh, rules)
        assert shapes["tokens"].shape == (k, 2, 256 // k, 4096)
        assert shapes["tokens"].dtype == jnp.int32

    def test_decode_specs_cache(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        cfg = reduced(ARCHS["mamba2-2.7b"])
        model = DecoderLM(cfg)
        rules = make_rules(cfg, mesh, mode="serve", num_params=1e8)
        shapes, logical = specs_mod.decode_input_specs(
            cfg, SHAPES["decode_32k"], mesh, rules, model)
        assert shapes["token"].shape == (128,)
        caches = shapes["caches"]["blocks"]
        assert caches["state"].shape[0] == cfg.num_layers


class TestServeEngine:
    def test_greedy_generate(self):
        cfg = reduced(ARCHS["granite-8b"], d_model=128)
        model = DecoderLM(cfg, attn_impl="dense", remat=False)
        params = model.init(jax.random.PRNGKey(0))
        engine = ServeEngine(model)
        prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size)
        out = engine.generate(params, prompt, max_new=5, cache_len=16, dtype=jnp.float32)
        assert out.shape == (2, 5)
        assert out.dtype == jnp.int32
        assert np.all((np.asarray(out) >= 0) & (np.asarray(out) < cfg.vocab_size))

    def test_generate_deterministic(self):
        cfg = reduced(ARCHS["granite-8b"], d_model=128)
        model = DecoderLM(cfg, attn_impl="dense", remat=False)
        params = model.init(jax.random.PRNGKey(0))
        engine = ServeEngine(model)
        prompt = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab_size)
        o1 = engine.generate(params, prompt, max_new=4, cache_len=16, dtype=jnp.float32)
        o2 = engine.generate(params, prompt, max_new=4, cache_len=16, dtype=jnp.float32)
        np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))


class TestOptim:
    def test_sgd_identity(self):
        opt = optim.sgd(1.0)
        g = {"w": jnp.ones(3)}
        step, _ = opt.update(g, opt.init(g))
        np.testing.assert_array_equal(np.asarray(step["w"]), np.ones(3))

    def test_adam_step_bounded(self):
        opt = optim.adam(lr=0.1)
        g = {"w": 100.0 * jnp.ones(4)}
        state = opt.init(g)
        step, state = opt.update(g, state)
        # adam normalizes: |step| ~ lr regardless of gradient scale
        assert np.all(np.abs(np.asarray(step["w"])) < 0.2)

    def test_momentum_accumulates(self):
        opt = optim.momentum(lr=1.0, beta=0.5)
        g = {"w": jnp.ones(2)}
        state = opt.init(g)
        s1, state = opt.update(g, state)
        s2, state = opt.update(g, state)
        assert float(s2["w"][0]) > float(s1["w"][0])

    def test_apply_update_dtype_preserved(self):
        p = {"w": jnp.ones(2, jnp.bfloat16)}
        out = optim.apply_update(p, {"w": jnp.ones(2, jnp.float32)})
        assert out["w"].dtype == jnp.bfloat16


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        cfg = reduced(ARCHS["gemma-2b"], d_model=128)
        model = DecoderLM(cfg, remat=False)
        params = model.init(jax.random.PRNGKey(0))
        d = str(tmp_path)
        ckpt.save_checkpoint(d, 7, params, extra={"eta": 1.5})
        restored, meta = ckpt.load_checkpoint(d, params)
        assert meta["step"] == 7 and meta["eta"] == 1.5
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_latest_step(self, tmp_path):
        d = str(tmp_path)
        assert ckpt.latest_step(d) is None
        ckpt.save_checkpoint(d, 3, {"w": jnp.ones(2)})
        ckpt.save_checkpoint(d, 11, {"w": jnp.ones(2)})
        assert ckpt.latest_step(d) == 11


class TestHloCost:
    def test_matmul_flops(self):
        """jit a plain matmul; the walker should count 2*m*n*k flops."""
        m, k, n = 64, 32, 48
        f = jax.jit(lambda a, b: a @ b)
        a = jnp.ones((m, k))
        b = jnp.ones((k, n))
        txt = f.lower(a, b).compile().as_text()
        c = hlo_cost(txt)
        assert c["flops"] == 2 * m * n * k

    def test_loop_multiplication(self):
        """fori_loop body flops are multiplied by the trip count."""
        m = 32
        trip = 7

        def body(x):
            return jax.lax.fori_loop(0, trip, lambda i, h: h @ h, x)

        x = jnp.eye(m)
        txt = jax.jit(body).lower(x).compile().as_text()
        c = hlo_cost(txt)
        assert c["flops"] == trip * 2 * m**3
        assert c["unknown_loops"] == 0

    def test_scan_layers(self):
        """lax.scan over stacked layer params multiplies like the layer count."""
        layers, d = 5, 16
        ws = jnp.stack([jnp.eye(d)] * layers)

        def f(x, ws):
            def step(h, w):
                return h @ w, None
            h, _ = jax.lax.scan(step, x, ws)
            return h

        txt = jax.jit(f).lower(jnp.ones((d, d)), ws).compile().as_text()
        c = hlo_cost(txt)
        assert c["flops"] == layers * 2 * d**3

    def test_parse_structure(self):
        txt = jax.jit(lambda a: a @ a).lower(jnp.ones((8, 8))).compile().as_text()
        comps, entry = parse_hlo(txt)
        assert entry is not None
        assert entry in comps
