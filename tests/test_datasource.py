"""Host-resident client data: ClientDataSource contract (DESIGN.md §14).

The source protocol decouples WHERE client data lives from the engines that
consume it.  Contracts pinned here:

* ``ArraySource`` (the in-memory default) unwraps to the historical
  device-resident engine — literally the same compiled program, bit-exact;
* host/npz/synthetic sources stream chunk-staged data through the §12 inner
  accumulation in the identical order, matching device-resident runs at the
  engine-parity tolerance (rtol 1e-5; within 1 ulp in practice — the chunk
  add fuses differently across the two programs, see DESIGN.md §14) while
  the STAGING itself is bit-invariant: prefetch depth, source kind, and
  double-buffering never change a single bit;
* kill/resume through a host-resident run reproduces the uninterrupted run
  bit-for-bit (host round keys are the same ``fold_in(key, t)``);
* the session rejects source configurations it cannot honor (non-stream
  engines, client meshes, fault injection, contradictory DataSpec kinds)
  rather than silently mis-staging.
"""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fedexp import make_algorithm
from repro.data.synthetic import linreg_loss, make_synthetic_linreg
from repro.fedsim import (
    ArraySource,
    CohortSpec,
    DataSpec,
    EngineSpec,
    FaultSpec,
    FederatedSession,
    HostArraySource,
    NpzSource,
    ShardSpec,
    StreamSpec,
    SyntheticSource,
    TrainSpec,
)
from repro.launch.mesh import auto_chunk_clients, make_client_mesh

M, D, TAU, ETA_L, ROUNDS, CHUNK = 44, 24, 2, 0.1, 4, 16
KEY = jax.random.PRNGKey(11)


@pytest.fixture(scope="module")
def problem():
    data = make_synthetic_linreg(jax.random.PRNGKey(3), M, D)
    return data.client_batches(), jnp.zeros(D)


def _host_batches(batches):
    return {k: np.asarray(v) for k, v in batches.items()}


def _session(batches, w0, *, rounds=ROUNDS, **kw):
    alg = make_algorithm("ldp-fedexp-gauss", clip_norm=0.3, sigma=0.21)
    kw.setdefault("engine", EngineSpec(engine="stream"))
    kw.setdefault("stream", StreamSpec(chunk_clients=CHUNK))
    return FederatedSession(alg, linreg_loss, w0, batches,
                            train=TrainSpec(rounds=rounds, tau=TAU,
                                            eta_l=ETA_L), **kw)


class TestSourceContract:
    def test_fetch_arbitrary_indices(self, problem):
        """fetch() serves non-monotone indices with repeats — the §14 gather
        path fetches by slot table."""
        batches, _ = problem
        idx = np.asarray([5, 2, 2, 41, 0])
        for src in (ArraySource(batches), HostArraySource(batches)):
            rows = src.fetch(idx)
            np.testing.assert_array_equal(np.asarray(rows["x"]),
                                          np.asarray(batches["x"])[idx])
            assert src.num_clients == M

    def test_npz_round_trip(self, problem):
        batches, _ = problem
        with tempfile.TemporaryDirectory() as tmp:
            path = os.path.join(tmp, "cohort.npz")
            np.savez(path, **_host_batches(batches))
            src = NpzSource(path)
            assert src.num_clients == M
            assert src.kind == "npz"
            rows = src.fetch(np.asarray([3, 1]))
            np.testing.assert_array_equal(
                np.asarray(rows["y"]), np.asarray(batches["y"])[[3, 1]])

    def test_synthetic_source_is_index_pure(self):
        def gen(idx):
            rng = [np.random.default_rng(1000 + int(i)) for i in idx]
            return {"x": np.stack([r.normal(size=(D,)) for r in rng]),
                    "y": np.zeros(len(idx))}

        src = SyntheticSource(gen, num_clients=10**6)
        a = src.fetch(np.asarray([7, 123456]))
        b = src.fetch(np.asarray([7, 123456]))
        np.testing.assert_array_equal(a["x"], b["x"])
        with pytest.raises(ValueError, match="num_clients"):
            SyntheticSource(gen, num_clients=0)

    def test_mismatched_leading_dims_rejected(self):
        with pytest.raises(ValueError, match="leading"):
            HostArraySource({"x": np.zeros((4, 2)), "y": np.zeros((5,))})


class TestArraySourcePassthrough:
    def test_bit_exact_with_raw_arrays(self, problem):
        """ArraySource unwraps to the device-resident path: the IDENTICAL
        compiled program, bit-for-bit — on the default scan engine too."""
        batches, w0 = problem
        for engine_kw in ({"engine": EngineSpec(), "stream": StreamSpec()},
                          {"engine": EngineSpec(engine="stream"),
                           "stream": StreamSpec(chunk_clients=CHUNK)}):
            raw = _session(batches, w0, **engine_kw).run(KEY)
            wrapped = _session(ArraySource(batches), w0, **engine_kw).run(KEY)
            np.testing.assert_array_equal(np.asarray(raw.final_w),
                                          np.asarray(wrapped.final_w))
            np.testing.assert_array_equal(np.asarray(raw.eta_history),
                                          np.asarray(wrapped.eta_history))


class TestHostResidentRuns:
    def test_matches_device_resident_stream(self, problem):
        batches, w0 = problem
        dev = _session(batches, w0).run(KEY)
        host = _session(HostArraySource(batches), w0).run(KEY)
        np.testing.assert_allclose(np.asarray(host.final_w),
                                   np.asarray(dev.final_w),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(host.eta_history),
                                   np.asarray(dev.eta_history),
                                   rtol=1e-5, atol=1e-6)

    def test_single_chunk_is_bit_exact_with_device(self, problem):
        """One chunk covering the cohort: staging degenerates to one
        device_put and the arithmetic is the identical accumulation."""
        batches, w0 = problem
        dev = _session(batches, w0, stream=StreamSpec(chunk_clients=64)).run(KEY)
        host = _session(HostArraySource(batches), w0,
                        stream=StreamSpec(chunk_clients=64)).run(KEY)
        np.testing.assert_array_equal(np.asarray(host.final_w),
                                      np.asarray(dev.final_w))

    def test_prefetch_depth_is_bit_invariant(self, problem):
        """The double-buffer contract: staging depth changes WHEN transfers
        happen, never WHAT is computed — bit-for-bit across depths."""
        batches, w0 = problem
        runs = [
            _session(HostArraySource(batches), w0,
                     data=DataSpec(kind="host", prefetch=depth)).run(KEY)
            for depth in (1, 2, 4)
        ]
        for other in runs[1:]:
            np.testing.assert_array_equal(np.asarray(runs[0].final_w),
                                          np.asarray(other.final_w))
            np.testing.assert_array_equal(np.asarray(runs[0].eta_history),
                                          np.asarray(other.eta_history))

    def test_source_kind_is_bit_invariant(self, problem):
        """host / npz / synthetic sources serving the same rows produce the
        same bits — the driver is source-blind past fetch()."""
        batches, w0 = problem
        hb = _host_batches(batches)
        host = _session(HostArraySource(batches), w0).run(KEY)
        with tempfile.TemporaryDirectory() as tmp:
            path = os.path.join(tmp, "cohort.npz")
            np.savez(path, **hb)
            npz = _session(NpzSource(path), w0).run(KEY)
        synth = _session(
            SyntheticSource(lambda idx: {k: v[idx] for k, v in hb.items()},
                            num_clients=M), w0).run(KEY)
        for other in (npz, synth):
            np.testing.assert_array_equal(np.asarray(host.final_w),
                                          np.asarray(other.final_w))

    def test_sampled_gather_matches_dense_reference(self, problem):
        """Host-resident × §14 gather: only ~cap clients are ever fetched,
        and the release matches the dense sampled device run."""
        batches, w0 = problem
        fetched = []

        def spy(idx):
            fetched.append(np.asarray(idx))
            return {k: np.asarray(v)[idx] for k, v in batches.items()}

        dense = _session(batches, w0, engine=EngineSpec(),
                         stream=StreamSpec(),
                         cohort=CohortSpec(q=0.4)).run(KEY)
        host = _session(SyntheticSource(spy, num_clients=M), w0,
                        cohort=CohortSpec(q=0.4, gather=True),
                        stream=StreamSpec(chunk_clients=8)).run(KEY)
        np.testing.assert_allclose(np.asarray(host.final_w),
                                   np.asarray(dense.final_w),
                                   rtol=1e-5, atol=1e-6)
        cap = CohortSpec(q=0.4, gather=True).resolved_cap(M)
        per_round = sum(len(i) for i in fetched) / ROUNDS
        assert per_round <= -(-cap // 8) * 8  # slot grid, not the cohort

    def test_kill_resume_bit_exact(self, problem):
        """Checkpoint/resume drives the host driver through the same carry
        machinery: a killed host-resident run resumes bit-for-bit."""
        batches, w0 = problem
        src = HostArraySource(batches)

        with tempfile.TemporaryDirectory() as tmp:
            full = _session(src, w0).run(KEY, checkpoint_dir=tmp + "/full",
                                         checkpoint_every=2)
            _session(src, w0, rounds=2).run(
                KEY, checkpoint_dir=tmp + "/killed", checkpoint_every=2)
            resumed = _session(src, w0).resume(tmp + "/killed")
        np.testing.assert_array_equal(np.asarray(resumed.final_w),
                                      np.asarray(full.final_w))
        np.testing.assert_array_equal(np.asarray(resumed.eta_history),
                                      np.asarray(full.eta_history))

    def test_run_batched_sweeps_host_session(self, problem):
        batches, w0 = problem
        session = _session(HostArraySource(batches), w0, rounds=2)
        keys = jax.random.split(jax.random.PRNGKey(5), 2)
        batched = session.run_batched(keys)
        single = session.run(keys[1])
        np.testing.assert_array_equal(np.asarray(batched.final_w[1]),
                                      np.asarray(single.final_w))


class TestSessionValidation:
    def test_source_requires_stream_engine(self, problem):
        batches, w0 = problem
        with pytest.raises(ValueError, match="engine='stream'"):
            _session(HostArraySource(batches), w0, engine=EngineSpec(),
                     stream=StreamSpec())

    def test_source_rejects_client_mesh(self, problem):
        batches, w0 = problem
        with pytest.raises(ValueError, match="mesh"):
            _session(HostArraySource(batches), w0,
                     shard=ShardSpec(mesh=make_client_mesh(),
                                     client_axis="clients"))

    def test_source_rejects_fault_injection(self, problem):
        batches, w0 = problem
        with pytest.raises(ValueError, match="fault"):
            _session(HostArraySource(batches), w0,
                     fault=FaultSpec(dropout=0.2))

    def test_dataspec_kind_must_match_input(self, problem):
        batches, w0 = problem
        with pytest.raises(ValueError, match="contradicts"):
            _session(batches, w0, data=DataSpec(kind="host"))
        with pytest.raises(ValueError, match="contradicts"):
            _session(HostArraySource(batches), w0,
                     data=DataSpec(kind="npz"))

    def test_dataspec_validation(self):
        with pytest.raises(ValueError, match="kind"):
            DataSpec(kind="carrier-pigeon")
        with pytest.raises(ValueError, match="prefetch"):
            DataSpec(prefetch=0)


class TestAutoChunk:
    def test_session_resolves_auto(self, problem):
        batches, w0 = problem
        session = _session(batches, w0, stream=StreamSpec(chunk_clients="auto"))
        assert isinstance(session.stream.chunk_clients, int)
        assert session.stream.chunk_clients >= 1
        out = session.run(KEY)
        dense = _session(batches, w0, stream=StreamSpec(chunk_clients=64)).run(KEY)
        np.testing.assert_allclose(np.asarray(out.final_w),
                                   np.asarray(dense.final_w),
                                   rtol=1e-5, atol=1e-6)

    def test_auto_spec_rejected_off_stream(self, problem):
        batches, w0 = problem
        with pytest.raises(ValueError, match="stream"):
            _session(batches, w0, engine=EngineSpec(),
                     stream=StreamSpec(chunk_clients="auto"))

    def test_heuristic_scales_with_budget(self):
        small = auto_chunk_clients(D, 100, budget_bytes=1 << 20)
        large = auto_chunk_clients(D, 100, budget_bytes=1 << 24)
        assert 1 <= small < large

    def test_actionable_error_when_one_client_cannot_fit(self):
        with pytest.raises(ValueError, match="chunk_clients=1"):
            auto_chunk_clients(dim=10**6, client_bytes=0, budget_bytes=1024)

    def test_spec_validates_auto_literal(self):
        assert StreamSpec(chunk_clients="auto").is_auto
        with pytest.raises(ValueError, match="auto"):
            StreamSpec(chunk_clients="automatic")
