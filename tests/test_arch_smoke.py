"""Per-architecture smoke tests (REQUIRED: reduced variant, one step, no NaNs).

Every assigned architecture instantiates a reduced same-family config
(2 layers, d_model <= 512, <= 4 experts) and runs one forward/train step on
CPU, asserting output shapes and finiteness. Decode-capable archs also check
prefill->decode consistency against the full forward pass.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, FederatedConfig, reduced
from repro.launch.rules import count_params
from repro.launch.train import FederatedTrainer
from repro.models.encdec import EncDecLM
from repro.models.transformer import DecoderLM

ALL_ARCHS = sorted(ARCHS)
DECODER_ARCHS = [a for a in ALL_ARCHS if ARCHS[a].arch_type != "audio"]


def _build(name, d_model=256, drop_free_moe=False):
    cfg = reduced(ARCHS[name], layers=2, d_model=d_model)
    if drop_free_moe and cfg.num_experts:
        # capacity drops depend on the token count, so prefill (few tokens)
        # and full forward (all tokens) can drop differently; a high capacity
        # factor makes routing drop-free and the comparison exact.
        import dataclasses
        cfg = dataclasses.replace(cfg, capacity_factor=16.0)
    if cfg.arch_type == "audio":
        return cfg, EncDecLM(cfg, attn_impl="dense", remat=False)
    return cfg, DecoderLM(cfg, attn_impl="dense", remat=False)


def _batch(cfg, key, b=2, s=24):
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    return toks


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_forward_loss_finite(name):
    cfg, model = _build(name)
    params = model.init(jax.random.PRNGKey(0))
    assert cfg.d_model <= 512 and cfg.num_layers <= 2
    if cfg.num_experts:
        assert cfg.num_experts <= 4
    toks = _batch(cfg, jax.random.PRNGKey(1))
    if cfg.arch_type == "audio":
        frames = jax.random.normal(jax.random.PRNGKey(2), (2, 20, cfg.d_model))
        loss = model.loss(params, frames, toks, toks)
    else:
        loss = model.loss(params, toks, toks)
    assert loss.shape == ()
    assert np.isfinite(float(loss))
    assert 1.0 < float(loss) < 20.0  # ~log(vocab) at init


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_one_federated_train_step(name):
    """One DP-FedEXP round on the reduced arch: finite metrics, eta_g >= 1."""
    cfg, model = _build(name)
    fed = FederatedConfig(algorithm="cdp-fedexp", local_steps=2, local_lr=0.05,
                          clip_norm=1.0, noise_sigma=0.01)
    trainer = FederatedTrainer(model, fed, count_params(model))
    step = jax.jit(trainer.make_train_step(cohort_k=2))
    params = model.init(jax.random.PRNGKey(0))
    k, tau, b, s = 2, fed.local_steps, 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (k, tau, b, s), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.arch_type == "audio":
        batch["frames"] = jax.random.normal(jax.random.PRNGKey(2), (k, tau, b, 12, cfg.d_model))
    new_params, metrics = step(params, batch, jax.random.PRNGKey(3))
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["eta_g"]) >= 1.0
    for leaf in jax.tree_util.tree_leaves(new_params):
        assert np.all(np.isfinite(np.asarray(leaf, np.float32)))
    # parameters actually moved
    moved = any(
        not np.allclose(np.asarray(a, np.float32), np.asarray(b_, np.float32))
        for a, b_ in zip(jax.tree_util.tree_leaves(params),
                         jax.tree_util.tree_leaves(new_params)))
    assert moved


@pytest.mark.parametrize("name", DECODER_ARCHS)
def test_prefill_decode_matches_forward(name):
    """Teacher-forced decode through the cache == full forward logits."""
    cfg, model = _build(name, drop_free_moe=True)
    params = model.init(jax.random.PRNGKey(0))
    b, s = 2, 16
    toks = _batch(cfg, jax.random.PRNGKey(1), b, s)

    h, _ = model.forward(params, toks)
    from repro.models.common import rms_norm
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    full_logits = model.logits(params, h)  # (B, S, V)

    split = s // 2
    caches = model.init_cache(b, s, dtype=jnp.float32)
    logits_p, caches = model.prefill(params, toks[:, :split], caches)
    np.testing.assert_allclose(np.asarray(logits_p), np.asarray(full_logits[:, split - 1]),
                               rtol=2e-3, atol=2e-3)
    logits_d = logits_p
    for t in range(split, s):
        logits_d, caches = model.decode_step(params, toks[:, t], jnp.int32(t), caches)
        np.testing.assert_allclose(np.asarray(logits_d), np.asarray(full_logits[:, t]),
                                   rtol=2e-3, atol=2e-3)


def test_whisper_decode_consistency():
    cfg, model = _build("whisper-large-v3")
    params = model.init(jax.random.PRNGKey(0))
    b, s = 2, 12
    frames = jax.random.normal(jax.random.PRNGKey(1), (b, 18, cfg.d_model))
    toks = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0, cfg.vocab_size)
    enc = model.encode(params, frames)
    full_logits, _ = model.decode(params, toks, enc)

    caches = model.init_cache(b, s, dtype=jnp.float32)
    _, caches = model.decode(params, toks[:, : s // 2], enc, caches=caches)
    for t in range(s // 2, s):
        logits_d, caches = model.decode_step(params, toks[:, t], jnp.int32(t), enc, caches)
        np.testing.assert_allclose(np.asarray(logits_d), np.asarray(full_logits[:, t]),
                                   rtol=2e-3, atol=2e-3)


def test_sliding_window_ring_cache():
    """h2o-danube3's SWA ring cache: decode equals forward past the window."""
    cfg, model = _build("h2o-danube-3-4b")
    assert cfg.sliding_window == 64
    # sequence longer than the reduced window would need s > 64; use a smaller
    # window to exercise the ring wrap cheaply.
    import dataclasses
    cfg = dataclasses.replace(cfg, sliding_window=8)
    model = DecoderLM(cfg, attn_impl="dense", remat=False)
    params = model.init(jax.random.PRNGKey(0))
    b, s = 1, 24
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab_size)
    h, _ = model.forward(params, toks)
    from repro.models.common import rms_norm
    full_logits = model.logits(params, rms_norm(h, params["final_norm"], cfg.norm_eps))

    caches = model.init_cache(b, s, dtype=jnp.float32)
    assert caches["blocks"]["k"].shape[2] == 8  # ring of window slots
    logits_p, caches = model.prefill(params, toks[:, :8], caches)
    for t in range(8, s):
        logits_d, caches = model.decode_step(params, toks[:, t], jnp.int32(t), caches)
        np.testing.assert_allclose(np.asarray(logits_d), np.asarray(full_logits[:, t]),
                                   rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("name", ["gemma-2b", "granite-moe-1b-a400m", "mamba2-2.7b"])
def test_bf16_forward(name):
    cfg, model = _build(name)
    model.dtype = jnp.bfloat16
    params = model.init(jax.random.PRNGKey(0))
    toks = _batch(cfg, jax.random.PRNGKey(1))
    loss = model.loss(params, toks, toks)
    assert np.isfinite(float(loss))


def test_full_configs_match_assignment():
    """The FULL configs carry the exact assigned hyperparameters."""
    a = ARCHS
    g = a["gemma-2b"]
    assert (g.num_layers, g.d_model, g.num_heads, g.num_kv_heads, g.d_ff,
            g.vocab_size, g.head_dim) == (18, 2048, 8, 1, 16384, 256000, 256)
    c = a["command-r-plus-104b"]
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size) == (64, 12288, 96, 8, 33792, 256000)
    m = a["granite-moe-1b-a400m"]
    assert (m.num_experts, m.top_k, m.d_ff, m.vocab_size) == (32, 8, 512, 49155)
    l4 = a["llama4-maverick-400b-a17b"]
    assert (l4.num_experts, l4.top_k, l4.num_layers, l4.d_model) == (128, 1, 48, 5120)
    mb = a["mamba2-2.7b"]
    assert (mb.num_layers, mb.d_model, mb.ssm_state) == (64, 2560, 128)
    z = a["zamba2-2.7b"]
    assert (z.num_layers, z.d_model, z.ssm_state, z.num_kv_heads) == (54, 2560, 64, 32)
    h2 = a["h2o-danube-3-4b"]
    assert (h2.num_layers, h2.d_model, h2.num_heads, h2.num_kv_heads) == (24, 3840, 32, 8)
    ch = a["chameleon-34b"]
    assert (ch.num_layers, ch.d_model, ch.num_heads, ch.d_ff) == (48, 8192, 64, 22016)
    gr = a["granite-8b"]
    assert (gr.num_layers, gr.d_model, gr.d_ff, gr.vocab_size) == (36, 4096, 14336, 49152)
    w = a["whisper-large-v3"]
    assert (w.num_layers, w.d_model, w.num_heads, w.d_ff, w.vocab_size) == (
        32, 1280, 20, 5120, 51866)
