"""Telemetry subsystem (DESIGN.md §15): trackers, engine tap, ledger.

The §15 contract this file pins:

  1. **Observation changes nothing.**  A run with a tracker attached is
     BIT-IDENTICAL to the same run without one, on every engine path —
     the tap adds an ``io_callback`` to the compiled program but never a
     float.  ``NullTracker`` (and no tracker) compile the tap out.
  2. **Exactly-T streaming.**  A T-round run delivers exactly T round
     events, in round order, each carrying the per-round schema
     (η / η_naive / η_target, metric on the eval cadence, participants,
     fault totals when faults are armed, cumulative ledger).
  3. **The ledger is the report.**  The per-round cumulative privacy
     ledger is monotone and its final entry equals
     ``session.privacy_report(δ)`` to 1e-9 — including retried rounds
     after a §13 rollback, which charge the ledger.
  4. **Resume and rollback replay cleanly.**  A resumed run emits only
     the resumed rounds (no duplicates), a recovery rollback emits a
     ``rollback`` control event and rewinds the stream, and
     ``tools/check_telemetry.py`` accepts every stream the session emits.
"""
import importlib.util
import json
import math
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fedexp import make_algorithm
from repro.data.synthetic import distance_to_opt, linreg_loss, make_synthetic_linreg
from repro.fedsim import (
    CohortSpec,
    EngineSpec,
    FaultSpec,
    FederatedSession,
    ShardSpec,
    StreamSpec,
    TelemetrySpec,
    TrainSpec,
)
from repro.fedsim.session import RecoveryPolicy
from repro.launch.mesh import make_client_mesh
from repro.telemetry import (
    CompositeTracker,
    JsonlTracker,
    NullTracker,
    StdoutTracker,
    Tracker,
    WandbTracker,
)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tools"))
from check_telemetry import check_stream  # noqa: E402

M, D, TAU, ETA_L, ROUNDS = 32, 16, 2, 0.1, 6
DELTA = 1e-5  # == TelemetrySpec().ledger_delta, so ledger lines match reports
KEY = jax.random.PRNGKey(11)

ALG_KWARGS = {
    "fedavg": {},
    "cdp-fedexp": dict(clip_norm=0.3, sigma=0.2, num_clients=M),
    "dp-fedavg-cdp": dict(clip_norm=0.3, sigma=0.2, num_clients=M),
}

FAULT = FaultSpec(dropout=0.3, straggler=0.2, straggler_steps=1, corrupt=0.02)


@pytest.fixture(scope="module")
def problem():
    data = make_synthetic_linreg(jax.random.PRNGKey(3), M, D)
    return data, jnp.zeros(D)


def _session(problem, name="cdp-fedexp", *, rounds=ROUNDS, **spec_kw):
    data, w0 = problem
    alg = make_algorithm(name, **ALG_KWARGS[name])
    return FederatedSession(
        alg, linreg_loss, w0, data.client_batches(),
        train=spec_kw.pop("train", TrainSpec(rounds=rounds, tau=TAU, eta_l=ETA_L)),
        eval_fn=spec_kw.pop("eval_fn", distance_to_opt(data.w_star)), **spec_kw)


def _lines(path):
    with open(path) as f:
        return [json.loads(line) for line in f]


def _round_lines(path):
    return [o for o in _lines(path) if "event" not in o]


class _ListTracker(Tracker):
    """In-memory sink for unit tests."""

    def __init__(self):
        self.events, self.phases, self.finished = [], [], 0

    def log(self, step, event):
        self.events.append((step, dict(event)))

    def start_phase(self, name, step=0):
        self.phases.append((name, step))

    def finish(self):
        self.finished += 1


# engine-path configs for the bit-identity sweep; "sharded" builds its mesh
# lazily (device count is a property of the CI leg, see conftest.py)
ENGINE_CONFIGS = {
    "scan": lambda: {},
    "chunked": lambda: dict(engine=EngineSpec(chunk_rounds=2)),
    "eager": lambda: dict(engine=EngineSpec(engine="eager")),
    "sampled": lambda: dict(cohort=CohortSpec(q=0.5)),
    "stream": lambda: dict(engine=EngineSpec(engine="stream"),
                           stream=StreamSpec(chunk_clients=16)),
    "gather": lambda: dict(engine=EngineSpec(engine="stream"),
                           stream=StreamSpec(chunk_clients=16),
                           cohort=CohortSpec(q=0.5, gather=True)),
    "sharded": lambda: dict(shard=ShardSpec(mesh=make_client_mesh())),
    "faults": lambda: dict(fault=FAULT),
}


class TestBitIdentity:
    """§15 acceptance: the tap observes, it never perturbs."""

    @pytest.mark.parametrize("path_name", sorted(ENGINE_CONFIGS))
    def test_tracker_on_matches_off(self, problem, tmp_path, path_name):
        cfg = ENGINE_CONFIGS[path_name]()
        r_off = _session(problem, **cfg).run(KEY)
        out = tmp_path / f"{path_name}.jsonl"
        r_on = _session(problem, **cfg).run(KEY, tracker=JsonlTracker(str(out)))
        for field in ("final_w", "last_w", "eta_history", "metric_history"):
            np.testing.assert_array_equal(
                np.asarray(getattr(r_off, field)),
                np.asarray(getattr(r_on, field)),
                err_msg=f"{path_name}.{field}")
        # exactly-T invariant: one round line per round, in order
        assert [o["round"] for o in _round_lines(out)] == list(range(ROUNDS))
        text = out.read_text().splitlines()
        assert check_stream(text, rounds=ROUNDS, label=path_name) == []

    def test_null_tracker_is_the_off_path(self, problem):
        sess = _session(problem)
        assert not sess._tap_on(None)
        assert not sess._tap_on(NullTracker())
        assert sess._tap_on(_ListTracker())
        r_null = _session(problem).run(KEY, tracker=NullTracker())
        r_off = _session(problem).run(KEY)
        np.testing.assert_array_equal(np.asarray(r_off.final_w),
                                      np.asarray(r_null.final_w))

    def test_host_driver_tracker_on_matches_off(self, problem, tmp_path):
        """§14 host-resident driver: the Python round loop feeds the same
        tap funnel directly (no io_callback)."""
        from repro.fedsim import HostArraySource
        data, w0 = problem
        host = jax.tree.map(np.asarray, data.client_batches())

        def sess():
            return FederatedSession(
                make_algorithm("cdp-fedexp", **ALG_KWARGS["cdp-fedexp"]),
                linreg_loss, w0, HostArraySource(host),
                train=TrainSpec(rounds=ROUNDS, tau=TAU, eta_l=ETA_L),
                eval_fn=distance_to_opt(data.w_star),
                engine=EngineSpec(engine="stream"),
                stream=StreamSpec(chunk_clients=16))

        r_off = sess().run(KEY)
        out = tmp_path / "host.jsonl"
        r_on = sess().run(KEY, tracker=JsonlTracker(str(out)))
        np.testing.assert_array_equal(np.asarray(r_off.final_w),
                                      np.asarray(r_on.final_w))
        np.testing.assert_array_equal(np.asarray(r_off.eta_history),
                                      np.asarray(r_on.eta_history))
        assert [o["round"] for o in _round_lines(out)] == list(range(ROUNDS))
        text = out.read_text().splitlines()
        assert check_stream(text, rounds=ROUNDS, label="host") == []

    def test_fault_totals_in_stream(self, problem, tmp_path):
        out = tmp_path / "faults.jsonl"
        _session(problem, fault=FAULT).run(KEY, tracker=JsonlTracker(str(out)))
        for o in _round_lines(out):
            for k in ("realized_clients", "dropped", "stragglers", "corrupt"):
                assert isinstance(o[k], int), (o["round"], k)
            assert 0 <= o["realized_clients"] <= M
            assert o["dropped"] + o["stragglers"] <= M


class TestLedger:
    """Per-round cumulative privacy ledger == the end-of-run report."""

    def test_ledger_monotone_and_matches_report(self, problem, tmp_path):
        out = tmp_path / "ledger.jsonl"
        sess = _session(problem, "dp-fedavg-cdp")
        sess.run(KEY, tracker=JsonlTracker(str(out)))
        rounds = _round_lines(out)
        assert [o["ledger_rounds"] for o in rounds] == list(range(1, ROUNDS + 1))
        eps = [o["eps"] for o in rounds]
        assert eps == sorted(eps)
        rep = sess.privacy_report(DELTA)
        assert abs(rounds[-1]["eps"] - rep.eps_numerical) < 1e-9
        assert abs(rounds[-1]["mu"] - rep.mu) < 1e-9
        assert abs(rounds[-1]["eps_rdp"] - rep.eps_rdp) < 1e-9

    def test_non_dp_algorithm_has_no_ledger(self, problem, tmp_path):
        out = tmp_path / "fedavg.jsonl"
        _session(problem, "fedavg").run(KEY, tracker=JsonlTracker(str(out)))
        rounds = _round_lines(out)
        assert len(rounds) == ROUNDS
        assert all("eps" not in o and "ledger_rounds" not in o for o in rounds)

    def test_ledger_delta_none_disables(self, problem, tmp_path):
        out = tmp_path / "nodelta.jsonl"
        _session(problem, telemetry=TelemetrySpec(ledger_delta=None)).run(
            KEY, tracker=JsonlTracker(str(out)))
        assert all("eps" not in o for o in _round_lines(out))

    def test_spec_validation(self):
        with pytest.raises(ValueError, match="ledger_delta"):
            TelemetrySpec(ledger_delta=0.0)
        with pytest.raises(ValueError, match="profile_rounds"):
            TelemetrySpec(profile_rounds=(4, 2))


class TestResumeAndRecovery:
    def test_resume_emits_only_new_rounds(self, problem, tmp_path):
        ck = str(tmp_path / "ck")
        _session(problem, rounds=3).run(KEY, checkpoint_dir=ck)
        out = tmp_path / "resume.jsonl"
        sess = _session(problem)
        sess.resume(ck, tracker=JsonlTracker(str(out)))
        rounds = _round_lines(out)
        assert [o["round"] for o in rounds] == [3, 4, 5]
        # the cumulative ledger counts from round 0, not from the checkpoint
        assert [o["ledger_rounds"] for o in rounds] == [4, 5, 6]
        rep = sess.privacy_report(DELTA)
        assert abs(rounds[-1]["eps"] - rep.eps_numerical) < 1e-9

    def test_recovery_rollback_stream(self, problem, tmp_path):
        sess = _session(problem, fault=FaultSpec(watchdog=True),
                        engine=EngineSpec(chunk_rounds=2))

        def poison_first_attempt(carry, attempt):
            if attempt >= 1:
                return carry
            w = carry[0].at[0].set(jnp.nan)
            return (w,) + tuple(carry[1:])

        sess._inject_divergence = poison_first_attempt
        out = tmp_path / "recovery.jsonl"
        r = sess.run(KEY, checkpoint_dir=str(tmp_path / "ck"),
                     checkpoint_every=2,
                     on_divergence=RecoveryPolicy(max_retries=2),
                     tracker=JsonlTracker(str(out)))
        assert r.fault_round is None

        lines = _lines(out)
        rollbacks = [o for o in lines if o.get("event") == "rollback"]
        assert len(rollbacks) == 1
        assert rollbacks[0]["to_round"] == 0 and rollbacks[0]["attempt"] == 1
        # the poisoned attempt surfaces the fault round before the rewind
        assert any(o.get("watchdog_fault_round") == 0 for o in lines)
        # validator accepts the rewind; 6 distinct rounds despite the retry
        text = out.read_text().splitlines()
        assert check_stream(text, rounds=ROUNDS, label="recovery") == []
        # retried rounds charge the ledger: final stream entry == report
        last = _round_lines(out)[-1]
        assert last["ledger_rounds"] == ROUNDS + 1  # one round re-run
        rep = sess.privacy_report(DELTA)
        assert abs(last["eps"] - rep.eps_numerical) < 1e-9
        # and the recovered run matches an unkilled one bit-for-bit
        r_ref = _session(problem, fault=FaultSpec(watchdog=True),
                         engine=EngineSpec(chunk_rounds=2)).run(KEY)
        np.testing.assert_array_equal(np.asarray(r_ref.final_w),
                                      np.asarray(r.final_w))


class TestProfiler:
    def test_profile_window_events(self, problem, tmp_path):
        prof = str(tmp_path / "trace")
        out = tmp_path / "prof.jsonl"
        _session(problem, telemetry=TelemetrySpec(
            profile_rounds=(2, 4), profile_dir=prof)).run(
            KEY, tracker=JsonlTracker(str(out)))
        events = [(o["event"], o["round"]) for o in _lines(out) if "event" in o]
        assert events == [("profile_start", 2), ("profile_stop", 4)]
        assert os.path.isdir(prof) and os.listdir(prof)
        # the round stream around the window is untouched
        assert [o["round"] for o in _round_lines(out)] == list(range(ROUNDS))


class TestBatched:
    def test_run_batched_replay_per_seed(self, problem, tmp_path):
        keys = jax.random.split(KEY, 3)
        out = tmp_path / "batched.jsonl"
        sess = _session(problem)
        r_on = sess.run_batched(keys, tracker=JsonlTracker(str(out)))
        r_off = _session(problem).run_batched(keys)
        np.testing.assert_array_equal(np.asarray(r_off.eta_history),
                                      np.asarray(r_on.eta_history))
        lines = _lines(out)
        assert len(lines) == 3 * ROUNDS
        for seed in range(3):
            mine = [o for o in lines if o["seed"] == seed]
            assert [o["round"] for o in mine] == list(range(ROUNDS))
            assert [o["ledger_rounds"] for o in mine] == \
                list(range(1, ROUNDS + 1))
            assert all("eta" in o and "metric" in o for o in mine)


class TestSinks:
    def test_jsonl_sanitizes_non_finite(self, tmp_path):
        out = tmp_path / "nan.jsonl"
        t = JsonlTracker(str(out))
        t.log(0, {"eta": float("nan"), "metric": float("inf"), "clip": 0.5})
        [o] = _lines(out)
        assert o == {"round": 0, "eta": None, "metric": None, "clip": 0.5}

    def test_jsonl_overwrite_vs_append(self, tmp_path):
        out = tmp_path / "mode.jsonl"
        JsonlTracker(str(out)).log(0, {"eta": 1.0})
        JsonlTracker(str(out)).log(1, {"eta": 2.0})  # default: overwrite
        assert [o["round"] for o in _lines(out)] == [1]
        JsonlTracker(str(out), append=True).log(2, {"eta": 3.0})
        assert [o["round"] for o in _lines(out)] == [1, 2]

    def test_stdout_tracker_cadence(self, capsys):
        t = StdoutTracker(every=2, prefix="x ")
        for step in range(4):
            t.log(step, {"eta": 1.0})
        t.log(9, {"event": "rollback", "to_round": 0})  # control: always
        lines = capsys.readouterr().out.splitlines()
        assert len(lines) == 3  # rounds 0, 2 + the control event
        assert lines[0].startswith("x [round")
        with pytest.raises(ValueError, match="every"):
            StdoutTracker(every=0)

    def test_composite_fans_out(self):
        a, b = _ListTracker(), _ListTracker()
        t = CompositeTracker(a, b)
        t.start_phase("run", 0)
        t.log(0, {"eta": 1.0})
        t.finish()
        for sink in (a, b):
            assert sink.events == [(0, {"eta": 1.0})]
            assert sink.phases == [("run", 0)]
            assert sink.finished == 1

    def test_sub_tracker_stamps_seed(self):
        parent = _ListTracker()
        sub = parent.sub(2)
        sub.log(0, {"eta": 1.0})
        sub.finish()  # no-op: must not close the parent
        assert parent.events == [(0, {"seed": 2, "eta": 1.0})]
        assert parent.finished == 0

    def test_wandb_tracker_gated_on_import(self):
        if importlib.util.find_spec("wandb") is None:
            with pytest.raises(ImportError):
                WandbTracker(run=object())
            return

        class FakeRun:
            def __init__(self):
                self.logged, self.finished = [], False

            def log(self, event, step=None):
                self.logged.append((step, event))

            def finish(self):
                self.finished = True

        run = FakeRun()
        t = WandbTracker(run=run)
        t.log(3, {"eta": 1.0})
        t.finish()
        assert run.logged == [(3, {"eta": 1.0})] and run.finished


class TestValidator:
    """tools/check_telemetry.py catches the drift it exists to catch."""

    GOOD = [
        '{"round": 0, "eta": 0.5, "ledger_rounds": 1, "eps": 0.1, "mu": 0.05}',
        '{"round": 1, "eta": 0.4, "ledger_rounds": 2, "eps": 0.2, "mu": 0.07}',
    ]

    def test_good_stream(self):
        assert check_stream(self.GOOD, rounds=2) == []

    def test_unknown_key_fails(self):
        bad = ['{"round": 0, "eta": 0.5, "banana": 1}']
        assert any("banana" in e for e in check_stream(bad))

    def test_contiguity_gap_fails(self):
        bad = ['{"round": 0, "eta": 0.5}', '{"round": 2, "eta": 0.5}']
        assert any("contiguity" in e for e in check_stream(bad))

    def test_rollback_rewinds_expectation(self):
        stream = ['{"round": 0, "eta": 0.5}',
                  '{"round": 0, "event": "rollback", "to_round": 0, "attempt": 1}',
                  '{"round": 0, "eta": 0.5}', '{"round": 1, "eta": 0.4}']
        assert check_stream(stream, rounds=2) == []

    def test_ledger_regression_fails(self):
        bad = ['{"round": 0, "eta": 0.5, "ledger_rounds": 1, "eps": 0.3}',
               '{"round": 1, "eta": 0.5, "ledger_rounds": 2, "eps": 0.1}']
        assert any("decreased" in e for e in check_stream(bad))

    def test_round_count_pinned(self):
        assert any("distinct" in e for e in
                   check_stream(self.GOOD, rounds=5))

    def test_frozen_rounds_exempt(self):
        stream = ['{"round": 0, "eta": 0.5, "watchdog_fault_round": 0}',
                  '{"round": 1, "frozen": true, "watchdog_fault_round": 0, '
                  '"round_time_s": 0.1}']
        assert check_stream(stream, rounds=1) == []

    def test_garbage_line_fails(self):
        assert any("JSON" in e for e in check_stream(["not json"]))


class TestResultHelpers:
    def test_eval_rounds_follows_cadence(self, problem):
        r = _session(problem, train=TrainSpec(
            rounds=ROUNDS, tau=TAU, eta_l=ETA_L, eval_every=2)).run(KEY)
        pairs = r.eval_rounds()
        assert [t for t, _ in pairs] == [1, 3, 5]
        assert all(math.isfinite(v) for _, v in pairs)

    def test_eval_rounds_no_eval_fn(self, problem):
        r = _session(problem, eval_fn=None).run(KEY)
        assert r.eval_rounds() == []

    def test_spec_identity_includes_telemetry(self, problem):
        ident = _session(problem).spec_identity()
        assert "cdp-fedexp" in ident
        assert "telemetry=TelemetrySpec" in ident
        assert "shard=mesh[none]" in ident
