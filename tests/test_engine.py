"""Scan-engine equivalence + in-kernel noise statistics (DESIGN.md §8).

Three layers of evidence that the compiled engine is the same algorithm:
  1. scan == eager, bit-for-bit, for every registered algorithm (same keys),
     including the stateful ones and chunked compilation.
  2. The Pallas kernel path == the jnp reference within tolerance for every
     fused_clip_aggregate call-site configuration (no noise / materialized
     noise / traced clip threshold / bf16 / ragged shapes).
  3. The in-kernel PRNG draws N(0, sigma^2) noise (moment + correlation
     checks) and the fused-noise pipeline agrees distributionally with the
     materialized-noise pipeline.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aggregation import fused_clip_aggregate
from repro.core.fedexp import make_algorithm
from repro.data.synthetic import distance_to_opt, linreg_loss, make_synthetic_linreg
from repro.fedsim import EngineSpec, FederatedSession, TrainSpec
from repro.kernels.dp_aggregate.ops import dp_aggregate, generate_ldp_noise

M, D, TAU, ETA_L, ROUNDS = 48, 24, 4, 0.1, 6

ALG_KWARGS = {
    "fedavg": {},
    "fedexp": {},
    "dp-fedavg-ldp-gauss": dict(clip_norm=0.3, sigma=0.21),
    "ldp-fedexp-gauss": dict(clip_norm=0.3, sigma=0.21),
    "dp-fedavg-privunit": dict(clip_norm=0.3, eps0=2.0, eps1=2.0, eps2=2.0, dim=D),
    "ldp-fedexp-privunit": dict(clip_norm=0.3, eps0=2.0, eps1=2.0, eps2=2.0, dim=D),
    "dp-fedavg-cdp": dict(clip_norm=0.3, sigma=0.2, num_clients=M),
    "cdp-fedexp": dict(clip_norm=0.3, sigma=0.2, num_clients=M),
    "dp-fedadam-cdp": dict(clip_norm=0.3, sigma=0.2, num_clients=M, server_lr=0.05),
    "cdp-fedexp-adaptive-clip": dict(z_mult=0.5, num_clients=M, dim=D),
}


@pytest.fixture(scope="module")
def problem():
    data = make_synthetic_linreg(jax.random.PRNGKey(3), M, D)
    return data, jnp.zeros(D)


def _run(problem, name, engine, **kw):
    data, w0 = problem
    alg = make_algorithm(name, **ALG_KWARGS[name])
    session = FederatedSession(alg, linreg_loss, w0, data.client_batches(),
                               train=TrainSpec(rounds=ROUNDS, tau=TAU, eta_l=ETA_L),
                               engine=EngineSpec(engine=engine, **kw),
                               eval_fn=distance_to_opt(data.w_star))
    return session.run(jax.random.PRNGKey(11))


class TestScanEagerEquivalence:
    @pytest.mark.parametrize("name", sorted(ALG_KWARGS))
    def test_scan_matches_eager_exactly(self, problem, name):
        r_e = _run(problem, name, "eager")
        r_s = _run(problem, name, "scan")
        if name == "dp-fedadam-cdp":
            # XLA compiles adam's rsqrt(v)+eps divide differently inside the
            # scan body — a 1-ULP wobble on the weights; everything upstream
            # of the optimizer (histories) is still bit-exact below.
            np.testing.assert_allclose(np.asarray(r_e.final_w),
                                       np.asarray(r_s.final_w), rtol=0, atol=1e-7)
            np.testing.assert_allclose(np.asarray(r_e.last_w),
                                       np.asarray(r_s.last_w), rtol=0, atol=1e-7)
        else:
            np.testing.assert_array_equal(np.asarray(r_e.final_w),
                                          np.asarray(r_s.final_w))
            np.testing.assert_array_equal(np.asarray(r_e.last_w),
                                          np.asarray(r_s.last_w))
        np.testing.assert_array_equal(np.asarray(r_e.eta_history),
                                      np.asarray(r_s.eta_history))
        np.testing.assert_array_equal(np.asarray(r_e.metric_history),
                                      np.asarray(r_s.metric_history))
        np.testing.assert_array_equal(np.asarray(r_e.eta_naive_history),
                                      np.asarray(r_s.eta_naive_history))

    @pytest.mark.parametrize("name", ["ldp-fedexp-gauss", "cdp-fedexp-adaptive-clip",
                                      "dp-fedadam-cdp"])
    def test_chunked_matches_unchunked(self, problem, name):
        r_1 = _run(problem, name, "scan")
        r_c = _run(problem, name, "scan", chunk_rounds=2)
        # same 1-ULP adam caveat as above (chunk length changes the program)
        atol = 1e-7 if name == "dp-fedadam-cdp" else 0
        np.testing.assert_allclose(np.asarray(r_1.final_w), np.asarray(r_c.final_w),
                                   rtol=0, atol=atol)
        np.testing.assert_array_equal(np.asarray(r_1.eta_history),
                                      np.asarray(r_c.eta_history))

    def test_unroll_is_bit_identical(self, problem):
        r_1 = _run(problem, "cdp-fedexp", "scan", scan_unroll=1)
        r_3 = _run(problem, "cdp-fedexp", "scan", scan_unroll=3)
        np.testing.assert_array_equal(np.asarray(r_1.final_w), np.asarray(r_3.final_w))

    def test_short_run_tail(self, problem):
        """rounds < avg_last: the iterate average covers all iterates."""
        data, w0 = problem
        alg = make_algorithm("fedexp")
        train = TrainSpec(rounds=1, tau=TAU, eta_l=ETA_L)
        key = jax.random.PRNGKey(1)
        r_e = FederatedSession(alg, linreg_loss, w0, data.client_batches(),
                               train=train,
                               engine=EngineSpec(engine="eager")).run(key)
        r_s = FederatedSession(alg, linreg_loss, w0, data.client_batches(),
                               train=train).run(key)
        np.testing.assert_array_equal(np.asarray(r_e.final_w), np.asarray(r_s.final_w))


class TestBatchedEngine:
    def test_batched_matches_single_runs(self, problem):
        data, w0 = problem
        alg = make_algorithm("ldp-fedexp-gauss", **ALG_KWARGS["ldp-fedexp-gauss"])
        keys = jnp.stack([jax.random.PRNGKey(21), jax.random.PRNGKey(22)])
        session = FederatedSession(alg, linreg_loss, w0, data.client_batches(),
                                   train=TrainSpec(rounds=ROUNDS, tau=TAU,
                                                   eta_l=ETA_L),
                                   eval_fn=distance_to_opt(data.w_star))
        rb = session.run_batched(keys)
        assert rb.final_w.shape == (2, D)
        assert rb.metric_history.shape == (2, ROUNDS)
        for s in range(2):
            r = session.run(keys[s])
            # vmap may reorder reductions (batched BLAS): tolerance, not exact
            np.testing.assert_allclose(np.asarray(rb.final_w[s]),
                                       np.asarray(r.final_w), rtol=1e-4, atol=1e-5)
            np.testing.assert_allclose(np.asarray(rb.eta_history[s]),
                                       np.asarray(r.eta_history), rtol=1e-4)

    def test_batched_w0_and_data(self, problem):
        data, _ = problem
        alg = make_algorithm("fedexp")
        keys = jnp.stack([jax.random.PRNGKey(0), jax.random.PRNGKey(1)])
        w0s = jnp.stack([jnp.zeros(D), 0.1 * jnp.ones(D)])
        batches = {k: jnp.stack([v, v]) for k, v in data.client_batches().items()}
        session = FederatedSession(alg, linreg_loss, w0s, batches,
                                   train=TrainSpec(rounds=3, tau=TAU, eta_l=ETA_L))
        rb = session.run_batched(keys, batched_w0=True, batched_data=True)
        assert rb.final_w.shape == (2, D)
        # different inits must give different trajectories
        assert not np.allclose(np.asarray(rb.final_w[0]), np.asarray(rb.final_w[1]))


class TestKernelVsJnp:
    """Every fused_clip_aggregate call-site configuration, kernel vs jnp."""

    def _check(self, stats_k, stats_j, rtol=2e-5, atol=2e-5):
        np.testing.assert_allclose(np.asarray(stats_k.cbar), np.asarray(stats_j.cbar),
                                   rtol=rtol, atol=atol)
        np.testing.assert_allclose(float(stats_k.mean_sq), float(stats_j.mean_sq),
                                   rtol=rtol, atol=atol)
        np.testing.assert_allclose(float(stats_k.mean_sq_clipped),
                                   float(stats_j.mean_sq_clipped), rtol=rtol, atol=atol)

    @pytest.mark.parametrize("m,d", [(8, 128), (24, 300), (10, 64), (33, 200)])
    @pytest.mark.parametrize("with_noise", [False, True])
    def test_shapes_and_noise(self, m, d, with_noise):
        key = jax.random.PRNGKey(m * d)
        u = 2.0 * jax.random.normal(key, (m, d))
        noise = (0.5 * jax.random.normal(jax.random.fold_in(key, 1), (m, d))
                 if with_noise else None)
        self._check(fused_clip_aggregate(u, 1.0, noise, backend="kernel"),
                    fused_clip_aggregate(u, 1.0, noise, backend="jnp"))

    def test_traced_clip_norm(self):
        """The adaptive-clip call site: clip is a traced per-round scalar."""
        u = jax.random.normal(jax.random.PRNGKey(5), (16, 96))

        from functools import partial

        @partial(jax.jit, static_argnames=("backend",))
        def release(c, backend):
            s = fused_clip_aggregate(u, c, None, backend=backend)
            return s.cbar, s.mean_sq_clipped

        for c in (0.25, 1.0, 4.0):
            ck, mk = release(jnp.float32(c), "kernel")
            cj, mj = release(jnp.float32(c), "jnp")
            np.testing.assert_allclose(np.asarray(ck), np.asarray(cj),
                                       rtol=2e-5, atol=2e-5)
            np.testing.assert_allclose(float(mk), float(mj), rtol=2e-5)

    def test_noise_key_routing(self):
        """noise_key + backend='kernel' materializes the SAME noise as jnp."""
        u = jax.random.normal(jax.random.PRNGKey(6), (16, 128))
        k = jax.random.PRNGKey(77)
        sk = fused_clip_aggregate(u, 0.5, noise_key=k, noise_sigma=0.3,
                                  backend="kernel")
        sj = fused_clip_aggregate(u, 0.5, noise_key=k, noise_sigma=0.3,
                                  backend="jnp")
        self._check(sk, sj)

    def test_bf16(self):
        u = jax.random.normal(jax.random.PRNGKey(7), (16, 128)).astype(jnp.bfloat16)
        sk = fused_clip_aggregate(u, 0.5, backend="kernel")
        sj = fused_clip_aggregate(u, 0.5, backend="jnp")
        np.testing.assert_allclose(np.asarray(sk.cbar, np.float32),
                                   np.asarray(sj.cbar, np.float32),
                                   rtol=2e-2, atol=2e-2)


class TestInKernelNoise:
    SIGMA = 1.3

    def test_moments(self):
        """Kernel-drawn noise matches N(0, sigma^2): mean, variance, and
        cross-row/column correlations within statistical tolerance."""
        m, d = 512, 256
        z = np.asarray(generate_ldp_noise(m, d, jax.random.PRNGKey(123), self.SIGMA))
        n = z.size
        assert abs(z.mean()) < 5 * self.SIGMA / np.sqrt(n)          # CLT bound
        np.testing.assert_allclose(z.std(), self.SIGMA, rtol=0.02)
        # fourth moment (kurtosis) distinguishes Gaussian from uniform bits
        np.testing.assert_allclose((z**4).mean(), 3 * self.SIGMA**4, rtol=0.1)
        # adjacent-lane and adjacent-row correlations ~ 0
        for a, b in ((z[:, :-1], z[:, 1:]), (z[:-1], z[1:])):
            corr = np.mean(a * b) / self.SIGMA**2
            assert abs(corr) < 5 / np.sqrt(a.size)

    def test_distinct_keys_distinct_noise(self):
        z1 = generate_ldp_noise(32, 128, jax.random.PRNGKey(1), 1.0)
        z2 = generate_ldp_noise(32, 128, jax.random.PRNGKey(2), 1.0)
        z1b = generate_ldp_noise(32, 128, jax.random.PRNGKey(1), 1.0)
        assert not np.allclose(np.asarray(z1), np.asarray(z2))
        np.testing.assert_array_equal(np.asarray(z1), np.asarray(z1b))

    def test_fused_pipeline_matches_kernel_noise_oracle(self):
        """dp_aggregate(fused) == dp_aggregate(materialized oracle noise)."""
        m, d = 40, 192
        key = jax.random.PRNGKey(9)
        u = jax.random.normal(key, (m, d))
        oracle = generate_ldp_noise(m, d, key, self.SIGMA)
        got = dp_aggregate(u, 0.5, noise_key=key, noise_sigma=self.SIGMA)
        want = dp_aggregate(u, 0.5, oracle)
        np.testing.assert_allclose(np.asarray(got.cbar), np.asarray(want.cbar),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(float(got.mean_sq), float(want.mean_sq), rtol=1e-5)

    def test_fused_pipeline_distribution_matches_materialized(self):
        """Full-pipeline distributional agreement: over repeated keys, the
        released mean_sq under in-kernel noise matches the materialized-noise
        path — both concentrate on mean_sq_clipped + d*sigma^2."""
        m, d, sigma = 64, 128, 0.7
        u = jax.random.normal(jax.random.PRNGKey(31), (m, d))
        fused, mat = [], []
        for i in range(8):
            k = jax.random.PRNGKey(1000 + i)
            fused.append(float(fused_clip_aggregate(
                u, 0.5, noise_key=k, noise_sigma=sigma,
                backend="kernel-fused").mean_sq))
            mat.append(float(fused_clip_aggregate(
                u, 0.5, noise_key=k, noise_sigma=sigma, backend="jnp").mean_sq))
        expected = float(fused_clip_aggregate(u, 0.5, backend="jnp").mean_sq_clipped)
        expected += d * sigma**2
        # both estimators target the same mean; each concentrates at
        # O(sigma^2 sqrt(d/m)) per draw, / sqrt(8) for the average
        tol = 5 * sigma**2 * np.sqrt(2.0 * d / m) / np.sqrt(8)
        assert abs(np.mean(fused) - expected) < tol
        assert abs(np.mean(mat) - expected) < tol

    def test_engine_with_fused_noise_backend_trains(self, problem):
        """End-to-end: the scan engine with the kernel-fused backend."""
        data, w0 = problem
        alg = make_algorithm("ldp-fedexp-gauss", clip_norm=0.3, sigma=0.21,
                             backend="kernel-fused")
        session = FederatedSession(alg, linreg_loss, w0, data.client_batches(),
                                   train=TrainSpec(rounds=3, tau=TAU, eta_l=ETA_L),
                                   eval_fn=distance_to_opt(data.w_star))
        r = session.run(jax.random.PRNGKey(2))
        assert np.all(np.isfinite(np.asarray(r.metric_history)))
        assert float(jnp.min(r.eta_history)) >= 1.0
