"""FederatedSession API (DESIGN.md §10): spec-driven runs, pytree models,
checkpoint/resume, and the deprecated-shim contract.

The resume tests are the acceptance criterion for resumable runs: a run to
round T must equal run-to-T/2 -> save -> resume -> run-to-T BIT-EXACTLY,
including the optimizer state (dp-fedadam-cdp) and the adaptive clip state
(cdp-fedexp-adaptive-clip) surviving the npz round trip.
"""
import os
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.core.fedexp import list_algorithms, make_algorithm
from repro.data.synthetic import distance_to_opt, linreg_loss, make_synthetic_linreg
from repro.fedsim import (
    CohortSpec,
    EngineSpec,
    FederatedSession,
    TrainSpec,
    flatten_model,
)
from repro.fedsim.server import run_federated, run_federated_batched

M, D, TAU, ETA_L, ROUNDS = 32, 16, 3, 0.1, 6

ALG_KWARGS = {
    "fedavg": {},
    "cdp-fedexp": dict(clip_norm=0.3, sigma=0.2, num_clients=M),
    "cdp-fedexp-adaptive-clip": dict(z_mult=0.5, num_clients=M, dim=D),
    "dp-fedadam-cdp": dict(clip_norm=0.3, sigma=0.2, num_clients=M, server_lr=0.05),
}


@pytest.fixture(scope="module")
def problem():
    data = make_synthetic_linreg(jax.random.PRNGKey(3), M, D)
    return data, jnp.zeros(D)


def _session(problem, name, *, rounds=ROUNDS, **spec_kw):
    data, w0 = problem
    alg = make_algorithm(name, **ALG_KWARGS[name])
    return FederatedSession(
        alg, linreg_loss, w0, data.client_batches(),
        train=spec_kw.pop("train", TrainSpec(rounds=rounds, tau=TAU, eta_l=ETA_L)),
        eval_fn=distance_to_opt(data.w_star), **spec_kw)


class TestShims:
    """run_federated/_batched are DEPRECATED shims that must stay
    bit-identical to the session they wrap."""

    def test_run_federated_matches_session_and_warns(self, problem):
        data, w0 = problem
        alg = make_algorithm("cdp-fedexp", **ALG_KWARGS["cdp-fedexp"])
        kw = dict(rounds=ROUNDS, tau=TAU, eta_l=ETA_L)
        r_s = _session(problem, "cdp-fedexp").run(jax.random.PRNGKey(11))
        import repro.fedsim.server as srv
        srv._deprecation_warned = False
        with pytest.warns(DeprecationWarning, match="FederatedSession"):
            r_f = run_federated(alg, linreg_loss, w0, data.client_batches(),
                                key=jax.random.PRNGKey(11),
                                eval_fn=distance_to_opt(data.w_star), **kw)
        np.testing.assert_array_equal(np.asarray(r_s.final_w), np.asarray(r_f.final_w))
        np.testing.assert_array_equal(np.asarray(r_s.eta_history),
                                      np.asarray(r_f.eta_history))
        np.testing.assert_array_equal(np.asarray(r_s.metric_history),
                                      np.asarray(r_f.metric_history))
        # the warning fires once per process, then the shim goes quiet
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            run_federated(alg, linreg_loss, w0, data.client_batches(),
                          key=jax.random.PRNGKey(11), **kw)

    def test_run_federated_batched_matches_session(self, problem):
        data, w0 = problem
        alg = make_algorithm("cdp-fedexp", **ALG_KWARGS["cdp-fedexp"])
        keys = jnp.stack([jax.random.PRNGKey(1), jax.random.PRNGKey(2)])
        r_s = _session(problem, "cdp-fedexp").run_batched(keys)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            r_f = run_federated_batched(alg, linreg_loss, w0, data.client_batches(),
                                        rounds=ROUNDS, tau=TAU, eta_l=ETA_L,
                                        keys=keys,
                                        eval_fn=distance_to_opt(data.w_star))
        np.testing.assert_array_equal(np.asarray(r_s.final_w), np.asarray(r_f.final_w))
        np.testing.assert_array_equal(np.asarray(r_s.eta_history),
                                      np.asarray(r_f.eta_history))


class TestSessionReuse:
    def test_repeated_runs_deterministic_and_cached(self, problem):
        sess = _session(problem, "cdp-fedexp")
        import repro.fedsim.server as srv
        r1 = sess.run(jax.random.PRNGKey(5))
        hits_before = srv._cached_scan_chunk_fn.cache_info().hits
        r2 = sess.run(jax.random.PRNGKey(5))
        np.testing.assert_array_equal(np.asarray(r1.final_w), np.asarray(r2.final_w))
        # the session owns its closures: the second run hits the compile cache
        assert srv._cached_scan_chunk_fn.cache_info().hits > hits_before

    def test_eager_engine(self, problem):
        r_s = _session(problem, "fedavg").run(jax.random.PRNGKey(5))
        r_e = _session(problem, "fedavg",
                       engine=EngineSpec(engine="eager")).run(jax.random.PRNGKey(5))
        np.testing.assert_array_equal(np.asarray(r_s.final_w), np.asarray(r_e.final_w))

    def test_spec_validation(self):
        with pytest.raises(ValueError, match="rounds"):
            TrainSpec(rounds=0, tau=1, eta_l=0.1)
        with pytest.raises(ValueError, match="engine"):
            EngineSpec(engine="warp")
        with pytest.raises(ValueError, match="not both"):
            CohortSpec(q=0.5, size=4)
        with pytest.raises(ValueError, match="replace"):
            CohortSpec(replace=True)

    def test_checkpoint_every_requires_dir(self, problem):
        with pytest.raises(ValueError, match="checkpoint_dir"):
            _session(problem, "fedavg").run(jax.random.PRNGKey(0),
                                            checkpoint_every=2)

    def test_run_batched_rejects_eager(self, problem):
        sess = _session(problem, "fedavg", engine=EngineSpec(engine="eager"))
        with pytest.raises(ValueError, match="eager"):
            sess.run_batched(jnp.stack([jax.random.PRNGKey(0)]))

    def test_cohort_size_exceeds_clients(self, problem):
        sess = _session(problem, "fedavg", cohort=CohortSpec(size=M + 1))
        with pytest.raises(ValueError, match="exceeds"):
            sess.run(jax.random.PRNGKey(0))

    def test_batched_data_seed_axis_not_mistaken_for_clients(self, problem):
        """Validation must see the client axis (1 under batched_data), not
        the leading seed axis."""
        data, w0 = problem
        batches = {k: jnp.stack([v, v])
                   for k, v in data.client_batches().items()}  # (S=2, M, ...)
        sess = FederatedSession(
            make_algorithm("fedavg"), linreg_loss, w0, batches,
            train=TrainSpec(rounds=2, tau=1, eta_l=ETA_L),
            cohort=CohortSpec(size=M // 2))
        keys = jnp.stack([jax.random.PRNGKey(0), jax.random.PRNGKey(1)])
        rb = sess.run_batched(keys, batched_data=True)  # must not raise
        assert rb.final_w.shape == (2, D)


class TestEvalCadence:
    def test_eval_every_masks_offcadence_rounds(self, problem):
        r1 = _session(problem, "cdp-fedexp").run(jax.random.PRNGKey(5))
        r3 = _session(
            problem, "cdp-fedexp",
            train=TrainSpec(rounds=ROUNDS, tau=TAU, eta_l=ETA_L, eval_every=3),
        ).run(jax.random.PRNGKey(5))
        m1, m3 = np.asarray(r1.metric_history), np.asarray(r3.metric_history)
        on = np.arange(ROUNDS) % 3 == 2          # rounds 2, 5 evaluate
        np.testing.assert_array_equal(m3[on], m1[on])
        assert np.isnan(m3[~on]).all()
        # the trajectory itself is untouched by the cadence
        np.testing.assert_array_equal(np.asarray(r1.final_w), np.asarray(r3.final_w))


class TestPytreeModels:
    def _tree_problem(self):
        key = jax.random.PRNGKey(0)
        params = {"W": 0.1 * jax.random.normal(key, (8, 4)), "b": jnp.zeros(4)}
        batches = {
            "x": jax.random.normal(jax.random.fold_in(key, 1), (M, 10, 8)),
            "y": jax.random.normal(jax.random.fold_in(key, 2), (M, 10, 4)),
        }

        def loss(p, batch):
            pred = batch["x"] @ p["W"] + p["b"]
            return 0.5 * jnp.mean(jnp.sum(jnp.square(pred - batch["y"]), -1))

        return params, batches, loss

    def test_pytree_run_matches_manual_flatten(self):
        params, batches, loss = self._tree_problem()
        alg = make_algorithm("cdp-fedexp", **ALG_KWARGS["cdp-fedexp"])
        train = TrainSpec(rounds=4, tau=2, eta_l=0.05)
        r_tree = FederatedSession(alg, loss, params, batches, train=train).run(
            jax.random.PRNGKey(7))
        assert isinstance(r_tree.final_w, dict)
        assert r_tree.final_w["W"].shape == (8, 4)

        flat, unravel = flatten_model(params)
        r_flat = FederatedSession(
            alg, lambda wf, b: loss(unravel(wf), b), flat, batches,
            train=train).run(jax.random.PRNGKey(7))
        np.testing.assert_array_equal(
            np.asarray(flatten_model(r_tree.final_w)[0]), np.asarray(r_flat.final_w))
        np.testing.assert_array_equal(np.asarray(r_tree.eta_history),
                                      np.asarray(r_flat.eta_history))

    def test_pytree_batched_and_eval(self):
        params, batches, loss = self._tree_problem()
        alg = make_algorithm("fedavg")
        eval_fn = lambda p: jnp.sum(jnp.square(p["W"]))
        sess = FederatedSession(alg, loss, params, batches,
                                train=TrainSpec(rounds=3, tau=2, eta_l=0.05),
                                eval_fn=eval_fn)
        keys = jnp.stack([jax.random.PRNGKey(1), jax.random.PRNGKey(2)])
        rb = sess.run_batched(keys)
        assert rb.final_w["W"].shape == (2, 8, 4)
        assert np.all(np.isfinite(np.asarray(rb.metric_history)))

    def test_batched_w0_with_pytree_rejected(self):
        params, batches, loss = self._tree_problem()
        sess = FederatedSession(make_algorithm("fedavg"), loss, params, batches,
                                train=TrainSpec(rounds=2, tau=1, eta_l=0.05))
        with pytest.raises(ValueError, match="batched_w0"):
            sess.run_batched(jnp.stack([jax.random.PRNGKey(0)]), batched_w0=True)


class TestCheckpointResume:
    """Acceptance: kill/resume == uninterrupted, bit-exactly, with optimizer
    and clip state surviving the round trip."""

    @pytest.mark.parametrize("name", sorted(ALG_KWARGS))
    def test_resume_matches_uninterrupted(self, problem, name, tmp_path):
        key = jax.random.PRNGKey(11)
        half = ROUNDS // 2
        # uninterrupted, chunked at the same boundary the resume will use so
        # even adam's 1-ULP-per-program wobble cannot differ
        r_full = _session(problem, name,
                          engine=EngineSpec(chunk_rounds=half)).run(key)

        _session(problem, name, rounds=half).run(key, checkpoint_dir=str(tmp_path))
        assert ckpt.latest_step(str(tmp_path)) == half
        r_res = _session(problem, name).resume(str(tmp_path))

        for field in ("final_w", "last_w", "eta_history", "metric_history",
                      "eta_naive_history", "eta_target_history"):
            np.testing.assert_array_equal(
                np.asarray(getattr(r_full, field)),
                np.asarray(getattr(r_res, field)), err_msg=f"{name}.{field}")

    def test_resume_matches_single_chunk_run(self, problem):
        """Chunk boundaries don't change results: resume == one-chunk run."""
        key = jax.random.PRNGKey(11)
        r_one = _session(problem, "cdp-fedexp").run(key)
        r_chunked = _session(problem, "cdp-fedexp",
                             engine=EngineSpec(chunk_rounds=2)).run(key)
        np.testing.assert_array_equal(np.asarray(r_one.final_w),
                                      np.asarray(r_chunked.final_w))

    def test_periodic_checkpoints_and_resume_from_latest(self, problem, tmp_path):
        key = jax.random.PRNGKey(11)
        sess = _session(problem, "cdp-fedexp-adaptive-clip")
        r_full = sess.run(key, checkpoint_dir=str(tmp_path), checkpoint_every=2)
        steps = sorted(int(f[5:13]) for f in os.listdir(tmp_path)
                       if f.endswith(".npz"))
        assert steps == [2, 4, ROUNDS]
        r_res = _session(problem, "cdp-fedexp-adaptive-clip").resume(str(tmp_path))
        # latest checkpoint IS the full run: resume returns it as-is
        np.testing.assert_array_equal(np.asarray(r_full.final_w),
                                      np.asarray(r_res.final_w))
        np.testing.assert_array_equal(np.asarray(r_full.eta_history),
                                      np.asarray(r_res.eta_history))

    def test_sampled_run_resumes_bit_exact(self, problem, tmp_path):
        """Sampling masks derive from fold_in(key, t): resume redraws the
        identical cohorts."""
        key = jax.random.PRNGKey(11)
        cohort = CohortSpec(q=0.5)
        r_full = _session(problem, "cdp-fedexp", cohort=cohort).run(key)
        _session(problem, "cdp-fedexp", rounds=ROUNDS // 2, cohort=cohort).run(
            key, checkpoint_dir=str(tmp_path))
        r_res = _session(problem, "cdp-fedexp", cohort=cohort).resume(str(tmp_path))
        np.testing.assert_array_equal(np.asarray(r_full.final_w),
                                      np.asarray(r_res.final_w))

    def test_resume_algorithm_mismatch_rejected(self, problem, tmp_path):
        _session(problem, "fedavg", rounds=2).run(jax.random.PRNGKey(0),
                                                  checkpoint_dir=str(tmp_path))
        with pytest.raises(ValueError, match="algorithm"):
            _session(problem, "cdp-fedexp").resume(str(tmp_path))

    def test_resume_past_rounds_rejected(self, problem, tmp_path):
        _session(problem, "fedavg").run(jax.random.PRNGKey(0),
                                        checkpoint_dir=str(tmp_path))
        with pytest.raises(ValueError, match="past"):
            _session(problem, "fedavg", rounds=2).resume(str(tmp_path))


class TestCheckpointPackage:
    """Satellite: checkpoint robustness (ValueError not assert, atomic meta,
    registered-dataclass paths)."""

    def test_shape_mismatch_raises_value_error(self, tmp_path):
        ckpt.save_checkpoint(str(tmp_path), 0, {"w": jnp.zeros(4)})
        with pytest.raises(ValueError, match=r"'w'.*\(4,\)"):
            ckpt.load_checkpoint(str(tmp_path), {"w": jnp.zeros(5)})

    def test_missing_leaf_raises_value_error(self, tmp_path):
        ckpt.save_checkpoint(str(tmp_path), 0, {"w": jnp.zeros(4)})
        with pytest.raises(ValueError, match="missing leaf"):
            ckpt.load_checkpoint(str(tmp_path), {"v": jnp.zeros(4)})

    def test_no_tmp_files_left_behind(self, tmp_path):
        ckpt.save_checkpoint(str(tmp_path), 3, {"w": jnp.zeros(4)},
                             extra={"note": "x"})
        files = sorted(os.listdir(tmp_path))
        assert files == ["ckpt_00000003.json", "ckpt_00000003.npz"]

    def test_registered_dataclass_roundtrip(self, tmp_path):
        from repro.core.adaptive_clip import AdaptiveClipState
        state = {"clipstate": AdaptiveClipState(clip=jnp.float32(0.7)),
                 "opt": (jnp.arange(3.0), (), jnp.int32(5))}
        ckpt.save_checkpoint(str(tmp_path), 1, state)
        loaded, meta = ckpt.load_checkpoint(str(tmp_path), state)
        assert float(loaded["clipstate"].clip) == pytest.approx(0.7)
        np.testing.assert_array_equal(np.asarray(loaded["opt"][0]),
                                      np.asarray(state["opt"][0]))
        assert int(loaded["opt"][2]) == 5
        assert meta["step"] == 1


class TestRegistry:
    # the 10 paper-era names, pinned bit-for-bit against their monolithic
    # classes by tests/test_compose.py; the registry also carries the newer
    # cross-product compositions (ldp-gauss-fedadam, ...)
    LEGACY_NAMES = {
        "fedavg", "fedexp", "dp-fedavg-ldp-gauss", "ldp-fedexp-gauss",
        "dp-fedavg-privunit", "ldp-fedexp-privunit", "dp-fedavg-cdp",
        "cdp-fedexp", "dp-fedadam-cdp", "cdp-fedexp-adaptive-clip",
    }

    def test_list_algorithms(self):
        names = list_algorithms()
        assert names == sorted(names) and len(names) == len(set(names))
        assert self.LEGACY_NAMES <= set(names)
        assert {"ldp-gauss-fedadam", "cdp-fedmom",
                "privunit-fedexp-adaptive-clip"} <= set(names)

    def test_unknown_name_enumerates(self):
        with pytest.raises(KeyError, match="cdp-fedexp"):
            make_algorithm("no-such-algorithm")

    def test_exported_from_core(self):
        from repro import core
        assert core.list_algorithms is list_algorithms
        assert core.make_algorithm is make_algorithm


class TestPrivacyReport:
    def test_subsampled_report_accounts_for_sampling(self, problem):
        """Sampling at FIXED sigma is not a free privacy win: the
        count-normalized mean's conditional sensitivity inflates by 1/q, and
        the subsampled-GDP amplification at best cancels it — the report must
        reflect the mechanism actually implemented, not a naive q-discount."""
        full = _session(problem, "cdp-fedexp").privacy_report(1e-5)
        samp = _session(problem, "cdp-fedexp",
                        cohort=CohortSpec(q=0.25)).privacy_report(1e-5)
        assert "q=0.25" in samp.setting
        assert samp.eps_numerical >= 0.9 * full.eps_numerical  # no free lunch
        # unsampled q path is the exact composition (unchanged numbers)
        from repro.core import accounting
        alg_kw = ALG_KWARGS["cdp-fedexp"]
        sigma_xi = D * alg_kw["sigma"] ** 2 / M
        ref = accounting.cdp_budget(alg_kw["clip_norm"], alg_kw["sigma"], M,
                                    ROUNDS, 1e-5, sigma_xi=sigma_xi)
        assert full.eps_numerical == pytest.approx(ref.eps_numerical)

    def test_adaptive_clip_sampled_report(self, problem):
        """The adaptive-clip report composes the 1/sqrt(q) conditional
        inflation (its noise tracks the realized cohort)."""
        import math
        samp = _session(problem, "cdp-fedexp-adaptive-clip",
                        cohort=CohortSpec(q=0.25)).privacy_report(1e-5)
        z, q = ALG_KWARGS["cdp-fedexp-adaptive-clip"]["z_mult"], 0.25
        from repro.core import accounting
        mu_round = math.sqrt((2.0 / (z * math.sqrt(q * M))) ** 2
                             + (1.0 / (D * z**2)) ** 2)
        assert samp.mu == pytest.approx(
            accounting.subsampled_gdp_mu(mu_round, q, ROUNDS))

    def test_non_private_raises(self, problem):
        with pytest.raises(ValueError, match="not a private"):
            _session(problem, "fedavg").privacy_report(1e-5)
