import os
import sys

# tests must see exactly ONE device (the dry-run sets its own XLA_FLAGS in a
# separate process); make src importable regardless of how pytest is invoked.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
