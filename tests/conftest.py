import os
import sys

# The suite runs under 1 device by default AND under CI's forced-8-device leg
# (XLA_FLAGS=--xla_force_host_platform_device_count=8) — tests must not
# assume a device count; tests/test_sharding.py adapts its mesh to whatever
# exists.  The dry-run sets its own XLA_FLAGS in a separate process.
# Make src importable regardless of how pytest is invoked.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
