"""Unit tests for the privacy mechanisms (PrivUnit / ScalarDP / Gaussian)."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import mechanisms as mech


class TestBetainc:
    def test_matches_jax(self):
        for a in (0.5, 2.0, 49.5):
            for x in (0.01, 0.3, 0.5, 0.77, 0.99):
                got = mech._betainc_f64(a, a, x)
                want = float(jax.scipy.special.betainc(a, a, x))
                assert abs(got - want) < 1e-5, (a, x)

    def test_bisect_inverts(self):
        alpha = 12.5
        ys = jnp.array([0.01, 0.2, 0.5, 0.9, 0.999])
        xs = mech._betainc_inv_bisect(alpha, ys)
        back = jax.scipy.special.betainc(alpha, alpha, xs)
        np.testing.assert_allclose(np.asarray(back), np.asarray(ys), atol=1e-5)


class TestPrivUnit:
    def test_norm_is_one_over_m(self):
        d = 64
        p = mech.make_privunit_params(d, 2.0, 2.0)
        u = jnp.zeros(d).at[0].set(1.0)
        z = mech.privunit_direction(jax.random.PRNGKey(0), u, p)
        assert abs(float(jnp.linalg.norm(z)) - 1.0 / p.m) < 1e-4

    def test_unbiased_direction(self):
        """E[z] = u (Lemma B.1) — Monte Carlo over 4000 draws."""
        d = 32
        p = mech.make_privunit_params(d, 2.0, 2.0)
        u = jax.random.normal(jax.random.PRNGKey(1), (d,))
        u = u / jnp.linalg.norm(u)
        keys = jax.random.split(jax.random.PRNGKey(2), 4000)
        zs = jax.vmap(lambda k: mech.privunit_direction(k, u, p))(keys)
        zbar = jnp.mean(zs, axis=0)
        # MC std of the mean ~ (1/m)/sqrt(n); m is O(1/sqrt(d))
        tol = 4.0 * (1.0 / p.m) / math.sqrt(4000)
        assert float(jnp.linalg.norm(zbar - u)) < tol

    def test_gamma_conditions(self):
        for d in (8, 64, 500):
            for eps1 in (0.5, 2.0, 6.0):
                p = mech.make_privunit_params(d, 2.0, eps1)
                assert 0.0 < p.gamma < 1.0
                assert p.m > 0.0

    def test_requires_d_ge_2(self):
        with pytest.raises(ValueError):
            mech.make_privunit_params(1, 2.0, 2.0)


class TestScalarDP:
    def test_outputs_on_lattice(self):
        sc = mech.make_scalardp_params(2.0, 1.0)
        keys = jax.random.split(jax.random.PRNGKey(0), 200)
        rs = jax.vmap(lambda k: mech.scalardp_magnitude(k, jnp.float32(0.4), sc))(keys)
        # r_hat = a*(j - b) for integer j in {0..k}
        js = np.asarray(rs) / sc.a + sc.b
        np.testing.assert_allclose(js, np.round(js), atol=1e-4)
        assert np.all((np.round(js) >= 0) & (np.round(js) <= sc.k))

    def test_unbiased(self):
        sc = mech.make_scalardp_params(3.0, 1.0)
        r = 0.63
        keys = jax.random.split(jax.random.PRNGKey(3), 20000)
        rs = jax.vmap(lambda k: mech.scalardp_magnitude(k, jnp.float32(r), sc))(keys)
        est = float(jnp.mean(rs))
        se = float(jnp.std(rs)) / math.sqrt(len(keys))
        assert abs(est - r) < 5 * se + 1e-3

    def test_randomized_response_rate(self):
        """P[j_hat == j] should be e^eps/(e^eps + k)."""
        eps2 = 2.0
        sc = mech.make_scalardp_params(eps2, 1.0)
        r = 1.0  # j deterministic = k
        keys = jax.random.split(jax.random.PRNGKey(4), 5000)
        rs = jax.vmap(lambda k: mech.scalardp_magnitude(k, jnp.float32(r), sc))(keys)
        js = np.round(np.asarray(rs) / sc.a + sc.b)
        p_keep = np.mean(js == sc.k)
        want = math.exp(eps2) / (math.exp(eps2) + sc.k)
        assert abs(p_keep - want) < 0.03


class TestNormEstimation:
    def test_sign_recovery_and_estimate(self):
        """Algorithm 4 recovers r_hat exactly from ||c|| and E[s_hat] <= r^2."""
        d, c_clip = 64, 1.0
        pu = mech.make_privunit_params(d, 2.0, 2.0)
        sc = mech.make_scalardp_params(2.0, c_clip)
        # paper's assumption: k(k+1)/(e^eps2 + k) not integer
        assert (sc.k * (sc.k + 1)) / (math.exp(sc.eps2) + sc.k) % 1 != 0

        delta = jax.random.normal(jax.random.PRNGKey(5), (d,))
        delta = 0.8 * c_clip * delta / jnp.linalg.norm(delta)
        keys = jax.random.split(jax.random.PRNGKey(6), 3000)

        def one(k):
            kd, km = jax.random.split(k)
            nrm = jnp.linalg.norm(delta)
            z = mech.privunit_direction(kd, delta / nrm, pu)
            r_hat = mech.scalardp_magnitude(km, nrm, sc)
            c = r_hat * z
            s_hat = mech.estimate_norm_sq(c, pu, sc)
            # sign recovery: |r_tilde| == |r_hat| and the reconstructed value
            # matches the true ScalarDP draw
            r_rec_abs = pu.m * jnp.linalg.norm(c)
            return s_hat, jnp.abs(jnp.abs(r_hat) - r_rec_abs)

        s_hats, rec_err = jax.vmap(one)(keys)
        assert float(jnp.max(rec_err)) < 1e-2
        true_sq = float(jnp.sum(delta**2))
        mean_s = float(jnp.mean(s_hats))
        se = float(jnp.std(s_hats)) / math.sqrt(len(keys))
        # Lemma B.2: E[s_hat] <= r^2 (should be close, debiased via variance UB)
        assert mean_s <= true_sq + 4 * se
        assert mean_s >= 0.3 * true_sq  # not degenerate


class TestGaussian:
    def test_ldp_randomize(self):
        d = 128
        delta = jnp.ones(d)
        c = mech.gaussian_ldp_randomize(jax.random.PRNGKey(0), delta, 0.5)
        assert c.shape == (d,)
        assert not jnp.allclose(c, delta)

    def test_cdp_sigma_xi(self):
        cfg = mech.GaussianCDPConfig(sigma=5.0, clip_norm=1.0, num_clients=100)
        assert cfg.mean_noise_std == pytest.approx(0.5)
        assert cfg.sigma_xi(1000) == pytest.approx(1000 * 25.0 / 100)
