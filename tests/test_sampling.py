"""Per-round client sampling (CohortSpec, DESIGN.md §10).

Three layers of evidence:
  1. CohortSpec(q=1.0) IS the unsampled engine — bit-for-bit for all 10
     algorithms (full participation routes through the identical program),
     and a full-cohort fixed-size draw (size=M, every client sampled) pushes
     the masked-moment machinery itself to agree with the unsampled release.
  2. Sampled runs are the same algorithm on every engine: fixed-size sampled
     runs match between the client-sharded mesh (8 devices under the CI leg)
     and the single-device engine, and sampled rounds stay one compiled scan
     program per chunk (compile-cache accounting, no per-round retrace).
  3. The masks themselves: Bernoulli/fixed/with-replacement draw statistics,
     determinism, and round-to-round variation.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fedexp import make_algorithm
from repro.data.synthetic import distance_to_opt, linreg_loss, make_synthetic_linreg
from repro.fedsim import CohortSpec, EngineSpec, FederatedSession, ShardSpec, TrainSpec
from repro.launch.mesh import make_client_mesh

# M not divisible by 8 (nor 2/4): the sharded legs exercise zero-weight
# padding COMBINED with the sampling mask
M, D, TAU, ETA_L, ROUNDS = 44, 24, 3, 0.1, 5

N_DEV = len(jax.devices())

ALG_KWARGS = {
    "fedavg": {},
    "fedexp": {},
    "dp-fedavg-ldp-gauss": dict(clip_norm=0.3, sigma=0.21),
    "ldp-fedexp-gauss": dict(clip_norm=0.3, sigma=0.21),
    "dp-fedavg-privunit": dict(clip_norm=0.3, eps0=2.0, eps1=2.0, eps2=2.0, dim=D),
    "ldp-fedexp-privunit": dict(clip_norm=0.3, eps0=2.0, eps1=2.0, eps2=2.0, dim=D),
    "dp-fedavg-cdp": dict(clip_norm=0.3, sigma=0.2, num_clients=M),
    "cdp-fedexp": dict(clip_norm=0.3, sigma=0.2, num_clients=M),
    "dp-fedadam-cdp": dict(clip_norm=0.3, sigma=0.2, num_clients=M, server_lr=0.05),
    "cdp-fedexp-adaptive-clip": dict(z_mult=0.5, num_clients=M, dim=D),
}


@pytest.fixture(scope="module")
def problem():
    data = make_synthetic_linreg(jax.random.PRNGKey(3), M, D)
    return data, jnp.zeros(D)


def _session(problem, name, *, cohort=CohortSpec(), mesh=None, rounds=ROUNDS):
    data, w0 = problem
    alg = make_algorithm(name, **ALG_KWARGS[name])
    return FederatedSession(
        alg, linreg_loss, w0, data.client_batches(),
        train=TrainSpec(rounds=rounds, tau=TAU, eta_l=ETA_L),
        shard=ShardSpec(mesh=mesh), cohort=cohort,
        eval_fn=distance_to_opt(data.w_star))


class TestFullParticipationParity:
    @pytest.mark.parametrize("name", sorted(ALG_KWARGS))
    def test_q1_is_bit_exact_with_unsampled(self, problem, name):
        """CohortSpec(q=1.0) normalizes to the unsampled engine path: the
        SAME compiled program, so bit-exactness is structural, and this test
        pins that the normalization never regresses."""
        key = jax.random.PRNGKey(11)
        r_u = _session(problem, name).run(key)
        r_q = _session(problem, name, cohort=CohortSpec(q=1.0)).run(key)
        for field in ("final_w", "last_w", "eta_history", "metric_history",
                      "eta_naive_history", "eta_target_history"):
            np.testing.assert_array_equal(
                np.asarray(getattr(r_u, field)), np.asarray(getattr(r_q, field)),
                err_msg=f"{name}.{field}")

    @pytest.mark.parametrize("name", ["ldp-fedexp-gauss", "cdp-fedexp"])
    def test_q1_sharded_is_bit_exact_with_unsampled_sharded(self, problem, name):
        """Same normalization on the sharded engine: q=1.0 under a client
        mesh IS the unsampled sharded program (all 10 share this path; two
        DP representatives keep the runtime bounded)."""
        key = jax.random.PRNGKey(11)
        mesh = make_client_mesh()
        r_u = _session(problem, name, mesh=mesh).run(key)
        r_q = _session(problem, name, cohort=CohortSpec(q=1.0), mesh=mesh).run(key)
        for field in ("final_w", "eta_history", "metric_history"):
            np.testing.assert_array_equal(
                np.asarray(getattr(r_u, field)), np.asarray(getattr(r_q, field)),
                err_msg=f"{name}.{field}")

    @pytest.mark.parametrize("name", sorted(ALG_KWARGS))
    def test_full_cohort_fixed_size_matches_unsampled(self, problem, name):
        """size=M samples EVERYONE (mask all-ones) but routes through the
        masked-moment machinery — the real numeric check that the sampled
        release is the same algorithm (reduction reorder tolerance, as for
        the sharded engine; eta is a reduction ratio, looser bar)."""
        key = jax.random.PRNGKey(11)
        r_u = _session(problem, name).run(key)
        r_s = _session(problem, name, cohort=CohortSpec(size=M)).run(key)
        for field in ("final_w", "last_w", "metric_history"):
            np.testing.assert_allclose(
                np.asarray(getattr(r_u, field)), np.asarray(getattr(r_s, field)),
                rtol=1e-5, atol=1e-5, err_msg=f"{name}.{field}")
        np.testing.assert_allclose(
            np.asarray(r_u.eta_history), np.asarray(r_s.eta_history),
            rtol=1e-4, atol=1e-5, err_msg=f"{name}.eta_history")


class TestShardedSampledEquivalence:
    """Fixed-size sampled runs match between the sharded mesh (8 forced host
    devices under the CI leg) and the single-device engine: the mask derives
    from the replicated round key, so every shard sees the same cohort."""

    @pytest.mark.parametrize("name", ["ldp-fedexp-gauss", "cdp-fedexp",
                                      "cdp-fedexp-adaptive-clip"])
    def test_fixed_size_sharded_matches_single_device(self, problem, name):
        key = jax.random.PRNGKey(11)
        cohort = CohortSpec(size=13)
        r_1 = _session(problem, name, cohort=cohort).run(key)
        r_m = _session(problem, name, cohort=cohort,
                       mesh=make_client_mesh()).run(key)
        for field in ("final_w", "last_w", "metric_history"):
            np.testing.assert_allclose(
                np.asarray(getattr(r_1, field)), np.asarray(getattr(r_m, field)),
                rtol=1e-5, atol=1e-5, err_msg=f"{name}.{field}")
        np.testing.assert_allclose(
            np.asarray(r_1.eta_history), np.asarray(r_m.eta_history),
            rtol=1e-4, atol=1e-5)

    def test_bernoulli_sharded_matches_single_device(self, problem):
        key = jax.random.PRNGKey(7)
        cohort = CohortSpec(q=0.4)
        r_1 = _session(problem, "cdp-fedexp", cohort=cohort).run(key)
        r_m = _session(problem, "cdp-fedexp", cohort=cohort,
                       mesh=make_client_mesh()).run(key)
        np.testing.assert_allclose(np.asarray(r_1.final_w),
                                   np.asarray(r_m.final_w),
                                   rtol=1e-5, atol=1e-5)


class TestSampledEngineMechanics:
    def test_sampled_run_is_one_program_per_chunk(self, problem):
        """A sampled run compiles ONE chunk program (mask drawn inside the
        scan body): the builder cache registers a single new entry and the
        second identical run is a pure cache hit — no per-round retrace."""
        import repro.fedsim.server as srv
        cohort = CohortSpec(q=0.3)
        sess = _session(problem, "ldp-fedexp-gauss", cohort=cohort)
        before = srv._cached_scan_chunk_fn.cache_info()
        sess.run(jax.random.PRNGKey(0))
        mid = srv._cached_scan_chunk_fn.cache_info()
        assert mid.misses == before.misses + 1
        sess.run(jax.random.PRNGKey(1))
        after = srv._cached_scan_chunk_fn.cache_info()
        assert after.misses == mid.misses and after.hits == mid.hits + 1

    def test_sampled_rounds_vary_cohort(self, problem):
        """Bernoulli rounds draw different cohorts: trajectories differ from
        full participation, yet stay finite and deterministic."""
        key = jax.random.PRNGKey(11)
        r_full = _session(problem, "cdp-fedexp").run(key)
        r_samp = _session(problem, "cdp-fedexp", cohort=CohortSpec(q=0.3)).run(key)
        assert not np.allclose(np.asarray(r_full.final_w),
                               np.asarray(r_samp.final_w))
        assert np.all(np.isfinite(np.asarray(r_samp.final_w)))
        r_again = _session(problem, "cdp-fedexp", cohort=CohortSpec(q=0.3)).run(key)
        np.testing.assert_array_equal(np.asarray(r_samp.final_w),
                                      np.asarray(r_again.final_w))

    def test_eager_engine_supports_sampling(self, problem):
        """scan == eager for sampled runs too (same round step, same keys)."""
        data, w0 = problem
        alg = make_algorithm("cdp-fedexp", **ALG_KWARGS["cdp-fedexp"])
        kw = dict(train=TrainSpec(rounds=3, tau=TAU, eta_l=ETA_L),
                  cohort=CohortSpec(size=10))
        key = jax.random.PRNGKey(2)
        r_s = FederatedSession(alg, linreg_loss, w0, data.client_batches(), **kw).run(key)
        r_e = FederatedSession(alg, linreg_loss, w0, data.client_batches(),
                               engine=EngineSpec(engine="eager"), **kw).run(key)
        np.testing.assert_array_equal(np.asarray(r_s.final_w), np.asarray(r_e.final_w))


class TestMaskDraws:
    def test_fixed_size_mask(self):
        cohort = CohortSpec(size=10)
        mask = np.asarray(cohort.round_mask(jax.random.PRNGKey(0), M))
        assert mask.shape == (M,) and set(np.unique(mask)) <= {0.0, 1.0}
        assert mask.sum() == 10

    def test_with_replacement_mask(self):
        cohort = CohortSpec(size=30, replace=True)
        mask = np.asarray(cohort.round_mask(jax.random.PRNGKey(0), 12))
        assert mask.sum() == 30            # multiplicities sum to the draws
        assert mask.max() >= 2.0           # 30 draws over 12 slots must repeat

    def test_bernoulli_mask_rate(self):
        cohort = CohortSpec(q=0.25)
        draws = np.stack([
            np.asarray(cohort.round_mask(jax.random.PRNGKey(s), 400))
            for s in range(32)])
        rate = draws.mean()
        assert abs(rate - 0.25) < 5 * np.sqrt(0.25 * 0.75 / draws.size)

    def test_mask_keyed_by_round(self):
        cohort = CohortSpec(q=0.5)
        m1 = np.asarray(cohort.round_mask(jax.random.PRNGKey(0), 64))
        m2 = np.asarray(cohort.round_mask(jax.random.PRNGKey(1), 64))
        m1b = np.asarray(cohort.round_mask(jax.random.PRNGKey(0), 64))
        assert not np.array_equal(m1, m2)
        np.testing.assert_array_equal(m1, m1b)

    def test_empty_bernoulli_round_is_noop_not_nan(self, problem):
        """q small enough that some round draws zero clients: the clamped
        count makes it a zero-update round, never NaN poison."""
        data, w0 = problem
        alg = make_algorithm("fedavg")
        sess = FederatedSession(alg, linreg_loss, w0, data.client_batches(),
                                train=TrainSpec(rounds=8, tau=1, eta_l=ETA_L),
                                cohort=CohortSpec(q=1e-4))
        r = sess.run(jax.random.PRNGKey(0))
        assert np.all(np.isfinite(np.asarray(r.final_w)))
