"""Fault-tolerant rounds (FaultSpec, DESIGN.md §13).

Four layers of evidence:
  1. ``FaultSpec()`` IS the unfaulted engine — bit-for-bit for every
     registry algorithm (the all-at-rest spec normalizes to None, so the
     session compiles the identical program as before this feature existed).
  2. Faulty runs are the same computation on every engine: 30% dropout +
     20% stragglers + 2% corrupted updates agree bit-exactly between scan
     and eager, and to reduction-reorder tolerance on the streaming and
     client-sharded engines (8 forced host devices under the CI leg) —
     while staying finite end to end.
  3. The divergence watchdog + auto-recovery: a seeded divergence trips
     the in-scan watchdog (and its eager twin), surfaces the faulting
     round, and ``run(on_divergence=RecoveryPolicy(...))`` rolls back to
     the newest intact checkpoint and resumes BIT-EXACTLY what an unkilled
     run produces; retried rounds join the privacy composition.
  4. Checkpoint corruption: truncated / garbage archives and mangled
     sidecars surface as ``ValueError`` naming the file, transient OSErrors
     retry with backoff, and ``load_latest_intact`` falls back past corrupt
     steps to the newest checkpoint that loads cleanly.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.core import accounting
from repro.core.fedexp import list_algorithms, make_algorithm
from repro.data.synthetic import distance_to_opt, linreg_loss, make_synthetic_linreg
from repro.fedsim import (
    CohortSpec,
    EngineSpec,
    FaultSpec,
    FederatedSession,
    RecoveryPolicy,
    ShardSpec,
    StreamSpec,
    TrainSpec,
)
from repro.fedsim.faults import (
    apply_faults,
    fault_masks,
    finite_rows,
    inject_corruption,
    resolve_steps,
    sanitize_moments,
)
from repro.launch.mesh import make_client_mesh

# M not divisible by 8 (nor 2/4): the sharded legs exercise zero-weight
# padding COMBINED with the fault masks
M, D, TAU, ETA_L, ROUNDS = 44, 24, 3, 0.1, 5

N_DEV = len(jax.devices())

ALG_KWARGS = {
    "fedavg": {},
    "fedexp": {},
    "dp-fedavg-ldp-gauss": dict(clip_norm=0.3, sigma=0.21),
    "ldp-fedexp-gauss": dict(clip_norm=0.3, sigma=0.21),
    "dp-fedavg-privunit": dict(clip_norm=0.3, eps0=2.0, eps1=2.0, eps2=2.0, dim=D),
    "ldp-fedexp-privunit": dict(clip_norm=0.3, eps0=2.0, eps1=2.0, eps2=2.0, dim=D),
    "dp-fedavg-cdp": dict(clip_norm=0.3, sigma=0.2, num_clients=M),
    "cdp-fedexp": dict(clip_norm=0.3, sigma=0.2, num_clients=M),
    "dp-fedadam-cdp": dict(clip_norm=0.3, sigma=0.2, num_clients=M, server_lr=0.05),
    "cdp-fedexp-adaptive-clip": dict(z_mult=0.5, num_clients=M, dim=D),
    "ldp-gauss-fedadam": dict(clip_norm=0.3, sigma=0.21, server_lr=0.05),
    "cdp-fedmom": dict(clip_norm=0.3, sigma=0.2, num_clients=M, server_lr=0.5),
    "privunit-fedexp-adaptive-clip": dict(eps0=2.0, eps1=2.0, eps2=2.0,
                                          z_mult=0.5, num_clients=M, dim=D),
    # §17 heterogeneous-privacy tier (deep fault parity in test_schedules.py;
    # here every name rides the FaultSpec() normalization pin)
    "ldp-fedexp-perclient": dict(clip_norm=0.3,
                                 epsilons=tuple(2.0 + 0.5 * (i % 4)
                                                for i in range(M)),
                                 delta=1e-5),
    "ldp-fedexp-schedule": dict(clip_norm=0.3, sigma=0.21, decay=0.9),
    "cdp-fedexp-schedule": dict(clip_norm=0.3, sigma=0.2, num_clients=M,
                                decay=0.9),
    "dp-scaffold": dict(clip_norm=0.3, sigma=0.2, num_clients=M,
                        central=True, tau=TAU, eta_l=ETA_L),
}


def _local(name):
    # dp-scaffold's pairing validation requires the control-variate LocalSpec
    from repro.fedsim import LocalSpec
    return (dict(local=LocalSpec(control_variates=True))
            if name == "dp-scaffold" else {})

# the acceptance fault model: 30% dropout + stragglers cut to 1 of TAU local
# steps + 2% corrupted (NaN) updates, every class active at once
FAULT = FaultSpec(dropout=0.3, straggler=0.2, straggler_steps=1, corrupt=0.02)


@pytest.fixture(scope="module")
def problem():
    data = make_synthetic_linreg(jax.random.PRNGKey(3), M, D)
    return data, jnp.zeros(D)


def _session(problem, name, *, fault=FAULT, rounds=ROUNDS, mesh=None,
             **spec_kw):
    data, w0 = problem
    alg = make_algorithm(name, **ALG_KWARGS[name])
    return FederatedSession(
        alg, linreg_loss, w0, data.client_batches(),
        train=spec_kw.pop("train", TrainSpec(rounds=rounds, tau=TAU, eta_l=ETA_L)),
        shard=ShardSpec(mesh=mesh), fault=fault,
        eval_fn=distance_to_opt(data.w_star), **{**_local(name), **spec_kw})


class TestSpecValidation:
    def test_registry_is_covered(self):
        """Every registered algorithm appears in this file's kwargs table —
        a new registration must add itself to the fault parity matrix."""
        assert sorted(ALG_KWARGS) == list_algorithms()

    def test_rates_validated(self):
        for field in ("dropout", "straggler", "corrupt"):
            with pytest.raises(ValueError, match=field):
                FaultSpec(**{field: 1.0})
            with pytest.raises(ValueError, match=field):
                FaultSpec(**{field: -0.1})
        with pytest.raises(ValueError, match="straggler_steps"):
            FaultSpec(straggler_steps=0)
        with pytest.raises(ValueError, match="eta_max"):
            FaultSpec(eta_max=0.0)

    def test_activity_properties(self):
        assert not FaultSpec().is_active and not FaultSpec().injects
        assert FaultSpec(dropout=0.1).injects
        assert FaultSpec(watchdog=True).is_active
        assert not FaultSpec(watchdog=True).injects

    def test_batched_engine_rejects_faults(self, problem):
        sess = _session(problem, "fedavg", fault=FaultSpec(dropout=0.1))
        with pytest.raises(ValueError, match="fault"):
            sess.run_batched(jnp.stack([jax.random.PRNGKey(0)]))

    def test_on_divergence_requires_watchdog_and_dir(self, problem, tmp_path):
        policy = RecoveryPolicy(max_retries=1)
        with pytest.raises(ValueError, match="watchdog"):
            _session(problem, "fedavg", fault=FaultSpec()).run(
                jax.random.PRNGKey(0), checkpoint_dir=str(tmp_path),
                on_divergence=policy)
        with pytest.raises(ValueError, match="checkpoint_dir"):
            _session(problem, "fedavg", fault=FaultSpec(watchdog=True)).run(
                jax.random.PRNGKey(0), on_divergence=policy)
        with pytest.raises(ValueError, match="max_retries"):
            RecoveryPolicy(max_retries=0)
        with pytest.raises(ValueError, match="backoff"):
            RecoveryPolicy(backoff=-1.0)


class TestFaultFreeNormalization:
    @pytest.mark.parametrize("name", sorted(ALG_KWARGS))
    def test_default_spec_is_bit_exact_with_unfaulted(self, problem, name):
        """FaultSpec() normalizes to the unfaulted engine path: the SAME
        compiled program, so bit-exactness is structural — pinned for every
        registry algorithm so the normalization never regresses."""
        key = jax.random.PRNGKey(11)
        r_f = _session(problem, name, fault=FaultSpec()).run(key)
        r_u = FederatedSession(
            make_algorithm(name, **ALG_KWARGS[name]), linreg_loss,
            problem[1], problem[0].client_batches(),
            train=TrainSpec(rounds=ROUNDS, tau=TAU, eta_l=ETA_L),
            eval_fn=distance_to_opt(problem[0].w_star),
            **_local(name)).run(key)
        for field in ("final_w", "last_w", "eta_history", "metric_history",
                      "eta_naive_history", "eta_target_history"):
            np.testing.assert_array_equal(
                np.asarray(getattr(r_u, field)), np.asarray(getattr(r_f, field)),
                err_msg=f"{name}.{field}")

    def test_watchdog_only_spec_matches_unfaulted_values(self, problem):
        """An armed watchdog on a healthy run changes the carry plumbing but
        not one bit of the trajectory."""
        key = jax.random.PRNGKey(11)
        r_u = _session(problem, "cdp-fedexp", fault=FaultSpec()).run(key)
        r_w = _session(problem, "cdp-fedexp",
                       fault=FaultSpec(watchdog=True)).run(key)
        assert r_w.fault_round is None
        for field in ("final_w", "last_w", "eta_history", "metric_history"):
            np.testing.assert_array_equal(
                np.asarray(getattr(r_u, field)), np.asarray(getattr(r_w, field)),
                err_msg=field)


class TestFaultyEngineParity:
    """The acceptance fault model on all four engines: same trajectory,
    finite everywhere."""

    @pytest.mark.parametrize("name", ["fedexp", "ldp-fedexp-gauss",
                                      "cdp-fedexp", "dp-fedadam-cdp",
                                      "cdp-fedexp-adaptive-clip"])
    def test_scan_matches_eager_bit_exact(self, problem, name):
        key = jax.random.PRNGKey(7)
        r_s = _session(problem, name).run(key)
        r_e = _session(problem, name,
                       engine=EngineSpec(engine="eager")).run(key)
        for field in ("final_w", "last_w", "eta_history", "metric_history"):
            np.testing.assert_array_equal(
                np.asarray(getattr(r_s, field)), np.asarray(getattr(r_e, field)),
                err_msg=f"{name}.{field}")
        assert np.all(np.isfinite(np.asarray(r_s.final_w)))

    @pytest.mark.parametrize("name", ["ldp-fedexp-gauss", "cdp-fedexp"])
    def test_scan_matches_stream(self, problem, name):
        key = jax.random.PRNGKey(7)
        r_d = _session(problem, name).run(key)
        r_t = _session(problem, name, engine=EngineSpec(engine="stream"),
                       stream=StreamSpec(chunk_clients=16)).run(key)
        for field in ("final_w", "last_w", "metric_history"):
            np.testing.assert_allclose(
                np.asarray(getattr(r_d, field)), np.asarray(getattr(r_t, field)),
                rtol=1e-5, atol=1e-5, err_msg=f"{name}.{field}")
        assert np.all(np.isfinite(np.asarray(r_t.final_w)))

    @pytest.mark.parametrize("name", ["ldp-fedexp-gauss", "cdp-fedexp",
                                      "cdp-fedexp-adaptive-clip"])
    def test_sharded_matches_single_device(self, problem, name):
        """Fault draws derive from the replicated round key and slice per
        shard, so the sharded faulty run is the single-device faulty run
        (8 forced host devices under the CI leg; 1 device = 1-shard mesh)."""
        key = jax.random.PRNGKey(7)
        r_1 = _session(problem, name).run(key)
        r_m = _session(problem, name, mesh=make_client_mesh()).run(key)
        for field in ("final_w", "last_w", "metric_history"):
            np.testing.assert_allclose(
                np.asarray(getattr(r_1, field)), np.asarray(getattr(r_m, field)),
                rtol=1e-5, atol=1e-5, err_msg=f"{name}.{field}")
        np.testing.assert_allclose(np.asarray(r_1.eta_history),
                                   np.asarray(r_m.eta_history),
                                   rtol=1e-4, atol=1e-5)

    def test_sharded_stream_matches_single_device(self, problem):
        key = jax.random.PRNGKey(7)
        r_1 = _session(problem, "cdp-fedexp").run(key)
        r_m = _session(problem, "cdp-fedexp", mesh=make_client_mesh(),
                       engine=EngineSpec(engine="stream"),
                       stream=StreamSpec(chunk_clients=8)).run(key)
        np.testing.assert_allclose(np.asarray(r_1.final_w),
                                   np.asarray(r_m.final_w),
                                   rtol=1e-5, atol=1e-5)

    def test_faulty_run_is_deterministic_and_differs_from_clean(self, problem):
        key = jax.random.PRNGKey(9)
        r_f1 = _session(problem, "cdp-fedexp").run(key)
        r_f2 = _session(problem, "cdp-fedexp").run(key)
        r_clean = _session(problem, "cdp-fedexp", fault=FaultSpec()).run(key)
        np.testing.assert_array_equal(np.asarray(r_f1.final_w),
                                      np.asarray(r_f2.final_w))
        assert not np.allclose(np.asarray(r_f1.final_w),
                               np.asarray(r_clean.final_w))

    def test_faults_compose_with_sampling(self, problem):
        """Dropout stacks on a sampled cohort: the effective mask is the
        product, the run stays finite, and scan == eager still holds."""
        key = jax.random.PRNGKey(5)
        kw = dict(cohort=CohortSpec(q=0.6))
        r_s = _session(problem, "cdp-fedexp", **kw).run(key)
        r_e = _session(problem, "cdp-fedexp",
                       engine=EngineSpec(engine="eager"), **kw).run(key)
        np.testing.assert_array_equal(np.asarray(r_s.final_w),
                                      np.asarray(r_e.final_w))
        assert np.all(np.isfinite(np.asarray(r_s.final_w)))

    def test_faulty_run_resumes_bit_exact(self, problem, tmp_path):
        """Fault draws derive from fold_in(round key, FAULT_TAG): resume
        redraws the identical faults."""
        key = jax.random.PRNGKey(11)
        half = ROUNDS // 2
        r_full = _session(problem, "cdp-fedexp",
                          engine=EngineSpec(chunk_rounds=half)).run(key)
        _session(problem, "cdp-fedexp", rounds=half).run(
            key, checkpoint_dir=str(tmp_path))
        r_res = _session(problem, "cdp-fedexp").resume(str(tmp_path))
        for field in ("final_w", "last_w", "eta_history", "metric_history"):
            np.testing.assert_array_equal(
                np.asarray(getattr(r_full, field)),
                np.asarray(getattr(r_res, field)), err_msg=field)

    def test_near_total_dropout_stays_finite(self, problem):
        """dropout=0.99 over M=44 clients makes empty rounds likely: the
        clamped realized count turns them into zero-update no-ops, never
        NaN.  The key is pinned so at least one round IS fully empty."""
        key = jax.random.PRNGKey(0)
        fault = FaultSpec(dropout=0.99)
        sess = _session(problem, "fedavg", fault=fault, rounds=8,
                        train=TrainSpec(rounds=8, tau=1, eta_l=ETA_L))
        empty = []
        for t in range(8):
            alive, _, _ = fault_masks(fault, jax.random.fold_in(key, t), M)
            empty.append(float(jnp.sum(alive)) == 0.0)
        assert any(empty), "pin a key that actually draws an empty round"
        r = sess.run(key)
        assert np.all(np.isfinite(np.asarray(r.final_w)))
        assert np.all(np.isfinite(np.asarray(r.eta_history)))


class TestFaultDraws:
    def test_masks_deterministic_and_round_keyed(self):
        fault = FaultSpec(dropout=0.3, straggler=0.2, corrupt=0.1)
        k0, k1 = jax.random.PRNGKey(0), jax.random.PRNGKey(1)
        a0, s0, c0 = fault_masks(fault, k0, 64)
        a0b, s0b, c0b = fault_masks(fault, k0, 64)
        a1, _, _ = fault_masks(fault, k1, 64)
        for x, y in ((a0, a0b), (s0, s0b), (c0, c0b)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        assert not np.array_equal(np.asarray(a0), np.asarray(a1))

    def test_disabled_classes_draw_nothing(self):
        alive, strag, corrupt = fault_masks(FaultSpec(dropout=0.5),
                                            jax.random.PRNGKey(0), 32)
        assert strag is None and corrupt is None
        assert set(np.unique(np.asarray(alive))) <= {0.0, 1.0}

    def test_dropout_rate_statistic(self):
        fault = FaultSpec(dropout=0.3)
        draws = np.stack([
            np.asarray(fault_masks(fault, jax.random.PRNGKey(s), 400)[0])
            for s in range(32)])
        alive_rate = draws.mean()
        assert abs(alive_rate - 0.7) < 5 * np.sqrt(0.3 * 0.7 / draws.size)

    def test_resolve_steps_caps_at_tau(self):
        fault = FaultSpec(straggler=0.5, straggler_steps=7)
        strag = jnp.array([1.0, 0.0, 1.0])
        steps = np.asarray(resolve_steps(fault, strag, 3))
        np.testing.assert_array_equal(steps, [3, 3, 3])  # capped at tau
        fault = FaultSpec(straggler=0.5, straggler_steps=1)
        steps = np.asarray(resolve_steps(fault, strag, 3))
        np.testing.assert_array_equal(steps, [1, 3, 1])

    def test_apply_faults_zero_weights_bad_rows(self):
        deltas = jnp.ones((4, 3))
        corrupt = jnp.array([0.0, 1.0, 0.0, 0.0])
        alive = jnp.array([1.0, 1.0, 0.0, 1.0])
        out, eff = apply_faults(deltas, jnp.ones(4), alive, corrupt)
        np.testing.assert_array_equal(np.asarray(eff), [1.0, 0.0, 0.0, 1.0])
        # failed rows are where-zeroed at the source: no NaN survives
        assert np.all(np.isfinite(np.asarray(out)))
        np.testing.assert_array_equal(np.asarray(out[1]), np.zeros(3))
        np.testing.assert_array_equal(np.asarray(out[2]), np.zeros(3))

    def test_finite_screen_catches_organic_divergence(self):
        """A genuinely diverged client (Inf it produced itself, no injection)
        degrades identically to an injected corruption."""
        deltas = jnp.ones((3, 2)).at[1, 0].set(jnp.inf)
        out, eff = apply_faults(deltas, jnp.ones(3), None, None)
        np.testing.assert_array_equal(np.asarray(eff), [1.0, 0.0, 1.0])
        assert np.all(np.isfinite(np.asarray(out)))

    def test_inject_corruption_and_finite_rows(self):
        deltas = inject_corruption(jnp.ones((3, 2)), jnp.array([0.0, 1.0, 0.0]))
        np.testing.assert_array_equal(np.asarray(finite_rows(deltas)),
                                      [1.0, 0.0, 1.0])

    def test_sanitize_moments_zeroes_nonfinite(self):
        moments = {"a": jnp.array([1.0, jnp.nan, jnp.inf]),
                   "n": jnp.int32(3)}
        clean = sanitize_moments(moments)
        np.testing.assert_array_equal(np.asarray(clean["a"]), [1.0, 0.0, 0.0])
        assert int(clean["n"]) == 3


def _poison(carry, attempt):
    """Divergence seed for recovery tests: attempt 0 runs with an Inf model
    coordinate (trips the watchdog at its first round), retries run clean."""
    if attempt > 0:
        return carry
    w = carry[0].at[0].set(jnp.inf)
    return (w,) + tuple(carry[1:])


class TestWatchdog:
    def test_eta_max_trips_scan_and_eager_identically(self, problem):
        """fedexp's eta_g >= 1 always, so eta_max=0.5 trips at round 0 on
        both engines — the compiled lax.cond watchdog and its host-side
        eager twin surface the same faulting round."""
        fault = FaultSpec(watchdog=True, eta_max=0.5)
        key = jax.random.PRNGKey(3)
        r_s = _session(problem, "fedexp", fault=fault).run(key)
        r_e = _session(problem, "fedexp", fault=fault,
                       engine=EngineSpec(engine="eager")).run(key)
        assert r_s.fault_round == 0 and r_e.fault_round == 0
        # the faulting round's update is NOT committed: params stay at w0
        np.testing.assert_array_equal(np.asarray(r_s.last_w),
                                      np.asarray(problem[1]))
        # the faulting round records its real (offending) eta, frozen rounds
        # emit NaN — identically on both engines
        eta_s, eta_e = np.asarray(r_s.eta_history), np.asarray(r_e.eta_history)
        np.testing.assert_array_equal(eta_s, eta_e)
        assert np.isfinite(eta_s[0]) and eta_s[0] > 0.5
        assert np.isnan(eta_s[1:]).all()

    def test_mid_run_trip_freezes_remaining_rounds(self, problem, tmp_path):
        """Poisoned carry at round 0 via the injection hook: the watchdog
        freezes every round of the chunk and the pre-poison histories are
        untouched."""
        fault = FaultSpec(watchdog=True)
        sess = _session(problem, "cdp-fedexp", fault=fault)
        sess._inject_divergence = _poison
        r = sess.run(jax.random.PRNGKey(11))
        assert r.fault_round == 0
        assert np.isnan(np.asarray(r.eta_history)[1:]).all()

    def test_healthy_watchdog_run_reports_no_fault(self, problem):
        r = _session(problem, "cdp-fedexp",
                     fault=FaultSpec(watchdog=True)).run(jax.random.PRNGKey(0))
        assert r.fault_round is None
        assert np.all(np.isfinite(np.asarray(r.eta_history)))


class TestRecovery:
    def test_rollback_resume_is_bit_exact_with_unkilled_run(self, problem,
                                                            tmp_path):
        """The acceptance criterion: poison attempt 0, recover from the
        initial checkpoint, and match the never-killed reference run
        bit-exactly (same chunk boundaries)."""
        fault = FaultSpec(watchdog=True)
        key = jax.random.PRNGKey(11)
        r_ref = _session(problem, "cdp-fedexp", fault=fault).run(key)

        sess = _session(problem, "cdp-fedexp", fault=fault)
        sess._inject_divergence = _poison
        r_rec = sess.run(key, checkpoint_dir=str(tmp_path),
                         checkpoint_every=2,
                         on_divergence=RecoveryPolicy(max_retries=2))
        assert r_rec.fault_round is None
        assert sess._rounds_retried == 1  # tripped at round 0, replayed it
        for field in ("final_w", "last_w", "eta_history", "metric_history"):
            np.testing.assert_array_equal(
                np.asarray(getattr(r_ref, field)),
                np.asarray(getattr(r_rec, field)), err_msg=field)

    def test_recovery_from_mid_run_checkpoint(self, problem, tmp_path):
        """Poison every attempt until the retries run out — then exhaustion
        surfaces the fault; with enough retries the run completes."""
        fault = FaultSpec(watchdog=True)
        key = jax.random.PRNGKey(11)

        sess = _session(problem, "cdp-fedexp", fault=fault,
                        engine=EngineSpec(chunk_rounds=2))
        calls = []

        def poison_twice(carry, attempt):
            calls.append(attempt)
            if attempt >= 2:
                return carry
            w = carry[0].at[0].set(jnp.nan)
            return (w,) + tuple(carry[1:])

        sess._inject_divergence = poison_twice
        r = sess.run(key, checkpoint_dir=str(tmp_path), checkpoint_every=2,
                     on_divergence=RecoveryPolicy(max_retries=3))
        assert r.fault_round is None
        assert calls == [0, 1, 2]
        assert np.all(np.isfinite(np.asarray(r.final_w)))
        r_ref = _session(problem, "cdp-fedexp", fault=fault,
                         engine=EngineSpec(chunk_rounds=2)).run(key)
        np.testing.assert_array_equal(np.asarray(r_ref.final_w),
                                      np.asarray(r.final_w))

    def test_retry_exhaustion_surfaces_fault(self, problem, tmp_path):
        fault = FaultSpec(watchdog=True)
        sess = _session(problem, "cdp-fedexp", fault=fault)

        def always_poison(carry, attempt):
            w = carry[0].at[0].set(jnp.inf)
            return (w,) + tuple(carry[1:])

        sess._inject_divergence = always_poison
        r = sess.run(jax.random.PRNGKey(0), checkpoint_dir=str(tmp_path),
                     checkpoint_every=2,
                     on_divergence=RecoveryPolicy(max_retries=2))
        assert r.fault_round is not None

    def test_tripped_state_never_persisted(self, problem, tmp_path):
        """A watchdog-tripped chunk must not write a checkpoint — a trip at
        round 0 under per-round checkpointing leaves the directory empty."""
        fault = FaultSpec(watchdog=True, eta_max=0.5)
        _session(problem, "fedexp", fault=fault).run(
            jax.random.PRNGKey(3), checkpoint_dir=str(tmp_path),
            checkpoint_every=1)
        assert ckpt.checkpoint_steps(str(tmp_path)) == []


class TestPrivacyUnderFaults:
    def test_realized_participation(self):
        assert accounting.realized_participation(0.5) == 0.5
        assert accounting.realized_participation(0.5, 0.2) == pytest.approx(0.4)
        with pytest.raises(ValueError, match="dropout"):
            accounting.realized_participation(0.5, 1.0)

    def test_report_composes_retried_rounds(self, problem, tmp_path):
        """Every executed round releases: after a rollback the replayed
        rounds join the composition, so eps grows."""
        fault = FaultSpec(watchdog=True)
        sess = _session(problem, "cdp-fedexp", fault=fault)
        base = sess.privacy_report(1e-5)
        sess._inject_divergence = _poison
        sess.run(jax.random.PRNGKey(11), checkpoint_dir=str(tmp_path),
                 checkpoint_every=2, on_divergence=RecoveryPolicy(max_retries=2))
        assert sess._rounds_retried >= 1
        retried = sess.privacy_report(1e-5)
        assert retried.eps_numerical > base.eps_numerical

    def test_report_uses_realized_participation(self, problem):
        """Dropout shrinks the realized per-round participation: the report
        matches cdp_budget at q * (1 - dropout), not nominal q."""
        q, dropout = 0.5, 0.3
        samp = _session(problem, "cdp-fedexp", fault=FaultSpec(dropout=dropout),
                        cohort=CohortSpec(q=q))
        kw = ALG_KWARGS["cdp-fedexp"]
        sigma_xi = D * kw["sigma"] ** 2 / M
        want = accounting.cdp_budget(
            kw["clip_norm"], kw["sigma"], M, ROUNDS, 1e-5, sigma_xi=sigma_xi,
            sampling_q=accounting.realized_participation(q, dropout))
        got = samp.privacy_report(1e-5)
        assert got.eps_numerical == pytest.approx(want.eps_numerical)
        assert got.mu == pytest.approx(want.mu)


class TestCheckpointCorruption:
    def _save(self, d, step, value=0.0):
        ckpt.save_checkpoint(str(d), step, {"w": jnp.full(4, value)},
                             extra={"k": "v"})

    def test_truncated_npz_raises_value_error(self, tmp_path):
        self._save(tmp_path, 1)
        path = tmp_path / "ckpt_00000001.npz"
        path.write_bytes(path.read_bytes()[:20])
        with pytest.raises(ValueError, match="corrupt checkpoint"):
            ckpt.load_checkpoint(str(tmp_path), {"w": jnp.zeros(4)})

    def test_garbage_npz_raises_value_error(self, tmp_path):
        self._save(tmp_path, 1)
        (tmp_path / "ckpt_00000001.npz").write_bytes(b"not a zip archive")
        with pytest.raises(ValueError, match="corrupt checkpoint"):
            ckpt.load_checkpoint(str(tmp_path), {"w": jnp.zeros(4)})

    def test_mangled_sidecar_raises_value_error(self, tmp_path):
        self._save(tmp_path, 1)
        (tmp_path / "ckpt_00000001.json").write_text("{not json")
        with pytest.raises(ValueError, match="sidecar"):
            ckpt.load_checkpoint(str(tmp_path), {"w": jnp.zeros(4)})

    def test_checksum_mismatch_detected(self, tmp_path):
        """Bit-rot INSIDE a structurally-valid archive: same length, flipped
        byte — only the sha256 catches it."""
        self._save(tmp_path, 1)
        path = tmp_path / "ckpt_00000001.npz"
        blob = bytearray(path.read_bytes())
        blob[-1] ^= 0xFF
        path.write_bytes(bytes(blob))
        with pytest.raises(ValueError, match="sha256 mismatch"):
            ckpt.load_checkpoint(str(tmp_path), {"w": jnp.zeros(4)})

    def test_latest_intact_falls_back_past_corruption(self, tmp_path):
        self._save(tmp_path, 2, value=2.0)
        self._save(tmp_path, 4, value=4.0)
        (tmp_path / "ckpt_00000004.npz").write_bytes(b"garbage")
        step, params, meta = ckpt.load_latest_intact(
            str(tmp_path), {"w": jnp.zeros(4)})
        assert step == 2 and meta["step"] == 2
        np.testing.assert_array_equal(np.asarray(params["w"]), np.full(4, 2.0))

    def test_latest_intact_none_intact_lists_failures(self, tmp_path):
        self._save(tmp_path, 1)
        self._save(tmp_path, 2)
        for f in os.listdir(tmp_path):
            if f.endswith(".npz"):
                (tmp_path / f).write_bytes(b"junk")
        with pytest.raises(ValueError, match="no intact checkpoint"):
            ckpt.load_latest_intact(str(tmp_path), {"w": jnp.zeros(4)})

    def test_latest_intact_empty_dir(self, tmp_path):
        with pytest.raises(FileNotFoundError):
            ckpt.load_latest_intact(str(tmp_path), {"w": jnp.zeros(4)})

    def test_callable_template(self, tmp_path):
        self._save(tmp_path, 3)
        step, params, _ = ckpt.load_latest_intact(
            str(tmp_path), lambda s: {"w": jnp.zeros(4)})
        assert step == 3

    def test_transient_oserror_retried_with_backoff(self, tmp_path,
                                                    monkeypatch):
        self._save(tmp_path, 1)
        attempts = []
        real = ckpt._load_once

        def flaky(directory, template, step):
            attempts.append(step)
            if len(attempts) < 3:
                raise OSError("transient I/O blip")
            return real(directory, template, step)

        monkeypatch.setattr(ckpt, "_load_once", flaky)
        sleeps = []
        monkeypatch.setattr(ckpt.time, "sleep", sleeps.append)
        params, meta = ckpt.load_checkpoint(str(tmp_path), {"w": jnp.zeros(4)},
                                            retries=3, backoff=0.1)
        assert len(attempts) == 3
        assert sleeps == [pytest.approx(0.1), pytest.approx(0.2)]  # linear

    def test_corruption_never_retried(self, tmp_path, monkeypatch):
        self._save(tmp_path, 1)
        (tmp_path / "ckpt_00000001.npz").write_bytes(b"junk")
        attempts = []
        real = ckpt._load_once

        def counting(directory, template, step):
            attempts.append(step)
            return real(directory, template, step)

        monkeypatch.setattr(ckpt, "_load_once", counting)
        with pytest.raises(ValueError):
            ckpt.load_checkpoint(str(tmp_path), {"w": jnp.zeros(4)}, retries=5)
        assert len(attempts) == 1  # permanent failure: no retry loop

    def test_session_resume_skips_corrupt_latest(self, problem, tmp_path):
        """End-to-end fallback: corrupt the newest checkpoint of a periodic
        run; resume rolls back to the previous intact one and still finishes
        the full round count."""
        key = jax.random.PRNGKey(11)
        sess = _session(problem, "cdp-fedexp", fault=FaultSpec())
        r_full = sess.run(key, checkpoint_dir=str(tmp_path), checkpoint_every=2)
        steps = ckpt.checkpoint_steps(str(tmp_path))
        newest = steps[-1]
        (tmp_path / f"ckpt_{newest:08d}.npz").write_bytes(b"bit rot")
        r_res = _session(problem, "cdp-fedexp",
                         fault=FaultSpec()).resume(str(tmp_path))
        np.testing.assert_array_equal(np.asarray(r_full.final_w),
                                      np.asarray(r_res.final_w))


class TestGarbageRowsDeterministic:
    """Deterministic twin of tests/test_faults_property.py (which needs
    hypothesis): hand-picked worst-case garbage blocks through the same
    degradation contract, so the invariant is exercised even where
    hypothesis is unavailable."""

    def test_garbage_block_degrades_to_finite(self):
        deltas = jnp.array([[1.0, 2.0],
                            [jnp.nan, 0.0],
                            [jnp.inf, -jnp.inf],
                            [0.0, 1e38],
                            [3.0, 4.0]])
        mask = jnp.array([1.0, 1.0, 1.0, 0.0, 1.0])
        alive = jnp.array([1.0, 1.0, 1.0, 1.0, 0.0])
        out, eff = apply_faults(deltas, mask, alive, None)
        out, eff = np.asarray(out), np.asarray(eff)
        assert np.all(np.isfinite(out))
        # NaN/Inf rows zero-weighted; masked-out and dropped rows stay out;
        # the finite 1e38 row survives (it is garbage but not poison)
        np.testing.assert_array_equal(eff, [1.0, 0.0, 0.0, 0.0, 0.0])
        assert np.all(eff <= np.asarray(mask))

    @pytest.mark.parametrize("engine", ["scan", "stream"])
    def test_heavy_corruption_keeps_model_finite(self, problem, engine):
        """50% corrupted + 60% dropout for every registry algorithm's
        moment protocol representative set: global model and moments stay
        finite on the dense and streaming engines."""
        data, w0 = problem
        for name in ("fedavg", "ldp-fedexp-gauss", "cdp-fedexp",
                     "dp-fedadam-cdp", "cdp-fedexp-adaptive-clip",
                     "privunit-fedexp-adaptive-clip"):
            alg = make_algorithm(name, **ALG_KWARGS[name])
            kw = dict(engine=EngineSpec(engine="stream"),
                      stream=StreamSpec(chunk_clients=16)) \
                if engine == "stream" else {}
            sess = FederatedSession(
                alg, linreg_loss, w0, data.client_batches(),
                train=TrainSpec(rounds=2, tau=1, eta_l=ETA_L),
                fault=FaultSpec(dropout=0.6, corrupt=0.5), **kw)
            r = sess.run(jax.random.PRNGKey(17))
            assert np.all(np.isfinite(np.asarray(r.final_w))), name
            assert np.all(np.isfinite(np.asarray(r.eta_history))), name
