"""Sparse sampled cohorts: gather == dense parity (DESIGN.md §14).

``CohortSpec(gather=True)`` replaces the all-M masked round with a gathered
(cap, ...) block — O(q·M·d) work instead of O(M·d) — and must be the SAME
release: per-client randomness keys by GLOBAL client index through the slot
table, fault rows gather through the same slots, and the masked-moment
protocol sees identical mask-weighted sums.  The contract pinned here:

* gather == dense sampled at rtol 1e-5 for every registry algorithm and
  every engine combination — scan, sharded, stream (the gather-stream inner
  scan over the SLOT grid), faulted rounds, LocalSpec trainers, weighted
  aggregation (the vector-start row_weights branch), and rounds whose
  realized cohort is EMPTY;
* ``gather_slots`` packs participants in index order, clamps padding slots
  to 0 with zero slot mask, and reports overflow;
* ``resolved_cap`` is exact for fixed-size cohorts, honors ``gather_cap``,
  and gives Bernoulli sampling 6-sigma headroom;
* the kernel layer's ``slots`` entry reduces gathered rows chunk-by-chunk
  to the dense sums.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.compose import (
    FedEXPStep,
    GaussianLDP,
    WeightedAggregation,
    compose_algorithm,
)
from repro.core.fedexp import make_algorithm
from repro.data.synthetic import linreg_loss, make_synthetic_linreg
from repro.fedsim import (
    CohortSpec,
    EngineSpec,
    FaultSpec,
    FederatedSession,
    LocalSpec,
    ShardSpec,
    StreamSpec,
    TrainSpec,
    gather_slots,
)
from repro.kernels.dp_aggregate.ops import dp_aggregate_sums_chunked
from repro.launch.mesh import make_client_mesh

M, D, TAU, ETA_L, ROUNDS, CHUNK = 44, 24, 2, 0.1, 4, 16

ALG_KWARGS = {
    "fedavg": {},
    "fedexp": {},
    "dp-fedavg-ldp-gauss": dict(clip_norm=0.3, sigma=0.21),
    "ldp-fedexp-gauss": dict(clip_norm=0.3, sigma=0.21),
    "dp-fedavg-privunit": dict(clip_norm=0.3, eps0=2.0, eps1=2.0, eps2=2.0, dim=D),
    "ldp-fedexp-privunit": dict(clip_norm=0.3, eps0=2.0, eps1=2.0, eps2=2.0, dim=D),
    "dp-fedavg-cdp": dict(clip_norm=0.3, sigma=0.2, num_clients=M),
    "cdp-fedexp": dict(clip_norm=0.3, sigma=0.2, num_clients=M),
    "dp-fedadam-cdp": dict(clip_norm=0.3, sigma=0.2, num_clients=M, server_lr=0.05),
    "cdp-fedexp-adaptive-clip": dict(z_mult=0.5, num_clients=M, dim=D),
    "ldp-gauss-fedadam": dict(clip_norm=0.3, sigma=0.21, server_lr=0.05),
    "cdp-fedmom": dict(clip_norm=0.3, sigma=0.2, num_clients=M),
    "privunit-fedexp-adaptive-clip": dict(eps0=2.0, eps1=2.0, eps2=2.0, dim=D,
                                          c0=0.5),
}

KEY = jax.random.PRNGKey(11)
Q = 0.4
DENSE = CohortSpec(q=Q)
SPARSE = CohortSpec(q=Q, gather=True)

# the full-registry sweeps are the suite's heaviest tests: these two
# representatives (one LDP, one CDP mechanism) stay unmarked so a local
# `-m "not slow"` run still covers every parity PATH, while the remaining
# registry names carry the `slow` marker (CI always runs the full matrix)
FAST_PARITY = ("ldp-fedexp-gauss", "cdp-fedexp")


def _sweep(names):
    return [n if n in FAST_PARITY else pytest.param(n, marks=pytest.mark.slow)
            for n in names]


@pytest.fixture(scope="module")
def problem():
    data = make_synthetic_linreg(jax.random.PRNGKey(3), M, D)
    return data.client_batches(), jnp.zeros(D)


def _session(problem, name, *, cohort, rounds=ROUNDS, **kw):
    batches, w0 = problem
    alg = make_algorithm(name, **ALG_KWARGS[name])
    return FederatedSession(alg, linreg_loss, w0, batches,
                            train=TrainSpec(rounds=rounds, tau=TAU,
                                            eta_l=ETA_L),
                            cohort=cohort, **kw)


def _stream_kw(chunk=CHUNK):
    return dict(engine=EngineSpec(engine="stream"),
                stream=StreamSpec(chunk_clients=chunk))


def _assert_runs_close(a, b, rtol=1e-5, atol=1e-6):
    np.testing.assert_allclose(np.asarray(a.final_w), np.asarray(b.final_w),
                               rtol=rtol, atol=atol)
    np.testing.assert_allclose(np.asarray(a.last_w), np.asarray(b.last_w),
                               rtol=rtol, atol=atol)
    np.testing.assert_allclose(np.asarray(a.eta_history),
                               np.asarray(b.eta_history),
                               rtol=rtol, atol=atol)


class TestGatherSlots:
    def test_packs_participants_in_index_order(self):
        mask = jnp.asarray([0., 1., 0., 1., 1., 0.])
        slots, slot_mask, overflow = gather_slots(mask, 4)
        np.testing.assert_array_equal(np.asarray(slots), [1, 3, 4, 0])
        np.testing.assert_array_equal(np.asarray(slot_mask), [1., 1., 1., 0.])
        assert float(overflow) == 0.0

    def test_padding_slots_are_zero_masked_client_zero(self):
        """Padding slots clamp to index 0 (real, finite data) but carry zero
        weight — the §9 zero-weight-row discipline."""
        slots, slot_mask, _ = gather_slots(jnp.zeros((5,)), 3)
        np.testing.assert_array_equal(np.asarray(slots), [0, 0, 0])
        np.testing.assert_array_equal(np.asarray(slot_mask), [0., 0., 0.])

    def test_overflow_reports_dropped_participants(self):
        slots, slot_mask, overflow = gather_slots(jnp.ones((6,)), 4)
        np.testing.assert_array_equal(np.asarray(slots), [0, 1, 2, 3])
        assert float(overflow) == 2.0

    def test_weighted_mask_values_ride_the_slot_mask(self):
        """Multiplicity/weight values in the mask survive the gather."""
        mask = jnp.asarray([0., 2., 0., 0.5])
        _, slot_mask, _ = gather_slots(mask, 3)
        np.testing.assert_array_equal(np.asarray(slot_mask), [2., 0.5, 0.])


class TestCohortSpecGather:
    def test_gather_requires_sampling(self):
        with pytest.raises(ValueError, match="nothing to skip"):
            CohortSpec(gather=True)

    def test_gather_rejects_replacement(self):
        with pytest.raises(ValueError, match="replace"):
            CohortSpec(size=4, replace=True, gather=True)

    def test_gather_cap_requires_gather(self):
        with pytest.raises(ValueError, match="gather_cap"):
            CohortSpec(q=0.1, gather_cap=8)
        with pytest.raises(ValueError, match="gather_cap"):
            CohortSpec(q=0.1, gather=True, gather_cap=0)

    def test_resolved_cap(self):
        assert CohortSpec(size=9, gather=True).resolved_cap(M) == 9
        assert CohortSpec(size=99, gather=True).resolved_cap(64) == 64
        assert CohortSpec(q=0.1, gather=True,
                          gather_cap=12).resolved_cap(1000) == 12
        # Bernoulli: qM + 6 sqrt(qM) + 16, never past M
        cap = CohortSpec(q=0.001, gather=True).resolved_cap(10**6)
        assert 1000 < cap < 1400
        assert CohortSpec(q=0.9, gather=True).resolved_cap(10) == 10


class TestGatherMatchesDense:
    @pytest.mark.parametrize("name", _sweep(sorted(ALG_KWARGS)))
    def test_scan_engine(self, problem, name):
        """All 13 registry algorithms: gather == dense sampled, rtol 1e-5."""
        dense = _session(problem, name, cohort=DENSE).run(KEY)
        sparse = _session(problem, name, cohort=SPARSE).run(KEY)
        _assert_runs_close(sparse, dense)

    @pytest.mark.parametrize("name", _sweep(sorted(ALG_KWARGS)))
    def test_gather_stream_engine(self, problem, name):
        """All 13 registry algorithms through the gather-stream inner scan
        (slot grid walked in chunks) against the dense sampled reference."""
        dense = _session(problem, name, cohort=DENSE).run(KEY)
        sparse = _session(problem, name, cohort=SPARSE,
                          **_stream_kw(chunk=8)).run(KEY)
        _assert_runs_close(sparse, dense)

    def test_fixed_size_cohort_is_exact_cap(self, problem):
        """size=k cohorts gather into exactly k slots — and stay bit-exact
        with the dense sampled release when the cap covers one chunk (the
        computation degenerates to the same masked-moments program shape)."""
        dense = _session(problem, "ldp-fedexp-gauss",
                         cohort=CohortSpec(size=9)).run(KEY)
        sparse = _session(problem, "ldp-fedexp-gauss",
                          cohort=CohortSpec(size=9, gather=True)).run(KEY)
        _assert_runs_close(sparse, dense)

    def test_sharded_gather(self, problem):
        """Each shard packs its own slot table; one psum per round (§9 × §14).
        Runs 1- and 8-device under the CI matrix."""
        shard = ShardSpec(mesh=make_client_mesh(), client_axis="clients")
        dense = _session(problem, "cdp-fedexp", cohort=DENSE).run(KEY)
        sparse = _session(problem, "cdp-fedexp", cohort=SPARSE,
                          shard=shard).run(KEY)
        _assert_runs_close(sparse, dense)

    def test_sharded_gather_stream(self, problem):
        shard = ShardSpec(mesh=make_client_mesh(), client_axis="clients")
        dense = _session(problem, "ldp-fedexp-gauss", cohort=DENSE).run(KEY)
        sparse = _session(problem, "ldp-fedexp-gauss", cohort=SPARSE,
                          shard=shard, **_stream_kw(chunk=8)).run(KEY)
        _assert_runs_close(sparse, dense)

    def test_faulted_gather(self, problem):
        """Fault draws stay full-cohort and gather through the same slots:
        a gathered faulty round degrades exactly as its dense reference."""
        fault = FaultSpec(dropout=0.3, straggler=0.2, straggler_steps=1,
                          corrupt=0.02)
        dense = _session(problem, "ldp-fedexp-gauss", cohort=DENSE,
                         fault=fault).run(KEY)
        sparse = _session(problem, "ldp-fedexp-gauss", cohort=SPARSE,
                          fault=fault).run(KEY)
        _assert_runs_close(sparse, dense)

    def test_faulted_gather_stream(self, problem):
        fault = FaultSpec(dropout=0.3, straggler=0.2, straggler_steps=1,
                          corrupt=0.02)
        dense = _session(problem, "fedexp", cohort=DENSE, fault=fault).run(KEY)
        sparse = _session(problem, "fedexp", cohort=SPARSE, fault=fault,
                          **_stream_kw(chunk=8)).run(KEY)
        _assert_runs_close(sparse, dense)

    def test_localspec_trainer_gathers(self):
        """Minibatch/momentum clients shuffle by GLOBAL client index through
        the slot table, so spec trainers are gather-position-independent."""
        samples = jax.random.normal(jax.random.PRNGKey(7), (M, 16, D))

        def sample_loss(w, b):
            return jnp.mean(jnp.square(b @ w - 1.0))

        local = LocalSpec(batch_size=4, momentum=0.5)
        train = TrainSpec(rounds=ROUNDS, tau=TAU, eta_l=ETA_L)
        alg = make_algorithm("fedexp")
        dense = FederatedSession(alg, sample_loss, jnp.zeros(D), samples,
                                 train=train, local=local, cohort=DENSE).run(KEY)
        sparse = FederatedSession(alg, sample_loss, jnp.zeros(D), samples,
                                  train=train, local=local, cohort=SPARSE).run(KEY)
        _assert_runs_close(sparse, dense)

    def test_weighted_aggregation_gathers(self, problem):
        """Per-client weights index by the slot vector (the vector-start
        row_weights branch) — same weighted sums as the dense mask path."""
        batches, w0 = problem
        alg = compose_algorithm(
            GaussianLDP(0.3, 0.21), FedEXPStep(),
            WeightedAggregation(weights=tuple(float(i % 3 + 1)
                                              for i in range(M))))
        train = TrainSpec(rounds=ROUNDS, tau=TAU, eta_l=ETA_L)
        dense = FederatedSession(alg, linreg_loss, w0, batches, train=train,
                                 cohort=DENSE).run(KEY)
        sparse = FederatedSession(alg, linreg_loss, w0, batches, train=train,
                                  cohort=SPARSE).run(KEY)
        _assert_runs_close(sparse, dense)

    def test_empty_realized_cohort(self, problem):
        """q small enough that some rounds sample NOBODY: the gathered round
        must resolve to the same zero-update no-op as the dense empty round
        (clamped counts — no NaN), across scan and gather-stream."""
        cohort_d = CohortSpec(q=0.01)
        cohort_s = CohortSpec(q=0.01, gather=True)
        dense = _session(problem, "fedexp", cohort=cohort_d, rounds=6).run(KEY)
        sparse = _session(problem, "fedexp", cohort=cohort_s, rounds=6).run(KEY)
        assert np.all(np.isfinite(np.asarray(sparse.final_w)))
        _assert_runs_close(sparse, dense)
        streamed = _session(problem, "fedexp", cohort=cohort_s, rounds=6,
                            **_stream_kw(chunk=8)).run(KEY)
        _assert_runs_close(streamed, dense)

    def test_gather_cap_overflow_drops_tail_participants(self, problem):
        """An explicit gather_cap below the realized cohort truncates (the
        documented failure mode the 6-sigma default headroom avoids): the run
        stays finite but is NOT the dense release."""
        tiny = CohortSpec(q=Q, gather=True, gather_cap=2)
        out = _session(problem, "fedavg", cohort=tiny).run(KEY)
        assert np.all(np.isfinite(np.asarray(out.final_w)))

    def test_batched_runs_gather(self, problem):
        """run_batched vmaps the same gathered round step."""
        keys = jax.random.split(jax.random.PRNGKey(5), 3)
        dense = _session(problem, "fedexp", cohort=DENSE).run_batched(keys)
        sparse = _session(problem, "fedexp", cohort=SPARSE).run_batched(keys)
        np.testing.assert_allclose(np.asarray(sparse.final_w),
                                   np.asarray(dense.final_w),
                                   rtol=1e-5, atol=1e-6)


class TestKernelSlotsEntry:
    def test_slots_match_dense_masked_sums(self):
        u = jax.random.normal(jax.random.PRNGKey(0), (M, D))
        mask = (jax.random.uniform(jax.random.PRNGKey(1), (M,)) < Q
                ).astype(jnp.float32)
        slots, slot_mask, _ = gather_slots(mask, 24)
        s_sparse, rel_sparse, clip_sparse = dp_aggregate_sums_chunked(
            u, 0.3, chunk_m=8, slots=slots, slot_mask=slot_mask, use_ref=True)
        s_dense, rel_dense, clip_dense = dp_aggregate_sums_chunked(
            u * mask[:, None], 0.3, chunk_m=4, use_ref=True)
        np.testing.assert_allclose(np.asarray(s_sparse), np.asarray(s_dense),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(float(clip_sparse), float(clip_dense),
                                   rtol=1e-5)

    def test_slots_require_slot_mask(self):
        u = jnp.ones((8, 4))
        with pytest.raises(ValueError, match="slot_mask"):
            dp_aggregate_sums_chunked(u, 1.0, chunk_m=4,
                                      slots=jnp.zeros((4,), jnp.int32))

    def test_slot_aligned_noise_shape_enforced(self):
        u = jnp.ones((8, 4))
        slots = jnp.zeros((4,), jnp.int32)
        with pytest.raises(ValueError, match="slot-aligned"):
            dp_aggregate_sums_chunked(
                u, 1.0, chunk_m=4, slots=slots,
                slot_mask=jnp.ones((4,)), noise=jnp.zeros((8, 4)))
