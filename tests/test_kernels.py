"""Pallas kernels vs their pure-jnp oracles (interpret mode, shape/dtype sweep)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.dp_aggregate.ops import dp_aggregate
from repro.kernels.dp_aggregate.ref import dp_aggregate_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_scan_ref


# ---------------------------------------------------------------------------
# dp_aggregate
# ---------------------------------------------------------------------------

class TestDPAggregate:
    @pytest.mark.parametrize("m,d", [(8, 128), (16, 256), (24, 300), (10, 64)])
    @pytest.mark.parametrize("with_noise", [False, True])
    def test_matches_ref(self, m, d, with_noise):
        key = jax.random.PRNGKey(m * d)
        u = 2.0 * jax.random.normal(key, (m, d))
        noise = (0.5 * jax.random.normal(jax.random.fold_in(key, 1), (m, d))
                 if with_noise else None)
        clip = 1.0
        want_sum, want_sq_rel, want_sq_clip = dp_aggregate_ref(u, noise, clip)
        got = dp_aggregate(u, clip, noise, use_ref=False, interpret=True, block_m=8)
        np.testing.assert_allclose(np.asarray(got.cbar), np.asarray(want_sum) / m,
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(float(got.mean_sq), float(want_sq_rel) / m, rtol=1e-5)
        np.testing.assert_allclose(float(got.mean_sq_clipped), float(want_sq_clip) / m,
                                   rtol=1e-5)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        u = jax.random.normal(jax.random.PRNGKey(0), (16, 128)).astype(dtype)
        got = dp_aggregate(u, 0.5, None, interpret=True)
        want = dp_aggregate_ref(u, None, 0.5)
        np.testing.assert_allclose(np.asarray(got.cbar, np.float32),
                                   np.asarray(want[0], np.float32) / 16,
                                   rtol=2e-2, atol=2e-2)

    def test_clipping_enforced(self):
        u = 100.0 * jax.random.normal(jax.random.PRNGKey(1), (8, 128))
        got = dp_aggregate(u, 1.0, None, interpret=True)
        assert float(got.mean_sq_clipped) <= 1.0 + 1e-5


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

class TestFlashAttention:
    @pytest.mark.parametrize("b,hq,hkv,sq,skv,dh", [
        (1, 2, 2, 64, 64, 32),     # MHA
        (2, 4, 2, 128, 128, 64),   # GQA
        (1, 8, 1, 96, 96, 64),     # MQA, non-multiple seq (pads)
        (1, 2, 2, 32, 160, 32),    # cross-length
    ])
    @pytest.mark.parametrize("causal", [True, False])
    def test_matches_ref(self, b, hq, hkv, sq, skv, dh, causal):
        if causal and sq != skv:
            pytest.skip("causal ref assumes aligned q/k indices")
        key = jax.random.PRNGKey(hash((b, hq, sq, skv)) % 2**31)
        q = jax.random.normal(key, (b, hq, sq, dh))
        k = jax.random.normal(jax.random.fold_in(key, 1), (b, hkv, skv, dh))
        v = jax.random.normal(jax.random.fold_in(key, 2), (b, hkv, skv, dh))
        got = flash_attention(q, k, v, causal=causal, block_q=32, block_k=32)
        want = attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("window", [16, 64])
    def test_sliding_window(self, window):
        b, h, s, dh = 1, 2, 128, 32
        key = jax.random.PRNGKey(7)
        q = jax.random.normal(key, (b, h, s, dh))
        k = jax.random.normal(jax.random.fold_in(key, 1), (b, h, s, dh))
        v = jax.random.normal(jax.random.fold_in(key, 2), (b, h, s, dh))
        got = flash_attention(q, k, v, causal=True, window=window, block_q=32, block_k=32)
        want = attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)

    def test_bf16(self):
        b, h, s, dh = 1, 2, 64, 32
        key = jax.random.PRNGKey(9)
        mk = lambda i: jax.random.normal(jax.random.fold_in(key, i), (b, h, s, dh)).astype(jnp.bfloat16)
        q, k, v = mk(0), mk(1), mk(2)
        got = flash_attention(q, k, v, causal=True, block_q=32, block_k=32)
        want = attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want, np.float32),
                                   rtol=5e-2, atol=5e-2)


# ---------------------------------------------------------------------------
# ssd scan (Mamba2)
# ---------------------------------------------------------------------------

class TestSSDScan:
    @pytest.mark.parametrize("b,s,h,p,n,chunk", [
        (1, 64, 2, 16, 8, 16),
        (2, 128, 4, 32, 16, 32),
        (1, 100, 2, 16, 8, 32),   # pad path
        (1, 256, 1, 64, 32, 64),
    ])
    def test_matches_ref(self, b, s, h, p, n, chunk):
        key = jax.random.PRNGKey(hash((b, s, h, p)) % 2**31)
        x = jax.random.normal(key, (b, s, h, p))
        dt = 0.1 + 0.5 * jax.random.uniform(jax.random.fold_in(key, 1), (b, s, h))
        a = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (h,)) * 0.3)
        bm = jax.random.normal(jax.random.fold_in(key, 3), (b, s, n)) / np.sqrt(n)
        cm = jax.random.normal(jax.random.fold_in(key, 4), (b, s, n)) / np.sqrt(n)
        got = ssd_scan(x, dt, a, bm, cm, chunk=chunk)
        want = ssd_scan_ref(x, dt, a, bm, cm)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)

    def test_model_chunked_matches_ref(self):
        """models.ssm.ssd_chunked (the jnp training path) vs the recurrence."""
        from repro.models.ssm import ssd_chunked
        key = jax.random.PRNGKey(11)
        b, s, h, p, n = 2, 96, 2, 16, 8
        x = jax.random.normal(key, (b, s, h, p))
        dt = 0.1 + 0.5 * jax.random.uniform(jax.random.fold_in(key, 1), (b, s, h))
        a = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (h,)) * 0.3)
        bm = jax.random.normal(jax.random.fold_in(key, 3), (b, s, n)) / np.sqrt(n)
        cm = jax.random.normal(jax.random.fold_in(key, 4), (b, s, n)) / np.sqrt(n)
        got = ssd_chunked(x, dt, a, bm, cm, chunk=32)
        want = ssd_scan_ref(x, dt, a, bm, cm)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)

    def test_decay_state_carry(self):
        """Long sequence with strong decay: chunk boundaries must be seamless."""
        b, s, h, p, n = 1, 128, 1, 8, 4
        key = jax.random.PRNGKey(12)
        x = jax.random.normal(key, (b, s, h, p))
        dt = jnp.full((b, s, h), 1.5)
        a = jnp.array([-2.0])
        bm = jnp.ones((b, s, n)) / n
        cm = jnp.ones((b, s, n))
        got = ssd_scan(x, dt, a, bm, cm, chunk=16)
        want = ssd_scan_ref(x, dt, a, bm, cm)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)
