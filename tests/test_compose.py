"""Composable algorithm stack (DESIGN.md §11).

Three layers of evidence:
  1. Composition parity: every legacy registry name, rebuilt as a
     mechanism x aggregation x step composition, produces BIT-IDENTICAL
     round trajectories to its monolithic class — full scan-engine sessions
     compared field by field, plus the moment halves with their extras.
  2. Zero contribution (hypothesis): padded and non-sampled clients
     contribute exactly zero to every RoundMoments field (Σc, Σ||c||²,
     count, the adaptive-clip bit sum, the PrivUnit s-hat sum) across all
     four mechanisms — masked rows can hold arbitrary garbage without
     changing a single bit of the release.
  3. New cross-products: compositions the inheritance design could not
     express (LDP-Gaussian + server Adam, PrivUnit + adaptive clip,
     CDP + server momentum, minibatch-momentum clients + CDP-FedEXP,
     weighted aggregation) run end-to-end through FederatedSession with a
     passing privacy_report.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # the property layer needs hypothesis (CI installs it); the parity
    import hypothesis  # and cross-product layers below always run
    import hypothesis.strategies as st
    from hypothesis import given, settings
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

from repro.core import fedexp as fx
from repro.core.compose import (
    ComposedAlgorithm,
    FedEXPStep,
    GaussianLDP,
    NoPrivacy,
    WeightedAggregation,
    compose_algorithm,
)
from repro.core.fedexp import make_algorithm
from repro.data.synthetic import distance_to_opt, linreg_loss, make_synthetic_linreg
from repro.fedsim import CohortSpec, FederatedSession, LocalSpec, TrainSpec
from repro.fedsim.local import mask_rows

M, D, TAU, ETA_L, ROUNDS = 24, 12, 2, 0.1, 4

LEGACY = {
    "fedavg": (fx.FedAvg, (), {}),
    "fedexp": (fx.FedEXP, (), {}),
    "dp-fedavg-ldp-gauss": (fx.DPFedAvgLDPGaussian, (0.3, 0.21), {}),
    "ldp-fedexp-gauss": (fx.LDPFedEXPGaussian, (0.3, 0.21), {}),
    "dp-fedavg-privunit": (fx.DPFedAvgPrivUnit, (0.3, 2.0, 2.0, 2.0, D), {}),
    "ldp-fedexp-privunit": (fx.LDPFedEXPPrivUnit, (0.3, 2.0, 2.0, 2.0, D), {}),
    "dp-fedavg-cdp": (fx.DPFedAvgCDP, (0.3, 0.2, M), {}),
    "cdp-fedexp": (fx.CDPFedEXP, (0.3, 0.2, M), {}),
    "dp-fedadam-cdp": (fx.DPFedAdamCDP, (0.3, 0.2, M), {"server_lr": 0.05}),
    "cdp-fedexp-adaptive-clip": (
        fx.CDPFedEXPAdaptiveClip, (),
        {"z_mult": 0.5, "num_clients": M, "dim": D}),
}

COMPOSED_KW = {
    "fedavg": {},
    "fedexp": {},
    "dp-fedavg-ldp-gauss": dict(clip_norm=0.3, sigma=0.21),
    "ldp-fedexp-gauss": dict(clip_norm=0.3, sigma=0.21),
    "dp-fedavg-privunit": dict(clip_norm=0.3, eps0=2.0, eps1=2.0, eps2=2.0, dim=D),
    "ldp-fedexp-privunit": dict(clip_norm=0.3, eps0=2.0, eps1=2.0, eps2=2.0, dim=D),
    "dp-fedavg-cdp": dict(clip_norm=0.3, sigma=0.2, num_clients=M),
    "cdp-fedexp": dict(clip_norm=0.3, sigma=0.2, num_clients=M),
    "dp-fedadam-cdp": dict(clip_norm=0.3, sigma=0.2, num_clients=M, server_lr=0.05),
    "cdp-fedexp-adaptive-clip": dict(z_mult=0.5, num_clients=M, dim=D),
}


@pytest.fixture(scope="module")
def problem():
    data = make_synthetic_linreg(jax.random.PRNGKey(3), M, D)
    return data, jnp.zeros(D)


def _legacy(name):
    cls, args, kw = LEGACY[name]
    return cls(*args, **kw)


def _run(problem, alg):
    data, w0 = problem
    session = FederatedSession(alg, linreg_loss, w0, data.client_batches(),
                               train=TrainSpec(rounds=ROUNDS, tau=TAU, eta_l=ETA_L),
                               eval_fn=distance_to_opt(data.w_star))
    return session.run(jax.random.PRNGKey(11))


class TestCompositionParity:
    """make_algorithm(name) == the monolithic class, bit-for-bit."""

    @pytest.mark.parametrize("name", sorted(LEGACY))
    def test_registry_builds_compositions(self, name):
        alg = make_algorithm(name, **COMPOSED_KW[name])
        assert isinstance(alg, ComposedAlgorithm)
        assert alg.name == name
        assert alg.is_private == _legacy(name).is_private

    @pytest.mark.parametrize("name", sorted(LEGACY))
    def test_session_trajectory_bit_identical(self, problem, name):
        r_l = _run(problem, _legacy(name))
        r_c = _run(problem, make_algorithm(name, **COMPOSED_KW[name]))
        for field in ("final_w", "last_w", "eta_history", "metric_history",
                      "eta_naive_history", "eta_target_history"):
            np.testing.assert_array_equal(
                np.asarray(getattr(r_l, field)), np.asarray(getattr(r_c, field)),
                err_msg=f"{name}.{field}")

    @pytest.mark.parametrize("name", sorted(LEGACY))
    def test_moment_halves_bit_identical(self, problem, name):
        """local_moments + apply_from_moments (the sharded round's two
        halves) agree bit-for-bit, extras included."""
        data, w0 = problem
        legacy, comp = _legacy(name), make_algorithm(name, **COMPOSED_KW[name])
        key = jax.random.PRNGKey(5)
        deltas = jax.random.normal(jax.random.PRNGKey(6), (M, D))
        mask = jnp.concatenate([jnp.ones(M - 3), jnp.zeros(3)])
        zeroed = mask_rows(deltas, mask)

        def halves(alg):
            s = alg.init_state(w0)

            @jax.jit
            def f(key, w, z, mask, s):
                mom = alg.local_moments(key, w, z, mask, 0, s)
                w_next, aux, s2 = alg.apply_from_moments(key, w, mom, s)
                return mom, w_next, aux.eta_g
            return f(key, w0, zeroed, mask, s)

        mom_l, w_l, eta_l_ = halves(legacy)
        mom_c, w_c, eta_c = halves(comp)
        base_l = mom_l[0] if isinstance(mom_l, tuple) else mom_l
        base_c = mom_c[0]
        for f in ("sum_c", "sum_sq", "sum_sq_clipped", "count"):
            np.testing.assert_array_equal(np.asarray(getattr(base_l, f)),
                                          np.asarray(getattr(base_c, f)),
                                          err_msg=f"{name}.{f}")
        # legacy extras (where they exist) must survive verbatim
        if isinstance(mom_l, tuple):
            for k, v in mom_l[1].items():
                np.testing.assert_array_equal(np.asarray(v),
                                              np.asarray(mom_c[1][k]),
                                              err_msg=f"{name}.extras[{k}]")
        np.testing.assert_array_equal(np.asarray(w_l), np.asarray(w_c))
        np.testing.assert_array_equal(np.asarray(eta_l_), np.asarray(eta_c))

    def test_stateful_guard_preserved(self):
        alg = make_algorithm("dp-fedadam-cdp", clip_norm=1.0, sigma=0.1,
                             num_clients=4, server_lr=0.1)
        with pytest.raises(TypeError):
            alg.apply_round(jax.random.PRNGKey(0), jnp.zeros(4), jnp.zeros((4, 4)))

    def test_attribute_passthrough(self):
        alg = make_algorithm("cdp-fedexp", clip_norm=0.3, sigma=0.2, num_clients=M)
        assert alg.sigma_xi is None and alg.clip_norm == 0.3
        assert alg.num_clients == M
        with pytest.raises(AttributeError, match="no attribute"):
            alg.nonexistent_field


MECHANISM_NAMES = ["fedexp", "ldp-fedexp-gauss", "ldp-fedexp-privunit",
                   "cdp-fedexp-adaptive-clip"]


def _mech_alg(name, m, d):
    kw = dict(COMPOSED_KW[name])
    if "dim" in kw:
        kw["dim"] = d
    if "num_clients" in kw:
        kw["num_clients"] = m
    return make_algorithm(name, **kw)


def check_masked_rows_never_leak(name, seed, m, d, n_drop):
    """Masked (padded / non-sampled) clients contribute exactly zero to
    every moment field: their deltas can be arbitrary garbage without
    flipping a single bit of the release (Σc, Σ||c||², count, bit sum,
    s-hat sum alike)."""
    n_drop = min(n_drop, m - 1)
    alg = _mech_alg(name, m, d)
    w = jnp.zeros(d)
    state = alg.init_state(w)
    key = jax.random.fold_in(jax.random.PRNGKey(seed), 1)
    deltas = jax.random.normal(jax.random.PRNGKey(seed), (m, d))
    drop = np.zeros(m, bool)
    drop[np.random.default_rng(seed).choice(m, n_drop, replace=False)] = True
    mask = jnp.asarray(~drop, jnp.float32)

    def moments(garbage):
        poisoned = jnp.where(jnp.asarray(drop)[:, None], garbage, deltas)
        return jax.jit(lambda: alg.local_moments(key, w, poisoned, mask,
                                                 0, state))()

    # garbage spans overflow (squares to inf) and NaN: the mechanisms'
    # internal row gating must keep every field bit-identical regardless
    mom_a, mom_b = moments(jnp.float32(1e30)), moments(jnp.float32(jnp.nan))
    la, lb = (jax.tree_util.tree_leaves(x) for x in (mom_a, mom_b))
    for a, b in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert np.all(np.isfinite(np.asarray(a)))
    # the count really is the kept-client count
    base = mom_a[0] if isinstance(mom_a, tuple) else mom_a
    assert float(base.count) == float(m - n_drop)


def check_adaptive_bit_sum_counts_only_kept(seed, m, d):
    """The clip-quantile bit sum excludes masked rows exactly."""
    alg = _mech_alg("cdp-fedexp-adaptive-clip", m, d)
    w = jnp.zeros(d)
    state = alg.init_state(w)
    deltas = jax.random.normal(jax.random.PRNGKey(seed), (m, d))
    mask = jnp.asarray(np.r_[np.ones(m - 1), 0.0], jnp.float32)
    _, extras = alg.local_moments(jax.random.PRNGKey(0), w, deltas, mask,
                                  0, state)
    norms = np.linalg.norm(np.asarray(deltas), axis=-1)
    want = float(np.sum((norms[: m - 1] <= float(state.clip))))
    assert float(extras["count_below"]) == want


def check_nan_poison_through_engine_protocol(seed):
    """NaN local updates from padding clients (zeroed at source by
    mask_rows, the engine's contract) leave every field finite."""
    alg = _mech_alg("ldp-fedexp-gauss", 8, 6)
    w = jnp.zeros(6)
    deltas = jax.random.normal(jax.random.PRNGKey(seed), (8, 6))
    mask = jnp.asarray([1, 1, 1, 1, 1, 0, 0, 0], jnp.float32)
    poisoned = jnp.where(mask[:, None] > 0, deltas, jnp.nan)
    mom, _ = alg.local_moments(jax.random.PRNGKey(1), w,
                               mask_rows(poisoned, mask), mask, 0, ())
    for leaf in jax.tree_util.tree_leaves(mom):
        assert np.all(np.isfinite(np.asarray(leaf)))


class TestZeroContributionDeterministic:
    """The zero-contribution invariants at fixed points — always runs, even
    without hypothesis (the property layer widens the same checks)."""

    @pytest.mark.parametrize("name", MECHANISM_NAMES)
    def test_masked_rows_never_leak(self, name):
        check_masked_rows_never_leak(name, seed=7, m=9, d=10, n_drop=2)

    def test_adaptive_bit_sum(self):
        check_adaptive_bit_sum_counts_only_kept(seed=3, m=8, d=6)

    def test_nan_poison(self):
        check_nan_poison_through_engine_protocol(seed=5)


if HAS_HYPOTHESIS:
    SETTINGS = dict(deadline=None, max_examples=15,
                    suppress_health_check=[hypothesis.HealthCheck.too_slow])

    class TestZeroContributionProperties:
        @given(name=st.sampled_from(MECHANISM_NAMES),
               seed=st.integers(0, 2**31 - 1),
               m=st.integers(3, 10), d=st.integers(4, 16),
               n_drop=st.integers(1, 2))
        @settings(**SETTINGS)
        def test_masked_rows_never_leak(self, name, seed, m, d, n_drop):
            check_masked_rows_never_leak(name, seed, m, d, n_drop)

        @given(seed=st.integers(0, 2**31 - 1), m=st.integers(3, 10),
               d=st.integers(4, 12))
        @settings(**SETTINGS)
        def test_adaptive_bit_sum_counts_only_kept(self, seed, m, d):
            check_adaptive_bit_sum_counts_only_kept(seed, m, d)

        @given(seed=st.integers(0, 2**31 - 1))
        @settings(**SETTINGS)
        def test_nan_poison_through_engine_protocol(self, seed):
            check_nan_poison_through_engine_protocol(seed)


class TestNewCompositions:
    """Cross-products the inheritance design could not express, end-to-end."""

    @pytest.mark.parametrize("name,kw", [
        ("ldp-gauss-fedadam", dict(clip_norm=0.3, sigma=0.21, server_lr=0.05)),
        ("cdp-fedmom", dict(clip_norm=0.3, sigma=0.2, num_clients=M,
                            server_lr=0.5)),
        ("privunit-fedexp-adaptive-clip", dict(eps0=2.0, eps1=2.0, eps2=2.0,
                                               dim=D, c0=0.5)),
    ])
    def test_runs_with_passing_privacy_report(self, problem, name, kw):
        data, w0 = problem
        session = FederatedSession(
            make_algorithm(name, **kw), linreg_loss, w0, data.client_batches(),
            train=TrainSpec(rounds=ROUNDS, tau=TAU, eta_l=ETA_L),
            eval_fn=distance_to_opt(data.w_star))
        r = session.run(jax.random.PRNGKey(2))
        assert np.all(np.isfinite(np.asarray(r.metric_history)))
        rep = session.privacy_report(1e-5)
        assert rep.eps_numerical > 0 and np.isfinite(rep.eps_numerical)

    def test_minibatch_momentum_clients_with_cdp_fedexp(self):
        """The acceptance composition: minibatch+momentum local training
        under CDP-FedEXP, sampled cohort, with honest accounting.  Client
        data carries a per-sample axis (what LocalSpec minibatching needs)."""
        targets = jax.random.normal(jax.random.PRNGKey(0), (M, 10, D))

        def sample_loss(w, b):
            return 0.5 * jnp.mean(jnp.sum(jnp.square(w - b), -1))

        session = FederatedSession(
            make_algorithm("cdp-fedexp", clip_norm=0.3, sigma=0.1,
                           num_clients=M),
            sample_loss, jnp.zeros(D), targets,
            train=TrainSpec(rounds=ROUNDS, tau=TAU, eta_l=0.3),
            local=LocalSpec(batch_size=4, epochs=2, momentum=0.5),
            cohort=CohortSpec(q=0.5),
            eval_fn=lambda w: jnp.sum(jnp.square(w - jnp.mean(targets, (0, 1)))))
        r = session.run(jax.random.PRNGKey(4))
        hist = np.asarray(r.metric_history)
        assert np.all(np.isfinite(hist)) and hist[-1] < hist[0]
        rep = session.privacy_report(1e-5)
        assert "q=0.5" in rep.setting

    def test_compose_algorithm_default_name(self):
        alg = compose_algorithm(NoPrivacy(), FedEXPStep())
        assert alg.name == "noprivacy-fedexpstep"

    def test_fixed_sigma_adaptive_clip_budget_is_refused(self):
        """A fixed-noise mechanism under an adaptive clip override has no
        static budget (its sensitivity/noise ratio tracks the traced C);
        reporting the clip_norm figure would be silently unsound."""
        from repro.core.compose import AdaptiveClipStep
        alg = compose_algorithm(GaussianLDP(0.3, 0.21), AdaptiveClipStep(),
                                name="ldp-gauss-adaptive")
        with pytest.raises(ValueError, match="adaptive"):
            alg.budget(1e-5, rounds=5, dim=D)
        # the C-independent mechanisms stay reportable
        assert make_algorithm("privunit-fedexp-adaptive-clip", eps0=2.0,
                              eps1=2.0, eps2=2.0, dim=D, c0=0.5).budget(
            1e-5, rounds=5, dim=D).eps_numerical == 6.0
        assert make_algorithm("cdp-fedexp-adaptive-clip", z_mult=0.5,
                              num_clients=M, dim=D).budget(
            1e-5, rounds=5, dim=D).eps_numerical > 0

    def test_privunit_adaptive_engine_consistency(self, problem):
        """Dense and masked-moment engines draw the SAME per-client PrivUnit
        randomness even though AdaptiveClipStep reserves extra key streams:
        a size=M cohort (everyone participates, moments path) must match the
        unsampled (dense) run like every other algorithm does."""
        data, w0 = problem
        kw = dict(eps0=2.0, eps1=2.0, eps2=2.0, dim=D, c0=0.5)

        def run(cohort):
            return FederatedSession(
                make_algorithm("privunit-fedexp-adaptive-clip", **kw),
                linreg_loss, w0, data.client_batches(),
                train=TrainSpec(rounds=3, tau=TAU, eta_l=ETA_L),
                cohort=cohort,
                eval_fn=distance_to_opt(data.w_star)).run(jax.random.PRNGKey(8))

        r_dense = run(CohortSpec())
        r_mom = run(CohortSpec(size=M))
        np.testing.assert_allclose(np.asarray(r_dense.final_w),
                                   np.asarray(r_mom.final_w),
                                   rtol=1e-5, atol=1e-5)


class TestWeightedAggregation:
    def test_weighted_mean_matches_manual(self):
        """NoPrivacy + weights: the round applies Σ v_i δ_i / Σ v_i."""
        weights = (1.0, 3.0, 0.5, 2.0, 1.5, 0.0)
        deltas = jax.random.normal(jax.random.PRNGKey(0), (6, 5))
        alg = compose_algorithm(NoPrivacy(), FedEXPStep(),
                                WeightedAggregation(weights), name="w-fedexp")
        assert not alg.supports_static_count

        @jax.jit
        def run(w, deltas):
            wn, aux = alg.apply_round(jax.random.PRNGKey(1), w, deltas)
            return wn, aux.eta_g
        w_next, eta = run(jnp.zeros(5), deltas)
        v = np.asarray(weights)
        wbar = (v[:, None] * np.asarray(deltas)).sum(0) / v.sum()
        mean_sq = (v * np.square(np.asarray(deltas)).sum(-1)).sum() / v.sum()
        want_eta = max(1.0, mean_sq / np.square(wbar).sum())
        np.testing.assert_allclose(np.asarray(w_next), float(eta) * wbar,
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(float(eta), want_eta, rtol=1e-5)

    def test_weighted_dp_session_runs(self, problem):
        """Weighted aggregation under a DP mechanism through the engine."""
        data, w0 = problem
        weights = tuple(float(x) for x in
                        np.random.default_rng(0).uniform(0.5, 2.0, M))
        alg = compose_algorithm(GaussianLDP(0.3, 0.21), FedEXPStep(),
                                WeightedAggregation(weights),
                                name="ldp-gauss-weighted-fedexp")
        session = FederatedSession(alg, linreg_loss, w0, data.client_batches(),
                                   train=TrainSpec(rounds=3, tau=TAU,
                                                   eta_l=ETA_L),
                                   eval_fn=distance_to_opt(data.w_star))
        r = session.run(jax.random.PRNGKey(9))
        assert np.all(np.isfinite(np.asarray(r.metric_history)))
        rep = session.privacy_report(1e-5)  # mechanism-owned budget still applies
        assert rep.eps_numerical > 0
