"""Property-based tests (hypothesis) for fault degradation (DESIGN.md §13).

The invariant: ANY garbage update block (NaN / Inf / huge rows) under ANY
dropout mask degrades to finite moments and a finite global model — for
every registry algorithm, on the dense and streaming engines alike.  The
deterministic twin in ``tests/test_faults.py`` covers the same contract
where hypothesis is unavailable.
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.core.fedexp import make_algorithm
from repro.data.synthetic import linreg_loss, make_synthetic_linreg
from repro.fedsim import (
    EngineSpec,
    FaultSpec,
    FederatedSession,
    LocalSpec,
    StreamSpec,
    TrainSpec,
)
from repro.fedsim.faults import apply_faults, fault_masks

M, D, ETA_L = 44, 24, 0.1

# mirrors tests/test_faults.py's registry-complete table (pinned there
# against list_algorithms())
ALG_KWARGS = {
    "fedavg": {},
    "fedexp": {},
    "dp-fedavg-ldp-gauss": dict(clip_norm=0.3, sigma=0.21),
    "ldp-fedexp-gauss": dict(clip_norm=0.3, sigma=0.21),
    "dp-fedavg-privunit": dict(clip_norm=0.3, eps0=2.0, eps1=2.0, eps2=2.0, dim=D),
    "ldp-fedexp-privunit": dict(clip_norm=0.3, eps0=2.0, eps1=2.0, eps2=2.0, dim=D),
    "dp-fedavg-cdp": dict(clip_norm=0.3, sigma=0.2, num_clients=M),
    "cdp-fedexp": dict(clip_norm=0.3, sigma=0.2, num_clients=M),
    "dp-fedadam-cdp": dict(clip_norm=0.3, sigma=0.2, num_clients=M, server_lr=0.05),
    "cdp-fedexp-adaptive-clip": dict(z_mult=0.5, num_clients=M, dim=D),
    "ldp-gauss-fedadam": dict(clip_norm=0.3, sigma=0.21, server_lr=0.05),
    "cdp-fedmom": dict(clip_norm=0.3, sigma=0.2, num_clients=M, server_lr=0.5),
    "privunit-fedexp-adaptive-clip": dict(eps0=2.0, eps1=2.0, eps2=2.0,
                                          z_mult=0.5, num_clients=M, dim=D),
    # §17 tier (tau/eta_l mirror the TrainSpec below)
    "ldp-fedexp-perclient": dict(clip_norm=0.3,
                                 epsilons=tuple(2.0 + 0.5 * (i % 4)
                                                for i in range(M)),
                                 delta=1e-5),
    "ldp-fedexp-schedule": dict(clip_norm=0.3, sigma=0.21, decay=0.9),
    "cdp-fedexp-schedule": dict(clip_norm=0.3, sigma=0.2, num_clients=M,
                                decay=0.9),
    "dp-scaffold": dict(clip_norm=0.3, sigma=0.2, num_clients=M,
                        central=True, tau=1, eta_l=ETA_L),
}

SETTINGS = dict(deadline=None, max_examples=25,
                suppress_health_check=[hypothesis.HealthCheck.too_slow])


@pytest.fixture(scope="module")
def problem():
    data = make_synthetic_linreg(jax.random.PRNGKey(3), M, D)
    return data, jnp.zeros(D)


@st.composite
def garbage_rows(draw, max_m=12, max_d=16):
    """(m, d) update block where arbitrary entries carry NaN/Inf/huge
    garbage, plus an arbitrary participation mask."""
    m = draw(st.integers(2, max_m))
    d = draw(st.integers(2, max_d))
    seed = draw(st.integers(0, 2**31 - 1))
    base = np.asarray(jax.random.normal(jax.random.PRNGKey(seed), (m, d)),
                      dtype=np.float32)
    poison = draw(st.lists(
        st.tuples(st.integers(0, m - 1), st.integers(0, d - 1),
                  st.sampled_from([np.nan, np.inf, -np.inf, 1e38])),
        max_size=m))
    for i, j, v in poison:
        base[i, j] = np.float32(v)
    mask = np.asarray(draw(st.lists(st.sampled_from([0.0, 1.0]),
                                    min_size=m, max_size=m)), dtype=np.float32)
    return base, mask


class TestGarbageRowProperties:
    @given(data=garbage_rows(), drop_seed=st.integers(0, 2**31 - 1),
           dropout=st.floats(0.0, 0.9))
    @settings(**SETTINGS)
    def test_apply_faults_always_finite(self, data, drop_seed, dropout):
        """ANY garbage block under ANY dropout mask degrades to finite rows
        with the bad rows zero-weighted — the where-gated masked-moment
        contract that makes 0*NaN impossible."""
        deltas, mask = data
        m = deltas.shape[0]
        alive = None
        if dropout > 0.0:
            alive = fault_masks(FaultSpec(dropout=dropout),
                                jax.random.PRNGKey(drop_seed), m)[0]
        out, eff = apply_faults(jnp.asarray(deltas), jnp.asarray(mask),
                                alive, None)
        out, eff = np.asarray(out), np.asarray(eff)
        assert np.all(np.isfinite(out))
        bad = ~np.all(np.isfinite(deltas), axis=-1)
        assert np.all(eff[bad] == 0.0)
        np.testing.assert_array_equal(out[bad], np.zeros_like(out[bad]))
        assert np.all(eff <= mask)

    @given(name=st.sampled_from(sorted(ALG_KWARGS)),
           engine=st.sampled_from(["scan", "stream"]),
           seed=st.integers(0, 2**31 - 1),
           corrupt=st.floats(0.01, 0.5), dropout=st.floats(0.0, 0.9))
    @settings(deadline=None, max_examples=15,
              suppress_health_check=[hypothesis.HealthCheck.too_slow])
    def test_faulty_round_keeps_global_model_finite(self, problem, name,
                                                    engine, seed, corrupt,
                                                    dropout):
        """NaN-corrupted rows under any dropout rate leave the round moments
        and the global model finite, for every registry algorithm, on the
        dense and streaming engines alike."""
        data, w0 = problem
        alg = make_algorithm(name, **ALG_KWARGS[name])
        kw = dict(engine=EngineSpec(engine="stream"),
                  stream=StreamSpec(chunk_clients=16)) if engine == "stream" \
            else {}
        if name == "dp-scaffold":
            kw["local"] = LocalSpec(control_variates=True)
        sess = FederatedSession(
            alg, linreg_loss, w0, data.client_batches(),
            train=TrainSpec(rounds=2, tau=1, eta_l=ETA_L),
            fault=FaultSpec(dropout=dropout, corrupt=corrupt), **kw)
        r = sess.run(jax.random.PRNGKey(seed))
        assert np.all(np.isfinite(np.asarray(r.final_w)))
        assert np.all(np.isfinite(np.asarray(r.last_w)))
        assert np.all(np.isfinite(np.asarray(r.eta_history)))
