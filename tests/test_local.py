"""LocalSpec trainers (DESIGN.md §11): minibatch SGD with local epochs,
FedProx, client momentum — pytree-native, engine-integrated, reproducible."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fedexp import make_algorithm
from repro.fedsim import FederatedSession, LocalSpec, TrainSpec
from repro.fedsim.local import cohort_updates_spec, local_update_spec
from repro.fedsim.specs import LOCAL_TRAIN_TAG, SAMPLING_TAG
from repro.launch.mesh import make_client_mesh
from repro.fedsim import ShardSpec

M, N, D = 16, 12, 6


@pytest.fixture(scope="module")
def problem():
    key = jax.random.PRNGKey(0)
    targets = jax.random.normal(key, (M, N, D))  # per-sample client data

    def loss(w, b):  # b: (n, D) or a minibatch slice of it
        return 0.5 * jnp.mean(jnp.sum(jnp.square(w - b), -1))

    return targets, loss


def _session(problem, spec=None, rounds=3, tau=2, **kw):
    targets, loss = problem
    alg = make_algorithm("cdp-fedexp", clip_norm=0.3, sigma=0.1, num_clients=M)
    local = {} if spec is None else {"local": spec}
    return FederatedSession(alg, loss, jnp.zeros(D), targets,
                            train=TrainSpec(rounds=rounds, tau=tau, eta_l=0.3),
                            **local, **kw)


class TestSpecValidation:
    def test_rejects_bad_fields(self):
        with pytest.raises(ValueError, match="batch_size"):
            LocalSpec(batch_size=0)
        with pytest.raises(ValueError, match="epochs"):
            LocalSpec(batch_size=4, epochs=0)
        with pytest.raises(ValueError, match="requires batch_size"):
            LocalSpec(epochs=2)
        with pytest.raises(ValueError, match="momentum"):
            LocalSpec(momentum=1.0)
        with pytest.raises(ValueError, match="prox_mu"):
            LocalSpec(prox_mu=-0.1)

    def test_default_detection(self):
        assert LocalSpec().is_default
        assert not LocalSpec(batch_size=4).is_default
        assert not LocalSpec(momentum=0.1).is_default

    def test_tags_disjoint(self):
        assert LOCAL_TRAIN_TAG != SAMPLING_TAG


class TestDefaultPath:
    def test_default_spec_is_bit_exact(self, problem):
        key = jax.random.PRNGKey(7)
        r0 = _session(problem).run(key)
        r1 = _session(problem, LocalSpec()).run(key)
        for field in ("final_w", "eta_history", "metric_history"):
            np.testing.assert_array_equal(np.asarray(getattr(r0, field)),
                                          np.asarray(getattr(r1, field)))


class TestTrainerSemantics:
    def test_full_cover_minibatch_matches_one_gd_step(self, problem):
        """batch_size=n, epochs=1 is one full-batch GD step (the shuffle only
        permutes the mean) — allclose to tau=1 full-batch."""
        targets, loss = problem
        w0 = jnp.zeros(D)
        spec = LocalSpec(batch_size=N, epochs=1)
        d_spec = local_update_spec(loss, w0, targets[0], jax.random.PRNGKey(1),
                                   spec, tau=5, eta_l=0.3)
        g = jax.grad(loss)(w0, targets[0])
        np.testing.assert_allclose(np.asarray(d_spec), np.asarray(-0.3 * g),
                                   rtol=1e-5, atol=1e-6)

    def test_momentum_recurrence(self, problem):
        """Two momentum steps match the hand-rolled velocity recurrence."""
        targets, loss = problem
        w0 = 0.3 * jnp.ones(D)
        beta, eta = 0.7, 0.1
        spec = LocalSpec(momentum=beta)
        delta = local_update_spec(loss, w0, targets[0], jax.random.PRNGKey(0),
                                  spec, tau=2, eta_l=eta)
        g1 = jax.grad(loss)(w0, targets[0])
        w1 = w0 - eta * g1
        g2 = jax.grad(loss)(w1, targets[0])
        w2 = w1 - eta * (beta * g1 + g2)
        np.testing.assert_allclose(np.asarray(delta), np.asarray(w2 - w0),
                                   rtol=1e-5, atol=1e-7)

    def test_prox_pulls_toward_global(self, problem):
        """A large FedProx mu shrinks the local drift."""
        targets, loss = problem
        w0 = jnp.zeros(D)
        k = jax.random.PRNGKey(0)
        d_plain = local_update_spec(loss, w0, targets[0], k,
                                    LocalSpec(momentum=1e-9), tau=8, eta_l=0.3)
        d_prox = local_update_spec(loss, w0, targets[0], k,
                                   LocalSpec(prox_mu=5.0), tau=8, eta_l=0.1)
        assert float(jnp.linalg.norm(d_prox)) < float(jnp.linalg.norm(d_plain))

    def test_pytree_native(self, problem):
        """The spec trainer runs on a raw parameter pytree and matches the
        flat trainer through ravel."""
        targets, _ = problem

        def tree_loss(p, b):
            return 0.5 * jnp.mean(jnp.sum(jnp.square(p["a"] + p["b"] - b), -1))

        params = {"a": jnp.zeros(D), "b": jnp.ones(D)}
        spec = LocalSpec(batch_size=4, epochs=2, momentum=0.5)
        delta = local_update_spec(tree_loss, params, targets[0],
                                  jax.random.PRNGKey(3), spec, tau=1, eta_l=0.2)
        assert set(delta) == {"a", "b"}

        from repro.fedsim.flat import flatten_model
        flat, unravel = flatten_model(params)
        d_flat = local_update_spec(lambda wf, b: tree_loss(unravel(wf), b),
                                   flat, targets[0], jax.random.PRNGKey(3),
                                   spec, tau=1, eta_l=0.2)
        np.testing.assert_allclose(
            np.asarray(flatten_model(delta)[0]), np.asarray(d_flat),
            rtol=1e-6, atol=1e-7)

    def test_minibatch_deterministic_and_round_varying(self, problem):
        targets, loss = problem
        w = jnp.zeros(D)
        spec = LocalSpec(batch_size=3)
        k1, k2 = jax.random.PRNGKey(0), jax.random.PRNGKey(1)
        d1 = cohort_updates_spec(loss, w, targets, spec, 1, 0.3, k1)
        d1b = cohort_updates_spec(loss, w, targets, spec, 1, 0.3, k1)
        d2 = cohort_updates_spec(loss, w, targets, spec, 1, 0.3, k2)
        np.testing.assert_array_equal(np.asarray(d1), np.asarray(d1b))
        assert not np.allclose(np.asarray(d1), np.asarray(d2))

    def test_global_start_offsets_match_full_cohort(self, problem):
        """Shard rows [s, s+k) reproduce the full cohort's rows exactly —
        the key derivation is by GLOBAL client index."""
        targets, loss = problem
        w = jnp.zeros(D)
        spec = LocalSpec(batch_size=3, epochs=2)
        key = jax.random.PRNGKey(5)
        full = cohort_updates_spec(loss, w, targets, spec, 1, 0.3, key)
        shard = cohort_updates_spec(
            loss, w, jax.tree_util.tree_map(lambda x: x[4:10], targets),
            spec, 1, 0.3, key, start=4)
        np.testing.assert_array_equal(np.asarray(full[4:10]), np.asarray(shard))


class TestEngineIntegration:
    def test_minibatch_session_trains(self, problem):
        r = _session(problem, LocalSpec(batch_size=4, epochs=2), rounds=4,
                     eval_fn=lambda w: jnp.sum(jnp.square(w - 0.0))).run(
            jax.random.PRNGKey(7))
        assert np.all(np.isfinite(np.asarray(r.final_w)))

    def test_sharded_minibatch_matches_single_device(self, problem):
        key = jax.random.PRNGKey(7)
        spec = LocalSpec(batch_size=4, momentum=0.5)
        r1 = _session(problem, spec).run(key)
        r2 = _session(problem, spec,
                      shard=ShardSpec(mesh=make_client_mesh())).run(key)
        np.testing.assert_allclose(np.asarray(r1.final_w),
                                   np.asarray(r2.final_w),
                                   rtol=1e-5, atol=1e-5)

    def test_eager_matches_scan_with_spec(self, problem):
        from repro.fedsim import EngineSpec
        key = jax.random.PRNGKey(7)
        spec = LocalSpec(batch_size=4, epochs=2, momentum=0.3)
        r_s = _session(problem, spec).run(key)
        r_e = _session(problem, spec,
                       engine=EngineSpec(engine="eager")).run(key)
        np.testing.assert_array_equal(np.asarray(r_s.final_w),
                                      np.asarray(r_e.final_w))

    def test_resume_bit_exact_with_minibatch(self, problem, tmp_path):
        """Minibatch shuffles derive from fold_in(key, t): resume redraws
        identical batches."""
        key = jax.random.PRNGKey(7)
        spec = LocalSpec(batch_size=4)
        from repro.fedsim import EngineSpec
        r_full = _session(problem, spec, rounds=4,
                          engine=EngineSpec(chunk_rounds=2)).run(key)
        _session(problem, spec, rounds=2).run(key, checkpoint_dir=str(tmp_path))
        r_res = _session(problem, spec, rounds=4).resume(str(tmp_path))
        np.testing.assert_array_equal(np.asarray(r_full.final_w),
                                      np.asarray(r_res.final_w))
