"""Heterogeneous privacy + adaptive noise schedules (DESIGN.md §17).

The §17 contract this file pins:

  1. **Degenerate cases are bit-for-bit.**  A constant ``NoiseSchedule``
     resolves to its inner mechanism's OWN object (same trace), so the
     scheduled registry names with decay 1 reproduce the fixed-sigma runs
     bit-identically; equal per-client epsilons reduce ``PerClientGaussian``
     to ``GaussianLDP`` with the common sigma; the migrated ``dp-scaffold``
     session reproduces the legacy ``run_dp_scaffold`` loop bit-for-bit on
     its supported path (central at any sigma, local at sigma 0).
  2. **Cross-engine parity.**  Every §17 composition — per-client sigmas,
     sigma(t) schedules (exponential + step), DP-SCAFFOLD central/local —
     agrees across scan / eager / stream / gather / sharded engines at
     rtol 1e-5 (scan == eager bit-exact; multi-chunk streams reassociate
     sums, hence the rtol contract, DESIGN.md §12).
  3. **Telemetry tells the truth.**  The per-round ``sigma`` event matches
     the declared schedule at f32 tolerance on every executed round, and the
     §15 cumulative ledger equals ``session.privacy_report`` to 1e-9 under a
     NON-constant schedule — including resumed runs and §13 retried rounds.
  4. **Accounting composes honestly** (hypothesis): the scheduled ledger is
     monotone in executed rounds, permutation-invariant, reduces EXACTLY to
     the uniform accountants on homogeneous schedules, and the heterogeneous
     report is the worst client's guarantee (every client's own budget is
     within it).
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # the property layer needs hypothesis (CI installs it); everything
    import hypothesis.strategies as st  # else below always runs
    from hypothesis import given, settings
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

from repro.core import accounting
from repro.core.compose import (
    CentralGaussian,
    FedEXPStep,
    GaussianLDP,
    NoiseSchedule,
    PerClientGaussian,
    compose_algorithm,
)
from repro.core.fedexp import make_algorithm
from repro.core.mechanisms import per_client_sigmas
from repro.data.synthetic import distance_to_opt, linreg_loss, make_synthetic_linreg
from repro.fedsim import (
    CohortSpec,
    EngineSpec,
    FaultSpec,
    FederatedSession,
    LocalSpec,
    ShardSpec,
    StreamSpec,
    TrainSpec,
)
from repro.fedsim.scaffold import DPScaffoldConfig, run_dp_scaffold
from repro.fedsim.session import RecoveryPolicy
from repro.launch.mesh import make_client_mesh
from repro.telemetry import JsonlTracker, Tracker

M, D, TAU, ETA_L, ROUNDS = 16, 10, 2, 0.1, 4
DELTA = 1e-5  # == TelemetrySpec().ledger_delta, so ledger lines match reports
KEY = jax.random.PRNGKey(11)

# heterogeneous per-client budgets: five distinct epsilon tiers across M
EPS_HETERO = tuple(0.5 + 0.25 * (i % 5) for i in range(M))

# the §17 compositions under test: name -> (algorithm factory, session kw)
ALGS = {
    "ldp-schedule": (
        lambda: make_algorithm("ldp-fedexp-schedule", clip_norm=0.3,
                               sigma=0.3, decay=0.8, boundaries=(2,),
                               scales=(0.5,)),
        {}),
    "cdp-schedule": (
        lambda: make_algorithm("cdp-fedexp-schedule", clip_norm=0.3,
                               sigma=0.25, num_clients=M, decay=0.9),
        {}),
    "perclient": (
        lambda: make_algorithm("ldp-fedexp-perclient", clip_norm=0.3,
                               epsilons=EPS_HETERO, delta=DELTA),
        {}),
    "scaffold-central": (
        lambda: make_algorithm("dp-scaffold", clip_norm=1.0, sigma=0.5,
                               central=True, num_clients=M, tau=TAU,
                               eta_l=ETA_L),
        dict(local=LocalSpec(control_variates=True))),
    "scaffold-local": (
        lambda: make_algorithm("dp-scaffold", clip_norm=1.0, sigma=0.5,
                               central=False, num_clients=M, tau=TAU,
                               eta_l=ETA_L),
        dict(local=LocalSpec(control_variates=True))),
}

RESULT_FIELDS = ("final_w", "last_w", "eta_history", "metric_history",
                 "eta_naive_history", "eta_target_history")


@pytest.fixture(scope="module")
def problem():
    data = make_synthetic_linreg(jax.random.PRNGKey(3), M, D)
    return data, jnp.zeros(D)


def _session(problem, alg, *, rounds=ROUNDS, **spec_kw):
    data, w0 = problem
    return FederatedSession(
        alg, linreg_loss, w0, data.client_batches(),
        train=spec_kw.pop("train",
                          TrainSpec(rounds=rounds, tau=TAU, eta_l=ETA_L)),
        eval_fn=spec_kw.pop("eval_fn", distance_to_opt(data.w_star)),
        **spec_kw)


def _assert_bitwise(r_a, r_b, label=""):
    for field in RESULT_FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(r_a, field)), np.asarray(getattr(r_b, field)),
            err_msg=f"{label}.{field}")


def _assert_close(r_a, r_b, label="", rtol=1e-5, atol=1e-6):
    for field in ("final_w", "last_w", "eta_history"):
        np.testing.assert_allclose(
            np.asarray(getattr(r_a, field)), np.asarray(getattr(r_b, field)),
            rtol=rtol, atol=atol, err_msg=f"{label}.{field}")


class _ListTracker(Tracker):
    """In-memory sink for the sigma/ledger event assertions."""

    def __init__(self):
        self.events = []

    def log(self, step, event):
        self.events.append((step, dict(event)))

    def rounds(self):
        return [e for _, e in self.events if "event" not in e]


# ---------------------------------------------------------------------------
# 1. Degenerate cases are bit-for-bit
# ---------------------------------------------------------------------------

class TestDegenerateBitwise:
    """decay=1 schedules, equal epsilons, and the migrated scaffold all
    reproduce their pre-§17 counterparts bit-identically."""

    @pytest.mark.parametrize("sched,fixed,kw", [
        ("ldp-fedexp-schedule", "ldp-fedexp-gauss",
         dict(clip_norm=0.3, sigma=0.21)),
        ("cdp-fedexp-schedule", "cdp-fedexp",
         dict(clip_norm=0.3, sigma=0.2, num_clients=M)),
    ])
    def test_constant_schedule_is_fixed_sigma(self, problem, sched, fixed, kw):
        alg_s = make_algorithm(sched, **kw)
        # a constant schedule resolves to the inner mechanism's OWN object,
        # so the engines run the identical trace — no round-index threading
        assert not alg_s.needs_round_index
        assert alg_s.mechanism.at_round(3) is alg_s.mechanism.inner
        r_s = _session(problem, alg_s).run(KEY)
        r_f = _session(problem, make_algorithm(fixed, **kw)).run(KEY)
        _assert_bitwise(r_s, r_f, label=sched)

    def test_constant_schedule_budget_is_fixed_budget(self):
        kw = dict(clip_norm=0.3, sigma=0.2, num_clients=M)
        rep_s = make_algorithm("cdp-fedexp-schedule", **kw).budget(
            DELTA, rounds=ROUNDS, dim=D)
        rep_f = make_algorithm("cdp-fedexp", **kw).budget(
            DELTA, rounds=ROUNDS, dim=D)
        assert rep_s == rep_f  # same floats AND same setting string

    def test_equal_epsilons_reduce_to_homogeneous(self, problem):
        """eps_i all equal: the per-client mechanism short-circuits to
        GaussianLDP's expressions with the common sigma — bit-identical
        under the same (mean) aggregation."""
        mech = PerClientGaussian(0.3, (1.0,) * M, DELTA)
        assert mech.n_scalar_extras == 0  # no mixed-noise extra rides psum
        (common,) = set(mech.sigmas)
        r_h = _session(problem,
                       compose_algorithm(mech, FedEXPStep())).run(KEY)
        r_u = _session(problem, compose_algorithm(
            GaussianLDP(0.3, common), FedEXPStep())).run(KEY)
        _assert_bitwise(r_h, r_u, label="perclient-uniform")

    @pytest.mark.parametrize("sigma,central", [
        (2.0, True), (0.0, True), (0.0, False)])
    def test_scaffold_matches_legacy_loop(self, problem, sigma, central):
        """The migrated session path reproduces the deprecated standalone
        loop bit-for-bit: central mode at ANY sigma (the (d,) server draws
        are shared), local mode at sigma 0 (the legacy monolithic (M,d)
        noise draw is replaced by the engine-reproducible per-row stream,
        identical exactly where no noise is drawn)."""
        data, w0 = problem
        cfg = DPScaffoldConfig(clip_norm=1.0, sigma=sigma, central=central,
                               num_clients=M)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            leg = run_dp_scaffold(cfg, linreg_loss, w0, data.client_batches(),
                                  rounds=ROUNDS, tau=TAU, eta_l=ETA_L,
                                  key=KEY, eval_fn=distance_to_opt(data.w_star))
        alg = make_algorithm("dp-scaffold", clip_norm=1.0, sigma=sigma,
                             central=central, num_clients=M, tau=TAU,
                             eta_l=ETA_L)
        mig = _session(problem, alg,
                       local=LocalSpec(control_variates=True)).run(KEY)
        for field in ("final_w", "last_w", "metric_history"):
            np.testing.assert_array_equal(
                np.asarray(getattr(leg, field)),
                np.asarray(getattr(mig, field)), err_msg=field)
        np.testing.assert_array_equal(np.asarray(mig.eta_history),
                                      np.ones(ROUNDS))

    def test_migrated_scaffold_does_not_warn(self, problem):
        """Satellite: only the LEGACY entry point is deprecated — building
        and running the session composition must emit nothing."""
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            alg = make_algorithm("dp-scaffold", clip_norm=1.0, sigma=0.5,
                                 central=True, num_clients=M, tau=TAU,
                                 eta_l=ETA_L)
            _session(problem, alg, rounds=1,
                     local=LocalSpec(control_variates=True)).run(KEY)


# ---------------------------------------------------------------------------
# 2. Cross-engine parity matrix
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def scan_ref(problem):
    """Scan-engine reference runs, built once per algorithm."""
    cache = {}

    def get(name):
        if name not in cache:
            factory, kw = ALGS[name]
            cache[name] = _session(problem, factory(), **kw).run(KEY)
        return cache[name]

    return get


class TestCrossEngineParity:
    """Every §17 composition, every engine, one contract: rtol 1e-5."""

    @pytest.mark.parametrize("name", sorted(ALGS))
    def test_eager_bit_exact(self, problem, scan_ref, name):
        factory, kw = ALGS[name]
        r = _session(problem, factory(),
                     engine=EngineSpec(engine="eager"), **kw).run(KEY)
        _assert_bitwise(r, scan_ref(name), label=f"{name}.eager")

    @pytest.mark.parametrize("name", sorted(ALGS))
    def test_stream_single_chunk(self, problem, scan_ref, name):
        """One covering chunk degenerates to the dense moment program."""
        factory, kw = ALGS[name]
        r = _session(problem, factory(),
                     engine=EngineSpec(engine="stream"),
                     stream=StreamSpec(chunk_clients=M), **kw).run(KEY)
        _assert_close(r, scan_ref(name), label=f"{name}.stream1")

    @pytest.mark.parametrize("name", sorted(ALGS))
    def test_stream_multi_chunk(self, problem, scan_ref, name):
        """Chunked additive moments reassociate the sums: rtol, not bits."""
        factory, kw = ALGS[name]
        r = _session(problem, factory(),
                     engine=EngineSpec(engine="stream"),
                     stream=StreamSpec(chunk_clients=6), **kw).run(KEY)
        _assert_close(r, scan_ref(name), label=f"{name}.streamN")

    @pytest.mark.parametrize("name", sorted(ALGS))
    def test_sharded(self, problem, scan_ref, name):
        """shard_map + psum (runs 1- and 8-device under the CI matrix);
        the scaffold's variate-table update rides the psum as an extra."""
        factory, kw = ALGS[name]
        r = _session(problem, factory(),
                     shard=ShardSpec(mesh=make_client_mesh()), **kw).run(KEY)
        _assert_close(r, scan_ref(name), label=f"{name}.sharded")

    @pytest.mark.parametrize("name", sorted(ALGS))
    def test_gather_matches_dense_sampled(self, problem, name):
        """Sampled cohorts: the §14 gathered slot table must be the same
        release as the dense masked round (per-client noise and the
        per-client sigma/variate rows key by GLOBAL index)."""
        factory, kw = ALGS[name]
        dense = _session(problem, factory(),
                         cohort=CohortSpec(q=0.5), **kw).run(KEY)
        sparse = _session(problem, factory(),
                          cohort=CohortSpec(q=0.5, gather=True), **kw).run(KEY)
        _assert_close(sparse, dense, label=f"{name}.gather")


# ---------------------------------------------------------------------------
# 3. Telemetry: per-round sigma + the ledger under non-constant schedules
# ---------------------------------------------------------------------------

class TestSigmaTelemetry:
    def test_schedule_sigma_tracks_declared_schedule(self, problem):
        alg = make_algorithm("ldp-fedexp-schedule", clip_norm=0.3, sigma=0.3,
                             decay=0.8, boundaries=(2,), scales=(0.5,))
        sink = _ListTracker()
        _session(problem, alg).run(KEY, tracker=sink)
        rounds = sink.rounds()
        assert len(rounds) == ROUNDS
        for t, event in enumerate(rounds):
            want = alg.mechanism.sigma_value(t)
            # the device computes sigma(t) in f32; compare at f32 rtol
            assert event["sigma"] == pytest.approx(want, rel=1e-5), t
        # the step drop actually happened: sigma(2) < sigma(1) * decay
        assert rounds[2]["sigma"] < 0.9 * rounds[1]["sigma"] * 0.8

    def test_validator_pins_exponential_schedule(self, problem, tmp_path):
        """tools/check_telemetry.py --sigma0/--sigma-decay accepts the
        emitted stream and rejects a wrong declaration (the CI smoke)."""
        import os
        import sys
        sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                        "tools"))
        from check_telemetry import check_stream
        alg = make_algorithm("cdp-fedexp-schedule", clip_norm=0.3, sigma=0.25,
                             num_clients=M, decay=0.9)
        out = tmp_path / "sched.jsonl"
        _session(problem, alg).run(KEY, tracker=JsonlTracker(str(out)))
        text = out.read_text().splitlines()
        assert check_stream(text, rounds=ROUNDS, sigma0=0.25,
                            sigma_decay=0.9) == []
        bad = check_stream(text, rounds=ROUNDS, sigma0=0.25, sigma_decay=0.8)
        assert len(bad) == ROUNDS - 1  # every round but t=0 breaks the pin

    def test_fixed_sigma_algorithms_emit_constant_sigma(self, problem):
        sink = _ListTracker()
        alg = make_algorithm("cdp-fedexp", clip_norm=0.3, sigma=0.2,
                             num_clients=M)
        _session(problem, alg).run(KEY, tracker=sink)
        # the tap payload is f32: 0.2 round-trips at f32 resolution
        assert all(e["sigma"] == pytest.approx(0.2, rel=1e-6)
                   for e in sink.rounds())

    def test_scaffold_emits_its_sigma(self, problem):
        sink = _ListTracker()
        alg = make_algorithm("dp-scaffold", clip_norm=1.0, sigma=0.5,
                             central=True, num_clients=M, tau=TAU,
                             eta_l=ETA_L)
        _session(problem, alg,
                 local=LocalSpec(control_variates=True)).run(KEY, tracker=sink)
        assert all(e["sigma"] == 0.5 for e in sink.rounds())

    def test_non_private_omits_sigma(self, problem):
        sink = _ListTracker()
        _session(problem, make_algorithm("fedexp")).run(KEY, tracker=sink)
        assert all("sigma" not in e for e in sink.rounds())


class TestScheduleLedger:
    """privacy_report == the §15 ledger to 1e-9 under NON-constant sigma."""

    SCHED_KW = dict(clip_norm=0.3, sigma=0.25, num_clients=M, decay=0.9)

    def _sched_session(self, problem, *, rounds=6, **kw):
        return _session(problem,
                        make_algorithm("cdp-fedexp-schedule", **self.SCHED_KW),
                        rounds=rounds, **kw)

    def test_ledger_is_the_composed_schedule(self, problem):
        sink = _ListTracker()
        sess = self._sched_session(problem)
        sess.run(KEY, tracker=sink)
        rounds = sink.rounds()
        alg = sess.algorithm
        for t, event in enumerate(rounds):
            # every prefix of the ledger is the honest composition of the
            # sigmas actually executed so far — not T-th of the final budget
            rep = alg.budget(DELTA, rounds=t + 1, dim=D)
            assert event["ledger_rounds"] == t + 1
            assert abs(event["mu"] - rep.mu) < 1e-9
            assert abs(event["eps"] - rep.eps_numerical) < 1e-9
            assert abs(event["eps_rdp"] - rep.eps_rdp) < 1e-9
        rep = sess.privacy_report(DELTA)
        assert abs(rounds[-1]["eps"] - rep.eps_numerical) < 1e-9
        assert abs(rounds[-1]["mu"] - rep.mu) < 1e-9

    def test_decaying_sigma_ledger_accelerates(self, problem):
        """Decaying sigma spends MORE per later round: the per-round mu
        increments strictly increase (the honest non-uniform composition,
        not a uniform T-fold average)."""
        sink = _ListTracker()
        self._sched_session(problem).run(KEY, tracker=sink)
        mus = [e["mu"] for e in sink.rounds()]
        inc = np.diff(np.square(mus))  # GDP composes in mu^2
        assert np.all(inc > 0)
        assert np.all(np.diff(inc) > 0)

    def test_resume_continues_the_ledger(self, problem, tmp_path):
        ck = str(tmp_path / "ck")
        self._sched_session(problem, rounds=3).run(KEY, checkpoint_dir=ck)
        sink = _ListTracker()
        sess = self._sched_session(problem)
        r = sess.resume(ck, tracker=sink)
        rounds = sink.rounds()
        assert [e["ledger_rounds"] for e in rounds] == [4, 5, 6]
        rep = sess.privacy_report(DELTA)
        assert abs(rounds[-1]["eps"] - rep.eps_numerical) < 1e-9
        # and the resumed trajectory is the uninterrupted one, sigma(t)
        # indexed by the ABSOLUTE round across the checkpoint boundary
        r_ref = self._sched_session(problem).run(KEY)
        np.testing.assert_array_equal(np.asarray(r_ref.final_w),
                                      np.asarray(r.final_w))

    def test_retried_rounds_charge_the_ledger(self, problem, tmp_path):
        """§13 recovery under a schedule: rolled-back rounds re-execute with
        their ORIGINAL sigma(t) (bit-exact with an unkilled run) and the
        retries join the composition the report and ledger agree on."""
        sess = self._sched_session(problem, fault=FaultSpec(watchdog=True),
                                   engine=EngineSpec(chunk_rounds=2))

        def poison_first_attempt(carry, attempt):
            if attempt >= 1:
                return carry
            w = carry[0].at[0].set(jnp.nan)
            return (w,) + tuple(carry[1:])

        sess._inject_divergence = poison_first_attempt
        sink = _ListTracker()
        r = sess.run(KEY, checkpoint_dir=str(tmp_path / "ck"),
                     checkpoint_every=2,
                     on_divergence=RecoveryPolicy(max_retries=2),
                     tracker=sink)
        assert r.fault_round is None
        last = sink.rounds()[-1]
        assert last["ledger_rounds"] == 6 + 1  # one round re-run
        rep = sess.privacy_report(DELTA)
        assert abs(last["eps"] - rep.eps_numerical) < 1e-9
        assert abs(last["mu"] - rep.mu) < 1e-9
        r_ref = self._sched_session(problem, fault=FaultSpec(watchdog=True),
                                    engine=EngineSpec(chunk_rounds=2)).run(KEY)
        np.testing.assert_array_equal(np.asarray(r_ref.final_w),
                                      np.asarray(r.final_w))

    def test_scaffold_ledger_matches_report(self, problem):
        """The two-release scaffold accounting rides the same ledger."""
        sink = _ListTracker()
        alg = make_algorithm("dp-scaffold", clip_norm=1.0, sigma=0.5,
                             central=True, num_clients=M, tau=TAU,
                             eta_l=ETA_L)
        sess = _session(problem, alg, local=LocalSpec(control_variates=True))
        sess.run(KEY, tracker=sink)
        last = sink.rounds()[-1]
        rep = sess.privacy_report(DELTA)
        assert "SCAFFOLD" in rep.setting
        assert abs(last["eps"] - rep.eps_numerical) < 1e-9
        assert abs(last["mu"] - rep.mu) < 1e-9


# ---------------------------------------------------------------------------
# 4. Construction / spec validation
# ---------------------------------------------------------------------------

class TestValidation:
    def test_scaffold_requires_control_variates_spec(self, problem):
        alg = make_algorithm("dp-scaffold", clip_norm=1.0, sigma=0.5,
                             central=True, num_clients=M, tau=TAU,
                             eta_l=ETA_L)
        with pytest.raises(ValueError, match="control_variates"):
            _session(problem, alg)

    def test_control_variates_requires_scaffold_algorithm(self, problem):
        with pytest.raises(ValueError, match="control_variates"):
            _session(problem, make_algorithm("fedexp"),
                     local=LocalSpec(control_variates=True))

    def test_control_variates_excludes_minibatch_fields(self):
        with pytest.raises(ValueError, match="control_variates"):
            LocalSpec(control_variates=True, batch_size=4)

    def test_scaffold_table_must_match_cohort(self, problem):
        alg = make_algorithm("dp-scaffold", clip_norm=1.0, sigma=0.5,
                             central=True, num_clients=M + 1, tau=TAU,
                             eta_l=ETA_L)
        with pytest.raises(ValueError, match="num_clients"):
            _session(problem, alg,
                     local=LocalSpec(control_variates=True)).run(KEY)

    def test_schedule_wraps_only_fixed_sigma_gaussians(self):
        with pytest.raises(ValueError, match="fixed-sigma"):
            NoiseSchedule(inner=CentralGaussian(z_mult=0.5, num_clients=M),
                          decay=0.9)
        with pytest.raises(ValueError, match="NoiseSchedule wraps"):
            NoiseSchedule(inner=PerClientGaussian(0.3, (1.0,) * 4, DELTA),
                          decay=0.9)

    def test_schedule_boundary_validation(self):
        inner = GaussianLDP(0.3, 0.21)
        with pytest.raises(ValueError, match="boundaries"):
            NoiseSchedule(inner=inner, boundaries=(3, 1), scales=(0.5, 0.5))
        with pytest.raises(ValueError, match="one-to-one"):
            NoiseSchedule(inner=inner, boundaries=(2,), scales=())
        with pytest.raises(ValueError, match="decay"):
            NoiseSchedule(inner=inner, decay=0.0)

    def test_per_client_epsilon_validation(self):
        with pytest.raises(ValueError, match="epsilons"):
            PerClientGaussian(0.3, (), DELTA)
        with pytest.raises(ValueError, match="positive"):
            per_client_sigmas((1.0, -1.0), DELTA, 0.3)

    def test_schedule_budget_needs_positive_sigma(self):
        alg = make_algorithm("dp-scaffold", clip_norm=1.0, sigma=0.0,
                             central=True, num_clients=M, tau=TAU,
                             eta_l=ETA_L)
        with pytest.raises(ValueError):
            alg.budget(DELTA, rounds=ROUNDS, dim=D)


# ---------------------------------------------------------------------------
# 5. Accounting properties (hypothesis; pure-python, no jax)
# ---------------------------------------------------------------------------

if HAS_HYPOTHESIS:
    # sigma >= 0.5 keeps every composed mu below ~3.4, where gdp_epsilon's
    # bisection is numerically monotone (the Balle-Wang delta(eps) suffers
    # tail cancellation past mu ~3.9 / eps ~24 — a regime where the
    # guarantee is vacuous anyway); mu itself is asserted monotone exactly
    SIGMAS = st.lists(st.floats(0.5, 5.0, allow_nan=False), min_size=1,
                      max_size=8)
    PROP = settings(max_examples=50, deadline=None)

    class TestAccountingProperties:
        @PROP
        @given(sigmas=SIGMAS)
        def test_schedule_ledger_is_monotone(self, sigmas):
            """Executing one more round never refunds budget: mu and eps of
            every prefix are nondecreasing (the §15 ledger invariant)."""
            mus, epss = [], []
            for t in range(1, len(sigmas) + 1):
                rep = accounting.schedule_ldp_budget(0.3, sigmas[:t], DELTA)
                mus.append(rep.mu)
                epss.append(rep.eps_numerical)
            assert all(a < b + 1e-12 for a, b in zip(mus, mus[1:]))
            assert all(a < b + 1e-9 for a, b in zip(epss, epss[1:]))

        @PROP
        @given(sigmas=SIGMAS, data=st.data())
        def test_composition_is_permutation_invariant(self, sigmas, data):
            """WHEN noise is spent must not matter, only the multiset of
            per-round scales — for the exact q=1 composition and the
            sampled CLT alike."""
            perm = data.draw(st.permutations(sigmas))
            for q in (1.0, 0.25):
                a = accounting.composed_gdp_mu(
                    [2.0 * 0.3 / s for s in sigmas], q=q)
                b = accounting.composed_gdp_mu(
                    [2.0 * 0.3 / s for s in perm], q=q)
                assert a == pytest.approx(b, rel=1e-9)

        @PROP
        @given(sigma=st.floats(0.1, 5.0), rounds=st.integers(1, 20),
               q=st.sampled_from([1.0, 0.25]))
        def test_homogeneous_reduction_is_exact(self, sigma, rounds, q):
            """A uniform schedule must reproduce the uniform accountants
            with the SAME floats — the degenerate case never drifts."""
            mu_u = accounting.composed_gdp_mu([2.0 * 0.3 / sigma] * rounds, q)
            assert mu_u == accounting.subsampled_gdp_mu(2.0 * 0.3 / sigma, q,
                                                        rounds)
            rep_s = accounting.schedule_cdp_budget(0.3, [sigma] * rounds, M,
                                                   DELTA, sampling_q=q)
            rep_f = accounting.cdp_budget(0.3, sigma, M, rounds, DELTA,
                                          sampling_q=q)
            assert rep_s.mu == rep_f.mu
            assert rep_s.eps_numerical == rep_f.eps_numerical
            # rho accumulates per round vs rounds*x: same to float precision
            assert rep_s.eps_rdp == pytest.approx(rep_f.eps_rdp, rel=1e-12)

        @PROP
        @given(eps=st.lists(st.floats(0.2, 8.0), min_size=1, max_size=12))
        def test_heterogeneous_report_is_worst_client(self, eps):
            """The per-client report is the WORST client's guarantee: every
            client's own single-release budget fits within it, and it equals
            the largest-epsilon client's own bound."""
            mech = PerClientGaussian(0.3, tuple(eps), DELTA)
            rep = mech.budget(DELTA, rounds=1, dim=D, sampling_q=1.0,
                              with_numerator=False)
            own = [accounting.ldp_gaussian_budget(0.3, s, DELTA)
                   for s in mech.sigmas]
            assert all(o.mu <= rep.mu + 1e-12 for o in own)
            assert rep.mu == max(o.mu for o in own)
            # calibration inverts the GDP curve: the report recovers the
            # declared worst epsilon (bisection tolerance)
            assert rep.eps_numerical == pytest.approx(max(eps), rel=1e-6)

        @PROP
        @given(eps=st.lists(st.floats(0.2, 8.0), min_size=2, max_size=12,
                            unique=True))
        def test_sigma_calibration_is_antitone(self, eps):
            """A bigger budget buys a smaller sigma, strictly."""
            sigmas = per_client_sigmas(tuple(sorted(eps)), DELTA, 0.3)
            assert all(a > b for a, b in zip(sigmas, sigmas[1:]))

        @PROP
        @given(sigma=st.floats(0.2, 2.0), rounds=st.integers(2, 10),
               decay=st.floats(0.5, 0.99))
        def test_decay_spends_more_than_constant(self, sigma, rounds, decay):
            """sigma(t) <= sigma0 everywhere implies the schedule's budget
            dominates the constant-sigma0 run — and is itself dominated by
            the constant run at the schedule's SMALLEST sigma."""
            sig = [sigma * decay ** t for t in range(rounds)]
            rep = accounting.schedule_ldp_budget(0.3, sig, DELTA)
            lo = accounting.schedule_ldp_budget(0.3, [sigma] * rounds, DELTA)
            hi = accounting.schedule_ldp_budget(0.3, [sig[-1]] * rounds, DELTA)
            assert lo.mu <= rep.mu <= hi.mu
