"""Compressed-communication layer: rand-k + count-sketch (DESIGN.md §16).

The §16 contract, pinned here:

* SCALAR-moment parity — compression changes what ``sum_c`` carries, never
  the scalar moments FedEXP's step-size rule consumes: for every
  compression-legal registry composition, the compressed ``local_moments``
  scalar sums (``sum_sq``, ``sum_sq_clipped``, ``count``, and every scalar
  extra — clip bits, PrivUnit sums) match the dense ones at rtol 1e-5.
* Cross-engine parity — a compressed composition is ONE algorithm on every
  engine: scan == stream (ragged chunk grid) == sampled-gather ==
  sharded (the §9 psum carries the (kc,) moments), at the engines' usual
  rtol.
* Lossless parity — ``RandKAggregation(k=d)`` keeps the map invertible, so
  the full compressed pipeline (COMPRESS_TAG plan, compressed-domain
  noise hook, decompress, η from the scalar moments) must reproduce the
  dense run: final weights AND η history at rtol 1e-5 for the noiseless
  compositions.
* Privacy boundaries — LDP mechanisms reject compression at composition
  time (their release is a full R^d vector per client; nothing sound to
  compress), the chunked kernel entry rejects ``noise`` + ``compress_fn``,
  and EF without top_k has nothing to feed back.
* Error feedback — the biased top-k sketch variant carries its truncation
  residual in a ``CompressionCarry`` that rides the engines' existing scan
  state, and still makes round-over-round progress.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aggregation import partial_clip_moments
from repro.core.compose import (
    CompressionCarry,
    CountSketchAggregation,
    FedEXPStep,
    GaussianLDP,
    RandKAggregation,
    WeightedAggregation,
    compose_algorithm,
    with_compression,
)
from repro.core.fedexp import make_algorithm
from repro.data.synthetic import linreg_loss, make_synthetic_linreg
from repro.fedsim import (
    CohortSpec,
    EngineSpec,
    FederatedSession,
    ShardSpec,
    StreamSpec,
    TrainSpec,
)
from repro.kernels.dp_aggregate.ops import dp_aggregate_sums_chunked
from repro.launch.mesh import make_client_mesh

# same ragged geometry as test_stream: M not divisible by the chunk size
M, D, TAU, ETA_L, ROUNDS, CHUNK = 44, 24, 2, 0.1, 4, 16
K = 8                      # rand-k keeps 8 of 24 coordinates
WIDTH, DEPTH = 6, 3        # sketch: 3 tables of width 6

# compression-legal registry names: central noise (added to the compressed
# aggregate, post-reduction) or no privacy at all
COMPRESS_OK = {
    "fedavg": {},
    "fedexp": {},
    "dp-fedavg-cdp": dict(clip_norm=0.3, sigma=0.2, num_clients=M),
    "cdp-fedexp": dict(clip_norm=0.3, sigma=0.2, num_clients=M),
    "dp-fedadam-cdp": dict(clip_norm=0.3, sigma=0.2, num_clients=M,
                           server_lr=0.05),
    "cdp-fedexp-adaptive-clip": dict(z_mult=0.5, num_clients=M, dim=D),
    "cdp-fedmom": dict(clip_norm=0.3, sigma=0.2, num_clients=M),
}
# LDP names: per-client noise drawn BEFORE aggregation -> must reject
LDP_NAMES = {
    "dp-fedavg-ldp-gauss": dict(clip_norm=0.3, sigma=0.21),
    "ldp-fedexp-gauss": dict(clip_norm=0.3, sigma=0.21),
    "dp-fedavg-privunit": dict(clip_norm=0.3, eps0=2.0, eps1=2.0, eps2=2.0,
                               dim=D),
    "ldp-fedexp-privunit": dict(clip_norm=0.3, eps0=2.0, eps1=2.0, eps2=2.0,
                                dim=D),
    "ldp-gauss-fedadam": dict(clip_norm=0.3, sigma=0.21, server_lr=0.05),
    "privunit-fedexp-adaptive-clip": dict(eps0=2.0, eps1=2.0, eps2=2.0,
                                          dim=D, c0=0.5),
}

KEY = jax.random.PRNGKey(17)
N_DEV = len(jax.devices())

# the compression-legal registry sweeps run two full sessions per case: the
# two representatives here (cheapest + the canonical CDP composition) stay
# unmarked so `-m "not slow"` keeps the compressed parity PATH covered,
# while the rest carry the `slow` marker (CI always runs the full matrix)
FAST_PARITY = ("fedavg", "cdp-fedexp")


def _sweep(names):
    return [n if n in FAST_PARITY else pytest.param(n, marks=pytest.mark.slow)
            for n in names]


def _alg(name, aggregation=None):
    alg = make_algorithm(name, **COMPRESS_OK[name])
    return alg if aggregation is None else with_compression(alg, aggregation)


AGGREGATIONS = [
    ("randk", RandKAggregation(k=K)),
    ("sketch", CountSketchAggregation(width=WIDTH, depth=DEPTH)),
]


@pytest.fixture(scope="module")
def problem():
    data = make_synthetic_linreg(jax.random.PRNGKey(3), M, D)
    return data.client_batches(), jnp.zeros(D)


def _session(problem, name, aggregation=None, *, engine=None, stream=None,
             cohort=None, shard=None):
    batches, w0 = problem
    kw = {}
    if engine is not None:
        kw["engine"] = engine
    if stream is not None:
        kw["stream"] = stream
    if cohort is not None:
        kw["cohort"] = cohort
    if shard is not None:
        kw["shard"] = shard
    return FederatedSession(_alg(name, aggregation), linreg_loss, w0, batches,
                            train=TrainSpec(rounds=ROUNDS, tau=TAU,
                                            eta_l=ETA_L), **kw)


def _assert_runs_close(a, b, rtol=1e-5, atol=1e-6):
    np.testing.assert_allclose(np.asarray(a.final_w), np.asarray(b.final_w),
                               rtol=rtol, atol=atol)
    np.testing.assert_allclose(np.asarray(a.eta_history),
                               np.asarray(b.eta_history),
                               rtol=rtol, atol=atol)


class TestScalarMomentParity:
    """Compression must not move any scalar the step-size rule reads."""

    @pytest.mark.parametrize("name", sorted(COMPRESS_OK))
    @pytest.mark.parametrize("agg_name,agg", AGGREGATIONS)
    def test_scalar_moments_match_dense(self, name, agg_name, agg):
        deltas = 0.3 * jax.random.normal(jax.random.PRNGKey(5), (M, D))
        mask = jnp.ones((M,), jnp.float32)
        w = jnp.zeros(D)

        dense_alg = _alg(name)
        comp_alg = _alg(name, agg)
        mom_d, ex_d = dense_alg.local_moments(
            KEY, w, deltas, mask, 0, dense_alg.init_state(w))
        mom_c, ex_c = comp_alg.local_moments(
            KEY, w, deltas, mask, 0, comp_alg.init_state(w))

        assert mom_c.sum_c.shape == (comp_alg.aggregation.comm_floats(D),)
        for field in ("sum_sq", "sum_sq_clipped", "count"):
            np.testing.assert_allclose(
                np.asarray(getattr(mom_c, field)),
                np.asarray(getattr(mom_d, field)), rtol=1e-5,
                err_msg=f"{name}+{agg_name}: scalar moment {field} moved")
        assert set(ex_c) == set(ex_d)
        for k, v in ex_d.items():
            np.testing.assert_allclose(np.asarray(ex_c[k]), np.asarray(v),
                                       rtol=1e-5,
                                       err_msg=f"{name}+{agg_name}: extra {k}")

    def test_comm_floats_model(self):
        """The §16 communication model: payload + 3 scalar moments (+1
        clip-bit count for adaptive-clip compositions)."""
        assert _alg("cdp-fedexp").comm_floats(D) == D + 3
        assert _alg("cdp-fedexp", RandKAggregation(k=K)).comm_floats(D) == K + 3
        assert _alg("cdp-fedexp",
                    CountSketchAggregation(width=WIDTH, depth=DEPTH)
                    ).comm_floats(D) == WIDTH * DEPTH + 3
        assert (_alg("cdp-fedexp-adaptive-clip",
                     RandKAggregation(k=K)).comm_floats(D) == K + 3 + 1)
        # k >= d never inflates the payload past dense
        assert RandKAggregation(k=10 * D).comm_floats(D) == D


class TestCrossEngineParity:
    """One compressed algorithm, every engine (DESIGN.md §16 interaction
    rules): the (kc,) moments accumulate/psum through the §12 machinery."""

    @pytest.mark.parametrize("name", _sweep(sorted(COMPRESS_OK)))
    def test_stream_matches_scan(self, problem, name):
        agg = RandKAggregation(k=K)
        scan = _session(problem, name, agg).run(KEY)
        stream = _session(problem, name, agg,
                          engine=EngineSpec(engine="stream"),
                          stream=StreamSpec(chunk_clients=CHUNK)).run(KEY)
        _assert_runs_close(stream, scan)

    @pytest.mark.parametrize("name", _sweep(sorted(COMPRESS_OK)))
    def test_gather_matches_dense_sampled(self, problem, name):
        agg = RandKAggregation(k=K)
        cohort = CohortSpec(size=12)
        dense = _session(problem, name, agg, cohort=cohort).run(KEY)
        gathered = _session(problem, name, agg,
                            cohort=CohortSpec(size=12, gather=True)).run(KEY)
        _assert_runs_close(gathered, dense)

    def test_sketch_streams(self, problem):
        agg = CountSketchAggregation(width=WIDTH, depth=DEPTH)
        scan = _session(problem, "cdp-fedexp", agg).run(KEY)
        stream = _session(problem, "cdp-fedexp", agg,
                          engine=EngineSpec(engine="stream"),
                          stream=StreamSpec(chunk_clients=CHUNK)).run(KEY)
        _assert_runs_close(stream, scan)

    @pytest.mark.skipif(N_DEV < 2, reason="needs >1 device (set XLA_FLAGS="
                        "--xla_force_host_platform_device_count=8)")
    @pytest.mark.parametrize("name", ["cdp-fedexp", "fedexp",
                                      "cdp-fedexp-adaptive-clip"])
    def test_sharded_matches_single(self, problem, name):
        """The §9 psum payload IS the compressed moment pytree: every shard
        rebuilds the identical COMPRESS_TAG plan from the replicated round
        key, so the (kc,) partial sums are summands of one linear map."""
        agg = RandKAggregation(k=K)
        single = _session(problem, name, agg).run(KEY)
        mesh = make_client_mesh(2)
        sharded = _session(problem, name, agg,
                           shard=ShardSpec(mesh=mesh)).run(KEY)
        _assert_runs_close(sharded, single)


class TestLosslessParity:
    """k = d keeps the rand-k map invertible: the entire compressed pipeline
    must reproduce the dense run, η history included — which also pins that
    FedEXP's η comes from the UNCOMPRESSED scalar moments."""

    @pytest.mark.parametrize("name", ["fedavg", "fedexp"])
    def test_lossless_randk_matches_dense(self, problem, name):
        dense = _session(problem, name).run(KEY)
        lossless = _session(problem, name, RandKAggregation(k=D)).run(KEY)
        _assert_runs_close(lossless, dense)

    def test_lossless_cdp_eta_close(self, problem):
        """With central noise the realization differs (compressed_noise draws
        per compressed cell), but at sigma -> 0 the η trajectory must agree."""
        batches, w0 = problem
        train = TrainSpec(rounds=ROUNDS, tau=TAU, eta_l=ETA_L)
        mk = lambda agg: with_compression(  # noqa: E731
            make_algorithm("cdp-fedexp", clip_norm=0.3, sigma=1e-7,
                           num_clients=M), agg) if agg else \
            make_algorithm("cdp-fedexp", clip_norm=0.3, sigma=1e-7,
                           num_clients=M)
        dense = FederatedSession(mk(None), linreg_loss, w0, batches,
                                 train=train).run(KEY)
        lossless = FederatedSession(mk(RandKAggregation(k=D)), linreg_loss,
                                    w0, batches, train=train).run(KEY)
        _assert_runs_close(lossless, dense, rtol=1e-4, atol=1e-5)


class TestPrivacyBoundaries:
    @pytest.mark.parametrize("name", sorted(LDP_NAMES))
    @pytest.mark.parametrize("agg", [RandKAggregation(k=K),
                                     CountSketchAggregation(width=WIDTH)])
    def test_ldp_rejects_compression(self, name, agg):
        alg = make_algorithm(name, **LDP_NAMES[name])
        with pytest.raises(ValueError, match="LDP mechanism releases a full"):
            with_compression(alg, agg)

    def test_weighted_rejects_silent_replacement(self):
        alg = compose_algorithm(
            GaussianLDP(0.3, 0.21), FedEXPStep(),
            WeightedAggregation(weights=tuple(1.0 for _ in range(M))))
        with pytest.raises(ValueError, match="weighted aggregation"):
            with_compression(alg, RandKAggregation(k=K))

    def test_chunked_kernel_rejects_noise_plus_compress(self):
        u = jax.random.normal(jax.random.PRNGKey(0), (8, D))
        noise = jnp.zeros((8, D))
        with pytest.raises(ValueError, match="LDP noise"):
            dp_aggregate_sums_chunked(u, 0.3, noise, chunk_m=4,
                                      compress_fn=lambda x: x[..., :K])

    def test_moments_reject_noise_plus_compress(self):
        u = jax.random.normal(jax.random.PRNGKey(0), (8, D))
        with pytest.raises(ValueError, match="compress_fn cannot combine"):
            partial_clip_moments(u, 0.3, jnp.zeros((8, D)),
                                 compress_fn=lambda x: x[..., :K])

    def test_ef_without_topk_rejected(self):
        with pytest.raises(ValueError, match="error_feedback without top_k"):
            CountSketchAggregation(width=WIDTH, error_feedback=True)

    def test_names_tag_the_variant(self):
        assert _alg("cdp-fedexp", RandKAggregation(k=K)).name == \
            f"cdp-fedexp+randk{K}"
        assert _alg("fedavg", CountSketchAggregation(
            width=WIDTH, depth=DEPTH, top_k=4, error_feedback=True)).name == \
            f"fedavg+sketch{WIDTH}x{DEPTH}-top4-ef"


class TestChunkedKernelCompression:
    def test_chunked_compressed_sums_match_dense_compressed(self):
        """Linearity makes the chunked compressed sum equal the dense one
        (re-associated at chunk boundaries only)."""
        u = 0.5 * jax.random.normal(jax.random.PRNGKey(2), (16, D))
        idx = jnp.arange(K, dtype=jnp.int32) * (D // K)
        compress = lambda x: jnp.take(x, idx, axis=-1)  # noqa: E731
        sum_c, sum_sq, sum_sq_clip = dp_aggregate_sums_chunked(
            u, 0.3, chunk_m=4, compress_fn=compress)
        mom = partial_clip_moments(u, 0.3, compress_fn=compress)
        assert sum_c.shape == (K,)
        np.testing.assert_allclose(np.asarray(sum_c), np.asarray(mom.sum_c),
                                   rtol=1e-5, atol=1e-7)
        np.testing.assert_allclose(np.asarray(sum_sq_clip),
                                   np.asarray(mom.sum_sq_clipped), rtol=1e-5)
        np.testing.assert_allclose(np.asarray(sum_sq),
                                   np.asarray(mom.sum_sq), rtol=1e-5)


class TestErrorFeedback:
    def _quad_problem(self):
        rng = np.random.default_rng(1)
        targets = jnp.asarray(rng.standard_normal((M, D)).astype(np.float32)
                              * 0.2 + 0.5)

        def loss(w, b):
            return 0.5 * jnp.sum(jnp.square(w - b))

        return loss, targets

    def test_ef_carry_rides_the_scan_state(self):
        alg = _alg("fedavg", CountSketchAggregation(
            width=WIDTH, depth=DEPTH, top_k=4, error_feedback=True))
        state = alg.init_state(jnp.zeros(D))
        assert isinstance(state, CompressionCarry)
        assert state.ef.shape == (D,)

    def test_ef_sketch_converges(self):
        """The biased top-k sketch with EF still makes progress on a
        quadratic: the truncation residual re-injects instead of vanishing."""
        loss, targets = self._quad_problem()
        alg = _alg("fedavg", CountSketchAggregation(
            width=WIDTH, depth=DEPTH, top_k=D // 2, error_feedback=True))
        w0 = jnp.zeros(D)
        res = FederatedSession(
            alg, loss, w0, targets,
            train=TrainSpec(rounds=12, tau=1, eta_l=0.5)).run(KEY)
        mean_t = np.asarray(jnp.mean(targets, axis=0))

        def mean_loss(w):
            return float(np.mean(0.5 * np.sum(
                np.square(np.asarray(w)[None, :] - np.asarray(targets)), -1)))

        assert np.all(np.isfinite(np.asarray(res.final_w)))
        # moved decisively toward the optimum (the cohort-mean target)
        d0 = float(np.linalg.norm(mean_t))
        d1 = float(np.linalg.norm(np.asarray(res.final_w) - mean_t))
        assert d1 < 0.6 * d0
        assert mean_loss(res.final_w) < mean_loss(w0)
